//! End-to-end driver (the repository's full-system workload): online
//! policy evaluation on the synthetic-ALE benchmark — 277-dimensional
//! partially observable observations, scripted expert policies, reward
//! cumulants — with a CCN learner against the equal-budget T-BPTT
//! baseline, exactly the Section-5 protocol at reduced step count.
//!
//! ```bash
//! cargo run --release --example atari_prediction -- [game] [steps] [seeds]
//! ```
//! Defaults: pong, 500k steps, 2 seeds. Results land in
//! results/atari_<game>.json and a learning-curve CSV next to it.

use std::path::Path;

use ccn_rtrl::config::{EnvKind, ExperimentConfig, LearnerKind};
use ccn_rtrl::coordinator::{aggregate_runs, run_sweep, sweep};
use ccn_rtrl::env::synthatari;
use ccn_rtrl::metrics::{render_table, write_csv};
use ccn_rtrl::util::json::Json;

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let game = args.get(1).cloned().unwrap_or_else(|| "pong".to_string());
    let steps: u64 = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(500_000);
    let n_seeds: u64 = args.get(3).and_then(|s| s.parse().ok()).unwrap_or(2);
    assert!(
        synthatari::env_names().contains(&game.as_str()),
        "unknown game {game}; try one of {:?}",
        synthatari::env_names()
    );

    // Table-1 Atari configs: CCN 5 features/stage; T-BPTT 5:8 (≈50k ops).
    let methods: Vec<(&str, LearnerKind)> = vec![
        (
            "ccn",
            LearnerKind::Ccn {
                total: 15,
                per_stage: 5,
                steps_per_stage: (steps / 3).max(1),
            },
        ),
        ("tbptt 8:5", LearnerKind::Tbptt { d: 8, k: 5 }),
    ];

    let mut configs = Vec::new();
    for (_, learner) in &methods {
        let base = ExperimentConfig {
            env: EnvKind::SynthAtari { game: game.clone() },
            learner: learner.clone(),
            alpha: 0.001,
            lambda: 0.99,
            gamma_override: None, // stream prescribes 0.98
            eps: 0.1,
            steps,
            seed: 0,
            curve_points: 40,
        };
        configs.extend(sweep::seeds(&base, &(0..n_seeds).collect::<Vec<_>>()));
    }

    eprintln!(
        "atari-prediction[{game}]: {} runs x {steps} steps on {} threads",
        configs.len(),
        sweep::default_threads()
    );
    let res = run_sweep(configs, sweep::default_threads());
    let aggs = aggregate_runs(&res.runs);

    let tbptt_tail = aggs
        .iter()
        .find(|a| a.learner.starts_with("tbptt"))
        .map(|a| a.tail_mean)
        .unwrap_or(f64::NAN);

    let mut rows = Vec::new();
    for a in &aggs {
        rows.push(vec![
            a.learner.clone(),
            format!("{:.6}", a.tail_mean),
            format!("{:.6}", a.tail_stderr),
            format!("{:.3}", a.tail_mean / tbptt_tail),
            format!("{:.0} steps/s", a.mean_steps_per_sec),
        ]);
    }
    println!(
        "{}",
        render_table(
            &["learner", "final err", "±se", "rel. to T-BPTT", "speed"],
            &rows
        )
    );

    // persist: aggregate JSON + curve CSV (Fig-8-style artifacts)
    std::fs::create_dir_all("results").ok();
    let json = Json::Arr(aggs.iter().map(|a| a.to_json()).collect());
    std::fs::write(
        format!("results/atari_{game}.json"),
        json.pretty(),
    )
    .expect("write results");
    for a in &aggs {
        let xs: Vec<f64> = a.curve_x.iter().map(|&v| v as f64).collect();
        write_csv(
            Path::new(&format!("results/atari_{game}_{}.csv", a.learner)),
            &["step", "mse", "stderr"],
            &[&xs, &a.curve_mean, &a.curve_stderr],
        )
        .expect("write csv");
    }
    eprintln!("wrote results/atari_{game}.json and per-learner CSVs");
}
