//! The full three-layer pipeline in one binary, end to end:
//!
//!   Layer 1 (Pallas kernel)  — authored in python/compile/kernels/,
//!   Layer 2 (JAX model)      — python/compile/model.py,
//!         both lowered once by `make artifacts` to HLO text;
//!   Layer 3 (this program)   — loads the artifacts via PJRT and runs a
//!   complete TD(lambda) learner on the trace-conditioning stream with
//!   *all column compute inside XLA*. Python is not running here.
//!
//! The same learner is run natively in Rust on the identical stream and
//! the two learning curves are compared — they must agree to float
//! tolerance, proving L1/L2/L3 compose.
//!
//! ```bash
//! make artifacts && cargo run --release --example pjrt_pipeline
//! ```

use std::path::Path;

use ccn_rtrl::env::returns::ReturnEval;
use ccn_rtrl::env::trace_conditioning::{TraceConditioning, TraceConditioningConfig};
use ccn_rtrl::env::Stream;
use ccn_rtrl::nets::lstm_column::LstmColumn;
use ccn_rtrl::nets::normalizer::{OnlineNormalizer, NORM_BETA};
use ccn_rtrl::runtime::{PjrtColumnarStage, PjrtRuntime};
use ccn_rtrl::util::dot;
use ccn_rtrl::util::prng::Xoshiro256;

const STEPS: u64 = 3_000;
const ALPHA: f32 = 0.003;
const LAMBDA: f32 = 0.99;

/// Minimal columnar TD(lambda) learner over a PJRT stage.
struct PjrtLearner<'rt> {
    stage: PjrtColumnarStage<'rt>,
    w: Vec<f32>,
    e_w: Vec<f32>,
    e_theta: Vec<f32>,
    grad: Vec<f32>,
    y_prev: f32,
    have_prev: bool,
    gamma: f32,
}

impl<'rt> PjrtLearner<'rt> {
    fn step(&mut self, x: &[f32], c: f32) -> f32 {
        self.stage.step(x).expect("pjrt step");
        let d = self.stage.n_cols;
        let per = 4 * self.stage.m + 8;
        let y = dot(&self.w, &self.stage.h_norm);
        if self.have_prev {
            let delta = c + self.gamma * y - self.y_prev;
            for (wk, &e) in self.w.iter_mut().zip(&self.e_w) {
                *wk += ALPHA * delta * e;
            }
            // apply theta update through the stage's parameter vectors
            for k in 0..d {
                let base = k * per;
                for j in 0..4 * self.stage.m {
                    self.stage.w[k * 4 * self.stage.m + j] +=
                        ALPHA * delta * self.e_theta[base + j];
                }
                for a in 0..4 {
                    self.stage.u[k * 4 + a] +=
                        ALPHA * delta * self.e_theta[base + 4 * self.stage.m + a];
                    self.stage.b[k * 4 + a] +=
                        ALPHA * delta * self.e_theta[base + 4 * self.stage.m + 4 + a];
                }
            }
        }
        let gl = self.gamma * LAMBDA;
        for (e, &f) in self.e_w.iter_mut().zip(&self.stage.h_norm) {
            *e = gl * *e + f;
        }
        for k in 0..d {
            self.stage
                .write_grad(k, self.w[k], &mut self.grad[k * per..(k + 1) * per]);
        }
        for (e, &g) in self.e_theta.iter_mut().zip(&self.grad) {
            *e = gl * *e + g;
        }
        self.y_prev = y;
        self.have_prev = true;
        y
    }
}

fn main() {
    let dir = PjrtRuntime::default_dir();
    let rt = PjrtRuntime::load(Path::new(&dir)).unwrap_or_else(|e| {
        eprintln!("cannot load artifacts ({e}); run `make artifacts` first");
        std::process::exit(1);
    });
    println!(
        "PJRT platform: {} | artifacts: {}",
        rt.platform(),
        rt.manifest.artifacts.len()
    );
    rt.verify_golden().expect("golden check");
    println!("golden fixture OK (jax == pjrt)");

    // columnar learner: 8 columns over the 2-feature stream, via the
    // c8/m16 artifact is not lowered; use the quickstart shape (8, 16)
    // with the 2 real features zero-padded to 16.
    let (n_cols, m) = (8, 16);
    let mut env = TraceConditioning::new(TraceConditioningConfig::default(), 0);
    let gamma = env.gamma();
    let mut stage = PjrtColumnarStage::new(&rt, n_cols, m, 0).expect("stage");

    // native twin with identical parameters
    let mut rng = Xoshiro256::seed_from_u64(42);
    let mut cols: Vec<LstmColumn> =
        (0..n_cols).map(|_| LstmColumn::new(m, &mut rng, 1.0)).collect();
    stage.set_params_from_columns(&cols);
    let per = 4 * m + 8;

    let mut pjrt_learner = PjrtLearner {
        stage,
        w: vec![0.0; n_cols],
        e_w: vec![0.0; n_cols],
        e_theta: vec![0.0; n_cols * per],
        grad: vec![0.0; n_cols * per],
        y_prev: 0.0,
        have_prev: false,
        gamma,
    };

    // native twin learner state
    let mut norm = OnlineNormalizer::new(n_cols, NORM_BETA, rt.manifest.eps);
    let mut w_n = vec![0.0f32; n_cols];
    let mut ew_n = vec![0.0f32; n_cols];
    let mut eth_n = vec![0.0f32; n_cols * per];
    let mut grad_n = vec![0.0f32; n_cols * per];
    let mut y_prev_n = 0.0f32;
    let mut have_prev_n = false;

    let mut eval = ReturnEval::new(gamma as f64, 1e-4);
    let mut x = vec![0.0f32; m];
    let mut max_dev = 0.0f32;
    let mut err_sum = 0.0f64;
    let mut err_n = 0u64;
    for t in 0..STEPS {
        let c = env.step_into(&mut x[..2]);
        // zero-padded to the artifact's input width
        let y_pjrt = pjrt_learner.step(&x, c);

        // native twin (same math in Rust)
        let mut raw = vec![0.0f32; n_cols];
        for (k, col) in cols.iter_mut().enumerate() {
            col.step_with_traces(&x);
            raw[k] = col.h;
        }
        let mut h_norm = vec![0.0f32; n_cols];
        norm.update_and_normalize(&raw, &mut h_norm);
        let y_native = dot(&w_n, &h_norm);
        if have_prev_n {
            let delta = c + gamma * y_native - y_prev_n;
            for (wk, &e) in w_n.iter_mut().zip(&ew_n) {
                *wk += ALPHA * delta * e;
            }
            for (k, col) in cols.iter_mut().enumerate() {
                let upd: Vec<f32> = eth_n[k * per..(k + 1) * per]
                    .iter()
                    .map(|&e| ALPHA * delta * e)
                    .collect();
                col.apply_update(&upd);
            }
        }
        let gl = gamma * LAMBDA;
        for (e, &f) in ew_n.iter_mut().zip(&h_norm) {
            *e = gl * *e + f;
        }
        for (k, col) in cols.iter().enumerate() {
            col.write_grad(w_n[k] / norm.denom(k), &mut grad_n[k * per..(k + 1) * per]);
        }
        for (e, &g) in eth_n.iter_mut().zip(&grad_n) {
            *e = gl * *e + g;
        }
        y_prev_n = y_native;
        have_prev_n = true;

        max_dev = max_dev.max((y_pjrt - y_native).abs());
        eval.push(y_pjrt as f64, c as f64);
        for (_, e2) in eval.drain() {
            err_sum += e2;
            err_n += 1;
        }
        if t % 1000 == 0 && t > 0 {
            println!(
                "step {t:>6}: y_pjrt {y_pjrt:+.4}  y_native {y_native:+.4}  \
                 running err {:.5}",
                err_sum / err_n.max(1) as f64
            );
        }
    }
    println!(
        "\nmax |y_pjrt - y_native| over {STEPS} steps of joint learning: {max_dev:.2e}"
    );
    assert!(
        max_dev < 2e-2,
        "PJRT and native paths diverged: {max_dev}"
    );
    println!("three-layer pipeline verified: Pallas kernel -> JAX model -> HLO \
              -> PJRT -> Rust TD(lambda), numerically matching native Rust.");
}
