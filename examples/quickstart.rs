//! Quickstart: build a columnar RTRL learner, point it at a partially
//! observable stream, and watch the prediction error fall — in ~30 lines
//! of user code.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use ccn_rtrl::env::returns::ReturnEval;
use ccn_rtrl::env::trace_conditioning::{TraceConditioning, TraceConditioningConfig};
use ccn_rtrl::env::Stream;
use ccn_rtrl::learn::{TdConfig, TdLambdaAgent};
use ccn_rtrl::metrics::Ewma;
use ccn_rtrl::nets::columnar::columnar_net;

fn main() {
    // 1. a stream: the trace-conditioning memory task (CS ... delay ... US)
    let mut env = TraceConditioning::new(TraceConditioningConfig::default(), 0);
    let gamma = env.gamma();

    // 2. a learner: 8 independent LSTM columns + exact RTRL + TD(lambda)
    let net = columnar_net(env.n_features(), 8, 0.01, /*seed=*/ 0);
    let mut agent = TdLambdaAgent::new(
        net,
        TdConfig {
            alpha: 0.003,
            gamma,
            lambda: 0.99,
        },
    );

    // 3. the online loop — no replay buffer, no batches, one pass
    let mut eval = ReturnEval::new(gamma as f64, 1e-4);
    let mut smoothed = Ewma::new(0.9995);
    let mut x = vec![0.0; env.n_features()];
    let total = 2_000_000u64;
    for t in 0..total {
        let cumulant = env.step_into(&mut x);
        let y = agent.step(&x, cumulant);
        eval.push(y as f64, cumulant as f64);
        for (_, err2) in eval.drain() {
            smoothed.push(err2);
        }
        if t % 200_000 == 0 && t > 0 {
            println!(
                "step {t:>8}  mean squared return error = {:.5}",
                smoothed.get()
            );
        }
    }
    println!("done: final error {:.5} (predicting zero scores ~0.053)", smoothed.get());
}
