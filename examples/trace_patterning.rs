//! Scaled-down Figure-4 experiment as a runnable example: all four
//! methods (Columnar, Constructive, CCN, best-k T-BPTT) on the trace
//! patterning benchmark at the same per-step compute budget.
//!
//! ```bash
//! cargo run --release --example trace_patterning -- [steps] [seeds]
//! ```
//! Defaults: 5M steps (1/10 of the paper), 3 seeds.

use ccn_rtrl::compute;
use ccn_rtrl::config::{EnvKind, ExperimentConfig, LearnerKind};
use ccn_rtrl::coordinator::{aggregate_runs, run_sweep, sweep};
use ccn_rtrl::metrics::render_table;

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let steps: u64 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(5_000_000);
    let n_seeds: u64 = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(3);
    let stage = (steps / 5).max(1); // 5 stages across the run, like the paper

    // the paper's Table-1 configurations (4k-op budget at n = 7)
    let methods = vec![
        ("columnar", LearnerKind::Columnar { d: 5 }, 0.003f32),
        (
            "constructive",
            LearnerKind::Constructive {
                total: 10,
                steps_per_stage: (steps / 10).max(1),
            },
            0.003,
        ),
        (
            "ccn",
            LearnerKind::Ccn {
                total: 20,
                per_stage: 4,
                steps_per_stage: stage,
            },
            0.003,
        ),
        ("tbptt 2:30", LearnerKind::Tbptt { d: 2, k: 30 }, 0.003),
    ];

    let mut configs = Vec::new();
    for (_, learner, alpha) in &methods {
        let base = ExperimentConfig {
            env: EnvKind::TracePatterning,
            learner: learner.clone(),
            alpha: *alpha,
            lambda: 0.99,
            gamma_override: None,
            eps: 0.1,
            steps,
            seed: 0,
            curve_points: 50,
        };
        configs.extend(sweep::seeds(&base, &(0..n_seeds).collect::<Vec<_>>()));
    }

    eprintln!(
        "running {} configs x {} steps on {} threads ...",
        configs.len(),
        steps,
        sweep::default_threads()
    );
    let res = run_sweep(configs, sweep::default_threads());
    let aggs = aggregate_runs(&res.runs);

    let mut rows = Vec::new();
    for (name, learner, _) in &methods {
        let a = aggs
            .iter()
            .find(|a| a.learner == learner.label())
            .expect("aggregated");
        let budget = match learner {
            LearnerKind::Columnar { d } => compute::columnar_ops(*d as u64, 7),
            LearnerKind::Constructive { total, .. } => {
                compute::constructive_ops(*total as u64, 7)
            }
            LearnerKind::Ccn {
                total, per_stage, ..
            } => compute::ccn_ops(*total as u64, 7, *per_stage as u64),
            LearnerKind::Tbptt { d, k } => {
                compute::tbptt_ops(*d as u64, 7, *k as u64)
            }
            LearnerKind::Snap1 { d } => 7 * (*d as u64) * (4 * 7 + 8),
        };
        rows.push(vec![
            name.to_string(),
            format!("{budget}"),
            format!("{:.5}", a.curve_mean.first().copied().unwrap_or(f64::NAN)),
            format!("{:.5} ± {:.5}", a.tail_mean, a.tail_stderr),
            format!("{:.2}M/s", a.mean_steps_per_sec / 1e6),
        ]);
    }
    println!(
        "{}",
        render_table(
            &["method", "ops/step", "initial err", "final err (±se)", "speed"],
            &rows
        )
    );
    println!(
        "paper (Fig. 4, 50M steps): constructive ≈ CCN < T-BPTT(2:30) < columnar;\n\
         at this scale the ordering emerges progressively — run longer to sharpen it."
    );
}
