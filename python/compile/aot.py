"""AOT lowering: jax -> HLO TEXT artifacts for the Rust runtime.

HLO *text* (not ``.serialize()``) is the interchange format: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids, so text round-trips cleanly. See /opt/xla-example/README.md.

Usage:
    cd python && python -m compile.aot --out ../artifacts

Emits, per (n_cols, m) configuration in MANIFEST below:
    col_step_c{C}_m{M}.hlo.txt   learning-stage step (fwd + RTRL + norm)
    col_fwd_c{C}_m{M}.hlo.txt    frozen-stage step  (fwd + norm)
plus ``manifest.json`` describing every artifact (shapes + io order) so
the Rust side can discover them without hard-coding.
"""

import argparse
import json
import os

import jax
from jax._src.lib import xla_client as xc

from .model import (
    columnar_learner_step,
    example_args_fwd,
    example_args_step,
    frozen_stage_step,
)


def _ccn_stage_shapes(n_input, features_per_stage, n_stages):
    """Input width per CCN stage: stage s sees the raw input plus all
    previously frozen (normalized) features."""
    return [
        (features_per_stage, n_input + features_per_stage * s)
        for s in range(n_stages)
    ]


def default_manifest():
    """The artifact set covering the paper's configurations (Table 1).

    - trace patterning (7 inputs: 6 CS + 1 US):
        columnar: 5 columns;  CCN: 4 features/stage x 5 stages (20 feats);
        constructive: 1 feature/stage x 10 stages.
    - Atari prediction (277 inputs: 256 pixels + 20 actions + 1 reward):
        columnar: 7 columns;  CCN: 5 features/stage x 3 stages.
    - quickstart demo: 8 columns over 16 inputs.
    """
    shapes = set()
    shapes.add((5, 7))  # trace columnar
    shapes.update(_ccn_stage_shapes(7, 4, 5))  # trace CCN
    shapes.update(_ccn_stage_shapes(7, 1, 6))  # trace constructive (first 6)
    shapes.add((7, 277))  # atari columnar
    shapes.update(_ccn_stage_shapes(277, 5, 3))  # atari CCN
    shapes.add((8, 16))  # quickstart
    shapes.add((3, 4))  # tiny shape used by the cross-language golden test
    return sorted(shapes)


def write_golden(out_dir, eps):
    """Golden input/output pairs for the Rust integration tests.

    Rust loads col_step_c3_m4 / col_fwd_c3_m4 via PJRT, feeds these inputs
    and must reproduce these outputs bit-for-bit-ish (f32 tolerance). This
    is the cross-language equivalent of the paper's PyTorch gradient check.
    """
    import numpy as np

    from .model import columnar_learner_step, frozen_stage_step, init_stage

    n_cols, m = 3, 4
    params, state = init_stage(jax.random.PRNGKey(0), n_cols, m)
    x = jax.random.normal(jax.random.PRNGKey(1), (m,))
    step_args = [
        x, params["w"], params["u"], params["b"],
        state["h"], state["c"], state["thw"], state["tcw"],
        state["thu"], state["tcu"], state["thb"], state["tcb"],
        state["mu"], state["var"],
    ]
    step_out = columnar_learner_step(*step_args, eps=eps)
    fwd_args = [
        x, params["w"], params["u"], params["b"],
        state["h"], state["c"], state["mu"], state["var"],
    ]
    fwd_out = frozen_stage_step(*fwd_args, eps=eps)

    def pack(arrs):
        return [
            {"shape": list(np.asarray(a).shape),
             "data": [float(v) for v in np.asarray(a, dtype=np.float32).ravel()]}
            for a in arrs
        ]

    golden = {
        "n_cols": n_cols,
        "m": m,
        "eps": eps,
        "step": {"inputs": pack(step_args), "outputs": pack(step_out)},
        "fwd": {"inputs": pack(fwd_args), "outputs": pack(fwd_out)},
    }
    path = os.path.join(out_dir, "golden.json")
    with open(path, "w") as f:
        json.dump(golden, f)
    print(f"wrote {path}")


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (ids reassigned by parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_step(n_cols, m, eps):
    fn = lambda *a: columnar_learner_step(*a, eps=eps, interpret=True)
    return to_hlo_text(jax.jit(fn).lower(*example_args_step(n_cols, m)))


def lower_fwd(n_cols, m, eps):
    fn = lambda *a: frozen_stage_step(*a, eps=eps, interpret=True)
    return to_hlo_text(jax.jit(fn).lower(*example_args_fwd(n_cols, m)))


STEP_INPUTS = [
    "x", "w", "u", "b", "h", "c",
    "thw", "tcw", "thu", "tcu", "thb", "tcb", "mu", "var",
]
STEP_OUTPUTS = [
    "h2", "c2", "thw2", "tcw2", "thu2", "tcu2", "thb2", "tcb2",
    "mu2", "var2", "h_norm", "denom",
]
FWD_INPUTS = ["x", "w", "u", "b", "h", "c", "mu", "var"]
FWD_OUTPUTS = ["h2", "c2", "mu2", "var2", "h_norm", "denom"]


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out", default="../artifacts", help="output dir")
    parser.add_argument(
        "--eps", type=float, default=0.01,
        help="normalizer epsilon baked into the artifacts",
    )
    parser.add_argument(
        "--shapes", default="",
        help="optional extra shapes 'C:M,C:M,...' to lower in addition "
             "to the default manifest",
    )
    args = parser.parse_args()
    os.makedirs(args.out, exist_ok=True)

    shapes = default_manifest()
    if args.shapes:
        for tok in args.shapes.split(","):
            c_str, m_str = tok.split(":")
            shapes.append((int(c_str), int(m_str)))
        shapes = sorted(set(shapes))

    manifest = {"eps": args.eps, "gate_order": "ifog", "artifacts": []}
    for n_cols, m in shapes:
        for kind, lower, ins, outs in (
            ("step", lower_step, STEP_INPUTS, STEP_OUTPUTS),
            ("fwd", lower_fwd, FWD_INPUTS, FWD_OUTPUTS),
        ):
            name = f"col_{kind}_c{n_cols}_m{m}.hlo.txt"
            path = os.path.join(args.out, name)
            text = lower(n_cols, m, args.eps)
            with open(path, "w") as f:
                f.write(text)
            manifest["artifacts"].append(
                {
                    "file": name,
                    "kind": kind,
                    "n_cols": n_cols,
                    "m": m,
                    "inputs": ins,
                    "outputs": outs,
                }
            )
            print(f"wrote {path} ({len(text)} chars)")

    write_golden(args.out, args.eps)

    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {os.path.join(args.out, 'manifest.json')} "
          f"({len(manifest['artifacts'])} artifacts)")


if __name__ == "__main__":
    main()
