"""Layer-1 Pallas kernel: batched LSTM-column forward + forward-mode RTRL.

This is the paper's compute hot-spot (Appendix B): one LSTM *column* has a
scalar hidden state ``h`` and cell ``c``, input vector ``x`` of length
``m``, and parameters

    W  : [4, m]   input weights for the gates (order: i, f, o, g)
    u  : [4]      recurrent weights
    b  : [4]      biases

RTRL for a scalar-state column needs one pair of traces per parameter:

    TH_p(t) = dh(t)/dp        TC_p(t) = dc(t)/dp

The paper derives the per-parameter recursions gate by gate; here they are
fused into one affine-plus-rank-1 update (algebraically identical — the
per-gate derivation is kept, un-fused, in ``ref.py`` as the oracle):

    gates:  z_a = W_a . x + u_a h + b_a,  a in {i, f, o, g}
            i, f, o = sigmoid(z_.), g = tanh(z_g)
            c' = f c + i g,  h' = o tanh(c')

    derivs: di = i(1-i), df = f(1-f), do = o(1-o), dg = 1-g^2

    A = c*df*u_f + i*dg*u_g + g*di*u_i          # dTC'/dTH  (chain via gates)
    B = tanh(c')*do*u_o                          # dTH'/dTH  (output gate)
    E = o*(1 - tanh(c')^2)                       # dTH'/dTC'
    q = [g*di, c*df, 0, i*dg]                    # direct coeff into c'
    r = [0,    0,    tanh(c')*do, 0]             # direct coeff into h'

    for the W-traces (direct input is x_j), u-traces (direct input is
    h(t-1)) and b-traces (direct input is 1):

        TC' = f*TC + A*TH + q (x) direct
        TH' = E*TC' + B*TH + r (x) direct

Columns are fully independent (that is the paper's point), so the kernel
tiles the **column dimension across the Pallas grid**: each grid step
holds one block of columns' parameters, state and traces in VMEM, does the
gate matmul on the MXU (W reshaped [BLK*4, m] @ x) and the trace
recursions on the VPU. No cross-column reduction exists by construction.

Must run with ``interpret=True`` on CPU — real TPU lowering emits a Mosaic
custom-call the CPU PJRT plugin cannot execute.
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Gate order used throughout the repo (python + rust must agree).
GATE_I, GATE_F, GATE_O, GATE_G = 0, 1, 2, 3


def _column_rtrl_kernel(
    x_ref,
    w_ref,
    u_ref,
    b_ref,
    h_ref,
    c_ref,
    thw_ref,
    tcw_ref,
    thu_ref,
    tcu_ref,
    thb_ref,
    tcb_ref,
    # outputs
    h2_ref,
    c2_ref,
    thw2_ref,
    tcw2_ref,
    thu2_ref,
    tcu2_ref,
    thb2_ref,
    tcb2_ref,
):
    """One grid step: a [BLK] block of columns. Shapes inside the block:

    x    [m]          shared input (same for every column in a stage)
    w    [BLK, 4, m]  u,b [BLK, 4]   h,c [BLK]
    thw/tcw [BLK, 4, m]   thu/tcu/thb/tcb [BLK, 4]
    """
    x = x_ref[...]
    w = w_ref[...]
    u = u_ref[...]
    b = b_ref[...]
    h = h_ref[...]
    c = c_ref[...]

    blk, _, m = w.shape

    # ---- forward: gate pre-activations via one MXU matmul ----
    z = jnp.dot(w.reshape(blk * 4, m), x).reshape(blk, 4) + u * h[:, None] + b

    i = jax.nn.sigmoid(z[:, GATE_I])
    f = jax.nn.sigmoid(z[:, GATE_F])
    o = jax.nn.sigmoid(z[:, GATE_O])
    g = jnp.tanh(z[:, GATE_G])

    c2 = f * c + i * g
    tanh_c2 = jnp.tanh(c2)
    h2 = o * tanh_c2

    # ---- trace recursion coefficients (per column) ----
    di = i * (1.0 - i)
    df = f * (1.0 - f)
    do = o * (1.0 - o)
    dg = 1.0 - g * g

    a_coef = c * df * u[:, GATE_F] + i * dg * u[:, GATE_G] + g * di * u[:, GATE_I]
    b_coef = tanh_c2 * do * u[:, GATE_O]
    e_coef = o * (1.0 - tanh_c2 * tanh_c2)

    zero = jnp.zeros_like(i)
    q = jnp.stack([g * di, c * df, zero, i * dg], axis=1)  # [BLK, 4]
    r = jnp.stack([zero, zero, tanh_c2 * do, zero], axis=1)  # [BLK, 4]

    fb = f[:, None]  # broadcast helpers
    ab = a_coef[:, None]
    bb = b_coef[:, None]
    eb = e_coef[:, None]

    # ---- W traces: direct term is x_j ----
    tcw2 = fb[..., None] * tcw_ref[...] + ab[..., None] * thw_ref[...] + (
        q[:, :, None] * x[None, None, :]
    )
    thw2 = eb[..., None] * tcw2 + bb[..., None] * thw_ref[...] + (
        r[:, :, None] * x[None, None, :]
    )

    # ---- u traces: direct term is h(t-1) ----
    tcu2 = fb * tcu_ref[...] + ab * thu_ref[...] + q * h[:, None]
    thu2 = eb * tcu2 + bb * thu_ref[...] + r * h[:, None]

    # ---- b traces: direct term is 1 ----
    tcb2 = fb * tcb_ref[...] + ab * thb_ref[...] + q
    thb2 = eb * tcb2 + bb * thb_ref[...] + r

    h2_ref[...] = h2
    c2_ref[...] = c2
    thw2_ref[...] = thw2
    tcw2_ref[...] = tcw2
    thu2_ref[...] = thu2
    tcu2_ref[...] = tcu2
    thb2_ref[...] = thb2
    tcb2_ref[...] = tcb2


def _pick_block(n_cols: int, col_block: int) -> int:
    """Largest divisor of n_cols not exceeding col_block (grid must tile)."""
    blk = min(col_block, n_cols)
    while n_cols % blk != 0:
        blk -= 1
    return blk


@partial(jax.jit, static_argnames=("col_block", "interpret"))
def column_rtrl_step(
    x,
    w,
    u,
    b,
    h,
    c,
    thw,
    tcw,
    thu,
    tcu,
    thb,
    tcb,
    *,
    col_block: int = 8,
    interpret: bool = True,
):
    """Batched column forward + RTRL trace update.

    Args:
      x:   [m]        input vector shared by all columns of the stage.
      w:   [C, 4, m]  gate input weights (gate order i, f, o, g).
      u:   [C, 4]     recurrent weights.
      b:   [C, 4]     biases.
      h,c: [C]        previous hidden / cell state.
      thw,tcw: [C, 4, m]  dh/dW, dc/dW traces.
      thu,tcu,thb,tcb: [C, 4]  dh/du, dc/du, dh/db, dc/db traces.

    Returns:
      (h2, c2, thw2, tcw2, thu2, tcu2, thb2, tcb2) — same shapes.
    """
    n_cols, _, m = w.shape
    blk = _pick_block(n_cols, col_block)
    grid = (n_cols // blk,)

    vec_spec = pl.BlockSpec((blk,), lambda idx: (idx,))
    g4_spec = pl.BlockSpec((blk, 4), lambda idx: (idx, 0))
    g4m_spec = pl.BlockSpec((blk, 4, m), lambda idx: (idx, 0, 0))
    x_spec = pl.BlockSpec((m,), lambda idx: (0,))

    out_shapes = (
        jax.ShapeDtypeStruct((n_cols,), w.dtype),  # h2
        jax.ShapeDtypeStruct((n_cols,), w.dtype),  # c2
        jax.ShapeDtypeStruct((n_cols, 4, m), w.dtype),  # thw2
        jax.ShapeDtypeStruct((n_cols, 4, m), w.dtype),  # tcw2
        jax.ShapeDtypeStruct((n_cols, 4), w.dtype),  # thu2
        jax.ShapeDtypeStruct((n_cols, 4), w.dtype),  # tcu2
        jax.ShapeDtypeStruct((n_cols, 4), w.dtype),  # thb2
        jax.ShapeDtypeStruct((n_cols, 4), w.dtype),  # tcb2
    )

    return pl.pallas_call(
        _column_rtrl_kernel,
        grid=grid,
        in_specs=[
            x_spec,
            g4m_spec,
            g4_spec,
            g4_spec,
            vec_spec,
            vec_spec,
            g4m_spec,
            g4m_spec,
            g4_spec,
            g4_spec,
            g4_spec,
            g4_spec,
        ],
        out_specs=(
            vec_spec,
            vec_spec,
            g4m_spec,
            g4m_spec,
            g4_spec,
            g4_spec,
            g4_spec,
            g4_spec,
        ),
        out_shape=out_shapes,
        interpret=interpret,
    )(x, w, u, b, h, c, thw, tcw, thu, tcu, thb, tcb)


def _column_forward_kernel(x_ref, w_ref, u_ref, b_ref, h_ref, c_ref, h2_ref, c2_ref):
    """Forward-only block step for frozen columns (no traces)."""
    x = x_ref[...]
    w = w_ref[...]
    u = u_ref[...]
    b = b_ref[...]
    h = h_ref[...]
    c = c_ref[...]
    blk, _, m = w.shape
    z = jnp.dot(w.reshape(blk * 4, m), x).reshape(blk, 4) + u * h[:, None] + b
    i = jax.nn.sigmoid(z[:, GATE_I])
    f = jax.nn.sigmoid(z[:, GATE_F])
    o = jax.nn.sigmoid(z[:, GATE_O])
    g = jnp.tanh(z[:, GATE_G])
    c2 = f * c + i * g
    h2_ref[...] = o * jnp.tanh(c2)
    c2_ref[...] = c2


@partial(jax.jit, static_argnames=("col_block", "interpret"))
def column_forward(x, w, u, b, h, c, *, col_block: int = 8, interpret: bool = True):
    """Forward pass of a block of frozen columns (no trace update).

    Same layouts as :func:`column_rtrl_step`; returns ``(h2, c2)``.
    """
    n_cols, _, m = w.shape
    blk = _pick_block(n_cols, col_block)
    grid = (n_cols // blk,)
    vec_spec = pl.BlockSpec((blk,), lambda idx: (idx,))
    g4_spec = pl.BlockSpec((blk, 4), lambda idx: (idx, 0))
    g4m_spec = pl.BlockSpec((blk, 4, m), lambda idx: (idx, 0, 0))
    x_spec = pl.BlockSpec((m,), lambda idx: (0,))
    out_shapes = (
        jax.ShapeDtypeStruct((n_cols,), w.dtype),
        jax.ShapeDtypeStruct((n_cols,), w.dtype),
    )
    return pl.pallas_call(
        _column_forward_kernel,
        grid=grid,
        in_specs=[x_spec, g4m_spec, g4_spec, g4_spec, vec_spec, vec_spec],
        out_specs=(vec_spec, vec_spec),
        out_shape=out_shapes,
        interpret=interpret,
    )(x, w, u, b, h, c)
