"""Pure-jnp oracle for the column-RTRL Pallas kernel.

Implements the Appendix-B recursions *gate by gate, parameter group by
parameter group* -- deliberately un-fused and as close to the paper's
derivation as possible -- so that it is an independent check of the fused
kernel in ``column_rtrl.py``. A second, even stronger oracle (jacfwd of
the unrolled column) lives in ``python/tests/test_gradients.py``.

Everything here operates on a single column; batching over columns is done
with ``jax.vmap`` in :func:`column_rtrl_step_ref`.
"""

import jax
import jax.numpy as jnp

GATE_I, GATE_F, GATE_O, GATE_G = 0, 1, 2, 3


def lstm_column_forward(x, w, u, b, h, c):
    """Forward pass of one LSTM column (paper eqs. 11-16).

    Args:
      x: [m] input.  w: [4, m].  u, b: [4].  h, c: scalars.

    Returns:
      (h2, c2, (i, f, o, g)).
    """
    z = w @ x + u * h + b
    i = jax.nn.sigmoid(z[GATE_I])
    f = jax.nn.sigmoid(z[GATE_F])
    o = jax.nn.sigmoid(z[GATE_O])
    g = jnp.tanh(z[GATE_G])
    c2 = f * c + i * g
    h2 = o * jnp.tanh(c2)
    return h2, c2, (i, f, o, g)


def _single_column_rtrl(x, w, u, b, h, c, thw, tcw, thu, tcu, thb, tcb):
    """RTRL trace update for one column, following the paper's derivation.

    For every parameter ``p`` (each of the 4m input weights, 4 recurrent
    weights and 4 biases) the paper derives:

        dgate_a/dp = act'(z_a) * (u_a * TH_p(t-1) + direct_a(p))
        TC_p(t) = f*TC_p(t-1) + c(t-1)*df/dp + i*dg/dp + g*di/dp
        TH_p(t) = o*(1 - tanh(c_t)^2)*TC_p(t) + tanh(c_t)*do/dp

    where ``direct_a(p)`` is x_j if p = W_a[j], h(t-1) if p = u_a, 1 if
    p = b_a, and 0 if p belongs to a different gate.
    """
    h2, c2, (i, f, o, g) = lstm_column_forward(x, w, u, b, h, c)

    di = i * (1 - i)
    df = f * (1 - f)
    do = o * (1 - o)
    dg = 1 - g * g
    dact = jnp.stack([di, df, do, dg])  # [4] derivative of each gate's act.
    tanh_c2 = jnp.tanh(c2)

    def gate_grad(th_prev, direct):
        """dgate_a/dp for all four gates a, given TH_p(t-1) and the direct
        term (nonzero only at the gate that owns p).

        th_prev: trace(s) of dh(t-1)/dp, shape S.
        direct:  [4] + S broadcastable direct contribution.
        Returns [4] + S array of gate derivatives.
        """
        return dact.reshape((4,) + (1,) * th_prev.ndim) * (
            u.reshape((4,) + (1,) * th_prev.ndim) * th_prev[None, ...] + direct
        )

    def trace_update(th_prev, tc_prev, direct):
        dgates = gate_grad(th_prev, direct)  # [4] + S
        tc2 = (
            f * tc_prev
            + c * dgates[GATE_F]
            + i * dgates[GATE_G]
            + g * dgates[GATE_I]
        )
        th2 = o * (1 - tanh_c2 * tanh_c2) * tc2 + tanh_c2 * dgates[GATE_O]
        return th2, tc2

    eye4 = jnp.eye(4)

    # W traces: parameter W[a, j]; direct term x_j into gate a only.
    # thw has shape [4, m] (one trace per W entry).
    direct_w = eye4[:, :, None] * x[None, None, :]  # [4(gate), 4(param-gate), m]
    thw2, tcw2 = trace_update(thw, tcw, direct_w)

    # u traces: parameter u[a]; direct term h(t-1) into gate a only.
    direct_u = eye4 * h  # [4, 4]
    thu2, tcu2 = trace_update(thu, tcu, direct_u)

    # b traces: parameter b[a]; direct term 1 into gate a only.
    thb2, tcb2 = trace_update(thb, tcb, eye4)

    return h2, c2, thw2, tcw2, thu2, tcu2, thb2, tcb2


def column_rtrl_step_ref(x, w, u, b, h, c, thw, tcw, thu, tcu, thb, tcb):
    """Batched-over-columns oracle with the same signature/layout as the
    Pallas kernel: w [C,4,m], u/b [C,4], h/c [C], traces as in the kernel.
    """
    fn = jax.vmap(_single_column_rtrl, in_axes=(None, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0))
    return fn(x, w, u, b, h, c, thw, tcw, thu, tcu, thb, tcb)


def column_forward_ref(x, w, u, b, h, c):
    """Batched forward-only oracle. Returns (h2, c2)."""

    def one(w_k, u_k, b_k, h_k, c_k):
        h2, c2, _ = lstm_column_forward(x, w_k, u_k, b_k, h_k, c_k)
        return h2, c2

    return jax.vmap(one)(w, u, b, h, c)
