"""Layer-2 JAX model: the CCN/columnar learner step.

This module assembles the paper's per-step computation out of the Layer-1
Pallas kernel (``kernels/column_rtrl.py``):

  1. advance every *learning* column one step and update its RTRL traces,
  2. update the online feature normalizer (paper eq. 10),
  3. emit the normalized features and the denominator needed to scale the
     trace-gradient into dy/dtheta on the Rust side,
  4. (frozen stages) advance frozen columns forward-only.

The functions here are lowered once by ``aot.py`` into HLO-text artifacts;
at run time the Rust coordinator (rust/src/runtime) loads and executes
them via PJRT. The TD(lambda) weight update itself is O(|theta|) and runs
in Rust on both the native and the PJRT path, so the artifact boundary is
"state in, state + features + traces out".
"""

from functools import partial

import jax
import jax.numpy as jnp

from .kernels.column_rtrl import column_forward, column_rtrl_step

# Paper default (Section 3.4): beta = 0.99999 for all experiments.
NORM_BETA = 0.99999


def normalizer_update(mu, var, f, beta=NORM_BETA):
    """One step of the paper's online mean/variance estimate (eq. 10).

        mu_t    = beta * mu_{t-1} + (1 - beta) * f_t
        sigma^2 = beta * sigma^2_{t-1}
                  + (1 - beta) * (mu_t - f_t) * (mu_{t-1} - f_t)

    Args: mu, var, f: [C] per-feature statistics and raw feature values.
    Returns: (mu2, var2).
    """
    mu2 = mu * beta + (1.0 - beta) * f
    var2 = var * beta + (1.0 - beta) * (mu2 - f) * (mu - f)
    return mu2, var2


def normalize(f, mu, var, eps):
    """Normalize features with an epsilon-floored standard deviation.

    Returns (f_hat, denom) where denom = max(eps, sigma); the caller needs
    denom to scale trace-gradients: dy/dp = w_k / denom_k * TH_p.
    """
    denom = jnp.maximum(eps, jnp.sqrt(jnp.maximum(var, 0.0)))
    return (f - mu) / denom, denom


@partial(jax.jit, static_argnames=("eps", "beta", "interpret"))
def columnar_learner_step(
    x,
    w,
    u,
    b,
    h,
    c,
    thw,
    tcw,
    thu,
    tcu,
    thb,
    tcb,
    mu,
    var,
    *,
    eps: float = 0.01,
    beta: float = NORM_BETA,
    interpret: bool = True,
):
    """One step for a stage of C learning columns over input x of size m.

    Calls the Pallas kernel for forward + trace update, then updates the
    normalizer with the *new* hidden states and returns the normalized
    feature vector.

    Returns (in order):
      h2, c2, thw2, tcw2, thu2, tcu2, thb2, tcb2, mu2, var2, h_norm, denom
    """
    h2, c2, thw2, tcw2, thu2, tcu2, thb2, tcb2 = column_rtrl_step(
        x, w, u, b, h, c, thw, tcw, thu, tcu, thb, tcb, interpret=interpret
    )
    mu2, var2 = normalizer_update(mu, var, h2, beta)
    h_norm, denom = normalize(h2, mu2, var2, eps)
    return h2, c2, thw2, tcw2, thu2, tcu2, thb2, tcb2, mu2, var2, h_norm, denom


@partial(jax.jit, static_argnames=("eps", "beta", "interpret"))
def frozen_stage_step(
    x, w, u, b, h, c, mu, var, *, eps: float = 0.01, beta: float = NORM_BETA,
    interpret: bool = True
):
    """One forward-only step for a frozen stage (no traces; the normalizer
    keeps running so downstream consumers see stable statistics).

    Returns (h2, c2, mu2, var2, h_norm, denom).
    """
    h2, c2 = column_forward(x, w, u, b, h, c, interpret=interpret)
    mu2, var2 = normalizer_update(mu, var, h2, beta)
    h_norm, denom = normalize(h2, mu2, var2, eps)
    return h2, c2, mu2, var2, h_norm, denom


def init_stage(key, n_cols, m, w_scale=0.5):
    """Initialize one stage's parameters and learner state (tests/demos)."""
    kw, ku, _ = jax.random.split(key, 3)
    w = jax.random.uniform(kw, (n_cols, 4, m), minval=-w_scale, maxval=w_scale)
    u = jax.random.uniform(ku, (n_cols, 4), minval=-w_scale, maxval=w_scale)
    b = jnp.zeros((n_cols, 4))
    zeros_g4m = jnp.zeros((n_cols, 4, m))
    zeros_g4 = jnp.zeros((n_cols, 4))
    state = dict(
        h=jnp.zeros(n_cols),
        c=jnp.zeros(n_cols),
        thw=zeros_g4m,
        tcw=zeros_g4m,
        thu=zeros_g4,
        tcu=zeros_g4,
        thb=zeros_g4,
        tcb=zeros_g4,
        mu=jnp.zeros(n_cols),
        var=jnp.ones(n_cols),
    )
    return dict(w=w, u=u, b=b), state


def example_args_step(n_cols, m, dtype=jnp.float32):
    """ShapeDtypeStructs for lowering columnar_learner_step."""
    s = jax.ShapeDtypeStruct
    return (
        s((m,), dtype),  # x
        s((n_cols, 4, m), dtype),  # w
        s((n_cols, 4), dtype),  # u
        s((n_cols, 4), dtype),  # b
        s((n_cols,), dtype),  # h
        s((n_cols,), dtype),  # c
        s((n_cols, 4, m), dtype),  # thw
        s((n_cols, 4, m), dtype),  # tcw
        s((n_cols, 4), dtype),  # thu
        s((n_cols, 4), dtype),  # tcu
        s((n_cols, 4), dtype),  # thb
        s((n_cols, 4), dtype),  # tcb
        s((n_cols,), dtype),  # mu
        s((n_cols,), dtype),  # var
    )


def example_args_fwd(n_cols, m, dtype=jnp.float32):
    """ShapeDtypeStructs for lowering frozen_stage_step."""
    s = jax.ShapeDtypeStruct
    return (
        s((m,), dtype),
        s((n_cols, 4, m), dtype),
        s((n_cols, 4), dtype),
        s((n_cols, 4), dtype),
        s((n_cols,), dtype),
        s((n_cols,), dtype),
        s((n_cols,), dtype),
        s((n_cols,), dtype),
    )
