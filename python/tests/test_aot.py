"""AOT path tests: lowering produces valid, loadable HLO text.

We check the text parses back through xla_client (same parser family the
Rust side's xla_extension uses), that the manifest enumerates coherent
shapes, and that numerics survive the round trip jax -> HLO -> execute.
"""

import json
import os
import subprocess
import sys

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from jax._src.lib import xla_client as xc

from compile.aot import (
    FWD_INPUTS,
    FWD_OUTPUTS,
    STEP_INPUTS,
    STEP_OUTPUTS,
    default_manifest,
    lower_fwd,
    lower_step,
)
from compile.model import columnar_learner_step, init_stage


def test_default_manifest_covers_paper_configs():
    shapes = default_manifest()
    assert (5, 7) in shapes  # trace-patterning columnar
    assert (4, 7) in shapes and (4, 23) in shapes  # trace CCN stages
    assert (7, 277) in shapes  # atari columnar
    assert (5, 277) in shapes  # atari CCN stage 0
    assert all(c > 0 and m > 0 for c, m in shapes)


def test_step_hlo_text_parses():
    text = lower_step(3, 5, 0.01)
    assert "HloModule" in text
    # must re-parse (this is exactly what HloModuleProto::from_text_file
    # does on the Rust side).
    comp = xc._xla.hlo_module_from_text(text)
    assert comp is not None


def test_fwd_hlo_text_parses():
    text = lower_fwd(3, 5, 0.01)
    assert "HloModule" in text
    comp = xc._xla.hlo_module_from_text(text)
    assert comp is not None


def test_hlo_text_structure():
    """The lowered step must expose one HLO parameter per model input and
    return a tuple with one element per model output — the contract the
    Rust runtime relies on (return_tuple=True, no tupled args)."""
    n_cols, m = 3, 4
    def entry_param_count(text):
        lines = text.splitlines()
        start = [i for i, l in enumerate(lines) if l.startswith("ENTRY")][0]
        return "\n".join(lines[start:]).count("parameter(")

    assert entry_param_count(lower_step(n_cols, m, 0.01)) == len(STEP_INPUTS)
    assert entry_param_count(lower_fwd(n_cols, m, 0.01)) == len(FWD_INPUTS)


def test_golden_roundtrip_consistency(tmp_path):
    """write_golden must emit outputs that re-running the model reproduces
    (protects the Rust cross-language check from a stale generator)."""
    from compile.aot import write_golden
    from compile.model import columnar_learner_step

    write_golden(str(tmp_path), 0.01)
    golden = json.loads((tmp_path / "golden.json").read_text())
    assert golden["n_cols"] == 3 and golden["m"] == 4
    step = golden["step"]
    args = [
        jnp.asarray(np.asarray(p["data"], dtype=np.float32).reshape(p["shape"]))
        for p in step["inputs"]
    ]
    outs = columnar_learner_step(*args, eps=golden["eps"])
    assert len(outs) == len(step["outputs"])
    for got, want in zip(outs, step["outputs"]):
        np.testing.assert_allclose(
            np.asarray(got).ravel(),
            np.asarray(want["data"], dtype=np.float32),
            rtol=2e-5, atol=2e-6,
        )


def test_aot_cli_writes_manifest(tmp_path):
    """Run the module CLI end-to-end on a tiny extra shape set."""
    out = tmp_path / "artifacts"
    env = dict(os.environ)
    res = subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out", str(out)],
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        capture_output=True, text=True, env=env, timeout=900,
    )
    assert res.returncode == 0, res.stderr
    manifest = json.loads((out / "manifest.json").read_text())
    assert manifest["gate_order"] == "ifog"
    files = {a["file"] for a in manifest["artifacts"]}
    assert f"col_step_c5_m7.hlo.txt" in files
    for a in manifest["artifacts"]:
        path = out / a["file"]
        assert path.exists()
        head = path.read_text()[:200]
        assert "HloModule" in head
        if a["kind"] == "step":
            assert a["inputs"] == STEP_INPUTS
            assert a["outputs"] == STEP_OUTPUTS
        else:
            assert a["inputs"] == FWD_INPUTS
            assert a["outputs"] == FWD_OUTPUTS
