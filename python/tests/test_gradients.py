"""Exactness of the RTRL traces against autodiff (the paper's check).

The paper verified its hand-derived C++ trace equations against PyTorch
BPTT gradients and "found them to match exactly". Here we verify the same
property against jax.jacfwd/jacrev of the *unrolled* column — the traces
after T steps must equal the true Jacobian dh_T/dtheta with no truncation
error, in float64.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.ref import column_rtrl_step_ref, lstm_column_forward

jax.config.update("jax_enable_x64", True)


def unrolled_h(params, xs):
    """h after len(xs) steps of a single column, as a function of params."""
    w, u, b = params
    h = jnp.zeros(())
    c = jnp.zeros(())
    for t in range(xs.shape[0]):
        h, c, _ = lstm_column_forward(xs[t], w, u, b, h, c)
    return h


def run_traces(w, u, b, xs):
    """Trace recursion over the same sequence; returns final traces."""
    n_cols, _, m = w.shape
    state = (
        jnp.zeros(n_cols), jnp.zeros(n_cols),
        jnp.zeros((n_cols, 4, m)), jnp.zeros((n_cols, 4, m)),
        jnp.zeros((n_cols, 4)), jnp.zeros((n_cols, 4)),
        jnp.zeros((n_cols, 4)), jnp.zeros((n_cols, 4)),
    )
    for t in range(xs.shape[0]):
        state = column_rtrl_step_ref(xs[t], w, u, b, *state)
    return state


@settings(max_examples=10, deadline=None)
@given(
    m=st.integers(min_value=1, max_value=8),
    t_len=st.integers(min_value=1, max_value=20),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_traces_equal_unrolled_jacobian(m, t_len, seed):
    rng = np.random.default_rng(seed)
    n_cols = 2
    w = jnp.asarray(rng.normal(size=(n_cols, 4, m)))
    u = jnp.asarray(rng.normal(size=(n_cols, 4)) * 0.5)
    b = jnp.asarray(rng.normal(size=(n_cols, 4)) * 0.1)
    xs = jnp.asarray(rng.normal(size=(t_len, m)))

    state = run_traces(w, u, b, xs)
    for k in range(n_cols):
        jac = jax.jacfwd(unrolled_h)((w[k], u[k], b[k]), xs)
        np.testing.assert_allclose(np.asarray(state[2][k]), np.asarray(jac[0]),
                                   rtol=1e-9, atol=1e-11)
        np.testing.assert_allclose(np.asarray(state[4][k]), np.asarray(jac[1]),
                                   rtol=1e-9, atol=1e-11)
        np.testing.assert_allclose(np.asarray(state[6][k]), np.asarray(jac[2]),
                                   rtol=1e-9, atol=1e-11)


def test_traces_equal_jacrev_long_sequence():
    """Reverse-mode cross-check over a longer horizon (T=60)."""
    rng = np.random.default_rng(42)
    m, t_len = 4, 60
    w = jnp.asarray(rng.normal(size=(1, 4, m)))
    u = jnp.asarray(rng.normal(size=(1, 4)) * 0.5)
    b = jnp.asarray(rng.normal(size=(1, 4)) * 0.1)
    xs = jnp.asarray(rng.normal(size=(t_len, m)))
    state = run_traces(w, u, b, xs)
    jac = jax.jacrev(unrolled_h)((w[0], u[0], b[0]), xs)
    np.testing.assert_allclose(np.asarray(state[2][0]), np.asarray(jac[0]),
                               rtol=1e-8, atol=1e-10)
    np.testing.assert_allclose(np.asarray(state[4][0]), np.asarray(jac[1]),
                               rtol=1e-8, atol=1e-10)
    np.testing.assert_allclose(np.asarray(state[6][0]), np.asarray(jac[2]),
                               rtol=1e-8, atol=1e-10)


def test_cell_traces_equal_jacobian_of_cell():
    """TC traces are dc/dtheta; check them too, not just TH."""
    rng = np.random.default_rng(5)
    m, t_len = 3, 15
    w = jnp.asarray(rng.normal(size=(1, 4, m)))
    u = jnp.asarray(rng.normal(size=(1, 4)) * 0.5)
    b = jnp.asarray(rng.normal(size=(1, 4)) * 0.1)
    xs = jnp.asarray(rng.normal(size=(t_len, m)))

    def unrolled_c(params, xs):
        w0, u0, b0 = params
        h = jnp.zeros(())
        c = jnp.zeros(())
        for t in range(xs.shape[0]):
            h, c, _ = lstm_column_forward(xs[t], w0, u0, b0, h, c)
        return c

    state = run_traces(w, u, b, xs)
    jac = jax.jacfwd(unrolled_c)((w[0], u[0], b[0]), xs)
    np.testing.assert_allclose(np.asarray(state[3][0]), np.asarray(jac[0]),
                               rtol=1e-9, atol=1e-11)
    np.testing.assert_allclose(np.asarray(state[5][0]), np.asarray(jac[1]),
                               rtol=1e-9, atol=1e-11)
    np.testing.assert_allclose(np.asarray(state[7][0]), np.asarray(jac[2]),
                               rtol=1e-9, atol=1e-11)


def test_prediction_gradient_via_traces():
    """dy/dtheta for y = sum_k w_out_k * h_k equals w_out_k * TH_k (the
    columnar factorization in Section 3.1)."""
    rng = np.random.default_rng(9)
    n_cols, m, t_len = 3, 4, 10
    w = jnp.asarray(rng.normal(size=(n_cols, 4, m)))
    u = jnp.asarray(rng.normal(size=(n_cols, 4)) * 0.5)
    b = jnp.asarray(rng.normal(size=(n_cols, 4)) * 0.1)
    w_out = jnp.asarray(rng.normal(size=n_cols))
    xs = jnp.asarray(rng.normal(size=(t_len, m)))

    def y_of_params(w_all):
        h = jnp.zeros(n_cols)
        c = jnp.zeros(n_cols)
        for t in range(t_len):
            hs = []
            cs = []
            for k in range(n_cols):
                hk, ck, _ = lstm_column_forward(xs[t], w_all[k], u[k], b[k], h[k], c[k])
                hs.append(hk)
                cs.append(ck)
            h = jnp.stack(hs)
            c = jnp.stack(cs)
        return jnp.dot(w_out, h)

    grad_w = jax.grad(y_of_params)(w)
    state = run_traces(w, u, b, xs)
    trace_grad = w_out[:, None, None] * state[2]
    np.testing.assert_allclose(np.asarray(trace_grad), np.asarray(grad_w),
                               rtol=1e-9, atol=1e-11)
