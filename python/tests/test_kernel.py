"""L1 correctness: Pallas column-RTRL kernel vs the pure-jnp oracle.

hypothesis sweeps column counts, input widths, block sizes and value
scales; dedicated cases cover saturated gates, zero inputs, and trace
accumulation over many steps.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.column_rtrl import column_forward, column_rtrl_step
from compile.kernels.ref import column_forward_ref, column_rtrl_step_ref

RTOL, ATOL = 2e-5, 2e-6


def make_args(rng, n_cols, m, scale=1.0, trace_scale=1.0):
    def r(*shape, s=scale):
        return jnp.asarray(rng.normal(size=shape) * s, dtype=jnp.float32)

    return (
        r(m),
        r(n_cols, 4, m),
        r(n_cols, 4, s=0.5 * scale),
        r(n_cols, 4, s=0.1 * scale),
        r(n_cols),
        r(n_cols),
        r(n_cols, 4, m, s=trace_scale),
        r(n_cols, 4, m, s=trace_scale),
        r(n_cols, 4, s=trace_scale),
        r(n_cols, 4, s=trace_scale),
        r(n_cols, 4, s=trace_scale),
        r(n_cols, 4, s=trace_scale),
    )


def assert_matches(out_kernel, out_ref):
    names = ["h2", "c2", "thw2", "tcw2", "thu2", "tcu2", "thb2", "tcb2"]
    for name, a, b in zip(names, out_kernel, out_ref):
        np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), rtol=RTOL, atol=ATOL, err_msg=name
        )


@settings(max_examples=25, deadline=None)
@given(
    n_cols=st.integers(min_value=1, max_value=12),
    m=st.integers(min_value=1, max_value=24),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    scale=st.sampled_from([0.1, 1.0, 3.0]),
)
def test_rtrl_step_matches_ref_hypothesis(n_cols, m, seed, scale):
    rng = np.random.default_rng(seed)
    args = make_args(rng, n_cols, m, scale=scale)
    assert_matches(column_rtrl_step(*args), column_rtrl_step_ref(*args))


@settings(max_examples=15, deadline=None)
@given(
    n_cols=st.integers(min_value=1, max_value=10),
    m=st.integers(min_value=1, max_value=16),
    col_block=st.integers(min_value=1, max_value=16),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_rtrl_step_block_size_invariance(n_cols, m, col_block, seed):
    """The Pallas grid tiling must not change the numbers."""
    rng = np.random.default_rng(seed)
    args = make_args(rng, n_cols, m)
    base = column_rtrl_step(*args, col_block=n_cols)
    tiled = column_rtrl_step(*args, col_block=col_block)
    assert_matches(tiled, base)


@settings(max_examples=15, deadline=None)
@given(
    n_cols=st.integers(min_value=1, max_value=8),
    m=st.integers(min_value=1, max_value=12),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_forward_matches_ref_hypothesis(n_cols, m, seed):
    rng = np.random.default_rng(seed)
    args = make_args(rng, n_cols, m)[:6]
    fk = column_forward(*args)
    fr = column_forward_ref(*args)
    np.testing.assert_allclose(np.asarray(fk[0]), np.asarray(fr[0]), rtol=RTOL, atol=ATOL)
    np.testing.assert_allclose(np.asarray(fk[1]), np.asarray(fr[1]), rtol=RTOL, atol=ATOL)


def test_saturated_gates():
    """Huge pre-activations saturate sigmoid/tanh; derivatives go to zero
    and the update must stay finite (no NaN from 0 * inf)."""
    rng = np.random.default_rng(7)
    args = list(make_args(rng, 4, 6))
    args[1] = args[1] * 0 + 50.0  # w
    args[3] = args[3] * 0 + 50.0  # b
    out = column_rtrl_step(*args)
    for a in out:
        assert np.all(np.isfinite(np.asarray(a)))
    assert_matches(out, column_rtrl_step_ref(*args))


def test_zero_input_zero_state():
    """From zero state/traces with zero input, traces of input weights stay
    zero (direct term is x=0) but bias traces become nonzero."""
    n_cols, m = 3, 5
    rng = np.random.default_rng(3)
    z_g4m = jnp.zeros((n_cols, 4, m), jnp.float32)
    z_g4 = jnp.zeros((n_cols, 4), jnp.float32)
    w = jnp.asarray(rng.normal(size=(n_cols, 4, m)), dtype=jnp.float32)
    u = jnp.asarray(rng.normal(size=(n_cols, 4)) * 0.5, dtype=jnp.float32)
    b = jnp.asarray(rng.normal(size=(n_cols, 4)) * 0.5, dtype=jnp.float32)
    out = column_rtrl_step(
        jnp.zeros(m, jnp.float32), w, u, b, jnp.zeros(n_cols, jnp.float32), jnp.zeros(n_cols, jnp.float32),
        z_g4m, z_g4m, z_g4, z_g4, z_g4, z_g4,
    )
    np.testing.assert_allclose(np.asarray(out[2]), 0.0, atol=1e-8)  # thw2
    np.testing.assert_allclose(np.asarray(out[3]), 0.0, atol=1e-8)  # tcw2
    assert np.any(np.abs(np.asarray(out[6])) > 1e-6)  # thb2 nonzero


def test_multi_step_accumulation_matches_ref():
    """Run 50 steps; kernel and oracle must stay in lockstep (no drift)."""
    rng = np.random.default_rng(11)
    n_cols, m = 5, 7
    params = make_args(rng, n_cols, m)[1:4]
    f32 = jnp.float32
    state_k = state_r = (
        jnp.zeros(n_cols, f32), jnp.zeros(n_cols, f32),
        jnp.zeros((n_cols, 4, m), f32), jnp.zeros((n_cols, 4, m), f32),
        jnp.zeros((n_cols, 4), f32), jnp.zeros((n_cols, 4), f32),
        jnp.zeros((n_cols, 4), f32), jnp.zeros((n_cols, 4), f32),
    )
    for _ in range(50):
        x = jnp.asarray(rng.normal(size=m), dtype=jnp.float32)
        state_k = column_rtrl_step(x, *params, *state_k)
        state_r = column_rtrl_step_ref(x, *params, *state_r)
    assert_matches(state_k, state_r)


def test_column_independence():
    """Perturbing column i's parameters must not change column j's output —
    the structural property that makes columnar RTRL linear-cost."""
    rng = np.random.default_rng(13)
    n_cols, m = 6, 9
    args = list(make_args(rng, n_cols, m))
    base = column_rtrl_step(*args)
    perturbed = list(args)
    w2 = np.asarray(perturbed[1]).copy()
    w2[2] += 1.5  # hit column 2 only
    perturbed[1] = jnp.asarray(w2)
    out = column_rtrl_step(*perturbed)
    others = [k for k in range(n_cols) if k != 2]
    np.testing.assert_allclose(
        np.asarray(out[0])[others], np.asarray(base[0])[others], rtol=0, atol=0
    )
    assert not np.allclose(np.asarray(out[0])[2], np.asarray(base[0])[2])


@pytest.mark.parametrize("n_cols,m", [(1, 1), (1, 64), (16, 1), (13, 277)])
def test_extreme_shapes(n_cols, m):
    rng = np.random.default_rng(n_cols * 100 + m)
    args = make_args(rng, n_cols, m)
    assert_matches(column_rtrl_step(*args), column_rtrl_step_ref(*args))
