"""L2 tests: normalizer (paper eq. 10), learner-step assembly, shapes."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.model import (
    NORM_BETA,
    columnar_learner_step,
    frozen_stage_step,
    init_stage,
    normalize,
    normalizer_update,
)


def test_normalizer_recursion_matches_paper_eq10():
    """Literal transcription check of eq. 10 on a hand-computed step."""
    mu, var, f, beta = 0.5, 2.0, 3.0, 0.9
    mu2, var2 = normalizer_update(jnp.asarray(mu), jnp.asarray(var),
                                  jnp.asarray(f), beta)
    expect_mu2 = mu * beta + (1 - beta) * f  # 0.75
    expect_var2 = var * beta + (1 - beta) * (expect_mu2 - f) * (mu - f)
    np.testing.assert_allclose(float(mu2), expect_mu2, rtol=1e-6)
    np.testing.assert_allclose(float(var2), expect_var2, rtol=1e-6)


def test_normalizer_converges_to_moments():
    """On an iid stream the running estimates approach the true moments."""
    rng = np.random.default_rng(0)
    beta = 0.999
    mu = jnp.zeros(1)
    var = jnp.ones(1)
    for _ in range(20000):
        f = jnp.asarray([rng.normal(loc=2.0, scale=3.0)])
        mu, var = normalizer_update(mu, var, f, beta)
    assert abs(float(mu[0]) - 2.0) < 0.3
    assert abs(float(jnp.sqrt(var[0])) - 3.0) < 0.5


@settings(max_examples=20, deadline=None)
@given(
    var=st.floats(min_value=0.0, max_value=10.0),
    eps=st.sampled_from([0.1, 0.01, 0.001]),
    f=st.floats(min_value=-100.0, max_value=100.0),
    mu=st.floats(min_value=-10.0, max_value=10.0),
)
def test_normalize_epsilon_floor(var, eps, f, mu):
    """The epsilon floor bounds |f_hat| <= |f - mu| / eps and keeps the
    output finite even at zero variance (the paper's stability fix)."""
    f_hat, denom = normalize(jnp.asarray(f), jnp.asarray(mu),
                             jnp.asarray(var), eps)
    assert np.isfinite(float(f_hat))
    assert float(denom) >= eps - 1e-9
    assert abs(float(f_hat)) <= abs(f - mu) / eps + 1e-6


def test_learner_step_shapes_and_finiteness():
    key = jax.random.PRNGKey(0)
    n_cols, m = 4, 11
    params, state = init_stage(key, n_cols, m)
    x = jax.random.normal(jax.random.PRNGKey(1), (m,))
    out = columnar_learner_step(
        x, params["w"], params["u"], params["b"],
        state["h"], state["c"], state["thw"], state["tcw"],
        state["thu"], state["tcu"], state["thb"], state["tcb"],
        state["mu"], state["var"],
    )
    assert len(out) == 12
    shapes = [o.shape for o in out]
    assert shapes[0] == (n_cols,)  # h2
    assert shapes[2] == (n_cols, 4, m)  # thw2
    assert shapes[10] == (n_cols,)  # h_norm
    for o in out:
        assert np.all(np.isfinite(np.asarray(o)))


def test_frozen_step_matches_learning_step_forward():
    """The frozen (forward-only) step must produce the same h2/c2/norm as
    the learning step — freezing changes traces, never the forward pass."""
    key = jax.random.PRNGKey(3)
    n_cols, m = 5, 7
    params, state = init_stage(key, n_cols, m)
    x = jax.random.normal(jax.random.PRNGKey(4), (m,))
    full = columnar_learner_step(
        x, params["w"], params["u"], params["b"],
        state["h"], state["c"], state["thw"], state["tcw"],
        state["thu"], state["tcu"], state["thb"], state["tcb"],
        state["mu"], state["var"],
    )
    froz = frozen_stage_step(
        x, params["w"], params["u"], params["b"],
        state["h"], state["c"], state["mu"], state["var"],
    )
    np.testing.assert_allclose(np.asarray(froz[0]), np.asarray(full[0]), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(froz[1]), np.asarray(full[1]), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(froz[4]), np.asarray(full[10]), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(froz[5]), np.asarray(full[11]), rtol=1e-6)


def test_learner_step_runs_many_steps_stable():
    """200 steps on random input: no NaN/inf, normalizer variance stays
    positive, normalized features stay bounded by the eps floor."""
    key = jax.random.PRNGKey(5)
    n_cols, m = 3, 6
    params, state = init_stage(key, n_cols, m)
    rng = np.random.default_rng(8)
    vals = list(state.values())
    keys = list(state.keys())
    eps = 0.01
    for _ in range(200):
        x = jnp.asarray(rng.normal(size=m), dtype=jnp.float32)
        out = columnar_learner_step(
            x, params["w"], params["u"], params["b"], *vals[:10], eps=eps
        )
        vals = list(out[:10])
        h_norm = np.asarray(out[10])
        assert np.all(np.isfinite(h_norm))
        # LSTM h in (-1, 1); with the eps floor, |h_norm| < 2 / eps always.
        assert np.all(np.abs(h_norm) < 2.0 / eps)
    assert np.all(np.asarray(vals[9]) >= 0)  # var non-negative
