//! Appendix A: the per-step operation-count estimates vs *measured*
//! work. We time each architecture at the paper's configurations and
//! check that measured time per step scales like the Appendix-A op
//! estimates across configurations (the estimates are counts, not
//! nanoseconds, so we compare *ratios*).

#[path = "common/mod.rs"]
mod common;

use std::time::Instant;

use ccn_rtrl::compute;
use ccn_rtrl::config::{build_agent, ExperimentConfig, LearnerKind};
use ccn_rtrl::metrics::render_table;
use ccn_rtrl::util::prng::Xoshiro256;

fn time_learner(learner: LearnerKind, n_inputs: usize, steps: u64) -> f64 {
    let cfg = ExperimentConfig {
        learner,
        alpha: 0.001,
        ..Default::default()
    };
    let mut agent = build_agent(&cfg, n_inputs, 0.9);
    let mut rng = Xoshiro256::seed_from_u64(0);
    let x: Vec<Vec<f32>> = (0..64)
        .map(|_| (0..n_inputs).map(|_| rng.uniform(0.0, 1.0)).collect())
        .collect();
    // warmup
    for i in 0..1000 {
        agent.step(&x[i % 64], 0.1);
    }
    let t0 = Instant::now();
    for i in 0..steps {
        agent.step(&x[(i % 64) as usize], 0.1);
    }
    t0.elapsed().as_secs_f64() / steps as f64
}

fn main() {
    let steps = common::steps(300_000);
    let n = 7usize;
    let cases: Vec<(String, LearnerKind, u64)> = vec![
        (
            "columnar d=5".into(),
            LearnerKind::Columnar { d: 5 },
            compute::columnar_ops(5, n as u64),
        ),
        (
            "ccn 20/4".into(),
            LearnerKind::Ccn {
                total: 20,
                per_stage: 4,
                steps_per_stage: u64::MAX / 2,
            },
            compute::ccn_ops(20, n as u64, 4),
        ),
        (
            "tbptt 2:30".into(),
            LearnerKind::Tbptt { d: 2, k: 30 },
            compute::tbptt_ops(2, n as u64, 30),
        ),
        (
            "tbptt 13:2".into(),
            LearnerKind::Tbptt { d: 13, k: 2 },
            compute::tbptt_ops(13, n as u64, 2),
        ),
        (
            "tbptt 10:20".into(),
            LearnerKind::Tbptt { d: 10, k: 20 },
            compute::tbptt_ops(10, n as u64, 20),
        ),
    ];

    let mut rows = Vec::new();
    let mut measured = Vec::new();
    for (name, learner, est) in &cases {
        // CCN estimate above assumes fully-grown net; drive it grown by
        // keeping a single stage forever only for columnar — acceptable
        // approximation at bench scale.
        let per = time_learner(learner.clone(), n, steps);
        measured.push(per);
        rows.push(vec![
            name.clone(),
            est.to_string(),
            format!("{:.1} ns", per * 1e9),
            format!("{:.2}", per * 1e9 / *est as f64 * 1000.0), // ns per kop
        ]);
    }
    println!("Appendix A — estimated ops vs measured per-step time ({steps} steps):");
    println!(
        "{}",
        render_table(
            &["config", "est ops/step", "measured/step", "ns per k-op"],
            &rows
        )
    );
    // shape check: the ~7x op ratio between tbptt 10:20 and 13:2... compare
    // estimate ratios to time ratios for the tbptt family.
    let est_ratio = cases[4].2 as f64 / cases[3].2 as f64;
    let t_ratio = measured[4] / measured[3];
    println!(
        "tbptt 10:20 vs 13:2 — estimate ratio {est_ratio:.2}x, measured {t_ratio:.2}x\n\
         (the Appendix-A model predicts relative cost within ~2x on this CPU)"
    );
}
