//! X2 (Section 6 extension): the plasticity-loss ablation. The paper's
//! acknowledged limitation is that CCN freezes most features over time;
//! it proposes (a) letting frozen features keep changing slowly or (b)
//! recycling useless features. We quantify the baseline effect: train a
//! CCN to full freeze on trace patterning, then *switch the activating
//! pattern set* (a non-stationarity) and compare recovery against a
//! columnar net that never froze.
//!
//! Expected shape: before the switch CCN is better (hierarchy); after the
//! switch the columnar net recovers while the fully frozen CCN's error
//! stays elevated — plasticity loss made visible.

#[path = "common/mod.rs"]
mod common;

use ccn_rtrl::config::{EnvKind, ExperimentConfig, LearnerKind};
use ccn_rtrl::coordinator::run_experiment;
use ccn_rtrl::metrics::render_table;

fn run_with_switch(learner: LearnerKind, steps: u64, seed: u64) -> (f64, f64) {
    // phase 1: normal trace patterning (env seed = seed)
    let cfg1 = ExperimentConfig {
        env: EnvKind::TracePatterning,
        learner: learner.clone(),
        alpha: 0.001,
        lambda: 0.99,
        gamma_override: None,
        eps: 0.1,
        steps,
        seed,
        curve_points: 20,
    };
    let res1 = run_experiment(&cfg1).expect("run");
    // phase 2 proxy: a *different* activating-pattern set (env seed
    // shifted) with the same learner config restarted at the same stage
    // schedule but frozen from the start is not directly expressible via
    // run_experiment; we approximate the paper's concern by measuring how
    // a CCN whose stages all froze (steps_per_stage = steps/5 over phase
    // 1's budget) performs when trained on the *switched* task for the
    // same number of steps with its schedule exhausted at the midpoint.
    let cfg2 = ExperimentConfig {
        env: EnvKind::TracePatterning,
        learner: match &learner {
            LearnerKind::Ccn {
                total, per_stage, ..
            } => LearnerKind::Ccn {
                total: *total,
                per_stage: *per_stage,
                // schedule exhausts halfway: second half runs fully frozen
                steps_per_stage: (steps / 10).max(1),
            },
            other => other.clone(),
        },
        seed: seed + 1000, // different activating set
        ..cfg1.clone()
    };
    let res2 = run_experiment(&cfg2).expect("run");
    (res1.tail_error, res2.tail_error)
}

fn main() {
    let steps = common::steps(1_500_000);
    let learners = vec![
        LearnerKind::Ccn {
            total: 20,
            per_stage: 4,
            steps_per_stage: (steps / 5).max(1),
        },
        LearnerKind::Columnar { d: 5 },
    ];
    let mut rows = Vec::new();
    for learner in learners {
        let (normal, frozen_regime) = run_with_switch(learner.clone(), steps, 0);
        rows.push(vec![
            learner.label(),
            format!("{normal:.5}"),
            format!("{frozen_regime:.5}"),
            format!("{:.2}x", frozen_regime / normal.max(1e-12)),
        ]);
    }
    println!("X2 — plasticity ablation (schedule-exhausted regime), {steps} steps:");
    println!(
        "{}",
        render_table(
            &["learner", "normal schedule", "early-frozen schedule", "penalty"],
            &rows
        )
    );
    println!(
        "shape: columnar (never frozen) pays no penalty; CCN pays when its\n\
         growth schedule exhausts early — the Section-6 plasticity concern."
    );
}
