//! Shared harness for the paper-figure benches (no `criterion` offline).
//!
//! Every bench binary regenerates one table/figure of the paper at a
//! configurable scale and prints the series plus writes CSVs under
//! results/. Scale knobs (env vars):
//!
//!   CCN_BENCH_STEPS   total steps per run   (default per-bench)
//!   CCN_BENCH_SEEDS   number of seeds       (default 3)
//!   CCN_BENCH_THREADS worker threads        (default all cores)

#![allow(dead_code)]

use std::path::Path;

use ccn_rtrl::coordinator::{aggregate_runs, run_sweep, sweep, AggregateResult};
use ccn_rtrl::config::ExperimentConfig;
use ccn_rtrl::metrics::write_csv;
use ccn_rtrl::util::json::Json;

/// Schema tag stamped into every bench JSON artifact. CI validates the
/// shape (`scripts/check_bench_schema.py`): a top-level `schema` +
/// `bench` pair, and every embedded latency histogram in the
/// `obs::HistogramSnapshot::to_json` shape (count == sum of bucket
/// counts, ascending bucket bounds, monotone percentiles).
pub const BENCH_SCHEMA: &str = "ccn.bench.v1";

pub fn env_u64(name: &str, default: u64) -> u64 {
    std::env::var(name)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

pub fn env_usize(name: &str, default: usize) -> usize {
    env_u64(name, default as u64) as usize
}

/// Write one unified-schema bench artifact: `fields` prefixed with the
/// `schema`/`bench` identity pair, pretty-printed to `out_path`.
pub fn write_bench_json(out_path: &str, bench: &str, fields: Vec<(&str, Json)>) {
    let mut all: Vec<(&str, Json)> = vec![
        ("schema", Json::Str(BENCH_SCHEMA.to_string())),
        ("bench", Json::Str(bench.to_string())),
    ];
    all.extend(fields);
    let json = Json::obj(all);
    if let Some(parent) = Path::new(out_path).parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent).expect("create results dir");
        }
    }
    std::fs::write(out_path, json.pretty()).expect("write bench json");
    eprintln!("[bench] wrote {out_path}");
}

pub fn steps(default: u64) -> u64 {
    env_u64("CCN_BENCH_STEPS", default)
}

pub fn seeds(default: u64) -> Vec<u64> {
    (0..env_u64("CCN_BENCH_SEEDS", default)).collect()
}

pub fn threads() -> usize {
    env_u64("CCN_BENCH_THREADS", sweep::default_threads() as u64) as usize
}

/// Run configs x seeds and aggregate.
pub fn sweep_and_aggregate(
    bases: Vec<ExperimentConfig>,
    seed_list: &[u64],
) -> Vec<AggregateResult> {
    let mut configs = Vec::new();
    for base in &bases {
        configs.extend(sweep::seeds(base, seed_list));
    }
    eprintln!(
        "[bench] {} runs ({} configs x {} seeds) on {} threads",
        configs.len(),
        bases.len(),
        seed_list.len(),
        threads()
    );
    let res = run_sweep(configs, threads()).expect("sweep");
    aggregate_runs(&res.runs)
}

/// Write one aggregate's learning curve as CSV under results/.
pub fn save_curves(prefix: &str, aggs: &[AggregateResult]) {
    for a in aggs {
        let xs: Vec<f64> = a.curve_x.iter().map(|&v| v as f64).collect();
        let path = format!("results/{prefix}_{}_{}.csv", a.env, a.learner);
        write_csv(
            Path::new(&path),
            &["step", "mse", "stderr"],
            &[&xs, &a.curve_mean, &a.curve_stderr],
        )
        .expect("write curve csv");
    }
    eprintln!("[bench] wrote {} curve CSVs under results/ ({prefix}_*)", aggs.len());
}
