//! Figure 10: prediction-vs-ground-truth traces at the end of learning on
//! five environments — the qualitative "does the prediction track the
//! return" plot. We train CCN and the best T-BPTT on five synthetic-ALE
//! games, then dump the final 600 steps of (prediction, empirical return)
//! per method to results/fig10_*.csv and print summary tracking stats.
//!
//! Paper shape: both methods follow the general trend; CCN tracks the
//! ground-truth return visibly more closely (most pronounced on Pong).

#[path = "common/mod.rs"]
mod common;

use std::path::Path;

use ccn_rtrl::config::{EnvKind, ExperimentConfig, LearnerKind};
use ccn_rtrl::coordinator::{run_sweep, sweep};
use ccn_rtrl::metrics::{render_table, write_csv};

const GAMES: [&str; 5] = ["pong", "breakout", "freeway", "chaser", "blinkgrid"];

fn main() {
    let steps = common::steps(400_000);

    let ccn = LearnerKind::Ccn {
        total: 15,
        per_stage: 5,
        steps_per_stage: (steps / 3).max(1),
    };
    let tbptt = LearnerKind::Tbptt { d: 8, k: 5 };

    let mut configs = Vec::new();
    for game in GAMES {
        for learner in [ccn.clone(), tbptt.clone()] {
            configs.push(ExperimentConfig {
                env: EnvKind::SynthAtari { game: game.into() },
                learner,
                alpha: 0.001,
                lambda: 0.99,
                gamma_override: None,
                eps: 0.1,
                steps,
                seed: 0,
                curve_points: 20,
            });
        }
    }
    eprintln!("[bench] fig10: {} runs x {steps} steps", configs.len());
    let res = run_sweep(configs, common::threads()).expect("sweep");

    let mut rows = Vec::new();
    for r in &res.runs {
        // reconstruct the empirical return over the recorded tail:
        // G_t = sum gamma^{j-t-1} c_j (truncated at the window end).
        let gamma = 0.98f64;
        let n = r.tail_trace.len();
        let mut g = vec![0.0f64; n + 1];
        for t in (0..n).rev() {
            g[t] = r.tail_trace[t].1 as f64 + gamma * g[t + 1];
        }
        // drop the last ~horizon entries whose return is truncated hard
        let valid = n.saturating_sub(200);
        let ys: Vec<f64> = r.tail_trace[..valid].iter().map(|&(y, _)| y as f64).collect();
        let gs: Vec<f64> = (0..valid).map(|t| g[t + 1]).collect();
        let steps_axis: Vec<f64> = (0..valid).map(|t| t as f64).collect();
        write_csv(
            Path::new(&format!("results/fig10_{}_{}.csv", r.env, r.learner)),
            &["t", "prediction", "return"],
            &[&steps_axis, &ys, &gs],
        )
        .expect("csv");
        // tracking error over the visualized window
        let mse: f64 = ys
            .iter()
            .zip(&gs)
            .map(|(y, g)| (y - g) * (y - g))
            .sum::<f64>()
            / valid.max(1) as f64;
        rows.push(vec![r.env.clone(), r.learner.clone(), format!("{mse:.5}")]);
    }
    println!("Figure 10 — final-phase prediction tracking (window MSE):");
    println!(
        "{}",
        render_table(&["environment", "learner", "tail-window MSE"], &rows)
    );
    println!("full traces: results/fig10_<env>_<learner>.csv (plot t vs prediction/return)");
}
