//! Figure 11: T-BPTT capacity/truncation sensitivity on the
//! Atari-prediction benchmark. Left panel: fix k=8, vary d in
//! {2,4,8,12,15}; right panel: fix d=8, vary k in {2,4,8,12,15}.
//! Errors averaged over environments, normalized to the d=15 (resp.
//! k=15) point = 1.0.
//!
//! Paper shape: more features help more than a longer window — d: 2 -> 15
//! halves the error; k: 2 -> 15 cuts it ~23%.

#[path = "common/mod.rs"]
mod common;

use ccn_rtrl::config::{EnvKind, ExperimentConfig, LearnerKind};
use ccn_rtrl::metrics::render_table;

const SWEEP: [usize; 3] = [2, 8, 15];
// a representative subset of environments keeps the bench tractable
const GAMES: [&str; 4] = ["pong", "breakout", "chaser", "drift0"];

fn main() {
    let steps = common::steps(200_000);
    let seeds = common::seeds(1);

    let mut bases = Vec::new();
    for game in GAMES {
        for &d in &SWEEP {
            bases.push(ExperimentConfig {
                env: EnvKind::SynthAtari { game: game.into() },
                learner: LearnerKind::Tbptt { d, k: 8 },
                alpha: 0.001,
                lambda: 0.99,
                gamma_override: None,
                eps: 0.01,
                steps,
                seed: 0,
                curve_points: 20,
            });
        }
        for &k in &SWEEP {
            if k == 8 {
                continue; // already covered by the d-sweep cell (8, 8)
            }
            bases.push(ExperimentConfig {
                env: EnvKind::SynthAtari { game: game.into() },
                learner: LearnerKind::Tbptt { d: 8, k },
                alpha: 0.001,
                lambda: 0.99,
                gamma_override: None,
                eps: 0.01,
                steps,
                seed: 0,
                curve_points: 20,
            });
        }
    }

    let aggs = common::sweep_and_aggregate(bases, &seeds);

    // average error over games for a given learner label
    let avg_err = |label: &str| -> f64 {
        let v: Vec<f64> = aggs
            .iter()
            .filter(|a| a.learner == label)
            .map(|a| a.tail_mean)
            .collect();
        v.iter().sum::<f64>() / v.len().max(1) as f64
    };

    let d_ref = avg_err(&LearnerKind::Tbptt { d: 15, k: 8 }.label());
    let mut rows = Vec::new();
    for &d in &SWEEP {
        let e = avg_err(&LearnerKind::Tbptt { d, k: 8 }.label());
        rows.push(vec![
            format!("d={d} (k=8)"),
            format!("{e:.5}"),
            format!("{:.3}", e / d_ref),
        ]);
    }
    let k_ref = avg_err(&LearnerKind::Tbptt { d: 8, k: 15 }.label());
    for &k in &SWEEP {
        let e = avg_err(&LearnerKind::Tbptt { d: 8, k }.label());
        rows.push(vec![
            format!("k={k} (d=8)"),
            format!("{e:.5}"),
            format!("{:.3}", e / k_ref),
        ]);
    }
    println!(
        "Figure 11 — T-BPTT sensitivity on the Atari benchmark, {steps} steps:"
    );
    println!(
        "{}",
        render_table(&["config", "avg err", "normalized (=1 at 15)"], &rows)
    );
    println!(
        "expected shape (paper): err(d=2) ≈ 2x err(d=15); err(k=2) ≈ 1.3x err(k=15)\n\
         — capacity matters more than window on this benchmark."
    );
}
