//! Figure 4: learning curves of Columnar(5), Constructive(10), CCN(20,
//! 4/stage) and the best equal-budget T-BPTT (2 features, k=30) on trace
//! patterning. All four use ≈4k ops/step (Appendix A).
//!
//! Paper shape to reproduce (at full 50M-step scale): all methods learn;
//! columnar converges to the *worst* plateau (no hierarchy); CCN and
//! constructive reach near-optimal error with stage-shaped drops; the
//! best T-BPTT lands in between.
//!
//! Default scale: 20M steps (0.4x paper), 3 seeds. Env overrides in
//! common/mod.rs. Pass --snap1 to add the SnAp-1 baseline (extension X1).

#[path = "common/mod.rs"]
mod common;

use ccn_rtrl::config::{EnvKind, ExperimentConfig, LearnerKind};
use ccn_rtrl::metrics::render_table;

fn main() {
    let with_snap1 = std::env::args().any(|a| a == "--snap1");
    let steps = common::steps(6_000_000);
    let seeds = common::seeds(2);
    // stage schedule scales with the run as in the paper (5 CCN stages,
    // 10 constructive stages over the whole run).
    let mut methods = vec![
        LearnerKind::Columnar { d: 5 },
        LearnerKind::Constructive {
            total: 10,
            steps_per_stage: (steps / 10).max(1),
        },
        LearnerKind::Ccn {
            total: 20,
            per_stage: 4,
            steps_per_stage: (steps / 5).max(1),
        },
        LearnerKind::Tbptt { d: 2, k: 30 },
    ];
    if with_snap1 {
        methods.push(LearnerKind::Snap1 { d: 5 });
    }

    let bases: Vec<ExperimentConfig> = methods
        .iter()
        .map(|learner| ExperimentConfig {
            env: EnvKind::TracePatterning,
            learner: learner.clone(),
            alpha: 0.001,
            lambda: 0.99,
            gamma_override: None,
            eps: 0.1,
            steps,
            seed: 0,
            curve_points: 100,
        })
        .collect();

    let aggs = common::sweep_and_aggregate(bases, &seeds);
    common::save_curves("fig4", &aggs);

    let mut rows = Vec::new();
    for a in &aggs {
        let start = a.curve_mean.iter().take(5).sum::<f64>() / 5.0;
        rows.push(vec![
            a.learner.clone(),
            format!("{:.5}", start),
            format!("{:.5} ± {:.5}", a.tail_mean, a.tail_stderr),
            format!("{:.2}x", start / a.tail_mean.max(1e-12)),
            format!("{:.2}M/s", a.mean_steps_per_sec / 1e6),
        ]);
    }
    println!("Figure 4 — trace patterning, equal ~4k-op budget, {steps} steps:");
    println!(
        "{}",
        render_table(
            &["method", "initial", "final (±se)", "improvement", "speed"],
            &rows
        )
    );
    println!(
        "expected shape (paper, 50M steps): ccn ≈ constructive < tbptt_2x30 < columnar"
    );
}
