//! Figure 5: the T-BPTT truncation/width trade-off at a *fixed* ~4k-op
//! budget on trace patterning. Table-1 pairs: 2:13, 3:10, 5:8, 8:6,
//! 10:5, 15:4, 20:3, 30:2 (k:d).
//!
//! Paper shape: large nets with tiny truncation (13 features, k=2) are
//! the worst — the truncation bias dominates when k is far below the
//! longest dependency (ISI up to 26); the best configuration is the
//! smallest network with the longest window (2:30).

#[path = "common/mod.rs"]
mod common;

use ccn_rtrl::compute::{self, TRACE_TBPTT_PAIRS};
use ccn_rtrl::config::{EnvKind, ExperimentConfig, LearnerKind};
use ccn_rtrl::metrics::render_table;

fn main() {
    let steps = common::steps(2_500_000);
    let seeds = common::seeds(2);

    let bases: Vec<ExperimentConfig> = TRACE_TBPTT_PAIRS
        .iter()
        .map(|&(k, d)| ExperimentConfig {
            env: EnvKind::TracePatterning,
            learner: LearnerKind::Tbptt {
                d: d as usize,
                k: k as usize,
            },
            alpha: 0.001,
            lambda: 0.99,
            gamma_override: None,
            eps: 0.01,
            steps,
            seed: 0,
            curve_points: 50,
        })
        .collect();

    let aggs = common::sweep_and_aggregate(bases, &seeds);
    common::save_curves("fig5", &aggs);

    let mut rows = Vec::new();
    for &(k, d) in &TRACE_TBPTT_PAIRS {
        let label = LearnerKind::Tbptt {
            d: d as usize,
            k: k as usize,
        }
        .label();
        let a = aggs.iter().find(|a| a.learner == label).unwrap();
        rows.push(vec![
            format!("{d}:{k}"),
            compute::tbptt_ops(d, 7, k).to_string(),
            format!("{:.5} ± {:.5}", a.tail_mean, a.tail_stderr),
        ]);
    }
    println!("Figure 5 — T-BPTT d:k pairs at equal ~4k-op budget, {steps} steps:");
    println!(
        "{}",
        render_table(&["d:k", "ops/step", "final err (±se)"], &rows)
    );
    println!(
        "expected shape (paper): 13:2 and 10:3 worst (k « longest dependency 26);\n\
         2:30 best — T-BPTT prefers fewer features + longer window here."
    );
}
