//! Figure 6: T-BPTT with the compute constraint *removed* — a fixed
//! 10-unit LSTM with truncation windows k in {2, 3, 5, 10, 20} on trace
//! patterning.
//!
//! Paper shape: performance improves monotonically (in the long run)
//! with k; k=20 approaches CCN-level error but uses ~10x the compute of
//! k=2 (by the Appendix-A estimate the exact ratio is (20+1)/(2+1) = 7x).

#[path = "common/mod.rs"]
mod common;

use ccn_rtrl::compute;
use ccn_rtrl::config::{EnvKind, ExperimentConfig, LearnerKind};
use ccn_rtrl::metrics::render_table;

const WINDOWS: [usize; 5] = [2, 3, 5, 10, 20];
const D: usize = 10;

fn main() {
    let steps = common::steps(2_500_000);
    let seeds = common::seeds(2);

    let bases: Vec<ExperimentConfig> = WINDOWS
        .iter()
        .map(|&k| ExperimentConfig {
            env: EnvKind::TracePatterning,
            learner: LearnerKind::Tbptt { d: D, k },
            alpha: 0.001,
            lambda: 0.99,
            gamma_override: None,
            eps: 0.01,
            steps,
            seed: 0,
            curve_points: 50,
        })
        .collect();

    let aggs = common::sweep_and_aggregate(bases, &seeds);
    common::save_curves("fig6", &aggs);

    let base_ops = compute::tbptt_ops(D as u64, 7, 2);
    let mut rows = Vec::new();
    for &k in &WINDOWS {
        let label = LearnerKind::Tbptt { d: D, k }.label();
        let a = aggs.iter().find(|a| a.learner == label).unwrap();
        let ops = compute::tbptt_ops(D as u64, 7, k as u64);
        rows.push(vec![
            format!("k={k}"),
            ops.to_string(),
            format!("{:.1}x", ops as f64 / base_ops as f64),
            format!("{:.5} ± {:.5}", a.tail_mean, a.tail_stderr),
        ]);
    }
    println!(
        "Figure 6 — T-BPTT d={D}, unconstrained compute, {steps} steps:"
    );
    println!(
        "{}",
        render_table(
            &["window", "ops/step", "vs k=2", "final err (±se)"],
            &rows
        )
    );
    println!("expected shape (paper): error falls as k grows; compute grows ~(k+1).");
}
