//! Figure 8: per-environment relative error of the CCN vs the best
//! equal-budget T-BPTT on the Atari-prediction benchmark (our
//! synthetic-ALE suite — see DESIGN.md §Substitutions), gamma = 0.98,
//! ~50k-op budget, error normalized so T-BPTT == 1.0 per environment.
//!
//! Paper shape: CCN beats T-BPTT in all but ~2 environments, often by
//! several-fold; worst case ~2x worse.

#[path = "common/mod.rs"]
mod common;

use ccn_rtrl::config::{EnvKind, ExperimentConfig, LearnerKind};
use ccn_rtrl::coordinator::aggregate::relative_errors;
use ccn_rtrl::env::synthatari;
use ccn_rtrl::metrics::render_table;

fn main() {
    let steps = common::steps(200_000);
    let seeds = common::seeds(2);

    let ccn = LearnerKind::Ccn {
        total: 15,
        per_stage: 5,
        steps_per_stage: (steps / 3).max(1),
    };
    let tbptt = LearnerKind::Tbptt { d: 8, k: 5 }; // best Table-1 pair

    let mut bases = Vec::new();
    for game in synthatari::env_names() {
        for learner in [ccn.clone(), tbptt.clone()] {
            bases.push(ExperimentConfig {
                env: EnvKind::SynthAtari { game: game.into() },
                learner,
                alpha: 0.001,
                lambda: 0.99,
                gamma_override: None,
                eps: 0.1,
                steps,
                seed: 0,
                curve_points: 40,
            });
        }
    }

    let aggs = common::sweep_and_aggregate(bases, &seeds);
    common::save_curves("fig8", &aggs);

    let rel = relative_errors(&aggs, &ccn.label(), &tbptt.label());
    let mut rows = Vec::new();
    let mut wins = 0;
    for (env, r) in &rel {
        if *r < 1.0 {
            wins += 1;
        }
        let ccn_agg = aggs
            .iter()
            .find(|a| a.learner == ccn.label() && &a.env == env)
            .unwrap();
        let tb = aggs
            .iter()
            .find(|a| a.learner == tbptt.label() && &a.env == env)
            .unwrap();
        rows.push(vec![
            env.clone(),
            format!("{:.5}", ccn_agg.tail_mean),
            format!("{:.5}", tb.tail_mean),
            format!("{:.3}", r),
        ]);
    }
    println!(
        "Figure 8 — per-environment error, CCN vs best T-BPTT (=1.0), {steps} steps:"
    );
    println!(
        "{}",
        render_table(
            &["environment", "ccn err", "tbptt err", "ccn/tbptt"],
            &rows
        )
    );
    println!(
        "CCN better in {wins}/{} environments \
         (paper: all but 2 of 50, many at <0.2x)",
        rel.len()
    );
}
