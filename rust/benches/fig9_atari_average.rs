//! Figure 9: average T-BPTT-relative error of *all four* methods on the
//! Atari-prediction benchmark (columnar, constructive, CCN vs the best
//! T-BPTT).
//!
//! Paper shape: all three proposed methods improve on T-BPTT on average;
//! CCN best, at less than half of T-BPTT's average error.

#[path = "common/mod.rs"]
mod common;

use ccn_rtrl::config::{EnvKind, ExperimentConfig, LearnerKind};
use ccn_rtrl::coordinator::aggregate::relative_errors;
use ccn_rtrl::env::synthatari;
use ccn_rtrl::metrics::render_table;

fn main() {
    let steps = common::steps(150_000);
    let seeds = common::seeds(1);

    let methods = vec![
        LearnerKind::Tbptt { d: 8, k: 5 },
        LearnerKind::Columnar { d: 7 },
        LearnerKind::Constructive {
            total: 8,
            steps_per_stage: (steps / 8).max(1),
        },
        LearnerKind::Ccn {
            total: 15,
            per_stage: 5,
            steps_per_stage: (steps / 3).max(1),
        },
    ];
    let baseline = methods[0].label();

    let mut bases = Vec::new();
    for game in synthatari::env_names() {
        for learner in &methods {
            bases.push(ExperimentConfig {
                env: EnvKind::SynthAtari { game: game.into() },
                learner: learner.clone(),
                alpha: 0.001,
                lambda: 0.99,
                gamma_override: None,
                eps: 0.1,
                steps,
                seed: 0,
                curve_points: 30,
            });
        }
    }

    let aggs = common::sweep_and_aggregate(bases, &seeds);

    let mut rows = Vec::new();
    for learner in &methods {
        let rel = relative_errors(&aggs, &learner.label(), &baseline);
        let avg: f64 = rel.iter().map(|(_, r)| r).sum::<f64>() / rel.len() as f64;
        rows.push(vec![learner.label(), format!("{avg:.3}")]);
    }
    println!(
        "Figure 9 — average relative error (best T-BPTT = 1.0), {steps} steps:"
    );
    println!(
        "{}",
        render_table(&["method", "avg error rel. to T-BPTT"], &rows)
    );
    println!(
        "expected shape (paper): ccn < constructive < columnar < 1.0 (tbptt);\n\
         ccn under ~0.5."
    );
}
