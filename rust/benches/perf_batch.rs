//! §SoA batch-membership bench: stepping throughput and evict/rehydrate
//! latency of the capacity-padded [`ColumnarSessionBatch`] under session
//! churn.
//!
//! The layout claim under test: membership ops are O(one lane's state),
//! so a single evict (`swap_remove_lane`) or rehydrate (`push_lane`)
//! costs the same against a 256-session resident batch as against a
//! 16-session one — p50/p99 flat across batch sizes instead of scaling
//! with them — and steady-state churn no longer erodes stepping
//! throughput. Reports, per batch size: fused `step_all` steps/s with
//! churn off and with churn on (one evict+rehydrate pair per tick), and
//! the p50/p99 of the individual evict and rehydrate ops. A second
//! phase drives the stage-aligned cohorts: mixed ccn + constructive
//! sessions fused through [`StagedSessionBatch::step_all`] versus
//! scalar twins consuming the identical observation stream — the fused
//! outputs must be bit-identical, and the batched steps/s is the
//! headline staged number. Writes the record in the unified
//! `ccn.bench.v1` schema to `results/BENCH_batch.json` (override with
//! CCN_BATCH_OUT) so the perf trajectory is machine-comparable across
//! commits; the evict/rehydrate latencies embed the full
//! `obs::Histogram` JSON.
//!
//! Scale knobs (env vars):
//!   CCN_BATCH_SIZES      comma-separated batch sizes   (default 16,64,256)
//!   CCN_BATCH_TICKS      step_all passes per phase     (default 200)
//!   CCN_BATCH_CHURN_OPS  evict+rehydrate pairs timed   (default 400)
//!   CCN_BATCH_INPUTS     observation width             (default 8)
//!   CCN_BATCH_D          columns per session           (default 8)
//!   CCN_BATCH_STAGED     sessions per staged kind      (default 64, 0 = skip)
//!   CCN_BATCH_OUT        result file                   (default results/BENCH_batch.json)

mod common;

use std::time::Instant;

use ccn_rtrl::config::LearnerKind;
use ccn_rtrl::learn::TdConfig;
use ccn_rtrl::metrics::render_table;
use ccn_rtrl::obs::{Histogram, HistogramSnapshot};
use ccn_rtrl::serve::{
    ColumnarSessionBatch, Session, SessionSpec, StagedSessionBatch,
};
use ccn_rtrl::util::json::Json;
use ccn_rtrl::util::prng::Xoshiro256;

use common::env_usize;

/// Nearest-rank percentile of a histogram snapshot, in microseconds.
fn pct_us(snap: &HistogramSnapshot, p: f64) -> f64 {
    snap.percentile(p) as f64 / 1000.0
}

fn env_sizes(name: &str, default: &[usize]) -> Vec<usize> {
    std::env::var(name)
        .ok()
        .map(|v| {
            v.split(',')
                .filter_map(|s| s.trim().parse().ok())
                .collect::<Vec<usize>>()
        })
        .filter(|v| !v.is_empty())
        .unwrap_or_else(|| default.to_vec())
}

fn main() {
    let sizes = env_sizes("CCN_BATCH_SIZES", &[16, 64, 256]);
    let ticks = env_usize("CCN_BATCH_TICKS", 200);
    let churn_ops = env_usize("CCN_BATCH_CHURN_OPS", 400);
    let n = env_usize("CCN_BATCH_INPUTS", 8);
    let d = env_usize("CCN_BATCH_D", 8);
    let out_path = std::env::var("CCN_BATCH_OUT")
        .unwrap_or_else(|_| "results/BENCH_batch.json".into());
    eprintln!(
        "[perf_batch] batch sizes {sizes:?}, d={d}, n={n}, {ticks} ticks, \
         {churn_ops} evict+rehydrate pairs"
    );

    let mut rows_table: Vec<Vec<String>> = Vec::new();
    let mut rows_json: Vec<Json> = Vec::new();
    for &bsz in &sizes {
        // one real session per lane, opened through the serving surface
        let mut batch: Option<ColumnarSessionBatch> = None;
        for s in 0..bsz {
            let session = Session::open(SessionSpec {
                learner: LearnerKind::Columnar { d },
                n_inputs: n,
                td: TdConfig {
                    alpha: 0.001,
                    gamma: 0.9,
                    lambda: 0.95,
                },
                eps: 0.01,
                seed: s as u64,
            })
            .expect("open columnar session");
            let spec = session
                .columnar_batch_spec()
                .expect("columnar sessions are batchable");
            let lane = session.to_lane().expect("columnar sessions convert");
            batch
                .get_or_insert_with(|| {
                    ColumnarSessionBatch::with_capacity(spec, bsz)
                })
                .push_lane(lane)
                .expect("push lane");
        }
        let mut batch = batch.expect("at least one session");
        assert_eq!(batch.len(), bsz);

        let mut rng = Xoshiro256::seed_from_u64(0xba7c4);
        let mut obs = vec![0.0f32; bsz * n];
        let mut cs = vec![0.0f32; bsz];
        let fill = |rng: &mut Xoshiro256, obs: &mut [f32], cs: &mut [f32]| {
            for v in obs.iter_mut() {
                *v = rng.uniform(-1.0, 1.0);
            }
            for v in cs.iter_mut() {
                *v = rng.uniform(-0.5, 0.5);
            }
        };

        // ---- phase 1: fused stepping, membership stable ---------------
        let t0 = Instant::now();
        for _ in 0..ticks {
            fill(&mut rng, &mut obs, &mut cs);
            batch.step_all(&obs, &cs);
        }
        let sps_stable = (bsz * ticks) as f64 / t0.elapsed().as_secs_f64();

        // ---- phase 2: membership churn --------------------------------
        // Each op pair is one LRU eviction + one rehydration as the shard
        // layer performs them: swap-remove a random lane out of the
        // batch, then push a (the same) lane back in. Individual op
        // latencies are the acceptance metric — O(lane) means flat
        // across batch sizes.
        let evict_hist = Histogram::new();
        let rehydrate_hist = Histogram::new();
        let t0 = Instant::now();
        let mut churn_steps = 0usize;
        for op in 0..churn_ops {
            let idx = rng.int_in(0, bsz as u64 - 1) as usize;
            let t = Instant::now();
            let lane = batch.swap_remove_lane(idx).expect("evict");
            evict_hist.record_duration(t.elapsed());
            let t = Instant::now();
            batch.push_lane(lane).expect("rehydrate");
            rehydrate_hist.record_duration(t.elapsed());
            // keep the batch hot between membership ops, as serving would
            if op % 4 == 0 {
                fill(&mut rng, &mut obs, &mut cs);
                batch.step_all(&obs, &cs);
                churn_steps += bsz;
            }
        }
        let churn_elapsed = t0.elapsed().as_secs_f64();
        let sps_churn = churn_steps as f64 / churn_elapsed;
        let evict = evict_hist.snapshot();
        let rehydrate = rehydrate_hist.snapshot();

        rows_table.push(vec![
            bsz.to_string(),
            format!("{sps_stable:.0}"),
            format!("{sps_churn:.0}"),
            format!("{:.1}", pct_us(&evict, 0.50)),
            format!("{:.1}", pct_us(&evict, 0.99)),
            format!("{:.1}", pct_us(&rehydrate, 0.50)),
            format!("{:.1}", pct_us(&rehydrate, 0.99)),
        ]);
        rows_json.push(Json::obj(vec![
            ("sessions", Json::Num(bsz as f64)),
            ("steps_per_s", Json::Num(sps_stable)),
            ("steps_per_s_churn", Json::Num(sps_churn)),
            ("evict", evict.to_json()),
            ("rehydrate", rehydrate.to_json()),
        ]));
    }

    // ---- staged cohorts: mixed ccn + constructive load -----------------
    // ccn/constructive sessions cohort per (spec, stage): every member
    // shares one learning stage plus per-lane frozen-prefix state, so
    // the fused pass applies the same SoA discipline the columnar batch
    // does. The fused outputs must stay bit-identical to scalar twins
    // fed the identical observation stream (asserted on the final tick);
    // steps_per_stage is set far beyond the tick budget so the phase
    // measures steady-state stepping, not cohort hops (the shard owns
    // hops; `perf_serve`'s mixed load covers that path end to end).
    let staged_n = env_usize("CCN_BATCH_STAGED", 64);
    let mut staged_rows: Vec<Vec<String>> = Vec::new();
    let mut staged_json: Vec<(&str, Json)> = Vec::new();
    if staged_n > 0 {
        let kinds: [(&str, LearnerKind); 2] = [
            (
                "ccn",
                LearnerKind::Ccn {
                    total: d.max(2),
                    per_stage: (d / 2).max(1),
                    steps_per_stage: 1_000_000_000,
                },
            ),
            (
                "constructive",
                LearnerKind::Constructive {
                    total: d.max(2),
                    steps_per_stage: 1_000_000_000,
                },
            ),
        ];
        for (tag, learner) in kinds {
            let open = |s: u64| {
                Session::open(SessionSpec {
                    learner: learner.clone(),
                    n_inputs: n,
                    td: TdConfig {
                        alpha: 0.001,
                        gamma: 0.9,
                        lambda: 0.95,
                    },
                    eps: 0.01,
                    seed: 0x57a9ed + s,
                })
                .expect("open staged session")
            };
            let members: Vec<Session> =
                (0..staged_n as u64).map(&open).collect();
            let spec = members[0]
                .staged_batch_spec()
                .expect("growing sessions are stage-batchable");
            let lanes: Vec<_> = members
                .iter()
                .map(|m| m.to_staged_lane().expect("to staged lane"))
                .collect();
            let mut batch = StagedSessionBatch::from_lanes(spec, &lanes)
                .expect("staged cohort");
            let mut twins: Vec<Session> =
                (0..staged_n as u64).map(&open).collect();

            let mut obs = vec![0.0f32; staged_n * n];
            let mut cs = vec![0.0f32; staged_n];
            let fill = |rng: &mut Xoshiro256, obs: &mut [f32], cs: &mut [f32]| {
                for v in obs.iter_mut() {
                    *v = rng.uniform(-1.0, 1.0);
                }
                for v in cs.iter_mut() {
                    *v = rng.uniform(-0.5, 0.5);
                }
            };

            let mut rng = Xoshiro256::seed_from_u64(0x57a9ed);
            let mut fused_final = Vec::new();
            let t0 = Instant::now();
            for _ in 0..ticks {
                fill(&mut rng, &mut obs, &mut cs);
                fused_final = batch.step_all(&obs, &cs).to_vec();
            }
            let batched_sps =
                (staged_n * ticks) as f64 / t0.elapsed().as_secs_f64();

            // identical stream for the scalar twins
            let mut rng = Xoshiro256::seed_from_u64(0x57a9ed);
            let mut scalar_final = vec![0.0f32; staged_n];
            let t0 = Instant::now();
            for _ in 0..ticks {
                fill(&mut rng, &mut obs, &mut cs);
                for (b, twin) in twins.iter_mut().enumerate() {
                    scalar_final[b] = twin
                        .step(&obs[b * n..(b + 1) * n], cs[b])
                        .expect("scalar twin step");
                }
            }
            let scalar_sps =
                (staged_n * ticks) as f64 / t0.elapsed().as_secs_f64();
            assert_eq!(
                fused_final, scalar_final,
                "{tag}: staged cohort diverged from its scalar twins"
            );

            staged_rows.push(vec![
                tag.into(),
                staged_n.to_string(),
                format!("{batched_sps:.0}"),
                format!("{scalar_sps:.0}"),
                format!("{:.1}x", batched_sps / scalar_sps),
            ]);
            staged_json.push((
                tag,
                Json::obj(vec![
                    ("sessions", Json::Num(staged_n as f64)),
                    ("steps_per_s", Json::Num(batched_sps)),
                    ("steps_per_s_scalar", Json::Num(scalar_sps)),
                    ("speedup", Json::Num(batched_sps / scalar_sps)),
                ]),
            ));
        }
    }

    println!(
        "{}",
        render_table(
            &[
                "batch",
                "steps/s",
                "steps/s (churn)",
                "evict p50 us",
                "evict p99 us",
                "rehydrate p50 us",
                "rehydrate p99 us",
            ],
            &rows_table,
        )
    );
    if !staged_rows.is_empty() {
        println!(
            "\nstaged cohorts ({ticks} fused ticks vs scalar twins, \
             bit-exact):\n{}",
            render_table(
                &["kind", "sessions", "batched steps/s", "scalar steps/s", "speedup"],
                &staged_rows,
            )
        );
    }

    common::write_bench_json(
        &out_path,
        "perf_batch",
        vec![
            ("inputs", Json::Num(n as f64)),
            ("d", Json::Num(d as f64)),
            ("ticks", Json::Num(ticks as f64)),
            ("churn_ops", Json::Num(churn_ops as f64)),
            ("rows", Json::Arr(rows_json)),
            ("staged_sessions", Json::Num(staged_n as f64)),
            ("staged", Json::obj(staged_json)),
        ],
    );
}
