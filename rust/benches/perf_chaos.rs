//! §Fault-tolerance bench: failover latency and acked-step survival
//! under repeated SIGKILL of real `ccn serve` children.
//!
//! Boots three child backends (disjoint id residue classes, per-backend
//! stores, optionally armed with a seeded [`FaultPlan`] via
//! `CCN_FAULTS`) behind an in-process replicating router
//! (`replicate_every = 1`). A client soaks step traffic while the bench
//! runs `CCN_CHAOS_CYCLES` kill/restart cycles: each cycle SIGKILLs the
//! backend currently hosting a probe session, times kill → next acked
//! step on that session (detection + promotion + retry, end to end)
//! into a histogram, then restarts the child on the same socket + store
//! and waits for it to rejoin the ring.
//!
//! Every acked step is mirrored onto a fault-free in-process twin and
//! compared bit-for-bit; a divergence or a session that stops answering
//! counts as an acknowledged step lost. The record lands in
//! `results/BENCH_chaos.json` (`ccn.bench.v1` schema): overall steps/s,
//! the failover-latency histogram (p50/p99), and
//! `acknowledged_steps_lost`, which is asserted to be **zero** — the
//! replication contract, not a soft metric.
//!
//! Scale knobs (env vars):
//!   CCN_CHAOS_CYCLES    kill/restart cycles          (default 3)
//!   CCN_CHAOS_TICKS     soak ticks per cycle         (default 40)
//!   CCN_CHAOS_SESSIONS  concurrent sessions          (default 3)
//!   CCN_CHAOS_INPUTS    observation width            (default 8)
//!   CCN_CHAOS_FAULTS    FaultPlan spec for children  (default: benign
//!                       read-drop/delay mix, seed 7; "" disarms)
//!   CCN_CHAOS_OUT       result file (default results/BENCH_chaos.json)

mod common;

use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};
use std::time::{Duration, Instant};

use ccn_rtrl::cluster::{ClientConfig, RouterConfig, RouterServer, WireClient};
use ccn_rtrl::metrics::render_table;
use ccn_rtrl::obs::Histogram;
use ccn_rtrl::serve::{ListenAddr, Server, Service};
use ccn_rtrl::util::fault::FaultPlan;
use ccn_rtrl::util::json::Json;
use ccn_rtrl::util::prng::Xoshiro256;

use common::env_usize;

/// Benign-by-construction default: read drops abort the op before it
/// runs, delays run it once late — so failed attempts are safely
/// retried and the twin stays in lockstep (see tests/cluster_chaos.rs).
const DEFAULT_FAULTS: &str =
    "seed:7;transport.read:drop:0.02;store.append:delay:0.3:2;\
     transport.write:delay:0.2:1";

fn fast_cfg() -> ClientConfig {
    ClientConfig {
        connect_timeout: Duration::from_millis(250),
        retries: 1,
        backoff: Duration::from_millis(10),
        ..ClientConfig::default()
    }
}

fn spawn_serve(
    sock: &Path,
    store: &Path,
    offset: u64,
    stride: u64,
    faults: &str,
) -> Child {
    let mut cmd = Command::new(env!("CARGO_BIN_EXE_ccn"));
    cmd.args([
        "serve".to_string(),
        "--listen".to_string(),
        format!("unix://{}", sock.display()),
        "--store-dir".to_string(),
        store.display().to_string(),
        "--shards".to_string(),
        "1".to_string(),
        "--id-offset".to_string(),
        offset.to_string(),
        "--id-stride".to_string(),
        stride.to_string(),
    ]);
    if !faults.is_empty() {
        cmd.env("CCN_FAULTS", faults);
    }
    cmd.stdin(Stdio::piped())
        .stdout(Stdio::null())
        .stderr(Stdio::null())
        .spawn()
        .expect("spawn ccn serve")
}

fn wait_ready(addr: &str) {
    let deadline = Instant::now() + Duration::from_secs(30);
    loop {
        if let Ok(mut c) = WireClient::dial(addr, fast_cfg()) {
            if c.ping().is_ok() {
                return;
            }
        }
        assert!(
            Instant::now() < deadline,
            "backend {addr} never answered ping"
        );
        std::thread::sleep(Duration::from_millis(50));
    }
}

fn wait_alive(client: &mut WireClient, idx: usize, want: bool) {
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        let h = client.request_ok(r#"{"op":"health"}"#).expect("health");
        let backends = h.get("backends").and_then(|b| b.as_arr()).unwrap();
        if backends[idx].get("alive") == Some(&Json::Bool(want)) {
            return;
        }
        assert!(
            Instant::now() < deadline,
            "backend {idx} never reached alive={want}"
        );
        std::thread::sleep(Duration::from_millis(50));
    }
}

/// Step through the router, retrying until acked (faults are benign,
/// failover promotes). Returns `(y, attempts)`.
fn step_acked(
    client: &mut WireClient,
    id: u64,
    x: &[f32],
    c: f32,
) -> (f64, u64) {
    let line = format!(
        r#"{{"op":"step","id":{id},"x":{},"c":{c}}}"#,
        Json::arr_f32(x).dump()
    );
    let deadline = Instant::now() + Duration::from_secs(20);
    let mut attempts = 0u64;
    loop {
        attempts += 1;
        if let Ok(reply) = client.request_line(&line) {
            if let Ok(v) = Json::parse(&reply) {
                if v.get("ok") == Some(&Json::Bool(true)) {
                    let y = v
                        .get("y")
                        .and_then(|y| y.as_f64())
                        .expect("acked step carries y");
                    return (y, attempts);
                }
            }
        }
        assert!(
            Instant::now() < deadline,
            "session {id}: step never acked (failover wedged?)"
        );
        std::thread::sleep(Duration::from_millis(20));
    }
}

fn main() {
    let cycles = env_usize("CCN_CHAOS_CYCLES", 3);
    let ticks = env_usize("CCN_CHAOS_TICKS", 40);
    let sessions = env_usize("CCN_CHAOS_SESSIONS", 3);
    let n = env_usize("CCN_CHAOS_INPUTS", 8);
    let faults = std::env::var("CCN_CHAOS_FAULTS")
        .unwrap_or_else(|_| DEFAULT_FAULTS.into());
    let out_path = std::env::var("CCN_CHAOS_OUT")
        .unwrap_or_else(|_| "results/BENCH_chaos.json".into());

    let fault_digest = if faults.is_empty() {
        None
    } else {
        let plan = FaultPlan::parse(&faults).expect("CCN_CHAOS_FAULTS spec");
        Some(plan.schedule_digest())
    };
    eprintln!(
        "[perf_chaos] {cycles} kill cycles x {ticks} ticks, {sessions} \
         sessions, faults: {}",
        match fault_digest {
            Some(d) => format!("armed (digest {d:016x})"),
            None => "disarmed".into(),
        }
    );

    // -- the fleet: 3 chaos-armed children + a replicating router -----
    let nanos = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .unwrap()
        .subsec_nanos();
    let base = std::env::temp_dir()
        .join(format!("ccn-perfchaos-{}-{nanos}", std::process::id()));
    std::fs::create_dir_all(&base).unwrap();
    let socks: Vec<PathBuf> =
        (0..3).map(|k| base.join(format!("b{k}.sock"))).collect();
    let stores: Vec<PathBuf> =
        (0..3).map(|k| base.join(format!("store{k}"))).collect();
    let addrs: Vec<String> = socks
        .iter()
        .map(|s| format!("unix://{}", s.display()))
        .collect();
    let mut children: Vec<Child> = (0..3)
        .map(|k| spawn_serve(&socks[k], &stores[k], k as u64, 3, &faults))
        .collect();
    for a in &addrs {
        wait_ready(a);
    }
    let mut cfg = RouterConfig::new(
        addrs.iter().map(|a| ListenAddr::parse(a).unwrap()).collect(),
    );
    cfg.client = fast_cfg();
    cfg.health_interval = Duration::from_millis(100);
    cfg.replicate_every = 1;
    let router = RouterServer::bind(
        cfg,
        &ListenAddr::parse("tcp://127.0.0.1:0").unwrap(),
    )
    .expect("bind router");
    let mut client =
        WireClient::dial(router.local_addr(), fast_cfg()).unwrap();

    // fault-free twin replaying exactly the acked inputs
    let twin_srv = Server::bind(
        Service::new(1),
        &ListenAddr::parse("tcp://127.0.0.1:0").unwrap(),
        0,
    )
    .unwrap();
    let mut twin =
        WireClient::dial(twin_srv.local_addr(), fast_cfg()).unwrap();

    let ids: Vec<u64> = (0..sessions)
        .map(|j| client.open("columnar:8", n, j as u64).expect("open"))
        .collect();
    let twin_ids: Vec<u64> = (0..sessions)
        .map(|j| twin.open("columnar:8", n, j as u64).expect("twin open"))
        .collect();

    let mut rng = Xoshiro256::seed_from_u64(0xdead);
    let failover = Histogram::new();
    let mut acked_steps = 0u64;
    let mut lost = 0u64;
    let mut retried = 0u64;
    let t0 = Instant::now();
    for cycle in 0..cycles {
        // soak this cycle's traffic, twin in lockstep
        for _ in 0..ticks {
            for (j, (&id, &tid)) in ids.iter().zip(&twin_ids).enumerate() {
                let x: Vec<f32> =
                    (0..n).map(|_| rng.uniform(-1.0, 1.0)).collect();
                let c = rng.uniform(-0.5, 0.5);
                let (y, attempts) = step_acked(&mut client, id, &x, c);
                retried += attempts - 1;
                let w = twin.step(tid, &x, c).expect("twin step");
                if y.to_bits() != w.to_bits() {
                    eprintln!(
                        "[perf_chaos] LOST: cycle {cycle} session {j} \
                         diverged from the acked-prefix twin"
                    );
                    lost += 1;
                }
                acked_steps += 1;
            }
        }

        // ships can fail under injected faults without failing the
        // acked op; the next acked op re-ships the full snapshot. Drain
        // the lag so the kill measures promotion, not the documented
        // failed-ship staleness window.
        let mut settle = 0;
        loop {
            let lag = client
                .request_ok(r#"{"op":"stats"}"#)
                .expect("stats")
                .get("cluster")
                .and_then(|c| c.get("repl_lag"))
                .and_then(|n| n.as_f64())
                .expect("cluster repl_lag");
            if lag == 0.0 {
                break;
            }
            assert!(settle < 50, "replication lag never drained");
            settle += 1;
            for (j, (&id, &tid)) in ids.iter().zip(&twin_ids).enumerate() {
                let x: Vec<f32> =
                    (0..n).map(|_| rng.uniform(-1.0, 1.0)).collect();
                let c = rng.uniform(-0.5, 0.5);
                let (y, attempts) = step_acked(&mut client, id, &x, c);
                retried += attempts - 1;
                let w = twin.step(tid, &x, c).expect("twin step");
                if y.to_bits() != w.to_bits() {
                    eprintln!(
                        "[perf_chaos] LOST: settle step of cycle {cycle} \
                         session {j}"
                    );
                    lost += 1;
                }
                acked_steps += 1;
            }
        }

        // kill the backend hosting the probe session; the next acked
        // step on it times the whole failover path
        let probe = ids[cycle % sessions];
        let victim = router
            .router()
            .placement_of(probe)
            .expect("probe session is placed");
        children[victim].kill().expect("kill victim");
        children[victim].wait().expect("reap victim");
        let x: Vec<f32> = (0..n).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let c = rng.uniform(-0.5, 0.5);
        let tk = Instant::now();
        let (y, attempts) = step_acked(&mut client, probe, &x, c);
        failover.record_duration(tk.elapsed());
        retried += attempts - 1;
        let tid = twin_ids[cycle % sessions];
        let w = twin.step(tid, &x, c).expect("twin step");
        if y.to_bits() != w.to_bits() {
            eprintln!("[perf_chaos] LOST: failover step of cycle {cycle}");
            lost += 1;
        }
        acked_steps += 1;

        // step every session once while the victim is still a corpse:
        // any session pinned to it promotes NOW (on the forward error),
        // not after the restart hands the pin a fresh, empty backend
        for (j, (&id, &tid)) in ids.iter().zip(&twin_ids).enumerate() {
            let x: Vec<f32> = (0..n).map(|_| rng.uniform(-1.0, 1.0)).collect();
            let c = rng.uniform(-0.5, 0.5);
            let (y, attempts) = step_acked(&mut client, id, &x, c);
            retried += attempts - 1;
            let w = twin.step(tid, &x, c).expect("twin step");
            if y.to_bits() != w.to_bits() {
                eprintln!(
                    "[perf_chaos] LOST: dead-window step of cycle {cycle} \
                     session {j}"
                );
                lost += 1;
            }
            acked_steps += 1;
        }

        // restart on the same socket + store (stale-lock takeover) and
        // wait for the probe loop to let it rejoin the ring
        children[victim] =
            spawn_serve(&socks[victim], &stores[victim], victim as u64, 3, &faults);
        wait_ready(&addrs[victim]);
        wait_alive(&mut client, victim, true);
    }
    let elapsed = t0.elapsed().as_secs_f64();
    let steps_per_s = acked_steps as f64 / elapsed;

    // the contract, not a metric: every acked step survived every kill
    assert_eq!(
        lost, 0,
        "{lost} acknowledged step(s) lost across {cycles} kill cycles"
    );

    let snap = failover.snapshot();
    println!(
        "{}",
        render_table(
            &["cycles", "acked steps", "retries", "steps/s", "failover p50 ms", "p99 ms"],
            &[vec![
                cycles.to_string(),
                acked_steps.to_string(),
                retried.to_string(),
                format!("{steps_per_s:.0}"),
                format!("{:.1}", snap.percentile(0.50) as f64 / 1e6),
                format!("{:.1}", snap.percentile(0.99) as f64 / 1e6),
            ]]
        )
    );
    println!("acknowledged steps lost: {lost} (contract: 0)");

    let mut fields = vec![
        ("cycles", Json::Num(cycles as f64)),
        ("ticks_per_cycle", Json::Num(ticks as f64)),
        ("sessions", Json::Num(sessions as f64)),
        ("inputs", Json::Num(n as f64)),
        ("replicate_every", Json::Num(1.0)),
        ("acked_steps", Json::Num(acked_steps as f64)),
        ("retries", Json::Num(retried as f64)),
        ("acknowledged_steps_lost", Json::Num(lost as f64)),
        ("elapsed_s", Json::Num(elapsed)),
        ("steps_per_s", Json::Num(steps_per_s)),
        ("failover_latency", snap.to_json()),
    ];
    if let Some(d) = fault_digest {
        fields.push(("fault_spec", Json::Str(faults.clone())));
        fields.push(("fault_digest", Json::Str(format!("{d:016x}"))));
    }
    common::write_bench_json(&out_path, "perf_chaos", fields);

    for mut child in children {
        let _ = child.kill();
        let _ = child.wait();
    }
    router.shutdown().expect("router shutdown");
    twin_srv.shutdown().expect("twin shutdown");
    let _ = std::fs::remove_dir_all(&base);
}
