//! §Cluster scale-out bench: the same client load against 1 vs 2
//! backends behind a `ccn route` router, plus the latency of live
//! session migration (`handoff`) under that load's residue.
//!
//! Each phase boots N in-process `ccn serve` listeners (disjoint
//! `--id-offset/--id-stride` residue classes), fronts them with a
//! [`RouterServer`], and drives M concurrent [`WireClient`] threads,
//! each stepping its own session cohort round-robin through real
//! sockets. The phases report aggregate steps/s; the 2-backend phase
//! then times `handoff` round trips into a histogram (p50/p99).
//!
//! The record lands in `results/BENCH_cluster.json` (`ccn.bench.v1`
//! schema): per-phase steps/s, the 2-vs-1 `speedup`, and the migration
//! latency histogram. The speedup is always *recorded*; it is only
//! *asserted* (> 1.5x) when `CCN_CLUSTER_ASSERT_SCALING=1`, so CI smoke
//! runs at tiny scale stay deterministic while perf runs enforce the
//! scale-out claim.
//!
//! Scale knobs (env vars):
//!   CCN_CLUSTER_CLIENTS     concurrent client threads   (default 4)
//!   CCN_CLUSTER_SESSIONS    sessions per client         (default 4)
//!   CCN_CLUSTER_TICKS       steps per session           (default 150)
//!   CCN_CLUSTER_SHARDS      worker shards per backend   (default 2)
//!   CCN_CLUSTER_INPUTS      observation width           (default 8)
//!   CCN_CLUSTER_MIGRATIONS  timed handoffs              (default 32)
//!   CCN_CLUSTER_OUT         result file (default results/BENCH_cluster.json)
//!   CCN_CLUSTER_ASSERT_SCALING=1  hard-assert the >1.5x speedup

mod common;

use std::sync::{Arc, Barrier};
use std::time::{Duration, Instant};

use ccn_rtrl::cluster::{ClientConfig, RouterConfig, RouterServer, WireClient};
use ccn_rtrl::metrics::render_table;
use ccn_rtrl::obs::{Histogram, HistogramSnapshot};
use ccn_rtrl::serve::{ListenAddr, Server, Service};
use ccn_rtrl::util::json::Json;
use ccn_rtrl::util::prng::Xoshiro256;

use common::env_usize;

struct PhaseResult {
    n_backends: usize,
    steps: u64,
    elapsed: f64,
    steps_per_s: f64,
    /// Merged per-step round-trip latency across every client thread.
    latency: HistogramSnapshot,
    migration: Option<Json>,
}

struct Cluster {
    backends: Vec<Server>,
    router: RouterServer,
}

fn boot(n_backends: usize, shards: usize) -> Cluster {
    let mut backends = Vec::new();
    let mut addrs = Vec::new();
    for k in 0..n_backends {
        let mut service = Service::new(shards);
        if n_backends > 1 {
            // disjoint residue classes, exactly like a real deployment
            service
                .set_id_scheme(k as u64, n_backends as u64)
                .expect("id scheme");
        }
        let server = Server::bind(
            service,
            &ListenAddr::parse("tcp://127.0.0.1:0").expect("addr"),
            0,
        )
        .expect("bind backend");
        addrs.push(ListenAddr::parse(server.local_addr()).expect("local"));
        backends.push(server);
    }
    let mut cfg = RouterConfig::new(addrs);
    cfg.health_interval = Duration::from_millis(200);
    let router = RouterServer::bind(
        cfg,
        &ListenAddr::parse("tcp://127.0.0.1:0").expect("addr"),
    )
    .expect("bind router");
    Cluster { backends, router }
}

fn run_phase(
    n_backends: usize,
    clients: usize,
    sessions: usize,
    ticks: usize,
    shards: usize,
    n: usize,
    migrations: usize,
) -> PhaseResult {
    let cluster = boot(n_backends, shards);
    let local = cluster.router.local_addr().to_string();
    eprintln!(
        "[perf_cluster] phase: {n_backends} backend(s), {clients} clients x \
         {sessions} sessions x {ticks} ticks via {local}"
    );

    let barrier = Arc::new(Barrier::new(clients + 1));
    let mut joins = Vec::new();
    for k in 0..clients {
        let local = local.clone();
        let barrier = Arc::clone(&barrier);
        joins.push(std::thread::spawn(
            move || -> (u64, Vec<u64>, HistogramSnapshot) {
                let mut client = WireClient::dial(&local, ClientConfig::default())
                    .expect("dial");
                let ids: Vec<u64> = (0..sessions)
                    .map(|j| {
                        client
                            .open("columnar:8", n, (k * sessions + j) as u64)
                            .expect("open")
                    })
                    .collect();
                let mut rng = Xoshiro256::seed_from_u64(0xc1a5 + k as u64);
                let hist = Histogram::new();
                barrier.wait(); // aligned start
                let mut steps = 0u64;
                for _ in 0..ticks {
                    for &id in &ids {
                        let x: Vec<f32> =
                            (0..n).map(|_| rng.uniform(-1.0, 1.0)).collect();
                        let c = rng.uniform(-0.5, 0.5);
                        let t = Instant::now();
                        client.step(id, &x, c).expect("step");
                        hist.record_duration(t.elapsed());
                        steps += 1;
                    }
                }
                barrier.wait(); // aligned stop
                (steps, ids, hist.snapshot())
            },
        ));
    }

    barrier.wait();
    let t0 = Instant::now();
    barrier.wait();
    let elapsed = t0.elapsed().as_secs_f64();

    let mut total_steps = 0u64;
    let mut all_ids = Vec::new();
    let mut latency = HistogramSnapshot::default();
    for join in joins {
        let (steps, ids, snap) = join.join().expect("client thread");
        total_steps += steps;
        all_ids.extend(ids);
        latency = latency.merge(&snap);
    }
    let steps_per_s = total_steps as f64 / elapsed;

    // every wire step must be accounted for by exactly one backend
    let served: u64 = cluster
        .backends
        .iter()
        .flat_map(|b| b.service().pool().stats())
        .map(|s| s.steps)
        .sum();
    assert_eq!(
        served, total_steps,
        "cluster must account every wire step exactly once"
    );

    // migration latency: time handoffs of live sessions (multi-backend
    // phases only — a handoff needs somewhere to go)
    let migration = if n_backends > 1 && migrations > 0 {
        let mut admin =
            WireClient::dial(&local, ClientConfig::default()).expect("dial");
        let hist = Histogram::new();
        let mut moved = 0usize;
        for (i, &id) in all_ids.iter().cycle().take(migrations).enumerate() {
            let line = format!(r#"{{"op":"handoff","id":{id}}}"#);
            let t = Instant::now();
            let v = admin.request_ok(&line).unwrap_or_else(|e| {
                panic!("handoff {i} of session {id} failed: {e}")
            });
            hist.record_duration(t.elapsed());
            moved += 1;
            assert!(v.get("from").is_some() && v.get("to").is_some());
        }
        let snap = hist.snapshot();
        eprintln!(
            "[perf_cluster] {moved} handoffs: p50 {:.1} us, p99 {:.1} us",
            snap.percentile(0.50) as f64 / 1000.0,
            snap.percentile(0.99) as f64 / 1000.0
        );
        Some(Json::obj(vec![
            ("count", Json::Num(moved as f64)),
            ("latency", snap.to_json()),
        ]))
    } else {
        None
    };

    cluster.router.shutdown().expect("router shutdown");
    for b in cluster.backends {
        b.shutdown().expect("backend shutdown");
    }
    PhaseResult {
        n_backends,
        steps: total_steps,
        elapsed,
        steps_per_s,
        latency,
        migration,
    }
}

fn main() {
    let clients = env_usize("CCN_CLUSTER_CLIENTS", 4);
    let sessions = env_usize("CCN_CLUSTER_SESSIONS", 4);
    let ticks = env_usize("CCN_CLUSTER_TICKS", 150);
    let shards = env_usize("CCN_CLUSTER_SHARDS", 2);
    let n = env_usize("CCN_CLUSTER_INPUTS", 8);
    let migrations = env_usize("CCN_CLUSTER_MIGRATIONS", 32);
    let out_path = std::env::var("CCN_CLUSTER_OUT")
        .unwrap_or_else(|_| "results/BENCH_cluster.json".into());

    let one = run_phase(1, clients, sessions, ticks, shards, n, 0);
    let two = run_phase(2, clients, sessions, ticks, shards, n, migrations);
    let speedup = two.steps_per_s / one.steps_per_s;

    let mut rows = Vec::new();
    for p in [&one, &two] {
        rows.push(vec![
            p.n_backends.to_string(),
            p.steps.to_string(),
            format!("{:.2}", p.elapsed),
            format!("{:.0}", p.steps_per_s),
            format!("{:.1}", p.latency.percentile(0.50) as f64 / 1000.0),
            format!("{:.1}", p.latency.percentile(0.99) as f64 / 1000.0),
        ]);
    }
    println!(
        "{}",
        render_table(
            &["backends", "steps", "secs", "steps/s", "p50 us", "p99 us"],
            &rows
        )
    );
    println!("scale-out: 2 backends = {speedup:.2}x one backend");

    if std::env::var("CCN_CLUSTER_ASSERT_SCALING").as_deref() == Ok("1") {
        assert!(
            speedup > 1.5,
            "2-backend throughput must beat 1.5x one backend, got {speedup:.2}x"
        );
    }

    let phase_json = |p: &PhaseResult| {
        let mut fields = vec![
            ("backends", Json::Num(p.n_backends as f64)),
            ("steps", Json::Num(p.steps as f64)),
            ("elapsed_s", Json::Num(p.elapsed)),
            ("steps_per_s", Json::Num(p.steps_per_s)),
            ("latency", p.latency.to_json()),
        ];
        if let Some(m) = &p.migration {
            fields.push(("migration", m.clone()));
        }
        Json::obj(fields)
    };
    common::write_bench_json(
        &out_path,
        "perf_cluster",
        vec![
            ("clients", Json::Num(clients as f64)),
            ("sessions_per_client", Json::Num(sessions as f64)),
            ("shards_per_backend", Json::Num(shards as f64)),
            ("ticks", Json::Num(ticks as f64)),
            ("inputs", Json::Num(n as f64)),
            ("backends_1", phase_json(&one)),
            ("backends_2", phase_json(&two)),
            ("speedup", Json::Num(speedup)),
        ],
    );
}
