//! §Perf micro-benchmarks of the real-time hot path: per-step cost of the
//! column RTRL update, the full learners at the paper's configurations,
//! and derived throughput (agent-steps/s and column-steps/s).
//!
//! The paper's C++ ran 50M trace-patterning steps in ~5 min on one CPU
//! (~167k agent-steps/s with a 5-column net). Targets (DESIGN.md §7):
//! beat that by >=10x on the trace config, and keep the 277-input Atari
//! config above 100k agent-steps/s.

#[path = "common/mod.rs"]
mod common;

use std::time::Instant;

use ccn_rtrl::config::{build_agent, ExperimentConfig, LearnerKind};
use ccn_rtrl::metrics::render_table;
use ccn_rtrl::nets::lstm_column::LstmColumn;
use ccn_rtrl::util::prng::Xoshiro256;

fn bench<F: FnMut()>(iters: u64, mut f: F) -> f64 {
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    t0.elapsed().as_secs_f64() / iters as f64
}

fn main() {
    let iters = common::steps(2_000_000);
    let mut rows = Vec::new();

    // raw column step at several input widths
    for &m in &[7usize, 23, 64, 277] {
        let mut rng = Xoshiro256::seed_from_u64(0);
        let mut col = LstmColumn::new(m, &mut rng, 0.5);
        let x: Vec<f32> = (0..m).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let scale_iters = (iters / (m as u64 / 4 + 1)).max(10_000);
        let per = bench(scale_iters, || col.step_with_traces(&x));
        rows.push(vec![
            format!("column m={m} (traces)"),
            format!("{:.1} ns", per * 1e9),
            format!("{:.1}M/s", 1e-6 / per),
        ]);
        let per_fwd = bench(scale_iters, || col.step_forward_only(&x));
        rows.push(vec![
            format!("column m={m} (frozen)"),
            format!("{:.1} ns", per_fwd * 1e9),
            format!("{:.1}M/s", 1e-6 / per_fwd),
        ]);
    }

    // full agents at paper configs
    let configs: Vec<(String, LearnerKind, usize)> = vec![
        ("trace columnar d=5".into(), LearnerKind::Columnar { d: 5 }, 7),
        (
            "trace ccn 20/4".into(),
            LearnerKind::Ccn {
                total: 20,
                per_stage: 4,
                steps_per_stage: u64::MAX / 2,
            },
            7,
        ),
        ("trace tbptt 2:30".into(), LearnerKind::Tbptt { d: 2, k: 30 }, 7),
        ("atari columnar d=7".into(), LearnerKind::Columnar { d: 7 }, 277),
        (
            "atari ccn 15/5".into(),
            LearnerKind::Ccn {
                total: 15,
                per_stage: 5,
                steps_per_stage: u64::MAX / 2,
            },
            277,
        ),
        ("atari tbptt 8:5".into(), LearnerKind::Tbptt { d: 8, k: 5 }, 277),
    ];
    for (name, learner, n) in configs {
        let cfg = ExperimentConfig {
            learner,
            ..Default::default()
        };
        let mut agent = build_agent(&cfg, n, 0.9);
        let mut rng = Xoshiro256::seed_from_u64(1);
        let xs: Vec<Vec<f32>> = (0..64)
            .map(|_| (0..n).map(|_| rng.uniform(0.0, 1.0)).collect())
            .collect();
        let mut i = 0usize;
        let agent_iters = (iters / (n as u64 / 4 + 1)).max(10_000);
        let per = bench(agent_iters, || {
            agent.step(&xs[i % 64], 0.1);
            i += 1;
        });
        rows.push(vec![
            name,
            format!("{:.0} ns", per * 1e9),
            format!("{:.2}M/s", 1e-6 / per),
        ]);
    }

    println!("§Perf hot-path micro-benchmarks:");
    println!("{}", render_table(&["path", "per step", "throughput"], &rows));
    println!(
        "reference: paper's C++ = ~0.17M agent-steps/s on the trace config \
         (50M steps / ~5 min)"
    );
}
