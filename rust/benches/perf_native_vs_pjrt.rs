//! A2: native-Rust vs PJRT-dispatched column steps — the reproduction of
//! the paper's appendix claim that a specialized single-stream
//! implementation (their C++) is ~50x faster than a general framework
//! (their PyTorch) for small recurrent networks trained one sample at a
//! time. Our native Rust path plays C++; the XLA/PJRT path plays the
//! framework. The *crossover* matters too: as the column block grows,
//! the framework's fixed dispatch cost amortizes.
//!
//! Skips gracefully when artifacts/ is absent.

#[path = "common/mod.rs"]
mod common;

use std::path::PathBuf;
use std::time::Instant;

use ccn_rtrl::metrics::render_table;
use ccn_rtrl::nets::lstm_column::LstmColumn;
use ccn_rtrl::runtime::{PjrtColumnarStage, PjrtRuntime};
use ccn_rtrl::util::prng::Xoshiro256;

fn artifacts_dir() -> Option<PathBuf> {
    for cand in ["artifacts", "../artifacts"] {
        let p = PathBuf::from(cand);
        if p.join("manifest.json").exists() {
            return Some(p);
        }
    }
    None
}

fn main() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("perf_native_vs_pjrt: artifacts/ not built — skipping");
        return;
    };
    let rt = PjrtRuntime::load(&dir).expect("pjrt");
    // shapes lowered by the default manifest: paper trace columnar (5,7),
    // atari columnar (7,277), quickstart (8,16)
    let shapes = [(5usize, 7usize), (8, 16), (7, 277)];
    let pjrt_iters = common::steps(300) as usize;
    let mut rows = Vec::new();
    for (c, m) in shapes {
        let mut stage = PjrtColumnarStage::new(&rt, c, m, 0).expect("stage");
        let mut rng = Xoshiro256::seed_from_u64(0);
        let mut cols: Vec<LstmColumn> =
            (0..c).map(|_| LstmColumn::new(m, &mut rng, 0.5)).collect();
        stage.set_params_from_columns(&cols);
        let x: Vec<f32> = (0..m).map(|_| rng.uniform(-1.0, 1.0)).collect();

        stage.step(&x).unwrap(); // compile + warm
        let t0 = Instant::now();
        for _ in 0..pjrt_iters {
            stage.step(&x).unwrap();
        }
        let pjrt_per = t0.elapsed().as_secs_f64() / pjrt_iters as f64;

        let native_iters = 200_000usize / (m / 4 + 1) + 1000;
        let t1 = Instant::now();
        for _ in 0..native_iters {
            for col in cols.iter_mut() {
                col.step_with_traces(&x);
            }
        }
        let native_per = t1.elapsed().as_secs_f64() / native_iters as f64;

        rows.push(vec![
            format!("c={c} m={m}"),
            format!("{:.1} us", pjrt_per * 1e6),
            format!("{:.2} us", native_per * 1e6),
            format!("{:.0}x", pjrt_per / native_per),
        ]);
    }
    println!("A2 — per-step column-stage cost, PJRT vs native Rust:");
    println!(
        "{}",
        render_table(&["shape", "pjrt", "native", "native speedup"], &rows)
    );
    println!(
        "paper appendix: specialized C++ ~50x faster than PyTorch for small\n\
         single-stream nets; dispatch overhead dominates at small shapes and\n\
         amortizes as m grows — same shape here."
    );
}
