//! §Serve throughput bench: K concurrent columnar TD(lambda) sessions
//! stepped through M shards with the SoA batched kernel, versus the same
//! K sessions stepped sequentially through the scalar path.
//!
//! Reports aggregate session-steps/sec for both paths, the speedup, the
//! p50/p99 latency of single `step` requests through a shard's mpsc
//! round-trip, and the batched-vs-scalar numerical parity on the final
//! tick (which must be <= 1e-6; the two paths are arithmetically
//! identical).
//!
//! Scale knobs (env vars):
//!   CCN_SERVE_SESSIONS  concurrent sessions  (default 256)
//!   CCN_SERVE_SHARDS    worker shards        (default 8)
//!   CCN_SERVE_TICKS     steps per session    (default 500)
//!   CCN_SERVE_COLUMNS   columns per session  (default 8)
//!   CCN_SERVE_INPUTS    observation width    (default 8)

use std::time::Instant;

use ccn_rtrl::config::LearnerKind;
use ccn_rtrl::learn::TdConfig;
use ccn_rtrl::metrics::{percentile, render_table};
use ccn_rtrl::serve::protocol::{Request, StepItem};
use ccn_rtrl::serve::shard::ShardPool;
use ccn_rtrl::serve::{Session, SessionSpec};
use ccn_rtrl::util::prng::Xoshiro256;

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

fn spec(d: usize, n_inputs: usize, seed: u64) -> SessionSpec {
    SessionSpec {
        learner: LearnerKind::Columnar { d },
        n_inputs,
        td: TdConfig {
            alpha: 0.001,
            gamma: 0.9,
            lambda: 0.95,
        },
        eps: 0.01,
        seed,
    }
}

fn main() {
    let sessions = env_usize("CCN_SERVE_SESSIONS", 256);
    let shards = env_usize("CCN_SERVE_SHARDS", 8);
    let ticks = env_usize("CCN_SERVE_TICKS", 500);
    let d = env_usize("CCN_SERVE_COLUMNS", 8);
    let n = env_usize("CCN_SERVE_INPUTS", 8);
    eprintln!(
        "[perf_serve] {sessions} sessions x {ticks} ticks, columnar:{d} \
         over {n} inputs, {shards} shards"
    );

    // deterministic per-session observation streams, shared by both paths
    let mut obs_rngs: Vec<Xoshiro256> = (0..sessions)
        .map(|s| Xoshiro256::seed_from_u64(1000 + s as u64))
        .collect();
    let draw_tick = |rngs: &mut Vec<Xoshiro256>| -> (Vec<Vec<f32>>, Vec<f32>) {
        let xs: Vec<Vec<f32>> = rngs
            .iter_mut()
            .map(|r| (0..n).map(|_| r.uniform(-1.0, 1.0)).collect())
            .collect();
        let cs: Vec<f32> = xs.iter().map(|x| 0.5 * x[0]).collect();
        (xs, cs)
    };

    // ---- baseline: sequential scalar sessions --------------------------
    let mut scalar: Vec<Session> = (0..sessions)
        .map(|s| Session::open(spec(d, n, s as u64)).expect("open"))
        .collect();
    let mut scalar_final = vec![0.0f32; sessions];
    let t0 = Instant::now();
    for _ in 0..ticks {
        let (xs, cs) = draw_tick(&mut obs_rngs);
        for (s, session) in scalar.iter_mut().enumerate() {
            scalar_final[s] = session.step(&xs[s], cs[s]).expect("step");
        }
    }
    let scalar_elapsed = t0.elapsed().as_secs_f64();
    let scalar_sps = (sessions * ticks) as f64 / scalar_elapsed;

    // ---- sharded + batched path ---------------------------------------
    let pool = ShardPool::new(shards);
    let mut ids = Vec::with_capacity(sessions);
    for s in 0..sessions {
        match pool.open(spec(d, n, s as u64)) {
            ccn_rtrl::serve::protocol::Response::Opened { id } => ids.push(id),
            other => panic!("open failed: {other:?}"),
        }
    }
    // reset the observation streams so both paths see identical data
    let mut obs_rngs: Vec<Xoshiro256> = (0..sessions)
        .map(|s| Xoshiro256::seed_from_u64(1000 + s as u64))
        .collect();
    let mut served_final = vec![0.0f32; sessions];
    let t1 = Instant::now();
    for _ in 0..ticks {
        let (xs, cs) = draw_tick(&mut obs_rngs);
        let items: Vec<StepItem> = ids
            .iter()
            .zip(xs)
            .zip(&cs)
            .map(|((&id, x), &c)| StepItem { id, x, c })
            .collect();
        let ys = pool.step_batch(items);
        for (s, y) in ys.into_iter().enumerate() {
            served_final[s] = y.expect("batched step");
        }
    }
    let served_elapsed = t1.elapsed().as_secs_f64();
    let served_sps = (sessions * ticks) as f64 / served_elapsed;

    // parity: both paths consumed identical observations, so the final
    // predictions must agree to <= 1e-6 (they are arithmetically equal).
    let max_dev = scalar_final
        .iter()
        .zip(&served_final)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(
        max_dev <= 1e-6,
        "batched/scalar parity violated: max |dy| = {max_dev}"
    );

    // ---- single-request latency through the mpsc round-trip -----------
    let lat_probes = 2000.min(ticks * sessions).max(100);
    let mut rng = Xoshiro256::seed_from_u64(0xfeed);
    let mut lat_us: Vec<f64> = Vec::with_capacity(lat_probes);
    for i in 0..lat_probes {
        let id = ids[i % ids.len()];
        let x: Vec<f32> = (0..n).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let t = Instant::now();
        let resp = pool.call(Request::Step { id, x, c: 0.0 });
        lat_us.push(t.elapsed().as_secs_f64() * 1e6);
        if let ccn_rtrl::serve::protocol::Response::Error { message } = resp {
            panic!("latency probe failed: {message}");
        }
    }
    let p50 = percentile(&mut lat_us, 0.50);
    let p99 = percentile(&mut lat_us, 0.99);

    println!(
        "{}",
        render_table(
            &["path", "sessions", "shards", "steps/s", "speedup"],
            &[
                vec![
                    "scalar sequential".into(),
                    sessions.to_string(),
                    "1".into(),
                    format!("{scalar_sps:.0}"),
                    "1.0x".into(),
                ],
                vec![
                    "sharded SoA batch".into(),
                    sessions.to_string(),
                    shards.to_string(),
                    format!("{served_sps:.0}"),
                    format!("{:.1}x", served_sps / scalar_sps),
                ],
            ],
        )
    );
    println!(
        "single-step latency through mpsc: p50 {p50:.1} us, p99 {p99:.1} us \
         ({lat_probes} probes)"
    );
    println!("batched/scalar parity on final tick: max |dy| = {max_dev:.2e}");
    let stats = pool.stats();
    let total: u64 = stats.iter().map(|&(_, t)| t).sum();
    println!(
        "shard step counts: {:?} (total {total})",
        stats.iter().map(|&(_, t)| t).collect::<Vec<_>>()
    );
}
