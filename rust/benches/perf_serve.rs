//! §Serve throughput bench: K concurrent columnar TD(lambda) sessions
//! stepped through M shards with the SoA batched kernel, versus the same
//! K sessions stepped sequentially through the scalar path — plus a
//! mixed-kind load (ccn + tbptt + snap1 cohorts resident on one pool)
//! now that every net family serves through the registry surface.
//!
//! Reports aggregate session-steps/sec for both columnar paths, the
//! speedup, per-kind steps/s and p50/p99 single-`step` latency through a
//! shard's mpsc round-trip, and the batched-vs-scalar numerical parity
//! on the final tick (which must be <= 1e-6; the two paths are
//! arithmetically identical). Writes the whole record in the unified
//! `ccn.bench.v1` schema to `results/BENCH_serve.json` (override with
//! CCN_SERVE_OUT) so the perf trajectory is machine-comparable across
//! commits; per-kind latency embeds the full `obs::Histogram` JSON.
//!
//! Scale knobs (env vars):
//!   CCN_SERVE_SESSIONS  concurrent columnar sessions   (default 256)
//!   CCN_SERVE_SHARDS    worker shards                  (default 8)
//!   CCN_SERVE_TICKS     steps per session              (default 500)
//!   CCN_SERVE_COLUMNS   columns per session            (default 8)
//!   CCN_SERVE_INPUTS    observation width              (default 8)
//!   CCN_SERVE_MIXED     sessions per mixed kind        (default 16)
//!   CCN_SERVE_OUT       result file                    (default results/BENCH_serve.json)

mod common;

use std::time::Instant;

use ccn_rtrl::config::LearnerKind;
use ccn_rtrl::learn::TdConfig;
use ccn_rtrl::metrics::render_table;
use ccn_rtrl::obs::{Histogram, HistogramSnapshot};
use ccn_rtrl::serve::protocol::{Request, Response, StepItem};
use ccn_rtrl::serve::shard::ShardPool;
use ccn_rtrl::serve::{Session, SessionSpec};
use ccn_rtrl::util::json::Json;
use ccn_rtrl::util::prng::Xoshiro256;

use common::env_usize;

/// Nearest-rank percentile of a histogram snapshot, in microseconds.
fn pct_us(snap: &HistogramSnapshot, p: f64) -> f64 {
    snap.percentile(p) as f64 / 1000.0
}

fn spec(learner: LearnerKind, n_inputs: usize, seed: u64) -> SessionSpec {
    SessionSpec {
        learner,
        n_inputs,
        td: TdConfig {
            alpha: 0.001,
            gamma: 0.9,
            lambda: 0.95,
        },
        eps: 0.01,
        seed,
    }
}

/// Open `count` sessions of one kind on the pool; returns their ids.
fn open_cohort(
    pool: &ShardPool,
    learner: &LearnerKind,
    count: usize,
    n_inputs: usize,
    seed_base: u64,
) -> Vec<u64> {
    (0..count)
        .map(|s| {
            match pool.open(spec(learner.clone(), n_inputs, seed_base + s as u64)) {
                Response::Opened { id } => id,
                other => panic!("open {} failed: {other:?}", learner.label()),
            }
        })
        .collect()
}

/// Drive one cohort for `ticks` batched steps; returns steps/s.
fn drive_cohort(pool: &ShardPool, ids: &[u64], n: usize, ticks: usize) -> f64 {
    let mut rng = Xoshiro256::seed_from_u64(0xc0_4057);
    let t0 = Instant::now();
    for _ in 0..ticks {
        let items: Vec<StepItem> = ids
            .iter()
            .map(|&id| StepItem {
                id,
                x: (0..n).map(|_| rng.uniform(-1.0, 1.0)).collect(),
                c: rng.uniform(-0.5, 0.5),
            })
            .collect();
        for y in pool.step_batch(items) {
            y.expect("cohort step");
        }
    }
    (ids.len() * ticks) as f64 / t0.elapsed().as_secs_f64()
}

/// Latency histogram of single-`step` requests against `ids`.
fn probe_latency(
    pool: &ShardPool,
    ids: &[u64],
    n: usize,
    probes: usize,
) -> HistogramSnapshot {
    let mut rng = Xoshiro256::seed_from_u64(0xfeed);
    let hist = Histogram::new();
    for i in 0..probes {
        let id = ids[i % ids.len()];
        let x: Vec<f32> = (0..n).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let t = Instant::now();
        let resp = pool.call(Request::Step { id, x, c: 0.0 });
        hist.record_duration(t.elapsed());
        if let Response::Error { message, .. } = resp {
            panic!("latency probe failed: {message}");
        }
    }
    hist.snapshot()
}

fn main() {
    let sessions = env_usize("CCN_SERVE_SESSIONS", 256);
    let shards = env_usize("CCN_SERVE_SHARDS", 8);
    let ticks = env_usize("CCN_SERVE_TICKS", 500);
    let d = env_usize("CCN_SERVE_COLUMNS", 8);
    let n = env_usize("CCN_SERVE_INPUTS", 8);
    let mixed = env_usize("CCN_SERVE_MIXED", 16);
    let out_path = std::env::var("CCN_SERVE_OUT")
        .unwrap_or_else(|_| "results/BENCH_serve.json".into());
    eprintln!(
        "[perf_serve] {sessions} sessions x {ticks} ticks, columnar:{d} \
         over {n} inputs, {shards} shards; mixed load {mixed}/kind"
    );

    // deterministic per-session observation streams, shared by both paths
    let mut obs_rngs: Vec<Xoshiro256> = (0..sessions)
        .map(|s| Xoshiro256::seed_from_u64(1000 + s as u64))
        .collect();
    let draw_tick = |rngs: &mut Vec<Xoshiro256>| -> (Vec<Vec<f32>>, Vec<f32>) {
        let xs: Vec<Vec<f32>> = rngs
            .iter_mut()
            .map(|r| (0..n).map(|_| r.uniform(-1.0, 1.0)).collect())
            .collect();
        let cs: Vec<f32> = xs.iter().map(|x| 0.5 * x[0]).collect();
        (xs, cs)
    };

    // ---- baseline: sequential scalar columnar sessions -----------------
    let mut scalar: Vec<Session> = (0..sessions)
        .map(|s| {
            Session::open(spec(LearnerKind::Columnar { d }, n, s as u64))
                .expect("open")
        })
        .collect();
    let mut scalar_final = vec![0.0f32; sessions];
    let t0 = Instant::now();
    for _ in 0..ticks {
        let (xs, cs) = draw_tick(&mut obs_rngs);
        for (s, session) in scalar.iter_mut().enumerate() {
            scalar_final[s] = session.step(&xs[s], cs[s]).expect("step");
        }
    }
    let scalar_elapsed = t0.elapsed().as_secs_f64();
    let scalar_sps = (sessions * ticks) as f64 / scalar_elapsed;

    // ---- sharded + batched columnar path -------------------------------
    let pool = ShardPool::new(shards);
    let ids = open_cohort(&pool, &LearnerKind::Columnar { d }, sessions, n, 0);
    // reset the observation streams so both paths see identical data
    let mut obs_rngs: Vec<Xoshiro256> = (0..sessions)
        .map(|s| Xoshiro256::seed_from_u64(1000 + s as u64))
        .collect();
    let mut served_final = vec![0.0f32; sessions];
    let t1 = Instant::now();
    for _ in 0..ticks {
        let (xs, cs) = draw_tick(&mut obs_rngs);
        let items: Vec<StepItem> = ids
            .iter()
            .zip(xs)
            .zip(&cs)
            .map(|((&id, x), &c)| StepItem { id, x, c })
            .collect();
        let ys = pool.step_batch(items);
        for (s, y) in ys.into_iter().enumerate() {
            served_final[s] = y.expect("batched step");
        }
    }
    let served_elapsed = t1.elapsed().as_secs_f64();
    let served_sps = (sessions * ticks) as f64 / served_elapsed;

    // parity: both paths consumed identical observations, so the final
    // predictions must agree to <= 1e-6 (they are arithmetically equal).
    let max_dev = scalar_final
        .iter()
        .zip(&served_final)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(
        max_dev <= 1e-6,
        "batched/scalar parity violated: max |dy| = {max_dev}"
    );

    // ---- mixed-kind load: ccn + tbptt + snap1 on the same pool ---------
    // every kind opens through the same registry surface; cohorts stay
    // resident together so the pool genuinely hosts a mixed population.
    let mixed_ticks = (ticks / 4).max(20);
    let mixed_kinds: Vec<(&str, LearnerKind)> = vec![
        (
            "ccn",
            LearnerKind::Ccn {
                total: d.max(2),
                per_stage: (d / 2).max(1),
                steps_per_stage: 100_000,
            },
        ),
        ("tbptt", LearnerKind::Tbptt { d, k: 10 }),
        ("snap1", LearnerKind::Snap1 { d }),
    ];
    let cohorts: Vec<(&str, Vec<u64>)> = mixed_kinds
        .iter()
        .enumerate()
        .map(|(i, (tag, learner))| {
            let ids =
                open_cohort(&pool, learner, mixed, n, 10_000 + 100 * i as u64);
            (*tag, ids)
        })
        .collect();
    let lat_probes = 500;
    let mut kind_rows: Vec<Vec<String>> = Vec::new();
    let mut kind_json: std::collections::BTreeMap<String, Json> =
        std::collections::BTreeMap::new();
    // the columnar cohort from the batched phase doubles as the
    // "columnar" entry of the mixed population.
    let mut all: Vec<(&str, &[u64], f64)> = Vec::new();
    let columnar_mixed_sps = drive_cohort(&pool, &ids, n, mixed_ticks);
    all.push(("columnar", ids.as_slice(), columnar_mixed_sps));
    for (tag, cohort_ids) in &cohorts {
        let sps = drive_cohort(&pool, cohort_ids, n, mixed_ticks);
        all.push((*tag, cohort_ids.as_slice(), sps));
    }
    for &(tag, cohort_ids, sps) in &all {
        if cohort_ids.is_empty() {
            // CCN_SERVE_MIXED=0 / CCN_SERVE_SESSIONS=0 disable a cohort
            continue;
        }
        let snap = probe_latency(&pool, cohort_ids, n, lat_probes);
        kind_rows.push(vec![
            tag.into(),
            cohort_ids.len().to_string(),
            format!("{sps:.0}"),
            format!("{:.1}", pct_us(&snap, 0.50)),
            format!("{:.1}", pct_us(&snap, 0.99)),
        ]);
        kind_json.insert(
            tag.to_string(),
            Json::obj(vec![
                ("sessions", Json::Num(cohort_ids.len() as f64)),
                ("steps_per_s", Json::Num(sps)),
                ("latency", snap.to_json()),
            ]),
        );
    }

    println!(
        "{}",
        render_table(
            &["path", "sessions", "shards", "steps/s", "speedup"],
            &[
                vec![
                    "scalar sequential".into(),
                    sessions.to_string(),
                    "1".into(),
                    format!("{scalar_sps:.0}"),
                    "1.0x".into(),
                ],
                vec![
                    "sharded SoA batch".into(),
                    sessions.to_string(),
                    shards.to_string(),
                    format!("{served_sps:.0}"),
                    format!("{:.1}x", served_sps / scalar_sps),
                ],
            ],
        )
    );
    println!("batched/scalar parity on final tick: max |dy| = {max_dev:.2e}");
    println!(
        "\nmixed-kind load ({mixed_ticks} ticks/kind, latency over \
         {lat_probes} probes):\n{}",
        render_table(
            &["kind", "sessions", "steps/s", "p50 us", "p99 us"],
            &kind_rows
        )
    );
    let stats = pool.stats();
    let total: u64 = stats.iter().map(|s| s.steps).sum();
    let kind_counts = ccn_rtrl::serve::protocol::ShardStats::merge_kinds(&stats);
    println!(
        "shard step counts: {:?} (total {total}); resident kinds: {kind_counts:?}",
        stats.iter().map(|s| s.steps).collect::<Vec<_>>()
    );

    common::write_bench_json(
        &out_path,
        "perf_serve",
        vec![
            ("sessions", Json::Num(sessions as f64)),
            ("shards", Json::Num(shards as f64)),
            ("ticks", Json::Num(ticks as f64)),
            ("columns", Json::Num(d as f64)),
            ("inputs", Json::Num(n as f64)),
            ("columnar_scalar_steps_per_s", Json::Num(scalar_sps)),
            ("columnar_batched_steps_per_s", Json::Num(served_sps)),
            ("batched_speedup", Json::Num(served_sps / scalar_sps)),
            ("parity_max_dev", Json::Num(max_dev as f64)),
            ("mixed_ticks", Json::Num(mixed_ticks as f64)),
            ("kinds", Json::Obj(kind_json)),
        ],
    );
}
