//! §Durable-tier churn bench: 4x more mixed-kind sessions than resident
//! capacity, stepped round-robin so (nearly) every step evicts one
//! session to disk and rehydrates another.
//!
//! Reports aggregate churn steps/s, explicit rehydration latency
//! (p50/p99 over timed `warm` ops against freshly parked sessions),
//! evictions/s and the final store stats, and writes the record in the
//! unified `ccn.bench.v1` schema to `results/BENCH_store.json` (override
//! with CCN_STORE_OUT) so the perf trajectory is machine-comparable
//! across commits; park/rehydrate latencies embed the full
//! `obs::Histogram` JSON.
//!
//! Scale knobs (env vars):
//!   CCN_STORE_SESSIONS  total sessions                (default 256)
//!   CCN_STORE_CAP       resident sessions per shard   (default sessions / (4 * shards))
//!   CCN_STORE_SHARDS    worker shards                 (default 4)
//!   CCN_STORE_TICKS     round-robin passes            (default 30)
//!   CCN_STORE_INPUTS    observation width             (default 8)
//!   CCN_STORE_PROBES    park+warm latency probes      (default 200)
//!   CCN_STORE_DIR       store directory               (default: fresh tempdir, removed after)
//!   CCN_STORE_OUT       result file                   (default results/BENCH_store.json)

mod common;

use std::time::Instant;

use ccn_rtrl::metrics::render_table;
use ccn_rtrl::obs::{Histogram, HistogramSnapshot};
use ccn_rtrl::serve::protocol::{Request, Response};
use ccn_rtrl::serve::shard::ShardPool;
use ccn_rtrl::store::StoreConfig;
use ccn_rtrl::util::json::Json;
use ccn_rtrl::util::prng::Xoshiro256;

use common::env_usize;

/// Nearest-rank percentile of a histogram snapshot, in microseconds.
fn pct_us(snap: &HistogramSnapshot, p: f64) -> f64 {
    snap.percentile(p) as f64 / 1000.0
}

fn main() {
    let sessions = env_usize("CCN_STORE_SESSIONS", 256);
    let shards = env_usize("CCN_STORE_SHARDS", 4);
    let cap = env_usize("CCN_STORE_CAP", (sessions / (4 * shards)).max(1));
    let ticks = env_usize("CCN_STORE_TICKS", 30);
    let n = env_usize("CCN_STORE_INPUTS", 8);
    let probes = env_usize("CCN_STORE_PROBES", 200);
    let out_path = std::env::var("CCN_STORE_OUT")
        .unwrap_or_else(|_| "results/BENCH_store.json".into());
    let (dir, ephemeral) = match std::env::var("CCN_STORE_DIR") {
        Ok(d) => (std::path::PathBuf::from(d), false),
        Err(_) => {
            let nanos = std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .unwrap()
                .as_nanos();
            (
                std::env::temp_dir().join(format!(
                    "ccn-bench-store-{}-{nanos}",
                    std::process::id()
                )),
                true,
            )
        }
    };
    eprintln!(
        "[perf_store] {sessions} mixed-kind sessions, resident cap \
         {cap}/shard x {shards} shards ({}x oversubscribed), {ticks} \
         round-robin ticks; store at {}",
        sessions as f64 / (cap * shards) as f64,
        dir.display()
    );

    let pool = ShardPool::with_store(shards, Some(StoreConfig::new(&dir, cap)))
        .expect("mount store");
    let kinds = ["columnar:8", "ccn:8:2:100000", "tbptt:4:10", "snap1:4"];
    let mut ids = Vec::with_capacity(sessions);
    for s in 0..sessions {
        let spec = ccn_rtrl::serve::SessionSpec {
            learner: ccn_rtrl::config::LearnerKind::parse(kinds[s % kinds.len()])
                .unwrap(),
            n_inputs: n,
            td: ccn_rtrl::learn::TdConfig {
                alpha: 0.001,
                gamma: 0.9,
                lambda: 0.95,
            },
            eps: 0.01,
            seed: s as u64,
        };
        match pool.open(spec) {
            Response::Opened { id } => ids.push(id),
            other => panic!("open failed: {other:?}"),
        }
    }

    // ---- churn: round-robin single steps, constant evict/rehydrate ----
    let mut rng = Xoshiro256::seed_from_u64(0x5704e);
    let t0 = Instant::now();
    for _ in 0..ticks {
        for &id in &ids {
            let x: Vec<f32> = (0..n).map(|_| rng.uniform(-1.0, 1.0)).collect();
            let c = rng.uniform(-0.5, 0.5);
            match pool.call(Request::Step { id, x, c }) {
                Response::Stepped { y } => assert!(y.is_finite()),
                other => panic!("churn step failed: {other:?}"),
            }
        }
    }
    let churn_elapsed = t0.elapsed().as_secs_f64();
    let churn_sps = (sessions * ticks) as f64 / churn_elapsed;

    // ---- park/rehydrate latency probes --------------------------------
    // Each probe first warms the session and dirties it with one step,
    // so the timed park is a real snapshot + synced append (an
    // already-parked or clean session would make `park` an idempotent
    // no-op and poison the recorded latency), and the timed warm is a
    // real load + registry-routed restore.
    let park_hist = Histogram::new();
    let warm_hist = Histogram::new();
    for i in 0..probes {
        let id = ids[i % ids.len()];
        match pool.call(Request::Warm { id }) {
            Response::Warmed { .. } => {}
            other => panic!("probe pre-warm failed: {other:?}"),
        }
        let x: Vec<f32> = (0..n).map(|_| rng.uniform(-1.0, 1.0)).collect();
        match pool.call(Request::Step { id, x, c: 0.0 }) {
            Response::Stepped { .. } => {}
            other => panic!("probe dirtying step failed: {other:?}"),
        }
        let t = Instant::now();
        match pool.call(Request::Park { id }) {
            Response::Parked { .. } => {}
            other => panic!("park probe failed: {other:?}"),
        }
        park_hist.record_duration(t.elapsed());
        let t = Instant::now();
        match pool.call(Request::Warm { id }) {
            Response::Warmed { rehydrated, .. } => {
                assert!(rehydrated, "probe target must have been parked")
            }
            other => panic!("warm probe failed: {other:?}"),
        }
        warm_hist.record_duration(t.elapsed());
    }
    let park = park_hist.snapshot();
    let warm = warm_hist.snapshot();
    let warm_p50 = pct_us(&warm, 0.50);
    let warm_p99 = pct_us(&warm, 0.99);
    let park_p50 = pct_us(&park, 0.50);
    let park_p99 = pct_us(&park, 0.99);

    let stats = pool.stats();
    let evictions: u64 = stats.iter().map(|s| s.evictions).sum();
    let rehydrations: u64 = stats.iter().map(|s| s.rehydrations).sum();
    let store_bytes: u64 = stats.iter().map(|s| s.store_bytes).sum();
    let parked: usize = stats.iter().map(|s| s.parked).sum();
    let evictions_per_s = evictions as f64 / churn_elapsed;

    println!(
        "{}",
        render_table(
            &["metric", "value"],
            &[
                vec!["churn steps/s".into(), format!("{churn_sps:.0}")],
                vec!["evictions".into(), evictions.to_string()],
                vec!["evictions/s (churn phase)".into(), format!("{evictions_per_s:.0}")],
                vec!["rehydrations".into(), rehydrations.to_string()],
                vec!["rehydrate p50".into(), format!("{warm_p50:.1} us")],
                vec!["rehydrate p99".into(), format!("{warm_p99:.1} us")],
                vec!["park p50".into(), format!("{park_p50:.1} us")],
                vec!["park p99".into(), format!("{park_p99:.1} us")],
                vec!["parked sessions".into(), parked.to_string()],
                vec!["store bytes".into(), store_bytes.to_string()],
            ],
        )
    );

    common::write_bench_json(
        &out_path,
        "perf_store",
        vec![
            ("sessions", Json::Num(sessions as f64)),
            ("shards", Json::Num(shards as f64)),
            ("resident_cap", Json::Num(cap as f64)),
            ("ticks", Json::Num(ticks as f64)),
            ("inputs", Json::Num(n as f64)),
            ("churn_steps_per_s", Json::Num(churn_sps)),
            ("evictions", Json::Num(evictions as f64)),
            ("evictions_per_s", Json::Num(evictions_per_s)),
            ("rehydrations", Json::Num(rehydrations as f64)),
            ("park", park.to_json()),
            ("rehydrate", warm.to_json()),
            ("store_bytes", Json::Num(store_bytes as f64)),
        ],
    );
    if ephemeral {
        drop(pool);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
