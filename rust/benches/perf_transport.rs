//! §Transport throughput bench: M concurrent TCP clients drive a live
//! listener, each owning a cohort of mixed-kind sessions stepped
//! round-robin through real sockets — the full ingress path (socket ->
//! reader thread -> shard mpsc -> SoA/scalar step -> writer thread ->
//! socket).
//!
//! Reports aggregate steps/s over the wire, per-net-kind steps/s and
//! p50/p99 single-step round-trip latency, and the refusal/connection
//! counters, and writes the record in the unified `ccn.bench.v1` schema
//! to `results/BENCH_transport.json` (override with CCN_TRANSPORT_OUT)
//! so the perf trajectory is machine-comparable across commits. Each
//! client thread records round-trips into its own `obs::Histogram`;
//! the main thread merges the per-client snapshots per kind and embeds
//! the merged histogram JSON.
//!
//! Scale knobs (env vars):
//!   CCN_TRANSPORT_CLIENTS   concurrent client threads  (default 8)
//!   CCN_TRANSPORT_SESSIONS  sessions per client        (default 4)
//!   CCN_TRANSPORT_TICKS     steps per session          (default 200)
//!   CCN_TRANSPORT_SHARDS    worker shards              (default 4)
//!   CCN_TRANSPORT_INPUTS    observation width          (default 8)
//!   CCN_TRANSPORT_OUT      result file (default results/BENCH_transport.json)

mod common;

use std::sync::{Arc, Barrier};
use std::time::Instant;

use ccn_rtrl::cluster::{ClientConfig, WireClient};
use ccn_rtrl::metrics::render_table;
use ccn_rtrl::obs::{Histogram, HistogramSnapshot};
use ccn_rtrl::serve::{ListenAddr, Server, Service};
use ccn_rtrl::util::json::Json;
use ccn_rtrl::util::prng::Xoshiro256;

use common::env_usize;

const KINDS: [&str; 4] = ["columnar:8", "ccn:8:2:100000", "tbptt:4:10", "snap1:4"];

/// Nearest-rank percentile of a histogram snapshot, in microseconds.
fn pct_us(snap: &HistogramSnapshot, p: f64) -> f64 {
    snap.percentile(p) as f64 / 1000.0
}

/// Per-kind latency histograms one client collected.
type KindSamples = Vec<(&'static str, HistogramSnapshot)>;

fn main() {
    let clients = env_usize("CCN_TRANSPORT_CLIENTS", 8);
    let sessions = env_usize("CCN_TRANSPORT_SESSIONS", 4);
    let ticks = env_usize("CCN_TRANSPORT_TICKS", 200);
    let shards = env_usize("CCN_TRANSPORT_SHARDS", 4);
    let n = env_usize("CCN_TRANSPORT_INPUTS", 8);
    let out_path = std::env::var("CCN_TRANSPORT_OUT")
        .unwrap_or_else(|_| "results/BENCH_transport.json".into());

    let server = Server::bind(
        Service::new(shards),
        &ListenAddr::parse("tcp://127.0.0.1:0").expect("addr"),
        0,
    )
    .expect("bind");
    let local = server.local_addr().to_string();
    eprintln!(
        "[perf_transport] {clients} clients x {sessions} sessions x {ticks} \
         ticks over {local} ({shards} shards)"
    );

    let barrier = Arc::new(Barrier::new(clients + 1));
    let mut joins = Vec::new();
    for k in 0..clients {
        let local = local.clone();
        let barrier = Arc::clone(&barrier);
        joins.push(std::thread::spawn(move || -> (u64, KindSamples) {
            let mut client =
                WireClient::dial(&local, ClientConfig::default()).expect("dial");
            let specs: Vec<&'static str> = (0..sessions)
                .map(|j| KINDS[(k * sessions + j) % KINDS.len()])
                .collect();
            let ids: Vec<u64> = specs
                .iter()
                .enumerate()
                .map(|(j, spec)| {
                    let line = format!(
                        r#"{{"op":"open","learner":"{spec}","n_inputs":{n},"seed":{}}}"#,
                        k * sessions + j
                    );
                    let v = client.request_ok(&line).expect("open");
                    v.get("id").unwrap().as_f64().unwrap() as u64
                })
                .collect();
            let mut rng = Xoshiro256::seed_from_u64(0xbe9c + k as u64);
            let hists: Vec<(&'static str, Histogram)> =
                KINDS.iter().map(|kind| (*kind, Histogram::new())).collect();
            barrier.wait(); // aligned start: measure true concurrency
            let mut steps = 0u64;
            for _ in 0..ticks {
                for (j, &id) in ids.iter().enumerate() {
                    let x: Vec<String> = (0..n)
                        .map(|_| format!("{}", rng.uniform(-1.0, 1.0)))
                        .collect();
                    let c = rng.uniform(-0.5, 0.5);
                    let line = format!(
                        r#"{{"op":"step","id":{id},"x":[{}],"c":{c}}}"#,
                        x.join(",")
                    );
                    let t = Instant::now();
                    client.request_ok(&line).expect("step");
                    steps += 1;
                    let kind_idx = (k * sessions + j) % KINDS.len();
                    hists[kind_idx].1.record_duration(t.elapsed());
                }
            }
            barrier.wait(); // aligned stop
            let samples: KindSamples = hists
                .iter()
                .map(|(kind, h)| (*kind, h.snapshot()))
                .collect();
            (steps, samples)
        }));
    }

    barrier.wait();
    let t0 = Instant::now();
    barrier.wait();
    let elapsed = t0.elapsed().as_secs_f64();

    let mut total_steps = 0u64;
    let mut by_kind: Vec<(&'static str, HistogramSnapshot)> = KINDS
        .iter()
        .map(|kind| (*kind, HistogramSnapshot::default()))
        .collect();
    for join in joins {
        let (steps, samples) = join.join().expect("client thread");
        total_steps += steps;
        for (slot, (_, snap)) in by_kind.iter_mut().zip(samples) {
            slot.1 = slot.1.merge(&snap);
        }
    }
    let steps_per_s = total_steps as f64 / elapsed;

    let stats = server.service().pool().stats();
    let served: u64 = stats.iter().map(|s| s.steps).sum();
    assert_eq!(served, total_steps, "server must account every wire step");
    server.shutdown().expect("shutdown");

    let mut rows = Vec::new();
    let mut kind_json = std::collections::BTreeMap::new();
    for (kind, snap) in by_kind {
        let count = snap.count();
        if count == 0 {
            continue;
        }
        let kind_sps = count as f64 / elapsed;
        rows.push(vec![
            kind.to_string(),
            count.to_string(),
            format!("{kind_sps:.0}"),
            format!("{:.1}", pct_us(&snap, 0.50)),
            format!("{:.1}", pct_us(&snap, 0.99)),
        ]);
        kind_json.insert(
            kind.to_string(),
            Json::obj(vec![
                ("steps", Json::Num(count as f64)),
                ("steps_per_s", Json::Num(kind_sps)),
                ("latency", snap.to_json()),
            ]),
        );
    }
    println!(
        "{}",
        render_table(
            &["kind", "steps", "steps/s", "p50 us", "p99 us"],
            &rows
        )
    );
    println!(
        "total: {total_steps} steps over {clients} connections in \
         {elapsed:.2}s = {steps_per_s:.0} steps/s"
    );

    common::write_bench_json(
        &out_path,
        "perf_transport",
        vec![
            ("conns", Json::Num(clients as f64)),
            ("sessions_per_conn", Json::Num(sessions as f64)),
            ("shards", Json::Num(shards as f64)),
            ("ticks", Json::Num(ticks as f64)),
            ("inputs", Json::Num(n as f64)),
            ("steps", Json::Num(total_steps as f64)),
            ("steps_per_s", Json::Num(steps_per_s)),
            ("kinds", Json::Obj(kind_json)),
        ],
    );
}
