//! A thin, reusable JSONL wire client for the serve protocol — one
//! synchronous request/reply cycle per call over a [`Stream`] (TCP or
//! UDS), with connect timeouts, bounded retry + exponential backoff,
//! and lazy reconnection.
//!
//! This is the client half the transport PR left as a follow-up; the
//! router ([`super::router`]), the benches (`perf_transport`,
//! `perf_cluster`) and the cluster e2e tests all speak through it.
//!
//! # Retry safety
//!
//! The error type is the contract: [`ClientError::Connect`] means no
//! request bytes left this process, so *any* op can be retried (here or
//! on another backend). [`ClientError::Io`] means bytes may have reached
//! the server — the serve transport executes a final unterminated line
//! at EOF, so retrying a mutating op (`step`, `open`, `close`, ...)
//! after a send could execute it twice. Only idempotent ops go through
//! [`WireClient::request_line_idempotent`]; everything else fails fast
//! and leaves the retry decision to a layer that knows the op's
//! semantics.

use std::io::{BufRead, BufReader, Write};
use std::time::{Duration, Instant};

use crate::serve::transport::Stream;
use crate::serve::ListenAddr;
use crate::util::fault::{self, FaultAction};
use crate::util::json::Json;

/// Connection policy for a [`WireClient`].
#[derive(Clone, Debug)]
pub struct ClientConfig {
    /// Bound on each TCP connect attempt (UDS connects fail fast).
    pub connect_timeout: Duration,
    /// Bound on waiting for one reply line.
    pub read_timeout: Duration,
    /// Bound on pushing one request line into the socket.
    pub write_timeout: Duration,
    /// Extra connect attempts after the first fails.
    pub retries: u32,
    /// Sleep before the first reconnect attempt; doubles per attempt.
    pub backoff: Duration,
}

impl Default for ClientConfig {
    fn default() -> ClientConfig {
        ClientConfig {
            connect_timeout: Duration::from_secs(1),
            read_timeout: Duration::from_secs(10),
            write_timeout: Duration::from_secs(10),
            retries: 2,
            backoff: Duration::from_millis(50),
        }
    }
}

/// Why a request failed — the variant is the retry contract (see the
/// module docs).
#[derive(Debug)]
pub enum ClientError {
    /// No connection could be established; nothing was sent.
    Connect(String),
    /// Read/write failure after the request may have been sent.
    Io(String),
    /// The server replied with something unusable (bad JSON) or with
    /// `ok:false` where success was required.
    Protocol(String),
}

impl ClientError {
    pub fn message(&self) -> &str {
        match self {
            ClientError::Connect(m)
            | ClientError::Io(m)
            | ClientError::Protocol(m) => m,
        }
    }

    /// True when the request is known NOT to have reached the server.
    pub fn is_connect(&self) -> bool {
        matches!(self, ClientError::Connect(_))
    }
}

impl std::fmt::Display for ClientError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ClientError::Connect(m) => write!(f, "connect: {m}"),
            ClientError::Io(m) => write!(f, "io: {m}"),
            ClientError::Protocol(m) => write!(f, "protocol: {m}"),
        }
    }
}

struct Conn {
    reader: BufReader<Stream>,
    writer: Stream,
}

/// One logical connection to a serve endpoint. Connects lazily on the
/// first request and reconnects (with the configured retry/backoff)
/// after any IO failure tears the socket down.
pub struct WireClient {
    addr: ListenAddr,
    cfg: ClientConfig,
    conn: Option<Conn>,
}

impl WireClient {
    /// No I/O happens here — the first request dials.
    pub fn new(addr: ListenAddr, cfg: ClientConfig) -> WireClient {
        WireClient { addr, cfg, conn: None }
    }

    /// Parse-and-construct convenience for `tcp://`/`unix://` strings.
    pub fn dial(addr: &str, cfg: ClientConfig) -> Result<WireClient, String> {
        Ok(WireClient::new(ListenAddr::parse(addr)?, cfg))
    }

    pub fn addr(&self) -> &ListenAddr {
        &self.addr
    }

    pub fn is_connected(&self) -> bool {
        self.conn.is_some()
    }

    /// Drop the socket; the next request re-dials.
    pub fn disconnect(&mut self) {
        if let Some(conn) = self.conn.take() {
            conn.writer.shutdown();
        }
    }

    fn ensure_conn(&mut self) -> Result<(), ClientError> {
        if self.conn.is_some() {
            return Ok(());
        }
        let mut wait = self.cfg.backoff;
        let mut last = String::from("no attempt made");
        for attempt in 0..=self.cfg.retries {
            if attempt > 0 {
                std::thread::sleep(wait);
                wait = wait.saturating_mul(2);
            }
            match Stream::connect(&self.addr, self.cfg.connect_timeout) {
                Ok(stream) => {
                    let setup = stream
                        .set_read_timeout(Some(self.cfg.read_timeout))
                        .and_then(|()| {
                            stream.set_write_timeout(Some(
                                self.cfg.write_timeout,
                            ))
                        })
                        .and_then(|()| stream.try_clone());
                    match setup {
                        Ok(writer) => {
                            self.conn = Some(Conn {
                                reader: BufReader::new(stream),
                                writer,
                            });
                            return Ok(());
                        }
                        Err(e) => last = format!("socket setup: {e}"),
                    }
                }
                Err(e) => last = e.to_string(),
            }
        }
        Err(ClientError::Connect(format!("{}: {last}", self.addr)))
    }

    /// One request/reply cycle: send `line` (no trailing newline), wait
    /// for the reply line. NEVER retries after the send — see the module
    /// docs for why; pair with [`ClientError::is_connect`] when the
    /// caller wants to fail over to another backend.
    pub fn request_line(&mut self, line: &str) -> Result<String, ClientError> {
        self.ensure_conn()?;
        // chaos hook: lose, stall, double or cut short the request
        // before/at the send — see crate::util::fault for the plan
        let mut dup = false;
        match fault::hit("client.request") {
            Some(FaultAction::Drop) => {
                self.disconnect();
                return Err(ClientError::Io(format!(
                    "{}: injected client.request drop",
                    self.addr
                )));
            }
            Some(FaultAction::Delay(ms)) => fault::sleep_ms(ms),
            Some(FaultAction::Truncate) => {
                // half a request and no newline, then hang up: the
                // server's final-line parse rejects the fragment, so
                // the op provably never executes — but this client
                // can't know that, hence Io, not Connect
                let conn = self.conn.as_mut().expect("ensured above");
                let half = &line.as_bytes()[..line.len() / 2];
                let _ = conn.writer.write_all(half);
                let _ = conn.writer.flush();
                self.disconnect();
                return Err(ClientError::Io(format!(
                    "{}: injected client.request truncate",
                    self.addr
                )));
            }
            Some(FaultAction::Dup) => dup = true,
            None => {}
        }
        let conn = self.conn.as_mut().expect("ensured above");
        let send = if dup {
            writeln!(conn.writer, "{line}")
                .and_then(|()| writeln!(conn.writer, "{line}"))
                .and_then(|()| conn.writer.flush())
        } else {
            writeln!(conn.writer, "{line}").and_then(|()| conn.writer.flush())
        };
        if let Err(e) = send {
            self.disconnect();
            return Err(ClientError::Io(format!("{}: write: {e}", self.addr)));
        }
        let read =
            read_line_deadline(&mut conn.reader, self.cfg.read_timeout);
        // a duplicated request leaves a stray reply queued on the
        // stream; kill the connection so it can never answer a later
        // request (the next cycle re-dials cleanly)
        let out = match read {
            Ok(bytes) if bytes.is_empty() => {
                self.disconnect();
                return Err(ClientError::Io(format!(
                    "{}: server closed the connection",
                    self.addr
                )));
            }
            Ok(bytes) => {
                let mut reply = String::from_utf8_lossy(&bytes).into_owned();
                while reply.ends_with('\n') || reply.ends_with('\r') {
                    reply.pop();
                }
                Ok(reply)
            }
            Err(e) => {
                self.disconnect();
                return Err(ClientError::Io(format!(
                    "{}: read: {e}",
                    self.addr
                )));
            }
        };
        if dup {
            self.disconnect();
        }
        out
    }

    /// [`WireClient::request_line`] for ops that are safe to execute
    /// twice (`ping`, `stats`, `metrics`, `snapshot`, `predict`): one
    /// full re-dial + re-send cycle after an IO failure.
    pub fn request_line_idempotent(
        &mut self,
        line: &str,
    ) -> Result<String, ClientError> {
        match self.request_line(line) {
            Err(ClientError::Io(_)) => self.request_line(line),
            other => other,
        }
    }

    /// Send and parse the reply object (any `ok` value passes through).
    pub fn request(&mut self, line: &str) -> Result<Json, ClientError> {
        let reply = self.request_line(line)?;
        Json::parse(&reply).map_err(|e| {
            ClientError::Protocol(format!(
                "{}: unparseable reply: {e}",
                self.addr
            ))
        })
    }

    /// Send, parse, and require `ok:true` — the bench/test workhorse.
    pub fn request_ok(&mut self, line: &str) -> Result<Json, ClientError> {
        let v = self.request(line)?;
        if v.get("ok") == Some(&Json::Bool(true)) {
            Ok(v)
        } else {
            let msg = v
                .get("error")
                .and_then(|e| e.as_str())
                .unwrap_or("request failed without an error message");
            Err(ClientError::Protocol(format!(
                "{}: {line}: {msg}",
                self.addr
            )))
        }
    }

    /// Liveness probe (idempotent, answered inline by the server).
    pub fn ping(&mut self) -> Result<(), ClientError> {
        let reply = self.request_line_idempotent(r#"{"op":"ping"}"#)?;
        let v = Json::parse(&reply).map_err(|e| {
            ClientError::Protocol(format!(
                "{}: unparseable ping reply: {e}",
                self.addr
            ))
        })?;
        if v.get("pong") == Some(&Json::Bool(true)) {
            Ok(())
        } else {
            Err(ClientError::Protocol(format!(
                "{}: not a pong: {reply}",
                self.addr
            )))
        }
    }

    /// Open a session: `{"op":"open","learner":KIND,"n_inputs":N,
    /// "seed":S}` → the minted id.
    pub fn open(
        &mut self,
        learner: &str,
        n_inputs: usize,
        seed: u64,
    ) -> Result<u64, ClientError> {
        let line = format!(
            r#"{{"op":"open","learner":"{learner}","n_inputs":{n_inputs},"seed":{seed}}}"#
        );
        let v = self.request_ok(&line)?;
        reply_id(&self.addr, &v)
    }

    /// Step one session; returns the prediction.
    pub fn step(
        &mut self,
        id: u64,
        x: &[f32],
        c: f32,
    ) -> Result<f64, ClientError> {
        let line = format!(
            r#"{{"op":"step","id":{id},"x":{},"c":{c}}}"#,
            Json::arr_f32(x).dump()
        );
        let v = self.request_ok(&line)?;
        v.get("y").and_then(|y| y.as_f64()).ok_or_else(|| {
            ClientError::Protocol(format!("{}: step reply has no y", self.addr))
        })
    }

    /// Step many sessions in one wire op; returns one `y` per item
    /// (`None` where the server reported a per-item error).
    pub fn step_batch(
        &mut self,
        items: &[(u64, Vec<f32>, f32)],
    ) -> Result<Vec<Option<f64>>, ClientError> {
        let line = Json::obj(vec![
            ("op", Json::Str("step_batch".to_string())),
            (
                "ids",
                Json::Arr(
                    items.iter().map(|(id, _, _)| Json::Num(*id as f64)).collect(),
                ),
            ),
            (
                "xs",
                Json::Arr(items.iter().map(|(_, x, _)| Json::arr_f32(x)).collect()),
            ),
            (
                "cs",
                Json::Arr(
                    items.iter().map(|(_, _, c)| Json::Num(*c as f64)).collect(),
                ),
            ),
        ])
        .dump();
        let v = self.request_ok(&line)?;
        let ys = v.get("ys").and_then(|y| y.as_arr()).ok_or_else(|| {
            ClientError::Protocol(format!(
                "{}: step_batch reply has no ys",
                self.addr
            ))
        })?;
        Ok(ys.iter().map(|y| y.as_f64()).collect())
    }

    /// Snapshot a session (idempotent): the versioned state envelope.
    pub fn snapshot(&mut self, id: u64) -> Result<Json, ClientError> {
        let line = format!(r#"{{"op":"snapshot","id":{id}}}"#);
        let reply = self.request_line_idempotent(&line)?;
        let v = Json::parse(&reply).map_err(|e| {
            ClientError::Protocol(format!(
                "{}: unparseable snapshot reply: {e}",
                self.addr
            ))
        })?;
        if v.get("ok") != Some(&Json::Bool(true)) {
            let msg = v
                .get("error")
                .and_then(|e| e.as_str())
                .unwrap_or("snapshot failed");
            return Err(ClientError::Protocol(format!(
                "{}: snapshot {id}: {msg}",
                self.addr
            )));
        }
        v.get("state").cloned().ok_or_else(|| {
            ClientError::Protocol(format!(
                "{}: snapshot reply has no state",
                self.addr
            ))
        })
    }

    /// Restore a snapshot; `id: Some(n)` restores *as* that id (the
    /// migration hook), `None` lets the server mint one. Returns the id
    /// the session lives under.
    pub fn restore(
        &mut self,
        state: &Json,
        id: Option<u64>,
    ) -> Result<u64, ClientError> {
        let line = match id {
            Some(id) => format!(
                r#"{{"op":"restore","id":{id},"state":{}}}"#,
                state.dump()
            ),
            None => format!(r#"{{"op":"restore","state":{}}}"#, state.dump()),
        };
        let v = self.request_ok(&line)?;
        reply_id(&self.addr, &v)
    }

    /// Park a session to the durable store.
    pub fn park(&mut self, id: u64) -> Result<(), ClientError> {
        let line = format!(r#"{{"op":"park","id":{id}}}"#);
        self.request_ok(&line).map(|_| ())
    }

    /// Warm a parked session back into shard memory.
    pub fn warm(&mut self, id: u64) -> Result<(), ClientError> {
        let line = format!(r#"{{"op":"warm","id":{id}}}"#);
        self.request_ok(&line).map(|_| ())
    }

    /// Close a session; returns its lifetime step count.
    pub fn close(&mut self, id: u64) -> Result<u64, ClientError> {
        let line = format!(r#"{{"op":"close","id":{id}}}"#);
        let v = self.request_ok(&line)?;
        v.get("steps")
            .and_then(|s| s.as_f64())
            .map(|s| s as u64)
            .ok_or_else(|| {
                ClientError::Protocol(format!(
                    "{}: close reply has no steps",
                    self.addr
                ))
            })
    }

    /// The server's `stats` reply (idempotent).
    pub fn stats(&mut self) -> Result<Json, ClientError> {
        let reply = self.request_line_idempotent(r#"{"op":"stats"}"#)?;
        Json::parse(&reply).map_err(|e| {
            ClientError::Protocol(format!(
                "{}: unparseable stats reply: {e}",
                self.addr
            ))
        })
    }

    /// The server's `metrics` reply (idempotent).
    pub fn metrics(&mut self) -> Result<Json, ClientError> {
        let reply = self.request_line_idempotent(r#"{"op":"metrics"}"#)?;
        Json::parse(&reply).map_err(|e| {
            ClientError::Protocol(format!(
                "{}: unparseable metrics reply: {e}",
                self.addr
            ))
        })
    }
}

/// Read one `\n`-terminated line under a *hard* deadline.
///
/// `BufReader::read_line` alone is not enough: it re-enters the
/// socket's `read` once per fragment, and a kernel read timeout is
/// per-`read` — a backend trickling one byte per timeout window would
/// stretch a "10 s" reply read indefinitely. This loop re-arms the
/// socket with the *remaining* budget before every fill, so
/// `read_timeout` bounds the whole reply end to end.
///
/// Returns the raw line bytes without the terminator; an empty vec
/// means the server closed the connection before sending anything.
fn read_line_deadline(
    reader: &mut BufReader<Stream>,
    budget: Duration,
) -> std::io::Result<Vec<u8>> {
    use std::io::{Error, ErrorKind};
    let deadline = Instant::now() + budget;
    let mut line: Vec<u8> = Vec::new();
    loop {
        let remaining = match deadline.checked_duration_since(Instant::now())
        {
            Some(d) if !d.is_zero() => d,
            _ => {
                return Err(Error::new(
                    ErrorKind::TimedOut,
                    format!(
                        "no complete reply within {} ms",
                        budget.as_millis()
                    ),
                ))
            }
        };
        reader.get_ref().set_read_timeout(Some(remaining))?;
        let (chunk_len, newline_at) = match reader.fill_buf() {
            Ok(chunk) => {
                if chunk.is_empty() {
                    // EOF: surface whatever arrived (empty = clean close)
                    return Ok(line);
                }
                let newline_at = chunk.iter().position(|&b| b == b'\n');
                let take = newline_at.unwrap_or(chunk.len());
                line.extend_from_slice(&chunk[..take]);
                (chunk.len(), newline_at)
            }
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(e)
                if matches!(
                    e.kind(),
                    ErrorKind::WouldBlock | ErrorKind::TimedOut
                ) =>
            {
                return Err(Error::new(
                    ErrorKind::TimedOut,
                    format!(
                        "no complete reply within {} ms",
                        budget.as_millis()
                    ),
                ))
            }
            Err(e) => return Err(e),
        };
        match newline_at {
            Some(pos) => {
                reader.consume(pos + 1);
                return Ok(line);
            }
            None => reader.consume(chunk_len),
        }
    }
}

fn reply_id(addr: &ListenAddr, v: &Json) -> Result<u64, ClientError> {
    v.get("id")
        .and_then(|id| id.as_f64())
        .map(|id| id as u64)
        .ok_or_else(|| {
            ClientError::Protocol(format!("{addr}: reply has no id"))
        })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::{Server, Service};

    fn tiny_cfg() -> ClientConfig {
        ClientConfig {
            connect_timeout: Duration::from_millis(250),
            retries: 0,
            backoff: Duration::from_millis(1),
            ..ClientConfig::default()
        }
    }

    #[test]
    fn full_session_lifecycle_over_tcp() {
        let server = Server::bind(
            Service::new(2),
            &ListenAddr::parse("tcp://127.0.0.1:0").unwrap(),
            0,
        )
        .unwrap();
        let mut c = WireClient::dial(server.local_addr(), tiny_cfg()).unwrap();
        c.ping().unwrap();
        let id = c.open("columnar:4", 3, 7).unwrap();
        let y1 = c.step(id, &[0.1, 0.2, -0.3], 0.5).unwrap();
        let snap = c.snapshot(id).unwrap();
        let restored = c.restore(&snap, None).unwrap();
        assert_ne!(restored, id, "fresh id when none requested");
        let pinned = c.restore(&snap, Some(4242)).unwrap();
        assert_eq!(pinned, 4242, "explicit id honored");
        // twin steps of twin states must agree bit-for-bit
        let y2 = c.step(restored, &[0.4, -0.1, 0.2], -0.25).unwrap();
        let y3 = c.step(pinned, &[0.4, -0.1, 0.2], -0.25).unwrap();
        assert_eq!(y2.to_bits(), y3.to_bits(), "{y1} twins diverged");
        let ys = c
            .step_batch(&[
                (id, vec![0.0, 0.1, 0.2], 0.0),
                (99_999, vec![0.0, 0.1, 0.2], 0.0),
            ])
            .unwrap();
        assert!(ys[0].is_some());
        assert!(ys[1].is_none(), "ghost id maps to a per-item null");
        assert_eq!(c.close(id).unwrap(), 2, "steps accounted");
        let stats = c.stats().unwrap();
        assert_eq!(stats.get("ok"), Some(&Json::Bool(true)));
        server.shutdown().unwrap();
    }

    #[test]
    fn stalled_backend_cannot_hang_a_request_past_its_deadline() {
        // a raw "backend" that accepts, then drips one byte every 50 ms
        // and never finishes a line: every fragment would re-arm a naive
        // per-read socket timeout, stretching a 400 ms deadline to 10 s
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = format!("tcp://{}", listener.local_addr().unwrap());
        let dripper = std::thread::spawn(move || {
            let (mut sock, _) = listener.accept().unwrap();
            for _ in 0..200 {
                if sock.write_all(b"x").and_then(|()| sock.flush()).is_err() {
                    return; // client hung up — done
                }
                std::thread::sleep(Duration::from_millis(50));
            }
        });
        let cfg = ClientConfig {
            connect_timeout: Duration::from_millis(250),
            read_timeout: Duration::from_millis(400),
            retries: 0,
            ..ClientConfig::default()
        };
        let mut c = WireClient::dial(&addr, cfg).unwrap();
        let t0 = Instant::now();
        let err = c.request_line(r#"{"op":"ping"}"#).unwrap_err();
        let took = t0.elapsed();
        assert!(!err.is_connect(), "request was sent: {err}");
        assert!(
            took >= Duration::from_millis(300),
            "gave up before the deadline: {took:?}"
        );
        assert!(
            took < Duration::from_secs(3),
            "read deadline not enforced end-to-end: {took:?}"
        );
        assert!(!c.is_connected(), "timed-out conn must be torn down");
        dripper.join().unwrap();
    }

    #[test]
    fn connect_failure_is_retriable_io_failure_is_not() {
        // nothing listens here (bound then dropped)
        let dead = {
            let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
            format!("tcp://{}", l.local_addr().unwrap())
        };
        let mut c = WireClient::dial(&dead, tiny_cfg()).unwrap();
        match c.request_line(r#"{"op":"ping"}"#) {
            Err(e) => assert!(e.is_connect(), "{e}"),
            Ok(r) => panic!("dead endpoint replied: {r}"),
        }

        // a live server killed mid-conversation surfaces as Io
        let server = Server::bind(
            Service::new(1),
            &ListenAddr::parse("tcp://127.0.0.1:0").unwrap(),
            0,
        )
        .unwrap();
        let mut c = WireClient::dial(server.local_addr(), tiny_cfg()).unwrap();
        c.ping().unwrap();
        server.shutdown().unwrap();
        // the socket is torn down; the next cycle must not claim Connect
        // (bytes may have been sent) ...
        match c.request_line(r#"{"op":"ping"}"#) {
            Err(e) => assert!(!e.is_connect(), "{e}"),
            // a race where the write lands before teardown finishes is
            // possible but the reply read must then fail
            Ok(r) => panic!("dead server replied: {r}"),
        }
        // ... and the idempotent wrapper may then retry the full cycle,
        // which fails as Connect now that the conn is known-dead
        match c.request_line_idempotent(r#"{"op":"ping"}"#) {
            Err(e) => assert!(e.is_connect(), "{e}"),
            Ok(r) => panic!("dead server replied: {r}"),
        }
    }
}
