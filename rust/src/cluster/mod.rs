//! The horizontal scale-out tier: `ccn route` in front of N `ccn serve`
//! backends.
//!
//! Three pieces, each useful on its own:
//!
//! - [`client`] — [`WireClient`]: a thin, reusable JSONL client for the
//!   serve protocol over TCP/UDS, with connect timeouts, bounded retry +
//!   backoff, and an error taxonomy that doubles as the retry-safety
//!   contract ([`ClientError::Connect`] = provably not sent, anything
//!   retriable; [`ClientError::Io`] = maybe executed, mutating ops must
//!   not be replayed). The benches and e2e tests speak through it too.
//! - [`ring`] — [`HashRing`]: deterministic consistent hashing of
//!   session ids over backend indices, with liveness as a lookup-time
//!   filter so death/revival never rebuilds anything.
//! - [`router`] — [`Router`] / [`RouterServer`]: the routing core and
//!   the `ccn route --listen ... --backend ...` front end. Serves the
//!   whole backend protocol transparently (byte-identical replies for
//!   single-backend ops) plus the cluster ops `health`, `handoff`,
//!   `drain`, `rebalance`, `promote`. Sessions migrate live between
//!   backends via snapshot → restore-as-same-id → close,
//!   copy-before-delete, with per-session ordering held across the move
//!   by per-id gates. With `--replicate-every K` every placed session
//!   also keeps a warm standby on its ring-successor backend (shipped
//!   after acked state-advancing ops, parked there as a replica); when
//!   a pinned home dies, routed ops promote the standby — warm the
//!   replica, re-pin, retry once — instead of failing, with an acked
//!   loss window of at most `K - 1` ops (`K = 1` → zero).
//!
//! # Deployment sketch
//!
//! ```text
//! ccn serve --listen unix:///tmp/b0.sock --store-dir /data/b0 \
//!           --id-offset 0 --id-stride 2 &
//! ccn serve --listen unix:///tmp/b1.sock --store-dir /data/b1 \
//!           --id-offset 1 --id-stride 2 &
//! ccn route --listen tcp://127.0.0.1:9000 \
//!           --backend unix:///tmp/b0.sock --backend unix:///tmp/b1.sock
//! ```
//!
//! Backends partition the id space by residue class (`--id-offset K
//! --id-stride N`) so fresh ids never collide across the fleet, and a
//! migrated id keeps its residue class valid on any backend (`restore`
//! with an explicit id fences every allocator past it). A killed backend
//! drops out of the ring on the next health probe; its parked sessions
//! survive in its store and rehydrate through the normal boot scan when
//! the process returns, at which point it rejoins the ring.
//!
//! # Fleet observability
//!
//! The router participates in the same observability stack as the
//! backends (see the `obs` module):
//!
//! - `--trace-file` / `--trace-sample` emit router-side JSONL trace
//!   events; the router injects `trace_id` / `span_id` into each
//!   forwarded op so a backend's trace events carry the same
//!   `trace_id` (and the router's span as `parent_span_id`). Join the
//!   two files with `scripts/check_trace.py --join`.
//! - `metrics {"scope": "fleet"}` fans the `metrics` op out to every
//!   live backend and merges the histogram/counter/window registries
//!   into one `merged` block, next to tagged per-backend sub-blocks.
//! - `--metrics-listen ADDR` serves Prometheus text exposition of the
//!   router's own registry on `GET /metrics`.

pub mod client;
pub mod ring;
pub mod router;

pub use client::{ClientConfig, ClientError, WireClient};
pub use ring::{HashRing, DEFAULT_VNODES};
pub use router::{Router, RouterConfig, RouterServer, ROUTE_COUNTERS, ROUTE_OPS};
