//! Consistent-hash ring over backend indices.
//!
//! Classic fixed-point construction: each backend contributes `vnodes`
//! pseudo-random points on the u64 circle; a key belongs to the first
//! clockwise point owned by a *live* backend. Properties the router
//! leans on:
//!
//! - **Stability**: adding/removing one backend re-homes only the keys
//!   in the arcs it owned (~1/N of the space), not everything — which is
//!   what keeps `rebalance` a bounded migration, not a full reshuffle.
//! - **Determinism**: the points depend only on (backend index, vnodes),
//!   so every router replica and every restart computes the same ring.
//! - **Liveness masking**: death is a *lookup-time* filter, not a ring
//!   rebuild — a dead backend's keys spill to the next live point and
//!   spring back the moment it revives.
//!
//! Hashing is the splitmix64 finalizer: zero-dep, well-mixed, and
//! already the idiom used by the store's segment checksums.

/// splitmix64 finalizer — avalanches all 64 bits of `z`.
fn mix(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Default vnodes per backend: enough that the largest/smallest backend
/// load ratio stays close to 1 for small N.
pub const DEFAULT_VNODES: usize = 64;

/// The ring: sorted `(point, backend index)` pairs.
#[derive(Debug, Clone)]
pub struct HashRing {
    points: Vec<(u64, usize)>,
    n_backends: usize,
}

impl HashRing {
    pub fn new(n_backends: usize, vnodes: usize) -> HashRing {
        let vnodes = vnodes.max(1);
        let mut points = Vec::with_capacity(n_backends * vnodes);
        for b in 0..n_backends {
            for r in 0..vnodes {
                // disjoint (backend, replica) seed per point; mixing the
                // packed pair avalanches into a unique circle position
                let point = mix(((b as u64) << 32) | r as u64);
                points.push((point, b));
            }
        }
        points.sort_unstable();
        HashRing { points, n_backends }
    }

    pub fn n_backends(&self) -> usize {
        self.n_backends
    }

    /// The live backend owning `key`: first clockwise point whose
    /// backend passes `live`, wrapping around; `None` when nothing is
    /// live. O(log points + dead-run) per lookup.
    pub fn home<F: Fn(usize) -> bool>(
        &self,
        key: u64,
        live: F,
    ) -> Option<usize> {
        if self.points.is_empty() {
            return None;
        }
        let h = mix(key);
        let start = self
            .points
            .partition_point(|&(p, _)| p < h);
        for i in 0..self.points.len() {
            let (_, b) = self.points[(start + i) % self.points.len()];
            if live(b) {
                return Some(b);
            }
        }
        None
    }

    /// The warm-standby backend for `key`: the first live backend
    /// clockwise from the key's position that is *not* `home`. This is
    /// the classic successor-replica placement — deterministic (every
    /// router instance picks the same standby), and exactly the backend
    /// `home()` would fail over to if `home` died, so a replica parked
    /// there is already where the promoted session will live. `None`
    /// when no live backend other than the home exists (replication
    /// degrades to off in a 1-backend fleet).
    pub fn successor<F: Fn(usize) -> bool>(
        &self,
        key: u64,
        home: usize,
        live: F,
    ) -> Option<usize> {
        self.home(key, |b| b != home && live(b))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::BTreeMap;

    #[test]
    fn every_key_lands_on_a_live_backend_dead_ones_never() {
        let ring = HashRing::new(4, DEFAULT_VNODES);
        for key in 0..1000u64 {
            let b = ring.home(key, |b| b != 2).unwrap();
            assert_ne!(b, 2, "dead backend got key {key}");
            assert!(b < 4);
        }
        assert_eq!(ring.home(7, |_| false), None, "no live backend");
    }

    #[test]
    fn death_moves_only_the_dead_backends_keys() {
        let ring = HashRing::new(4, DEFAULT_VNODES);
        let before: Vec<usize> = (0..2000u64)
            .map(|k| ring.home(k, |_| true).unwrap())
            .collect();
        let after: Vec<usize> = (0..2000u64)
            .map(|k| ring.home(k, |b| b != 1).unwrap())
            .collect();
        for (k, (b, a)) in before.iter().zip(&after).enumerate() {
            if *b != 1 {
                assert_eq!(b, a, "key {k} moved although its home is live");
            } else {
                assert_ne!(*a, 1, "key {k} stayed on the dead backend");
            }
        }
    }

    #[test]
    fn load_spreads_roughly_evenly() {
        let ring = HashRing::new(4, DEFAULT_VNODES);
        let mut counts: BTreeMap<usize, usize> = BTreeMap::new();
        for key in 0..4000u64 {
            *counts.entry(ring.home(key, |_| true).unwrap()).or_default() +=
                1;
        }
        assert_eq!(counts.len(), 4, "every backend owns some keys");
        for (&b, &n) in &counts {
            // perfect would be 1000; vnode placement keeps skew bounded
            assert!(
                (300..=2200).contains(&n),
                "backend {b} owns {n}/4000 keys — ring badly skewed"
            );
        }
    }

    #[test]
    fn successor_is_exactly_the_failover_home() {
        let ring = HashRing::new(4, DEFAULT_VNODES);
        for key in 0..2000u64 {
            let home = ring.home(key, |_| true).unwrap();
            let standby = ring.successor(key, home, |_| true).unwrap();
            assert_ne!(standby, home, "key {key}: standby on the home");
            // the replica lives exactly where the key spills if its
            // home dies — promotion needs no copy, just a warm
            assert_eq!(
                Some(standby),
                ring.home(key, |b| b != home),
                "key {key}: standby is not the failover target"
            );
        }
        // a 1-backend fleet has nowhere to replicate
        let solo = HashRing::new(1, DEFAULT_VNODES);
        assert_eq!(solo.successor(7, 0, |_| true), None);
    }

    #[test]
    fn ring_is_deterministic_across_instances() {
        let a = HashRing::new(3, 32);
        let b = HashRing::new(3, 32);
        for key in 0..500u64 {
            assert_eq!(a.home(key, |_| true), b.home(key, |_| true));
        }
    }
}
