//! The routing tier: one [`Router`] fronting N backend `ccn serve`
//! processes, plus [`RouterServer`] — the `ccn route` accept loop that
//! speaks the same JSONL protocol to clients.
//!
//! # Routing model
//!
//! Placement is **table-first, ring-second**: a `RwLock<HashMap<id,
//! backend>>` records where every session the router placed (or located)
//! actually lives; ids not in the table fall back to their
//! consistent-hash home ([`super::ring::HashRing`]) and, when the home
//! answers "no session", to a probe of the remaining live backends —
//! found sessions are cached back into the table. A restarted router
//! therefore recovers placements lazily instead of persisting them.
//! Fresh `open`/`restore` ops are placed by ring over a monotonic
//! placement counter, and the minted id (backends partition the id space
//! via `--id-offset/--id-stride`) is recorded.
//!
//! # Transparency
//!
//! The router forwards the client's **raw request line** and returns the
//! backend's **raw reply line** — for any op against a single backend
//! the reply is byte-identical to talking to that backend directly (the
//! bar the e2e suite pins). Locally-generated errors (bad JSON, unknown
//! op) reuse the exact serve code paths, so those bytes match too. Only
//! a `step_batch` spanning backends is split and re-merged — through
//! [`Response::SteppedMany`], the same serializer the backend uses.
//!
//! # Migration ordering
//!
//! Every id has a gate (`RwLock<()>`): routed ops hold it shared,
//! `handoff` holds it exclusively for snapshot-on-source →
//! restore-as-same-id-on-destination → close-on-source. In-flight ops
//! for the moving id queue on the gate and release against the updated
//! table only after the destination has acked the restore — per-session
//! order is preserved across the move, and the copy exists on the
//! destination *before* the source copy dies (the store tier's reshard
//! rule, applied across processes). A crash between restore and close
//! leaves a duplicate that the routing table shadows — never a loss.
//!
//! # Failure handling
//!
//! Connect failures mark a backend dead (out of the ring at lookup
//! time); ops that provably never reached a backend retry on the next
//! candidate (`route.retries`). Ops that may have been executed are
//! **never** replayed *onto the same authority* — the transport executes
//! a final unterminated line at EOF, so blind retry could double-step a
//! learner. A dead backend's parked sessions live in its store; when the
//! process restarts on the same store dir the boot scan rehydrates them,
//! the health loop sees the dead→alive transition, and the backend
//! re-enters the ring.
//!
//! # Warm-standby replication & promotion
//!
//! With `--replicate-every K` (K ≥ 1), every session the router places
//! gets a **warm standby**: after an acked state-advancing op, once `K`
//! such ops have accumulated since the last ship, the router snapshots
//! the session on its home and ships the envelope to the session's
//! [`HashRing::successor`] — exactly the backend the ring would fail
//! over to — where it is parked as a replica (`replicate` op), never
//! resident. Standby failures never fail the client's op: the ack
//! already happened; the miss only grows `route.repl_errors` and leaves
//! `route.repl_lag` (acked-but-unreplicated ops, summed over sessions)
//! elevated until the next successful ship.
//!
//! When a routed op finds its table-pinned home unreachable, the router
//! **promotes** instead of failing loudly: it re-acquires the id's gate
//! exclusively (serializing against any in-flight op still talking to
//! the old home), re-checks the table (another thread may have already
//! promoted), `warm`s the parked replica on the standby, re-pins the
//! table, and retries the op once against the new authority. Retrying
//! even a maybe-executed op is safe *here*: the replica's state only
//! ever advances through acked ships, so an op the dead home executed
//! but never acked is absent from the replica — the retry runs it once
//! on the new timeline. The cost is bounded staleness: up to `K - 1`
//! acked ops (plus any ops the standby missed while unreachable) are
//! lost on promotion; `K = 1` makes the acked-loss window zero.
//! `{"op":"promote","id":N}` forces the same path by hand.
//!
//! # Fleet observability
//!
//! Every router op is timed into `route.<op>` histograms and the
//! `route.retries`/`route.err_*`/`route.migrations` counters of the
//! router's own [`Registry`], served by its `metrics`/`stats` ops along
//! with a `cluster` block. Three fleet-scope extensions:
//!
//! - **Correlation** (`ccn route --trace-file PATH [--trace-sample N]`):
//!   every well-formed op gets a `trace_id` + hop `span_id`
//!   (client-supplied ids are reused, missing ones minted and spliced
//!   into the forwarded line as ordinary optional fields), and every
//!   sampled op appends one JSONL event — op, correlation pair, backend,
//!   `forward_ns`, `dur_ns`, ok. A backend tracing with the same flags
//!   echoes the pair into its own events, so
//!   `scripts/check_trace.py --join router.jsonl backend.jsonl` stitches
//!   the two files into end-to-end spans. Correlation never changes a
//!   reply: the backend's op parser ignores unknown keys and replies
//!   never echo them (byte-transparency is e2e-pinned with tracing on).
//! - **Fleet roll-up** (`{"op":"metrics","scope":"fleet"}`): fans
//!   `metrics` out to every live backend and folds the parsed registries
//!   through [`RegistrySnapshot::merge`] — merged totals plus each
//!   backend's own snapshot in one reply.
//! - **Exposition** (`ccn route --metrics-listen tcp://H:P`): the
//!   router's registry as Prometheus text at `GET /metrics`
//!   ([`crate::obs::MetricsServer`]).

use std::borrow::Cow;
use std::cell::Cell;
use std::collections::{BTreeMap, HashMap};
use std::io::{BufReader, BufWriter, Write};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, MutexGuard, RwLock};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::obs::{
    mint_id, Histogram, MetricsServer, Registry, RegistrySnapshot, TraceConfig,
    TraceHandle, WindowedCounter,
};
use crate::serve::protocol::{parse_wire_op, Response, StepItem, WireOp};
use crate::serve::transport::{
    read_line_bytes, LineRead, Listener, SocketLock, Stream, MAX_LINE_BYTES,
    POLL_INTERVAL, WRITE_TIMEOUT,
};
use crate::serve::ListenAddr;
use crate::util::json::Json;

use super::client::{ClientConfig, ClientError, WireClient};
use super::ring::{HashRing, DEFAULT_VNODES};

/// Router-tier op names, pre-registered as `route.<op>` histograms so
/// the router's `metrics` schema is complete from the first request.
pub const ROUTE_OPS: [&str; 18] = [
    "open",
    "step",
    "step_batch",
    "predict",
    "snapshot",
    "restore",
    "park",
    "warm",
    "close",
    "stats",
    "metrics",
    "ping",
    "health",
    "handoff",
    "drain",
    "rebalance",
    "replicate",
    "promote",
];

/// Router-tier counters. `route.repl_lag` is a gauge in counter
/// clothing: the number of acked state-advancing ops not yet shipped to
/// a standby, summed over sessions — it goes *down* on every successful
/// ship.
pub const ROUTE_COUNTERS: [&str; 8] = [
    "route.retries",
    "route.err_backend",
    "route.err_no_backend",
    "route.migrations",
    "route.replicated",
    "route.repl_errors",
    "route.repl_lag",
    "route.promotions",
];

/// Configuration for [`Router::new`] / [`RouterServer::bind`].
#[derive(Clone, Debug)]
pub struct RouterConfig {
    /// The backend `ccn serve` endpoints, in ring order.
    pub backends: Vec<ListenAddr>,
    /// Client cap for the router's own listener (0 = unlimited).
    pub max_conns: usize,
    /// Cadence of the background liveness probe.
    pub health_interval: Duration,
    /// Connect/read/write/retry policy for every backend connection.
    pub client: ClientConfig,
    /// Ring points per backend.
    pub vnodes: usize,
    /// Router-side JSONL trace log (`ccn route --trace-file` /
    /// `--trace-sample`). When set, every forwarded op also carries
    /// `trace_id`/`span_id` correlation fields.
    pub trace: Option<TraceConfig>,
    /// Prometheus text endpoint (`ccn route --metrics-listen`).
    pub metrics_listen: Option<ListenAddr>,
    /// Warm-standby replication cadence (`ccn route --replicate-every
    /// K`): ship a session's state to its ring-successor standby every
    /// `K` acked state-advancing ops. `0` disables replication (the
    /// default); `1` makes the acked-loss window on failover zero.
    pub replicate_every: u64,
}

impl RouterConfig {
    pub fn new(backends: Vec<ListenAddr>) -> RouterConfig {
        RouterConfig {
            backends,
            max_conns: 0,
            health_interval: Duration::from_millis(500),
            client: ClientConfig::default(),
            vnodes: DEFAULT_VNODES,
            trace: None,
            metrics_listen: None,
            replicate_every: 0,
        }
    }
}

fn mlock<T>(lock: &Mutex<T>) -> MutexGuard<'_, T> {
    lock.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

fn rlock<T>(lock: &RwLock<T>) -> std::sync::RwLockReadGuard<'_, T> {
    lock.read().unwrap_or_else(|poisoned| poisoned.into_inner())
}

fn wlock<T>(lock: &RwLock<T>) -> std::sync::RwLockWriteGuard<'_, T> {
    lock.write().unwrap_or_else(|poisoned| poisoned.into_inner())
}

fn error_line(msg: impl Into<String>) -> String {
    Response::error(msg).to_json().dump()
}

fn reply_is_ok(reply: &str) -> bool {
    Json::parse(reply)
        .map(|v| v.get("ok") == Some(&Json::Bool(true)))
        .unwrap_or(false)
}

/// One configured backend and its routing state.
struct Backend {
    addr: ListenAddr,
    label: String,
    /// Last contact (probe or forward) succeeded.
    alive: AtomicBool,
    /// Eligible for *new* placements; cleared by `drain`, restored by a
    /// dead→alive transition (a restarted process has re-scanned its
    /// store and owns its parked sessions again).
    in_ring: AtomicBool,
    /// The router's own connection for health probes and migrations —
    /// client traffic uses per-connection clients instead.
    admin: Mutex<WireClient>,
}

/// Why a forward failed, and whether the request provably never reached
/// the backend (→ safe to try the next candidate).
enum ForwardErr {
    /// Nothing was sent (connect failure, or an idempotent op whose
    /// retry window closed): trying another backend cannot double-run.
    NotSent(String),
    /// Bytes may have been executed: no retry anywhere.
    Broken(String),
}

impl ForwardErr {
    fn message(self) -> String {
        match self {
            ForwardErr::NotSent(m) | ForwardErr::Broken(m) => m,
        }
    }
}

/// The routing core. Shared (`Arc`) between the accept loop, every
/// connection thread, and the health thread; per-connection backend
/// sockets live in the caller-owned map passed to [`Router::handle_line`].
pub struct Router {
    backends: Vec<Backend>,
    ring: HashRing,
    client_cfg: ClientConfig,
    /// Authoritative placements: every session the router opened,
    /// restored, located, or migrated.
    table: RwLock<HashMap<u64, usize>>,
    /// Per-id migration gates (see module docs). Entries die with the
    /// session's `close`.
    gates: Mutex<HashMap<u64, Arc<RwLock<()>>>>,
    /// Monotonic counter driving ring placement of fresh opens.
    placements: AtomicU64,
    obs: Arc<Registry>,
    timers: BTreeMap<&'static str, Arc<Histogram>>,
    retries: Arc<AtomicU64>,
    err_backend: Arc<AtomicU64>,
    err_no_backend: Arc<AtomicU64>,
    migrations: Arc<AtomicU64>,
    /// Warm-standby cadence (0 = replication off). See module docs.
    replicate_every: u64,
    /// Per-id acked state-advancing ops since the last successful ship.
    /// All updates to `repl_lag` happen under this mutex so the gauge
    /// always equals the sum of the clocks.
    repl_clock: Mutex<HashMap<u64, u64>>,
    replicated: Arc<AtomicU64>,
    repl_errors: Arc<AtomicU64>,
    repl_lag: Arc<AtomicU64>,
    promotions: Arc<AtomicU64>,
    /// Router-side trace log; when set, forwarded ops carry correlation
    /// ids and sampled ops emit one JSONL event each.
    trace: Option<TraceHandle>,
    /// Origin for trace timestamps (monotonic, ns since router boot).
    epoch: Instant,
    /// Windowed ops/s gauge (the router's `metrics` windows block).
    win_ops: Arc<WindowedCounter>,
}

/// Per-request correlation context, stack-local to one
/// [`Router::handle_line`]. `trace_id`/`span_id` are the *effective* ids
/// (client-supplied when valid, freshly minted otherwise); the cells
/// collect where the request actually went for the router's own event.
struct TraceCtx {
    trace_id: String,
    span_id: String,
    /// This request is one of the 1-in-N the router's own log records.
    sampled: bool,
    /// Last backend a forward succeeded against.
    backend: Cell<Option<usize>>,
    /// Total wall time spent inside forwards (including failed probes).
    forward_ns: Cell<u64>,
}

/// Splice correlation keys into a raw request line, right after the
/// opening `{`. Only keys the client did NOT send are added: the JSON
/// parser's later-duplicate-wins rule would let a client key override a
/// spliced twin anyway, and reusing client ids keeps an upstream tracer
/// working. Every routed op has at least an `"op"` key, so the splice's
/// trailing comma is always valid.
fn inject_correlation(line: &str, add: &[(&str, &str)]) -> String {
    let Some(pos) = line.find('{') else {
        return line.to_string();
    };
    let mut out = String::with_capacity(line.len() + 32 * add.len());
    out.push_str(&line[..=pos]);
    for (key, val) in add {
        out.push('"');
        out.push_str(key);
        out.push_str("\":\"");
        out.push_str(val);
        out.push_str("\",");
    }
    out.push_str(&line[pos + 1..]);
    out
}

impl Router {
    pub fn new(cfg: RouterConfig) -> Result<Router, String> {
        if cfg.backends.is_empty() {
            return Err("route: at least one --backend is required".into());
        }
        let backends: Vec<Backend> = cfg
            .backends
            .iter()
            .map(|addr| Backend {
                addr: addr.clone(),
                label: addr.to_string(),
                alive: AtomicBool::new(true),
                in_ring: AtomicBool::new(true),
                admin: Mutex::new(WireClient::new(
                    addr.clone(),
                    cfg.client.clone(),
                )),
            })
            .collect();
        let obs = Arc::new(Registry::new());
        let mut timers = BTreeMap::new();
        for op in ROUTE_OPS {
            timers.insert(op, obs.histogram(&format!("route.{op}")));
        }
        let retries = obs.counter("route.retries");
        let err_backend = obs.counter("route.err_backend");
        let err_no_backend = obs.counter("route.err_no_backend");
        let migrations = obs.counter("route.migrations");
        let replicated = obs.counter("route.replicated");
        let repl_errors = obs.counter("route.repl_errors");
        let repl_lag = obs.counter("route.repl_lag");
        let promotions = obs.counter("route.promotions");
        let trace = match &cfg.trace {
            Some(tc) => {
                let mut t = TraceHandle::open(tc, obs.counter("trace.dropped"))?;
                t.set_drop_window(obs.window("trace.dropped"));
                Some(t)
            }
            None => None,
        };
        let win_ops = obs.window("ops");
        Ok(Router {
            ring: HashRing::new(backends.len(), cfg.vnodes),
            backends,
            client_cfg: cfg.client,
            table: RwLock::new(HashMap::new()),
            gates: Mutex::new(HashMap::new()),
            placements: AtomicU64::new(0),
            obs,
            timers,
            retries,
            err_backend,
            err_no_backend,
            migrations,
            replicate_every: cfg.replicate_every,
            repl_clock: Mutex::new(HashMap::new()),
            replicated,
            repl_errors,
            repl_lag,
            promotions,
            trace,
            epoch: Instant::now(),
            win_ops,
        })
    }

    /// The router's telemetry registry (`route.*` histograms/counters).
    pub fn registry(&self) -> &Arc<Registry> {
        &self.obs
    }

    pub fn n_backends(&self) -> usize {
        self.backends.len()
    }

    /// Known placements (diagnostics/tests).
    pub fn placement_of(&self, id: u64) -> Option<usize> {
        rlock(&self.table).get(&id).copied()
    }

    fn alive(&self, b: usize) -> bool {
        self.backends[b].alive.load(Ordering::Relaxed)
    }

    fn routable(&self, b: usize) -> bool {
        self.alive(b) && self.backends[b].in_ring.load(Ordering::Relaxed)
    }

    fn set_alive(&self, b: usize, now: bool) {
        let was = self.backends[b].alive.swap(now, Ordering::Relaxed);
        if now && !was {
            // dead→alive: the process restarted (its boot scan owns the
            // parked sessions again) — rejoin the ring
            self.backends[b].in_ring.store(true, Ordering::Relaxed);
        }
    }

    /// Resolve a backend label (`tcp://...` / `unix://...`) to its index.
    fn backend_index(&self, label: &str) -> Option<usize> {
        self.backends.iter().position(|b| b.label == label)
    }

    fn gate(&self, id: u64) -> Arc<RwLock<()>> {
        let mut gates = mlock(&self.gates);
        Arc::clone(
            gates
                .entry(id)
                .or_insert_with(|| Arc::new(RwLock::new(()))),
        )
    }

    fn forget(&self, id: u64) {
        wlock(&self.table).remove(&id);
        mlock(&self.gates).remove(&id);
        let mut clocks = mlock(&self.repl_clock);
        if let Some(n) = clocks.remove(&id) {
            self.repl_lag.fetch_sub(n, Ordering::Relaxed);
        }
    }

    /// Ring home among placeable members, spilling to merely-alive ones
    /// when everything is drained.
    fn ring_home(&self, key: u64) -> Option<usize> {
        self.ring
            .home(key, |b| self.routable(b))
            .or_else(|| self.ring.home(key, |b| self.alive(b)))
    }

    fn client<'a>(
        &self,
        conns: &'a mut HashMap<usize, WireClient>,
        b: usize,
    ) -> &'a mut WireClient {
        conns.entry(b).or_insert_with(|| {
            WireClient::new(
                self.backends[b].addr.clone(),
                self.client_cfg.clone(),
            )
        })
    }

    /// Forward one raw line to backend `b`. `idempotent` ops may be
    /// replayed on a fresh connection; mutating ops never are.
    fn forward(
        &self,
        conns: &mut HashMap<usize, WireClient>,
        b: usize,
        raw: &str,
        idempotent: bool,
    ) -> Result<String, ForwardErr> {
        let client = self.client(conns, b);
        let res = if idempotent {
            client.request_line_idempotent(raw)
        } else {
            client.request_line(raw)
        };
        match res {
            Ok(reply) => {
                self.set_alive(b, true);
                Ok(reply)
            }
            Err(e) => {
                self.err_backend.fetch_add(1, Ordering::Relaxed);
                let msg = format!(
                    "backend {} is unreachable: {e}",
                    self.backends[b].label
                );
                match e {
                    ClientError::Connect(_) => {
                        self.set_alive(b, false);
                        Err(ForwardErr::NotSent(msg))
                    }
                    // an idempotent op that still failed after the
                    // client's internal replay sent nothing *effectful*
                    ClientError::Io(_) if idempotent => {
                        Err(ForwardErr::NotSent(msg))
                    }
                    ClientError::Io(_) | ClientError::Protocol(_) => {
                        Err(ForwardErr::Broken(msg))
                    }
                }
            }
        }
    }

    /// [`Router::forward`] plus correlation bookkeeping: time spent
    /// forwarding (failed probes included) and the backend that finally
    /// answered accumulate into the request's [`TraceCtx`].
    fn forward_traced(
        &self,
        conns: &mut HashMap<usize, WireClient>,
        b: usize,
        raw: &str,
        idempotent: bool,
        ctx: Option<&TraceCtx>,
    ) -> Result<String, ForwardErr> {
        let t0 = Instant::now();
        let res = self.forward(conns, b, raw, idempotent);
        if let Some(ctx) = ctx {
            ctx.forward_ns
                .set(ctx.forward_ns.get() + t0.elapsed().as_nanos() as u64);
            if res.is_ok() {
                ctx.backend.set(Some(b));
            }
        }
        res
    }

    /// Does this reply say "that session does not live here"?
    fn is_no_session(reply: &str) -> bool {
        match Json::parse(reply) {
            Ok(v) => {
                v.get("ok") == Some(&Json::Bool(false))
                    && v.get("error")
                        .and_then(|e| e.as_str())
                        .is_some_and(|m| m.contains("no session"))
            }
            Err(_) => false,
        }
    }

    /// Every live backend, `first` first — the candidate order for
    /// placement and probing.
    fn candidates(&self, first: usize) -> Vec<usize> {
        let mut order = vec![first];
        order.extend(
            (0..self.backends.len())
                .filter(|&b| b != first && self.alive(b)),
        );
        order
    }

    /// Count one acked state-advancing op against `id`'s replication
    /// clock; ship to the standby when `replicate_every` is due.
    fn maybe_replicate(
        &self,
        conns: &mut HashMap<usize, WireClient>,
        id: u64,
    ) {
        if self.replicate_every == 0 {
            return;
        }
        let due = {
            let mut clocks = mlock(&self.repl_clock);
            let c = clocks.entry(id).or_insert(0);
            *c += 1;
            self.repl_lag.fetch_add(1, Ordering::Relaxed);
            *c >= self.replicate_every
        };
        if due {
            self.replicate_now(conns, id);
        }
    }

    /// Ship `id`'s current state from its table-pinned home to its
    /// ring-successor standby, where it parks as a replica. Best-effort:
    /// the triggering op is already acked, so a miss never fails the
    /// client — it bumps `route.repl_errors` and leaves `route.repl_lag`
    /// standing until the next successful ship.
    fn replicate_now(&self, conns: &mut HashMap<usize, WireClient>, id: u64) {
        let Some(home) = rlock(&self.table).get(&id).copied() else {
            self.repl_errors.fetch_add(1, Ordering::Relaxed);
            return;
        };
        let Some(standby) =
            self.ring.successor(id, home, |b| self.alive(b))
        else {
            // a 1-backend fleet (or an otherwise-dead one) has nowhere
            // to ship — replication degrades to off for this session
            self.repl_errors.fetch_add(1, Ordering::Relaxed);
            return;
        };
        // the ship covers every op counted so far; a concurrent writer
        // bumping the clock mid-ship keeps its (post-snapshot) ops in
        // the lag gauge
        let drained =
            mlock(&self.repl_clock).get(&id).copied().unwrap_or(0);
        let state = match self.client(conns, home).snapshot(id) {
            Ok(s) => s,
            Err(e) => {
                if e.is_connect() {
                    self.set_alive(home, false);
                }
                self.repl_errors.fetch_add(1, Ordering::Relaxed);
                return;
            }
        };
        let line = Json::obj(vec![
            ("op", Json::Str("replicate".to_string())),
            ("id", Json::Num(id as f64)),
            ("state", state),
        ])
        .dump();
        // parking a replica is an overwrite: idempotent, safe to replay
        let ok = match self
            .client(conns, standby)
            .request_line_idempotent(&line)
        {
            Ok(reply) => reply_is_ok(&reply),
            Err(e) => {
                if e.is_connect() {
                    self.set_alive(standby, false);
                }
                false
            }
        };
        if ok {
            self.replicated.fetch_add(1, Ordering::Relaxed);
            let mut clocks = mlock(&self.repl_clock);
            if let Some(c) = clocks.get_mut(&id) {
                let n = (*c).min(drained);
                *c -= n;
                self.repl_lag.fetch_sub(n, Ordering::Relaxed);
            }
        } else {
            self.repl_errors.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Promote `id`'s warm standby to authority after its pinned home
    /// `dead` stopped answering. Exclusive on the id's gate: an
    /// in-flight op still holding it shared finishes first — its reply,
    /// however late, lands on the old timeline — so promotion and late
    /// replies serialize and nothing runs twice on the new authority.
    /// Refuses when the home still answers a probe (a blip is not a
    /// death), when replication is off, or when no live standby exists.
    fn promote(
        &self,
        conns: &mut HashMap<usize, WireClient>,
        id: u64,
        dead: usize,
    ) -> Result<usize, String> {
        let gate = self.gate(id);
        let _exclusive = wlock(&gate);
        // re-check under the gate: a racing op may already have promoted
        if let Some(&b) = rlock(&self.table).get(&id) {
            if b != dead && self.alive(b) {
                return Ok(b);
            }
        }
        if self.replicate_every == 0 {
            return Err(format!(
                "promote: backend {} is unreachable and session {id} has \
                 no replica (start the router with --replicate-every)",
                self.backends[dead].label
            ));
        }
        // only a provably-unreachable home loses authority: a
        // still-answering home means the failed op was a blip, and
        // promoting under it would leave two resident authorities
        if mlock(&self.backends[dead].admin).ping().is_ok() {
            self.set_alive(dead, true);
            return Err(format!(
                "promote: backend {} is alive — use handoff to move \
                 session {id}",
                self.backends[dead].label
            ));
        }
        self.set_alive(dead, false);
        let Some(standby) =
            self.ring.successor(id, dead, |b| self.alive(b))
        else {
            return Err(format!(
                "promote: no live standby for session {id} besides {}",
                self.backends[dead].label
            ));
        };
        // the replica sits parked on the standby; warm makes it resident
        let line = format!(r#"{{"op":"warm","id":{id}}}"#);
        match self.forward(conns, standby, &line, false) {
            Ok(reply) if reply_is_ok(&reply) => {}
            Ok(reply) => {
                return Err(format!(
                    "promote: standby {} has no replica of session {id}: \
                     {reply}",
                    self.backends[standby].label
                ));
            }
            Err(e) => return Err(format!("promote: {}", e.message())),
        }
        wlock(&self.table).insert(id, standby);
        // whatever the dead home acked after the last ship is lost (the
        // documented ≤ K-1 staleness window); the new timeline starts
        // at the replica, so the id's lag contribution resets
        {
            let mut clocks = mlock(&self.repl_clock);
            if let Some(c) = clocks.get_mut(&id) {
                self.repl_lag.fetch_sub(*c, Ordering::Relaxed);
                *c = 0;
            }
        }
        self.promotions.fetch_add(1, Ordering::Relaxed);
        Ok(standby)
    }

    /// `{"op":"promote","id":N}`: operator-forced failover onto the
    /// session's warm standby (the same path routed ops take
    /// automatically when their pinned home dies).
    fn promote_reply(
        &self,
        conns: &mut HashMap<usize, WireClient>,
        v: &Json,
    ) -> String {
        let Some(id) = wire_id(v) else {
            return error_line("promote: missing or invalid 'id'");
        };
        let Some(home) = rlock(&self.table)
            .get(&id)
            .copied()
            .or_else(|| self.ring_home(id))
        else {
            self.err_no_backend.fetch_add(1, Ordering::Relaxed);
            return error_line("route: no live backend");
        };
        match self.promote(conns, id, home) {
            Ok(b) => Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("id", Json::Num(id as f64)),
                ("to", Json::Str(self.backends[b].label.clone())),
            ])
            .dump(),
            Err(e) => error_line(e),
        }
    }

    /// Best-effort delete of `id`'s parked replica after its close: the
    /// standby's copy must not resurrect a closed session on a later
    /// promotion. Errors (no replica yet, standby down) are ignored.
    fn drop_replica(&self, conns: &mut HashMap<usize, WireClient>, id: u64) {
        if self.replicate_every == 0 {
            return;
        }
        let Some(home) = rlock(&self.table).get(&id).copied() else {
            return;
        };
        let Some(standby) =
            self.ring.successor(id, home, |b| self.alive(b))
        else {
            return;
        };
        let _ = self
            .client(conns, standby)
            .request_line(&format!(r#"{{"op":"close","id":{id}}}"#));
    }

    /// Route an id-addressed op: table-pinned → exactly that backend;
    /// otherwise ring home with locate-and-cache probing on "no session".
    fn route_id(
        &self,
        conns: &mut HashMap<usize, WireClient>,
        id: u64,
        raw: &str,
        idempotent: bool,
        advances: bool,
        ctx: Option<&TraceCtx>,
    ) -> String {
        let gate = self.gate(id);
        let shared = rlock(&gate);
        if let Some(&b) = rlock(&self.table).get(&id) {
            // the session's state is THERE; a dead pin fails over to the
            // session's warm standby when one exists, and otherwise
            // fails loudly — it never silently re-routes onto a backend
            // without the state
            let err =
                match self.forward_traced(conns, b, raw, idempotent, ctx) {
                    Ok(reply) => {
                        if advances && reply_is_ok(&reply) {
                            self.maybe_replicate(conns, id);
                        }
                        return reply;
                    }
                    Err(e) => e,
                };
            // promotion needs the gate exclusively — release our shared
            // hold before attempting it (the gate is not reentrant)
            drop(shared);
            let msg = err.message();
            return match self.promote(conns, id, b) {
                Err(_) => error_line(msg),
                Ok(standby) => {
                    // the replica never saw an un-acked op (ships follow
                    // acks), so one retry on the new authority cannot
                    // double-run even a maybe-executed op
                    self.retries.fetch_add(1, Ordering::Relaxed);
                    let _shared = rlock(&gate);
                    match self
                        .forward_traced(conns, standby, raw, idempotent, ctx)
                    {
                        Ok(reply) => {
                            if advances && reply_is_ok(&reply) {
                                self.maybe_replicate(conns, id);
                            }
                            reply
                        }
                        Err(e) => error_line(e.message()),
                    }
                }
            };
        }
        let Some(home) = self.ring_home(id) else {
            self.err_no_backend.fetch_add(1, Ordering::Relaxed);
            return error_line("route: no live backend");
        };
        let mut home_reply: Option<String> = None;
        let mut last_err: Option<String> = None;
        for (i, b) in self.candidates(home).into_iter().enumerate() {
            if i > 0 {
                self.retries.fetch_add(1, Ordering::Relaxed);
            }
            match self.forward_traced(conns, b, raw, idempotent, ctx) {
                Ok(reply) => {
                    if Self::is_no_session(&reply) {
                        // not here — keep probing; remember the home's
                        // exact reply for the nowhere case
                        home_reply.get_or_insert(reply);
                        continue;
                    }
                    wlock(&self.table).insert(id, b);
                    if advances && reply_is_ok(&reply) {
                        self.maybe_replicate(conns, id);
                    }
                    return reply;
                }
                Err(ForwardErr::NotSent(m)) => {
                    last_err = Some(m);
                    continue;
                }
                Err(ForwardErr::Broken(m)) => return error_line(m),
            }
        }
        // nowhere: the home's own "no session" reply is what a direct
        // single-backend run would have said, byte for byte
        home_reply.unwrap_or_else(|| {
            error_line(last_err.unwrap_or_else(|| {
                self.err_no_backend.fetch_add(1, Ordering::Relaxed);
                "route: no live backend".to_string()
            }))
        })
    }

    /// Place a fresh `open`/mint-id `restore` by ring over the placement
    /// counter; record the minted id.
    fn route_open(
        &self,
        conns: &mut HashMap<usize, WireClient>,
        raw: &str,
        ctx: Option<&TraceCtx>,
    ) -> String {
        let key = self.placements.fetch_add(1, Ordering::Relaxed);
        let Some(first) = self.ring_home(key) else {
            self.err_no_backend.fetch_add(1, Ordering::Relaxed);
            return error_line("route: no live backend");
        };
        let mut last_err: Option<String> = None;
        for (i, b) in self.candidates(first).into_iter().enumerate() {
            if i > 0 {
                self.retries.fetch_add(1, Ordering::Relaxed);
            }
            match self.forward_traced(conns, b, raw, false, ctx) {
                Ok(reply) => {
                    if let Ok(v) = Json::parse(&reply) {
                        if v.get("ok") == Some(&Json::Bool(true)) {
                            if let Some(id) =
                                v.get("id").and_then(|id| id.as_f64())
                            {
                                wlock(&self.table).insert(id as u64, b);
                                if self.replicate_every > 0 {
                                    // seed the standby right away so a
                                    // home that dies before the first
                                    // K-boundary still has something to
                                    // promote
                                    self.replicate_now(conns, id as u64);
                                }
                            }
                        }
                    }
                    return reply;
                }
                Err(ForwardErr::NotSent(m)) => {
                    last_err = Some(m);
                    continue;
                }
                Err(ForwardErr::Broken(m)) => return error_line(m),
            }
        }
        error_line(last_err.unwrap_or_else(|| "route: no live backend".into()))
    }

    /// `step_batch`: all items on one backend → forward the raw line
    /// (bit-transparent); otherwise split per backend and re-merge via
    /// the backend's own serializer.
    fn route_step_batch(
        &self,
        conns: &mut HashMap<usize, WireClient>,
        items: &[StepItem],
        raw: &str,
        ctx: Option<&TraceCtx>,
    ) -> String {
        // hold every touched id's gate, in sorted unique order (same
        // global order as any concurrent batch — no lock cycles; a
        // handoff holds exactly one gate, so no cycle there either)
        let mut ids: Vec<u64> = items.iter().map(|it| it.id).collect();
        ids.sort_unstable();
        ids.dedup();
        let gates: Vec<Arc<RwLock<()>>> =
            ids.iter().map(|&id| self.gate(id)).collect();
        let _shared: Vec<_> = gates.iter().map(|g| rlock(g)).collect();

        let mut by_backend: BTreeMap<usize, Vec<usize>> = BTreeMap::new();
        let mut unroutable: Vec<usize> = Vec::new();
        {
            let table = rlock(&self.table);
            for (i, it) in items.iter().enumerate() {
                let b = table
                    .get(&it.id)
                    .copied()
                    .or_else(|| self.ring_home(it.id));
                match b {
                    Some(b) => by_backend.entry(b).or_default().push(i),
                    None => unroutable.push(i),
                }
            }
        }
        if by_backend.len() == 1 && unroutable.is_empty() {
            let (&b, _) = by_backend.iter().next().expect("one entry");
            return match self.forward_traced(conns, b, raw, false, ctx) {
                Ok(reply) => {
                    if self.replicate_every > 0 {
                        // pin + count the acked slots; the raw reply
                        // passes through untouched
                        let (ys, _) = parse_batch_reply(&reply);
                        let mut acked: Vec<u64> = ys
                            .iter()
                            .enumerate()
                            .filter(|(_, y)| y.is_some())
                            .filter_map(|(slot, _)| {
                                items.get(slot).map(|it| it.id)
                            })
                            .collect();
                        acked.sort_unstable();
                        acked.dedup();
                        for id in acked {
                            wlock(&self.table).insert(id, b);
                            self.maybe_replicate(conns, id);
                        }
                    }
                    reply
                }
                Err(e) => error_line(e.message()),
            };
        }
        if !unroutable.is_empty() {
            self.err_no_backend.fetch_add(1, Ordering::Relaxed);
        }
        let mut ys: Vec<Result<f32, String>> =
            vec![Err("route: no live backend".to_string()); items.len()];
        for (&b, idxs) in &by_backend {
            let mut sub_fields = vec![
                ("op", Json::Str("step_batch".to_string())),
                (
                    "ids",
                    Json::Arr(
                        idxs.iter()
                            .map(|&i| Json::Num(items[i].id as f64))
                            .collect(),
                    ),
                ),
                (
                    "xs",
                    Json::Arr(
                        idxs.iter().map(|&i| Json::arr_f32(&items[i].x)).collect(),
                    ),
                ),
                (
                    "cs",
                    Json::Arr(
                        idxs.iter()
                            .map(|&i| Json::Num(items[i].c as f64))
                            .collect(),
                    ),
                ),
            ];
            if let Some(ctx) = ctx {
                // split sub-batches carry the same correlation pair, so
                // every shard of the batch joins back to one trace
                sub_fields.push(("trace_id", Json::Str(ctx.trace_id.clone())));
                sub_fields.push(("span_id", Json::Str(ctx.span_id.clone())));
            }
            let sub = Json::obj(sub_fields).dump();
            match self.forward_traced(conns, b, &sub, false, ctx) {
                Ok(reply) => {
                    let (sub_ys, sub_errs) = parse_batch_reply(&reply);
                    for (slot, &i) in idxs.iter().enumerate() {
                        ys[i] = match sub_ys.get(slot) {
                            Some(Some(y)) => Ok(*y),
                            Some(None) => {
                                Err(sub_errs.get(&slot).cloned().unwrap_or_else(
                                    || "step failed".to_string(),
                                ))
                            }
                            None => Err(format!(
                                "backend {} returned a short batch",
                                self.backends[b].label
                            )),
                        };
                    }
                }
                Err(e) => {
                    let msg = e.message();
                    for &i in idxs {
                        ys[i] = Err(msg.clone());
                    }
                }
            }
        }
        if self.replicate_every > 0 {
            for (&b, idxs) in &by_backend {
                let mut acked: Vec<u64> = idxs
                    .iter()
                    .filter(|&&i| ys[i].is_ok())
                    .map(|&i| items[i].id)
                    .collect();
                acked.sort_unstable();
                acked.dedup();
                for id in acked {
                    wlock(&self.table).insert(id, b);
                    self.maybe_replicate(conns, id);
                }
            }
        }
        Response::SteppedMany { ys }.to_json().dump()
    }

    /// Live-migrate one session (gate held exclusively): snapshot on the
    /// source, restore under the *same id* on the destination, close the
    /// source copy only after the destination acked. Returns
    /// `(source, destination)`.
    fn handoff(
        &self,
        conns: &mut HashMap<usize, WireClient>,
        id: u64,
        want: Option<usize>,
    ) -> Result<(usize, usize), String> {
        let gate = self.gate(id);
        let _exclusive = wlock(&gate);
        // locate the source: table pin first, else probe a snapshot out
        // of every live backend
        let pinned = rlock(&self.table).get(&id).copied();
        let order: Vec<usize> = match pinned {
            Some(b) => vec![b],
            None => (0..self.backends.len())
                .filter(|&b| self.alive(b))
                .collect(),
        };
        let mut state: Option<(usize, Json)> = None;
        let mut last = format!("handoff: no backend has session {id}");
        for b in order {
            match self.client(conns, b).snapshot(id) {
                Ok(s) => {
                    state = Some((b, s));
                    break;
                }
                Err(e) => {
                    if e.is_connect() {
                        self.set_alive(b, false);
                    }
                    last = format!("handoff: {e}");
                }
            }
        }
        let Some((source, state)) = state else {
            return Err(last);
        };
        let dest = match want {
            Some(d) => d,
            None => self
                .ring
                .home(id, |b| b != source && self.routable(b))
                .or_else(|| {
                    (0..self.backends.len())
                        .find(|&b| b != source && self.alive(b))
                })
                .ok_or_else(|| {
                    format!(
                        "handoff: no live destination besides {}",
                        self.backends[source].label
                    )
                })?,
        };
        if dest == source {
            wlock(&self.table).insert(id, source);
            return Ok((source, source));
        }
        // copy-to-destination BEFORE delete-on-source: a crash in the
        // gap leaves a shadowed duplicate, never a lost session
        self.client(conns, dest)
            .restore(&state, Some(id))
            .map_err(|e| {
                if e.is_connect() {
                    self.set_alive(dest, false);
                }
                format!(
                    "handoff: restore on {}: {e}",
                    self.backends[dest].label
                )
            })?;
        wlock(&self.table).insert(id, dest);
        self.migrations.fetch_add(1, Ordering::Relaxed);
        // the destination owns the id now; a failed source close only
        // leaves a stale shadowed copy behind
        if self.client(conns, source).close(id).is_err() {
            self.err_backend.fetch_add(1, Ordering::Relaxed);
        }
        Ok((source, dest))
    }

    fn handoff_reply(
        &self,
        conns: &mut HashMap<usize, WireClient>,
        v: &Json,
    ) -> String {
        let Some(id) = wire_id(v) else {
            return error_line("handoff: missing or invalid 'id'");
        };
        let want = match v.get("to").and_then(|t| t.as_str()) {
            None => None,
            Some(label) => match self.backend_index(label) {
                Some(b) => Some(b),
                None => {
                    return error_line(format!(
                        "handoff: unknown backend '{label}'"
                    ))
                }
            },
        };
        match self.handoff(conns, id, want) {
            Ok((source, dest)) => Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("id", Json::Num(id as f64)),
                ("from", Json::Str(self.backends[source].label.clone())),
                ("to", Json::Str(self.backends[dest].label.clone())),
            ])
            .dump(),
            Err(e) => error_line(e),
        }
    }

    /// Migrate every table-known session off a backend and take it out
    /// of the ring (rolling-restart prep). Sessions the router has never
    /// routed are untouched — they surface later via locate-and-cache.
    fn drain_reply(
        &self,
        conns: &mut HashMap<usize, WireClient>,
        v: &Json,
    ) -> String {
        let Some(label) = v.get("backend").and_then(|b| b.as_str()) else {
            return error_line("drain: missing 'backend'");
        };
        let Some(victim) = self.backend_index(label) else {
            return error_line(format!("drain: unknown backend '{label}'"));
        };
        self.backends[victim].in_ring.store(false, Ordering::Relaxed);
        let ids: Vec<u64> = rlock(&self.table)
            .iter()
            .filter(|&(_, &b)| b == victim)
            .map(|(&id, _)| id)
            .collect();
        let mut moved = 0usize;
        let mut errors: Vec<Json> = Vec::new();
        for id in ids {
            match self.handoff(conns, id, None) {
                Ok(_) => moved += 1,
                Err(e) => errors.push(Json::obj(vec![
                    ("id", Json::Num(id as f64)),
                    ("error", Json::Str(e)),
                ])),
            }
        }
        let mut fields = vec![
            ("ok", Json::Bool(errors.is_empty())),
            ("backend", Json::Str(label.to_string())),
            ("moved", Json::Num(moved as f64)),
        ];
        if !errors.is_empty() {
            fields.push(("errors", Json::Arr(errors)));
        }
        Json::obj(fields).dump()
    }

    /// Re-point every table entry at its current ring home (after a
    /// membership change: a revived backend, a finished drain).
    fn rebalance_reply(
        &self,
        conns: &mut HashMap<usize, WireClient>,
    ) -> String {
        let entries: Vec<(u64, usize)> = rlock(&self.table)
            .iter()
            .map(|(&id, &b)| (id, b))
            .collect();
        let mut moved = 0usize;
        let mut errors: Vec<Json> = Vec::new();
        for (id, cur) in entries {
            let Some(home) = self.ring.home(id, |b| self.routable(b)) else {
                continue;
            };
            if home == cur {
                continue;
            }
            match self.handoff(conns, id, Some(home)) {
                Ok(_) => moved += 1,
                Err(e) => errors.push(Json::obj(vec![
                    ("id", Json::Num(id as f64)),
                    ("error", Json::Str(e)),
                ])),
            }
        }
        let mut fields = vec![
            ("ok", Json::Bool(errors.is_empty())),
            ("moved", Json::Num(moved as f64)),
        ];
        if !errors.is_empty() {
            fields.push(("errors", Json::Arr(errors)));
        }
        Json::obj(fields).dump()
    }

    /// Probe every backend's liveness once (the health thread's tick;
    /// also runs inline for the `health` op). Uses the admin connections.
    pub fn probe_all(&self) {
        for (b, backend) in self.backends.iter().enumerate() {
            let ok = mlock(&backend.admin).ping().is_ok();
            self.set_alive(b, ok);
        }
    }

    fn health_reply(&self) -> String {
        self.probe_all();
        let mut list: Vec<Json> = Vec::new();
        for backend in &self.backends {
            let alive = backend.alive.load(Ordering::Relaxed);
            let mut fields = vec![
                ("addr", Json::Str(backend.label.clone())),
                ("alive", Json::Bool(alive)),
                (
                    "in_ring",
                    Json::Bool(backend.in_ring.load(Ordering::Relaxed)),
                ),
            ];
            if alive {
                if let Ok(stats) = mlock(&backend.admin).stats() {
                    for key in ["sessions", "resident", "parked", "steps"] {
                        if let Some(v) = stats.get(key).and_then(|v| v.as_f64())
                        {
                            fields.push((key, Json::Num(v)));
                        }
                    }
                }
            }
            list.push(Json::obj(fields));
        }
        Json::obj(vec![
            ("ok", Json::Bool(true)),
            ("backends", Json::Arr(list)),
            ("table", Json::Num(rlock(&self.table).len() as f64)),
            (
                "migrations",
                Json::Num(self.migrations.load(Ordering::Relaxed) as f64),
            ),
        ])
        .dump()
    }

    /// Membership/topology summary attached to `stats` and `metrics`.
    fn cluster_block(
        &self,
        per_backend: Option<&[Option<Json>]>,
    ) -> Json {
        let list: Vec<Json> = self
            .backends
            .iter()
            .enumerate()
            .map(|(i, backend)| {
                let mut fields = vec![
                    ("addr", Json::Str(backend.label.clone())),
                    (
                        "alive",
                        Json::Bool(backend.alive.load(Ordering::Relaxed)),
                    ),
                    (
                        "in_ring",
                        Json::Bool(backend.in_ring.load(Ordering::Relaxed)),
                    ),
                ];
                if let Some(stats) =
                    per_backend.and_then(|s| s.get(i)).and_then(|s| s.as_ref())
                {
                    for key in ["sessions", "resident", "parked", "steps"] {
                        if let Some(v) = stats.get(key).and_then(|v| v.as_f64())
                        {
                            fields.push((key, Json::Num(v)));
                        }
                    }
                }
                Json::obj(fields)
            })
            .collect();
        Json::obj(vec![
            ("backends", Json::Arr(list)),
            ("table", Json::Num(rlock(&self.table).len() as f64)),
            (
                "placements",
                Json::Num(self.placements.load(Ordering::Relaxed) as f64),
            ),
            (
                "migrations",
                Json::Num(self.migrations.load(Ordering::Relaxed) as f64),
            ),
            (
                "replicate_every",
                Json::Num(self.replicate_every as f64),
            ),
            (
                "replicated",
                Json::Num(self.replicated.load(Ordering::Relaxed) as f64),
            ),
            (
                "repl_errors",
                Json::Num(self.repl_errors.load(Ordering::Relaxed) as f64),
            ),
            (
                "repl_lag",
                Json::Num(self.repl_lag.load(Ordering::Relaxed) as f64),
            ),
            (
                "promotions",
                Json::Num(self.promotions.load(Ordering::Relaxed) as f64),
            ),
        ])
    }

    /// Aggregate `stats` across live backends + the `cluster` block.
    fn stats_reply(&self, conns: &mut HashMap<usize, WireClient>) -> String {
        let mut per_backend: Vec<Option<Json>> =
            vec![None; self.backends.len()];
        let mut sums: BTreeMap<&str, f64> = BTreeMap::new();
        let mut kinds: BTreeMap<String, f64> = BTreeMap::new();
        for b in 0..self.backends.len() {
            if !self.alive(b) {
                continue;
            }
            match self.client(conns, b).stats() {
                Ok(stats) => {
                    for key in [
                        "sessions",
                        "resident",
                        "parked",
                        "steps",
                        "store_bytes",
                        "evictions",
                        "rehydrations",
                    ] {
                        if let Some(v) = stats.get(key).and_then(|v| v.as_f64())
                        {
                            *sums.entry(key).or_default() += v;
                        }
                    }
                    if let Some(ks) = stats.get("kinds").and_then(|k| k.as_obj())
                    {
                        for (k, n) in ks {
                            if let Some(n) = n.as_f64() {
                                *kinds.entry(k.clone()).or_default() += n;
                            }
                        }
                    }
                    per_backend[b] = Some(stats);
                }
                Err(e) => {
                    if e.is_connect() {
                        self.set_alive(b, false);
                    }
                    self.err_backend.fetch_add(1, Ordering::Relaxed);
                }
            }
        }
        let mut fields = vec![("ok", Json::Bool(true))];
        for key in [
            "sessions",
            "resident",
            "parked",
            "steps",
            "store_bytes",
            "evictions",
            "rehydrations",
        ] {
            fields.push((key, Json::Num(*sums.get(key).unwrap_or(&0.0))));
        }
        fields.push((
            "kinds",
            Json::Obj(
                kinds.into_iter().map(|(k, n)| (k, Json::Num(n))).collect(),
            ),
        ));
        fields.push(("cluster", self.cluster_block(Some(&per_backend))));
        Json::obj(fields).dump()
    }

    /// The router's own registry (one consistent snapshot, `route.*`
    /// under `histograms`) + the `cluster` block.
    fn metrics_reply(&self) -> String {
        match self.obs.snapshot().to_json() {
            Json::Obj(mut fields) => {
                fields.insert("ok".to_string(), Json::Bool(true));
                fields.insert("cluster".to_string(), self.cluster_block(None));
                Json::Obj(fields).dump()
            }
            other => other.dump(),
        }
    }

    /// `{"op":"metrics","scope":"fleet"}`: fan `metrics` out to every
    /// live backend and fold the parsed registries through
    /// [`RegistrySnapshot::merge`] — the cross-process exercise of the
    /// bucketwise [`crate::obs::HistogramSnapshot::merge`]. The reply
    /// carries the merged totals, each backend's own (unmodified)
    /// snapshot, the router's registry, and the cluster block; an
    /// unreachable or unparsable backend is reported per-backend without
    /// failing the roll-up.
    fn fleet_metrics_reply(
        &self,
        conns: &mut HashMap<usize, WireClient>,
    ) -> String {
        let mut merged = RegistrySnapshot::default();
        let mut blocks: Vec<Json> = Vec::new();
        for b in 0..self.backends.len() {
            let addr = ("addr", Json::Str(self.backends[b].label.clone()));
            if !self.alive(b) {
                blocks.push(Json::obj(vec![
                    addr,
                    ("alive", Json::Bool(false)),
                ]));
                continue;
            }
            let block = match self.forward(conns, b, r#"{"op":"metrics"}"#, true)
            {
                Ok(reply) => match Json::parse(&reply) {
                    Ok(v) if v.get("ok") == Some(&Json::Bool(true)) => {
                        match RegistrySnapshot::from_metrics_json(&v) {
                            Ok(snap) => {
                                merged = merged.merge(&snap);
                                Json::obj(vec![
                                    addr,
                                    ("alive", Json::Bool(true)),
                                    ("metrics", v),
                                ])
                            }
                            Err(e) => Json::obj(vec![
                                addr,
                                ("alive", Json::Bool(true)),
                                ("error", Json::Str(e)),
                            ]),
                        }
                    }
                    _ => Json::obj(vec![
                        addr,
                        ("alive", Json::Bool(true)),
                        (
                            "error",
                            Json::Str(
                                "backend returned an error reply".to_string(),
                            ),
                        ),
                    ]),
                },
                Err(e) => Json::obj(vec![
                    addr,
                    ("alive", Json::Bool(false)),
                    ("error", Json::Str(e.message())),
                ]),
            };
            blocks.push(block);
        }
        Json::obj(vec![
            ("ok", Json::Bool(true)),
            ("scope", Json::Str("fleet".to_string())),
            ("merged", merged.to_json()),
            ("backends", Json::Arr(blocks)),
            ("router", self.obs.snapshot().to_json()),
            ("cluster", self.cluster_block(None)),
        ])
        .dump()
    }

    fn timer(&self, op: &str) -> Option<&Arc<Histogram>> {
        self.timers.get(op)
    }

    /// Handle one raw request line against the cluster. `conns` is the
    /// calling connection's private map of backend sockets (keeps
    /// per-client ordering on each backend without any global lock).
    pub fn handle_line(
        &self,
        line: &str,
        conns: &mut HashMap<usize, WireClient>,
    ) -> String {
        let t0 = Instant::now();
        self.win_ops.add(1);
        let (name, ctx, reply) = self.dispatch(line, conns);
        let dur = t0.elapsed();
        if let Some(h) = self.timer(name) {
            h.record_duration(dur);
        }
        if let (Some(trace), Some(ctx)) = (&self.trace, &ctx) {
            if ctx.sampled {
                trace.emit(&self.route_trace_event(name, ctx, dur, &reply));
            }
        }
        reply
    }

    /// The router's side of an end-to-end trace: one event per sampled
    /// routed op, carrying the correlation pair it forwarded, which
    /// backend answered, and how much of the op was the forward itself.
    fn route_trace_event(
        &self,
        op: &str,
        ctx: &TraceCtx,
        dur: Duration,
        reply: &str,
    ) -> Json {
        let mut fields: Vec<(&str, Json)> = vec![
            ("ts_ns", Json::Num(self.epoch.elapsed().as_nanos() as f64)),
            ("op", Json::Str(op.to_string())),
            ("trace_id", Json::Str(ctx.trace_id.clone())),
            ("span_id", Json::Str(ctx.span_id.clone())),
        ];
        if let Some(b) = ctx.backend.get() {
            fields.push((
                "backend",
                Json::Str(self.backends[b].label.clone()),
            ));
        }
        fields.push(("forward_ns", Json::Num(ctx.forward_ns.get() as f64)));
        fields.push(("dur_ns", Json::Num(dur.as_nanos() as f64)));
        let ok = Json::parse(reply)
            .map(|v| v.get("ok") == Some(&Json::Bool(true)))
            .unwrap_or(false);
        fields.push(("ok", Json::Bool(ok)));
        Json::obj(fields)
    }

    fn dispatch(
        &self,
        line: &str,
        conns: &mut HashMap<usize, WireClient>,
    ) -> (&'static str, Option<TraceCtx>, String) {
        let v = match Json::parse(line) {
            // the exact bytes a backend would send for the same garbage
            Err(e) => {
                return ("step", None, error_line(format!("bad json: {e}")))
            }
            Ok(v) => v,
        };
        // router-tier ops first: they are not part of the backend
        // protocol (a backend would reject them as unknown)
        match v.get("op").and_then(|o| o.as_str()) {
            Some("health") => return ("health", None, self.health_reply()),
            Some("handoff") => {
                return ("handoff", None, self.handoff_reply(conns, &v))
            }
            Some("drain") => {
                return ("drain", None, self.drain_reply(conns, &v))
            }
            Some("rebalance") => {
                return ("rebalance", None, self.rebalance_reply(conns))
            }
            Some("promote") => {
                return ("promote", None, self.promote_reply(conns, &v))
            }
            _ => {}
        }
        let op = match parse_wire_op(&v) {
            Err(e) => return ("step", None, error_line(e)),
            Ok(op) => op,
        };
        // with tracing configured, every well-formed op gets correlation
        // context: client-supplied ids are reused (an upstream tracer
        // keeps working), missing ones are minted, and only the missing
        // keys are spliced into the forwarded line
        let (ctx, fwd): (Option<TraceCtx>, Cow<'_, str>) = match &self.trace {
            None => (None, Cow::Borrowed(line)),
            Some(trace) => {
                let incoming = crate::obs::span::from_wire(&v);
                let (trace_id, had_trace) = match &incoming {
                    Some(s) => (s.trace_id.clone(), true),
                    None => (mint_id(), false),
                };
                let (span_id, had_span) =
                    match incoming.as_ref().and_then(|s| s.span_id.clone()) {
                        Some(s) => (s, true),
                        None => (mint_id(), false),
                    };
                let mut add: Vec<(&str, &str)> = Vec::new();
                if !had_trace {
                    add.push(("trace_id", trace_id.as_str()));
                }
                if !had_span {
                    add.push(("span_id", span_id.as_str()));
                }
                let fwd = if add.is_empty() {
                    Cow::Borrowed(line)
                } else {
                    Cow::Owned(inject_correlation(line, &add))
                };
                let ctx = TraceCtx {
                    trace_id,
                    span_id,
                    sampled: trace.should_sample(),
                    backend: Cell::new(None),
                    forward_ns: Cell::new(0),
                };
                (Some(ctx), fwd)
            }
        };
        let cx = ctx.as_ref();
        let fwd = fwd.as_ref();
        let (name, reply) = match op {
            // same bytes as the backend's inline pong
            WireOp::Ping => (
                "ping",
                Json::obj(vec![
                    ("ok", Json::Bool(true)),
                    ("pong", Json::Bool(true)),
                ])
                .dump(),
            ),
            WireOp::Open(_) => ("open", self.route_open(conns, fwd, cx)),
            WireOp::Restore { id: None, .. } => {
                ("restore", self.route_open(conns, fwd, cx))
            }
            WireOp::Restore { id: Some(id), .. } => {
                ("restore", self.route_id(conns, id, fwd, false, true, cx))
            }
            WireOp::Step { id, .. } => {
                ("step", self.route_id(conns, id, fwd, false, true, cx))
            }
            WireOp::Predict { id, .. } => {
                ("predict", self.route_id(conns, id, fwd, true, false, cx))
            }
            WireOp::Snapshot { id } => {
                ("snapshot", self.route_id(conns, id, fwd, true, false, cx))
            }
            WireOp::Park { id } => {
                ("park", self.route_id(conns, id, fwd, false, false, cx))
            }
            WireOp::Warm { id } => {
                ("warm", self.route_id(conns, id, fwd, false, false, cx))
            }
            WireOp::Close { id } => {
                let reply = self.route_id(conns, id, fwd, false, false, cx);
                if let Ok(v) = Json::parse(&reply) {
                    if v.get("ok") == Some(&Json::Bool(true)) {
                        self.drop_replica(conns, id);
                        self.forget(id);
                    }
                }
                ("close", reply)
            }
            // replicas are the router's own business: a client-shipped
            // envelope would bypass the clock/standby bookkeeping
            WireOp::Replicate { .. } => (
                "replicate",
                error_line(
                    "replicate: the router manages replicas itself (start \
                     it with --replicate-every); send replicate directly \
                     to a backend",
                ),
            ),
            WireOp::StepBatch(items) => (
                "step_batch",
                self.route_step_batch(conns, &items, fwd, cx),
            ),
            WireOp::Stats => ("stats", self.stats_reply(conns)),
            WireOp::Metrics => {
                let fleet = v.get("scope").and_then(|s| s.as_str())
                    == Some("fleet");
                if fleet {
                    ("metrics", self.fleet_metrics_reply(conns))
                } else {
                    ("metrics", self.metrics_reply())
                }
            }
        };
        (name, ctx, reply)
    }
}

/// Strict wire id (mirrors the protocol's rule: non-negative integer).
fn wire_id(v: &Json) -> Option<u64> {
    match v.get("id").and_then(|id| id.as_f64()) {
        Some(f) if f >= 0.0 && f.fract() == 0.0 && f < u64::MAX as f64 => {
            Some(f as u64)
        }
        _ => None,
    }
}

/// Decode one backend `step_batch` reply: per-slot `Some(y)`/`None`,
/// plus the per-slot error messages.
fn parse_batch_reply(reply: &str) -> (Vec<Option<f32>>, BTreeMap<usize, String>) {
    let mut errs = BTreeMap::new();
    let Ok(v) = Json::parse(reply) else {
        return (Vec::new(), errs);
    };
    if let Some(list) = v.get("errors").and_then(|e| e.as_arr()) {
        for entry in list {
            if let (Some(i), Some(msg)) = (
                entry.get("index").and_then(|i| i.as_usize()),
                entry.get("error").and_then(|m| m.as_str()),
            ) {
                errs.insert(i, msg.to_string());
            }
        }
    }
    let ys = v
        .get("ys")
        .and_then(|y| y.as_arr())
        .map(|arr| arr.iter().map(|y| y.as_f64().map(|y| y as f32)).collect())
        .unwrap_or_default();
    (ys, errs)
}

/// The `ccn route` front end: accept loop + health thread around a
/// shared [`Router`]. One synchronous thread per client connection
/// (read → route → write), reusing the serve transport's stream/liner
/// machinery — including the unix socket path lock.
pub struct RouterServer {
    router: Arc<Router>,
    stop: Arc<AtomicBool>,
    accept_join: Option<JoinHandle<()>>,
    health_join: Option<JoinHandle<()>>,
    conn_joins: Arc<Mutex<Vec<JoinHandle<()>>>>,
    /// Prometheus scrape endpoint (`--metrics-listen`), exposing the
    /// router's own registry.
    metrics: Option<MetricsServer>,
    local: String,
    unix_path: Option<PathBuf>,
    sock_lock: Option<SocketLock>,
}

impl RouterServer {
    pub fn bind(
        cfg: RouterConfig,
        listen: &ListenAddr,
    ) -> Result<RouterServer, String> {
        let max_conns = cfg.max_conns;
        let health_interval = cfg.health_interval;
        let metrics_listen = cfg.metrics_listen.clone();
        let router = Arc::new(Router::new(cfg)?);
        let metrics = match &metrics_listen {
            Some(addr) => Some(MetricsServer::bind(
                addr,
                Arc::clone(router.registry()),
            )?),
            None => None,
        };
        let (listener, local, sock_lock) = Listener::bind(listen)?;
        listener
            .set_nonblocking(true)
            .map_err(|e| format!("route: set nonblocking: {e}"))?;
        let stop = Arc::new(AtomicBool::new(false));
        let conn_joins = Arc::new(Mutex::new(Vec::new()));
        let active = Arc::new(AtomicUsize::new(0));
        let read_hist = router.obs.histogram("stage.transport_read");
        let accept_join = {
            let router = Arc::clone(&router);
            let stop = Arc::clone(&stop);
            let conn_joins = Arc::clone(&conn_joins);
            std::thread::spawn(move || {
                run_accept(
                    listener, router, stop, conn_joins, active, max_conns,
                    read_hist,
                )
            })
        };
        let health_join = {
            let router = Arc::clone(&router);
            let stop = Arc::clone(&stop);
            std::thread::spawn(move || {
                // probe immediately so dead-at-boot backends leave the
                // ring before the first client op; each tick then sleeps
                // a jittered 75%..125% of the configured interval so a
                // fleet of routers restarted in lockstep never probes
                // the same backends in phase (xorshift64, per-process
                // seed)
                let mut jstate: u64 =
                    0x9E37_79B9_7F4A_7C15 ^ u64::from(std::process::id());
                while !stop.load(Ordering::Relaxed) {
                    router.probe_all();
                    jstate ^= jstate << 13;
                    jstate ^= jstate >> 7;
                    jstate ^= jstate << 17;
                    let frac = (jstate >> 11) as f64 / (1u64 << 53) as f64;
                    let target = health_interval.mul_f64(0.75 + 0.5 * frac);
                    let mut slept = Duration::ZERO;
                    while slept < target && !stop.load(Ordering::Relaxed) {
                        std::thread::sleep(POLL_INTERVAL);
                        slept += POLL_INTERVAL;
                    }
                }
            })
        };
        Ok(RouterServer {
            router,
            stop,
            accept_join: Some(accept_join),
            health_join: Some(health_join),
            conn_joins,
            metrics,
            local,
            unix_path: match listen {
                ListenAddr::Unix(p) => Some(p.clone()),
                ListenAddr::Tcp(_) => None,
            },
            sock_lock,
        })
    }

    /// The bound endpoint (real port when 0 was requested).
    pub fn local_addr(&self) -> &str {
        &self.local
    }

    /// The routing core (tests/diagnostics drive it directly).
    pub fn router(&self) -> &Arc<Router> {
        &self.router
    }

    /// The metrics endpoint's bound address, when one was configured.
    pub fn metrics_addr(&self) -> Option<&str> {
        self.metrics.as_ref().map(|m| m.local_addr())
    }

    /// Stop accepting, join every thread, remove the unix socket + lock.
    pub fn shutdown(mut self) -> Result<(), String> {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(metrics) = self.metrics.take() {
            metrics.shutdown();
        }
        if let Some(join) = self.accept_join.take() {
            let _ = join.join();
        }
        if let Some(join) = self.health_join.take() {
            let _ = join.join();
        }
        let joins: Vec<JoinHandle<()>> = match self.conn_joins.lock() {
            Ok(mut j) => std::mem::take(&mut *j),
            Err(_) => Vec::new(),
        };
        for join in joins {
            let _ = join.join();
        }
        if let Some(path) = &self.unix_path {
            let _ = std::fs::remove_file(path);
        }
        drop(self.sock_lock.take());
        Ok(())
    }
}

#[allow(clippy::too_many_arguments)]
fn run_accept(
    listener: Listener,
    router: Arc<Router>,
    stop: Arc<AtomicBool>,
    conn_joins: Arc<Mutex<Vec<JoinHandle<()>>>>,
    active: Arc<AtomicUsize>,
    max_conns: usize,
    read_hist: Arc<Histogram>,
) {
    while !stop.load(Ordering::Relaxed) {
        let stream = match listener.accept() {
            Ok(s) => s,
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock
                        | std::io::ErrorKind::TimedOut
                ) =>
            {
                std::thread::sleep(POLL_INTERVAL);
                continue;
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(_) => {
                std::thread::sleep(POLL_INTERVAL);
                continue;
            }
        };
        let _ = stream.set_nonblocking(false);
        if max_conns > 0 && active.load(Ordering::Relaxed) >= max_conns {
            let mut s = stream;
            let _ = s.set_write_timeout(Some(WRITE_TIMEOUT));
            let reply =
                error_line(format!("server is at --max-conns ({max_conns})"));
            let _ = writeln!(s, "{reply}");
            let _ = s.flush();
            s.shutdown();
            continue;
        }
        active.fetch_add(1, Ordering::Relaxed);
        let join = {
            let router = Arc::clone(&router);
            let stop = Arc::clone(&stop);
            let active = Arc::clone(&active);
            let read_hist = Arc::clone(&read_hist);
            std::thread::spawn(move || {
                run_conn(stream, router, stop, read_hist);
                active.fetch_sub(1, Ordering::Relaxed);
            })
        };
        if let Ok(mut joins) = conn_joins.lock() {
            joins.retain(|j| !j.is_finished());
            joins.push(join);
        }
    }
}

/// One synchronous client connection: read a line, route it, write the
/// reply. The per-connection backend socket map lives here, so requests
/// from one client stay ordered on every backend they touch.
fn run_conn(
    stream: Stream,
    router: Arc<Router>,
    stop: Arc<AtomicBool>,
    read_hist: Arc<Histogram>,
) {
    let _ = stream.set_read_timeout(Some(POLL_INTERVAL));
    let write_half = match stream.try_clone() {
        Ok(s) => s,
        Err(_) => {
            stream.shutdown();
            return;
        }
    };
    let _ = write_half.set_write_timeout(Some(WRITE_TIMEOUT));
    let mut reader = BufReader::new(stream);
    let mut out = BufWriter::new(write_half);
    let mut conns: HashMap<usize, WireClient> = HashMap::new();
    let mut buf: Vec<u8> = Vec::new();
    loop {
        if stop.load(Ordering::Relaxed) {
            break;
        }
        buf.clear();
        let reply = match read_line_bytes(
            &mut reader,
            &mut buf,
            &stop,
            MAX_LINE_BYTES,
            &read_hist,
        ) {
            Ok(LineRead::Line) => match std::str::from_utf8(&buf) {
                Err(_) => error_line("request line is not valid utf-8"),
                Ok(text) => {
                    let line = text.trim();
                    if line.is_empty() {
                        continue;
                    }
                    router.handle_line(line, &mut conns)
                }
            },
            Ok(LineRead::TooLong) => error_line(format!(
                "request line exceeds {MAX_LINE_BYTES} bytes"
            )),
            Ok(LineRead::Eof) | Err(_) => break,
        };
        if writeln!(out, "{reply}").and_then(|()| out.flush()).is_err() {
            break;
        }
    }
    if let Ok(inner) = out.into_inner() {
        inner.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::{Server, Service};
    use crate::store::StoreConfig;

    fn fast_cfg(backends: Vec<ListenAddr>) -> RouterConfig {
        let mut cfg = RouterConfig::new(backends);
        cfg.client = ClientConfig {
            connect_timeout: Duration::from_millis(250),
            retries: 0,
            backoff: Duration::from_millis(1),
            ..ClientConfig::default()
        };
        cfg.health_interval = Duration::from_millis(100);
        cfg
    }

    fn backend(shards: usize) -> (Server, ListenAddr) {
        let server = Server::bind(
            Service::new(shards),
            &ListenAddr::parse("tcp://127.0.0.1:0").unwrap(),
            0,
        )
        .unwrap();
        let addr = ListenAddr::parse(server.local_addr()).unwrap();
        (server, addr)
    }

    #[test]
    fn single_backend_routing_is_byte_transparent() {
        let (server, addr) = backend(2);
        let router = Router::new(fast_cfg(vec![addr.clone()])).unwrap();
        let mut conns = HashMap::new();
        let mut direct =
            WireClient::new(addr, ClientConfig::default());
        // deterministic request sequence, including error paths
        let open =
            r#"{"op":"open","learner":"columnar:4","n_inputs":3,"seed":5}"#;
        let via_router = router.handle_line(open, &mut conns);
        // the direct twin runs on a twin service; to compare bytes we
        // replay the SAME session through both paths on the one backend:
        // every reply the router returns must equal a raw client's
        let seq = [
            r#"{"op":"step","id":1,"x":[0.5,-0.25,0.125],"c":0.5}"#,
            r#"{"op":"predict","id":1,"x":[0.5,-0.25,0.125]}"#,
            r#"{"op":"snapshot","id":1}"#,
            r#"{"op":"step","id":77,"x":[0.1],"c":0.0}"#, // ghost id
            r#"{"op":"nonsense"}"#,                       // unknown op
            r#"{not json"#,                               // parse error
            r#"{"op":"ping"}"#,
        ];
        assert!(via_router.contains(r#""id":1"#), "{via_router}");
        for line in seq {
            let via = router.handle_line(line, &mut conns);
            let raw = match direct.request_line(line) {
                Ok(r) => r,
                // raw parse errors close nothing; client stays usable
                Err(e) => panic!("direct send failed: {e}"),
            };
            assert_eq!(via, raw, "router not transparent for {line}");
        }
        server.shutdown().unwrap();
    }

    #[test]
    fn inject_correlation_splices_only_missing_keys() {
        let spliced = inject_correlation(
            r#"{"op":"step","id":1,"x":[0.5],"c":0.0}"#,
            &[("trace_id", "abc123"), ("span_id", "def456")],
        );
        let v = Json::parse(&spliced).expect("spliced line stays valid JSON");
        assert_eq!(v.get("trace_id").and_then(|t| t.as_str()), Some("abc123"));
        assert_eq!(v.get("span_id").and_then(|s| s.as_str()), Some("def456"));
        assert_eq!(v.get("op").and_then(|o| o.as_str()), Some("step"));
        assert_eq!(v.get("id").and_then(|i| i.as_f64()), Some(1.0));
        // nothing to add → the line passes through byte-identically
        let same = inject_correlation(r#"{"op":"ping"}"#, &[]);
        assert_eq!(same, r#"{"op":"ping"}"#);
    }

    #[test]
    fn traced_routing_is_byte_identical_and_events_correlate() {
        let (server, addr) = backend(1);
        let nanos = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .unwrap()
            .as_nanos();
        let trace_path = std::env::temp_dir().join(format!(
            "ccn_route_trace_{}_{nanos}.jsonl",
            std::process::id()
        ));
        let mut cfg = fast_cfg(vec![addr.clone()]);
        cfg.trace = Some(TraceConfig {
            path: trace_path.clone(),
            sample: 1,
        });
        let traced = Router::new(cfg).unwrap();
        let plain = Router::new(fast_cfg(vec![addr])).unwrap();
        let mut tc = HashMap::new();
        let mut pc = HashMap::new();
        // two twin sessions on the one backend: session 1 via the traced
        // router, session 2 via the untraced one, same spec and inputs
        let open =
            r#"{"op":"open","learner":"columnar:4","n_inputs":2,"seed":11}"#;
        let o1 = traced.handle_line(open, &mut tc);
        let o2 = plain.handle_line(open, &mut pc);
        assert!(o1.contains(r#""id":1"#), "{o1}");
        assert!(o2.contains(r#""id":2"#), "{o2}");
        for tick in 0..5 {
            let x = 0.1 * tick as f64;
            let t = traced.handle_line(
                &format!(r#"{{"op":"step","id":1,"x":[{x},0.5],"c":0.25}}"#),
                &mut tc,
            );
            let p = plain.handle_line(
                &format!(r#"{{"op":"step","id":2,"x":[{x},0.5],"c":0.25}}"#),
                &mut pc,
            );
            // identical computation → identical y: tracing and
            // correlation injection change nothing downstream
            let ty = Json::parse(&t).unwrap().get("y").cloned();
            let py = Json::parse(&p).unwrap().get("y").cloned();
            assert_eq!(ty, py, "traced reply diverged at tick {tick}");
        }
        // a client-supplied trace id is reused, not replaced
        let reply = traced.handle_line(
            r#"{"op":"snapshot","id":1,"trace_id":"client-supplied-1"}"#,
            &mut tc,
        );
        assert!(reply.contains(r#""ok":true"#), "{reply}");
        drop(traced); // flush + join the trace writer
        let body = std::fs::read_to_string(&trace_path).unwrap();
        let events: Vec<Json> =
            body.lines().map(|l| Json::parse(l).unwrap()).collect();
        assert_eq!(events.len(), 7, "sample=1 logs every op:\n{body}");
        for ev in &events {
            assert!(ev.get("trace_id").is_some(), "{ev:?}");
            assert!(ev.get("span_id").is_some(), "{ev:?}");
            assert!(ev.get("dur_ns").is_some(), "{ev:?}");
            assert_eq!(ev.get("ok"), Some(&Json::Bool(true)), "{ev:?}");
        }
        let last = events.last().unwrap();
        assert_eq!(
            last.get("trace_id").and_then(|t| t.as_str()),
            Some("client-supplied-1")
        );
        assert!(
            last.get("backend").and_then(|b| b.as_str()).is_some(),
            "forwarded op records its backend: {last:?}"
        );
        let _ = std::fs::remove_file(&trace_path);
        server.shutdown().unwrap();
    }

    #[test]
    fn handoff_moves_a_live_session_and_steps_continue() {
        let (s1, a1) = backend(1);
        let (s2, a2) = backend(1);
        let router =
            Router::new(fast_cfg(vec![a1.clone(), a2.clone()])).unwrap();
        let mut conns = HashMap::new();
        // both backends mint disjoint ids in a real deployment; here we
        // only need one session, opened via the router
        let open =
            r#"{"op":"open","learner":"ccn:4:2:1000","n_inputs":3,"seed":9}"#;
        let reply = router.handle_line(open, &mut conns);
        let id = Json::parse(&reply)
            .unwrap()
            .get("id")
            .and_then(|i| i.as_f64())
            .unwrap() as u64;
        let source = router.placement_of(id).unwrap();
        let dest = 1 - source;
        let step = format!(
            r#"{{"op":"step","id":{id},"x":[0.2,0.1,-0.3],"c":0.25}}"#
        );
        let y1 = router.handle_line(&step, &mut conns);
        assert!(y1.contains(r#""ok":true"#), "{y1}");
        let handoff = format!(
            r#"{{"op":"handoff","id":{id},"to":"{}"}}"#,
            router.backends[dest].label
        );
        let moved = router.handle_line(&handoff, &mut conns);
        assert!(moved.contains(r#""ok":true"#), "{moved}");
        assert_eq!(router.placement_of(id), Some(dest));
        let y2 = router.handle_line(&step, &mut conns);
        assert!(y2.contains(r#""ok":true"#), "{y2}");
        // the source no longer owns the id
        let mut direct = WireClient::new(
            if source == 0 { a1 } else { a2 },
            ClientConfig::default(),
        );
        let on_source = direct.request_line(&step).unwrap();
        assert!(on_source.contains("no session"), "{on_source}");
        // health + stats carry the cluster view
        let health = router.handle_line(r#"{"op":"health"}"#, &mut conns);
        assert!(health.contains(r#""ok":true"#), "{health}");
        let stats = router.handle_line(r#"{"op":"stats"}"#, &mut conns);
        let v = Json::parse(&stats).unwrap();
        assert!(v.get("cluster").is_some(), "{stats}");
        assert_eq!(
            v.get("sessions").and_then(|s| s.as_f64()),
            Some(1.0),
            "exactly the migrated session remains: {stats}"
        );
        s1.shutdown().unwrap();
        s2.shutdown().unwrap();
    }

    fn store_backend(tag: &str) -> (Server, ListenAddr, std::path::PathBuf) {
        let nanos = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .unwrap()
            .as_nanos();
        let dir = std::env::temp_dir().join(format!(
            "ccn_router_{tag}_{}_{nanos}",
            std::process::id()
        ));
        let svc = Service::with_store(1, Some(StoreConfig::new(&dir, 0)))
            .expect("store-backed service boots");
        let server = Server::bind(
            svc,
            &ListenAddr::parse("tcp://127.0.0.1:0").unwrap(),
            0,
        )
        .unwrap();
        let addr = ListenAddr::parse(server.local_addr()).unwrap();
        (server, addr, dir)
    }

    fn opened_id(reply: &str) -> u64 {
        Json::parse(reply)
            .unwrap()
            .get("id")
            .and_then(|i| i.as_f64())
            .expect("open reply carries an id") as u64
    }

    fn y_of(reply: &str) -> Json {
        Json::parse(reply)
            .unwrap_or_else(|e| panic!("unparseable reply {reply}: {e}"))
            .get("y")
            .cloned()
            .unwrap_or_else(|| panic!("reply has no y: {reply}"))
    }

    #[test]
    fn killed_home_promotes_the_warm_standby_bit_exact() {
        let (s1, a1, d1) = store_backend("promo_a");
        let (s2, a2, d2) = store_backend("promo_b");
        let mut cfg = fast_cfg(vec![a1.clone(), a2.clone()]);
        cfg.replicate_every = 1; // zero acked-loss window
        let router = Router::new(cfg).unwrap();
        let mut conns = HashMap::new();
        let open =
            r#"{"op":"open","learner":"columnar:4","n_inputs":2,"seed":3}"#;
        let id = opened_id(&router.handle_line(open, &mut conns));
        let home = router.placement_of(id).unwrap();
        let standby_addr = if home == 0 { a2 } else { a1 };
        // acked soak: with K=1, every reply means the standby has the
        // state up to and including that step
        let mut acked: Vec<(String, Json)> = Vec::new();
        for t in 0..7 {
            let x = 0.1 * t as f64 - 0.2;
            let line = format!(
                r#"{{"op":"step","id":{id},"x":[{x},0.5],"c":0.25}}"#
            );
            let reply = router.handle_line(&line, &mut conns);
            assert!(reply.contains(r#""ok":true"#), "{reply}");
            acked.push((line, y_of(&reply)));
        }
        assert_eq!(
            router.repl_lag.load(Ordering::Relaxed),
            0,
            "K=1 leaves no acked op unshipped"
        );
        assert!(router.replicated.load(Ordering::Relaxed) >= 8);
        let mut servers = [Some(s1), Some(s2)];
        servers[home].take().unwrap().shutdown().unwrap();
        // the next routed op finds the dead pin and promotes the standby
        let line =
            format!(r#"{{"op":"step","id":{id},"x":[0.7,0.5],"c":0.25}}"#);
        let reply = router.handle_line(&line, &mut conns);
        assert!(reply.contains(r#""ok":true"#), "{reply}");
        let y8 = y_of(&reply);
        assert_eq!(router.placement_of(id), Some(1 - home));
        assert_eq!(router.promotions.load(Ordering::Relaxed), 1);
        // bit-exact: a twin on the survivor replays the acked history
        let mut direct =
            WireClient::new(standby_addr, ClientConfig::default());
        let twin = opened_id(&direct.request_line(open).unwrap());
        for (line, y) in &acked {
            let tl = line.replace(
                &format!(r#""id":{id}"#),
                &format!(r#""id":{twin}"#),
            );
            let ty = y_of(&direct.request_line(&tl).unwrap());
            assert_eq!(&ty, y, "twin diverged on {line}");
        }
        let tl =
            format!(r#"{{"op":"step","id":{twin},"x":[0.7,0.5],"c":0.25}}"#);
        let ty = y_of(&direct.request_line(&tl).unwrap());
        assert_eq!(ty, y8, "post-promotion step diverged from the twin");
        servers[1 - home].take().unwrap().shutdown().unwrap();
        let _ = std::fs::remove_dir_all(&d1);
        let _ = std::fs::remove_dir_all(&d2);
    }

    #[test]
    fn promotion_races_a_late_reply_single_winner_no_double_run() {
        let (s1, a1, d1) = store_backend("race_a");
        let (s2, a2, d2) = store_backend("race_b");
        let mut cfg = fast_cfg(vec![a1.clone(), a2.clone()]);
        cfg.replicate_every = 1;
        let router = Arc::new(Router::new(cfg).unwrap());
        let mut conns = HashMap::new();
        let open =
            r#"{"op":"open","learner":"columnar:4","n_inputs":1,"seed":9}"#;
        let id = opened_id(&router.handle_line(open, &mut conns));
        let home = router.placement_of(id).unwrap();
        let survivor_addr = if home == 0 { a2 } else { a1 };
        let mut acked: Vec<Json> = Vec::new();
        for t in 0..5 {
            let x = 0.2 * t as f64;
            let reply = router.handle_line(
                &format!(r#"{{"op":"step","id":{id},"x":[{x}],"c":0.5}}"#),
                &mut conns,
            );
            assert!(reply.contains(r#""ok":true"#), "{reply}");
            acked.push(y_of(&reply));
        }
        let mut servers = [Some(s1), Some(s2)];
        servers[home].take().unwrap().shutdown().unwrap();
        // two racers: a routed op that discovers the dead pin, and an
        // operator-forced promote. The per-id gate admits exactly one
        // promotion; the loser re-checks the table and rides the winner.
        let threads: Vec<_> = (0..2)
            .map(|i| {
                let router = Arc::clone(&router);
                std::thread::spawn(move || {
                    let mut conns = HashMap::new();
                    let line = if i == 0 {
                        format!(r#"{{"op":"predict","id":{id},"x":[0.3]}}"#)
                    } else {
                        format!(r#"{{"op":"promote","id":{id}}}"#)
                    };
                    router.handle_line(&line, &mut conns)
                })
            })
            .collect();
        for (i, t) in threads.into_iter().enumerate() {
            let reply = t.join().unwrap();
            if i == 0 {
                // the routed op always lands: it either wins the
                // promotion or retries onto the winner's re-pin
                assert!(reply.contains(r#""ok":true"#), "{reply}");
            } else {
                // the operator promote either wins/rides the promotion,
                // or — having read the table after the winner re-pinned
                // — correctly refuses to promote away from a live home
                assert!(
                    reply.contains(r#""ok":true"#)
                        || reply.contains("alive"),
                    "{reply}"
                );
            }
        }
        assert_eq!(
            router.promotions.load(Ordering::Relaxed),
            1,
            "exactly one promotion despite two racers"
        );
        assert_eq!(router.placement_of(id), Some(1 - home));
        // nothing ran twice: the next step matches a twin that replayed
        // exactly the acked prefix
        let reply = router.handle_line(
            &format!(r#"{{"op":"step","id":{id},"x":[0.9],"c":0.5}}"#),
            &mut conns,
        );
        let y = y_of(&reply);
        let mut direct =
            WireClient::new(survivor_addr, ClientConfig::default());
        let twin = opened_id(&direct.request_line(open).unwrap());
        for (t, want) in acked.iter().enumerate() {
            let x = 0.2 * t as f64;
            let r = direct
                .request_line(&format!(
                    r#"{{"op":"step","id":{twin},"x":[{x}],"c":0.5}}"#
                ))
                .unwrap();
            assert_eq!(&y_of(&r), want, "twin diverged at acked step {t}");
        }
        let r = direct
            .request_line(&format!(
                r#"{{"op":"step","id":{twin},"x":[0.9],"c":0.5}}"#
            ))
            .unwrap();
        assert_eq!(y_of(&r), y, "post-race step diverged — a double run");
        servers[1 - home].take().unwrap().shutdown().unwrap();
        let _ = std::fs::remove_dir_all(&d1);
        let _ = std::fs::remove_dir_all(&d2);
    }

    #[test]
    fn dead_backend_rejoins_on_probe_while_traffic_flows() {
        let (s1, a1) = backend(1);
        let (s2, a2) = backend(1);
        let router = Arc::new(Router::new(fast_cfg(vec![a1, a2])).unwrap());
        let mut conns = HashMap::new();
        let open =
            r#"{"op":"open","learner":"columnar:4","n_inputs":1,"seed":2}"#;
        let id = opened_id(&router.handle_line(open, &mut conns));
        let home = router.placement_of(id).unwrap();
        let victim = 1 - home;
        // a partition: the router believes the victim is gone
        router.backends[victim].alive.store(false, Ordering::Relaxed);
        router.backends[victim].in_ring.store(false, Ordering::Relaxed);
        // live traffic against the surviving home while the victim is out
        let stepper = {
            let router = Arc::clone(&router);
            std::thread::spawn(move || {
                let mut conns = HashMap::new();
                let mut oks = 0;
                for t in 0..50 {
                    let x = 0.01 * t as f64;
                    let reply = router.handle_line(
                        &format!(
                            r#"{{"op":"step","id":{id},"x":[{x}],"c":0.5}}"#
                        ),
                        &mut conns,
                    );
                    if reply.contains(r#""ok":true"#) {
                        oks += 1;
                    }
                }
                oks
            })
        };
        // mid-traffic, the probe finds the victim answering again:
        // dead→alive restores ring membership
        router.probe_all();
        assert!(router.alive(victim), "probe revives the victim");
        assert!(
            router.backends[victim].in_ring.load(Ordering::Relaxed),
            "dead→alive restores ring membership"
        );
        assert_eq!(stepper.join().unwrap(), 50, "traffic never faltered");
        // fresh placements can land on the rejoined backend again
        let mut placed_on_victim = false;
        for _ in 0..64 {
            let reply = router.handle_line(open, &mut conns);
            assert!(reply.contains(r#""ok":true"#), "{reply}");
            if router.placement_of(opened_id(&reply)) == Some(victim) {
                placed_on_victim = true;
                break;
            }
        }
        assert!(placed_on_victim, "rejoined backend takes placements");
        s1.shutdown().unwrap();
        s2.shutdown().unwrap();
    }

    #[test]
    fn dead_pin_without_replication_fails_loudly_not_silently() {
        let (s1, a1) = backend(1);
        let (s2, a2) = backend(1);
        let router = Router::new(fast_cfg(vec![a1, a2])).unwrap();
        let mut conns = HashMap::new();
        let open =
            r#"{"op":"open","learner":"columnar:4","n_inputs":1,"seed":4}"#;
        let id = opened_id(&router.handle_line(open, &mut conns));
        let home = router.placement_of(id).unwrap();
        let mut servers = [Some(s1), Some(s2)];
        servers[home].take().unwrap().shutdown().unwrap();
        let reply = router.handle_line(
            &format!(r#"{{"op":"step","id":{id},"x":[0.1],"c":0.5}}"#),
            &mut conns,
        );
        assert!(reply.contains(r#""ok":false"#), "{reply}");
        assert!(reply.contains("unreachable"), "{reply}");
        assert_eq!(router.promotions.load(Ordering::Relaxed), 0);
        servers[1 - home].take().unwrap().shutdown().unwrap();
    }
}
