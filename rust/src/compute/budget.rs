//! Appendix-A operation-count equations and budget allocation.
//!
//! Notation (paper): |h| = hidden features d, |x| = input features n,
//! k = truncation window, u = features-per-stage.
//!
//! - LSTM cell forward (per feature):            4|h| + 4|x| + 4
//! - Fully connected LSTM forward:               4|h|^2 + 4|h||x| + 4|h|
//! - T-BPTT total:                 (k + 1) (4|h|^2 + 4|h||x| + 4|h|)
//! - Columnar cell forward:                      4|x| + 8  (hidden = 1)
//! - Columnar total (learning = 6x forward):     7 |h| (4|x| + 8)
//! - CCN forward (avg fan-in |h|/2 hidden):      |h| (2|h| + 4|x| + 4)
//! - CCN total:     |h|(2|h|+4|x|+4) + 6u(2|h|+4|x|+4)
//! - Constructive = CCN with u = 1.

/// Per-step ops for one forward pass of a fully connected LSTM.
pub fn lstm_forward_ops(d: u64, n: u64) -> u64 {
    4 * d * d + 4 * d * n + 4 * d
}

/// Per-step ops of T-BPTT with truncation k (forward + k-step backward).
pub fn tbptt_ops(d: u64, n: u64, k: u64) -> u64 {
    (k + 1) * lstm_forward_ops(d, n)
}

/// Per-step ops of a columnar network with d columns over n inputs.
/// RTRL bookkeeping is budgeted at 6x the forward cost (Appendix A).
pub fn columnar_ops(d: u64, n: u64) -> u64 {
    d * (4 * n + 8) + 6 * d * (4 * n + 8)
}

/// Per-step ops of a CCN with d total features, n raw inputs, and u
/// features learned per stage (average hidden fan-in d/2).
pub fn ccn_ops(d: u64, n: u64, u: u64) -> u64 {
    let cell = 2 * d + 4 * n + 4;
    d * cell + 6 * u * cell
}

/// Constructive network = CCN learning one feature per stage.
pub fn constructive_ops(d: u64, n: u64) -> u64 {
    ccn_ops(d, n, 1)
}

/// Largest d such that tbptt_ops(d, n, k) <= budget (0 if none).
pub fn tbptt_features_for_budget(budget: u64, n: u64, k: u64) -> u64 {
    let mut d = 0;
    while tbptt_ops(d + 1, n, k) <= budget {
        d += 1;
    }
    d
}

/// Largest column count within budget for a columnar network.
pub fn columnar_features_for_budget(budget: u64, n: u64) -> u64 {
    let per = 7 * (4 * n + 8);
    budget / per
}

/// Largest total features within budget for a CCN with u per stage.
pub fn ccn_features_for_budget(budget: u64, n: u64, u: u64) -> u64 {
    let mut d = 0;
    while ccn_ops(d + u, n, u) <= budget {
        d += u;
    }
    d
}

/// The k:d pairs the paper sweeps for T-BPTT on trace patterning
/// (Table 1): 2:13, 3:10, 5:8, 8:6, 10:5, 15:4, 20:3, 30:2.
pub const TRACE_TBPTT_PAIRS: [(u64, u64); 8] = [
    (2, 13),
    (3, 10),
    (5, 8),
    (8, 6),
    (10, 5),
    (15, 4),
    (20, 3),
    (30, 2),
];

/// The k:d pairs for the Atari benchmark (Table 1): 15:2, 8:5, 5:8,
/// 4:10, 2:25.
pub const ATARI_TBPTT_PAIRS: [(u64, u64); 5] = [(15, 2), (8, 5), (5, 8), (4, 10), (2, 25)];

#[cfg(test)]
mod tests {
    use super::*;

    /// Trace patterning: n = 7 inputs, budget ~ 4,000 ops (Section 4.1).
    const TRACE_N: u64 = 7;
    const TRACE_BUDGET: u64 = 4_000;

    /// Atari: n = 277 inputs, budget ~ 50,000 ops (Section 5.2).
    const ATARI_N: u64 = 277;
    const ATARI_BUDGET: u64 = 50_000;

    #[test]
    fn lstm_forward_matches_formula() {
        assert_eq!(lstm_forward_ops(2, 7), 4 * 4 + 4 * 2 * 7 + 8);
        assert_eq!(lstm_forward_ops(1, 1), 4 + 4 + 4);
    }

    #[test]
    fn paper_trace_tbptt_pairs_fit_budget() {
        // Every Table-1 k:d pair must land near (and not wildly above) the
        // ~4k budget; the paper says "approximately" so allow 25% slack.
        for &(k, d) in &TRACE_TBPTT_PAIRS {
            let ops = tbptt_ops(d, TRACE_N, k);
            assert!(
                ops <= TRACE_BUDGET * 5 / 4,
                "k={k} d={d}: {ops} ops exceeds trace budget"
            );
            // and must be within reach of the budget (not trivially small)
            assert!(ops >= TRACE_BUDGET / 4, "k={k} d={d}: {ops} too small");
        }
    }

    #[test]
    fn paper_atari_tbptt_pairs_fit_budget() {
        // The paper's own Table-1 Atari pairs span ~36k..91k ops by its
        // Appendix-A estimate ("approximately 50k"); assert every pair is
        // in that sanctioned band rather than exactly on budget.
        for &(k, d) in &ATARI_TBPTT_PAIRS {
            let ops = tbptt_ops(d, ATARI_N, k);
            assert!(
                ops <= ATARI_BUDGET * 2,
                "k={k} d={d}: {ops} ops exceeds atari budget band"
            );
            assert!(ops >= ATARI_BUDGET / 4, "k={k} d={d}: {ops} too small");
        }
    }

    #[test]
    fn columnar_trace_config_fits() {
        // Paper: columnar uses 5 features on trace patterning.
        let ops = columnar_ops(5, TRACE_N);
        assert!(ops <= TRACE_BUDGET, "columnar 5x7: {ops}");
        // and 7 features on atari within ~50k (the estimate lands ~9% over
        // the nominal budget — the paper's "approximately").
        let ops_atari = columnar_ops(7, ATARI_N);
        assert!(
            ops_atari <= ATARI_BUDGET * 5 / 4,
            "columnar 7x277: {ops_atari}"
        );
    }

    #[test]
    fn ccn_trace_config_fits() {
        // Paper: CCN has 20 features, 4 per stage on trace patterning.
        let ops = ccn_ops(20, TRACE_N, 4);
        assert!(ops <= TRACE_BUDGET, "ccn 20/4 trace: {ops}");
        // Atari: CCN 5 features/stage; total features grows to ~15.
        let ops_atari = ccn_ops(15, ATARI_N, 5);
        assert!(
            ops_atari <= ATARI_BUDGET * 5 / 4,
            "ccn 15/5 atari: {ops_atari}"
        );
    }

    #[test]
    fn constructive_is_ccn_u1() {
        assert_eq!(constructive_ops(10, 7), ccn_ops(10, 7, 1));
    }

    #[test]
    fn budget_inversion_consistent() {
        for &(k, _) in &TRACE_TBPTT_PAIRS {
            let d = tbptt_features_for_budget(TRACE_BUDGET * 5 / 4, TRACE_N, k);
            assert!(d >= 1);
            assert!(tbptt_ops(d, TRACE_N, k) <= TRACE_BUDGET * 5 / 4);
            assert!(tbptt_ops(d + 1, TRACE_N, k) > TRACE_BUDGET * 5 / 4);
        }
        let d = columnar_features_for_budget(TRACE_BUDGET, TRACE_N);
        assert!(columnar_ops(d, TRACE_N) <= TRACE_BUDGET);
        assert!(columnar_ops(d + 1, TRACE_N) > TRACE_BUDGET);
        let d = ccn_features_for_budget(TRACE_BUDGET, TRACE_N, 4);
        assert!(ccn_ops(d, TRACE_N, 4) <= TRACE_BUDGET);
    }

    #[test]
    fn tbptt_monotone_in_k_and_d() {
        assert!(tbptt_ops(5, 7, 10) < tbptt_ops(5, 7, 20));
        assert!(tbptt_ops(5, 7, 10) < tbptt_ops(6, 7, 10));
    }

    #[test]
    fn fig6_compute_ratio() {
        // Fig 6 caption: k=20 is ten times the compute of k=2 (same d=10).
        let r = tbptt_ops(10, 7, 20) as f64 / tbptt_ops(10, 7, 2) as f64;
        assert!((r - 7.0).abs() < 1.0, "ratio {r}"); // (21/3 = 7x by formula)
    }
}
