//! Per-step compute accounting (paper Appendix A).
//!
//! The paper's central experimental control is a fixed per-step floating
//! point operation budget shared by all learners; the truncation/width
//! trade-off of Figures 4–5 and the Atari configurations all come from
//! these equations. We implement them exactly and use them both to choose
//! configurations and to assert (in tests/benches) that measured operation
//! counts track the estimates.

pub mod budget;

pub use budget::*;
