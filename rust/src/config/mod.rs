//! Experiment configuration: typed specs, JSON round-trip, CLI overrides,
//! and the paper's Table-1 presets.

use crate::env::synthatari;
use crate::env::trace_conditioning::{TraceConditioning, TraceConditioningConfig};
use crate::env::trace_patterning::{TracePatterning, TracePatterningConfig};
use crate::env::{cycle_world::CycleWorld, Stream};
use crate::learn::{TdConfig, TdLambdaAgent};
use crate::nets::ccn::{CcnConfig, CcnNet};
use crate::nets::normalizer::NORM_BETA;
use crate::nets::snap1::Snap1Net;
use crate::nets::tbptt::TbpttNet;
use crate::nets::ServableNet;
use crate::util::json::Json;

/// A configuration the rest of the system cannot act on. Carried as a
/// typed error (not a panic) so the CLI and the serve protocol can report
/// it to the caller.
#[derive(Clone, Debug, PartialEq)]
pub enum ConfigError {
    UnknownGame(String),
    BadLearnerSpec(String),
    UnsupportedLearner { learner: String, context: String },
}

impl std::fmt::Display for ConfigError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ConfigError::UnknownGame(game) => write!(
                f,
                "unknown game '{game}' (available: {})",
                synthatari::env_names().join(", ")
            ),
            ConfigError::BadLearnerSpec(spec) => write!(
                f,
                "bad learner spec '{spec}' (columnar:D | \
                 constructive:TOTAL:STEPS_PER_STAGE | \
                 ccn:TOTAL:PER_STAGE:STEPS_PER_STAGE | tbptt:D:K | snap1:D)"
            ),
            ConfigError::UnsupportedLearner { learner, context } => {
                write!(f, "learner '{learner}' is not supported by {context}")
            }
        }
    }
}

impl std::error::Error for ConfigError {}

/// Which network/learning algorithm to run.
#[derive(Clone, Debug, PartialEq)]
pub enum LearnerKind {
    /// d independent columns, learned forever (Section 3.1).
    Columnar { d: usize },
    /// grow one feature per stage (Section 3.2).
    Constructive { total: usize, steps_per_stage: u64 },
    /// the full CCN (Section 3.3).
    Ccn {
        total: usize,
        per_stage: usize,
        steps_per_stage: u64,
    },
    /// fully connected LSTM + truncated BPTT (the baseline).
    Tbptt { d: usize, k: usize },
    /// SnAp-1 diagonal RTRL on a dense LSTM (related-work baseline).
    Snap1 { d: usize },
}

impl LearnerKind {
    /// Parse a CLI/protocol spec string, e.g. `columnar:8` or
    /// `ccn:20:4:100000` (the inverse of nothing in particular — labels
    /// use `_`, specs use `:`).
    pub fn parse(spec: &str) -> Result<LearnerKind, ConfigError> {
        let parts: Vec<&str> = spec.split(':').collect();
        let bad = || ConfigError::BadLearnerSpec(spec.to_string());
        let usize_at = |i: usize| -> Result<usize, ConfigError> {
            parts.get(i).and_then(|s| s.parse().ok()).ok_or_else(bad)
        };
        let u64_at = |i: usize| -> Result<u64, ConfigError> {
            parts.get(i).and_then(|s| s.parse().ok()).ok_or_else(bad)
        };
        match parts[0] {
            "columnar" => Ok(LearnerKind::Columnar { d: usize_at(1)? }),
            "constructive" => Ok(LearnerKind::Constructive {
                total: usize_at(1)?,
                steps_per_stage: u64_at(2)?,
            }),
            "ccn" => Ok(LearnerKind::Ccn {
                total: usize_at(1)?,
                per_stage: usize_at(2)?,
                steps_per_stage: u64_at(3)?,
            }),
            "tbptt" => Ok(LearnerKind::Tbptt {
                d: usize_at(1)?,
                k: usize_at(2)?,
            }),
            "snap1" => Ok(LearnerKind::Snap1 { d: usize_at(1)? }),
            _ => Err(bad()),
        }
    }

    /// The stable net-kind tag of this learner spec, always in the same
    /// [`crate::nets::NetRegistry`] *family* as the built net's
    /// `PersistableNet::kind`. The two tags are usually equal, but a
    /// degenerate spec can build a net that self-reports a sibling
    /// corner of its family (e.g. `ccn:T:1:S` builds a net whose
    /// `kind()` is `constructive`); snapshot restore only requires
    /// family equality, so both tags restore interchangeably.
    pub fn kind(&self) -> &'static str {
        match self {
            LearnerKind::Columnar { .. } => "columnar",
            LearnerKind::Constructive { .. } => "constructive",
            LearnerKind::Ccn { .. } => "ccn",
            LearnerKind::Tbptt { .. } => "tbptt",
            LearnerKind::Snap1 { .. } => "snap1",
        }
    }

    pub fn to_json(&self) -> Json {
        match self {
            LearnerKind::Columnar { d } => Json::obj(vec![
                ("kind", Json::Str("columnar".into())),
                ("d", Json::Num(*d as f64)),
            ]),
            LearnerKind::Constructive {
                total,
                steps_per_stage,
            } => Json::obj(vec![
                ("kind", Json::Str("constructive".into())),
                ("total", Json::Num(*total as f64)),
                ("steps_per_stage", Json::Num(*steps_per_stage as f64)),
            ]),
            LearnerKind::Ccn {
                total,
                per_stage,
                steps_per_stage,
            } => Json::obj(vec![
                ("kind", Json::Str("ccn".into())),
                ("total", Json::Num(*total as f64)),
                ("per_stage", Json::Num(*per_stage as f64)),
                ("steps_per_stage", Json::Num(*steps_per_stage as f64)),
            ]),
            LearnerKind::Tbptt { d, k } => Json::obj(vec![
                ("kind", Json::Str("tbptt".into())),
                ("d", Json::Num(*d as f64)),
                ("k", Json::Num(*k as f64)),
            ]),
            LearnerKind::Snap1 { d } => Json::obj(vec![
                ("kind", Json::Str("snap1".into())),
                ("d", Json::Num(*d as f64)),
            ]),
        }
    }

    pub fn from_json(l: &Json) -> Option<LearnerKind> {
        Some(match l.get("kind")?.as_str()? {
            "columnar" => LearnerKind::Columnar {
                d: l.get("d")?.as_usize()?,
            },
            "constructive" => LearnerKind::Constructive {
                total: l.get("total")?.as_usize()?,
                steps_per_stage: l.get("steps_per_stage")?.as_f64()? as u64,
            },
            "ccn" => LearnerKind::Ccn {
                total: l.get("total")?.as_usize()?,
                per_stage: l.get("per_stage")?.as_usize()?,
                steps_per_stage: l.get("steps_per_stage")?.as_f64()? as u64,
            },
            "tbptt" => LearnerKind::Tbptt {
                d: l.get("d")?.as_usize()?,
                k: l.get("k")?.as_usize()?,
            },
            "snap1" => LearnerKind::Snap1 {
                d: l.get("d")?.as_usize()?,
            },
            _ => return None,
        })
    }

    /// True for the CCN family (columnar/constructive/ccn) — the kinds
    /// that share [`crate::nets::ccn::CcnNet`]'s snapshot format; false
    /// for the dense baselines (tbptt/snap1). All five kinds are
    /// serveable; v1 snapshot envelopes covered only this family.
    pub fn is_ccn_family(&self) -> bool {
        !matches!(
            self,
            LearnerKind::Tbptt { .. } | LearnerKind::Snap1 { .. }
        )
    }

    pub fn label(&self) -> String {
        match self {
            LearnerKind::Columnar { d } => format!("columnar_{d}"),
            LearnerKind::Constructive {
                total,
                steps_per_stage,
            } => format!("constructive_{total}_{steps_per_stage}"),
            LearnerKind::Ccn {
                total,
                per_stage,
                steps_per_stage,
            } => format!("ccn_{total}_{per_stage}_{steps_per_stage}"),
            LearnerKind::Tbptt { d, k } => format!("tbptt_{d}x{k}"),
            LearnerKind::Snap1 { d } => format!("snap1_{d}"),
        }
    }
}

/// Which prediction stream to run on.
#[derive(Clone, Debug, PartialEq)]
pub enum EnvKind {
    TracePatterning,
    /// fast variant with short intervals (tests/smoke)
    TracePatterningTiny,
    TraceConditioning,
    CycleWorld { n: u64 },
    /// one of the synthetic-ALE suite games, e.g. "pong"
    SynthAtari { game: String },
}

impl EnvKind {
    pub fn label(&self) -> String {
        match self {
            EnvKind::TracePatterning => "trace_patterning".into(),
            EnvKind::TracePatterningTiny => "trace_patterning_tiny".into(),
            EnvKind::TraceConditioning => "trace_conditioning".into(),
            EnvKind::CycleWorld { n } => format!("cycle_world_{n}"),
            EnvKind::SynthAtari { game } => format!("atari_{game}"),
        }
    }

    pub fn parse(name: &str) -> Option<EnvKind> {
        match name {
            "trace_patterning" | "trace" => Some(EnvKind::TracePatterning),
            "trace_tiny" => Some(EnvKind::TracePatterningTiny),
            "trace_conditioning" => Some(EnvKind::TraceConditioning),
            _ => {
                if let Some(n) = name.strip_prefix("cycle_world_") {
                    n.parse().ok().map(|n| EnvKind::CycleWorld { n })
                } else if synthatari::env_names().contains(&name) {
                    Some(EnvKind::SynthAtari { game: name.into() })
                } else {
                    None
                }
            }
        }
    }
}

/// A fully specified experiment.
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    pub env: EnvKind,
    pub learner: LearnerKind,
    pub alpha: f32,
    pub lambda: f32,
    /// None => use the stream's prescribed gamma.
    pub gamma_override: Option<f32>,
    /// normalizer epsilon (CCN family).
    pub eps: f32,
    pub steps: u64,
    pub seed: u64,
    /// number of points kept on the learning curve.
    pub curve_points: usize,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        Self {
            env: EnvKind::TracePatterning,
            learner: LearnerKind::Ccn {
                total: 20,
                per_stage: 4,
                steps_per_stage: 10_000_000,
            },
            alpha: 0.001,
            lambda: 0.99,
            gamma_override: None,
            eps: 0.01,
            steps: 50_000_000,
            seed: 0,
            curve_points: 200,
        }
    }
}

impl ExperimentConfig {
    /// Paper Table-1 presets, scaled by `scale` (1.0 = the paper's 50M
    /// steps; benches use ~0.02).
    pub fn paper_trace(learner: LearnerKind, scale: f64, seed: u64) -> Self {
        let steps = (50_000_000.0 * scale) as u64;
        let sps = |paper: u64| ((paper as f64 * scale) as u64).max(1);
        let learner = match learner {
            LearnerKind::Constructive { total, .. } => LearnerKind::Constructive {
                total,
                steps_per_stage: sps(5_000_000),
            },
            LearnerKind::Ccn {
                total, per_stage, ..
            } => LearnerKind::Ccn {
                total,
                per_stage,
                steps_per_stage: sps(10_000_000),
            },
            other => other,
        };
        Self {
            env: EnvKind::TracePatterning,
            learner,
            alpha: 0.001,
            lambda: 0.99,
            gamma_override: None,
            eps: 0.01,
            steps,
            seed,
            curve_points: 100,
        }
    }

    pub fn label(&self) -> String {
        format!(
            "{}:{}:a{}:s{}",
            self.env.label(),
            self.learner.label(),
            self.alpha,
            self.seed
        )
    }

    pub fn to_json(&self) -> Json {
        let learner = self.learner.to_json();
        Json::obj(vec![
            ("env", Json::Str(self.env.label())),
            ("learner", learner),
            ("alpha", Json::Num(self.alpha as f64)),
            ("lambda", Json::Num(self.lambda as f64)),
            (
                "gamma",
                self.gamma_override
                    .map(|g| Json::Num(g as f64))
                    .unwrap_or(Json::Null),
            ),
            ("eps", Json::Num(self.eps as f64)),
            ("steps", Json::Num(self.steps as f64)),
            ("seed", Json::Num(self.seed as f64)),
            ("curve_points", Json::Num(self.curve_points as f64)),
        ])
    }

    pub fn from_json(v: &Json) -> Option<Self> {
        let env = EnvKind::parse(v.get("env")?.as_str()?)
            .or_else(|| {
                let s = v.get("env")?.as_str()?;
                s.strip_prefix("atari_").and_then(|g| {
                    EnvKind::parse(g)
                })
            })?;
        let learner = LearnerKind::from_json(v.get("learner")?)?;
        Some(Self {
            env,
            learner,
            alpha: v.get("alpha")?.as_f64()? as f32,
            lambda: v.get("lambda")?.as_f64()? as f32,
            gamma_override: v.get("gamma").and_then(|g| g.as_f64()).map(|g| g as f32),
            eps: v.get("eps")?.as_f64()? as f32,
            steps: v.get("steps")?.as_f64()? as u64,
            seed: v.get("seed")?.as_f64()? as u64,
            curve_points: v.get("curve_points")?.as_usize()?,
        })
    }
}

/// Build the stream for a config (seeded independently of the learner).
pub fn build_stream(env: &EnvKind, seed: u64) -> Result<Box<dyn Stream>, ConfigError> {
    Ok(match env {
        EnvKind::TracePatterning => Box::new(TracePatterning::new(
            TracePatterningConfig::default(),
            seed,
        )),
        EnvKind::TracePatterningTiny => Box::new(TracePatterning::new(
            TracePatterningConfig::tiny(),
            seed,
        )),
        EnvKind::TraceConditioning => Box::new(TraceConditioning::new(
            TraceConditioningConfig::default(),
            seed,
        )),
        EnvKind::CycleWorld { n } => Box::new(CycleWorld::new(*n, 0.9)),
        EnvKind::SynthAtari { game } => Box::new(
            synthatari::make_env(game, seed)
                .ok_or_else(|| ConfigError::UnknownGame(game.clone()))?,
        ),
    })
}

/// Build a CCN-family net for a learner spec. Returns an error for the
/// dense baselines (tbptt/snap1), which are not CCN-shaped — used by the
/// serve layer, whose snapshot format covers the CCN family only.
pub fn build_ccn(
    learner: &LearnerKind,
    n_inputs: usize,
    eps: f32,
    seed: u64,
) -> Result<CcnNet, ConfigError> {
    let cfg = match learner {
        LearnerKind::Columnar { d } => CcnConfig {
            n_inputs,
            total_features: *d,
            features_per_stage: *d,
            steps_per_stage: u64::MAX,
            init_scale: 1.0,
            norm_eps: eps,
            norm_beta: NORM_BETA,
        },
        LearnerKind::Constructive {
            total,
            steps_per_stage,
        } => CcnConfig {
            n_inputs,
            total_features: *total,
            features_per_stage: 1,
            steps_per_stage: *steps_per_stage,
            init_scale: 1.0,
            norm_eps: eps,
            norm_beta: NORM_BETA,
        },
        LearnerKind::Ccn {
            total,
            per_stage,
            steps_per_stage,
        } => CcnConfig {
            n_inputs,
            total_features: *total,
            features_per_stage: *per_stage,
            steps_per_stage: *steps_per_stage,
            init_scale: 1.0,
            norm_eps: eps,
            norm_beta: NORM_BETA,
        },
        other => {
            return Err(ConfigError::UnsupportedLearner {
                learner: other.label(),
                context: "the CCN family (columnar|constructive|ccn)".into(),
            })
        }
    };
    Ok(CcnNet::new(cfg, seed))
}

/// Build *any* learner kind as a boxed [`ServableNet`] — the single net
/// factory behind the experiment runner and the serve layer's `open`.
/// Every kind the registry can restore can also be built here.
pub fn build_servable(
    learner: &LearnerKind,
    n_inputs: usize,
    eps: f32,
    seed: u64,
) -> Result<Box<dyn ServableNet>, ConfigError> {
    let net: Box<dyn ServableNet> = match learner {
        LearnerKind::Tbptt { d, k } => Box::new(TbpttNet::new(n_inputs, *d, *k, seed)),
        LearnerKind::Snap1 { d } => Box::new(Snap1Net::new(n_inputs, *d, seed)),
        ccn_family => Box::new(build_ccn(ccn_family, n_inputs, eps, seed)?),
    };
    Ok(net)
}

/// Build the agent (net + TD(lambda)) for a config over `n_inputs`
/// features with discount `gamma`.
pub fn build_agent(
    cfg: &ExperimentConfig,
    n_inputs: usize,
    gamma: f32,
) -> TdLambdaAgent<Box<dyn ServableNet>> {
    let net = build_servable(&cfg.learner, n_inputs, cfg.eps, cfg.seed)
        .expect("every learner kind is servable");
    TdLambdaAgent::new(
        net,
        TdConfig {
            alpha: cfg.alpha,
            gamma,
            lambda: cfg.lambda,
        },
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nets::{PersistableNet, PredictionNet};

    #[test]
    fn json_roundtrip_all_learners() {
        let learners = vec![
            LearnerKind::Columnar { d: 5 },
            LearnerKind::Constructive {
                total: 10,
                steps_per_stage: 100,
            },
            LearnerKind::Ccn {
                total: 20,
                per_stage: 4,
                steps_per_stage: 200,
            },
            LearnerKind::Tbptt { d: 2, k: 30 },
            LearnerKind::Snap1 { d: 7 },
        ];
        for learner in learners {
            let cfg = ExperimentConfig {
                learner: learner.clone(),
                ..Default::default()
            };
            let j = cfg.to_json();
            let back = ExperimentConfig::from_json(&Json::parse(&j.dump()).unwrap())
                .expect("roundtrip");
            assert_eq!(back.learner, learner);
            assert_eq!(back.steps, cfg.steps);
        }
    }

    #[test]
    fn env_parse_names() {
        assert_eq!(EnvKind::parse("trace"), Some(EnvKind::TracePatterning));
        assert_eq!(
            EnvKind::parse("pong"),
            Some(EnvKind::SynthAtari {
                game: "pong".into()
            })
        );
        assert_eq!(
            EnvKind::parse("cycle_world_8"),
            Some(EnvKind::CycleWorld { n: 8 })
        );
        assert_eq!(EnvKind::parse("nope"), None);
    }

    #[test]
    fn build_agent_matches_learner_kind() {
        let cfg = ExperimentConfig {
            learner: LearnerKind::Tbptt { d: 2, k: 30 },
            ..Default::default()
        };
        let agent = build_agent(&cfg, 7, 0.9);
        assert_eq!(agent.net.name(), "tbptt");
        let cfg2 = ExperimentConfig {
            learner: LearnerKind::Columnar { d: 5 },
            ..Default::default()
        };
        let agent2 = build_agent(&cfg2, 7, 0.9);
        assert_eq!(agent2.net.name(), "columnar");
        assert_eq!(agent2.net.n_features(), 5);
    }

    #[test]
    fn paper_trace_preset_scales() {
        let cfg = ExperimentConfig::paper_trace(
            LearnerKind::Ccn {
                total: 20,
                per_stage: 4,
                steps_per_stage: 0,
            },
            0.01,
            3,
        );
        assert_eq!(cfg.steps, 500_000);
        match cfg.learner {
            LearnerKind::Ccn {
                steps_per_stage, ..
            } => assert_eq!(steps_per_stage, 100_000),
            ref other => panic!("paper_trace must preserve the ccn kind, got {other:?}"),
        }
    }

    #[test]
    fn learner_spec_parse_roundtrips_and_rejects() {
        assert_eq!(
            LearnerKind::parse("columnar:8").unwrap(),
            LearnerKind::Columnar { d: 8 }
        );
        assert_eq!(
            LearnerKind::parse("ccn:20:4:100000").unwrap(),
            LearnerKind::Ccn {
                total: 20,
                per_stage: 4,
                steps_per_stage: 100_000
            }
        );
        assert_eq!(
            LearnerKind::parse("tbptt:2:30").unwrap(),
            LearnerKind::Tbptt { d: 2, k: 30 }
        );
        assert!(matches!(
            LearnerKind::parse("columnar"),
            Err(ConfigError::BadLearnerSpec(_))
        ));
        assert!(matches!(
            LearnerKind::parse("hopfield:4"),
            Err(ConfigError::BadLearnerSpec(_))
        ));
    }

    #[test]
    fn build_stream_reports_unknown_game() {
        let err = build_stream(
            &EnvKind::SynthAtari {
                game: "nonexistent".into(),
            },
            0,
        )
        .err()
        .expect("must not panic on unknown games");
        assert_eq!(err, ConfigError::UnknownGame("nonexistent".into()));
        assert!(err.to_string().contains("pong"), "lists alternatives");
    }

    #[test]
    fn build_servable_builds_every_kind_with_matching_tag() {
        let learners = vec![
            LearnerKind::Columnar { d: 3 },
            LearnerKind::Constructive {
                total: 4,
                steps_per_stage: 100,
            },
            LearnerKind::Ccn {
                total: 4,
                per_stage: 2,
                steps_per_stage: 100,
            },
            LearnerKind::Tbptt { d: 2, k: 5 },
            LearnerKind::Snap1 { d: 2 },
        ];
        for learner in learners {
            let net = build_servable(&learner, 3, 0.01, 0)
                .unwrap_or_else(|e| panic!("{}: {e}", learner.label()));
            assert_eq!(net.kind(), learner.kind(), "{}", learner.label());
            assert_eq!(net.n_inputs(), 3);
        }
    }

    #[test]
    fn build_ccn_rejects_dense_baselines() {
        let err = build_ccn(&LearnerKind::Tbptt { d: 2, k: 10 }, 4, 0.01, 0)
            .err()
            .expect("tbptt is not ccn-shaped");
        assert!(err.to_string().contains("tbptt"));
    }
}
