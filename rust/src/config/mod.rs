//! Experiment configuration: typed specs, JSON round-trip, CLI overrides,
//! and the paper's Table-1 presets.

use crate::env::synthatari;
use crate::env::trace_conditioning::{TraceConditioning, TraceConditioningConfig};
use crate::env::trace_patterning::{TracePatterning, TracePatterningConfig};
use crate::env::{cycle_world::CycleWorld, Stream};
use crate::learn::{TdConfig, TdLambdaAgent};
use crate::nets::ccn::{CcnConfig, CcnNet};
use crate::nets::normalizer::NORM_BETA;
use crate::nets::snap1::Snap1Net;
use crate::nets::tbptt::TbpttNet;
use crate::nets::PredictionNet;
use crate::util::json::Json;

/// Which network/learning algorithm to run.
#[derive(Clone, Debug, PartialEq)]
pub enum LearnerKind {
    /// d independent columns, learned forever (Section 3.1).
    Columnar { d: usize },
    /// grow one feature per stage (Section 3.2).
    Constructive { total: usize, steps_per_stage: u64 },
    /// the full CCN (Section 3.3).
    Ccn {
        total: usize,
        per_stage: usize,
        steps_per_stage: u64,
    },
    /// fully connected LSTM + truncated BPTT (the baseline).
    Tbptt { d: usize, k: usize },
    /// SnAp-1 diagonal RTRL on a dense LSTM (related-work baseline).
    Snap1 { d: usize },
}

impl LearnerKind {
    pub fn label(&self) -> String {
        match self {
            LearnerKind::Columnar { d } => format!("columnar_{d}"),
            LearnerKind::Constructive {
                total,
                steps_per_stage,
            } => format!("constructive_{total}_{steps_per_stage}"),
            LearnerKind::Ccn {
                total,
                per_stage,
                steps_per_stage,
            } => format!("ccn_{total}_{per_stage}_{steps_per_stage}"),
            LearnerKind::Tbptt { d, k } => format!("tbptt_{d}x{k}"),
            LearnerKind::Snap1 { d } => format!("snap1_{d}"),
        }
    }
}

/// Which prediction stream to run on.
#[derive(Clone, Debug, PartialEq)]
pub enum EnvKind {
    TracePatterning,
    /// fast variant with short intervals (tests/smoke)
    TracePatterningTiny,
    TraceConditioning,
    CycleWorld { n: u64 },
    /// one of the synthetic-ALE suite games, e.g. "pong"
    SynthAtari { game: String },
}

impl EnvKind {
    pub fn label(&self) -> String {
        match self {
            EnvKind::TracePatterning => "trace_patterning".into(),
            EnvKind::TracePatterningTiny => "trace_patterning_tiny".into(),
            EnvKind::TraceConditioning => "trace_conditioning".into(),
            EnvKind::CycleWorld { n } => format!("cycle_world_{n}"),
            EnvKind::SynthAtari { game } => format!("atari_{game}"),
        }
    }

    pub fn parse(name: &str) -> Option<EnvKind> {
        match name {
            "trace_patterning" | "trace" => Some(EnvKind::TracePatterning),
            "trace_tiny" => Some(EnvKind::TracePatterningTiny),
            "trace_conditioning" => Some(EnvKind::TraceConditioning),
            _ => {
                if let Some(n) = name.strip_prefix("cycle_world_") {
                    n.parse().ok().map(|n| EnvKind::CycleWorld { n })
                } else if synthatari::env_names().contains(&name) {
                    Some(EnvKind::SynthAtari { game: name.into() })
                } else {
                    None
                }
            }
        }
    }
}

/// A fully specified experiment.
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    pub env: EnvKind,
    pub learner: LearnerKind,
    pub alpha: f32,
    pub lambda: f32,
    /// None => use the stream's prescribed gamma.
    pub gamma_override: Option<f32>,
    /// normalizer epsilon (CCN family).
    pub eps: f32,
    pub steps: u64,
    pub seed: u64,
    /// number of points kept on the learning curve.
    pub curve_points: usize,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        Self {
            env: EnvKind::TracePatterning,
            learner: LearnerKind::Ccn {
                total: 20,
                per_stage: 4,
                steps_per_stage: 10_000_000,
            },
            alpha: 0.001,
            lambda: 0.99,
            gamma_override: None,
            eps: 0.01,
            steps: 50_000_000,
            seed: 0,
            curve_points: 200,
        }
    }
}

impl ExperimentConfig {
    /// Paper Table-1 presets, scaled by `scale` (1.0 = the paper's 50M
    /// steps; benches use ~0.02).
    pub fn paper_trace(learner: LearnerKind, scale: f64, seed: u64) -> Self {
        let steps = (50_000_000.0 * scale) as u64;
        let sps = |paper: u64| ((paper as f64 * scale) as u64).max(1);
        let learner = match learner {
            LearnerKind::Constructive { total, .. } => LearnerKind::Constructive {
                total,
                steps_per_stage: sps(5_000_000),
            },
            LearnerKind::Ccn {
                total, per_stage, ..
            } => LearnerKind::Ccn {
                total,
                per_stage,
                steps_per_stage: sps(10_000_000),
            },
            other => other,
        };
        Self {
            env: EnvKind::TracePatterning,
            learner,
            alpha: 0.001,
            lambda: 0.99,
            gamma_override: None,
            eps: 0.01,
            steps,
            seed,
            curve_points: 100,
        }
    }

    pub fn label(&self) -> String {
        format!(
            "{}:{}:a{}:s{}",
            self.env.label(),
            self.learner.label(),
            self.alpha,
            self.seed
        )
    }

    pub fn to_json(&self) -> Json {
        let learner = match &self.learner {
            LearnerKind::Columnar { d } => Json::obj(vec![
                ("kind", Json::Str("columnar".into())),
                ("d", Json::Num(*d as f64)),
            ]),
            LearnerKind::Constructive {
                total,
                steps_per_stage,
            } => Json::obj(vec![
                ("kind", Json::Str("constructive".into())),
                ("total", Json::Num(*total as f64)),
                ("steps_per_stage", Json::Num(*steps_per_stage as f64)),
            ]),
            LearnerKind::Ccn {
                total,
                per_stage,
                steps_per_stage,
            } => Json::obj(vec![
                ("kind", Json::Str("ccn".into())),
                ("total", Json::Num(*total as f64)),
                ("per_stage", Json::Num(*per_stage as f64)),
                ("steps_per_stage", Json::Num(*steps_per_stage as f64)),
            ]),
            LearnerKind::Tbptt { d, k } => Json::obj(vec![
                ("kind", Json::Str("tbptt".into())),
                ("d", Json::Num(*d as f64)),
                ("k", Json::Num(*k as f64)),
            ]),
            LearnerKind::Snap1 { d } => Json::obj(vec![
                ("kind", Json::Str("snap1".into())),
                ("d", Json::Num(*d as f64)),
            ]),
        };
        Json::obj(vec![
            ("env", Json::Str(self.env.label())),
            ("learner", learner),
            ("alpha", Json::Num(self.alpha as f64)),
            ("lambda", Json::Num(self.lambda as f64)),
            (
                "gamma",
                self.gamma_override
                    .map(|g| Json::Num(g as f64))
                    .unwrap_or(Json::Null),
            ),
            ("eps", Json::Num(self.eps as f64)),
            ("steps", Json::Num(self.steps as f64)),
            ("seed", Json::Num(self.seed as f64)),
            ("curve_points", Json::Num(self.curve_points as f64)),
        ])
    }

    pub fn from_json(v: &Json) -> Option<Self> {
        let env = EnvKind::parse(v.get("env")?.as_str()?)
            .or_else(|| {
                let s = v.get("env")?.as_str()?;
                s.strip_prefix("atari_").and_then(|g| {
                    EnvKind::parse(g)
                })
            })?;
        let l = v.get("learner")?;
        let learner = match l.get("kind")?.as_str()? {
            "columnar" => LearnerKind::Columnar {
                d: l.get("d")?.as_usize()?,
            },
            "constructive" => LearnerKind::Constructive {
                total: l.get("total")?.as_usize()?,
                steps_per_stage: l.get("steps_per_stage")?.as_f64()? as u64,
            },
            "ccn" => LearnerKind::Ccn {
                total: l.get("total")?.as_usize()?,
                per_stage: l.get("per_stage")?.as_usize()?,
                steps_per_stage: l.get("steps_per_stage")?.as_f64()? as u64,
            },
            "tbptt" => LearnerKind::Tbptt {
                d: l.get("d")?.as_usize()?,
                k: l.get("k")?.as_usize()?,
            },
            "snap1" => LearnerKind::Snap1 {
                d: l.get("d")?.as_usize()?,
            },
            _ => return None,
        };
        Some(Self {
            env,
            learner,
            alpha: v.get("alpha")?.as_f64()? as f32,
            lambda: v.get("lambda")?.as_f64()? as f32,
            gamma_override: v.get("gamma").and_then(|g| g.as_f64()).map(|g| g as f32),
            eps: v.get("eps")?.as_f64()? as f32,
            steps: v.get("steps")?.as_f64()? as u64,
            seed: v.get("seed")?.as_f64()? as u64,
            curve_points: v.get("curve_points")?.as_usize()?,
        })
    }
}

/// Build the stream for a config (seeded independently of the learner).
pub fn build_stream(env: &EnvKind, seed: u64) -> Box<dyn Stream> {
    match env {
        EnvKind::TracePatterning => Box::new(TracePatterning::new(
            TracePatterningConfig::default(),
            seed,
        )),
        EnvKind::TracePatterningTiny => Box::new(TracePatterning::new(
            TracePatterningConfig::tiny(),
            seed,
        )),
        EnvKind::TraceConditioning => Box::new(TraceConditioning::new(
            TraceConditioningConfig::default(),
            seed,
        )),
        EnvKind::CycleWorld { n } => Box::new(CycleWorld::new(*n, 0.9)),
        EnvKind::SynthAtari { game } => Box::new(
            synthatari::make_env(game, seed)
                .unwrap_or_else(|| panic!("unknown game {game}")),
        ),
    }
}

/// Build the agent (net + TD(lambda)) for a config over `n_inputs`
/// features with discount `gamma`.
pub fn build_agent(
    cfg: &ExperimentConfig,
    n_inputs: usize,
    gamma: f32,
) -> TdLambdaAgent<Box<dyn PredictionNet>> {
    let net: Box<dyn PredictionNet> = match &cfg.learner {
        LearnerKind::Columnar { d } => Box::new(CcnNet::new(
            CcnConfig {
                n_inputs,
                total_features: *d,
                features_per_stage: *d,
                steps_per_stage: u64::MAX,
                init_scale: 1.0,
                norm_eps: cfg.eps,
                norm_beta: NORM_BETA,
            },
            cfg.seed,
        )),
        LearnerKind::Constructive {
            total,
            steps_per_stage,
        } => Box::new(CcnNet::new(
            CcnConfig {
                n_inputs,
                total_features: *total,
                features_per_stage: 1,
                steps_per_stage: *steps_per_stage,
                init_scale: 1.0,
                norm_eps: cfg.eps,
                norm_beta: NORM_BETA,
            },
            cfg.seed,
        )),
        LearnerKind::Ccn {
            total,
            per_stage,
            steps_per_stage,
        } => Box::new(CcnNet::new(
            CcnConfig {
                n_inputs,
                total_features: *total,
                features_per_stage: *per_stage,
                steps_per_stage: *steps_per_stage,
                init_scale: 1.0,
                norm_eps: cfg.eps,
                norm_beta: NORM_BETA,
            },
            cfg.seed,
        )),
        LearnerKind::Tbptt { d, k } => Box::new(TbpttNet::new(n_inputs, *d, *k, cfg.seed)),
        LearnerKind::Snap1 { d } => Box::new(Snap1Net::new(n_inputs, *d, cfg.seed)),
    };
    TdLambdaAgent::new(
        net,
        TdConfig {
            alpha: cfg.alpha,
            gamma,
            lambda: cfg.lambda,
        },
    )
}

impl PredictionNet for Box<dyn PredictionNet> {
    fn n_features(&self) -> usize {
        (**self).n_features()
    }
    fn advance(&mut self, x: &[f32]) {
        (**self).advance(x)
    }
    fn features(&self) -> &[f32] {
        (**self).features()
    }
    fn n_learnable_params(&self) -> usize {
        (**self).n_learnable_params()
    }
    fn grad_y(&self, w_out: &[f32], grad: &mut [f32]) {
        (**self).grad_y(w_out, grad)
    }
    fn apply_update(&mut self, delta: &[f32]) {
        (**self).apply_update(delta)
    }
    fn param_epoch(&self) -> u64 {
        (**self).param_epoch()
    }
    fn end_step(&mut self) {
        (**self).end_step()
    }
    fn flops_per_step(&self) -> u64 {
        (**self).flops_per_step()
    }
    fn name(&self) -> &'static str {
        (**self).name()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_roundtrip_all_learners() {
        let learners = vec![
            LearnerKind::Columnar { d: 5 },
            LearnerKind::Constructive {
                total: 10,
                steps_per_stage: 100,
            },
            LearnerKind::Ccn {
                total: 20,
                per_stage: 4,
                steps_per_stage: 200,
            },
            LearnerKind::Tbptt { d: 2, k: 30 },
            LearnerKind::Snap1 { d: 7 },
        ];
        for learner in learners {
            let cfg = ExperimentConfig {
                learner: learner.clone(),
                ..Default::default()
            };
            let j = cfg.to_json();
            let back = ExperimentConfig::from_json(&Json::parse(&j.dump()).unwrap())
                .expect("roundtrip");
            assert_eq!(back.learner, learner);
            assert_eq!(back.steps, cfg.steps);
        }
    }

    #[test]
    fn env_parse_names() {
        assert_eq!(EnvKind::parse("trace"), Some(EnvKind::TracePatterning));
        assert_eq!(
            EnvKind::parse("pong"),
            Some(EnvKind::SynthAtari {
                game: "pong".into()
            })
        );
        assert_eq!(
            EnvKind::parse("cycle_world_8"),
            Some(EnvKind::CycleWorld { n: 8 })
        );
        assert_eq!(EnvKind::parse("nope"), None);
    }

    #[test]
    fn build_agent_matches_learner_kind() {
        let cfg = ExperimentConfig {
            learner: LearnerKind::Tbptt { d: 2, k: 30 },
            ..Default::default()
        };
        let agent = build_agent(&cfg, 7, 0.9);
        assert_eq!(agent.net.name(), "tbptt");
        let cfg2 = ExperimentConfig {
            learner: LearnerKind::Columnar { d: 5 },
            ..Default::default()
        };
        let agent2 = build_agent(&cfg2, 7, 0.9);
        assert_eq!(agent2.net.name(), "columnar");
        assert_eq!(agent2.net.n_features(), 5);
    }

    #[test]
    fn paper_trace_preset_scales() {
        let cfg = ExperimentConfig::paper_trace(
            LearnerKind::Ccn {
                total: 20,
                per_stage: 4,
                steps_per_stage: 0,
            },
            0.01,
            3,
        );
        assert_eq!(cfg.steps, 500_000);
        match cfg.learner {
            LearnerKind::Ccn {
                steps_per_stage, ..
            } => assert_eq!(steps_per_stage, 100_000),
            _ => panic!(),
        }
    }
}
