//! Aggregation of multi-seed runs into the statistics the paper plots:
//! mean learning curves with standard-error bands (Figs 4–6), final
//! errors with one-standard-error margins (Fig 8), and T-BPTT-normalized
//! relative errors (Figs 8, 9, 11).

use std::collections::BTreeMap;

use super::runner::RunResult;
use crate::metrics::{aggregate_curves, OnlineStats};
use crate::util::json::Json;

#[derive(Clone, Debug)]
pub struct AggregateResult {
    pub learner: String,
    pub env: String,
    pub n_seeds: usize,
    pub curve_x: Vec<u64>,
    pub curve_mean: Vec<f64>,
    pub curve_stderr: Vec<f64>,
    pub tail_mean: f64,
    pub tail_stderr: f64,
    pub mean_steps_per_sec: f64,
}

impl AggregateResult {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("learner", Json::Str(self.learner.clone())),
            ("env", Json::Str(self.env.clone())),
            ("n_seeds", Json::Num(self.n_seeds as f64)),
            (
                "curve_x",
                Json::arr_f64(&self.curve_x.iter().map(|&v| v as f64).collect::<Vec<_>>()),
            ),
            ("curve_mean", Json::arr_f64(&self.curve_mean)),
            ("curve_stderr", Json::arr_f64(&self.curve_stderr)),
            ("tail_mean", Json::Num(self.tail_mean)),
            ("tail_stderr", Json::Num(self.tail_stderr)),
            ("steps_per_sec", Json::Num(self.mean_steps_per_sec)),
        ])
    }
}

/// Group runs by (learner, env) and aggregate over seeds.
pub fn aggregate_runs(runs: &[RunResult]) -> Vec<AggregateResult> {
    let mut groups: BTreeMap<(String, String), Vec<&RunResult>> = BTreeMap::new();
    for r in runs {
        groups
            .entry((r.learner.clone(), r.env.clone()))
            .or_default()
            .push(r);
    }
    groups
        .into_iter()
        .map(|((learner, env), rs)| {
            let curves: Vec<_> = rs.iter().map(|r| r.curve.clone()).collect();
            let (xs, mean, stderr) = aggregate_curves(&curves);
            let mut tail = OnlineStats::new();
            let mut speed = OnlineStats::new();
            for r in &rs {
                tail.push(r.tail_error);
                speed.push(r.steps_per_sec);
            }
            AggregateResult {
                learner,
                env,
                n_seeds: rs.len(),
                curve_x: xs,
                curve_mean: mean,
                curve_stderr: stderr,
                tail_mean: tail.mean(),
                tail_stderr: tail.stderr(),
                mean_steps_per_sec: speed.mean(),
            }
        })
        .collect()
}

/// Per-environment error of `learner`, normalized by `baseline`'s error in
/// the same environment (the paper's Fig-8/9 metric: baseline == 1.0).
pub fn relative_errors(
    aggs: &[AggregateResult],
    learner: &str,
    baseline: &str,
) -> Vec<(String, f64)> {
    let mut out = Vec::new();
    for a in aggs.iter().filter(|a| a.learner == learner) {
        if let Some(b) = aggs
            .iter()
            .find(|b| b.learner == baseline && b.env == a.env)
        {
            if b.tail_mean > 0.0 {
                out.push((a.env.clone(), a.tail_mean / b.tail_mean));
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::Curve;

    fn fake_run(learner: &str, env: &str, seed: u64, errs: &[f64]) -> RunResult {
        let mut curve = Curve::new(errs.len() as u64, errs.len());
        for &e in errs {
            curve.push(e);
        }
        curve.finish();
        RunResult {
            label: format!("{env}:{learner}:s{seed}"),
            learner: learner.into(),
            kind: learner.into(),
            env: env.into(),
            seed,
            tail_error: *errs.last().unwrap(),
            curve,
            steps: errs.len() as u64,
            steps_per_sec: 1000.0,
            flops_per_step: 42,
            tail_trace: vec![],
        }
    }

    #[test]
    fn groups_by_learner_and_env() {
        let runs = vec![
            fake_run("ccn", "pong", 0, &[4.0, 2.0]),
            fake_run("ccn", "pong", 1, &[6.0, 4.0]),
            fake_run("tbptt", "pong", 0, &[8.0, 8.0]),
        ];
        let aggs = aggregate_runs(&runs);
        assert_eq!(aggs.len(), 2);
        let ccn = aggs.iter().find(|a| a.learner == "ccn").unwrap();
        assert_eq!(ccn.n_seeds, 2);
        assert!((ccn.curve_mean[0] - 5.0).abs() < 1e-12);
        assert!((ccn.tail_mean - 3.0).abs() < 1e-12);
        assert!(ccn.tail_stderr > 0.0);
    }

    #[test]
    fn relative_error_normalizes_baseline_to_one() {
        let runs = vec![
            fake_run("ccn", "pong", 0, &[1.0, 2.0]),
            fake_run("tbptt", "pong", 0, &[1.0, 4.0]),
            fake_run("ccn", "breakout", 0, &[1.0, 9.0]),
            fake_run("tbptt", "breakout", 0, &[1.0, 3.0]),
        ];
        let aggs = aggregate_runs(&runs);
        let rel = relative_errors(&aggs, "ccn", "tbptt");
        let rel_tbptt = relative_errors(&aggs, "tbptt", "tbptt");
        assert!(rel_tbptt.iter().all(|(_, v)| (v - 1.0).abs() < 1e-12));
        let pong = rel.iter().find(|(e, _)| e == "pong").unwrap();
        assert!((pong.1 - 0.5).abs() < 1e-12);
        let brk = rel.iter().find(|(e, _)| e == "breakout").unwrap();
        assert!((brk.1 - 3.0).abs() < 1e-12);
    }
}
