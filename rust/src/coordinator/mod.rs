//! Experiment coordination: the online agent loop ([`runner`]), the
//! multi-seed / multi-config sweep scheduler ([`sweep`]), and result
//! aggregation ([`aggregate`]). This is the Layer-3 orchestrator — the
//! paper ran the analogous role with GNU parallel over 1,000 CPUs; we run
//! a work-stealing thread pool over local cores with identical semantics
//! (every (config, seed) cell runs exactly once; results are keyed and
//! aggregated per configuration).

pub mod aggregate;
pub mod runner;
pub mod sweep;

pub use aggregate::{aggregate_runs, AggregateResult};
pub use runner::{run_experiment, RunResult};
pub use sweep::{run_sweep, SweepResult};
