//! The online agent loop: stream -> agent -> return-error curve.

use std::time::Instant;

use crate::config::{build_agent, build_stream, ConfigError, ExperimentConfig};
use crate::env::returns::ReturnEval;
use crate::metrics::Curve;
use crate::nets::PersistableNet;
use crate::util::json::Json;

/// Outcome of one (config, seed) run.
#[derive(Clone, Debug)]
pub struct RunResult {
    pub label: String,
    pub learner: String,
    /// registered net-kind tag ([`crate::nets::NetRegistry`]) the run's
    /// net self-reported; same registry family as the learner spec's
    /// kind (equal for all non-degenerate specs)
    pub kind: String,
    pub env: String,
    pub seed: u64,
    /// mean-squared return error learning curve (binned)
    pub curve: Curve,
    /// mean error over the final 10% of the run
    pub tail_error: f64,
    pub steps: u64,
    pub steps_per_sec: f64,
    /// Appendix-A per-step operation estimate at end of run
    pub flops_per_step: u64,
    /// final-phase (y_t, c_t) trace for prediction visualizations (Fig 10)
    pub tail_trace: Vec<(f32, f32)>,
}

impl RunResult {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("label", Json::Str(self.label.clone())),
            ("learner", Json::Str(self.learner.clone())),
            ("kind", Json::Str(self.kind.clone())),
            ("env", Json::Str(self.env.clone())),
            ("seed", Json::Num(self.seed as f64)),
            (
                "curve_x",
                Json::arr_f64(&self.curve.xs.iter().map(|&v| v as f64).collect::<Vec<_>>()),
            ),
            ("curve_y", Json::arr_f64(&self.curve.ys)),
            ("tail_error", Json::Num(self.tail_error)),
            ("steps", Json::Num(self.steps as f64)),
            ("steps_per_sec", Json::Num(self.steps_per_sec)),
            ("flops_per_step", Json::Num(self.flops_per_step as f64)),
        ])
    }
}

/// How many trailing (y, c) pairs to keep for Fig-10 style plots.
const TAIL_TRACE_LEN: usize = 600;

/// Run one experiment to completion. Fails fast (before any stepping) on
/// configurations that name resources that don't exist.
pub fn run_experiment(cfg: &ExperimentConfig) -> Result<RunResult, ConfigError> {
    // env and learner use decorrelated seed streams so that comparing
    // learners on the same seed shares the exact observation sequence.
    let mut stream = build_stream(&cfg.env, cfg.seed)?;
    let gamma = cfg.gamma_override.unwrap_or_else(|| stream.gamma());
    let mut agent = build_agent(cfg, stream.n_features(), gamma);

    let mut x = vec![0.0f32; stream.n_features()];
    let mut eval = ReturnEval::new(gamma as f64, 1e-4);
    let mut curve = Curve::new(cfg.steps, cfg.curve_points);
    let mut tail_trace: Vec<(f32, f32)> = Vec::with_capacity(TAIL_TRACE_LEN);

    let start = Instant::now();
    for t in 0..cfg.steps {
        let c = stream.step_into(&mut x);
        let y = agent.step(&x, c);
        eval.push(y as f64, c as f64);
        for (_, e2) in eval.drain() {
            curve.push(e2);
        }
        if cfg.steps - t <= TAIL_TRACE_LEN as u64 {
            tail_trace.push((y, c));
        }
    }
    eval.finish();
    for (_, e2) in eval.drain() {
        curve.push(e2);
    }
    curve.finish();
    let elapsed = start.elapsed().as_secs_f64();

    Ok(RunResult {
        label: cfg.label(),
        learner: cfg.learner.label(),
        kind: agent.net.kind().to_string(),
        env: cfg.env.label(),
        seed: cfg.seed,
        tail_error: curve.tail_mean(0.1),
        curve,
        steps: cfg.steps,
        steps_per_sec: cfg.steps as f64 / elapsed.max(1e-9),
        flops_per_step: agent.flops_per_step(),
        tail_trace,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{EnvKind, LearnerKind};

    fn quick_cfg(learner: LearnerKind) -> ExperimentConfig {
        ExperimentConfig {
            env: EnvKind::CycleWorld { n: 6 },
            learner,
            alpha: 0.01,
            lambda: 0.9,
            gamma_override: None,
            eps: 0.01,
            steps: 60_000,
            seed: 0,
            curve_points: 20,
        }
    }

    #[test]
    fn columnar_run_learns_cycle_world() {
        let res = run_experiment(&quick_cfg(LearnerKind::Columnar { d: 4 })).unwrap();
        assert_eq!(res.curve.ys.len(), 20);
        let first = res.curve.ys[1];
        assert!(
            res.tail_error < first * 0.5,
            "error must fall: first {first} tail {}",
            res.tail_error
        );
        assert!(res.steps_per_sec > 1000.0);
        assert_eq!(res.tail_trace.len(), 600);
    }

    #[test]
    fn same_seed_same_curve() {
        let a = run_experiment(&quick_cfg(LearnerKind::Tbptt { d: 2, k: 6 })).unwrap();
        let b = run_experiment(&quick_cfg(LearnerKind::Tbptt { d: 2, k: 6 })).unwrap();
        assert_eq!(a.curve.ys, b.curve.ys, "runs must be deterministic");
    }

    #[test]
    fn bad_env_surfaces_error_not_panic() {
        let mut cfg = quick_cfg(LearnerKind::Columnar { d: 2 });
        cfg.env = EnvKind::SynthAtari {
            game: "bogus".into(),
        };
        assert!(run_experiment(&cfg).is_err());
    }

    #[test]
    fn different_learners_share_observation_stream() {
        // same env seed => same cumulant sequence regardless of learner.
        let a = run_experiment(&quick_cfg(LearnerKind::Columnar { d: 2 })).unwrap();
        let b = run_experiment(&quick_cfg(LearnerKind::Tbptt { d: 2, k: 4 })).unwrap();
        let ca: Vec<f32> = a.tail_trace.iter().map(|&(_, c)| c).collect();
        let cb: Vec<f32> = b.tail_trace.iter().map(|&(_, c)| c).collect();
        assert_eq!(ca, cb, "cumulant stream must be learner-independent");
    }
}
