//! Multi-seed / multi-config sweep scheduler.
//!
//! A fixed-size worker pool pulls (config) cells from a shared queue —
//! the local-core equivalent of the paper's GNU-parallel-over-1,000-CPUs
//! setup. Results arrive unordered and are re-keyed by config label, so
//! scheduling order can never change the science.

use std::collections::VecDeque;
use std::sync::{Arc, Mutex};

use super::runner::{run_experiment, RunResult};
use crate::config::{ConfigError, ExperimentConfig};

#[derive(Clone, Debug)]
pub struct SweepResult {
    pub runs: Vec<RunResult>,
}

impl SweepResult {
    /// All runs for one configuration label (any seed).
    pub fn runs_for(&self, label_prefix: &str) -> Vec<&RunResult> {
        self.runs
            .iter()
            .filter(|r| r.label.starts_with(label_prefix))
            .collect()
    }
}

/// Run every config once, using up to `threads` workers. Every config's
/// environment is validated up front (streams are cheap to construct),
/// so a bad cell fails the sweep *before* any compute is spent rather
/// than after hours of valid runs.
pub fn run_sweep(
    configs: Vec<ExperimentConfig>,
    threads: usize,
) -> Result<SweepResult, ConfigError> {
    for cfg in &configs {
        crate::config::build_stream(&cfg.env, cfg.seed)?;
    }
    let n = configs.len();
    let queue: Arc<Mutex<VecDeque<(usize, ExperimentConfig)>>> =
        Arc::new(Mutex::new(configs.into_iter().enumerate().collect()));
    let results: Arc<Mutex<Vec<Option<Result<RunResult, ConfigError>>>>> =
        Arc::new(Mutex::new((0..n).map(|_| None).collect()));

    let workers = threads.max(1).min(n.max(1));
    std::thread::scope(|scope| {
        for _ in 0..workers {
            let queue = Arc::clone(&queue);
            let results = Arc::clone(&results);
            scope.spawn(move || loop {
                let job = queue.lock().unwrap().pop_front();
                match job {
                    Some((idx, cfg)) => {
                        let res = run_experiment(&cfg);
                        results.lock().unwrap()[idx] = Some(res);
                    }
                    None => break,
                }
            });
        }
    });

    let mut runs = Vec::with_capacity(n);
    for cell in Arc::try_unwrap(results)
        .expect("all workers joined")
        .into_inner()
        .unwrap()
    {
        runs.push(cell.expect("every cell must have run exactly once")?);
    }
    Ok(SweepResult { runs })
}

/// Expand one config over a seed list.
pub fn seeds(cfg: &ExperimentConfig, seed_list: &[u64]) -> Vec<ExperimentConfig> {
    seed_list
        .iter()
        .map(|&seed| ExperimentConfig {
            seed,
            ..cfg.clone()
        })
        .collect()
}

/// Number of worker threads to use by default.
pub fn default_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{EnvKind, LearnerKind};
    use crate::util::check::{check, prop_assert};

    fn quick(seed: u64, steps: u64) -> ExperimentConfig {
        ExperimentConfig {
            env: EnvKind::CycleWorld { n: 5 },
            learner: LearnerKind::Columnar { d: 2 },
            alpha: 0.01,
            lambda: 0.9,
            gamma_override: None,
            eps: 0.01,
            steps,
            seed,
            curve_points: 5,
        }
    }

    #[test]
    fn every_cell_runs_exactly_once_in_order() {
        let configs: Vec<_> = (0..7).map(|s| quick(s, 3000)).collect();
        let res = run_sweep(configs, 3).unwrap();
        assert_eq!(res.runs.len(), 7);
        for (i, r) in res.runs.iter().enumerate() {
            assert_eq!(r.seed, i as u64, "results keyed by submission order");
        }
    }

    #[test]
    fn parallel_equals_serial() {
        let configs: Vec<_> = (0..4).map(|s| quick(s, 5000)).collect();
        let par = run_sweep(configs.clone(), 4).unwrap();
        let ser = run_sweep(configs, 1).unwrap();
        for (a, b) in par.runs.iter().zip(&ser.runs) {
            assert_eq!(a.curve.ys, b.curve.ys, "thread count must not matter");
        }
    }

    #[test]
    fn seeds_helper_expands() {
        let base = quick(0, 100);
        let expanded = seeds(&base, &[3, 5, 8]);
        assert_eq!(expanded.len(), 3);
        assert_eq!(expanded[2].seed, 8);
        assert_eq!(expanded[0].steps, 100);
    }

    #[test]
    fn prop_sweep_preserves_all_labels() {
        check("sweep label preservation", 5, |g| {
            let n = g.sized_usize(1, 6);
            let configs: Vec<_> = (0..n as u64).map(|s| quick(s, 500)).collect();
            let labels: Vec<String> = configs.iter().map(|c| c.label()).collect();
            let res = run_sweep(configs, g.usize_in(1, 4)).expect("sweep runs");
            for (want, run) in labels.iter().zip(&res.runs) {
                prop_assert(&run.label == want, format!("label {want}"))?;
            }
            Ok(())
        });
    }
}
