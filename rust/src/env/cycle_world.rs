//! Cycle world: a deterministic N-state ring with an observation that only
//! distinguishes one state. The cumulant fires at state 0; predicting it
//! requires counting steps — the minimal "state construction" diagnostic
//! (cf. the diagnostic MDPs of Rafiee et al. 2022). Deterministic, so a
//! learner's asymptotic error should approach zero exactly.

use super::{OracleReturn, Stream};

pub struct CycleWorld {
    n: u64,
    pos: u64,
    gamma: f32,
}

impl CycleWorld {
    pub fn new(n: u64, gamma: f32) -> Self {
        assert!(n >= 2);
        Self { n, pos: 0, gamma }
    }
}

pub const N_FEATURES: usize = 2;

impl Stream for CycleWorld {
    fn n_features(&self) -> usize {
        N_FEATURES
    }

    fn gamma(&self) -> f32 {
        self.gamma
    }

    fn name(&self) -> &'static str {
        "cycle_world"
    }

    /// Features: [at_special, cumulant]; cumulant = 1 exactly at state 0.
    fn step_into(&mut self, x: &mut [f32]) -> f32 {
        self.pos = (self.pos + 1) % self.n;
        let special = if self.pos == 0 { 1.0 } else { 0.0 };
        x[0] = special;
        x[1] = special;
        special
    }
}

impl OracleReturn for CycleWorld {
    fn oracle_return(&self) -> Option<f64> {
        // steps until next visit of state 0
        let k = self.n - self.pos;
        let g = self.gamma as f64;
        // G = gamma^(k-1) * 1 / (1 - gamma^n) summed over future laps
        Some(g.powi(k as i32 - 1) / (1.0 - g.powi(self.n as i32)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::env::returns::ReturnEval;

    #[test]
    fn fires_every_n_steps() {
        let mut env = CycleWorld::new(6, 0.9);
        let mut x = vec![0.0; 2];
        let mut fires = Vec::new();
        for t in 0..60 {
            if env.step_into(&mut x) == 1.0 {
                fires.push(t);
            }
        }
        assert_eq!(fires.len(), 10);
        for w in fires.windows(2) {
            assert_eq!(w[1] - w[0], 6);
        }
    }

    #[test]
    fn oracle_matches_empirical() {
        let mut env = CycleWorld::new(5, 0.8);
        let mut ev = ReturnEval::new(0.8, 1e-12);
        let mut oracle = Vec::new();
        let mut x = vec![0.0; 2];
        for _ in 0..3000 {
            let c = env.step_into(&mut x) as f64;
            let y = env.oracle_return().unwrap();
            oracle.push(y);
            ev.push(y, c);
        }
        let errs = ev.drain();
        assert!(!errs.is_empty());
        for &(_, e2) in &errs {
            assert!(e2 < 1e-10, "oracle prediction must have ~zero error: {e2}");
        }
    }
}
