//! Environment substrate: online prediction streams (paper Section 2).
//!
//! A [`Stream`] produces, at every step, a feature vector `x_t` and a
//! cumulant `c_t` (a fixed index of `x_t`). The learner's job is to
//! predict the discounted sum of future cumulants, G_t = sum_{j>t}
//! gamma^{j-t-1} c_j, online; [`returns::ReturnEval`] computes the
//! empirical return error with O(1) amortized cost per step.
//!
//! Implementations:
//! - [`trace_patterning`]: the animal-learning benchmark of Section 4.
//! - [`trace_conditioning`]: single-pattern variant (Rafiee et al. 2022),
//!   used as a simpler diagnostic.
//! - [`cycle_world`]: a tiny deterministic memory diagnostic.
//! - [`synthatari`]: the Atari-prediction substitute — synthetic 16x16
//!   partially observable games driven by scripted expert policies
//!   (see DESIGN.md §Substitutions).

pub mod cycle_world;
pub mod returns;
pub mod synthatari;
pub mod trace_conditioning;
pub mod trace_patterning;

/// An online prediction stream.
pub trait Stream: Send {
    /// Number of features in `x_t` (fixed for the stream's lifetime).
    fn n_features(&self) -> usize;

    /// Advance one step, writing `x_t` into `x` (len == n_features()).
    /// Returns the cumulant `c_t` carried by this observation.
    fn step_into(&mut self, x: &mut [f32]) -> f32;

    /// Discount factor the benchmark prescribes for this stream.
    fn gamma(&self) -> f32;

    /// Human-readable name (used in results files).
    fn name(&self) -> &'static str;

    /// Convenience allocating step (tests, examples).
    fn step(&mut self) -> (Vec<f32>, f32) {
        let mut x = vec![0.0; self.n_features()];
        let c = self.step_into(&mut x);
        (x, c)
    }
}

/// Ground-truth oracle interface: streams that can report the exact
/// expected return at the current step (trace patterning can; the
/// synthetic Atari games cannot in closed form).
pub trait OracleReturn {
    /// Exact expected discounted return G_t from the state *after* the
    /// most recent `step_into` call, if computable.
    fn oracle_return(&self) -> Option<f64>;
}

#[cfg(test)]
mod tests {
    use super::trace_patterning::{TracePatterning, TracePatterningConfig};
    use super::Stream;

    #[test]
    fn step_convenience_matches_step_into() {
        let mut env = TracePatterning::new(TracePatterningConfig::default(), 3);
        let (x, c) = env.step();
        assert_eq!(x.len(), env.n_features());
        assert_eq!(c, x[6]); // cumulant is the US feature
    }
}
