//! Empirical-return evaluation (paper eq. 1).
//!
//! The paper scores a learner by the squared difference between its online
//! prediction y_t and the *empirical* discounted return
//! G_t = sum_{j=t+1}^{inf} gamma^{j-t-1} c_j. G_t depends on the future,
//! so errors are emitted with a delay: we buffer (y, c) pairs and, once a
//! block plus a truncation horizon is available, compute all suffix
//! returns in one backward sweep — O(1) amortized per step, versus O(H)
//! for the naive per-step update (H is hundreds at gamma = 0.98).
//!
//! Truncating at horizon H where gamma^H < tol bounds the return error by
//! gamma^H * c_max / (1 - gamma); tol defaults to 1e-4.

/// Streaming evaluator producing squared prediction errors.
pub struct ReturnEval {
    gamma: f64,
    horizon: usize,
    block: usize,
    ys: Vec<f64>,
    cs: Vec<f64>,
    /// (step_index, squared_error) ready to consume.
    ready: Vec<(u64, f64)>,
    emitted: u64,
}

impl ReturnEval {
    /// `tol` controls the truncation horizon: gamma^H <= tol.
    pub fn new(gamma: f64, tol: f64) -> Self {
        assert!((0.0..1.0).contains(&gamma), "gamma must be in [0,1)");
        let horizon = if gamma == 0.0 {
            1
        } else {
            (tol.ln() / gamma.ln()).ceil().max(1.0) as usize
        };
        Self {
            gamma,
            horizon,
            block: (4 * horizon).max(1024),
            ys: Vec::new(),
            cs: Vec::new(),
            ready: Vec::new(),
            emitted: 0,
        }
    }

    pub fn horizon(&self) -> usize {
        self.horizon
    }

    /// Feed the prediction made at step t and the cumulant observed at
    /// step t. Completed squared errors accumulate in the internal queue;
    /// drain them with [`ReturnEval::drain`].
    pub fn push(&mut self, y: f64, c: f64) {
        self.ys.push(y);
        self.cs.push(c);
        if self.ys.len() >= self.block + self.horizon {
            self.flush_block();
        }
    }

    /// Squared errors completed so far, in step order.
    pub fn drain(&mut self) -> Vec<(u64, f64)> {
        std::mem::take(&mut self.ready)
    }

    /// Flush everything buffered, treating the stream as ended (the tail
    /// within `horizon` of the end is scored against the truncated return).
    pub fn finish(&mut self) {
        if !self.ys.is_empty() {
            let n = self.ys.len();
            let suffix = self.suffix_returns();
            for t in 0..n {
                let g = if t + 1 < n { suffix[t + 1] } else { 0.0 };
                let e = self.ys[t] - g;
                self.ready.push((self.emitted, e * e));
                self.emitted += 1;
            }
            self.ys.clear();
            self.cs.clear();
        }
    }

    /// suffix[t] = c_t + gamma * suffix[t+1], truncated at buffer end.
    fn suffix_returns(&self) -> Vec<f64> {
        let n = self.cs.len();
        let mut s = vec![0.0; n + 1];
        for t in (0..n).rev() {
            s[t] = self.cs[t] + self.gamma * s[t + 1];
        }
        s.truncate(n);
        s
    }

    fn flush_block(&mut self) {
        let n = self.ys.len();
        let emit = n - self.horizon; // entries with a full horizon of future
        let suffix = self.suffix_returns();
        for t in 0..emit {
            let g = suffix[t + 1]; // G_t starts at c_{t+1}
            let e = self.ys[t] - g;
            self.ready.push((self.emitted, e * e));
            self.emitted += 1;
        }
        self.ys.drain(..emit);
        self.cs.drain(..emit);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Naive O(n^2) reference for the truncated empirical return.
    fn naive_return(cs: &[f64], t: usize, gamma: f64, horizon: usize) -> f64 {
        let mut g = 0.0;
        for j in (t + 1)..cs.len().min(t + 1 + horizon) {
            g += gamma.powi((j - t - 1) as i32) * cs[j];
        }
        g
    }

    #[test]
    fn matches_naive_reference() {
        let gamma = 0.9;
        let mut ev = ReturnEval::new(gamma, 1e-4);
        let n = 6000;
        let cs: Vec<f64> = (0..n).map(|i| ((i * 37) % 11) as f64 / 10.0).collect();
        let ys: Vec<f64> = (0..n).map(|i| (i % 7) as f64 / 7.0).collect();
        for i in 0..n {
            ev.push(ys[i], cs[i]);
        }
        let got = ev.drain();
        assert!(!got.is_empty());
        for &(t, e2) in got.iter().take(500) {
            let t = t as usize;
            let g = naive_return(&cs, t, gamma, n); // un-truncated reference
            let want = (ys[t] - g) * (ys[t] - g);
            assert!(
                (e2 - want).abs() < 1e-6,
                "t={t}: {e2} vs {want}"
            );
        }
    }

    #[test]
    fn horizon_from_gamma() {
        let ev = ReturnEval::new(0.9, 1e-4);
        assert!(ev.horizon() >= 87 && ev.horizon() <= 89);
        let ev2 = ReturnEval::new(0.98, 1e-4);
        assert!(ev2.horizon() >= 450 && ev2.horizon() <= 460);
        let ev3 = ReturnEval::new(0.0, 1e-4);
        assert_eq!(ev3.horizon(), 1);
    }

    #[test]
    fn gamma_zero_is_next_step_prediction() {
        let mut ev = ReturnEval::new(0.0, 1e-4);
        for i in 0..3000 {
            let c = (i % 2) as f64;
            ev.push(0.5, c);
        }
        let errs = ev.drain();
        // G_t = c_{t+1}; y = 0.5 everywhere; error = 0.25 each step.
        assert!(!errs.is_empty());
        for &(_, e2) in &errs {
            assert!((e2 - 0.25).abs() < 1e-9);
        }
    }

    #[test]
    fn finish_flushes_tail() {
        let mut ev = ReturnEval::new(0.5, 1e-3);
        for _ in 0..10 {
            ev.push(1.0, 0.0);
        }
        ev.finish();
        let errs = ev.drain();
        assert_eq!(errs.len(), 10);
        // with all-zero cumulants, G = 0 and each error is 1.
        for &(_, e2) in &errs {
            assert!((e2 - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn emission_order_and_indices() {
        let mut ev = ReturnEval::new(0.9, 1e-2);
        let n = 5000;
        for i in 0..n {
            ev.push(i as f64, 0.0);
        }
        ev.finish();
        let errs = ev.drain();
        assert_eq!(errs.len(), n);
        for (i, &(t, _)) in errs.iter().enumerate() {
            assert_eq!(t, i as u64);
        }
    }

    #[test]
    fn constant_cumulant_return_is_geometric() {
        // c = 1 forever: G = 1/(1-gamma). Predicting exactly that gives ~0
        // error (up to truncation tolerance).
        let gamma = 0.9;
        let mut ev = ReturnEval::new(gamma, 1e-8);
        let g_inf = 1.0 / (1.0 - gamma);
        for _ in 0..4000 {
            ev.push(g_inf, 1.0);
        }
        let errs = ev.drain();
        assert!(!errs.is_empty());
        for &(_, e2) in &errs {
            assert!(e2 < 1e-6, "err {e2}");
        }
    }
}
