//! BlinkGrid: a pure-memory beacon task in the Atari feature format.
//! A beacon flashes at a random cell for exactly one frame; `delay` steps
//! later (delay is *signaled by the beacon's row*), a reward arrives. The
//! frame is dark in between — the only way to predict the reward timing
//! is to remember where and when the beacon flashed. This is trace
//! conditioning lifted into the 256-pixel observation space.

use super::{plot, Game, FRAME_W};
use crate::util::prng::Xoshiro256;

pub struct BlinkGrid {
    /// steps until the pending reward (None if idle)
    countdown: Option<u64>,
    /// steps until the next beacon flash
    next_flash: u64,
    rewards: u32,
    t: u64,
}

impl BlinkGrid {
    pub fn new() -> Self {
        Self {
            countdown: None,
            next_flash: 5,
            rewards: 0,
            t: 0,
        }
    }
}

impl Default for BlinkGrid {
    fn default() -> Self {
        Self::new()
    }
}

impl Game for BlinkGrid {
    fn reset(&mut self, rng: &mut Xoshiro256) {
        self.countdown = None;
        self.next_flash = rng.int_in(5, 20);
        self.rewards = 0;
        self.t = 0;
    }

    fn step(&mut self, rng: &mut Xoshiro256, frame: &mut [f32]) -> (usize, f32, bool) {
        self.t += 1;
        let mut reward = 0.0;
        let action = (self.t % 3) as usize + 10; // arbitrary cycling expert

        if let Some(cd) = self.countdown {
            if cd == 0 {
                reward = 1.0;
                self.rewards += 1;
                self.countdown = None;
                self.next_flash = rng.int_in(30, 60);
            } else {
                self.countdown = Some(cd - 1);
            }
        } else if self.next_flash == 0 {
            // flash: row encodes the delay (row r => delay 8 + r), column
            // random. One frame only.
            let row = rng.int_in(0, 7) as i32;
            let col = rng.int_in(0, FRAME_W as u64 - 1) as i32;
            plot(frame, col, row, 1.0);
            plot(frame, col, row + 8, 1.0); // mirrored blob, 2px signature
            self.countdown = Some(8 + row as u64);
        } else {
            self.next_flash -= 1;
        }

        let done = self.rewards >= 20;
        (action, reward, done)
    }

    fn name(&self) -> &'static str {
        "blinkgrid"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::env::synthatari::FRAME_SIZE;

    #[test]
    fn reward_follows_flash_by_row_coded_delay() {
        let mut g = BlinkGrid::new();
        let mut rng = Xoshiro256::seed_from_u64(0);
        g.reset(&mut rng);
        let mut frame = vec![0.0; FRAME_SIZE];
        let mut flash_t: Option<(u64, u64)> = None; // (time, delay)
        let mut checked = 0;
        for t in 0..20_000u64 {
            frame.fill(0.0);
            let (_, r, done) = g.step(&mut rng, &mut frame);
            // detect flash
            let lit: Vec<usize> = (0..FRAME_SIZE).filter(|&i| frame[i] > 0.0).collect();
            if !lit.is_empty() {
                let row = (lit[0] / FRAME_W) as u64;
                flash_t = Some((t, 8 + row));
            }
            if r > 0.0 {
                let (ft, delay) = flash_t.expect("reward without flash");
                assert_eq!(t - ft, delay + 1, "reward timing");
                checked += 1;
            }
            if done {
                g.reset(&mut rng);
                flash_t = None;
            }
        }
        assert!(checked > 50, "rewards checked: {checked}");
    }

    #[test]
    fn frame_dark_between_flashes() {
        let mut g = BlinkGrid::new();
        let mut rng = Xoshiro256::seed_from_u64(1);
        g.reset(&mut rng);
        let mut frame = vec![0.0; FRAME_SIZE];
        let mut dark = 0;
        let mut lit = 0;
        for _ in 0..1000 {
            frame.fill(0.0);
            g.step(&mut rng, &mut frame);
            if frame.iter().all(|&v| v == 0.0) {
                dark += 1;
            } else {
                lit += 1;
            }
        }
        assert!(dark > 900, "mostly dark: {dark}");
        assert!(lit > 5, "flashes happen: {lit}");
    }
}
