//! Breakout-like game: paddle at the bottom, ball, four brick rows.
//! Bricks are static (always rendered); the ball blinks every other
//! frame, so predicting the next reward (brick hit) requires integrating
//! the ball's trajectory over time.

use super::{plot, Game, FRAME_H, FRAME_W};
use crate::util::prng::Xoshiro256;

pub struct Breakout {
    ball_x: f32,
    ball_y: f32,
    vel_x: f32,
    vel_y: f32,
    pad_x: f32,
    /// bricks[row] is a 16-bit column mask, rows 2..=5
    bricks: [u16; 4],
    lives: u32,
    t: u64,
}

const BRICK_ROW0: usize = 2;

impl Breakout {
    pub fn new() -> Self {
        Self {
            ball_x: 8.0,
            ball_y: 10.0,
            vel_x: 0.5,
            vel_y: -0.7,
            pad_x: 8.0,
            bricks: [u16::MAX; 4],
            lives: 3,
            t: 0,
        }
    }

    fn serve(&mut self, rng: &mut Xoshiro256) {
        self.ball_x = rng.uniform(4.0, 12.0);
        self.ball_y = 10.0;
        self.vel_x = rng.uniform(-0.7, 0.7);
        self.vel_y = -0.7;
    }

    fn bricks_left(&self) -> u32 {
        self.bricks.iter().map(|b| b.count_ones()).sum()
    }
}

impl Default for Breakout {
    fn default() -> Self {
        Self::new()
    }
}

impl Game for Breakout {
    fn reset(&mut self, rng: &mut Xoshiro256) {
        self.bricks = [u16::MAX; 4];
        self.lives = 3;
        self.pad_x = 8.0;
        self.t = 0;
        self.serve(rng);
    }

    fn step(&mut self, rng: &mut Xoshiro256, frame: &mut [f32]) -> (usize, f32, bool) {
        self.t += 1;

        // expert: track ball x with noise; actions 0=noop 3=left 4=right
        let target = self.ball_x + rng.uniform(-1.0, 1.0);
        let action = if target > self.pad_x + 0.5 {
            self.pad_x = (self.pad_x + 1.0).min(FRAME_W as f32 - 2.0);
            4
        } else if target < self.pad_x - 0.5 {
            self.pad_x = (self.pad_x - 1.0).max(1.0);
            3
        } else {
            0
        };

        self.ball_x += self.vel_x;
        self.ball_y += self.vel_y;
        // side walls
        if self.ball_x <= 0.0 || self.ball_x >= FRAME_W as f32 - 1.0 {
            self.vel_x = -self.vel_x;
            self.ball_x = self.ball_x.clamp(0.0, FRAME_W as f32 - 1.0);
        }
        // ceiling
        if self.ball_y <= 0.0 {
            self.vel_y = self.vel_y.abs();
            self.ball_y = 0.0;
        }

        let mut reward = 0.0;
        let mut done = false;

        // brick collision
        let by = self.ball_y as i32;
        let bx = self.ball_x as i32;
        if (BRICK_ROW0 as i32..(BRICK_ROW0 + 4) as i32).contains(&by)
            && (0..16).contains(&bx)
        {
            let row = by as usize - BRICK_ROW0;
            let bit = 1u16 << bx;
            if self.bricks[row] & bit != 0 {
                self.bricks[row] &= !bit;
                reward = 1.0;
                self.vel_y = self.vel_y.abs(); // bounce down
                if self.bricks_left() == 0 {
                    done = true;
                }
            }
        }

        // paddle / floor
        if self.ball_y >= FRAME_H as f32 - 2.0 {
            if (self.ball_x - self.pad_x).abs() <= 2.0 {
                self.vel_y = -self.vel_y.abs();
                // english: hitting off-center skews vx
                self.vel_x += 0.3 * (self.ball_x - self.pad_x).signum();
                self.vel_x = self.vel_x.clamp(-0.9, 0.9);
            } else if self.ball_y >= FRAME_H as f32 - 1.0 {
                self.lives -= 1;
                reward = -1.0;
                if self.lives == 0 {
                    done = true;
                } else {
                    self.serve(rng);
                }
            }
        }

        // render: bricks always, paddle always, ball on odd frames only
        for (r, mask) in self.bricks.iter().enumerate() {
            for c in 0..16 {
                if mask & (1 << c) != 0 {
                    plot(frame, c as i32, (BRICK_ROW0 + r) as i32, 1.0);
                }
            }
        }
        for dx in -1..=1 {
            plot(frame, self.pad_x as i32 + dx, FRAME_H as i32 - 1, 1.0);
        }
        if self.t % 2 == 1 {
            plot(frame, self.ball_x as i32, self.ball_y as i32, 1.0);
        }

        (action, reward, done)
    }

    fn name(&self) -> &'static str {
        "breakout"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::env::synthatari::FRAME_SIZE;

    #[test]
    fn bricks_get_destroyed_and_reward_matches() {
        let mut g = Breakout::new();
        let mut rng = Xoshiro256::seed_from_u64(0);
        g.reset(&mut rng);
        let mut frame = vec![0.0; FRAME_SIZE];
        let before = g.bricks_left();
        let mut total_reward = 0.0;
        for _ in 0..5000 {
            frame.fill(0.0);
            let (_, r, done) = g.step(&mut rng, &mut frame);
            if r > 0.0 {
                total_reward += r;
            }
            if done {
                break;
            }
        }
        let destroyed = before - g.bricks_left();
        assert!(destroyed > 0, "no bricks destroyed");
        assert_eq!(destroyed as f64, total_reward as f64);
    }

    #[test]
    fn ball_blinks_every_other_frame() {
        let mut g = Breakout::new();
        let mut rng = Xoshiro256::seed_from_u64(3);
        g.reset(&mut rng);
        let mut f1 = vec![0.0; FRAME_SIZE];
        let mut counts = Vec::new();
        for _ in 0..100 {
            f1.fill(0.0);
            g.step(&mut rng, &mut f1);
            counts.push(f1.iter().filter(|&&v| v > 0.0).count());
        }
        // alternating pixel counts (ball present on odd t)
        let diffs: Vec<i64> = counts
            .windows(2)
            .map(|w| w[1] as i64 - w[0] as i64)
            .collect();
        assert!(diffs.iter().any(|&d| d != 0), "ball must blink");
    }

    #[test]
    fn game_ends_on_life_loss_or_clear() {
        let mut g = Breakout::new();
        let mut rng = Xoshiro256::seed_from_u64(5);
        g.reset(&mut rng);
        let mut frame = vec![0.0; FRAME_SIZE];
        for _ in 0..500_000 {
            let (_, _, done) = g.step(&mut rng, &mut frame);
            if done {
                return;
            }
        }
        panic!("episode never terminated");
    }
}
