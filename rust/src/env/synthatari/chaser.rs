//! Chaser: the expert pursues a fleeing prey on the grid. The prey is
//! rendered only every third frame; between glimpses the learner must
//! extrapolate its motion to predict the catch (reward +1).

use super::{plot, Game, FRAME_H, FRAME_W};
use crate::util::prng::Xoshiro256;

pub struct Chaser {
    agent_x: i32,
    agent_y: i32,
    prey_x: f32,
    prey_y: f32,
    prey_vx: f32,
    prey_vy: f32,
    catches: u32,
    t: u64,
}

impl Chaser {
    pub fn new() -> Self {
        Self {
            agent_x: 2,
            agent_y: 2,
            prey_x: 12.0,
            prey_y: 12.0,
            prey_vx: 0.4,
            prey_vy: -0.3,
            catches: 0,
            t: 0,
        }
    }

    fn respawn_prey(&mut self, rng: &mut Xoshiro256) {
        // spawn away from the agent
        loop {
            self.prey_x = rng.uniform(1.0, FRAME_W as f32 - 2.0);
            self.prey_y = rng.uniform(1.0, FRAME_H as f32 - 2.0);
            let dx = self.prey_x - self.agent_x as f32;
            let dy = self.prey_y - self.agent_y as f32;
            if dx * dx + dy * dy > 36.0 {
                break;
            }
        }
        self.prey_vx = rng.uniform(-0.6, 0.6);
        self.prey_vy = rng.uniform(-0.6, 0.6);
    }
}

impl Default for Chaser {
    fn default() -> Self {
        Self::new()
    }
}

impl Game for Chaser {
    fn reset(&mut self, rng: &mut Xoshiro256) {
        self.agent_x = 2;
        self.agent_y = 2;
        self.catches = 0;
        self.t = 0;
        self.respawn_prey(rng);
    }

    fn step(&mut self, rng: &mut Xoshiro256, frame: &mut [f32]) -> (usize, f32, bool) {
        self.t += 1;

        // expert: greedy step toward prey with 10% random move
        // actions: 6..=9 = N/S/E/W, 0 = noop
        let action;
        if rng.next_f32() < 0.1 {
            let dir = rng.below(4);
            action = 6 + dir as usize;
            match dir {
                0 => self.agent_y -= 1,
                1 => self.agent_y += 1,
                2 => self.agent_x += 1,
                _ => self.agent_x -= 1,
            }
        } else {
            let dx = self.prey_x - self.agent_x as f32;
            let dy = self.prey_y - self.agent_y as f32;
            if dx.abs() > dy.abs() {
                if dx > 0.0 {
                    self.agent_x += 1;
                    action = 8;
                } else {
                    self.agent_x -= 1;
                    action = 9;
                }
            } else if dy > 0.0 {
                self.agent_y += 1;
                action = 7;
            } else {
                self.agent_y -= 1;
                action = 6;
            }
        }
        self.agent_x = self.agent_x.clamp(0, FRAME_W as i32 - 1);
        self.agent_y = self.agent_y.clamp(0, FRAME_H as i32 - 1);

        // prey: drift + flee when close
        let dx = self.prey_x - self.agent_x as f32;
        let dy = self.prey_y - self.agent_y as f32;
        let dist2 = dx * dx + dy * dy;
        if dist2 < 16.0 && dist2 > 1e-6 {
            let norm = dist2.sqrt();
            self.prey_vx = 0.7 * dx / norm + rng.uniform(-0.2, 0.2);
            self.prey_vy = 0.7 * dy / norm + rng.uniform(-0.2, 0.2);
        }
        self.prey_x += self.prey_vx;
        self.prey_y += self.prey_vy;
        if self.prey_x <= 0.0 || self.prey_x >= FRAME_W as f32 - 1.0 {
            self.prey_vx = -self.prey_vx;
            self.prey_x = self.prey_x.clamp(0.0, FRAME_W as f32 - 1.0);
        }
        if self.prey_y <= 0.0 || self.prey_y >= FRAME_H as f32 - 1.0 {
            self.prey_vy = -self.prey_vy;
            self.prey_y = self.prey_y.clamp(0.0, FRAME_H as f32 - 1.0);
        }

        // catch?
        let mut reward = 0.0;
        let dx = self.prey_x - self.agent_x as f32;
        let dy = self.prey_y - self.agent_y as f32;
        if dx * dx + dy * dy <= 2.0 {
            reward = 1.0;
            self.catches += 1;
            self.respawn_prey(rng);
        }

        // render: agent always; prey every 3rd frame only
        plot(frame, self.agent_x, self.agent_y, 1.0);
        if self.t % 3 == 0 {
            plot(frame, self.prey_x as i32, self.prey_y as i32, 0.7);
        }

        let done = self.catches >= 8;
        (action, reward, done)
    }

    fn name(&self) -> &'static str {
        "chaser"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::env::synthatari::FRAME_SIZE;

    #[test]
    fn expert_catches_prey() {
        let mut g = Chaser::new();
        let mut rng = Xoshiro256::seed_from_u64(0);
        g.reset(&mut rng);
        let mut frame = vec![0.0; FRAME_SIZE];
        let mut catches = 0;
        for _ in 0..20_000 {
            frame.fill(0.0);
            let (_, r, done) = g.step(&mut rng, &mut frame);
            if r > 0.0 {
                catches += 1;
            }
            if done {
                g.reset(&mut rng);
            }
        }
        assert!(catches > 20, "catches: {catches}");
    }

    #[test]
    fn prey_visible_only_every_third_frame() {
        let mut g = Chaser::new();
        let mut rng = Xoshiro256::seed_from_u64(1);
        g.reset(&mut rng);
        let mut frame = vec![0.0; FRAME_SIZE];
        let mut with_prey = 0;
        for _ in 0..300 {
            frame.fill(0.0);
            g.step(&mut rng, &mut frame);
            let n = frame.iter().filter(|&&v| v > 0.0).count();
            if n >= 2 {
                with_prey += 1;
            }
        }
        assert!(with_prey >= 80 && with_prey <= 120, "prey frames: {with_prey}");
    }

    #[test]
    fn agent_stays_in_bounds() {
        let mut g = Chaser::new();
        let mut rng = Xoshiro256::seed_from_u64(2);
        g.reset(&mut rng);
        let mut frame = vec![0.0; FRAME_SIZE];
        for _ in 0..5000 {
            g.step(&mut rng, &mut frame);
            assert!((0..FRAME_W as i32).contains(&g.agent_x));
            assert!((0..FRAME_H as i32).contains(&g.agent_y));
        }
    }
}
