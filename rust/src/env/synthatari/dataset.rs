//! Dataset-as-simulator wrapper (the paper's Section 5.1 protocol).
//!
//! The paper collects >= 200k steps of expert play per game, then treats
//! the dataset as a simulator: stream episodes in order; after exhausting
//! them, shuffle the *episode order* and loop for another epoch. This
//! wrapper reproduces that protocol over any [`Stream`]. Frames are
//! stored quantized (u8) to keep a 200k-step dataset around ~56 MB.
//!
//! Our scripted experts are fixed policies, so live streaming (the
//! default in experiments) is distributionally equivalent; this wrapper
//! exists for protocol fidelity, reproducibility tests, and anywhere a
//! frozen dataset matters (e.g. exact replay comparisons across learners).

use super::super::Stream;
use crate::util::prng::Xoshiro256;

/// One recorded episode: features quantized to u8 per 1/255 steps.
struct Episode {
    /// [steps x n_features] quantized features
    xs: Vec<u8>,
    /// cumulants per step (f32, small)
    cs: Vec<f32>,
}

pub struct DatasetSim {
    n_features: usize,
    gamma: f32,
    name: &'static str,
    episodes: Vec<Episode>,
    order: Vec<usize>,
    rng: Xoshiro256,
    epi_idx: usize,
    step_idx: usize,
    pub epochs_completed: u64,
}

/// Quantize a feature in [-1, 1] to u8 (0..=255 over [-1, 1]).
#[inline]
fn quantize(v: f32) -> u8 {
    (((v.clamp(-1.0, 1.0) + 1.0) * 0.5) * 255.0).round() as u8
}

#[inline]
fn dequantize(q: u8) -> f32 {
    (q as f32 / 255.0) * 2.0 - 1.0
}

impl DatasetSim {
    /// Record at least `min_steps` from `src`, continuing to the end of
    /// the in-progress pseudo-episode (fixed-length chunks of
    /// `episode_len`, mirroring the paper's "keep collecting until the
    /// episode terminates").
    pub fn collect(
        src: &mut dyn Stream,
        min_steps: usize,
        episode_len: usize,
        seed: u64,
    ) -> Self {
        let n = src.n_features();
        let mut episodes = Vec::new();
        let mut collected = 0usize;
        let mut x = vec![0.0f32; n];
        while collected < min_steps {
            let mut ep = Episode {
                xs: Vec::with_capacity(episode_len * n),
                cs: Vec::with_capacity(episode_len),
            };
            for _ in 0..episode_len {
                let c = src.step_into(&mut x);
                ep.xs.extend(x.iter().map(|&v| quantize(v)));
                ep.cs.push(c);
                collected += 1;
            }
            episodes.push(ep);
        }
        let order: Vec<usize> = (0..episodes.len()).collect();
        Self {
            n_features: n,
            gamma: src.gamma(),
            name: src.name(),
            episodes,
            order,
            rng: Xoshiro256::seed_from_u64(seed ^ 0xDA7A),
            epi_idx: 0,
            step_idx: 0,
            epochs_completed: 0,
        }
    }

    pub fn total_steps(&self) -> usize {
        self.episodes.iter().map(|e| e.cs.len()).sum()
    }
}

impl Stream for DatasetSim {
    fn n_features(&self) -> usize {
        self.n_features
    }

    fn gamma(&self) -> f32 {
        self.gamma
    }

    fn name(&self) -> &'static str {
        self.name
    }

    fn step_into(&mut self, x: &mut [f32]) -> f32 {
        let ep = &self.episodes[self.order[self.epi_idx]];
        let n = self.n_features;
        let base = self.step_idx * n;
        for (i, xi) in x.iter_mut().enumerate() {
            *xi = dequantize(ep.xs[base + i]);
        }
        let c = ep.cs[self.step_idx];
        self.step_idx += 1;
        if self.step_idx >= ep.cs.len() {
            self.step_idx = 0;
            self.epi_idx += 1;
            if self.epi_idx >= self.order.len() {
                self.epi_idx = 0;
                self.epochs_completed += 1;
                // paper: shuffle episode order between epochs
                let mut order = std::mem::take(&mut self.order);
                self.rng.shuffle(&mut order);
                self.order = order;
            }
        }
        c
    }
}

#[cfg(test)]
mod tests {
    use super::super::make_env;
    use super::*;

    #[test]
    fn quantization_roundtrip_bounds() {
        for v in [-1.0f32, -0.5, 0.0, 0.25, 1.0] {
            let q = dequantize(quantize(v));
            assert!((q - v).abs() <= 1.0 / 255.0 + 1e-6, "{v} -> {q}");
        }
        // out-of-range clamps
        assert_eq!(quantize(2.0), 255);
        assert_eq!(quantize(-2.0), 0);
    }

    #[test]
    fn collect_and_replay_preserves_features() {
        let mut live = make_env("blinkgrid", 4).unwrap();
        let mut ds = DatasetSim::collect(&mut live, 2000, 500, 4);
        assert!(ds.total_steps() >= 2000);
        assert_eq!(ds.n_features(), 277);
        let mut x = vec![0.0; 277];
        for _ in 0..ds.total_steps() {
            let c = ds.step_into(&mut x);
            assert!((-1.0..=1.0).contains(&c));
            assert!(x.iter().all(|v| v.is_finite() && (-1.0..=1.0).contains(v)));
        }
    }

    #[test]
    fn epochs_shuffle_episode_order() {
        let mut live = make_env("pong", 5).unwrap();
        let mut ds = DatasetSim::collect(&mut live, 3000, 300, 5);
        let n = ds.total_steps();
        let mut x = vec![0.0; 277];
        // first epoch, in order
        let order_before = ds.order.clone();
        for _ in 0..n {
            ds.step_into(&mut x);
        }
        assert_eq!(ds.epochs_completed, 1);
        assert_ne!(ds.order, order_before, "order must shuffle between epochs");
        // replay still works for another epoch
        for _ in 0..n {
            ds.step_into(&mut x);
        }
        assert_eq!(ds.epochs_completed, 2);
    }

    #[test]
    fn first_epoch_matches_live_stream_quantized() {
        let mut live1 = make_env("chaser", 6).unwrap();
        let mut live2 = make_env("chaser", 6).unwrap();
        let ds_steps = 600;
        let mut ds = DatasetSim::collect(&mut live1, ds_steps, 200, 6);
        let mut x_live = vec![0.0; 277];
        let mut x_ds = vec![0.0; 277];
        for _ in 0..ds_steps {
            let c_live = live2.step_into(&mut x_live);
            let c_ds = ds.step_into(&mut x_ds);
            assert!((c_live - c_ds).abs() <= 1e-6);
            for (a, b) in x_live.iter().zip(&x_ds) {
                assert!((a - b).abs() <= 1.0 / 255.0 + 1e-6);
            }
        }
    }
}
