//! LatentDrift: a parametric family of partially observable games. The
//! latent state is a seed-random 2-oscillator system; two sprites render
//! its phase with variant-specific blink schedules, and the reward fires
//! when the latent phases align (a periodic but non-trivially observable
//! event). Variants `drift0..driftN` differ in frequencies, blink masks
//! and reward threshold — they stand in for "the other 45 Atari games" so
//! Figure 8's per-environment comparison has a population to range over.

use super::{plot, Game, FRAME_H, FRAME_W};
use crate::util::prng::Xoshiro256;

pub struct LatentDrift {
    // per-variant constants (fixed at construction)
    freq_a: f32,
    freq_b: f32,
    blink_a: u64,
    blink_b: u64,
    align_thresh: f32,
    // state
    phase_a: f32,
    phase_b: f32,
    cooldown: u64,
    rewards: u32,
    t: u64,
    variant: u64,
}

impl LatentDrift {
    pub fn new(variant: u64) -> Self {
        // derive variant constants deterministically
        let mut rng = Xoshiro256::seed_from_u64(0xD21F7 ^ variant.wrapping_mul(0x9E37));
        Self {
            freq_a: rng.uniform(0.05, 0.25),
            freq_b: rng.uniform(0.02, 0.15),
            blink_a: rng.int_in(2, 4),
            blink_b: rng.int_in(2, 5),
            align_thresh: rng.uniform(0.12, 0.3),
            phase_a: 0.0,
            phase_b: 0.0,
            cooldown: 0,
            rewards: 0,
            t: 0,
            variant,
        }
    }
}

impl Game for LatentDrift {
    fn reset(&mut self, rng: &mut Xoshiro256) {
        self.phase_a = rng.uniform(0.0, std::f32::consts::TAU);
        self.phase_b = rng.uniform(0.0, std::f32::consts::TAU);
        self.cooldown = 0;
        self.rewards = 0;
        self.t = 0;
    }

    fn step(&mut self, rng: &mut Xoshiro256, frame: &mut [f32]) -> (usize, f32, bool) {
        self.t += 1;
        self.phase_a += self.freq_a + rng.uniform(-0.005, 0.005);
        self.phase_b += self.freq_b + rng.uniform(-0.005, 0.005);
        if self.phase_a > std::f32::consts::TAU {
            self.phase_a -= std::f32::consts::TAU;
        }
        if self.phase_b > std::f32::consts::TAU {
            self.phase_b -= std::f32::consts::TAU;
        }

        // sprites trace circles; each has its own blink schedule
        let ax = 8.0 + 5.0 * self.phase_a.cos();
        let ay = 8.0 + 5.0 * self.phase_a.sin();
        let bx = 8.0 + 3.0 * self.phase_b.cos();
        let by = 8.0 + 3.0 * self.phase_b.sin();
        if self.t % self.blink_a != 0 {
            plot(frame, ax as i32, ay as i32, 1.0);
        }
        if self.t % self.blink_b == 0 {
            plot(frame, bx as i32, by as i32, 0.6);
        }
        // static corner markers so the frame is never empty
        plot(frame, 0, 0, 0.3);
        plot(frame, FRAME_W as i32 - 1, FRAME_H as i32 - 1, 0.3);

        // reward when phases align (within threshold) and off cooldown
        let mut reward = 0.0;
        let diff = (self.phase_a - self.phase_b).rem_euclid(std::f32::consts::TAU);
        let aligned = diff.min(std::f32::consts::TAU - diff) < self.align_thresh;
        if self.cooldown > 0 {
            self.cooldown -= 1;
        } else if aligned {
            reward = 1.0;
            self.rewards += 1;
            self.cooldown = 25;
        }

        let action = ((self.t / 4) % 5) as usize + 15; // cycling expert
        let done = self.rewards >= 15;
        (action, reward, done)
    }

    fn name(&self) -> &'static str {
        match self.variant {
            0 => "drift0",
            1 => "drift1",
            2 => "drift2",
            3 => "drift3",
            4 => "drift4",
            _ => "driftN",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::env::synthatari::FRAME_SIZE;

    #[test]
    fn variants_differ() {
        let a = LatentDrift::new(0);
        let b = LatentDrift::new(1);
        assert!(
            (a.freq_a - b.freq_a).abs() > 1e-6
                || (a.freq_b - b.freq_b).abs() > 1e-6,
            "variants must have different dynamics"
        );
    }

    #[test]
    fn rewards_periodic_with_cooldown() {
        let mut g = LatentDrift::new(0);
        let mut rng = Xoshiro256::seed_from_u64(0);
        g.reset(&mut rng);
        let mut frame = vec![0.0; FRAME_SIZE];
        let mut n_rewards = 0usize;
        let mut last_reward: Option<u64> = None;
        for t in 0..20_000u64 {
            frame.fill(0.0);
            let (_, r, done) = g.step(&mut rng, &mut frame);
            if r > 0.0 {
                if let Some(prev) = last_reward {
                    assert!(t - prev > 25, "cooldown enforced within episode");
                }
                last_reward = Some(t);
                n_rewards += 1;
            }
            if done {
                g.reset(&mut rng);
                last_reward = None; // cooldown does not span episodes
            }
        }
        assert!(n_rewards > 10, "rewards: {n_rewards}");
    }

    #[test]
    fn deterministic_per_variant_and_seed() {
        for variant in 0..3 {
            let mut g1 = LatentDrift::new(variant);
            let mut g2 = LatentDrift::new(variant);
            let mut r1 = Xoshiro256::seed_from_u64(7);
            let mut r2 = Xoshiro256::seed_from_u64(7);
            g1.reset(&mut r1);
            g2.reset(&mut r2);
            let mut f1 = vec![0.0; FRAME_SIZE];
            let mut f2 = vec![0.0; FRAME_SIZE];
            for _ in 0..500 {
                f1.fill(0.0);
                f2.fill(0.0);
                let s1 = g1.step(&mut r1, &mut f1);
                let s2 = g2.step(&mut r2, &mut f2);
                assert_eq!(s1, s2);
                assert_eq!(f1, f2);
            }
        }
    }
}
