//! Freeway-like game: the expert's chicken crosses ten lanes of traffic.
//! Cars are rendered only on even frames (downscale aliasing), so
//! predicting an imminent collision (negative reward) needs trajectory
//! memory. Reward +1 for reaching the top, -1 on collision (knocked back).

use super::{plot, Game, FRAME_H, FRAME_W};
use crate::util::prng::Xoshiro256;

const N_LANES: usize = 10;
const LANE_ROW0: usize = 3;
const CHICKEN_COL: i32 = 8;

pub struct Freeway {
    chicken_y: i32,
    /// car position per lane (float column) and speed (px/step, signed)
    car_x: [f32; N_LANES],
    car_v: [f32; N_LANES],
    crossings: u32,
    t: u64,
}

impl Freeway {
    pub fn new() -> Self {
        Self {
            chicken_y: FRAME_H as i32 - 1,
            car_x: [0.0; N_LANES],
            car_v: [0.0; N_LANES],
            crossings: 0,
            t: 0,
        }
    }

    fn lane_row(lane: usize) -> i32 {
        (LANE_ROW0 + lane) as i32
    }
}

impl Default for Freeway {
    fn default() -> Self {
        Self::new()
    }
}

impl Game for Freeway {
    fn reset(&mut self, rng: &mut Xoshiro256) {
        self.chicken_y = FRAME_H as i32 - 1;
        self.crossings = 0;
        self.t = 0;
        for lane in 0..N_LANES {
            self.car_x[lane] = rng.uniform(0.0, FRAME_W as f32);
            let speed = rng.uniform(0.3, 1.1);
            self.car_v[lane] = if lane % 2 == 0 { speed } else { -speed };
        }
    }

    fn step(&mut self, rng: &mut Xoshiro256, frame: &mut [f32]) -> (usize, f32, bool) {
        self.t += 1;

        // expert: advance when the next lane is clear over a short
        // lookahead; if a car is bearing down on the *current* lane, flee
        // upward regardless. A little stochastic impatience keeps
        // occasional collisions in the data (as with a real policy).
        let lane_unsafe = |row: i32, horizon: u64| -> bool {
            for lane in 0..N_LANES {
                if Self::lane_row(lane) == row {
                    for lookahead in 0..=horizon {
                        let cx = (self.car_x[lane] + self.car_v[lane] * lookahead as f32)
                            .rem_euclid(FRAME_W as f32);
                        if (cx - CHICKEN_COL as f32).abs() < 2.5 {
                            return true;
                        }
                    }
                }
            }
            false
        };
        let next_unsafe = lane_unsafe(self.chicken_y - 1, 4);
        let here_unsafe = lane_unsafe(self.chicken_y, 2);
        let action = if !next_unsafe || rng.next_f32() < 0.01 {
            self.chicken_y = (self.chicken_y - 1).max(0);
            5 // up
        } else if here_unsafe {
            // both ahead and here are hot: retreat one row
            self.chicken_y = (self.chicken_y + 1).min(FRAME_H as i32 - 1);
            6 // down
        } else {
            0 // noop
        };

        // cars advance (wrap around)
        for lane in 0..N_LANES {
            self.car_x[lane] += self.car_v[lane];
            if self.car_x[lane] < 0.0 {
                self.car_x[lane] += FRAME_W as f32;
            }
            if self.car_x[lane] >= FRAME_W as f32 {
                self.car_x[lane] -= FRAME_W as f32;
            }
        }

        let mut reward = 0.0;
        // collision check
        for lane in 0..N_LANES {
            if Self::lane_row(lane) == self.chicken_y
                && (self.car_x[lane] - CHICKEN_COL as f32).abs() < 1.5
            {
                reward = -1.0;
                self.chicken_y = (self.chicken_y + 4).min(FRAME_H as i32 - 1);
            }
        }
        // crossing
        if self.chicken_y == 0 {
            reward = 1.0;
            self.crossings += 1;
            self.chicken_y = FRAME_H as i32 - 1;
        }

        // render: chicken always; cars only on even frames (aliasing)
        plot(frame, CHICKEN_COL, self.chicken_y, 1.0);
        if self.t % 2 == 0 {
            for lane in 0..N_LANES {
                let row = Self::lane_row(lane);
                plot(frame, self.car_x[lane] as i32, row, 1.0);
                plot(frame, self.car_x[lane] as i32 + 1, row, 1.0);
            }
        }

        let done = self.crossings >= 10;
        (action, reward, done)
    }

    fn name(&self) -> &'static str {
        "freeway"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::env::synthatari::FRAME_SIZE;

    #[test]
    fn chicken_crosses_and_collides() {
        let mut g = Freeway::new();
        let mut rng = Xoshiro256::seed_from_u64(0);
        g.reset(&mut rng);
        let mut frame = vec![0.0; FRAME_SIZE];
        let (mut cross, mut hit) = (0, 0);
        for _ in 0..50_000 {
            frame.fill(0.0);
            let (_, r, done) = g.step(&mut rng, &mut frame);
            if r > 0.0 {
                cross += 1;
            }
            if r < 0.0 {
                hit += 1;
            }
            if done {
                g.reset(&mut rng);
            }
        }
        eprintln!("freeway balance: cross={cross} hit={hit}");
        assert!(cross > 10, "crossings: {cross}");
        assert!(hit > 0, "collisions: {hit}");
        assert!(cross > hit, "expert should cross more than it crashes");
    }

    #[test]
    fn cars_aliased_on_odd_frames() {
        let mut g = Freeway::new();
        let mut rng = Xoshiro256::seed_from_u64(1);
        g.reset(&mut rng);
        let mut frame = vec![0.0; FRAME_SIZE];
        let mut odd_pixels = Vec::new();
        let mut even_pixels = Vec::new();
        for i in 0..100 {
            frame.fill(0.0);
            g.step(&mut rng, &mut frame);
            let n = frame.iter().filter(|&&v| v > 0.0).count();
            if (i + 1) % 2 == 0 {
                even_pixels.push(n);
            } else {
                odd_pixels.push(n);
            }
        }
        let avg_even: f64 =
            even_pixels.iter().sum::<usize>() as f64 / even_pixels.len() as f64;
        let avg_odd: f64 =
            odd_pixels.iter().sum::<usize>() as f64 / odd_pixels.len() as f64;
        assert!(avg_even > avg_odd + 5.0, "cars must blink: {avg_even} vs {avg_odd}");
    }
}
