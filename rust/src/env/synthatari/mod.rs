//! Synthetic Atari-prediction benchmark (substitute for ALE + pre-trained
//! Rainbow-DQN agents — see DESIGN.md §Substitutions).
//!
//! The paper's benchmark exists to pose *high-dimensional, partially
//! observable* prediction problems: 16x16 downscaled single frames (no
//! frame stacking), the expert's action one-hot, and the clipped reward.
//! Single frames are insufficient (the Pong ball is often invisible);
//! accurate prediction requires remembering the trajectory.
//!
//! We reproduce exactly that interface with synthetic games: each
//! [`Game`] is a small latent-state simulator with a *scripted expert
//! policy*, rendering to a 16x16 frame in which moving objects are
//! deliberately rendered intermittently (blink/aliasing) so the stream is
//! genuinely partially observable. The learner-facing vector is
//!
//! ```text
//! x_t = [ frame_t (256) | one-hot action_{t-1} (20) | r_{t-1} (1) ]
//! ```
//!
//! with cumulant c_t = r_{t-1} (clipped to [-1, 1]), discount 0.98 —
//! matching Section 5's 277 features.

pub mod blinkgrid;
pub mod breakout;
pub mod chaser;
pub mod dataset;
pub mod drift;
pub mod freeway;
pub mod pong;

use super::Stream;
use crate::util::prng::Xoshiro256;

pub const FRAME_W: usize = 16;
pub const FRAME_H: usize = 16;
pub const FRAME_SIZE: usize = FRAME_W * FRAME_H;
pub const N_ACTIONS: usize = 20;
pub const N_FEATURES: usize = FRAME_SIZE + N_ACTIONS + 1; // 277
pub const REWARD_INDEX: usize = N_FEATURES - 1;
pub const GAMMA: f32 = 0.98;

/// One latent-state game with a scripted expert policy.
pub trait Game: Send {
    /// Reset to the start of an episode.
    fn reset(&mut self, rng: &mut Xoshiro256);

    /// Advance one step with the expert policy. Renders the (partially
    /// observable) frame into `frame` and returns (action, reward, done).
    fn step(&mut self, rng: &mut Xoshiro256, frame: &mut [f32]) -> (usize, f32, bool);

    fn name(&self) -> &'static str;
}

/// Plot a pixel if inside the frame (row-major).
#[inline]
pub fn plot(frame: &mut [f32], x: i32, y: i32, v: f32) {
    if (0..FRAME_W as i32).contains(&x) && (0..FRAME_H as i32).contains(&y) {
        frame[y as usize * FRAME_W + x as usize] = v;
    }
}

/// Wraps a [`Game`] into the 277-feature prediction [`Stream`].
pub struct AtariStream {
    game: Box<dyn Game>,
    rng: Xoshiro256,
    prev_action: usize,
    prev_reward: f32,
    episode_steps: u64,
    max_episode_steps: u64,
}

impl AtariStream {
    pub fn new(mut game: Box<dyn Game>, seed: u64) -> Self {
        let mut rng = Xoshiro256::seed_from_u64(seed ^ 0x6174_6172); // "atar"
        game.reset(&mut rng);
        Self {
            game,
            rng,
            prev_action: 0,
            prev_reward: 0.0,
            episode_steps: 0,
            max_episode_steps: 2000,
        }
    }

    pub fn game_name(&self) -> &'static str {
        self.game.name()
    }
}

impl Stream for AtariStream {
    fn n_features(&self) -> usize {
        N_FEATURES
    }

    fn gamma(&self) -> f32 {
        GAMMA
    }

    fn name(&self) -> &'static str {
        self.game.name()
    }

    fn step_into(&mut self, x: &mut [f32]) -> f32 {
        debug_assert_eq!(x.len(), N_FEATURES);
        x.fill(0.0);
        let (frame, rest) = x.split_at_mut(FRAME_SIZE);
        let (action, reward, done) = self.game.step(&mut self.rng, frame);
        // previous action/reward channels (the learner sees a_{t-1}, r_{t-1})
        rest[self.prev_action.min(N_ACTIONS - 1)] = 1.0;
        let c = self.prev_reward.clamp(-1.0, 1.0);
        rest[N_ACTIONS] = c;
        self.prev_action = action;
        self.prev_reward = reward;
        self.episode_steps += 1;
        if done || self.episode_steps >= self.max_episode_steps {
            self.game.reset(&mut self.rng);
            self.episode_steps = 0;
        }
        c
    }
}

/// All environments of the benchmark suite (analogous to the paper's
/// per-game evaluation of Figure 8).
pub fn env_names() -> Vec<&'static str> {
    vec![
        "pong", "breakout", "freeway", "chaser", "blinkgrid",
        "drift0", "drift1", "drift2", "drift3", "drift4",
    ]
}

/// Construct a named environment stream.
pub fn make_env(name: &str, seed: u64) -> Option<AtariStream> {
    let game: Box<dyn Game> = match name {
        "pong" => Box::new(pong::Pong::new()),
        "breakout" => Box::new(breakout::Breakout::new()),
        "freeway" => Box::new(freeway::Freeway::new()),
        "chaser" => Box::new(chaser::Chaser::new()),
        "blinkgrid" => Box::new(blinkgrid::BlinkGrid::new()),
        _ => {
            if let Some(idx) = name.strip_prefix("drift") {
                let variant: u64 = idx.parse().ok()?;
                Box::new(drift::LatentDrift::new(variant))
            } else {
                return None;
            }
        }
    };
    Some(AtariStream::new(game, seed))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_builds_every_env() {
        for name in env_names() {
            let mut env = make_env(name, 0).unwrap_or_else(|| panic!("{name}"));
            assert_eq!(env.n_features(), 277);
            let mut x = vec![0.0; N_FEATURES];
            for _ in 0..200 {
                let c = env.step_into(&mut x);
                assert!((-1.0..=1.0).contains(&c), "{name}: cumulant {c}");
                assert_eq!(c, x[REWARD_INDEX]);
                assert!(x.iter().all(|v| v.is_finite()));
            }
        }
    }

    #[test]
    fn one_hot_action_channel() {
        let mut env = make_env("pong", 1).unwrap();
        let mut x = vec![0.0; N_FEATURES];
        for _ in 0..500 {
            env.step_into(&mut x);
            let ones: usize = (FRAME_SIZE..FRAME_SIZE + N_ACTIONS)
                .filter(|&i| x[i] == 1.0)
                .count();
            assert_eq!(ones, 1, "exactly one action bit set");
        }
    }

    #[test]
    fn frames_are_partially_observable() {
        // Over a window, the pixel count must vary (objects blink) for the
        // moving-sprite games — otherwise the task degenerates to MDP.
        for name in ["pong", "breakout", "chaser"] {
            let mut env = make_env(name, 2).unwrap();
            let mut x = vec![0.0; N_FEATURES];
            let mut counts = Vec::new();
            for _ in 0..300 {
                env.step_into(&mut x);
                counts.push(
                    x[..FRAME_SIZE].iter().filter(|&&v| v > 0.0).count(),
                );
            }
            let min = counts.iter().min().unwrap();
            let max = counts.iter().max().unwrap();
            assert!(max > min, "{name}: pixel count constant at {min}");
        }
    }

    #[test]
    fn rewards_occur() {
        for name in env_names() {
            let mut env = make_env(name, 3).unwrap();
            let mut x = vec![0.0; N_FEATURES];
            let mut nonzero = 0;
            for _ in 0..20_000 {
                if env.step_into(&mut x) != 0.0 {
                    nonzero += 1;
                }
            }
            assert!(nonzero > 0, "{name}: no rewards in 20k steps");
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let mut a = make_env("freeway", 9).unwrap();
        let mut b = make_env("freeway", 9).unwrap();
        let mut xa = vec![0.0; N_FEATURES];
        let mut xb = vec![0.0; N_FEATURES];
        for _ in 0..1000 {
            let ca = a.step_into(&mut xa);
            let cb = b.step_into(&mut xb);
            assert_eq!(ca, cb);
            assert_eq!(xa, xb);
        }
    }
}
