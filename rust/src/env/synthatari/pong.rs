//! Pong-like game. The ball is rendered only on 2 of every 3 frames —
//! exactly the property the paper highlights for downscaled Pong ("the
//! ball or paddles are not visible in many frames"). The expert tracks
//! the ball with small noise; the scripted opponent is slightly weaker,
//! so the expert scores more often than it concedes (positive return).

use super::{plot, Game, FRAME_H, FRAME_W};
use crate::util::prng::Xoshiro256;

pub struct Pong {
    ball_x: f32,
    ball_y: f32,
    vel_x: f32,
    vel_y: f32,
    /// expert paddle (left column), center row
    pad_l: f32,
    /// opponent paddle (right column)
    pad_r: f32,
    t: u64,
    score_l: u32,
    score_r: u32,
}

const PAD_HALF: f32 = 1.5;
const MAX_SCORE: u32 = 5;

impl Pong {
    pub fn new() -> Self {
        Self {
            ball_x: 8.0,
            ball_y: 8.0,
            vel_x: 0.7,
            vel_y: 0.3,
            pad_l: 8.0,
            pad_r: 8.0,
            t: 0,
            score_l: 0,
            score_r: 0,
        }
    }

    fn serve(&mut self, rng: &mut Xoshiro256, toward_left: bool) {
        self.ball_x = 8.0;
        self.ball_y = rng.uniform(3.0, 12.0);
        self.vel_x = if toward_left { -0.7 } else { 0.7 };
        self.vel_y = rng.uniform(-0.5, 0.5);
    }
}

impl Default for Pong {
    fn default() -> Self {
        Self::new()
    }
}

impl Game for Pong {
    fn reset(&mut self, rng: &mut Xoshiro256) {
        self.pad_l = 8.0;
        self.pad_r = 8.0;
        self.score_l = 0;
        self.score_r = 0;
        self.t = 0;
        let toward_left = rng.next_u64() & 1 == 0;
        self.serve(rng, toward_left);
    }

    fn step(&mut self, rng: &mut Xoshiro256, frame: &mut [f32]) -> (usize, f32, bool) {
        self.t += 1;

        // --- expert policy: track the ball with noise; actions 0/1/2 ---
        let target = self.ball_y + rng.uniform(-1.0, 1.0);
        let action = if target > self.pad_l + 0.5 {
            self.pad_l = (self.pad_l + 1.0).min(FRAME_H as f32 - 2.0);
            2 // down
        } else if target < self.pad_l - 0.5 {
            self.pad_l = (self.pad_l - 1.0).max(1.0);
            1 // up
        } else {
            0 // noop
        };

        // --- opponent: slower tracking (0.6 px/step) + more noise ---
        let opp_target = self.ball_y + rng.uniform(-2.5, 2.5);
        if opp_target > self.pad_r + 0.5 {
            self.pad_r = (self.pad_r + 0.6).min(FRAME_H as f32 - 2.0);
        } else if opp_target < self.pad_r - 0.5 {
            self.pad_r = (self.pad_r - 0.6).max(1.0);
        }

        // --- ball physics ---
        self.ball_x += self.vel_x;
        self.ball_y += self.vel_y;
        if self.ball_y <= 0.0 || self.ball_y >= FRAME_H as f32 - 1.0 {
            self.vel_y = -self.vel_y;
            self.ball_y = self.ball_y.clamp(0.0, FRAME_H as f32 - 1.0);
        }

        let mut reward = 0.0;
        // left wall: expert must intercept
        if self.ball_x <= 1.0 {
            if (self.ball_y - self.pad_l).abs() <= PAD_HALF + 0.5 {
                self.vel_x = self.vel_x.abs();
                self.vel_y += rng.uniform(-0.2, 0.2);
            } else {
                reward = -1.0;
                self.score_r += 1;
                self.serve(rng, false);
            }
        }
        // right wall: opponent intercepts
        if self.ball_x >= FRAME_W as f32 - 2.0 {
            if (self.ball_y - self.pad_r).abs() <= PAD_HALF + 0.5 {
                self.vel_x = -self.vel_x.abs();
                self.vel_y += rng.uniform(-0.2, 0.2);
            } else {
                reward = 1.0;
                self.score_l += 1;
                self.serve(rng, true);
            }
        }

        // --- render (partially observable) ---
        for dy in -1..=1 {
            plot(frame, 0, self.pad_l as i32 + dy, 1.0);
            plot(frame, FRAME_W as i32 - 1, self.pad_r as i32 + dy, 1.0);
        }
        // ball blinks: invisible every 3rd frame
        if self.t % 3 != 0 {
            plot(frame, self.ball_x as i32, self.ball_y as i32, 1.0);
        }

        let done = self.score_l >= MAX_SCORE || self.score_r >= MAX_SCORE;
        (action, reward, done)
    }

    fn name(&self) -> &'static str {
        "pong"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::env::synthatari::FRAME_SIZE;

    #[test]
    fn expert_scores_more_than_it_concedes() {
        let mut g = Pong::new();
        let mut rng = Xoshiro256::seed_from_u64(0);
        g.reset(&mut rng);
        let mut frame = vec![0.0; FRAME_SIZE];
        let (mut plus, mut minus) = (0, 0);
        for _ in 0..60_000 {
            frame.fill(0.0);
            let (_, r, done) = g.step(&mut rng, &mut frame);
            if r > 0.0 {
                plus += 1;
            }
            if r < 0.0 {
                minus += 1;
            }
            if done {
                g.reset(&mut rng);
            }
        }
        assert!(plus > 0 && minus > 0, "both sides should score: +{plus} -{minus}");
        assert!(plus > minus, "expert should win on average: +{plus} -{minus}");
    }

    #[test]
    fn ball_blinks() {
        let mut g = Pong::new();
        let mut rng = Xoshiro256::seed_from_u64(1);
        g.reset(&mut rng);
        let mut frame = vec![0.0; FRAME_SIZE];
        let mut visible = 0;
        let mut hidden = 0;
        for _ in 0..300 {
            frame.fill(0.0);
            g.step(&mut rng, &mut frame);
            // paddles contribute 6 pixels (possibly fewer at edges)
            let pixels = frame.iter().filter(|&&v| v > 0.0).count();
            if pixels > 6 {
                visible += 1;
            } else {
                hidden += 1;
            }
        }
        assert!(visible > 100, "ball mostly visible: {visible}");
        assert!(hidden > 50, "ball hidden on ~1/3 frames: {hidden}");
    }

    #[test]
    fn episode_terminates() {
        let mut g = Pong::new();
        let mut rng = Xoshiro256::seed_from_u64(2);
        g.reset(&mut rng);
        let mut frame = vec![0.0; FRAME_SIZE];
        let mut done_seen = false;
        for _ in 0..200_000 {
            let (_, _, done) = g.step(&mut rng, &mut frame);
            if done {
                done_seen = true;
                break;
            }
        }
        assert!(done_seen);
    }
}
