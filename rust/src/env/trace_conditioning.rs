//! Trace conditioning: the single-stimulus ancestor of trace patterning
//! (Rafiee et al. 2022). One CS feature, one US feature; every CS is
//! followed by the US after ISI steps. Pure memory, no discrimination —
//! used as a fast diagnostic that a learner can bridge a delay at all.

use super::{OracleReturn, Stream};
use crate::util::prng::Xoshiro256;

#[derive(Clone, Debug)]
pub struct TraceConditioningConfig {
    pub isi_min: u64,
    pub isi_max: u64,
    pub iti_min: u64,
    pub iti_max: u64,
    pub gamma: f32,
}

impl Default for TraceConditioningConfig {
    fn default() -> Self {
        Self {
            isi_min: 10,
            isi_max: 20,
            iti_min: 50,
            iti_max: 80,
            gamma: 0.9,
        }
    }
}

pub const N_FEATURES: usize = 2;
pub const US_INDEX: usize = 1;

enum Phase {
    Cs,
    Isi { remaining: u64 },
    Us,
    Iti { remaining: u64 },
}

pub struct TraceConditioning {
    cfg: TraceConditioningConfig,
    rng: Xoshiro256,
    phase: Phase,
}

impl TraceConditioning {
    pub fn new(cfg: TraceConditioningConfig, seed: u64) -> Self {
        Self {
            cfg,
            rng: Xoshiro256::seed_from_u64(seed ^ 0x636f_6e64), // "cond"
            phase: Phase::Cs,
        }
    }
}

impl Stream for TraceConditioning {
    fn n_features(&self) -> usize {
        N_FEATURES
    }

    fn gamma(&self) -> f32 {
        self.cfg.gamma
    }

    fn name(&self) -> &'static str {
        "trace_conditioning"
    }

    fn step_into(&mut self, x: &mut [f32]) -> f32 {
        x.fill(0.0);
        match self.phase {
            Phase::Cs => {
                x[0] = 1.0;
                let isi = self.rng.int_in(self.cfg.isi_min, self.cfg.isi_max);
                self.phase = Phase::Isi { remaining: isi };
                0.0
            }
            Phase::Isi { remaining } => {
                self.phase = if remaining > 1 {
                    Phase::Isi {
                        remaining: remaining - 1,
                    }
                } else {
                    Phase::Us
                };
                0.0
            }
            Phase::Us => {
                x[US_INDEX] = 1.0;
                let iti = self.rng.int_in(self.cfg.iti_min, self.cfg.iti_max);
                self.phase = Phase::Iti { remaining: iti };
                1.0
            }
            Phase::Iti { remaining } => {
                self.phase = if remaining > 1 {
                    Phase::Iti {
                        remaining: remaining - 1,
                    }
                } else {
                    Phase::Cs
                };
                0.0
            }
        }
    }
}

impl OracleReturn for TraceConditioning {
    fn oracle_return(&self) -> Option<f64> {
        match self.phase {
            Phase::Isi { remaining } => {
                Some((self.cfg.gamma as f64).powi(remaining as i32 - 1))
            }
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_cs_followed_by_us() {
        let mut env = TraceConditioning::new(TraceConditioningConfig::default(), 3);
        let mut x = vec![0.0; 2];
        let mut cs_count = 0;
        let mut us_count = 0;
        for _ in 0..50_000 {
            let us = env.step_into(&mut x);
            if x[0] == 1.0 {
                cs_count += 1;
            }
            if us == 1.0 {
                us_count += 1;
            }
        }
        assert!(cs_count > 100);
        assert!((cs_count as i64 - us_count as i64).abs() <= 1);
    }

    #[test]
    fn isi_within_bounds() {
        let cfg = TraceConditioningConfig::default();
        let mut env = TraceConditioning::new(cfg.clone(), 5);
        let mut x = vec![0.0; 2];
        let mut last_cs = None;
        for t in 0..50_000u64 {
            let us = env.step_into(&mut x);
            if x[0] == 1.0 {
                last_cs = Some(t);
            }
            if us == 1.0 {
                let isi = t - last_cs.unwrap() - 1;
                assert!((cfg.isi_min..=cfg.isi_max).contains(&isi));
            }
        }
    }

    #[test]
    fn oracle_only_during_isi() {
        let mut env = TraceConditioning::new(TraceConditioningConfig::default(), 7);
        let mut x = vec![0.0; 2];
        for _ in 0..1000 {
            let us = env.step_into(&mut x);
            if us == 1.0 {
                assert!(env.oracle_return().is_none());
            }
            if let Some(g) = env.oracle_return() {
                assert!(g > 0.0 && g <= 1.0);
            }
        }
    }
}
