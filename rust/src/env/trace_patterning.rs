//! Trace patterning (paper Section 4; Rafiee et al. 2022).
//!
//! Stream of 7 features: 6 conditional-stimulus (CS) features + 1
//! unconditional-stimulus (US) feature. Each *trial*:
//!
//! 1. a CS pattern (3 of the 6 features set to one; C(6,3) = 20 patterns)
//!    is shown for `cs_duration` steps,
//! 2. an inter-stimulus interval (ISI ~ U[isi_min, isi_max]) of silence,
//! 3. if the pattern is one of the 10 (randomly chosen per seed)
//!    *activating* patterns, US = 1 for one step; otherwise nothing,
//! 4. an inter-trial interval (ITI ~ U[iti_min, iti_max]) of silence.
//!
//! The cumulant is the US feature; the only way to predict it is to
//! remember *which* pattern appeared ISI steps ago — a pattern
//! discrimination plus a memory task.
//!
//! The exact expected return is computable (the generator knows the trial
//! schedule), so this stream also implements [`OracleReturn`], which the
//! tests use to validate [`super::returns::ReturnEval`] end to end.

use super::{OracleReturn, Stream};
use crate::util::prng::Xoshiro256;

#[derive(Clone, Debug)]
pub struct TracePatterningConfig {
    pub isi_min: u64,
    pub isi_max: u64,
    pub iti_min: u64,
    pub iti_max: u64,
    pub cs_duration: u64,
    pub gamma: f32,
}

impl Default for TracePatterningConfig {
    /// Paper values: ISI ~ U[14,26], ITI ~ U[80,120], gamma = 0.9.
    fn default() -> Self {
        Self {
            isi_min: 14,
            isi_max: 26,
            iti_min: 80,
            iti_max: 120,
            cs_duration: 1,
            gamma: 0.9,
        }
    }
}

impl TracePatterningConfig {
    /// Small intervals for fast tests (matches the paper's Fig-3 sketch).
    pub fn tiny() -> Self {
        Self {
            isi_min: 3,
            isi_max: 3,
            iti_min: 7,
            iti_max: 7,
            cs_duration: 1,
            gamma: 0.9,
        }
    }
}

#[derive(Clone, Copy, Debug, PartialEq)]
enum Phase {
    /// Showing the CS pattern; counter counts remaining CS steps.
    Cs { remaining: u64 },
    /// Waiting out the ISI; if `activate`, US fires at the end.
    Isi { remaining: u64, activate: bool },
    /// The US step itself (1 step; US=1 iff activate).
    Us { activate: bool },
    /// Inter-trial silence.
    Iti { remaining: u64 },
}

pub struct TracePatterning {
    cfg: TracePatterningConfig,
    rng: Xoshiro256,
    /// All 20 patterns as feature-index triples.
    patterns: Vec<[usize; 3]>,
    /// patterns[i] activates the US iff activating[i].
    activating: Vec<bool>,
    phase: Phase,
    current_pattern: usize,
}

pub const N_FEATURES: usize = 7;
pub const US_INDEX: usize = 6;

fn all_patterns() -> Vec<[usize; 3]> {
    let mut out = Vec::with_capacity(20);
    for a in 0..6 {
        for b in (a + 1)..6 {
            for c in (b + 1)..6 {
                out.push([a, b, c]);
            }
        }
    }
    out
}

impl TracePatterning {
    pub fn new(cfg: TracePatterningConfig, seed: u64) -> Self {
        let mut rng = Xoshiro256::seed_from_u64(seed ^ 0x7261_6365); // "race"
        let patterns = all_patterns();
        // 10 randomly chosen activating patterns, fixed for the run.
        let chosen = rng.choose_indices(patterns.len(), 10);
        let mut activating = vec![false; patterns.len()];
        for i in chosen {
            activating[i] = true;
        }
        let mut env = Self {
            cfg,
            rng,
            patterns,
            activating,
            phase: Phase::Iti { remaining: 1 },
            current_pattern: 0,
        };
        env.begin_trial();
        env
    }

    fn begin_trial(&mut self) {
        self.current_pattern = self.rng.below(self.patterns.len() as u64) as usize;
        self.phase = Phase::Cs {
            remaining: self.cfg.cs_duration,
        };
    }

    /// Which patterns activate the US (for tests/oracles).
    pub fn activating_patterns(&self) -> Vec<[usize; 3]> {
        self.patterns
            .iter()
            .zip(&self.activating)
            .filter(|(_, &a)| a)
            .map(|(p, _)| *p)
            .collect()
    }

    fn sample_isi(&mut self) -> u64 {
        self.rng.int_in(self.cfg.isi_min, self.cfg.isi_max)
    }

    fn sample_iti(&mut self) -> u64 {
        self.rng.int_in(self.cfg.iti_min, self.cfg.iti_max)
    }

    /// Exact number of steps until the US fires (from the state after the
    /// most recent observation), if an activating US is scheduled.
    fn steps_to_us(&self) -> Option<u64> {
        match self.phase {
            // ISI not yet sampled during the CS — oracle undefined there.
            Phase::Cs { .. } => None,
            Phase::Isi {
                remaining,
                activate,
            } => {
                // `remaining` more silent steps, then the US step.
                if activate {
                    Some(remaining + 1)
                } else {
                    None
                }
            }
            Phase::Us { activate } => {
                if activate {
                    Some(1)
                } else {
                    None
                }
            }
            Phase::Iti { .. } => None,
        }
    }
}

impl Stream for TracePatterning {
    fn n_features(&self) -> usize {
        N_FEATURES
    }

    fn gamma(&self) -> f32 {
        self.cfg.gamma
    }

    fn name(&self) -> &'static str {
        "trace_patterning"
    }

    fn step_into(&mut self, x: &mut [f32]) -> f32 {
        debug_assert_eq!(x.len(), N_FEATURES);
        x.fill(0.0);
        match self.phase {
            Phase::Cs { remaining } => {
                for &i in &self.patterns[self.current_pattern] {
                    x[i] = 1.0;
                }
                if remaining > 1 {
                    self.phase = Phase::Cs {
                        remaining: remaining - 1,
                    };
                } else {
                    // Paper timing (Fig. 3): the US fires exactly ISI steps
                    // after CS onset, i.e. ISI-1 silent steps in between.
                    let isi = self.sample_isi();
                    let activate = self.activating[self.current_pattern];
                    self.phase = if isi <= 1 {
                        Phase::Us { activate }
                    } else {
                        Phase::Isi {
                            remaining: isi - 1,
                            activate,
                        }
                    };
                }
                0.0
            }
            Phase::Isi {
                remaining,
                activate,
            } => {
                if remaining > 1 {
                    self.phase = Phase::Isi {
                        remaining: remaining - 1,
                        activate,
                    };
                } else {
                    self.phase = Phase::Us { activate };
                }
                0.0
            }
            Phase::Us { activate } => {
                let us = if activate { 1.0 } else { 0.0 };
                x[US_INDEX] = us;
                let iti = self.sample_iti();
                self.phase = Phase::Iti { remaining: iti };
                us
            }
            Phase::Iti { remaining } => {
                if remaining > 1 {
                    self.phase = Phase::Iti {
                        remaining: remaining - 1,
                    };
                } else {
                    self.begin_trial();
                }
                0.0
            }
        }
    }
}

impl OracleReturn for TracePatterning {
    fn oracle_return(&self) -> Option<f64> {
        // Exact return from "now" (the state after the last emitted obs):
        // gamma^(k-1) when the US fires in k steps; future-trial
        // contributions are < gamma^ITI (negligible; tests use a
        // tolerance that covers them).
        self.steps_to_us()
            .map(|k| (self.cfg.gamma as f64).powi(k as i32 - 1))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::env::returns::ReturnEval;
    use crate::util::check::{check, prop_assert};

    #[test]
    fn twenty_patterns_ten_activating() {
        let env = TracePatterning::new(TracePatterningConfig::default(), 0);
        assert_eq!(env.patterns.len(), 20);
        assert_eq!(env.activating.iter().filter(|&&a| a).count(), 10);
        // patterns distinct
        let mut seen: Vec<[usize; 3]> = env.patterns.clone();
        seen.sort();
        seen.dedup();
        assert_eq!(seen.len(), 20);
    }

    #[test]
    fn activating_set_differs_across_seeds() {
        let a = TracePatterning::new(TracePatterningConfig::default(), 1)
            .activating_patterns();
        let b = TracePatterning::new(TracePatterningConfig::default(), 2)
            .activating_patterns();
        assert_ne!(a, b);
    }

    #[test]
    fn cs_has_three_active_features_us_zero_during_cs() {
        let mut env = TracePatterning::new(TracePatterningConfig::default(), 5);
        let mut x = vec![0.0; N_FEATURES];
        let mut cs_seen = 0;
        for _ in 0..5000 {
            env.step_into(&mut x);
            let n_cs: usize = (0..6).filter(|&i| x[i] == 1.0).count();
            if n_cs > 0 {
                assert_eq!(n_cs, 3);
                assert_eq!(x[US_INDEX], 0.0, "US must not overlap CS");
                cs_seen += 1;
            }
        }
        assert!(cs_seen >= 30, "CS trials should occur: {cs_seen}");
    }

    #[test]
    fn us_fires_only_for_activating_patterns_at_isi() {
        let cfg = TracePatterningConfig {
            isi_min: 5,
            isi_max: 5,
            iti_min: 10,
            iti_max: 10,
            cs_duration: 1,
            gamma: 0.9,
        };
        let mut env = TracePatterning::new(cfg, 11);
        let activating = env.activating_patterns();
        let mut x = vec![0.0; N_FEATURES];
        let mut last_pattern: Option<[usize; 3]> = None;
        let mut steps_since_cs = 0u64;
        let mut checked = 0;
        for _ in 0..20_000 {
            let us = env.step_into(&mut x);
            let cs: Vec<usize> = (0..6).filter(|&i| x[i] == 1.0).collect();
            if cs.len() == 3 {
                last_pattern = Some([cs[0], cs[1], cs[2]]);
                steps_since_cs = 0;
            } else {
                steps_since_cs += 1;
            }
            if us == 1.0 {
                let p = last_pattern.expect("US without CS");
                assert!(activating.contains(&p), "US fired for non-activating {p:?}");
                assert_eq!(steps_since_cs, 5, "US must fire ISI steps after CS onset");
                checked += 1;
            }
        }
        assert!(checked > 20, "need US events: {checked}");
    }

    #[test]
    fn nonactivating_patterns_never_fire() {
        let mut env = TracePatterning::new(TracePatterningConfig::tiny(), 17);
        let activating = env.activating_patterns();
        let mut x = vec![0.0; N_FEATURES];
        let mut last_pattern = None;
        for _ in 0..50_000 {
            let us = env.step_into(&mut x);
            let cs: Vec<usize> = (0..6).filter(|&i| x[i] == 1.0).collect();
            if cs.len() == 3 {
                last_pattern = Some([cs[0], cs[1], cs[2]]);
            }
            if let Some(p) = last_pattern {
                if !activating.contains(&p) {
                    assert_eq!(us, 0.0);
                }
            }
        }
    }

    #[test]
    fn isi_iti_within_bounds() {
        let cfg = TracePatterningConfig::default();
        let mut env = TracePatterning::new(cfg.clone(), 23);
        let mut x = vec![0.0; N_FEATURES];
        let mut last_cs: Option<u64> = None;
        let mut last_us: Option<u64> = None;
        for t in 0..100_000u64 {
            let us = env.step_into(&mut x);
            let is_cs = (0..6).any(|i| x[i] == 1.0);
            if is_cs {
                if let Some(ut) = last_us {
                    // ITI = silent steps between the US and the next CS.
                    let iti = t - ut - 1;
                    assert!(
                        (cfg.iti_min..=cfg.iti_max).contains(&iti),
                        "iti {iti} out of bounds"
                    );
                }
                // only measure the ITI against the *immediately preceding*
                // trial; non-activating trials emit no US.
                last_us = None;
                last_cs = Some(t);
            }
            if us == 1.0 {
                let ct = last_cs.expect("US without CS");
                // paper: US fires exactly ISI steps after CS onset.
                let isi = t - ct;
                assert!(
                    (cfg.isi_min..=cfg.isi_max).contains(&isi),
                    "isi {isi} out of bounds"
                );
                last_us = Some(t);
            }
        }
    }

    #[test]
    fn oracle_matches_empirical_return() {
        // During an activating ISI the oracle return gamma^(k-1) must match
        // the empirical return computed by ReturnEval.
        let cfg = TracePatterningConfig::default();
        let gamma = cfg.gamma as f64;
        let mut env = TracePatterning::new(cfg, 31);
        let mut ev = ReturnEval::new(gamma, 1e-9);
        let mut oracle_vals: Vec<(u64, f64)> = Vec::new();
        let mut x = vec![0.0; N_FEATURES];
        for t in 0..30_000u64 {
            let c = env.step_into(&mut x) as f64;
            // predict the oracle value when known, else 0 (only oracle
            // steps are checked below).
            let y = env.oracle_return().unwrap_or(-1.0);
            if y >= 0.0 {
                oracle_vals.push((t, y));
            }
            ev.push(y.max(0.0), c);
        }
        let errs = ev.drain();
        let mut checked = 0;
        for &(t, o) in &oracle_vals {
            if let Ok(idx) = errs.binary_search_by_key(&t, |&(i, _)| i) {
                let (_, e2) = errs[idx];
                // future-trial contribution makes this inexact at
                // ~gamma^(ISI+ITI) — generous tolerance.
                assert!(e2 < 1e-6, "t={t} oracle {o} err {e2}");
                checked += 1;
            }
        }
        assert!(checked > 100, "checked {checked}");
    }

    #[test]
    fn prop_stream_is_deterministic_per_seed() {
        check("trace patterning deterministic", 20, |g| {
            let seed = g.rng.next_u64();
            let mut a = TracePatterning::new(TracePatterningConfig::tiny(), seed);
            let mut b = TracePatterning::new(TracePatterningConfig::tiny(), seed);
            let mut xa = vec![0.0; N_FEATURES];
            let mut xb = vec![0.0; N_FEATURES];
            for _ in 0..500 {
                let ca = a.step_into(&mut xa);
                let cb = b.step_into(&mut xb);
                prop_assert(ca == cb && xa == xb, "streams diverged")?;
            }
            Ok(())
        });
    }
}
