//! Online learning: TD(lambda) over a [`crate::nets::PredictionNet`].

pub mod td_lambda;

pub use td_lambda::{TdConfig, TdLambdaAgent, TdState};
