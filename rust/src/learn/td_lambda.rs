//! Online TD(lambda) with accumulating eligibility traces (Sutton 1988),
//! applied to non-linear recurrent networks as in the paper (and
//! TD-Gammon before it).
//!
//! Per step t, with observation x_t carrying cumulant c_t:
//!
//! 1. advance the net, read features f_t, predict y_t = w . f_t
//! 2. delta_{t-1} = c_t + gamma * y_t - y_{t-1}
//! 3. w     += alpha * delta * e_w      (readout eligibility)
//!    theta += alpha * delta * e_theta  (net-parameter eligibility)
//! 4. e_w     = gamma * lambda * e_w     + f_t
//!    e_theta = gamma * lambda * e_theta + dy_t/dtheta  (RTRL / T-BPTT)
//!
//! Constructive growth: when the net's feature count grows, w and e_w are
//! zero-extended (the paper initializes new outgoing weights to zero, so
//! adding a feature never perturbs predictions); when the net's learnable
//! parameter set changes identity (stage freeze), e_theta is reset.

use crate::nets::PredictionNet;
use crate::util::json::Json;
use crate::util::{axpy, dot};

#[derive(Clone, Copy, Debug)]
pub struct TdConfig {
    pub alpha: f32,
    pub gamma: f32,
    pub lambda: f32,
}

impl Default for TdConfig {
    /// Paper trace-patterning defaults: gamma 0.9, lambda 0.99.
    fn default() -> Self {
        Self {
            alpha: 0.001,
            gamma: 0.9,
            lambda: 0.99,
        }
    }
}

/// The agent's learning state minus the net: readout weights, both
/// eligibility traces, and the TD bootstrap bookkeeping. Captured and
/// restored for session snapshots ([`crate::serve`]); the net itself is
/// serialized separately through [`crate::nets::PersistableNet::save`]
/// and restored by [`crate::nets::NetRegistry`] under its kind tag —
/// restore the net first, then [`TdLambdaAgent::set_td_state`] validates
/// this state against it (shapes and parameter epoch).
#[derive(Clone, Debug, PartialEq)]
pub struct TdState {
    pub w: Vec<f32>,
    pub e_w: Vec<f32>,
    pub e_theta: Vec<f32>,
    pub y_prev: f32,
    pub have_prev: bool,
    pub epoch_seen: u64,
    pub steps: u64,
}

impl TdState {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("w", Json::arr_f32(&self.w)),
            ("e_w", Json::arr_f32(&self.e_w)),
            ("e_theta", Json::arr_f32(&self.e_theta)),
            ("y_prev", Json::Num(self.y_prev as f64)),
            ("have_prev", Json::Bool(self.have_prev)),
            ("epoch_seen", Json::Num(self.epoch_seen as f64)),
            ("steps", Json::Num(self.steps as f64)),
        ])
    }

    pub fn from_json(v: &Json) -> Option<Self> {
        Some(Self {
            w: v.get("w")?.to_f32_vec()?,
            e_w: v.get("e_w")?.to_f32_vec()?,
            e_theta: v.get("e_theta")?.to_f32_vec()?,
            y_prev: v.get("y_prev")?.as_f64()? as f32,
            have_prev: v.get("have_prev")?.as_bool()?,
            epoch_seen: v.get("epoch_seen")?.as_f64()? as u64,
            steps: v.get("steps")?.as_f64()? as u64,
        })
    }
}

pub struct TdLambdaAgent<N: PredictionNet> {
    pub net: N,
    cfg: TdConfig,
    /// readout weights over net.features()
    pub w: Vec<f32>,
    e_w: Vec<f32>,
    e_theta: Vec<f32>,
    grad_buf: Vec<f32>,
    update_buf: Vec<f32>,
    y_prev: f32,
    have_prev: bool,
    epoch_seen: u64,
    steps: u64,
}

impl<N: PredictionNet> TdLambdaAgent<N> {
    pub fn new(net: N, cfg: TdConfig) -> Self {
        let d = net.n_features();
        let np = net.n_learnable_params();
        let epoch = net.param_epoch();
        Self {
            net,
            cfg,
            w: vec![0.0; d],
            e_w: vec![0.0; d],
            e_theta: vec![0.0; np],
            grad_buf: vec![0.0; np],
            update_buf: vec![0.0; np],
            y_prev: 0.0,
            have_prev: false,
            epoch_seen: epoch,
            steps: 0,
        }
    }

    pub fn config(&self) -> TdConfig {
        self.cfg
    }

    pub fn steps(&self) -> u64 {
        self.steps
    }

    /// Capture the learning state (snapshot support; the net is captured
    /// separately by the caller).
    pub fn td_state(&self) -> TdState {
        TdState {
            w: self.w.clone(),
            e_w: self.e_w.clone(),
            e_theta: self.e_theta.clone(),
            y_prev: self.y_prev,
            have_prev: self.have_prev,
            epoch_seen: self.epoch_seen,
            steps: self.steps,
        }
    }

    /// Restore a previously captured [`TdState`]. The state must be
    /// consistent with the *current* net (feature count, learnable
    /// parameter count and parameter epoch) — restore the net first.
    pub fn set_td_state(&mut self, st: TdState) -> Result<(), String> {
        if st.w.len() != self.net.n_features() {
            return Err(format!(
                "td restore: {} readout weights but net has {} features",
                st.w.len(),
                self.net.n_features()
            ));
        }
        if st.e_w.len() != st.w.len() {
            return Err("td restore: e_w / w length mismatch".into());
        }
        if st.e_theta.len() != self.net.n_learnable_params() {
            return Err(format!(
                "td restore: {} theta traces but net has {} learnable params",
                st.e_theta.len(),
                self.net.n_learnable_params()
            ));
        }
        if st.epoch_seen != self.net.param_epoch() {
            return Err(format!(
                "td restore: epoch {} but net is at epoch {}",
                st.epoch_seen,
                self.net.param_epoch()
            ));
        }
        let np = st.e_theta.len();
        self.grad_buf = vec![0.0; np];
        self.update_buf = vec![0.0; np];
        self.w = st.w;
        self.e_w = st.e_w;
        self.e_theta = st.e_theta;
        self.y_prev = st.y_prev;
        self.have_prev = st.have_prev;
        self.epoch_seen = st.epoch_seen;
        self.steps = st.steps;
        Ok(())
    }

    /// Constructive growth bookkeeping: zero-extend the readout weights
    /// and their traces when the net grew features, and reset the
    /// parameter traces when the learnable set changed identity (stage
    /// freeze). New entries are all zero, so running this eagerly right
    /// after a transition is arithmetically identical to running it at
    /// the start of the next step — and it keeps `td_state()` consistent
    /// with the net at every op boundary, so a snapshot taken exactly on
    /// a stage boundary restores cleanly.
    fn sync_growth(&mut self) {
        let d = self.net.n_features();
        if d > self.w.len() {
            self.w.resize(d, 0.0); // new outgoing weights start at zero
            self.e_w.resize(d, 0.0);
        }
        if self.net.param_epoch() != self.epoch_seen {
            self.epoch_seen = self.net.param_epoch();
            let np = self.net.n_learnable_params();
            self.e_theta.clear();
            self.e_theta.resize(np, 0.0);
            self.grad_buf.clear();
            self.grad_buf.resize(np, 0.0);
            self.update_buf.clear();
            self.update_buf.resize(np, 0.0);
        }
    }

    /// One online step: consume observation + cumulant, return prediction
    /// y_t made *at this step* (the value scored against the return).
    pub fn step(&mut self, x: &[f32], cumulant: f32) -> f32 {
        let TdConfig {
            alpha,
            gamma,
            lambda,
        } = self.cfg;

        self.net.advance(x);
        self.sync_growth();

        let feats = self.net.features();
        let y = dot(&self.w, feats);

        // TD update for the previous prediction
        if self.have_prev {
            let delta = cumulant + gamma * y - self.y_prev;
            let a_delta = alpha * delta;
            axpy(a_delta, &self.e_w, &mut self.w);
            if !self.e_theta.is_empty() {
                for (u, &e) in self.update_buf.iter_mut().zip(self.e_theta.iter()) {
                    *u = a_delta * e;
                }
                self.net.apply_update(&self.update_buf);
            }
        }

        // eligibility decay + accumulate current gradients
        let gl = gamma * lambda;
        let feats = self.net.features();
        for (e, &f) in self.e_w.iter_mut().zip(feats.iter()) {
            *e = gl * *e + f;
        }
        if !self.e_theta.is_empty() {
            self.net.grad_y(&self.w, &mut self.grad_buf);
            for (e, &g) in self.e_theta.iter_mut().zip(self.grad_buf.iter()) {
                *e = gl * *e + g;
            }
        }

        self.y_prev = y;
        self.have_prev = true;
        self.steps += 1;
        self.net.end_step();
        // settle any stage transition *inside* this step so the captured
        // state is never a net/readout shape mismatch (all new entries
        // are zeros; see sync_growth)
        self.sync_growth();
        y
    }

    /// Prediction without learning (evaluation-only passes).
    pub fn predict_only(&mut self, x: &[f32]) -> f32 {
        self.net.advance(x);
        let d = self.net.n_features();
        if d > self.w.len() {
            self.w.resize(d, 0.0);
            self.e_w.resize(d, 0.0);
        }
        dot(&self.w, self.net.features())
    }

    /// Total per-step operation estimate: net + TD bookkeeping.
    pub fn flops_per_step(&self) -> u64 {
        // readout + two eligibility updates are O(d + |theta|); the net
        // dominates, but count them for honesty.
        let d = self.w.len() as u64;
        let np = self.e_theta.len() as u64;
        self.net.flops_per_step() + 4 * d + 3 * np
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nets::columnar::columnar_net;
    use crate::nets::tbptt::TbpttNet;

    /// A fixed "identity" feature net for testing TD mechanics in
    /// isolation: features = x, no learnable params.
    struct TabularNet {
        feats: Vec<f32>,
    }

    impl PredictionNet for TabularNet {
        fn n_features(&self) -> usize {
            self.feats.len()
        }
        fn advance(&mut self, x: &[f32]) {
            self.feats.copy_from_slice(x);
        }
        fn features(&self) -> &[f32] {
            &self.feats
        }
        fn n_learnable_params(&self) -> usize {
            0
        }
        fn grad_y(&self, _w: &[f32], _g: &mut [f32]) {}
        fn apply_update(&mut self, _d: &[f32]) {}
        fn flops_per_step(&self) -> u64 {
            0
        }
        fn name(&self) -> &'static str {
            "tabular"
        }
    }

    #[test]
    fn td0_converges_to_constant_return() {
        // single always-on feature, constant cumulant 1, gamma 0.5:
        // true value = c/(1-gamma) = 2 (cumulant arrives every step).
        let net = TabularNet { feats: vec![0.0] };
        let mut agent = TdLambdaAgent::new(
            net,
            TdConfig {
                alpha: 0.05,
                gamma: 0.5,
                lambda: 0.0,
            },
        );
        let mut y = 0.0;
        for _ in 0..5000 {
            y = agent.step(&[1.0], 1.0);
        }
        assert!((y - 2.0).abs() < 0.05, "y = {y}");
    }

    #[test]
    fn td_lambda_solves_two_state_chain() {
        // states A, B alternate; cumulant 1 only on entering A.
        // gamma = 0.8: v(A) = gamma*v(B) + ... solve: entering A yields
        // c=1; v(A) = 0 + .8 v(B); v(B) = 1 + .8 v(A)  =>
        // v(A) = .8(1+.8 v(A)) => v(A)= .8/(1-.64)=2.222, v(B)= 2.778.
        let net = TabularNet {
            feats: vec![0.0, 0.0],
        };
        let mut agent = TdLambdaAgent::new(
            net,
            TdConfig {
                alpha: 0.02,
                gamma: 0.8,
                lambda: 0.9,
            },
        );
        let mut ys = [0.0f32; 2];
        for t in 0..60_000u64 {
            let s = (t % 2) as usize; // 0 = A, 1 = B
            let x = if s == 0 { [1.0, 0.0] } else { [0.0, 1.0] };
            let c = if s == 0 { 1.0 } else { 0.0 }; // reward on entering A
            ys[s] = agent.step(&x, c);
        }
        assert!((ys[0] - 2.222).abs() < 0.1, "v(A) = {}", ys[0]);
        assert!((ys[1] - 2.778).abs() < 0.1, "v(B) = {}", ys[1]);
    }

    #[test]
    fn columnar_agent_learns_cycle_world() {
        use crate::env::cycle_world::CycleWorld;
        use crate::env::returns::ReturnEval;
        use crate::env::Stream;

        let mut env = CycleWorld::new(6, 0.9);
        let net = columnar_net(2, 5, 0.01, 0);
        let mut agent = TdLambdaAgent::new(
            net,
            TdConfig {
                alpha: 0.01,
                gamma: 0.9,
                lambda: 0.9,
            },
        );
        let mut x = vec![0.0; 2];
        let mut early = ReturnEval::new(0.9, 1e-6);
        let mut late = ReturnEval::new(0.9, 1e-6);
        let total = 120_000;
        for t in 0..total {
            let c = env.step_into(&mut x);
            let y = agent.step(&x, c);
            if t < 20_000 {
                early.push(y as f64, c as f64);
            }
            if t >= total - 20_000 {
                late.push(y as f64, c as f64);
            }
        }
        let mean = |v: Vec<(u64, f64)>| {
            let n = v.len() as f64;
            v.iter().map(|&(_, e)| e).sum::<f64>() / n
        };
        let e_early = mean(early.drain());
        let e_late = mean(late.drain());
        assert!(
            e_late < e_early * 0.5,
            "learning must reduce error: early {e_early:.4} late {e_late:.4}"
        );
    }

    #[test]
    fn tbptt_agent_learns_cycle_world() {
        use crate::env::cycle_world::CycleWorld;
        use crate::env::returns::ReturnEval;
        use crate::env::Stream;

        let mut env = CycleWorld::new(5, 0.9);
        let net = TbpttNet::new(2, 4, 10, 0);
        let mut agent = TdLambdaAgent::new(
            net,
            TdConfig {
                alpha: 0.01,
                gamma: 0.9,
                lambda: 0.9,
            },
        );
        let mut x = vec![0.0; 2];
        let mut early = ReturnEval::new(0.9, 1e-6);
        let mut late = ReturnEval::new(0.9, 1e-6);
        let total = 120_000;
        for t in 0..total {
            let c = env.step_into(&mut x);
            let y = agent.step(&x, c);
            if t < 20_000 {
                early.push(y as f64, c as f64);
            }
            if t >= total - 20_000 {
                late.push(y as f64, c as f64);
            }
        }
        let mean = |v: Vec<(u64, f64)>| {
            let n = v.len() as f64;
            v.iter().map(|&(_, e)| e).sum::<f64>() / n
        };
        let e_early = mean(early.drain());
        let e_late = mean(late.drain());
        assert!(
            e_late < e_early * 0.6,
            "tbptt must learn: early {e_early:.4} late {e_late:.4}"
        );
    }

    #[test]
    fn td_state_roundtrip_continues_identically() {
        use crate::env::cycle_world::CycleWorld;
        use crate::env::Stream;

        let mut env = CycleWorld::new(5, 0.9);
        let make = || {
            TdLambdaAgent::new(
                columnar_net(2, 3, 0.01, 4),
                TdConfig {
                    alpha: 0.01,
                    gamma: 0.9,
                    lambda: 0.9,
                },
            )
        };
        let mut agent = make();
        let mut x = vec![0.0; 2];
        for _ in 0..500 {
            let c = env.step_into(&mut x);
            agent.step(&x, c);
        }
        // round-trip the TD state through JSON into a fresh agent whose
        // net is byte-identical (same seed, same step count via replay).
        let st = agent.td_state();
        let back = TdState::from_json(&Json::parse(&st.to_json().dump()).unwrap())
            .expect("td state json");
        assert_eq!(back, st);
        let mut restored = make();
        // replay the net to the same point so epochs/features match
        let mut env2 = CycleWorld::new(5, 0.9);
        let mut x2 = vec![0.0; 2];
        for _ in 0..500 {
            let c = env2.step_into(&mut x2);
            restored.step(&x2, c);
        }
        restored.set_td_state(back).expect("restore");
        for _ in 0..200 {
            let c = env.step_into(&mut x);
            let c2 = env2.step_into(&mut x2);
            assert_eq!(c, c2);
            let ya = agent.step(&x, c);
            let yb = restored.step(&x2, c2);
            assert_eq!(ya, yb, "restored agent must continue identically");
        }
    }

    #[test]
    fn set_td_state_rejects_mismatched_shapes() {
        let mut agent =
            TdLambdaAgent::new(columnar_net(2, 3, 0.01, 4), TdConfig::default());
        let mut st = agent.td_state();
        st.w.push(0.0);
        assert!(agent.set_td_state(st).is_err());
    }

    #[test]
    fn snapshot_exactly_at_stage_boundary_restores() {
        use crate::nets::ccn::{CcnConfig, CcnNet};
        use crate::nets::PersistableNet;
        // pre-fix, the growth bookkeeping ran at the start of the *next*
        // step, so a state captured right after the boundary step paired
        // old-shaped readout weights with an already-grown net and
        // set_td_state refused the restore.
        let cfg = CcnConfig {
            n_inputs: 2,
            total_features: 4,
            features_per_stage: 2,
            steps_per_stage: 25,
            init_scale: 0.5,
            norm_eps: 0.01,
            norm_beta: 0.999,
        };
        let mut agent =
            TdLambdaAgent::new(CcnNet::new(cfg.clone(), 3), TdConfig::default());
        for t in 0..25u64 {
            // the 25th step crosses the stage boundary
            let x = [(t % 3) as f32 / 3.0, 1.0];
            agent.step(&x, 0.1);
        }
        assert_eq!(agent.net.n_features(), 4, "stage 2 materialized");
        let st = agent.td_state();
        assert_eq!(st.w.len(), 4, "state is shape-consistent with the net");
        let net_json = agent.net.save();
        let net =
            CcnNet::from_json(&Json::parse(&net_json.dump()).unwrap()).unwrap();
        let mut restored = TdLambdaAgent::new(net, TdConfig::default());
        restored
            .set_td_state(st)
            .expect("boundary snapshot must restore");
        for t in 0..30u64 {
            let x = [(t % 5) as f32 / 5.0, 0.5];
            assert_eq!(agent.step(&x, 0.1), restored.step(&x, 0.1));
        }
    }

    #[test]
    fn growth_extends_weights_with_zeros() {
        use crate::nets::ccn::{CcnConfig, CcnNet};
        let net = CcnNet::new(
            CcnConfig {
                n_inputs: 2,
                total_features: 4,
                features_per_stage: 2,
                steps_per_stage: 25,
                init_scale: 0.5,
                norm_eps: 0.01,
                norm_beta: 0.999,
            },
            0,
        );
        let mut agent = TdLambdaAgent::new(net, TdConfig::default());
        for t in 0..60u64 {
            let x = [(t % 3) as f32 / 3.0, 1.0];
            agent.step(&x, 0.1);
            if t == 23 {
                assert_eq!(agent.w.len(), 2);
            }
            if t == 24 {
                // the stage boundary settles *inside* the step that
                // crosses it (eager sync_growth): the readout grows
                // immediately and the new outgoing weights are exactly
                // zero, so predictions are unperturbed.
                assert_eq!(agent.w.len(), 4);
                assert_eq!(agent.w[2], 0.0);
                assert_eq!(agent.w[3], 0.0);
            }
            if t == 26 {
                // one update has run since; magnitudes stay tiny
                assert!(agent.w[2].abs() < 0.1 && agent.w[3].abs() < 0.1);
            }
        }
    }
}
