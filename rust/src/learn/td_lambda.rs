//! Online TD(lambda) with accumulating eligibility traces (Sutton 1988),
//! applied to non-linear recurrent networks as in the paper (and
//! TD-Gammon before it).
//!
//! Per step t, with observation x_t carrying cumulant c_t:
//!
//! 1. advance the net, read features f_t, predict y_t = w . f_t
//! 2. delta_{t-1} = c_t + gamma * y_t - y_{t-1}
//! 3. w     += alpha * delta * e_w      (readout eligibility)
//!    theta += alpha * delta * e_theta  (net-parameter eligibility)
//! 4. e_w     = gamma * lambda * e_w     + f_t
//!    e_theta = gamma * lambda * e_theta + dy_t/dtheta  (RTRL / T-BPTT)
//!
//! Constructive growth: when the net's feature count grows, w and e_w are
//! zero-extended (the paper initializes new outgoing weights to zero, so
//! adding a feature never perturbs predictions); when the net's learnable
//! parameter set changes identity (stage freeze), e_theta is reset.

use crate::nets::PredictionNet;
use crate::util::{axpy, dot};

#[derive(Clone, Copy, Debug)]
pub struct TdConfig {
    pub alpha: f32,
    pub gamma: f32,
    pub lambda: f32,
}

impl Default for TdConfig {
    /// Paper trace-patterning defaults: gamma 0.9, lambda 0.99.
    fn default() -> Self {
        Self {
            alpha: 0.001,
            gamma: 0.9,
            lambda: 0.99,
        }
    }
}

pub struct TdLambdaAgent<N: PredictionNet> {
    pub net: N,
    cfg: TdConfig,
    /// readout weights over net.features()
    pub w: Vec<f32>,
    e_w: Vec<f32>,
    e_theta: Vec<f32>,
    grad_buf: Vec<f32>,
    update_buf: Vec<f32>,
    y_prev: f32,
    have_prev: bool,
    epoch_seen: u64,
    steps: u64,
}

impl<N: PredictionNet> TdLambdaAgent<N> {
    pub fn new(net: N, cfg: TdConfig) -> Self {
        let d = net.n_features();
        let np = net.n_learnable_params();
        let epoch = net.param_epoch();
        Self {
            net,
            cfg,
            w: vec![0.0; d],
            e_w: vec![0.0; d],
            e_theta: vec![0.0; np],
            grad_buf: vec![0.0; np],
            update_buf: vec![0.0; np],
            y_prev: 0.0,
            have_prev: false,
            epoch_seen: epoch,
            steps: 0,
        }
    }

    pub fn config(&self) -> TdConfig {
        self.cfg
    }

    pub fn steps(&self) -> u64 {
        self.steps
    }

    /// One online step: consume observation + cumulant, return prediction
    /// y_t made *at this step* (the value scored against the return).
    pub fn step(&mut self, x: &[f32], cumulant: f32) -> f32 {
        let TdConfig {
            alpha,
            gamma,
            lambda,
        } = self.cfg;

        self.net.advance(x);

        // constructive growth bookkeeping
        let d = self.net.n_features();
        if d > self.w.len() {
            self.w.resize(d, 0.0); // new outgoing weights start at zero
            self.e_w.resize(d, 0.0);
        }
        if self.net.param_epoch() != self.epoch_seen {
            self.epoch_seen = self.net.param_epoch();
            let np = self.net.n_learnable_params();
            self.e_theta.clear();
            self.e_theta.resize(np, 0.0);
            self.grad_buf.clear();
            self.grad_buf.resize(np, 0.0);
            self.update_buf.clear();
            self.update_buf.resize(np, 0.0);
        }

        let feats = self.net.features();
        let y = dot(&self.w, feats);

        // TD update for the previous prediction
        if self.have_prev {
            let delta = cumulant + gamma * y - self.y_prev;
            let a_delta = alpha * delta;
            axpy(a_delta, &self.e_w, &mut self.w);
            if !self.e_theta.is_empty() {
                for (u, &e) in self.update_buf.iter_mut().zip(self.e_theta.iter()) {
                    *u = a_delta * e;
                }
                self.net.apply_update(&self.update_buf);
            }
        }

        // eligibility decay + accumulate current gradients
        let gl = gamma * lambda;
        let feats = self.net.features();
        for (e, &f) in self.e_w.iter_mut().zip(feats.iter()) {
            *e = gl * *e + f;
        }
        if !self.e_theta.is_empty() {
            self.net.grad_y(&self.w, &mut self.grad_buf);
            for (e, &g) in self.e_theta.iter_mut().zip(self.grad_buf.iter()) {
                *e = gl * *e + g;
            }
        }

        self.y_prev = y;
        self.have_prev = true;
        self.steps += 1;
        self.net.end_step();
        y
    }

    /// Prediction without learning (evaluation-only passes).
    pub fn predict_only(&mut self, x: &[f32]) -> f32 {
        self.net.advance(x);
        let d = self.net.n_features();
        if d > self.w.len() {
            self.w.resize(d, 0.0);
            self.e_w.resize(d, 0.0);
        }
        dot(&self.w, self.net.features())
    }

    /// Total per-step operation estimate: net + TD bookkeeping.
    pub fn flops_per_step(&self) -> u64 {
        // readout + two eligibility updates are O(d + |theta|); the net
        // dominates, but count them for honesty.
        let d = self.w.len() as u64;
        let np = self.e_theta.len() as u64;
        self.net.flops_per_step() + 4 * d + 3 * np
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nets::columnar::columnar_net;
    use crate::nets::tbptt::TbpttNet;

    /// A fixed "identity" feature net for testing TD mechanics in
    /// isolation: features = x, no learnable params.
    struct TabularNet {
        feats: Vec<f32>,
    }

    impl PredictionNet for TabularNet {
        fn n_features(&self) -> usize {
            self.feats.len()
        }
        fn advance(&mut self, x: &[f32]) {
            self.feats.copy_from_slice(x);
        }
        fn features(&self) -> &[f32] {
            &self.feats
        }
        fn n_learnable_params(&self) -> usize {
            0
        }
        fn grad_y(&self, _w: &[f32], _g: &mut [f32]) {}
        fn apply_update(&mut self, _d: &[f32]) {}
        fn flops_per_step(&self) -> u64 {
            0
        }
        fn name(&self) -> &'static str {
            "tabular"
        }
    }

    #[test]
    fn td0_converges_to_constant_return() {
        // single always-on feature, constant cumulant 1, gamma 0.5:
        // true value = c/(1-gamma) = 2 (cumulant arrives every step).
        let net = TabularNet { feats: vec![0.0] };
        let mut agent = TdLambdaAgent::new(
            net,
            TdConfig {
                alpha: 0.05,
                gamma: 0.5,
                lambda: 0.0,
            },
        );
        let mut y = 0.0;
        for _ in 0..5000 {
            y = agent.step(&[1.0], 1.0);
        }
        assert!((y - 2.0).abs() < 0.05, "y = {y}");
    }

    #[test]
    fn td_lambda_solves_two_state_chain() {
        // states A, B alternate; cumulant 1 only on entering A.
        // gamma = 0.8: v(A) = gamma*v(B) + ... solve: entering A yields
        // c=1; v(A) = 0 + .8 v(B); v(B) = 1 + .8 v(A)  =>
        // v(A) = .8(1+.8 v(A)) => v(A)= .8/(1-.64)=2.222, v(B)= 2.778.
        let net = TabularNet {
            feats: vec![0.0, 0.0],
        };
        let mut agent = TdLambdaAgent::new(
            net,
            TdConfig {
                alpha: 0.02,
                gamma: 0.8,
                lambda: 0.9,
            },
        );
        let mut ys = [0.0f32; 2];
        for t in 0..60_000u64 {
            let s = (t % 2) as usize; // 0 = A, 1 = B
            let x = if s == 0 { [1.0, 0.0] } else { [0.0, 1.0] };
            let c = if s == 0 { 1.0 } else { 0.0 }; // reward on entering A
            ys[s] = agent.step(&x, c);
        }
        assert!((ys[0] - 2.222).abs() < 0.1, "v(A) = {}", ys[0]);
        assert!((ys[1] - 2.778).abs() < 0.1, "v(B) = {}", ys[1]);
    }

    #[test]
    fn columnar_agent_learns_cycle_world() {
        use crate::env::cycle_world::CycleWorld;
        use crate::env::returns::ReturnEval;
        use crate::env::Stream;

        let mut env = CycleWorld::new(6, 0.9);
        let net = columnar_net(2, 5, 0.01, 0);
        let mut agent = TdLambdaAgent::new(
            net,
            TdConfig {
                alpha: 0.01,
                gamma: 0.9,
                lambda: 0.9,
            },
        );
        let mut x = vec![0.0; 2];
        let mut early = ReturnEval::new(0.9, 1e-6);
        let mut late = ReturnEval::new(0.9, 1e-6);
        let total = 120_000;
        for t in 0..total {
            let c = env.step_into(&mut x);
            let y = agent.step(&x, c);
            if t < 20_000 {
                early.push(y as f64, c as f64);
            }
            if t >= total - 20_000 {
                late.push(y as f64, c as f64);
            }
        }
        let mean = |v: Vec<(u64, f64)>| {
            let n = v.len() as f64;
            v.iter().map(|&(_, e)| e).sum::<f64>() / n
        };
        let e_early = mean(early.drain());
        let e_late = mean(late.drain());
        assert!(
            e_late < e_early * 0.5,
            "learning must reduce error: early {e_early:.4} late {e_late:.4}"
        );
    }

    #[test]
    fn tbptt_agent_learns_cycle_world() {
        use crate::env::cycle_world::CycleWorld;
        use crate::env::returns::ReturnEval;
        use crate::env::Stream;

        let mut env = CycleWorld::new(5, 0.9);
        let net = TbpttNet::new(2, 4, 10, 0);
        let mut agent = TdLambdaAgent::new(
            net,
            TdConfig {
                alpha: 0.01,
                gamma: 0.9,
                lambda: 0.9,
            },
        );
        let mut x = vec![0.0; 2];
        let mut early = ReturnEval::new(0.9, 1e-6);
        let mut late = ReturnEval::new(0.9, 1e-6);
        let total = 120_000;
        for t in 0..total {
            let c = env.step_into(&mut x);
            let y = agent.step(&x, c);
            if t < 20_000 {
                early.push(y as f64, c as f64);
            }
            if t >= total - 20_000 {
                late.push(y as f64, c as f64);
            }
        }
        let mean = |v: Vec<(u64, f64)>| {
            let n = v.len() as f64;
            v.iter().map(|&(_, e)| e).sum::<f64>() / n
        };
        let e_early = mean(early.drain());
        let e_late = mean(late.drain());
        assert!(
            e_late < e_early * 0.6,
            "tbptt must learn: early {e_early:.4} late {e_late:.4}"
        );
    }

    #[test]
    fn growth_extends_weights_with_zeros() {
        use crate::nets::ccn::{CcnConfig, CcnNet};
        let net = CcnNet::new(
            CcnConfig {
                n_inputs: 2,
                total_features: 4,
                features_per_stage: 2,
                steps_per_stage: 25,
                init_scale: 0.5,
                norm_eps: 0.01,
                norm_beta: 0.999,
            },
            0,
        );
        let mut agent = TdLambdaAgent::new(net, TdConfig::default());
        for t in 0..60u64 {
            let x = [(t % 3) as f32 / 3.0, 1.0];
            agent.step(&x, 0.1);
            if t == 24 {
                assert_eq!(agent.w.len(), 2);
            }
            if t == 26 {
                assert_eq!(agent.w.len(), 4);
                // new outgoing weights must start at zero (y unperturbed),
                // but by t==26 one update has already run; check magnitude
                // is tiny relative to learned weights.
                assert!(agent.w[2].abs() < 0.1 && agent.w[3].abs() < 0.1);
            }
        }
    }
}
