//! # ccn-rtrl — Scalable Real-Time Recurrent Learning with
//! # Columnar-Constructive Networks
//!
//! Production-quality reproduction of Javed, Shah, Sutton & White (2023):
//! scalable RTRL via Columnar networks, Constructive networks and their
//! combination (CCN), with TD(lambda) policy evaluation under fixed
//! per-step compute budgets, benchmarked against equal-budget T-BPTT.
//!
//! Architecture (see DESIGN.md):
//! - [`nets`]/[`learn`]: native Rust learners — the real-time hot path.
//! - [`serve`]: the online prediction service — thousands of concurrent
//!   TD(lambda) sessions, stepped by sharded workers and a batched
//!   structure-of-arrays columnar kernel, spoken to over a JSONL
//!   protocol on stdio or a concurrent TCP/UDS listener
//!   (`ccn serve [--listen tcp://H:P]`).
//! - [`store`]: the durable session tier — per-shard append-compact
//!   segment files of snapshot envelopes, LRU eviction, lazy
//!   rehydration and crash recovery (`--store-dir`/`--resident-cap`).
//! - `runtime` (feature `pjrt`): PJRT bridge executing the
//!   JAX/Pallas-authored AOT artifacts (`artifacts/*.hlo.txt`) from Rust;
//!   numerically cross-checked against the native path. Off by default
//!   because the `xla` crate is unavailable in the offline toolchain.
//! - [`cluster`]: the horizontal tier — `ccn route` consistent-hash
//!   routes session ids over N backend `ccn serve` processes, with live
//!   store-backed session migration (`handoff`/`drain`/`rebalance`),
//!   health-checked membership, and a reusable JSONL wire client.
//! - [`obs`]: zero-dependency telemetry — per-op latency histograms,
//!   stage timers, named counters, and the optional JSONL trace log
//!   (`ccn serve --trace-file`), surfaced via the `metrics` wire op.
//! - [`env`]: prediction streams (trace patterning, synthetic-ALE suite).
//! - [`coordinator`]: experiment runner, multi-seed sweeps, aggregation.
//! - [`compute`]: the paper's Appendix-A operation-count budget equations.
//! - [`util`], [`metrics`], [`config`]: offline-friendly substrates.

pub mod cluster;
pub mod compute;
pub mod config;
pub mod coordinator;
pub mod learn;
pub mod nets;
pub mod env;
pub mod metrics;
pub mod obs;
#[cfg(feature = "pjrt")]
pub mod runtime;
pub mod serve;
pub mod store;
pub mod util;
