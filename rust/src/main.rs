//! `ccn` — CLI for the Columnar-Constructive-Network RTRL framework.
//!
//! Subcommands:
//!   run          run one experiment (env x learner) and write results
//!   sweep        run a learner over several seeds in parallel
//!   serve        multi-session online prediction service (JSONL on
//!                stdin/stdout; see the serve module docs)
//!   route        consistent-hash router over N `ccn serve` backends with
//!                live session migration (see the cluster module docs)
//!   print-config show the Table-1 default configuration as JSON
//!   list-envs    list available prediction streams
//!   pjrt-verify  load AOT artifacts via PJRT and check the golden fixture
//!                (requires building with --features pjrt)
//!   pjrt-bench   time native vs PJRT column steps (the C++-vs-framework
//!                comparison of the paper's appendix; --features pjrt)

use std::io::Read;
use std::path::{Path, PathBuf};

use ccn_rtrl::config::{EnvKind, ExperimentConfig, LearnerKind};
use ccn_rtrl::coordinator::{aggregate_runs, run_experiment, run_sweep, sweep};
use ccn_rtrl::env::synthatari;
use ccn_rtrl::metrics::render_table;
use ccn_rtrl::nets::NetRegistry;
use ccn_rtrl::obs::{MetricsServer, TraceConfig};
#[cfg(feature = "pjrt")]
use ccn_rtrl::runtime::{PjrtColumnarStage, PjrtRuntime};
use ccn_rtrl::cluster::{RouterConfig, RouterServer};
use ccn_rtrl::serve::{ListenAddr, Server, Service};
use ccn_rtrl::store::StoreConfig;
use ccn_rtrl::util::cli::Args;
use ccn_rtrl::util::fault;
use ccn_rtrl::util::json::Json;

/// Arm deterministic fault injection for the listener subcommands:
/// `--faults SPEC` wins, the `CCN_FAULTS` env var is the fallback.
/// Reports the schedule digest so two runs can prove they replayed the
/// identical fault schedule.
fn install_faults(flag: Option<String>) -> Result<(), String> {
    let armed = match flag {
        Some(spec) => {
            fault::install(Some(fault::FaultPlan::parse(&spec)?));
            true
        }
        None => fault::install_from_env()?,
    };
    if armed {
        if let Some(digest) = fault::global_digest() {
            eprintln!("fault injection armed (schedule digest {digest:016x})");
        }
    }
    Ok(())
}

fn cfg_from_args(args: &mut Args) -> Result<ExperimentConfig, String> {
    let env = EnvKind::parse(&args.str_or("env", "trace"))
        .ok_or_else(|| "unknown --env".to_string())?;
    let learner = LearnerKind::parse(&args.str_or("learner", "ccn:20:4:100000"))
        .map_err(|e| e.to_string())?;
    Ok(ExperimentConfig {
        env,
        learner,
        alpha: args.f64_or("alpha", 0.001) as f32,
        lambda: args.f64_or("lambda", 0.99) as f32,
        gamma_override: args.opt_f64("gamma").map(|g| g as f32),
        eps: args.f64_or("eps", 0.01) as f32,
        steps: args.u64_or("steps", 500_000),
        seed: args.u64_or("seed", 0),
        curve_points: args.usize_or("curve-points", 100),
    })
}

fn write_results(path: &str, value: &Json) -> std::io::Result<()> {
    if let Some(parent) = Path::new(path).parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)?;
        }
    }
    std::fs::write(path, value.pretty())
}

fn cmd_run(mut args: Args) -> Result<(), String> {
    let cfg = cfg_from_args(&mut args)?;
    let out = args.str_or("out", "results/run.json");
    args.finish()?;
    eprintln!("running {} ...", cfg.label());
    let res = run_experiment(&cfg).map_err(|e| e.to_string())?;
    println!(
        "{}",
        render_table(
            &["learner", "env", "steps", "tail_error", "steps/s", "ops/step"],
            &[vec![
                res.learner.clone(),
                res.env.clone(),
                res.steps.to_string(),
                format!("{:.6}", res.tail_error),
                format!("{:.0}", res.steps_per_sec),
                res.flops_per_step.to_string(),
            ]],
        )
    );
    write_results(&out, &res.to_json()).map_err(|e| e.to_string())?;
    eprintln!("wrote {out}");
    Ok(())
}

fn cmd_sweep(mut args: Args) -> Result<(), String> {
    let cfg = cfg_from_args(&mut args)?;
    let seed_list: Vec<u64> = args
        .usize_list_or("seeds", &[0, 1, 2, 3, 4])
        .into_iter()
        .map(|s| s as u64)
        .collect();
    let threads = args.usize_or("threads", sweep::default_threads());
    let out = args.str_or("out", "results/sweep.json");
    args.finish()?;
    let configs = sweep::seeds(&cfg, &seed_list);
    eprintln!(
        "sweeping {} over {} seeds on {} threads ...",
        cfg.learner.label(),
        seed_list.len(),
        threads
    );
    let res = run_sweep(configs, threads).map_err(|e| e.to_string())?;
    let aggs = aggregate_runs(&res.runs);
    let mut rows = Vec::new();
    for a in &aggs {
        rows.push(vec![
            a.learner.clone(),
            a.env.clone(),
            a.n_seeds.to_string(),
            format!("{:.6}", a.tail_mean),
            format!("{:.6}", a.tail_stderr),
            format!("{:.0}", a.mean_steps_per_sec),
        ]);
    }
    println!(
        "{}",
        render_table(
            &["learner", "env", "seeds", "tail_mean", "tail_stderr", "steps/s"],
            &rows
        )
    );
    let json = Json::Arr(aggs.iter().map(|a| a.to_json()).collect());
    write_results(&out, &json).map_err(|e| e.to_string())?;
    eprintln!("wrote {out}");
    Ok(())
}

/// Park until stdin reaches EOF — Ctrl-D in the foreground, or the
/// parent closing the pipe, is the graceful-shutdown signal for the
/// listener subcommands; console input is otherwise ignored (the
/// protocol runs on the sockets). When stdin is *already* closed at
/// startup (daemonized: `ccn serve --listen ... < /dev/null &`, a
/// service manager, etc.) there is no shutdown channel: serve until
/// killed. A kill is the crash path — parked state survives, resident
/// state does not.
fn wait_for_stdin_eof() {
    fn park_forever() -> ! {
        eprintln!(
            "stdin is closed or unreadable: serving until killed (no \
             graceful shutdown channel; only parked sessions survive a kill)"
        );
        loop {
            std::thread::sleep(std::time::Duration::from_secs(3600));
        }
    }
    let mut stdin = std::io::stdin().lock();
    let mut scratch = [0u8; 4096];
    let mut first_read = true;
    loop {
        match stdin.read(&mut scratch) {
            Ok(0) if first_read => park_forever(),
            Ok(0) => break,
            Ok(_) => first_read = false,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            // an unreadable stdin at startup (fd 0 closed by a
            // supervisor) is the daemonized case, not a shutdown request
            Err(_) if first_read => park_forever(),
            Err(_) => break,
        }
    }
}

fn cmd_serve(mut args: Args) -> Result<(), String> {
    let shards = args.usize_or("shards", sweep::default_threads());
    let store_dir = args.opt_str("store-dir");
    let resident_cap = args.usize_or("resident-cap", 0);
    let listen = args.opt_str("listen");
    let max_conns = args.usize_or("max-conns", 0);
    let trace_file = args.opt_str("trace-file");
    let trace_sample = args.opt_str("trace-sample");
    let metrics_listen = args.opt_str("metrics-listen");
    let id_offset = args.u64_or("id-offset", 0);
    let id_stride = args.u64_or("id-stride", 1);
    let faults = args.opt_str("faults");
    args.finish()?;
    install_faults(faults)?;
    if id_stride == 0 {
        return Err("--id-stride must be >= 1".into());
    }
    if id_offset >= id_stride {
        return Err(format!(
            "--id-offset must be < --id-stride (got offset {id_offset}, \
             stride {id_stride}): each backend owns one residue class"
        ));
    }
    if resident_cap > 0 && store_dir.is_none() {
        return Err(
            "--resident-cap needs --store-dir: evicting a session without \
             a durable store would destroy it"
                .into(),
        );
    }
    if max_conns > 0 && listen.is_none() {
        return Err(
            "--max-conns needs --listen: the stdio loop has exactly one client"
                .into(),
        );
    }
    if trace_sample.is_some() && trace_file.is_none() {
        return Err(
            "--trace-sample needs --trace-file: there is nowhere to write \
             the sampled events"
                .into(),
        );
    }
    let trace_cfg = trace_file
        .map(|path| -> Result<TraceConfig, String> {
            let sample = match &trace_sample {
                None => 1,
                Some(s) => s.parse::<u64>().ok().filter(|&n| n >= 1).ok_or_else(
                    || format!("--trace-sample must be an integer >= 1, got {s:?}"),
                )?,
            };
            Ok(TraceConfig { path: PathBuf::from(path), sample })
        })
        .transpose()?;
    let listen = listen.map(|s| ListenAddr::parse(&s)).transpose()?;
    let metrics_listen =
        metrics_listen.map(|s| ListenAddr::parse(&s)).transpose()?;
    let store_cfg = store_dir.map(|dir| StoreConfig::new(dir, resident_cap));
    eprintln!(
        "ccn serve: {shards} shard(s); {} (op: open|step|step_batch|predict|\
         snapshot|restore|park|warm|close|stats|metrics; net kinds: {})",
        if listen.is_none() {
            "JSONL requests on stdin, responses on stdout"
        } else {
            "JSONL over the listener below; stdin only signals shutdown"
        },
        NetRegistry::kinds().join("|")
    );
    if let Some(cfg) = &store_cfg {
        eprintln!(
            "durable tier: {} (resident cap {}/shard)",
            cfg.dir.display(),
            if cfg.resident_cap == 0 {
                "unlimited".to_string()
            } else {
                cfg.resident_cap.to_string()
            }
        );
    }
    let mut service = Service::with_store(shards, store_cfg)?;
    if (id_offset, id_stride) != (0, 1) {
        service.set_id_scheme(id_offset, id_stride)?;
        eprintln!("id scheme: offset {id_offset}, stride {id_stride}");
    }
    if let Some(cfg) = &trace_cfg {
        service.set_trace(cfg)?;
        eprintln!(
            "trace: {} (1 in {} ops sampled)",
            cfg.path.display(),
            cfg.sample
        );
    }
    // The scrape endpoint shares the Service's registry by Arc, so it
    // must start before `Server::bind` consumes the service. It works on
    // the stdio path too: one protocol client, many scrapers.
    let metrics = metrics_listen
        .map(|addr| {
            MetricsServer::bind(&addr, std::sync::Arc::clone(service.registry()))
        })
        .transpose()?;
    if let Some(m) = &metrics {
        eprintln!("metrics exposition on {} (GET /metrics)", m.local_addr());
    }
    let parked = match service.pool().stats().iter().map(|s| s.parked).sum::<usize>()
    {
        0 => String::new(),
        n => format!("; resumed {n} parked session(s)"),
    };
    eprintln!("ready{parked}");
    let Some(addr) = listen else {
        // Flush the durable tier even when the stdio loop errored (a
        // client hanging up is routine and must not cost session state);
        // report whichever failure matters more.
        let served = service.run_stdio();
        match service.close() {
            Ok(flushed) if flushed > 0 => {
                eprintln!("flushed {flushed} session(s) to the store")
            }
            Ok(_) => {}
            Err(e) => {
                served?; // a stdio error is the root cause; surface it first
                return Err(format!("shutdown flush: {e}"));
            }
        }
        if let Some(m) = metrics {
            m.shutdown();
        }
        return served;
    };
    let server = Server::bind(service, &addr, max_conns)?;
    eprintln!(
        "listening on {} ({} conns max); serving until stdin closes",
        server.local_addr(),
        if max_conns == 0 {
            "unlimited".to_string()
        } else {
            max_conns.to_string()
        }
    );
    wait_for_stdin_eof();
    let flushed = server.shutdown()?;
    if let Some(m) = metrics {
        m.shutdown();
    }
    if flushed > 0 {
        eprintln!("flushed {flushed} session(s) to the store");
    }
    Ok(())
}

fn cmd_route(mut args: Args) -> Result<(), String> {
    let listen = args
        .opt_str("listen")
        .ok_or("route: --listen tcp://HOST:PORT|unix://PATH is required")?;
    let backends = args.opt_str_all("backend");
    let max_conns = args.usize_or("max-conns", 0);
    let health_interval_ms = args.u64_or("health-interval-ms", 500);
    let connect_timeout_ms = args.u64_or("connect-timeout-ms", 1_000);
    let request_timeout_ms = args.u64_or("request-timeout-ms", 10_000);
    let retries = args.u64_or("retries", 2);
    let replicate_every = args.u64_or("replicate-every", 0);
    let trace_file = args.opt_str("trace-file");
    let trace_sample = args.opt_str("trace-sample");
    let metrics_listen = args.opt_str("metrics-listen");
    let faults = args.opt_str("faults");
    args.finish()?;
    install_faults(faults)?;
    if trace_sample.is_some() && trace_file.is_none() {
        return Err(
            "--trace-sample needs --trace-file: there is nowhere to write \
             the sampled events"
                .into(),
        );
    }
    if backends.is_empty() {
        return Err(
            "route: at least one --backend tcp://HOST:PORT|unix://PATH is \
             required (repeat the flag per backend)"
                .into(),
        );
    }
    let listen = ListenAddr::parse(&listen)?;
    let backends = backends
        .iter()
        .map(|b| ListenAddr::parse(b))
        .collect::<Result<Vec<_>, _>>()?;
    let mut cfg = RouterConfig::new(backends);
    cfg.max_conns = max_conns;
    cfg.health_interval = std::time::Duration::from_millis(health_interval_ms);
    cfg.client.connect_timeout =
        std::time::Duration::from_millis(connect_timeout_ms);
    cfg.client.read_timeout =
        std::time::Duration::from_millis(request_timeout_ms);
    cfg.client.write_timeout =
        std::time::Duration::from_millis(request_timeout_ms);
    cfg.client.retries = retries.min(u32::MAX as u64) as u32;
    cfg.replicate_every = replicate_every;
    cfg.trace = trace_file
        .map(|path| -> Result<TraceConfig, String> {
            let sample = match &trace_sample {
                None => 1,
                Some(s) => s.parse::<u64>().ok().filter(|&n| n >= 1).ok_or_else(
                    || format!("--trace-sample must be an integer >= 1, got {s:?}"),
                )?,
            };
            Ok(TraceConfig { path: PathBuf::from(path), sample })
        })
        .transpose()?;
    cfg.metrics_listen =
        metrics_listen.map(|s| ListenAddr::parse(&s)).transpose()?;
    let n = cfg.backends.len();
    if let Some(tc) = &cfg.trace {
        eprintln!(
            "trace: {} (1 in {} ops sampled; trace_id/span_id correlate \
             with backend traces)",
            tc.path.display(),
            tc.sample
        );
    }
    let server = RouterServer::bind(cfg, &listen)?;
    if let Some(addr) = server.metrics_addr() {
        eprintln!("metrics exposition on {addr} (GET /metrics)");
    }
    eprintln!(
        "ccn route: consistent-hash routing over {n} backend(s); cluster \
         ops: health|handoff|drain|rebalance|promote (plus the full serve \
         protocol)"
    );
    if replicate_every > 0 {
        eprintln!(
            "warm-standby replication: shipping session state to the \
             ring-successor every {replicate_every} acked step(s) \
             (acked-loss window on failover: {} step(s))",
            replicate_every - 1
        );
    }
    eprintln!(
        "listening on {} ({} conns max); routing until stdin closes",
        server.local_addr(),
        if max_conns == 0 {
            "unlimited".to_string()
        } else {
            max_conns.to_string()
        }
    );
    wait_for_stdin_eof();
    server.shutdown()
}

#[cfg(feature = "pjrt")]
fn cmd_pjrt_verify(mut args: Args) -> Result<(), String> {
    let dir = args.str_or("artifacts", "artifacts");
    args.finish()?;
    let rt = PjrtRuntime::load(Path::new(&dir)).map_err(|e| e.to_string())?;
    eprintln!(
        "platform {} | {} artifacts",
        rt.platform(),
        rt.manifest.artifacts.len()
    );
    rt.verify_golden().map_err(|e| e.to_string())?;
    println!("pjrt golden check OK (jax == rust-pjrt round trip)");
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn cmd_pjrt_verify(_args: Args) -> Result<(), String> {
    Err("this binary was built without the `pjrt` feature; rebuild with \
         `--features pjrt` (requires the vendored xla crate, see Cargo.toml)"
        .into())
}

#[cfg(feature = "pjrt")]
fn cmd_pjrt_bench(mut args: Args) -> Result<(), String> {
    let dir = args.str_or("artifacts", "artifacts");
    let steps = args.usize_or("steps", 200);
    args.finish()?;
    let rt = PjrtRuntime::load(Path::new(&dir)).map_err(|e| e.to_string())?;
    let (n_cols, m) = (5, 7);
    let mut stage =
        PjrtColumnarStage::new(&rt, n_cols, m, 0).map_err(|e| e.to_string())?;
    // native twin
    use ccn_rtrl::nets::lstm_column::LstmColumn;
    use ccn_rtrl::util::prng::Xoshiro256;
    let mut rng = Xoshiro256::seed_from_u64(0);
    let mut cols: Vec<LstmColumn> =
        (0..n_cols).map(|_| LstmColumn::new(m, &mut rng, 1.0)).collect();
    stage.set_params_from_columns(&cols);

    let xs: Vec<Vec<f32>> = (0..steps)
        .map(|_| (0..m).map(|_| rng.uniform(-1.0, 1.0)).collect())
        .collect();

    let t0 = std::time::Instant::now();
    for x in &xs {
        stage.step(x).map_err(|e| e.to_string())?;
    }
    let pjrt_per = t0.elapsed().as_secs_f64() / steps as f64;

    let t1 = std::time::Instant::now();
    let native_iters = 200_000usize;
    for i in 0..native_iters {
        let x = &xs[i % xs.len()];
        for col in cols.iter_mut() {
            col.step_with_traces(x);
        }
    }
    let native_per = t1.elapsed().as_secs_f64() / native_iters as f64;

    println!(
        "{}",
        render_table(
            &["path", "per-step", "steps/s", "speedup"],
            &[
                vec![
                    "pjrt".into(),
                    format!("{:.1} us", pjrt_per * 1e6),
                    format!("{:.0}", 1.0 / pjrt_per),
                    "1.0x".into(),
                ],
                vec![
                    "native".into(),
                    format!("{:.2} us", native_per * 1e6),
                    format!("{:.0}", 1.0 / native_per),
                    format!("{:.0}x", pjrt_per / native_per),
                ],
            ],
        )
    );
    println!(
        "(the paper reports its specialized C++ ~50x faster than a framework\n\
         for single-stream small-network learning; same shape here)"
    );
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn cmd_pjrt_bench(_args: Args) -> Result<(), String> {
    Err("this binary was built without the `pjrt` feature; rebuild with \
         `--features pjrt` (requires the vendored xla crate, see Cargo.toml)"
        .into())
}

fn main() {
    let args = Args::from_env();
    let result = match args.subcommand.as_deref() {
        Some("run") => cmd_run(args),
        Some("sweep") => cmd_sweep(args),
        Some("serve") => cmd_serve(args),
        Some("route") => cmd_route(args),
        Some("print-config") => {
            println!("{}", ExperimentConfig::default().to_json().pretty());
            Ok(())
        }
        Some("list-envs") => {
            println!("trace_patterning (trace)");
            println!("trace_patterning_tiny (trace_tiny)");
            println!("trace_conditioning");
            println!("cycle_world_<N>");
            for g in synthatari::env_names() {
                println!("{g}");
            }
            Ok(())
        }
        Some("pjrt-verify") => cmd_pjrt_verify(args),
        Some("pjrt-bench") => cmd_pjrt_bench(args),
        _ => {
            eprintln!(
                "usage: ccn <run|sweep|serve|route|print-config|list-envs|pjrt-verify|pjrt-bench> [options]\n\
                 \n\
                 run options: --env <name> --learner <spec> --steps N --alpha A\n\
                   --lambda L --gamma G --eps E --seed S --out results/run.json\n\
                 learner specs: columnar:D | constructive:TOTAL:STEPS_PER_STAGE |\n\
                   ccn:TOTAL:PER_STAGE:STEPS_PER_STAGE | tbptt:D:K | snap1:D\n\
                 sweep adds: --seeds 0,1,2 --threads T\n\
                 serve options: --shards N --store-dir DIR --resident-cap K\n\
                   --listen tcp://HOST:PORT|unix://PATH --max-conns M\n\
                   --trace-file PATH --trace-sample N --metrics-listen ADDR\n\
                   (JSONL protocol on stdin/stdout by default; ops: open|step|\n\
                   step_batch|predict|snapshot|restore|park|warm|close|stats|\n\
                   metrics; every learner spec above is serveable and\n\
                   snapshot-safe. --store-dir mounts the durable session tier:\n\
                   sessions beyond K per shard are LRU-evicted to disk,\n\
                   rehydrated on demand, and survive restarts. --listen serves\n\
                   many concurrent clients over TCP or a unix socket instead\n\
                   of stdio, until stdin closes. --trace-file appends one\n\
                   JSONL event per sampled op (1 in N, default every op) with\n\
                   latency and stage breakdown. --metrics-listen ADDR serves\n\
                   Prometheus text exposition on GET /metrics over a second\n\
                   listener. --id-offset K --id-stride N\n\
                   makes this backend mint only ids of residue class K mod N,\n\
                   so a cluster's backends never collide)\n\
                 route options: --listen tcp://HOST:PORT|unix://PATH\n\
                   --backend ADDR (repeat per backend) --max-conns M\n\
                   --health-interval-ms H --connect-timeout-ms C\n\
                   --request-timeout-ms R --retries K --replicate-every K\n\
                   --trace-file PATH --trace-sample N --metrics-listen ADDR\n\
                   (consistent-hash routes session ids over the backends,\n\
                   serving the full serve protocol transparently plus the\n\
                   cluster ops health|handoff|drain|rebalance|promote — live\n\
                   store-backed session migration between backends.\n\
                   --replicate-every K parks a warm standby copy of every\n\
                   session on its ring-successor backend after every K acked\n\
                   steps; a dead backend's sessions then fail over onto their\n\
                   standbys automatically (K=1: no acked step is ever lost).\n\
                   --trace-file emits router-side trace events whose\n\
                   trace_id/span_id are injected into forwarded ops so\n\
                   backend traces join on trace_id; metrics {{\"scope\":\n\
                   \"fleet\"}} rolls every backend's registry into one merged\n\
                   block; --metrics-listen ADDR serves GET /metrics)\n\
                 serve and route also take --faults SPEC (or the CCN_FAULTS\n\
                   env var): seeded deterministic fault injection for chaos\n\
                   testing, e.g. \"seed:7;transport.read:drop:0.05;\\\n\
                   store.append:delay:0.2:5\" (points: client.request,\n\
                   transport.read, transport.write, store.append, store.load,\n\
                   shard.enqueue; actions: drop|delay|dup|truncate)"
            );
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}
