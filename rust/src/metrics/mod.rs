//! Online metrics: running statistics, windowed errors, learning curves,
//! and simple CSV/JSON result writers used by the coordinator and benches.

use std::io::Write;
use std::path::Path;

/// Numerically stable streaming mean/variance (Welford).
#[derive(Clone, Debug, Default)]
pub struct OnlineStats {
    n: u64,
    mean: f64,
    m2: f64,
}

impl OnlineStats {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    /// Standard error of the mean.
    pub fn stderr(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.std() / (self.n as f64).sqrt()
        }
    }
}

/// Exponentially weighted moving average (the paper plots smoothed error).
#[derive(Clone, Debug)]
pub struct Ewma {
    beta: f64,
    value: f64,
    initialized: bool,
}

impl Ewma {
    pub fn new(beta: f64) -> Self {
        Self {
            beta,
            value: 0.0,
            initialized: false,
        }
    }

    pub fn push(&mut self, x: f64) {
        if self.initialized {
            self.value = self.beta * self.value + (1.0 - self.beta) * x;
        } else {
            self.value = x;
            self.initialized = true;
        }
    }

    pub fn get(&self) -> f64 {
        self.value
    }
}

/// A learning curve recorded at a fixed number of points: pushes stream in,
/// each bin stores the mean of its window. Keeps memory O(points) for
/// arbitrarily long runs.
#[derive(Clone, Debug)]
pub struct Curve {
    bin_size: u64,
    acc: f64,
    acc_n: u64,
    pub xs: Vec<u64>,
    pub ys: Vec<f64>,
    seen: u64,
}

impl Curve {
    /// `total_steps` and `points` fix the bin width up front.
    pub fn new(total_steps: u64, points: usize) -> Self {
        Self {
            bin_size: (total_steps / points.max(1) as u64).max(1),
            acc: 0.0,
            acc_n: 0,
            xs: Vec::new(),
            ys: Vec::new(),
            seen: 0,
        }
    }

    pub fn push(&mut self, value: f64) {
        self.acc += value;
        self.acc_n += 1;
        self.seen += 1;
        if self.acc_n >= self.bin_size {
            self.xs.push(self.seen);
            self.ys.push(self.acc / self.acc_n as f64);
            self.acc = 0.0;
            self.acc_n = 0;
        }
    }

    /// Flush a trailing partial bin (call at end of run).
    pub fn finish(&mut self) {
        if self.acc_n > 0 {
            self.xs.push(self.seen);
            self.ys.push(self.acc / self.acc_n as f64);
            self.acc = 0.0;
            self.acc_n = 0;
        }
    }

    /// Mean of the last `frac` of the curve (e.g. final-window error).
    pub fn tail_mean(&self, frac: f64) -> f64 {
        if self.ys.is_empty() {
            return f64::NAN;
        }
        let k = ((self.ys.len() as f64 * frac).ceil() as usize)
            .clamp(1, self.ys.len());
        let tail = &self.ys[self.ys.len() - k..];
        tail.iter().sum::<f64>() / tail.len() as f64
    }
}

/// Mean over aligned curves plus stderr band (for multi-seed plots).
pub fn aggregate_curves(curves: &[Curve]) -> (Vec<u64>, Vec<f64>, Vec<f64>) {
    assert!(!curves.is_empty());
    let len = curves.iter().map(|c| c.ys.len()).min().unwrap();
    let xs = curves[0].xs[..len].to_vec();
    let mut mean = Vec::with_capacity(len);
    let mut stderr = Vec::with_capacity(len);
    for i in 0..len {
        let mut st = OnlineStats::new();
        for c in curves {
            st.push(c.ys[i]);
        }
        mean.push(st.mean());
        stderr.push(st.stderr());
    }
    (xs, mean, stderr)
}

/// Write a CSV file: header + rows of f64 columns.
pub fn write_csv(
    path: &Path,
    header: &[&str],
    columns: &[&[f64]],
) -> std::io::Result<()> {
    assert!(!columns.is_empty());
    let rows = columns[0].len();
    assert!(columns.iter().all(|c| c.len() == rows), "ragged columns");
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    writeln!(f, "{}", header.join(","))?;
    for r in 0..rows {
        let row: Vec<String> = columns.iter().map(|c| format!("{}", c[r])).collect();
        writeln!(f, "{}", row.join(","))?;
    }
    Ok(())
}

/// Percentile of a sample set by nearest rank; sorts in place. Used for
/// latency reporting (p50/p99) in the serve benches.
///
/// [`crate::obs::HistogramSnapshot::percentile`] follows the same
/// nearest-rank convention over log2 buckets, so a bench that switches
/// from collecting raw samples to recording into an [`crate::obs::Histogram`]
/// reports comparable quantiles (exact on bucket boundaries, bucket-upper-
/// bound approximations in between).
///
/// Convention:
/// - `None` for an empty sample set (there is no percentile to report —
///   callers must not invent one);
/// - `p` is a fraction and is clamped to `[0, 1]`: `p = 0.0` selects the
///   minimum, `p = 1.0` the maximum, and a single-element slice returns
///   that element for every `p`;
/// - the selected rank is `round((len - 1) * p)`;
/// - NaN samples sort last (`f64::total_cmp`) and are only selected when
///   every sample is NaN.
pub fn percentile(samples: &mut [f64], p: f64) -> Option<f64> {
    if samples.is_empty() {
        return None;
    }
    // total_cmp is a total order that places NaN after every real value
    samples.sort_by(|a, b| a.total_cmp(b));
    let idx = ((samples.len() - 1) as f64 * p.clamp(0.0, 1.0)).round() as usize;
    Some(samples[idx])
}

/// Render an aligned text table (benches print these per paper figure).
pub fn render_table(header: &[&str], rows: &[Vec<String>]) -> String {
    let ncols = header.len();
    let mut widths: Vec<usize> = header.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate().take(ncols) {
            widths[i] = widths[i].max(cell.len());
        }
    }
    let mut out = String::new();
    let fmt_row = |cells: &[String], widths: &[usize]| -> String {
        cells
            .iter()
            .enumerate()
            .map(|(i, c)| format!("{:>width$}", c, width = widths[i]))
            .collect::<Vec<_>>()
            .join("  ")
    };
    let head: Vec<String> = header.iter().map(|s| s.to_string()).collect();
    out.push_str(&fmt_row(&head, &widths));
    out.push('\n');
    out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (ncols - 1)));
    out.push('\n');
    for row in rows {
        out.push_str(&fmt_row(row, &widths));
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_nearest_rank() {
        let mut xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&mut xs, 0.0), Some(1.0));
        assert_eq!(percentile(&mut xs, 1.0), Some(100.0));
        assert_eq!(percentile(&mut xs, 0.5), Some(51.0)); // round(99*0.5)=50 -> 51.0
        // unsorted input
        let mut shuffled = vec![3.0, 1.0, 2.0];
        assert_eq!(percentile(&mut shuffled, 1.0), Some(3.0));
    }

    #[test]
    fn percentile_edge_cases() {
        // empty: no percentile exists
        let mut none: Vec<f64> = vec![];
        assert_eq!(percentile(&mut none, 0.5), None);
        // single element: every p selects it, including the extremes
        for p in [0.0, 0.5, 0.99, 1.0] {
            let mut one = vec![7.0];
            assert_eq!(percentile(&mut one, p), Some(7.0));
        }
        // out-of-range p clamps to min/max instead of panicking
        let mut xs = vec![1.0, 2.0, 3.0];
        assert_eq!(percentile(&mut xs, -0.5), Some(1.0));
        assert_eq!(percentile(&mut xs, 100.0), Some(3.0));
        // NaN sorts last: selected only when everything is NaN
        let mut with_nan = vec![f64::NAN, 2.0, 1.0];
        assert_eq!(percentile(&mut with_nan, 0.5), Some(2.0));
        let mut all_nan = vec![f64::NAN, f64::NAN];
        assert!(percentile(&mut all_nan, 0.5).unwrap().is_nan());
    }

    #[test]
    fn online_stats_matches_closed_form() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        let mut st = OnlineStats::new();
        for &x in &xs {
            st.push(x);
        }
        assert!((st.mean() - 5.0).abs() < 1e-12);
        // sample variance of this classic dataset is 32/7
        assert!((st.var() - 32.0 / 7.0).abs() < 1e-12);
    }

    #[test]
    fn online_stats_single_value() {
        let mut st = OnlineStats::new();
        st.push(3.0);
        assert_eq!(st.mean(), 3.0);
        assert_eq!(st.var(), 0.0);
        assert_eq!(st.stderr(), 0.0);
    }

    #[test]
    fn ewma_converges() {
        let mut e = Ewma::new(0.9);
        e.push(10.0);
        assert_eq!(e.get(), 10.0); // first value initializes
        for _ in 0..200 {
            e.push(2.0);
        }
        assert!((e.get() - 2.0).abs() < 1e-6);
    }

    #[test]
    fn curve_bins_and_tail() {
        let mut c = Curve::new(100, 10);
        for i in 0..100 {
            c.push(i as f64);
        }
        c.finish();
        assert_eq!(c.ys.len(), 10);
        assert!((c.ys[0] - 4.5).abs() < 1e-12); // mean of 0..9
        assert!((c.tail_mean(0.2) - (84.5 + 94.5) / 2.0).abs() < 1e-12);
    }

    #[test]
    fn curve_partial_bin_flush() {
        let mut c = Curve::new(10, 3);
        for i in 0..8 {
            c.push(i as f64);
        }
        c.finish();
        assert_eq!(*c.xs.last().unwrap(), 8);
        assert_eq!(c.acc_n, 0);
    }

    #[test]
    fn aggregate_mean_and_stderr() {
        let mut a = Curve::new(4, 2);
        let mut b = Curve::new(4, 2);
        for v in [1.0, 1.0, 3.0, 3.0] {
            a.push(v);
        }
        for v in [3.0, 3.0, 5.0, 5.0] {
            b.push(v);
        }
        a.finish();
        b.finish();
        let (xs, mean, stderr) = aggregate_curves(&[a, b]);
        assert_eq!(xs, vec![2, 4]);
        assert_eq!(mean, vec![2.0, 4.0]);
        assert!((stderr[0] - 1.0).abs() < 1e-12);
    }

    #[test]
    fn csv_roundtrip() {
        let dir = std::env::temp_dir().join("ccn_test_csv");
        let path = dir.join("x.csv");
        write_csv(&path, &["a", "b"], &[&[1.0, 2.0], &[3.0, 4.0]]).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text.lines().count(), 3);
        assert!(text.starts_with("a,b\n1,3\n"));
    }

    #[test]
    fn table_alignment() {
        let t = render_table(
            &["method", "err"],
            &[
                vec!["ccn".into(), "0.5".into()],
                vec!["tbptt".into(), "1".into()],
            ],
        );
        assert!(t.contains("method"));
        assert!(t.lines().count() == 4);
    }
}
