//! Constructive-Columnar Network (paper Section 3.3).
//!
//! A CCN grows in *stages*. Stage `s` holds `features_per_stage`
//! independent LSTM columns whose input is the raw observation
//! concatenated with the normalized features of all earlier (frozen)
//! stages — so later stages hold *hierarchical* recurrent features.
//! Only the newest stage learns (exact, cheap RTRL per column); after
//! `steps_per_stage` steps it is frozen and the next stage materializes.
//!
//! Degenerate corners of the configuration space:
//! - `features_per_stage == total_features` (one everlasting stage) is a
//!   **Columnar network** (Section 3.1);
//! - `features_per_stage == 1` is a **Constructive network** (Section 3.2).
//!
//! Within a step, stages are evaluated in order and each consumes the
//! *current-step* normalized outputs of the stages before it, exactly as
//! in Figure 2 (h3/h4 read h1/h2's fresh values).

use super::lstm_column::LstmColumn;
use super::normalizer::OnlineNormalizer;
use super::{BatchCapability, PersistableNet, PredictionNet};
use crate::compute;
use crate::util::json::Json;
use crate::util::prng::Xoshiro256;

#[derive(Clone, Debug)]
pub struct CcnConfig {
    pub n_inputs: usize,
    pub total_features: usize,
    pub features_per_stage: usize,
    /// Steps before freezing the learning stage; `u64::MAX` never freezes
    /// (that is the columnar configuration).
    pub steps_per_stage: u64,
    pub init_scale: f32,
    pub norm_eps: f32,
    pub norm_beta: f32,
}

impl CcnConfig {
    /// Paper trace-patterning CCN: 20 features, 4 per stage.
    pub fn trace_paper() -> Self {
        Self {
            n_inputs: 7,
            total_features: 20,
            features_per_stage: 4,
            steps_per_stage: 10_000_000,
            init_scale: 1.0,
            norm_eps: 0.01,
            norm_beta: super::normalizer::NORM_BETA,
        }
    }

    /// `steps_per_stage == u64::MAX` (the columnar corner) is encoded as
    /// JSON null, since f64 cannot hold u64::MAX exactly.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("n_inputs", Json::Num(self.n_inputs as f64)),
            ("total_features", Json::Num(self.total_features as f64)),
            (
                "features_per_stage",
                Json::Num(self.features_per_stage as f64),
            ),
            (
                "steps_per_stage",
                if self.steps_per_stage == u64::MAX {
                    Json::Null
                } else {
                    Json::Num(self.steps_per_stage as f64)
                },
            ),
            ("init_scale", Json::Num(self.init_scale as f64)),
            ("norm_eps", Json::Num(self.norm_eps as f64)),
            ("norm_beta", Json::Num(self.norm_beta as f64)),
        ])
    }

    pub fn from_json(v: &Json) -> Option<Self> {
        let steps_per_stage = match v.get("steps_per_stage")? {
            Json::Null => u64::MAX,
            // strict: fractional/negative/oversized stage budgets used to
            // truncate silently and corrupt the growth schedule on restore
            other => other.as_u64_strict()?,
        };
        Some(Self {
            n_inputs: v.get("n_inputs")?.as_usize()?,
            total_features: v.get("total_features")?.as_usize()?,
            features_per_stage: v.get("features_per_stage")?.as_usize()?,
            steps_per_stage,
            init_scale: v.get("init_scale")?.as_f64()? as f32,
            norm_eps: v.get("norm_eps")?.as_f64()? as f32,
            norm_beta: v.get("norm_beta")?.as_f64()? as f32,
        })
    }
}

struct Stage {
    columns: Vec<LstmColumn>,
    normalizer: OnlineNormalizer,
    /// raw hidden states scratch
    raw: Vec<f32>,
    /// input width of this stage's columns
    m: usize,
}

pub struct CcnNet {
    cfg: CcnConfig,
    stages: Vec<Stage>,
    /// index of the learning stage (== stages.len() - 1)
    learning_stage: usize,
    steps_in_stage: u64,
    epoch: u64,
    /// normalized features of all materialized columns, stage-major
    feats: Vec<f32>,
    /// scratch input buffer: [x_raw | feats of stages 0..s]
    xbuf: Vec<f32>,
    rng: Xoshiro256,
    frozen_forever: bool,
}

impl CcnNet {
    pub fn new(cfg: CcnConfig, seed: u64) -> Self {
        assert!(cfg.total_features >= 1);
        assert!(cfg.features_per_stage >= 1);
        assert!(cfg.n_inputs >= 1);
        let rng = Xoshiro256::seed_from_u64(seed ^ 0x6363_6e6e); // "ccnn"
        let mut net = Self {
            cfg,
            stages: Vec::new(),
            learning_stage: 0,
            steps_in_stage: 0,
            epoch: 0,
            feats: Vec::new(),
            xbuf: Vec::new(),
            rng,
            frozen_forever: false,
        };
        net.push_stage();
        net
    }

    fn stage_width(&self, s: usize) -> usize {
        (self.cfg.features_per_stage)
            .min(self.cfg.total_features - self.cfg.features_per_stage * s)
    }

    fn push_stage(&mut self) {
        let s = self.stages.len();
        let u = self.stage_width(s);
        let m = self.cfg.n_inputs + self.cfg.features_per_stage * s;
        let columns = (0..u)
            .map(|_| LstmColumn::new(m, &mut self.rng, self.cfg.init_scale))
            .collect();
        self.stages.push(Stage {
            columns,
            normalizer: OnlineNormalizer::new(u, self.cfg.norm_beta, self.cfg.norm_eps),
            raw: vec![0.0; u],
            m,
        });
        self.learning_stage = s;
        self.steps_in_stage = 0;
        self.feats.resize(self.feats.len() + u, 0.0);
        self.xbuf = vec![0.0; m + u]; // widest needed so far
        self.epoch += 1;
    }

    /// Materialized feature count.
    fn d(&self) -> usize {
        self.feats.len()
    }

    fn learning(&self) -> &Stage {
        &self.stages[self.learning_stage]
    }

    /// Exact steps spent in the current stage (tests).
    pub fn steps_in_stage(&self) -> u64 {
        self.steps_in_stage
    }

    pub fn n_stages(&self) -> usize {
        self.stages.len()
    }

    /// Access a column (tests / parity checks / SoA packing).
    pub fn column(&self, stage: usize, k: usize) -> &LstmColumn {
        &self.stages[stage].columns[k]
    }

    /// A stage's online normalizer (read-only; SoA packing + snapshots).
    pub fn stage_norm(&self, stage: usize) -> &OnlineNormalizer {
        &self.stages[stage].normalizer
    }

    pub fn config(&self) -> &CcnConfig {
        &self.cfg
    }

    /// All features materialized and frozen (readout-only regime).
    pub fn frozen_forever(&self) -> bool {
        self.frozen_forever
    }

    /// The rng driving stage-construction draws — staged cohort lanes
    /// carry it so a batched session hops stages with the exact draws its
    /// scalar twin would have made.
    pub fn rng_state(&self) -> [u64; 4] {
        self.rng.state()
    }

    /// Apply a pending stage boundary: if the stage clock has reached
    /// `steps_per_stage`, either materialize the next stage or (once all
    /// features exist) freeze forever. Idempotent when no boundary is
    /// pending. `end_step` calls this after ticking the clock; the serve
    /// layer calls it directly when rebuilding a session from a staged
    /// cohort lane whose clock crossed the boundary inside the batch.
    pub fn settle_stage_boundary(&mut self) {
        if self.steps_in_stage >= self.cfg.steps_per_stage && !self.frozen_forever {
            let materialized = self.d();
            if materialized < self.cfg.total_features {
                self.push_stage();
            } else {
                // every feature frozen: the net stops adapting its
                // recurrent parameters (readout keeps learning) — the
                // plasticity-loss regime Section 6 discusses.
                self.frozen_forever = true;
                self.epoch += 1;
            }
        }
    }

    /// Rebuild a net from captured per-stage state. `stages_parts[s]` is
    /// `(columns, normalizer)`; widths must match what `cfg` prescribes
    /// for stage `s`. The rebuilt net continues exactly where the
    /// original left off (all cross-step state lives in the columns, the
    /// normalizers, the stage clock and the rng).
    pub fn from_parts(
        cfg: CcnConfig,
        stages_parts: Vec<(Vec<LstmColumn>, OnlineNormalizer)>,
        steps_in_stage: u64,
        epoch: u64,
        frozen_forever: bool,
        rng: Xoshiro256,
    ) -> Result<Self, String> {
        if stages_parts.is_empty() {
            return Err("ccn: at least one stage required".into());
        }
        let mut stages = Vec::with_capacity(stages_parts.len());
        let mut total = 0usize;
        for (s, (columns, normalizer)) in stages_parts.into_iter().enumerate() {
            if s > 0 && cfg.features_per_stage * s >= cfg.total_features {
                return Err(format!("ccn: stage {s} exceeds total_features"));
            }
            let want_u = cfg
                .features_per_stage
                .min(cfg.total_features - cfg.features_per_stage * s);
            let want_m = cfg.n_inputs + cfg.features_per_stage * s;
            if columns.len() != want_u {
                return Err(format!(
                    "ccn stage {s}: {} columns, want {want_u}",
                    columns.len()
                ));
            }
            if columns.iter().any(|c| c.m != want_m) {
                return Err(format!("ccn stage {s}: column width != {want_m}"));
            }
            if normalizer.len() != want_u {
                return Err(format!(
                    "ccn stage {s}: normalizer width {} != {want_u}",
                    normalizer.len()
                ));
            }
            total += want_u;
            stages.push(Stage {
                raw: vec![0.0; want_u],
                m: want_m,
                columns,
                normalizer,
            });
        }
        let last = stages.len() - 1;
        let xbuf_len = stages[last].m + stages[last].columns.len();
        Ok(Self {
            cfg,
            learning_stage: last,
            steps_in_stage,
            epoch,
            feats: vec![0.0; total],
            xbuf: vec![0.0; xbuf_len],
            rng,
            frozen_forever,
            stages,
        })
    }

    /// Full serialization of parameters, traces, normalizer statistics
    /// and growth bookkeeping (the session snapshot format of
    /// [`crate::serve`]).
    pub fn to_json(&self) -> Json {
        let stages: Vec<Json> = self
            .stages
            .iter()
            .map(|st| {
                Json::obj(vec![
                    (
                        "columns",
                        Json::Arr(st.columns.iter().map(|c| c.to_json()).collect()),
                    ),
                    ("norm", st.normalizer.to_json()),
                ])
            })
            .collect();
        let rng_state: Vec<Json> = self
            .rng
            .state()
            .iter()
            .map(|s| Json::Str(format!("{s:016x}")))
            .collect();
        Json::obj(vec![
            ("cfg", self.cfg.to_json()),
            ("stages", Json::Arr(stages)),
            ("steps_in_stage", Json::Num(self.steps_in_stage as f64)),
            ("epoch", Json::Num(self.epoch as f64)),
            ("frozen_forever", Json::Bool(self.frozen_forever)),
            ("rng", Json::Arr(rng_state)),
        ])
    }

    pub fn from_json(v: &Json) -> Result<Self, String> {
        let bad = |what: &str| format!("ccn snapshot: bad or missing '{what}'");
        let cfg = CcnConfig::from_json(v.get("cfg").ok_or_else(|| bad("cfg"))?)
            .ok_or_else(|| bad("cfg"))?;
        let mut parts = Vec::new();
        for sj in v
            .get("stages")
            .and_then(|s| s.as_arr())
            .ok_or_else(|| bad("stages"))?
        {
            let cols_json =
                sj.get("columns").and_then(|c| c.as_arr()).ok_or_else(|| bad("columns"))?;
            let mut columns = Vec::with_capacity(cols_json.len());
            for cj in cols_json {
                columns.push(LstmColumn::from_json(cj).ok_or_else(|| bad("column"))?);
            }
            let norm = OnlineNormalizer::from_json(
                sj.get("norm").ok_or_else(|| bad("norm"))?,
            )
            .ok_or_else(|| bad("norm"))?;
            parts.push((columns, norm));
        }
        let mut rng_state = [0u64; 4];
        let rng_json =
            v.get("rng").and_then(|r| r.as_arr()).ok_or_else(|| bad("rng"))?;
        if rng_json.len() != 4 {
            return Err(bad("rng"));
        }
        for (dst, src) in rng_state.iter_mut().zip(rng_json) {
            let s = src.as_str().ok_or_else(|| bad("rng"))?;
            *dst = u64::from_str_radix(s, 16).map_err(|_| bad("rng"))?;
        }
        Self::from_parts(
            cfg,
            parts,
            // strict u64: `as_f64 as u64` silently mangled fractional,
            // negative, and >2^53 stage clocks into valid-looking ones
            v.get("steps_in_stage")
                .and_then(|s| s.as_u64_strict())
                .ok_or_else(|| bad("steps_in_stage"))?,
            v.get("epoch")
                .and_then(|e| e.as_u64_strict())
                .ok_or_else(|| bad("epoch"))?,
            v.get("frozen_forever")
                .and_then(|f| f.as_bool())
                .ok_or_else(|| bad("frozen_forever"))?,
            Xoshiro256::from_state(rng_state),
        )
    }
}

impl PredictionNet for CcnNet {
    fn n_features(&self) -> usize {
        self.d()
    }

    fn advance(&mut self, x: &[f32]) {
        debug_assert_eq!(x.len(), self.cfg.n_inputs);
        let n = self.cfg.n_inputs;
        self.xbuf[..n].copy_from_slice(x);
        let mut feat_off = 0; // offset into self.feats / xbuf past raw input
        let n_stages = self.stages.len();
        for s in 0..n_stages {
            let learning = s == self.learning_stage && !self.frozen_forever;
            let stage = &mut self.stages[s];
            let width = stage.columns.len();
            let input = &self.xbuf[..stage.m];
            for (k, col) in stage.columns.iter_mut().enumerate() {
                if learning {
                    col.step_with_traces(input);
                } else {
                    col.step_forward_only(input);
                }
                stage.raw[k] = col.h;
            }
            // normalize this stage's fresh features and expose them both
            // to the readout (feats) and to later stages (xbuf).
            let out = &mut self.feats[feat_off..feat_off + width];
            stage.normalizer.update_and_normalize(&stage.raw, out);
            self.xbuf[n + feat_off..n + feat_off + width].copy_from_slice(out);
            feat_off += width;
        }
    }

    fn features(&self) -> &[f32] {
        &self.feats
    }

    fn n_learnable_params(&self) -> usize {
        if self.frozen_forever {
            return 0;
        }
        let st = self.learning();
        st.columns.len() * LstmColumn::n_params(st.m)
    }

    fn grad_y(&self, w_out: &[f32], grad: &mut [f32]) {
        debug_assert_eq!(w_out.len(), self.d());
        if self.frozen_forever {
            return;
        }
        let st = self.learning();
        let per = LstmColumn::n_params(st.m);
        let feat_base = self.cfg.features_per_stage * self.learning_stage;
        for (k, col) in st.columns.iter().enumerate() {
            // y = sum w_g * (h_g - mu_g)/denom_g  =>
            // dy/dtheta_k = w_k / denom_k * TH_theta_k
            let scale = w_out[feat_base + k] / st.normalizer.denom(k);
            col.write_grad(scale, &mut grad[k * per..(k + 1) * per]);
        }
    }

    fn apply_update(&mut self, delta: &[f32]) {
        if self.frozen_forever {
            return;
        }
        let st = &mut self.stages[self.learning_stage];
        let per = LstmColumn::n_params(st.m);
        for (k, col) in st.columns.iter_mut().enumerate() {
            col.apply_update(&delta[k * per..(k + 1) * per]);
        }
    }

    fn param_epoch(&self) -> u64 {
        self.epoch
    }

    fn end_step(&mut self) {
        self.steps_in_stage += 1;
        self.settle_stage_boundary();
    }

    fn flops_per_step(&self) -> u64 {
        let d = self.d() as u64;
        let n = self.cfg.n_inputs as u64;
        let u = self.learning().columns.len() as u64;
        if self.stages.len() == 1 && self.cfg.steps_per_stage == u64::MAX {
            compute::columnar_ops(d, n)
        } else {
            compute::ccn_ops(d, n, u)
        }
    }

    fn name(&self) -> &'static str {
        if self.cfg.steps_per_stage == u64::MAX {
            "columnar"
        } else if self.cfg.features_per_stage == 1 {
            "constructive"
        } else {
            "ccn"
        }
    }
}

impl PersistableNet for CcnNet {
    /// The three CCN-family kinds share one snapshot format; any of them
    /// restores through [`CcnNet::from_json`].
    fn kind(&self) -> &'static str {
        self.name()
    }

    fn n_inputs(&self) -> usize {
        self.cfg.n_inputs
    }

    fn save(&self) -> Json {
        self.to_json()
    }

    /// A single never-freezing stage *is* the pure-columnar shape the SoA
    /// batch store holds; every other CCN-family shape is a frozen prefix
    /// plus one learning stage and batches into stage-keyed cohorts.
    fn batch_capability(&self) -> BatchCapability {
        if self.cfg.steps_per_stage == u64::MAX && self.stages.len() == 1 {
            BatchCapability::Columnar {
                n_inputs: self.cfg.n_inputs,
                d: self.stages[0].columns.len(),
                eps: self.cfg.norm_eps,
                beta: self.cfg.norm_beta,
            }
        } else {
            BatchCapability::Staged {
                n_inputs: self.cfg.n_inputs,
                d: self.d(),
                stage: self.learning_stage,
                features_per_stage: self.cfg.features_per_stage,
                total_features: self.cfg.total_features,
                steps_per_stage: self.cfg.steps_per_stage,
                init_scale: self.cfg.init_scale,
                frozen_forever: self.frozen_forever,
                eps: self.cfg.norm_eps,
                beta: self.cfg.norm_beta,
                prefix_sig: staged_prefix_sig(
                    &self.cfg,
                    self.learning_stage,
                    self.frozen_forever,
                ),
            }
        }
    }
}

/// FNV-1a digest of the structural spec of a staged cohort: shape
/// integers plus the exact f32 bit patterns that enter the math. Two
/// sessions with equal signatures are structurally interchangeable lanes
/// of the same cohort.
pub(crate) fn staged_prefix_sig(cfg: &CcnConfig, stage: usize, frozen: bool) -> u64 {
    fn mix(mut h: u64, v: u64) -> u64 {
        for b in v.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
        h
    }
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    h = mix(h, cfg.n_inputs as u64);
    h = mix(h, cfg.total_features as u64);
    h = mix(h, cfg.features_per_stage as u64);
    h = mix(h, cfg.steps_per_stage);
    h = mix(h, stage as u64);
    h = mix(h, frozen as u64);
    h = mix(h, cfg.init_scale.to_bits() as u64);
    h = mix(h, cfg.norm_eps.to_bits() as u64);
    h = mix(h, cfg.norm_beta.to_bits() as u64);
    h
}

impl super::ServableNet for CcnNet {
    fn as_any(&self) -> &dyn std::any::Any {
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::{check, prop_assert};

    fn tiny_cfg() -> CcnConfig {
        CcnConfig {
            n_inputs: 3,
            total_features: 6,
            features_per_stage: 2,
            steps_per_stage: 50,
            init_scale: 0.5,
            norm_eps: 0.01,
            norm_beta: 0.999,
        }
    }

    fn drive(net: &mut CcnNet, steps: usize, seed: u64) {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        let n = net.cfg.n_inputs;
        for _ in 0..steps {
            let x: Vec<f32> = (0..n).map(|_| rng.uniform(-1.0, 1.0)).collect();
            net.advance(&x);
            net.end_step();
        }
    }

    #[test]
    fn stages_materialize_on_schedule() {
        let mut net = CcnNet::new(tiny_cfg(), 0);
        assert_eq!(net.n_features(), 2);
        assert_eq!(net.n_stages(), 1);
        drive(&mut net, 50, 1);
        assert_eq!(net.n_stages(), 2, "stage 2 after steps_per_stage");
        assert_eq!(net.n_features(), 4);
        drive(&mut net, 50, 2);
        assert_eq!(net.n_stages(), 3);
        assert_eq!(net.n_features(), 6);
        // all features materialized; next boundary freezes everything
        drive(&mut net, 50, 3);
        assert_eq!(net.n_stages(), 3);
        assert_eq!(net.n_learnable_params(), 0);
    }

    #[test]
    fn stage_input_widths_grow() {
        let mut net = CcnNet::new(tiny_cfg(), 0);
        drive(&mut net, 120, 1);
        assert_eq!(net.stages[0].m, 3);
        assert_eq!(net.stages[1].m, 5);
        assert_eq!(net.stages[2].m, 7);
    }

    #[test]
    fn frozen_parameters_never_change() {
        let mut net = CcnNet::new(tiny_cfg(), 7);
        drive(&mut net, 60, 1); // stage 0 frozen now
        let frozen = net.column(0, 0).params();
        // keep learning with updates applied to the learning stage
        let mut rng = Xoshiro256::seed_from_u64(9);
        for _ in 0..100 {
            let x: Vec<f32> = (0..3).map(|_| rng.uniform(-1.0, 1.0)).collect();
            net.advance(&x);
            let np = net.n_learnable_params();
            let delta: Vec<f32> = (0..np).map(|_| rng.uniform(-0.01, 0.01)).collect();
            net.apply_update(&delta);
            net.end_step();
        }
        assert_eq!(net.column(0, 0).params(), frozen, "frozen stage mutated");
    }

    #[test]
    fn param_epoch_tracks_stage_transitions() {
        let mut net = CcnNet::new(tiny_cfg(), 3);
        let e0 = net.param_epoch();
        drive(&mut net, 49, 1);
        assert_eq!(net.param_epoch(), e0);
        drive(&mut net, 1, 2);
        assert_eq!(net.param_epoch(), e0 + 1);
    }

    #[test]
    fn columnar_corner_never_freezes() {
        let cfg = CcnConfig {
            n_inputs: 4,
            total_features: 5,
            features_per_stage: 5,
            steps_per_stage: u64::MAX,
            init_scale: 0.5,
            norm_eps: 0.01,
            norm_beta: 0.999,
        };
        let mut net = CcnNet::new(cfg, 0);
        assert_eq!(net.name(), "columnar");
        drive(&mut net, 5000, 1);
        assert_eq!(net.n_stages(), 1);
        assert!(net.n_learnable_params() > 0);
    }

    #[test]
    fn column_independence_within_stage() {
        // perturbing one learning column's parameters must not affect the
        // features of its siblings (paper Section 3.1's structural claim).
        let cfg = tiny_cfg();
        let mut a = CcnNet::new(cfg.clone(), 5);
        let mut b = CcnNet::new(cfg, 5);
        // perturb column 1 of the learning stage in b only
        let np = b.n_learnable_params();
        let per = np / 2;
        let mut delta = vec![0.0; np];
        for v in delta[per..].iter_mut() {
            *v = 0.1;
        }
        b.apply_update(&delta);
        let mut rng = Xoshiro256::seed_from_u64(11);
        for _ in 0..30 {
            let x: Vec<f32> = (0..3).map(|_| rng.uniform(-1.0, 1.0)).collect();
            a.advance(&x);
            b.advance(&x);
            // feature 0 (column 0 of stage 0) must be identical
            assert_eq!(a.features()[0], b.features()[0]);
            // feature 1 must differ at some point (checked after loop)
        }
        assert_ne!(a.features()[1], b.features()[1]);
    }

    #[test]
    fn grad_reflects_normalizer_denominator() {
        let mut net = CcnNet::new(tiny_cfg(), 13);
        drive(&mut net, 10, 1);
        let d = net.n_features();
        let w = vec![1.0; d];
        let mut g1 = vec![0.0; net.n_learnable_params()];
        net.grad_y(&w, &mut g1);
        // doubling w doubles the gradient
        let w2 = vec![2.0; d];
        let mut g2 = vec![0.0; net.n_learnable_params()];
        net.grad_y(&w2, &mut g2);
        for (a, b) in g1.iter().zip(&g2) {
            assert!((2.0 * a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn json_roundtrip_continues_identically() {
        // run a net through a stage transition, snapshot, restore, and
        // check both nets evolve identically afterwards (features and
        // growth schedule).
        let mut net = CcnNet::new(tiny_cfg(), 21);
        drive(&mut net, 75, 1); // mid-stage-2
        let snap = net.to_json();
        let text = snap.dump();
        let mut back = CcnNet::from_json(
            &crate::util::json::Json::parse(&text).unwrap(),
        )
        .expect("roundtrip");
        assert_eq!(back.n_stages(), net.n_stages());
        assert_eq!(back.steps_in_stage(), net.steps_in_stage());
        assert_eq!(back.param_epoch(), net.param_epoch());
        assert_eq!(back.n_features(), net.n_features());
        let mut rng = Xoshiro256::seed_from_u64(77);
        for t in 0..120 {
            let x: Vec<f32> = (0..3).map(|_| rng.uniform(-1.0, 1.0)).collect();
            net.advance(&x);
            back.advance(&x);
            assert_eq!(net.features(), back.features(), "step {t}");
            net.end_step();
            back.end_step();
            assert_eq!(net.n_stages(), back.n_stages(), "growth must match");
        }
    }

    #[test]
    fn spec_decode_rejects_mangled_stage_budgets() {
        // pre-fix, `as_f64 as u64` silently accepted all of these:
        // 1.5 -> 1 (truncation), -1 -> 0 (saturation), 1e16 -> rounded
        let base = tiny_cfg();
        for bad_num in [
            Json::Num(1.5),
            Json::Num(-1.0),
            Json::Num(-0.5),
            Json::Num(1e16),
            Json::Num(f64::INFINITY),
        ] {
            let mut o = match base.to_json() {
                Json::Obj(o) => o,
                _ => unreachable!(),
            };
            o.insert("steps_per_stage".into(), bad_num.clone());
            assert!(
                CcnConfig::from_json(&Json::Obj(o)).is_none(),
                "steps_per_stage {bad_num:?} must be rejected"
            );
        }
        // boundaries that must keep decoding: null (columnar corner,
        // u64::MAX) and 2^53 (last exact integer)
        let mut o = match base.to_json() {
            Json::Obj(o) => o,
            _ => unreachable!(),
        };
        o.insert("steps_per_stage".into(), Json::Null);
        assert_eq!(
            CcnConfig::from_json(&Json::Obj(o)).unwrap().steps_per_stage,
            u64::MAX
        );
        let mut o = match base.to_json() {
            Json::Obj(o) => o,
            _ => unreachable!(),
        };
        o.insert("steps_per_stage".into(), Json::Num(9007199254740992.0));
        assert_eq!(
            CcnConfig::from_json(&Json::Obj(o)).unwrap().steps_per_stage,
            9_007_199_254_740_992
        );
    }

    #[test]
    fn snapshot_decode_rejects_mangled_stage_clocks() {
        let mut net = CcnNet::new(tiny_cfg(), 17);
        drive(&mut net, 60, 1);
        for field in ["steps_in_stage", "epoch"] {
            for bad_num in [Json::Num(0.5), Json::Num(-3.0), Json::Num(1e16)] {
                let mut o = match net.to_json() {
                    Json::Obj(o) => o,
                    _ => unreachable!(),
                };
                o.insert(field.into(), bad_num.clone());
                let err = CcnNet::from_json(&Json::Obj(o))
                    .err()
                    .unwrap_or_else(|| panic!("{field}={bad_num:?} must fail"));
                assert!(err.contains(field), "loud error names the field: {err}");
            }
        }
        // round trip at the exact freeze boundary keeps working
        let j = Json::parse(&net.to_json().dump()).unwrap();
        let back = CcnNet::from_json(&j).expect("boundary roundtrip");
        assert_eq!(back.steps_in_stage(), net.steps_in_stage());
        assert_eq!(back.param_epoch(), net.param_epoch());
    }

    #[test]
    fn staged_capability_tracks_stage_and_freeze() {
        let mut net = CcnNet::new(tiny_cfg(), 23);
        let cap0 = net.batch_capability();
        let (d0, s0, sig0) = match cap0 {
            BatchCapability::Staged {
                d,
                stage,
                prefix_sig,
                frozen_forever,
                ..
            } => {
                assert!(!frozen_forever);
                (d, stage, prefix_sig)
            }
            other => panic!("ccn must report Staged, got {other:?}"),
        };
        assert_eq!((d0, s0), (2, 0));
        drive(&mut net, 50, 1); // cross one stage boundary
        match net.batch_capability() {
            BatchCapability::Staged {
                d,
                stage,
                prefix_sig,
                ..
            } => {
                assert_eq!((d, stage), (4, 1));
                assert_ne!(prefix_sig, sig0, "stage is part of the signature");
            }
            other => panic!("{other:?}"),
        }
        drive(&mut net, 100, 2); // materialize all + freeze
        match net.batch_capability() {
            BatchCapability::Staged {
                frozen_forever, d, ..
            } => {
                assert!(frozen_forever);
                assert_eq!(d, 6);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn from_json_rejects_inconsistent_stages() {
        let net = CcnNet::new(tiny_cfg(), 0);
        let j = net.to_json();
        // corrupt: claim 2 inputs while columns are built for 3
        let mut cfg = tiny_cfg();
        cfg.n_inputs = 2;
        let mut o = match j {
            Json::Obj(o) => o,
            _ => unreachable!(),
        };
        o.insert("cfg".into(), cfg.to_json());
        assert!(CcnNet::from_json(&Json::Obj(o)).is_err());
    }

    #[test]
    fn prop_feats_finite_and_bounded() {
        check("ccn features bounded", 10, |g| {
            let cfg = CcnConfig {
                n_inputs: g.sized_usize(1, 5),
                total_features: 4,
                features_per_stage: g.usize_in(1, 4),
                steps_per_stage: 30,
                init_scale: 1.0,
                norm_eps: 0.01,
                norm_beta: 0.999,
            };
            let mut net = CcnNet::new(cfg.clone(), g.rng.next_u64());
            let mut rng = Xoshiro256::seed_from_u64(g.rng.next_u64());
            for _ in 0..200 {
                let x: Vec<f32> =
                    (0..cfg.n_inputs).map(|_| rng.uniform(-1.0, 1.0)).collect();
                net.advance(&x);
                net.end_step();
                for &f in net.features() {
                    prop_assert(
                        f.is_finite() && f.abs() <= 2.0 / cfg.norm_eps,
                        format!("feature {f}"),
                    )?;
                }
            }
            Ok(())
        });
    }
}
