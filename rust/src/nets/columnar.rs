//! Columnar networks (paper Section 3.1): one everlasting stage of
//! independent single-unit LSTM columns, all learning simultaneously
//! with exact per-column RTRL. Implemented as the never-freezing corner
//! of [`super::ccn::CcnNet`]'s configuration space.

use super::ccn::{CcnConfig, CcnNet};
use super::normalizer::NORM_BETA;

/// Build a columnar network of `d` columns over `n_inputs` inputs.
pub fn columnar_net(n_inputs: usize, d: usize, eps: f32, seed: u64) -> CcnNet {
    CcnNet::new(
        CcnConfig {
            n_inputs,
            total_features: d,
            features_per_stage: d,
            steps_per_stage: u64::MAX,
            init_scale: 1.0,
            norm_eps: eps,
            norm_beta: NORM_BETA,
        },
        seed,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nets::PredictionNet;
    use crate::util::prng::Xoshiro256;

    #[test]
    fn columnar_has_all_features_immediately() {
        let net = columnar_net(7, 5, 0.01, 0);
        assert_eq!(net.n_features(), 5);
        assert_eq!(net.name(), "columnar");
        // 5 columns x (4*7 + 8) params each
        assert_eq!(net.n_learnable_params(), 5 * 36);
    }

    #[test]
    fn learns_forever() {
        let mut net = columnar_net(3, 4, 0.01, 1);
        let mut rng = Xoshiro256::seed_from_u64(2);
        for _ in 0..10_000 {
            let x: Vec<f32> = (0..3).map(|_| rng.uniform(0.0, 1.0)).collect();
            net.advance(&x);
            net.end_step();
        }
        assert!(net.n_learnable_params() > 0);
        assert_eq!(net.param_epoch(), 1, "no stage transitions ever");
    }

    #[test]
    fn flops_match_appendix_formula() {
        let net = columnar_net(7, 5, 0.01, 3);
        assert_eq!(
            net.flops_per_step(),
            crate::compute::columnar_ops(5, 7)
        );
    }
}
