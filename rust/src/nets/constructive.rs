//! Constructive networks (paper Section 3.2): grow the recurrent network
//! one feature at a time; the single learning unit sees the raw input
//! plus every previously frozen feature, so deep hierarchical features
//! emerge across stages. Implemented as the `features_per_stage = 1`
//! corner of [`super::ccn::CcnNet`].

use super::ccn::{CcnConfig, CcnNet};
use super::normalizer::NORM_BETA;

/// Build a constructive network growing to `total_features` features,
/// advancing stages every `steps_per_stage` steps.
pub fn constructive_net(
    n_inputs: usize,
    total_features: usize,
    steps_per_stage: u64,
    eps: f32,
    seed: u64,
) -> CcnNet {
    CcnNet::new(
        CcnConfig {
            n_inputs,
            total_features,
            features_per_stage: 1,
            steps_per_stage,
            init_scale: 1.0,
            norm_eps: eps,
            norm_beta: NORM_BETA,
        },
        seed,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nets::lstm_column::LstmColumn;
    use crate::nets::PredictionNet;
    use crate::util::prng::Xoshiro256;

    #[test]
    fn one_feature_at_a_time() {
        let mut net = constructive_net(4, 3, 20, 0.01, 0);
        assert_eq!(net.name(), "constructive");
        assert_eq!(net.n_features(), 1);
        // learnable = exactly one column over the raw input
        assert_eq!(net.n_learnable_params(), LstmColumn::n_params(4));
        let mut rng = Xoshiro256::seed_from_u64(1);
        for _ in 0..20 {
            let x: Vec<f32> = (0..4).map(|_| rng.uniform(-1.0, 1.0)).collect();
            net.advance(&x);
            net.end_step();
        }
        assert_eq!(net.n_features(), 2);
        // second unit consumes raw input + 1 frozen feature
        assert_eq!(net.n_learnable_params(), LstmColumn::n_params(5));
    }

    #[test]
    fn uses_less_compute_than_columnar_same_size() {
        // Section 3.2: "constructive networks use even less per-step
        // computation than columnar networks".
        let constructive = constructive_net(7, 10, 1000, 0.01, 0);
        let columnar = super::super::columnar::columnar_net(7, 10, 0.01, 0);
        assert!(constructive.flops_per_step() < columnar.flops_per_step());
    }
}
