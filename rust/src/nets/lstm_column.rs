//! A single LSTM *column*: scalar hidden state, forward-mode RTRL traces
//! (paper Appendix B). This is the native-Rust twin of the Pallas kernel
//! `python/compile/kernels/column_rtrl.py`; the math is the same fused
//! affine-plus-rank-1 form (see the kernel's module docs for the
//! derivation from the paper's per-gate equations) and the two are held
//! in lockstep by the golden-file integration test.
//!
//! Parameter layout (flat, the order the whole repo uses):
//!
//! ```text
//! [ W_i (m) | W_f (m) | W_o (m) | W_g (m) | u_i u_f u_o u_g | b_i b_f b_o b_g ]
//! ```
//!
//! Per-parameter traces: TH_p = dh/dp and TC_p = dc/dp, stored in the
//! same layout. A column with input width m has 4m + 8 parameters and
//! 2(4m + 8) trace scalars.

use crate::util::json::Json;
use crate::util::prng::Xoshiro256;
use crate::util::sigmoid;

pub const GATE_I: usize = 0;
pub const GATE_F: usize = 1;
pub const GATE_O: usize = 2;
pub const GATE_G: usize = 3;

#[derive(Clone, Debug)]
pub struct LstmColumn {
    pub m: usize,
    /// input weights, [4 * m], gate-major (W_i then W_f, W_o, W_g)
    pub w: Vec<f32>,
    /// recurrent weights [u_i, u_f, u_o, u_g]
    pub u: [f32; 4],
    /// biases
    pub b: [f32; 4],
    /// hidden & cell state
    pub h: f32,
    pub c: f32,
    /// dh/dW and dc/dW traces, [4 * m]
    pub thw: Vec<f32>,
    pub tcw: Vec<f32>,
    /// dh/du, dc/du, dh/db, dc/db traces
    pub thu: [f32; 4],
    pub tcu: [f32; 4],
    pub thb: [f32; 4],
    pub tcb: [f32; 4],
}

impl LstmColumn {
    /// Number of learnable parameters of one column.
    pub fn n_params(m: usize) -> usize {
        4 * m + 8
    }

    /// Random init: weights ~ U[-scale, scale], biases 0, state/traces 0.
    pub fn new(m: usize, rng: &mut Xoshiro256, scale: f32) -> Self {
        let w = (0..4 * m).map(|_| rng.uniform(-scale, scale)).collect();
        let u = [
            rng.uniform(-scale, scale),
            rng.uniform(-scale, scale),
            rng.uniform(-scale, scale),
            rng.uniform(-scale, scale),
        ];
        Self {
            m,
            w,
            u,
            b: [0.0; 4],
            h: 0.0,
            c: 0.0,
            thw: vec![0.0; 4 * m],
            tcw: vec![0.0; 4 * m],
            thu: [0.0; 4],
            tcu: [0.0; 4],
            thb: [0.0; 4],
            tcb: [0.0; 4],
        }
    }

    /// All-zero column of input width `m` — a blank slate for unpacking
    /// SoA lanes ([`crate::serve::batch`]) or deserialized state into.
    pub fn zeroed(m: usize) -> Self {
        Self {
            m,
            w: vec![0.0; 4 * m],
            u: [0.0; 4],
            b: [0.0; 4],
            h: 0.0,
            c: 0.0,
            thw: vec![0.0; 4 * m],
            tcw: vec![0.0; 4 * m],
            thu: [0.0; 4],
            tcu: [0.0; 4],
            thb: [0.0; 4],
            tcb: [0.0; 4],
        }
    }

    /// Full serialization: parameters, state and traces. f32 -> f64 JSON
    /// numbers are exact, so the round trip is lossless.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("m", Json::Num(self.m as f64)),
            ("w", Json::arr_f32(&self.w)),
            ("u", Json::arr_f32(&self.u)),
            ("b", Json::arr_f32(&self.b)),
            ("h", Json::Num(self.h as f64)),
            ("c", Json::Num(self.c as f64)),
            ("thw", Json::arr_f32(&self.thw)),
            ("tcw", Json::arr_f32(&self.tcw)),
            ("thu", Json::arr_f32(&self.thu)),
            ("tcu", Json::arr_f32(&self.tcu)),
            ("thb", Json::arr_f32(&self.thb)),
            ("tcb", Json::arr_f32(&self.tcb)),
        ])
    }

    /// Inverse of [`Self::to_json`]; `None` on shape mismatch.
    pub fn from_json(v: &Json) -> Option<Self> {
        let m = v.get("m")?.as_usize()?;
        let vec_of = |key: &str, len: usize| -> Option<Vec<f32>> {
            let arr = v.get(key)?.to_f32_vec()?;
            if arr.len() == len {
                Some(arr)
            } else {
                None
            }
        };
        let four = |key: &str| -> Option<[f32; 4]> {
            vec_of(key, 4)?.try_into().ok()
        };
        Some(Self {
            m,
            w: vec_of("w", 4 * m)?,
            u: four("u")?,
            b: four("b")?,
            h: v.get("h")?.as_f64()? as f32,
            c: v.get("c")?.as_f64()? as f32,
            thw: vec_of("thw", 4 * m)?,
            tcw: vec_of("tcw", 4 * m)?,
            thu: four("thu")?,
            tcu: four("tcu")?,
            thb: four("thb")?,
            tcb: four("tcb")?,
        })
    }

    /// Reset state and traces (parameters untouched).
    pub fn reset_state(&mut self) {
        self.h = 0.0;
        self.c = 0.0;
        self.thw.iter_mut().for_each(|v| *v = 0.0);
        self.tcw.iter_mut().for_each(|v| *v = 0.0);
        self.thu = [0.0; 4];
        self.tcu = [0.0; 4];
        self.thb = [0.0; 4];
        self.tcb = [0.0; 4];
    }

    /// Gate pre-activations and activations for input `x`.
    ///
    /// One fused pass over `x` computes all four dot products (4x fewer
    /// loads of `x` than four separate `dot` calls — this is the hot
    /// inner loop of the entire framework).
    #[inline]
    fn gates(&self, x: &[f32]) -> (f32, f32, f32, f32) {
        let m = self.m;
        debug_assert_eq!(x.len(), m);
        let (wi, rest) = self.w.split_at(m);
        let (wf, rest) = rest.split_at(m);
        let (wo, wg) = rest.split_at(m);
        let (mut zi, mut zf, mut zo, mut zg) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
        for j in 0..m {
            let xj = x[j];
            zi += wi[j] * xj;
            zf += wf[j] * xj;
            zo += wo[j] * xj;
            zg += wg[j] * xj;
        }
        (
            sigmoid(zi + self.u[GATE_I] * self.h + self.b[GATE_I]),
            sigmoid(zf + self.u[GATE_F] * self.h + self.b[GATE_F]),
            sigmoid(zo + self.u[GATE_O] * self.h + self.b[GATE_O]),
            (zg + self.u[GATE_G] * self.h + self.b[GATE_G]).tanh(),
        )
    }

    /// Forward + RTRL trace update (the learning-stage step).
    pub fn step_with_traces(&mut self, x: &[f32]) {
        let (i, f, o, g) = self.gates(x);
        let c_prev = self.c;
        let h_prev = self.h;
        let c2 = f * c_prev + i * g;
        let tanh_c2 = c2.tanh();
        let h2 = o * tanh_c2;

        let di = i * (1.0 - i);
        let df = f * (1.0 - f);
        let do_ = o * (1.0 - o);
        let dg = 1.0 - g * g;

        // fused trace-recursion coefficients (see kernel docs)
        let a_coef = c_prev * df * self.u[GATE_F]
            + i * dg * self.u[GATE_G]
            + g * di * self.u[GATE_I];
        let b_coef = tanh_c2 * do_ * self.u[GATE_O];
        let e_coef = o * (1.0 - tanh_c2 * tanh_c2);
        // per-gate direct coefficients into c' and h'
        let q = [g * di, c_prev * df, 0.0, i * dg];
        let r = [0.0, 0.0, tanh_c2 * do_, 0.0];

        let m = self.m;
        for a in 0..4 {
            let (qa, ra) = (q[a], r[a]);
            let base = a * m;
            // W traces: direct term x_j. Iterator zips remove the bounds
            // checks in this, the most-executed loop of the framework.
            let tcw_row = &mut self.tcw[base..base + m];
            let thw_row = &mut self.thw[base..base + m];
            for ((tc_j, th_j), &xj) in
                tcw_row.iter_mut().zip(thw_row.iter_mut()).zip(x.iter())
            {
                let th_prev = *th_j;
                let tc = f * *tc_j + a_coef * th_prev + qa * xj;
                *th_j = e_coef * tc + b_coef * th_prev + ra * xj;
                *tc_j = tc;
            }
            // u traces: direct term h(t-1)
            let tcu = f * self.tcu[a] + a_coef * self.thu[a] + qa * h_prev;
            self.thu[a] = e_coef * tcu + b_coef * self.thu[a] + ra * h_prev;
            self.tcu[a] = tcu;
            // b traces: direct term 1
            let tcb = f * self.tcb[a] + a_coef * self.thb[a] + qa;
            self.thb[a] = e_coef * tcb + b_coef * self.thb[a] + ra;
            self.tcb[a] = tcb;
        }

        self.c = c2;
        self.h = h2;
    }

    /// Forward only (frozen column — no trace bookkeeping).
    pub fn step_forward_only(&mut self, x: &[f32]) {
        let (i, f, o, g) = self.gates(x);
        self.c = f * self.c + i * g;
        self.h = o * self.c.tanh();
    }

    /// Write `scale * TH_p` for every parameter p into `out`
    /// (out.len() == n_params). Used for dy/dtheta = w_k/denom_k * TH.
    pub fn write_grad(&self, scale: f32, out: &mut [f32]) {
        let m = self.m;
        debug_assert_eq!(out.len(), Self::n_params(m));
        for (dst, &src) in out[..4 * m].iter_mut().zip(self.thw.iter()) {
            *dst = scale * src;
        }
        for a in 0..4 {
            out[4 * m + a] = scale * self.thu[a];
            out[4 * m + 4 + a] = scale * self.thb[a];
        }
    }

    /// theta += delta, same flat layout.
    pub fn apply_update(&mut self, delta: &[f32]) {
        let m = self.m;
        debug_assert_eq!(delta.len(), Self::n_params(m));
        for (w, &d) in self.w.iter_mut().zip(delta[..4 * m].iter()) {
            *w += d;
        }
        for a in 0..4 {
            self.u[a] += delta[4 * m + a];
            self.b[a] += delta[4 * m + 4 + a];
        }
    }

    /// Copy a flat parameter vector in (tests / parity checks).
    pub fn set_params(&mut self, params: &[f32]) {
        let m = self.m;
        assert_eq!(params.len(), Self::n_params(m));
        self.w.copy_from_slice(&params[..4 * m]);
        for a in 0..4 {
            self.u[a] = params[4 * m + a];
            self.b[a] = params[4 * m + 4 + a];
        }
    }

    pub fn params(&self) -> Vec<f32> {
        let mut out = self.w.clone();
        out.extend_from_slice(&self.u);
        out.extend_from_slice(&self.b);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::{check, prop_close};

    fn run_sequence(col: &mut LstmColumn, xs: &[Vec<f32>], traces: bool) -> f32 {
        for x in xs {
            if traces {
                col.step_with_traces(x);
            } else {
                col.step_forward_only(x);
            }
        }
        col.h
    }

    /// Central finite difference of h_T w.r.t. parameter `p_idx`.
    fn fd_grad(
        base: &LstmColumn,
        xs: &[Vec<f32>],
        p_idx: usize,
        eps: f32,
    ) -> f32 {
        let mut params = base.params();
        params[p_idx] += eps;
        let mut plus = base.clone();
        plus.set_params(&params);
        plus.reset_state();
        let hp = run_sequence(&mut plus, xs, false);

        params[p_idx] -= 2.0 * eps;
        let mut minus = base.clone();
        minus.set_params(&params);
        minus.reset_state();
        let hm = run_sequence(&mut minus, xs, false);
        (hp - hm) / (2.0 * eps)
    }

    #[test]
    fn traces_match_finite_differences() {
        let m = 5;
        let t_len = 12;
        let mut rng = Xoshiro256::seed_from_u64(42);
        let mut col = LstmColumn::new(m, &mut rng, 0.8);
        let xs: Vec<Vec<f32>> = (0..t_len)
            .map(|_| (0..m).map(|_| rng.uniform(-1.0, 1.0)).collect())
            .collect();
        let mut live = col.clone();
        run_sequence(&mut live, &xs, true);

        let n_params = LstmColumn::n_params(m);
        let mut grad = vec![0.0; n_params];
        live.write_grad(1.0, &mut grad);
        for p in 0..n_params {
            let fd = fd_grad(&col, &xs, p, 1e-3);
            assert!(
                (grad[p] - fd).abs() < 2e-3 * (1.0 + fd.abs()),
                "param {p}: trace {} vs fd {fd}",
                grad[p]
            );
        }
    }

    #[test]
    fn forward_only_matches_traced_forward() {
        let m = 7;
        let mut rng = Xoshiro256::seed_from_u64(3);
        let col = LstmColumn::new(m, &mut rng, 0.6);
        let mut a = col.clone();
        let mut b = col.clone();
        for _ in 0..50 {
            let x: Vec<f32> = (0..m).map(|_| rng.uniform(-1.0, 1.0)).collect();
            a.step_with_traces(&x);
            b.step_forward_only(&x);
            assert_eq!(a.h, b.h, "freezing must not change the forward pass");
            assert_eq!(a.c, b.c);
        }
    }

    #[test]
    fn zero_input_keeps_w_traces_zero() {
        let m = 4;
        let mut rng = Xoshiro256::seed_from_u64(5);
        let mut col = LstmColumn::new(m, &mut rng, 0.5);
        let x = vec![0.0; m];
        for _ in 0..10 {
            col.step_with_traces(&x);
        }
        assert!(col.thw.iter().all(|&v| v == 0.0));
        assert!(col.thb.iter().any(|&v| v.abs() > 1e-6), "bias traces flow");
    }

    #[test]
    fn saturated_gates_stay_finite() {
        let m = 3;
        let mut rng = Xoshiro256::seed_from_u64(7);
        let mut col = LstmColumn::new(m, &mut rng, 0.5);
        for w in col.w.iter_mut() {
            *w = 80.0;
        }
        col.b = [80.0; 4];
        let x = vec![1.0; m];
        for _ in 0..20 {
            col.step_with_traces(&x);
            assert!(col.h.is_finite() && col.c.is_finite());
            assert!(col.thw.iter().all(|v| v.is_finite()));
        }
    }

    #[test]
    fn apply_update_roundtrip() {
        let m = 4;
        let mut rng = Xoshiro256::seed_from_u64(9);
        let mut col = LstmColumn::new(m, &mut rng, 0.5);
        let before = col.params();
        let delta: Vec<f32> = (0..LstmColumn::n_params(m))
            .map(|i| i as f32 * 0.01)
            .collect();
        col.apply_update(&delta);
        let after = col.params();
        for i in 0..before.len() {
            assert!((after[i] - before[i] - delta[i]).abs() < 1e-6);
        }
    }

    #[test]
    fn json_roundtrip_preserves_everything() {
        let m = 6;
        let mut rng = Xoshiro256::seed_from_u64(11);
        let mut col = LstmColumn::new(m, &mut rng, 0.7);
        for _ in 0..25 {
            let x: Vec<f32> = (0..m).map(|_| rng.uniform(-1.0, 1.0)).collect();
            col.step_with_traces(&x);
        }
        let j = col.to_json();
        let text = j.dump();
        let back = LstmColumn::from_json(&crate::util::json::Json::parse(&text).unwrap())
            .expect("roundtrip");
        assert_eq!(back.m, col.m);
        assert_eq!(back.w, col.w);
        assert_eq!(back.u, col.u);
        assert_eq!(back.h, col.h);
        assert_eq!(back.c, col.c);
        assert_eq!(back.thw, col.thw);
        assert_eq!(back.tcw, col.tcw);
        assert_eq!(back.tcb, col.tcb);
        // the restored column must continue exactly like the original
        let mut a = col.clone();
        let mut b = back;
        for _ in 0..10 {
            let x: Vec<f32> = (0..m).map(|_| rng.uniform(-1.0, 1.0)).collect();
            a.step_with_traces(&x);
            b.step_with_traces(&x);
            assert_eq!(a.h, b.h);
            assert_eq!(a.thw, b.thw);
        }
    }

    #[test]
    fn from_json_rejects_wrong_shapes() {
        let mut rng = Xoshiro256::seed_from_u64(1);
        let col = LstmColumn::new(3, &mut rng, 0.5);
        let mut j = col.to_json();
        if let crate::util::json::Json::Obj(o) = &mut j {
            o.insert("m".into(), crate::util::json::Json::Num(5.0));
        }
        assert!(LstmColumn::from_json(&j).is_none(), "m=5 but arrays sized 3");
    }

    #[test]
    fn prop_traces_bounded_for_bounded_inputs() {
        // LSTM gates are contractive for moderate recurrent weights: with
        // |u| <= 0.5 the traces must not blow up over long horizons.
        check("column traces bounded", 10, |g| {
            let m = g.sized_usize(1, 8);
            let mut rng = Xoshiro256::seed_from_u64(g.rng.next_u64());
            let mut col = LstmColumn::new(m, &mut rng, 0.5);
            for _ in 0..2000 {
                let x: Vec<f32> = (0..m).map(|_| rng.uniform(-1.0, 1.0)).collect();
                col.step_with_traces(&x);
            }
            for &v in col.thw.iter() {
                prop_close(v.clamp(-1e4, 1e4), v, 0.0, "trace magnitude")?;
                if !v.is_finite() || v.abs() > 1e4 {
                    return Err(format!("trace exploded: {v}"));
                }
            }
            Ok(())
        });
    }
}
