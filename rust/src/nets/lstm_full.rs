//! Fully connected LSTM cell (the T-BPTT comparator's network).
//!
//! Parameter layout (flat, gate-major then unit):
//!
//! ```text
//! [ Wx (4*d*n) | Wh (4*d*d) | b (4*d) ]
//! ```
//!
//! with gates ordered i, f, o, g, matching the column layout. The step
//! returns a [`StepRecord`] holding everything BPTT needs to run the
//! backward pass later.

use crate::util::json::Json;
use crate::util::prng::Xoshiro256;
use crate::util::{dot, sigmoid};

pub const GATE_I: usize = 0;
pub const GATE_F: usize = 1;
pub const GATE_O: usize = 2;
pub const GATE_G: usize = 3;

#[derive(Clone, Debug)]
pub struct LstmFull {
    pub n: usize,
    pub d: usize,
    /// input weights [4 * d * n]: wx[a*d*n + j*n + i]
    pub wx: Vec<f32>,
    /// recurrent weights [4 * d * d]: wh[a*d*d + j*d + k]
    pub wh: Vec<f32>,
    /// biases [4 * d]
    pub b: Vec<f32>,
    pub h: Vec<f32>,
    pub c: Vec<f32>,
}

/// Everything the backward pass needs about one step.
#[derive(Clone, Debug)]
pub struct StepRecord {
    pub x: Vec<f32>,
    pub h_prev: Vec<f32>,
    pub c_prev: Vec<f32>,
    pub i: Vec<f32>,
    pub f: Vec<f32>,
    pub o: Vec<f32>,
    pub g: Vec<f32>,
    pub c: Vec<f32>,
}

impl StepRecord {
    pub fn zeroed(n: usize, d: usize) -> Self {
        Self {
            x: vec![0.0; n],
            h_prev: vec![0.0; d],
            c_prev: vec![0.0; d],
            i: vec![0.0; d],
            f: vec![0.0; d],
            o: vec![0.0; d],
            g: vec![0.0; d],
            c: vec![0.0; d],
        }
    }

    /// Full serialization; f32 -> f64 JSON numbers are exact so the round
    /// trip is lossless.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("x", Json::arr_f32(&self.x)),
            ("h_prev", Json::arr_f32(&self.h_prev)),
            ("c_prev", Json::arr_f32(&self.c_prev)),
            ("i", Json::arr_f32(&self.i)),
            ("f", Json::arr_f32(&self.f)),
            ("o", Json::arr_f32(&self.o)),
            ("g", Json::arr_f32(&self.g)),
            ("c", Json::arr_f32(&self.c)),
        ])
    }

    /// Inverse of [`Self::to_json`] for a record of shape `(n, d)`;
    /// `None` on any length mismatch.
    pub fn from_json(v: &Json, n: usize, d: usize) -> Option<Self> {
        let vec_of = |key: &str, len: usize| -> Option<Vec<f32>> {
            let arr = v.get(key)?.to_f32_vec()?;
            if arr.len() == len {
                Some(arr)
            } else {
                None
            }
        };
        Some(Self {
            x: vec_of("x", n)?,
            h_prev: vec_of("h_prev", d)?,
            c_prev: vec_of("c_prev", d)?,
            i: vec_of("i", d)?,
            f: vec_of("f", d)?,
            o: vec_of("o", d)?,
            g: vec_of("g", d)?,
            c: vec_of("c", d)?,
        })
    }

    fn resize(&mut self, n: usize, d: usize) {
        self.x.resize(n, 0.0);
        for v in [
            &mut self.h_prev,
            &mut self.c_prev,
            &mut self.i,
            &mut self.f,
            &mut self.o,
            &mut self.g,
            &mut self.c,
        ] {
            v.resize(d, 0.0);
        }
    }
}

impl LstmFull {
    pub fn n_params(n: usize, d: usize) -> usize {
        4 * d * n + 4 * d * d + 4 * d
    }

    pub fn new(n: usize, d: usize, rng: &mut Xoshiro256, scale: f32) -> Self {
        Self {
            n,
            d,
            wx: (0..4 * d * n).map(|_| rng.uniform(-scale, scale)).collect(),
            wh: (0..4 * d * d).map(|_| rng.uniform(-scale, scale)).collect(),
            b: vec![0.0; 4 * d],
            h: vec![0.0; d],
            c: vec![0.0; d],
        }
    }

    /// Full serialization: parameters and recurrent state. The round
    /// trip is lossless (f32 -> f64 JSON numbers are exact).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("n", Json::Num(self.n as f64)),
            ("d", Json::Num(self.d as f64)),
            ("wx", Json::arr_f32(&self.wx)),
            ("wh", Json::arr_f32(&self.wh)),
            ("b", Json::arr_f32(&self.b)),
            ("h", Json::arr_f32(&self.h)),
            ("c", Json::arr_f32(&self.c)),
        ])
    }

    /// Inverse of [`Self::to_json`]; `None` on shape mismatch.
    pub fn from_json(v: &Json) -> Option<Self> {
        let n = v.get("n")?.as_usize()?;
        let d = v.get("d")?.as_usize()?;
        if n == 0 || d == 0 {
            return None;
        }
        let vec_of = |key: &str, len: usize| -> Option<Vec<f32>> {
            let arr = v.get(key)?.to_f32_vec()?;
            if arr.len() == len {
                Some(arr)
            } else {
                None
            }
        };
        Some(Self {
            n,
            d,
            wx: vec_of("wx", 4 * d * n)?,
            wh: vec_of("wh", 4 * d * d)?,
            b: vec_of("b", 4 * d)?,
            h: vec_of("h", d)?,
            c: vec_of("c", d)?,
        })
    }

    /// One forward step; records the activations for BPTT.
    pub fn step(&mut self, x: &[f32]) -> StepRecord {
        let mut rec = StepRecord::zeroed(self.n, self.d);
        self.step_into_record(x, &mut rec);
        rec
    }

    /// Forward step writing into a caller-owned record — the hot path;
    /// lets [`super::tbptt::TbpttNet`] keep a preallocated ring buffer
    /// with zero per-step allocation.
    pub fn step_into_record(&mut self, x: &[f32], rec: &mut StepRecord) {
        let (n, d) = (self.n, self.d);
        debug_assert_eq!(x.len(), n);
        rec.resize(n, d);
        rec.x.copy_from_slice(x);
        rec.h_prev.copy_from_slice(&self.h);
        rec.c_prev.copy_from_slice(&self.c);
        for j in 0..d {
            let zi = dot(&self.wx[(GATE_I * d + j) * n..(GATE_I * d + j + 1) * n], x)
                + dot(&self.wh[(GATE_I * d + j) * d..(GATE_I * d + j + 1) * d], &rec.h_prev)
                + self.b[GATE_I * d + j];
            let zf = dot(&self.wx[(GATE_F * d + j) * n..(GATE_F * d + j + 1) * n], x)
                + dot(&self.wh[(GATE_F * d + j) * d..(GATE_F * d + j + 1) * d], &rec.h_prev)
                + self.b[GATE_F * d + j];
            let zo = dot(&self.wx[(GATE_O * d + j) * n..(GATE_O * d + j + 1) * n], x)
                + dot(&self.wh[(GATE_O * d + j) * d..(GATE_O * d + j + 1) * d], &rec.h_prev)
                + self.b[GATE_O * d + j];
            let zg = dot(&self.wx[(GATE_G * d + j) * n..(GATE_G * d + j + 1) * n], x)
                + dot(&self.wh[(GATE_G * d + j) * d..(GATE_G * d + j + 1) * d], &rec.h_prev)
                + self.b[GATE_G * d + j];
            let (i, f, o, g) = (sigmoid(zi), sigmoid(zf), sigmoid(zo), zg.tanh());
            rec.i[j] = i;
            rec.f[j] = f;
            rec.o[j] = o;
            rec.g[j] = g;
            self.c[j] = f * rec.c_prev[j] + i * g;
            self.h[j] = o * self.c[j].tanh();
        }
        rec.c.copy_from_slice(&self.c);
    }

    /// theta += delta (flat layout above).
    pub fn apply_update(&mut self, delta: &[f32]) {
        let (n, d) = (self.n, self.d);
        debug_assert_eq!(delta.len(), Self::n_params(n, d));
        let (dwx, rest) = delta.split_at(4 * d * n);
        let (dwh, db) = rest.split_at(4 * d * d);
        for (w, &dv) in self.wx.iter_mut().zip(dwx) {
            *w += dv;
        }
        for (w, &dv) in self.wh.iter_mut().zip(dwh) {
            *w += dv;
        }
        for (w, &dv) in self.b.iter_mut().zip(db) {
            *w += dv;
        }
    }

    pub fn params(&self) -> Vec<f32> {
        let mut out = self.wx.clone();
        out.extend_from_slice(&self.wh);
        out.extend_from_slice(&self.b);
        out
    }

    pub fn set_params(&mut self, p: &[f32]) {
        let (n, d) = (self.n, self.d);
        assert_eq!(p.len(), Self::n_params(n, d));
        self.wx.copy_from_slice(&p[..4 * d * n]);
        self.wh
            .copy_from_slice(&p[4 * d * n..4 * d * n + 4 * d * d]);
        self.b.copy_from_slice(&p[4 * d * n + 4 * d * d..]);
    }

    pub fn reset_state(&mut self) {
        self.h.iter_mut().for_each(|v| *v = 0.0);
        self.c.iter_mut().for_each(|v| *v = 0.0);
    }

    /// Backward pass over `records` (oldest..newest) for dy/dtheta where
    /// dy/dh_final = `dh_final`. Accumulates into `grad` (flat layout).
    /// This is truncated BPTT when `records` holds only the last k steps.
    pub fn bptt_grad(&self, records: &[StepRecord], dh_final: &[f32], grad: &mut [f32]) {
        self.bptt_grad_rev(records.iter().rev(), dh_final, grad)
    }

    /// Same as [`LstmFull::bptt_grad`] but takes the records already in
    /// reverse (newest-first) order — lets callers with ring buffers avoid
    /// cloning the window every step (the per-step hot path).
    pub fn bptt_grad_rev<'a, I>(&self, records_rev: I, dh_final: &[f32], grad: &mut [f32])
    where
        I: Iterator<Item = &'a StepRecord>,
    {
        let (n, d) = (self.n, self.d);
        debug_assert_eq!(grad.len(), Self::n_params(n, d));
        grad.iter_mut().for_each(|v| *v = 0.0);
        let mut dh = dh_final.to_vec();
        let mut dc = vec![0.0f32; d];
        let (gwx, rest) = grad.split_at_mut(4 * d * n);
        let (gwh, gb) = rest.split_at_mut(4 * d * d);
        let mut dh_prev = vec![0.0f32; d];
        let mut dz = vec![0.0f32; 4 * d];
        for rec in records_rev {
            for j in 0..d {
                let tanh_c = rec.c[j].tanh();
                // h = o * tanh(c)
                let do_ = dh[j] * tanh_c;
                let dcj = dc[j] + dh[j] * rec.o[j] * (1.0 - tanh_c * tanh_c);
                // c = f*c_prev + i*g
                let di = dcj * rec.g[j];
                let dg = dcj * rec.i[j];
                let df = dcj * rec.c_prev[j];
                dz[GATE_I * d + j] = di * rec.i[j] * (1.0 - rec.i[j]);
                dz[GATE_F * d + j] = df * rec.f[j] * (1.0 - rec.f[j]);
                dz[GATE_O * d + j] = do_ * rec.o[j] * (1.0 - rec.o[j]);
                dz[GATE_G * d + j] = dg * (1.0 - rec.g[j] * rec.g[j]);
                dc[j] = dcj * rec.f[j]; // dc_prev
            }
            dh_prev.iter_mut().for_each(|v| *v = 0.0);
            for a in 0..4 {
                for j in 0..d {
                    let dzv = dz[a * d + j];
                    if dzv == 0.0 {
                        continue;
                    }
                    let row = (a * d + j) * n;
                    crate::util::axpy(dzv, &rec.x, &mut gwx[row..row + n]);
                    let rrow = (a * d + j) * d;
                    crate::util::axpy(dzv, &rec.h_prev, &mut gwh[rrow..rrow + d]);
                    gb[a * d + j] += dzv;
                    // dh_prev += wh_row * dz
                    for k in 0..d {
                        dh_prev[k] += dzv * self.wh[rrow + k];
                    }
                }
            }
            std::mem::swap(&mut dh, &mut dh_prev);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run(full: &mut LstmFull, xs: &[Vec<f32>]) -> Vec<StepRecord> {
        xs.iter().map(|x| full.step(x)).collect()
    }

    #[test]
    fn bptt_full_window_matches_finite_differences() {
        let (n, d, t_len) = (3, 4, 8);
        let mut rng = Xoshiro256::seed_from_u64(0);
        let base = LstmFull::new(n, d, &mut rng, 0.6);
        let xs: Vec<Vec<f32>> = (0..t_len)
            .map(|_| (0..n).map(|_| rng.uniform(-1.0, 1.0)).collect())
            .collect();
        let w_out: Vec<f32> = (0..d).map(|_| rng.uniform(-1.0, 1.0)).collect();

        let mut live = base.clone();
        let records = run(&mut live, &xs);
        let mut grad = vec![0.0; LstmFull::n_params(n, d)];
        live.bptt_grad(&records, &w_out, &mut grad);

        let y_of = |params: &[f32]| -> f32 {
            let mut net = base.clone();
            net.set_params(params);
            net.reset_state();
            for x in &xs {
                net.step(x);
            }
            dot(&w_out, &net.h)
        };
        let p0 = base.params();
        let eps = 1e-3;
        for p in (0..p0.len()).step_by(7) {
            let mut pp = p0.clone();
            pp[p] += eps;
            let yp = y_of(&pp);
            pp[p] -= 2.0 * eps;
            let ym = y_of(&pp);
            let fd = (yp - ym) / (2.0 * eps);
            assert!(
                (grad[p] - fd).abs() < 2e-3 * (1.0 + fd.abs()),
                "param {p}: bptt {} vs fd {fd}",
                grad[p]
            );
        }
    }

    #[test]
    fn truncated_window_ignores_older_inputs() {
        // with window k, changing an input older than k steps must not
        // change the truncated gradient *through the recorded window*
        // (the records capture h_prev as data).
        let (n, d) = (2, 3);
        let mut rng = Xoshiro256::seed_from_u64(1);
        let mut net = LstmFull::new(n, d, &mut rng, 0.6);
        let xs: Vec<Vec<f32>> = (0..10)
            .map(|_| (0..n).map(|_| rng.uniform(-1.0, 1.0)).collect())
            .collect();
        let records = run(&mut net, &xs);
        let w_out = vec![1.0; d];
        let k = 4;
        let mut grad_trunc = vec![0.0; LstmFull::n_params(n, d)];
        net.bptt_grad(&records[10 - k..], &w_out, &mut grad_trunc);
        let mut grad_full = vec![0.0; LstmFull::n_params(n, d)];
        net.bptt_grad(&records, &w_out, &mut grad_full);
        // truncation must actually change the gradient (bias exists)
        let diff: f32 = grad_trunc
            .iter()
            .zip(&grad_full)
            .map(|(a, b)| (a - b).abs())
            .sum();
        assert!(diff > 1e-4, "truncated == full would mean no bias to study");
    }

    #[test]
    fn json_roundtrip_preserves_params_state_and_records() {
        let (n, d) = (3, 4);
        let mut rng = Xoshiro256::seed_from_u64(13);
        let mut net = LstmFull::new(n, d, &mut rng, 0.7);
        let mut rec = StepRecord::zeroed(n, d);
        for _ in 0..20 {
            let x: Vec<f32> = (0..n).map(|_| rng.uniform(-1.0, 1.0)).collect();
            net.step_into_record(&x, &mut rec);
        }
        let back = LstmFull::from_json(
            &crate::util::json::Json::parse(&net.to_json().dump()).unwrap(),
        )
        .expect("lstm roundtrip");
        assert_eq!(back.wx, net.wx);
        assert_eq!(back.wh, net.wh);
        assert_eq!(back.b, net.b);
        assert_eq!(back.h, net.h);
        assert_eq!(back.c, net.c);
        let rec_back = StepRecord::from_json(
            &crate::util::json::Json::parse(&rec.to_json().dump()).unwrap(),
            n,
            d,
        )
        .expect("record roundtrip");
        assert_eq!(rec_back.x, rec.x);
        assert_eq!(rec_back.h_prev, rec.h_prev);
        assert_eq!(rec_back.c, rec.c);
        // wrong shape is rejected
        assert!(StepRecord::from_json(&rec.to_json(), n + 1, d).is_none());
    }

    #[test]
    fn single_unit_full_lstm_matches_column_rtrl() {
        // The paper checked its trace equations against BPTT; we replicate:
        // a d=1 fully connected LSTM is exactly one column, so untruncated
        // BPTT's dy/dtheta must equal the column's RTRL traces.
        use crate::nets::lstm_column::LstmColumn;
        let n = 4;
        let t_len = 15;
        let mut rng = Xoshiro256::seed_from_u64(7);
        let mut full = LstmFull::new(n, 1, &mut rng, 0.7);
        // build the equivalent column: W rows = wx rows, u = wh, b = b
        let mut col = LstmColumn::new(n, &mut rng, 0.1);
        let mut params = Vec::new();
        params.extend_from_slice(&full.wx); // 4*n, gate-major = column W
        for a in 0..4 {
            // u_a
            params.push(full.wh[a]);
        }
        for a in 0..4 {
            params.push(full.b[a]);
        }
        col.set_params(&params);

        let xs: Vec<Vec<f32>> = (0..t_len)
            .map(|_| (0..n).map(|_| rng.uniform(-1.0, 1.0)).collect())
            .collect();
        let records = run(&mut full, &xs);
        for x in &xs {
            col.step_with_traces(x);
        }
        assert!((full.h[0] - col.h).abs() < 1e-5, "forward passes agree");

        let mut bptt = vec![0.0; LstmFull::n_params(n, 1)];
        full.bptt_grad(&records, &[1.0], &mut bptt);
        let mut rtrl = vec![0.0; LstmColumn::n_params(n)];
        col.write_grad(1.0, &mut rtrl);
        // layouts: bptt = [wx(4n) | wh(4) | b(4)], rtrl = [W(4n) | u(4) | b(4)]
        for p in 0..rtrl.len() {
            assert!(
                (bptt[p] - rtrl[p]).abs() < 1e-4 * (1.0 + bptt[p].abs()),
                "param {p}: bptt {} vs rtrl {}",
                bptt[p],
                rtrl[p]
            );
        }
    }
}
