//! Recurrent feature networks (paper Section 3).
//!
//! All learners expose the same [`PredictionNet`] interface so the
//! TD(lambda) agent in [`crate::learn`] is architecture-agnostic:
//!
//! - [`ccn::CcnNet`] — the paper's contribution: staged, columnar,
//!   RTRL-trained. Columnar networks and Constructive networks are the
//!   two degenerate corners of its configuration space
//!   ([`columnar::columnar_net`], [`constructive::constructive_net`]).
//! - [`tbptt::TbpttNet`] — the main comparator: fully connected LSTM
//!   trained with truncated BPTT.
//! - [`snap1::Snap1Net`] — the related-work baseline: SnAp-1 / diagonal
//!   RTRL on a fully connected LSTM.
//!
//! [`lstm_column::LstmColumn`] holds the Appendix-B forward-mode trace
//! math; [`normalizer::OnlineNormalizer`] the Section-3.4 feature
//! normalization.
//!
//! Every family also implements [`PersistableNet`] (complete JSON state
//! capture under a stable `kind` tag) and is registered in
//! [`registry::NetRegistry`], which maps kind -> constructor-from-json.
//! [`ServableNet`] combines the two traits; the serve layer holds
//! sessions as `Box<dyn ServableNet>` and discovers the SoA batched fast
//! path through [`PersistableNet::batch_capability`] instead of matching
//! on concrete types.

pub mod ccn;
pub mod columnar;
pub mod constructive;
pub mod lstm_column;
pub mod lstm_full;
pub mod normalizer;
pub mod registry;
pub mod snap1;
pub mod tbptt;

pub use registry::NetRegistry;

use crate::util::json::Json;

/// A recurrent feature network with per-step gradient estimates of its
/// linear readout y = w . features().
pub trait PredictionNet: Send {
    /// Features currently exposed to the readout (may grow over time for
    /// constructive nets; the agent zero-extends its weights).
    fn n_features(&self) -> usize;

    /// Advance the recurrent state with observation `x` and refresh
    /// features() and the gradient bookkeeping.
    fn advance(&mut self, x: &[f32]);

    /// The (normalized, where applicable) feature vector after the last
    /// `advance`; length n_features().
    fn features(&self) -> &[f32];

    /// Number of *currently learnable* network parameters (excludes the
    /// readout weights, which the agent owns; excludes frozen stages).
    fn n_learnable_params(&self) -> usize;

    /// Write dy/dtheta for y = w_out . features() into `grad`
    /// (len == n_learnable_params()).
    fn grad_y(&self, w_out: &[f32], grad: &mut [f32]);

    /// theta += delta over the learnable parameters (same layout as
    /// `grad_y`).
    fn apply_update(&mut self, delta: &[f32]);

    /// Monotone counter that increments whenever the identity of the
    /// learnable parameter set changes (e.g. a CCN stage transition).
    /// The agent resets its eligibility traces when it observes a change.
    fn param_epoch(&self) -> u64 {
        0
    }

    /// Hook called once per step after the TD update (stage clocks).
    fn end_step(&mut self) {}

    /// Estimated per-step operation count (Appendix-A accounting).
    fn flops_per_step(&self) -> u64;

    fn name(&self) -> &'static str;
}

/// How a net can participate in the serve layer's SoA fast path
/// ([`crate::serve::batch`]). Capability is *discovered* from the net, so
/// the batched store never needs to know which architectures exist.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum BatchCapability {
    /// No batched representation; sessions stay on the scalar path.
    None,
    /// The net is `d` forever-learning independent LSTM columns over
    /// `n_inputs` raw inputs behind one online normalizer — the exact
    /// shape a `ColumnarSessionBatch` lane holds.
    Columnar {
        n_inputs: usize,
        d: usize,
        /// normalizer epsilon
        eps: f32,
        /// normalizer beta
        beta: f32,
    },
    /// The net is a frozen columnar prefix plus one learning stage
    /// (constructive/ccn): every session at the same spec *and the same
    /// stage* is structurally identical, so the serve layer batches them
    /// into stage-keyed cohorts (`StagedSessionBatch`) and migrates a
    /// session to the next cohort when its stage clock hits
    /// `steps_per_stage` (or into the frozen-forever cohort once every
    /// feature is materialized).
    Staged {
        n_inputs: usize,
        /// materialized feature count (readout width) at this stage
        d: usize,
        /// index of the learning stage (== number of frozen stages)
        stage: usize,
        features_per_stage: usize,
        total_features: usize,
        steps_per_stage: u64,
        /// column init scale — part of the spec because cohort hops
        /// construct the next stage's columns from the lane rng
        init_scale: f32,
        /// all features materialized and frozen; only the readout learns
        frozen_forever: bool,
        /// normalizer epsilon
        eps: f32,
        /// normalizer beta
        beta: f32,
        /// FNV-1a digest of the structural spec (shape + float bits):
        /// two nets with equal `prefix_sig` have byte-compatible frozen
        /// prefixes and may share a cohort
        prefix_sig: u64,
    },
}

/// The persistence companion to [`PredictionNet`]: a net that can write
/// its complete state (parameters, recurrent state, gradient bookkeeping)
/// to JSON and be rebuilt from it by [`NetRegistry::restore`] under its
/// [`kind`](PersistableNet::kind) tag. Implemented by every net family so
/// the serve layer can snapshot and restore any of them through one
/// versioned envelope.
pub trait PersistableNet {
    /// Stable snapshot tag this net restores under; one of
    /// [`NetRegistry::kinds`] (`columnar`, `constructive`, `ccn`,
    /// `tbptt`, `snap1`).
    fn kind(&self) -> &'static str;

    /// Observation width the net consumes (snapshot/spec consistency
    /// checks).
    fn n_inputs(&self) -> usize;

    /// Complete state serialization. `NetRegistry::restore(self.kind(),
    /// &self.save())` rebuilds a net that continues bit-identically.
    fn save(&self) -> Json;

    /// Batched-stepping capability discovery; defaults to scalar-only.
    fn batch_capability(&self) -> BatchCapability {
        BatchCapability::None
    }
}

/// Everything the serve layer needs from a net: stepping
/// ([`PredictionNet`]), persistence ([`PersistableNet`]) and runtime
/// downcasting (`as_any`, for lossless conversion into specialized
/// stores like the SoA columnar batch).
pub trait ServableNet: PredictionNet + PersistableNet {
    fn as_any(&self) -> &dyn std::any::Any;
}

/// Boxed nets (including trait objects like `Box<dyn ServableNet>`)
/// forward both traits to their contents, so `TdLambdaAgent` can own a
/// net of any family behind one type. A method added to either trait
/// without a default body is forwarded automatically.
impl<T: PredictionNet + ?Sized> PredictionNet for Box<T> {
    fn n_features(&self) -> usize {
        (**self).n_features()
    }
    fn advance(&mut self, x: &[f32]) {
        (**self).advance(x)
    }
    fn features(&self) -> &[f32] {
        (**self).features()
    }
    fn n_learnable_params(&self) -> usize {
        (**self).n_learnable_params()
    }
    fn grad_y(&self, w_out: &[f32], grad: &mut [f32]) {
        (**self).grad_y(w_out, grad)
    }
    fn apply_update(&mut self, delta: &[f32]) {
        (**self).apply_update(delta)
    }
    fn param_epoch(&self) -> u64 {
        (**self).param_epoch()
    }
    fn end_step(&mut self) {
        (**self).end_step()
    }
    fn flops_per_step(&self) -> u64 {
        (**self).flops_per_step()
    }
    fn name(&self) -> &'static str {
        (**self).name()
    }
}

impl<T: PersistableNet + ?Sized> PersistableNet for Box<T> {
    fn kind(&self) -> &'static str {
        (**self).kind()
    }
    fn n_inputs(&self) -> usize {
        (**self).n_inputs()
    }
    fn save(&self) -> Json {
        (**self).save()
    }
    fn batch_capability(&self) -> BatchCapability {
        (**self).batch_capability()
    }
}
