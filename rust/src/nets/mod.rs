//! Recurrent feature networks (paper Section 3).
//!
//! All learners expose the same [`PredictionNet`] interface so the
//! TD(lambda) agent in [`crate::learn`] is architecture-agnostic:
//!
//! - [`ccn::CcnNet`] — the paper's contribution: staged, columnar,
//!   RTRL-trained. Columnar networks and Constructive networks are the
//!   two degenerate corners of its configuration space
//!   ([`columnar::columnar_net`], [`constructive::constructive_net`]).
//! - [`tbptt::TbpttNet`] — the main comparator: fully connected LSTM
//!   trained with truncated BPTT.
//! - [`snap1::Snap1Net`] — the related-work baseline: SnAp-1 / diagonal
//!   RTRL on a fully connected LSTM.
//!
//! [`lstm_column::LstmColumn`] holds the Appendix-B forward-mode trace
//! math; [`normalizer::OnlineNormalizer`] the Section-3.4 feature
//! normalization.

pub mod ccn;
pub mod columnar;
pub mod constructive;
pub mod lstm_column;
pub mod lstm_full;
pub mod normalizer;
pub mod snap1;
pub mod tbptt;

/// A recurrent feature network with per-step gradient estimates of its
/// linear readout y = w . features().
pub trait PredictionNet: Send {
    /// Features currently exposed to the readout (may grow over time for
    /// constructive nets; the agent zero-extends its weights).
    fn n_features(&self) -> usize;

    /// Advance the recurrent state with observation `x` and refresh
    /// features() and the gradient bookkeeping.
    fn advance(&mut self, x: &[f32]);

    /// The (normalized, where applicable) feature vector after the last
    /// `advance`; length n_features().
    fn features(&self) -> &[f32];

    /// Number of *currently learnable* network parameters (excludes the
    /// readout weights, which the agent owns; excludes frozen stages).
    fn n_learnable_params(&self) -> usize;

    /// Write dy/dtheta for y = w_out . features() into `grad`
    /// (len == n_learnable_params()).
    fn grad_y(&self, w_out: &[f32], grad: &mut [f32]);

    /// theta += delta over the learnable parameters (same layout as
    /// `grad_y`).
    fn apply_update(&mut self, delta: &[f32]);

    /// Monotone counter that increments whenever the identity of the
    /// learnable parameter set changes (e.g. a CCN stage transition).
    /// The agent resets its eligibility traces when it observes a change.
    fn param_epoch(&self) -> u64 {
        0
    }

    /// Hook called once per step after the TD update (stage clocks).
    fn end_step(&mut self) {}

    /// Estimated per-step operation count (Appendix-A accounting).
    fn flops_per_step(&self) -> u64;

    fn name(&self) -> &'static str;
}
