//! Online feature normalization (paper Section 3.4, eq. 10).
//!
//! Features in constructive/CCN networks have varying fan-in, so their
//! scales differ; normalizing each to zero mean / unit variance with an
//! epsilon-floored denominator lets one step-size work for all of them.
//!
//! ```text
//! mu_t      = beta mu_{t-1} + (1 - beta) f_t
//! sigma^2_t = beta sigma^2_{t-1} + (1-beta)(mu_t - f_t)(mu_{t-1} - f_t)
//! f_hat     = (f - mu) / max(eps, sigma)
//! ```
//!
//! beta = 0.99999 in all the paper's experiments; eps is tuned in
//! {0.1, 0.01, 0.001}.

/// Paper's beta for all experiments.
pub const NORM_BETA: f32 = 0.99999;

use crate::util::json::Json;

#[derive(Clone, Debug)]
pub struct OnlineNormalizer {
    mu: Vec<f32>,
    var: Vec<f32>,
    denom: Vec<f32>,
    beta: f32,
    eps: f32,
}

impl OnlineNormalizer {
    /// mu starts at 0, sigma^2 at 1 (paper's initialization).
    pub fn new(n: usize, beta: f32, eps: f32) -> Self {
        Self {
            mu: vec![0.0; n],
            var: vec![1.0; n],
            denom: vec![1.0; n],
            beta,
            eps,
        }
    }

    pub fn len(&self) -> usize {
        self.mu.len()
    }

    pub fn is_empty(&self) -> bool {
        self.mu.is_empty()
    }

    /// Add `extra` fresh features (CCN growth): stats start at (0, 1).
    pub fn grow(&mut self, extra: usize) {
        self.mu.extend(std::iter::repeat(0.0).take(extra));
        self.var.extend(std::iter::repeat(1.0).take(extra));
        self.denom.extend(std::iter::repeat(1.0).take(extra));
    }

    /// Update running stats with raw features `f` and write the normalized
    /// values into `out`. `f.len()` may be <= len() (CCN updates only the
    /// materialized prefix).
    pub fn update_and_normalize(&mut self, f: &[f32], out: &mut [f32]) {
        debug_assert!(f.len() <= self.mu.len());
        debug_assert_eq!(f.len(), out.len());
        let beta = self.beta;
        for k in 0..f.len() {
            let prev_mu = self.mu[k];
            let mu = beta * prev_mu + (1.0 - beta) * f[k];
            let var =
                beta * self.var[k] + (1.0 - beta) * (mu - f[k]) * (prev_mu - f[k]);
            self.mu[k] = mu;
            self.var[k] = var;
            let d = self.eps.max(var.max(0.0).sqrt());
            self.denom[k] = d;
            out[k] = (f[k] - mu) / d;
        }
    }

    /// Denominator max(eps, sigma_k) from the latest update — needed to
    /// scale trace gradients: dy/dp = w_k / denom_k * TH_p.
    #[inline]
    pub fn denom(&self, k: usize) -> f32 {
        self.denom[k]
    }

    pub fn eps(&self) -> f32 {
        self.eps
    }

    pub fn beta(&self) -> f32 {
        self.beta
    }

    /// Running statistics as `(mu, var, denom)` — read-only views for
    /// SoA packing ([`crate::serve::batch`]) and serialization.
    pub fn state(&self) -> (&[f32], &[f32], &[f32]) {
        (&self.mu, &self.var, &self.denom)
    }

    /// Rebuild from captured statistics; `None` if lengths disagree.
    pub fn from_state(
        beta: f32,
        eps: f32,
        mu: Vec<f32>,
        var: Vec<f32>,
        denom: Vec<f32>,
    ) -> Option<Self> {
        if mu.len() != var.len() || mu.len() != denom.len() {
            return None;
        }
        Some(Self {
            mu,
            var,
            denom,
            beta,
            eps,
        })
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("beta", Json::Num(self.beta as f64)),
            ("eps", Json::Num(self.eps as f64)),
            ("mu", Json::arr_f32(&self.mu)),
            ("var", Json::arr_f32(&self.var)),
            ("denom", Json::arr_f32(&self.denom)),
        ])
    }

    pub fn from_json(v: &Json) -> Option<Self> {
        Self::from_state(
            v.get("beta")?.as_f64()? as f32,
            v.get("eps")?.as_f64()? as f32,
            v.get("mu")?.to_f32_vec()?,
            v.get("var")?.to_f32_vec()?,
            v.get("denom")?.to_f32_vec()?,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::{check, prop_assert};
    use crate::util::prng::Xoshiro256;

    #[test]
    fn matches_paper_recursion_by_hand() {
        let mut n = OnlineNormalizer::new(1, 0.9, 0.01);
        let mut out = [0.0];
        n.update_and_normalize(&[3.0], &mut out);
        // mu = 0.9*0 + 0.1*3 = 0.3
        // var = 0.9*1 + 0.1*(0.3-3)(0-3) = 0.9 + 0.1*8.1 = 1.71
        assert!((n.mu[0] - 0.3).abs() < 1e-6);
        assert!((n.var[0] - 1.71).abs() < 1e-5);
        let expect = (3.0 - 0.3) / 1.71f32.sqrt();
        assert!((out[0] - expect).abs() < 1e-5);
    }

    #[test]
    fn converges_to_stream_moments() {
        let mut n = OnlineNormalizer::new(1, 0.999, 0.01);
        let mut rng = Xoshiro256::seed_from_u64(0);
        let mut out = [0.0];
        for _ in 0..50_000 {
            let f = 2.0 + 3.0 * rng.normal() as f32;
            n.update_and_normalize(&[f], &mut out);
        }
        assert!((n.mu[0] - 2.0).abs() < 0.3, "mu {}", n.mu[0]);
        assert!((n.var[0].sqrt() - 3.0).abs() < 0.5, "sigma {}", n.var[0].sqrt());
    }

    #[test]
    fn eps_floor_bounds_output() {
        // constant feature: variance collapses to ~0; the eps floor must
        // keep outputs finite and small.
        let mut n = OnlineNormalizer::new(1, 0.9, 0.1);
        let mut out = [0.0];
        for _ in 0..10_000 {
            n.update_and_normalize(&[5.0], &mut out);
            assert!(out[0].is_finite());
        }
        assert!(out[0].abs() < 1e-3, "normalized constant ~0: {}", out[0]);
        assert!(n.denom(0) >= 0.1 - 1e-7);
    }

    #[test]
    fn grow_preserves_existing_stats() {
        let mut n = OnlineNormalizer::new(2, 0.9, 0.01);
        let mut out = [0.0; 2];
        for _ in 0..100 {
            n.update_and_normalize(&[1.0, -1.0], &mut out);
        }
        let mu0 = n.mu[0];
        n.grow(3);
        assert_eq!(n.len(), 5);
        assert_eq!(n.mu[0], mu0);
        assert_eq!(n.var[3], 1.0);
    }

    #[test]
    fn json_roundtrip_continues_identically() {
        let mut n = OnlineNormalizer::new(3, 0.99, 0.01);
        let mut rng = Xoshiro256::seed_from_u64(8);
        let mut out = [0.0; 3];
        for _ in 0..500 {
            let f: Vec<f32> = (0..3).map(|_| rng.uniform(-2.0, 2.0)).collect();
            n.update_and_normalize(&f, &mut out);
        }
        let mut back =
            OnlineNormalizer::from_json(&n.to_json()).expect("roundtrip");
        let mut out2 = [0.0; 3];
        for _ in 0..50 {
            let f: Vec<f32> = (0..3).map(|_| rng.uniform(-2.0, 2.0)).collect();
            n.update_and_normalize(&f, &mut out);
            back.update_and_normalize(&f, &mut out2);
            assert_eq!(out, out2);
            assert_eq!(n.denom(1), back.denom(1));
        }
    }

    #[test]
    fn prop_normalized_bounded_by_eps_law() {
        check("normalizer bound", 100, |g| {
            let eps = *[0.1f32, 0.01, 0.001]
                .get(g.usize_in(0, 2))
                .unwrap();
            let mut n = OnlineNormalizer::new(1, 0.99, eps);
            let mut out = [0.0];
            for _ in 0..200 {
                let f = g.f32_in(-2.0, 2.0);
                n.update_and_normalize(&[f], &mut out);
                // |f - mu| <= 4 given the range; so |out| <= 4/eps.
                prop_assert(
                    out[0].abs() <= 4.0 / eps + 1e-3,
                    format!("out {} eps {eps}", out[0]),
                )?;
            }
            Ok(())
        });
    }
}
