//! The net registry: snapshot kind -> constructor-from-json.
//!
//! Every [`super::PersistableNet`] family registers its restore function
//! here under the stable kind tag its `kind()` reports. The serve layer's
//! versioned snapshot envelope (`{"v":2,"kind":...,"net":...}`) routes
//! through [`NetRegistry::restore`], so adding a new architecture to the
//! service is one entry in the registration table — no session, shard or
//! protocol code changes.
//!
//! Kinds are grouped into *families* that share a serialization format:
//! `columnar`, `constructive` and `ccn` are the three corners of the
//! [`CcnNet`] configuration space and all restore through
//! [`CcnNet::from_json`]; `tbptt` and `snap1` are their own families.

use super::ccn::CcnNet;
use super::snap1::Snap1Net;
use super::tbptt::TbpttNet;
use super::ServableNet;
use crate::util::json::Json;

type RestoreFn = fn(&Json) -> Result<Box<dyn ServableNet>, String>;

fn restore_ccn(v: &Json) -> Result<Box<dyn ServableNet>, String> {
    CcnNet::from_json(v).map(|n| Box::new(n) as Box<dyn ServableNet>)
}

fn restore_tbptt(v: &Json) -> Result<Box<dyn ServableNet>, String> {
    TbpttNet::from_json(v).map(|n| Box::new(n) as Box<dyn ServableNet>)
}

fn restore_snap1(v: &Json) -> Result<Box<dyn ServableNet>, String> {
    Snap1Net::from_json(v).map(|n| Box::new(n) as Box<dyn ServableNet>)
}

/// `(kind, family, restore)` for every registered net kind.
const ENTRIES: &[(&str, &str, RestoreFn)] = &[
    ("columnar", "ccn", restore_ccn),
    ("constructive", "ccn", restore_ccn),
    ("ccn", "ccn", restore_ccn),
    ("tbptt", "tbptt", restore_tbptt),
    ("snap1", "snap1", restore_snap1),
];

/// Static lookup from snapshot kind tags to net constructors.
pub struct NetRegistry;

impl NetRegistry {
    /// Every registered kind tag, in registration order.
    pub fn kinds() -> Vec<&'static str> {
        ENTRIES.iter().map(|e| e.0).collect()
    }

    /// The serialization family a kind belongs to (`None` for unknown
    /// kinds). Kinds in the same family restore through the same
    /// constructor and may be used interchangeably in envelopes.
    pub fn family(kind: &str) -> Option<&'static str> {
        ENTRIES.iter().find(|e| e.0 == kind).map(|e| e.1)
    }

    /// Rebuild a net from `PersistableNet::save` output under `kind`.
    pub fn restore(kind: &str, net: &Json) -> Result<Box<dyn ServableNet>, String> {
        let entry = ENTRIES.iter().find(|e| e.0 == kind).ok_or_else(|| {
            format!(
                "unknown net kind '{kind}' (registered: {})",
                NetRegistry::kinds().join(", ")
            )
        })?;
        (entry.2)(net)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nets::{PersistableNet, PredictionNet};
    use crate::util::prng::Xoshiro256;

    #[test]
    fn every_kind_is_registered_with_a_family() {
        let kinds = NetRegistry::kinds();
        assert_eq!(
            kinds,
            vec!["columnar", "constructive", "ccn", "tbptt", "snap1"]
        );
        for k in kinds {
            assert!(NetRegistry::family(k).is_some());
        }
        assert_eq!(NetRegistry::family("columnar"), NetRegistry::family("ccn"));
        assert_ne!(NetRegistry::family("tbptt"), NetRegistry::family("snap1"));
        assert_eq!(NetRegistry::family("hopfield"), None);
    }

    #[test]
    fn save_restore_roundtrips_through_kind_tag() {
        // one net per family, driven, saved, restored through the
        // registry by its own kind() tag, then stepped in lockstep.
        let mut rng = Xoshiro256::seed_from_u64(3);
        let nets: Vec<Box<dyn ServableNet>> = vec![
            Box::new(crate::nets::columnar::columnar_net(3, 4, 0.01, 1)),
            Box::new(crate::nets::tbptt::TbpttNet::new(3, 2, 6, 2)),
            Box::new(crate::nets::snap1::Snap1Net::new(3, 2, 3)),
        ];
        for mut net in nets {
            for _ in 0..40 {
                let x: Vec<f32> = (0..3).map(|_| rng.uniform(-1.0, 1.0)).collect();
                net.advance(&x);
                net.end_step();
            }
            let mut back = NetRegistry::restore(net.kind(), &net.save())
                .unwrap_or_else(|e| panic!("{} restore: {e}", net.kind()));
            assert_eq!(back.kind(), net.kind());
            assert_eq!(back.n_inputs(), 3);
            for _ in 0..20 {
                let x: Vec<f32> = (0..3).map(|_| rng.uniform(-1.0, 1.0)).collect();
                net.advance(&x);
                back.advance(&x);
                assert_eq!(net.features(), back.features(), "{}", net.kind());
            }
        }
    }

    #[test]
    fn restore_rejects_unknown_kind() {
        let err = NetRegistry::restore("hopfield", &Json::Null).unwrap_err();
        assert!(err.contains("hopfield") && err.contains("tbptt"), "{err}");
    }
}
