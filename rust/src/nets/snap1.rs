//! SnAp-1 / diagonal-RTRL baseline (Menick et al. 2021; discussed in the
//! paper's related work as the "biased but cheap" alternative).
//!
//! For a fully connected LSTM, SnAp-1 keeps one trace per parameter but
//! only through the hidden unit the parameter *directly* affects — all
//! cross-unit influence (dh_k/dp for k != j(p)) is dropped. For unit j
//! this is exactly the column trace recursion with input vector
//! [x ; h_{t-1}] treated as data, and the unit's own recurrent diagonal
//! Wh[a][j][j] playing the column's `u` role. We therefore implement each
//! unit as an [`LstmColumn`] over the extended input with its own slot
//! zeroed (the diagonal lives in `u`; the masked W entry is provably dead
//! since its direct term is always zero).
//!
//! Unlike columnar networks, the *forward* network here is dense — the
//! gradient, not the function class, is approximated. That is precisely
//! the trade the paper argues against, and this net lets the benches
//! show it.

use super::lstm_column::LstmColumn;
use super::{PersistableNet, PredictionNet};
use crate::util::json::Json;
use crate::util::prng::Xoshiro256;

pub struct Snap1Net {
    n: usize,
    d: usize,
    units: Vec<LstmColumn>,
    h_prev: Vec<f32>,
    feats: Vec<f32>,
    xbuf: Vec<f32>,
}

impl Snap1Net {
    pub fn new(n_inputs: usize, d: usize, seed: u64) -> Self {
        let mut rng = Xoshiro256::seed_from_u64(seed ^ 0x736e_6170); // "snap"
        let m = n_inputs + d;
        let mut units: Vec<LstmColumn> = (0..d)
            .map(|_| LstmColumn::new(m, &mut rng, 1.0))
            .collect();
        // the masked diagonal W entries start (and stay) functionally dead;
        // zero them so params() comparisons are clean.
        for (j, u) in units.iter_mut().enumerate() {
            for a in 0..4 {
                u.w[a * m + n_inputs + j] = 0.0;
            }
        }
        Self {
            n: n_inputs,
            d,
            units,
            h_prev: vec![0.0; d],
            feats: vec![0.0; d],
            xbuf: vec![0.0; m],
        }
    }

    /// Full serialization: every unit column (parameters + SnAp-1 traces)
    /// plus the dense hidden state. Lossless round trip.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("n", Json::Num(self.n as f64)),
            ("d", Json::Num(self.d as f64)),
            (
                "units",
                Json::Arr(self.units.iter().map(|u| u.to_json()).collect()),
            ),
            ("h_prev", Json::arr_f32(&self.h_prev)),
        ])
    }

    /// Inverse of [`Self::to_json`] (the [`super::NetRegistry`] `snap1`
    /// constructor).
    pub fn from_json(v: &Json) -> Result<Snap1Net, String> {
        let bad = |what: &str| format!("snap1 snapshot: bad or missing '{what}'");
        let n = v
            .get("n")
            .and_then(|x| x.as_usize())
            .filter(|&n| n >= 1)
            .ok_or_else(|| bad("n"))?;
        let d = v
            .get("d")
            .and_then(|x| x.as_usize())
            .filter(|&d| d >= 1)
            .ok_or_else(|| bad("d"))?;
        let m = n + d;
        let units_json = v
            .get("units")
            .and_then(|u| u.as_arr())
            .ok_or_else(|| bad("units"))?;
        if units_json.len() != d {
            return Err(format!(
                "snap1 snapshot: {} units, d = {d}",
                units_json.len()
            ));
        }
        let mut units = Vec::with_capacity(d);
        for uj in units_json {
            let unit = LstmColumn::from_json(uj).ok_or_else(|| bad("units"))?;
            if unit.m != m {
                return Err(format!(
                    "snap1 snapshot: unit width {} != n + d = {m}",
                    unit.m
                ));
            }
            units.push(unit);
        }
        let h_prev = v
            .get("h_prev")
            .and_then(|h| h.to_f32_vec())
            .filter(|h| h.len() == d)
            .ok_or_else(|| bad("h_prev"))?;
        // features() mirrors h_prev after every advance; xbuf is scratch.
        Ok(Self {
            n,
            d,
            units,
            feats: h_prev.clone(),
            h_prev,
            xbuf: vec![0.0; m],
        })
    }
}

impl PredictionNet for Snap1Net {
    fn n_features(&self) -> usize {
        self.d
    }

    fn advance(&mut self, x: &[f32]) {
        debug_assert_eq!(x.len(), self.n);
        let n = self.n;
        self.xbuf[..n].copy_from_slice(x);
        self.xbuf[n..].copy_from_slice(&self.h_prev);
        for (j, unit) in self.units.iter_mut().enumerate() {
            // zero own slot: the unit's self-recurrence flows through `u`
            let saved = self.xbuf[n + j];
            self.xbuf[n + j] = 0.0;
            unit.step_with_traces(&self.xbuf);
            self.xbuf[n + j] = saved;
            self.feats[j] = unit.h;
        }
        self.h_prev.copy_from_slice(&self.feats);
    }

    fn features(&self) -> &[f32] {
        &self.feats
    }

    fn n_learnable_params(&self) -> usize {
        self.d * LstmColumn::n_params(self.n + self.d)
    }

    fn grad_y(&self, w_out: &[f32], grad: &mut [f32]) {
        let per = LstmColumn::n_params(self.n + self.d);
        for (j, unit) in self.units.iter().enumerate() {
            unit.write_grad(w_out[j], &mut grad[j * per..(j + 1) * per]);
        }
    }

    fn apply_update(&mut self, delta: &[f32]) {
        let per = LstmColumn::n_params(self.n + self.d);
        for (j, unit) in self.units.iter_mut().enumerate() {
            unit.apply_update(&delta[j * per..(j + 1) * per]);
        }
    }

    fn flops_per_step(&self) -> u64 {
        // forward + ~6x trace bookkeeping over m = n + d inputs per unit
        let m = (self.n + self.d) as u64;
        7 * self.d as u64 * (4 * m + 8)
    }

    fn name(&self) -> &'static str {
        "snap1"
    }
}

impl PersistableNet for Snap1Net {
    fn kind(&self) -> &'static str {
        "snap1"
    }

    fn n_inputs(&self) -> usize {
        self.n
    }

    fn save(&self) -> Json {
        self.to_json()
    }
}

impl super::ServableNet for Snap1Net {
    fn as_any(&self) -> &dyn std::any::Any {
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nets::lstm_full::LstmFull;

    #[test]
    fn forward_matches_dense_lstm() {
        // the SnAp-1 approximation is in the gradient only; the forward
        // dynamics must equal a fully connected LSTM with the same params.
        let (n, d) = (3, 4);
        let snap = Snap1Net::new(n, d, 0);
        let mut dense = LstmFull::new(n, d, &mut Xoshiro256::seed_from_u64(99), 0.1);
        // copy snap's params into the dense layout
        let m = n + d;
        for a in 0..4 {
            for j in 0..d {
                for i in 0..n {
                    dense.wx[(a * d + j) * n + i] = snap.units[j].w[a * m + i];
                }
                for k in 0..d {
                    dense.wh[(a * d + j) * d + k] = if k == j {
                        snap.units[j].u[a]
                    } else {
                        snap.units[j].w[a * m + n + k]
                    };
                }
                dense.b[a * d + j] = snap.units[j].b[a];
            }
        }
        let mut snap = snap;
        let mut rng = Xoshiro256::seed_from_u64(5);
        for _ in 0..40 {
            let x: Vec<f32> = (0..n).map(|_| rng.uniform(-1.0, 1.0)).collect();
            snap.advance(&x);
            dense.step(&x);
            for j in 0..d {
                assert!(
                    (snap.features()[j] - dense.h[j]).abs() < 1e-5,
                    "unit {j}: {} vs {}",
                    snap.features()[j],
                    dense.h[j]
                );
            }
        }
    }

    #[test]
    fn masked_diagonal_stays_dead() {
        let (n, d) = (2, 3);
        let mut snap = Snap1Net::new(n, d, 1);
        let mut rng = Xoshiro256::seed_from_u64(2);
        for _ in 0..200 {
            let x: Vec<f32> = (0..n).map(|_| rng.uniform(-1.0, 1.0)).collect();
            snap.advance(&x);
            // the masked entries' traces never become nonzero
            let m = n + d;
            for (j, u) in snap.units.iter().enumerate() {
                for a in 0..4 {
                    assert_eq!(u.thw[a * m + n + j], 0.0);
                }
            }
        }
    }

    #[test]
    fn json_roundtrip_continues_bit_exactly() {
        let (n, d) = (3, 4);
        let mut snap = Snap1Net::new(n, d, 9);
        let mut rng = Xoshiro256::seed_from_u64(1);
        for _ in 0..60 {
            let x: Vec<f32> = (0..n).map(|_| rng.uniform(-1.0, 1.0)).collect();
            snap.advance(&x);
        }
        let text = snap.to_json().dump();
        let mut back =
            Snap1Net::from_json(&crate::util::json::Json::parse(&text).unwrap())
                .expect("snap1 roundtrip");
        assert_eq!(back.features(), snap.features());
        let w_out: Vec<f32> = (0..d).map(|j| 0.1 * j as f32 - 0.2).collect();
        let mut ga = vec![0.0; snap.n_learnable_params()];
        let mut gb = vec![0.0; back.n_learnable_params()];
        snap.grad_y(&w_out, &mut ga);
        back.grad_y(&w_out, &mut gb);
        assert_eq!(ga, gb, "restored traces must match");
        for _ in 0..30 {
            let x: Vec<f32> = (0..n).map(|_| rng.uniform(-1.0, 1.0)).collect();
            snap.advance(&x);
            back.advance(&x);
            assert_eq!(snap.features(), back.features());
        }
    }

    #[test]
    fn from_json_rejects_wrong_unit_width() {
        let snap = Snap1Net::new(2, 2, 0);
        let j = snap.to_json();
        if let crate::util::json::Json::Obj(mut o) = j {
            // claim n = 3: unit width 4 no longer equals n + d = 5
            o.insert("n".into(), crate::util::json::Json::Num(3.0));
            assert!(Snap1Net::from_json(&crate::util::json::Json::Obj(o)).is_err());
        }
    }

    #[test]
    fn gradient_is_biased_vs_full_bptt() {
        // SnAp-1's whole point: cheaper but biased. Verify its gradient
        // differs from untruncated BPTT on a dense network (if they were
        // equal the approximation would be vacuous here).
        let (n, d) = (2, 3);
        let mut snap = Snap1Net::new(n, d, 3);
        let mut rng = Xoshiro256::seed_from_u64(7);
        for _ in 0..30 {
            let x: Vec<f32> = (0..n).map(|_| rng.uniform(-1.0, 1.0)).collect();
            snap.advance(&x);
        }
        let w_out = vec![1.0; d];
        let mut g = vec![0.0; snap.n_learnable_params()];
        snap.grad_y(&w_out, &mut g);
        let nonzero = g.iter().filter(|v| v.abs() > 1e-9).count();
        assert!(nonzero > 0, "snap gradient must be nonzero");
    }
}
