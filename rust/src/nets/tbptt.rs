//! T-BPTT comparator (Williams & Peng 1990; the paper's main baseline).
//!
//! A fully connected LSTM whose prediction gradient dy_t/dtheta is
//! computed every step by backpropagating through the last `k` recorded
//! steps. Gradients are *biased*: dependencies longer than k are
//! invisible (Figures 5, 6 and 11 quantify the cost of that bias). The
//! per-step compute is (k+1) forward-equivalents (Appendix A).

use super::lstm_full::{LstmFull, StepRecord};
use super::{PersistableNet, PredictionNet};
use crate::compute;
use crate::util::json::Json;
use crate::util::prng::Xoshiro256;

pub struct TbpttNet {
    lstm: LstmFull,
    /// preallocated ring of the last k step records (no per-step allocs):
    /// `ring[(cursor - 1 - i).rem_euclid(k)]` is the i-th newest record.
    ring: Vec<StepRecord>,
    cursor: usize,
    filled: usize,
    k: usize,
    feats: Vec<f32>,
}

impl TbpttNet {
    pub fn new(n_inputs: usize, d: usize, k: usize, seed: u64) -> Self {
        assert!(k >= 1);
        let mut rng = Xoshiro256::seed_from_u64(seed ^ 0x7470_7474); // "tptt"
        Self {
            lstm: LstmFull::new(n_inputs, d, &mut rng, 1.0),
            ring: (0..k).map(|_| StepRecord::zeroed(n_inputs, d)).collect(),
            cursor: 0,
            filled: 0,
            k,
            feats: vec![0.0; d],
        }
    }

    pub fn truncation(&self) -> usize {
        self.k
    }

    /// Full serialization: LSTM parameters/state plus the BPTT ring
    /// buffer in storage order with its cursor, so the newest-first
    /// window walk (and therefore `grad_y`) resumes bit-identically.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("k", Json::Num(self.k as f64)),
            ("lstm", self.lstm.to_json()),
            (
                "ring",
                Json::Arr(self.ring.iter().map(|r| r.to_json()).collect()),
            ),
            ("cursor", Json::Num(self.cursor as f64)),
            ("filled", Json::Num(self.filled as f64)),
        ])
    }

    /// Inverse of [`Self::to_json`] (the [`super::NetRegistry`] `tbptt`
    /// constructor).
    pub fn from_json(v: &Json) -> Result<TbpttNet, String> {
        let bad = |what: &str| format!("tbptt snapshot: bad or missing '{what}'");
        let k = v
            .get("k")
            .and_then(|n| n.as_usize())
            .filter(|&k| k >= 1)
            .ok_or_else(|| bad("k"))?;
        let lstm = LstmFull::from_json(v.get("lstm").ok_or_else(|| bad("lstm"))?)
            .ok_or_else(|| bad("lstm"))?;
        let ring_json = v
            .get("ring")
            .and_then(|r| r.as_arr())
            .ok_or_else(|| bad("ring"))?;
        if ring_json.len() != k {
            return Err(format!(
                "tbptt snapshot: ring has {} records, k = {k}",
                ring_json.len()
            ));
        }
        let mut ring = Vec::with_capacity(k);
        for rj in ring_json {
            ring.push(
                StepRecord::from_json(rj, lstm.n, lstm.d).ok_or_else(|| bad("ring"))?,
            );
        }
        let cursor = v
            .get("cursor")
            .and_then(|n| n.as_usize())
            .filter(|&c| c < k)
            .ok_or_else(|| bad("cursor"))?;
        let filled = v
            .get("filled")
            .and_then(|n| n.as_usize())
            .filter(|&f| f <= k)
            .ok_or_else(|| bad("filled"))?;
        // features() mirrors the hidden state after every advance, so it
        // is reconstructed rather than stored.
        let feats = lstm.h.clone();
        Ok(Self {
            ring,
            cursor,
            filled,
            k,
            feats,
            lstm,
        })
    }

    /// Records newest-first (the order the backward pass consumes).
    fn window_rev(&self) -> impl Iterator<Item = &StepRecord> {
        let (head, tail) = self.ring.split_at(self.cursor);
        head.iter()
            .rev()
            .chain(tail.iter().rev())
            .take(self.filled)
    }

    #[cfg(test)]
    fn window_len(&self) -> usize {
        self.filled
    }
}

impl PredictionNet for TbpttNet {
    fn n_features(&self) -> usize {
        self.lstm.d
    }

    fn advance(&mut self, x: &[f32]) {
        // write into the ring slot in place — zero allocation per step
        let slot = self.cursor;
        // split borrow: lstm and ring are disjoint fields
        let Self { lstm, ring, .. } = self;
        lstm.step_into_record(x, &mut ring[slot]);
        self.cursor = (self.cursor + 1) % self.k;
        self.filled = (self.filled + 1).min(self.k);
        self.feats.copy_from_slice(&self.lstm.h);
    }

    fn features(&self) -> &[f32] {
        &self.feats
    }

    fn n_learnable_params(&self) -> usize {
        LstmFull::n_params(self.lstm.n, self.lstm.d)
    }

    fn grad_y(&self, w_out: &[f32], grad: &mut [f32]) {
        // newest-first walk over the ring buffer; no window clone
        self.lstm.bptt_grad_rev(self.window_rev(), w_out, grad);
    }

    fn apply_update(&mut self, delta: &[f32]) {
        self.lstm.apply_update(delta);
    }

    fn flops_per_step(&self) -> u64 {
        compute::tbptt_ops(self.lstm.d as u64, self.lstm.n as u64, self.k as u64)
    }

    fn name(&self) -> &'static str {
        "tbptt"
    }
}

impl PersistableNet for TbpttNet {
    fn kind(&self) -> &'static str {
        "tbptt"
    }

    fn n_inputs(&self) -> usize {
        self.lstm.n
    }

    fn save(&self) -> Json {
        self.to_json()
    }
}

impl super::ServableNet for TbpttNet {
    fn as_any(&self) -> &dyn std::any::Any {
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn window_is_bounded_by_k() {
        let mut net = TbpttNet::new(3, 2, 5, 0);
        let mut rng = Xoshiro256::seed_from_u64(1);
        for t in 0..20 {
            let x: Vec<f32> = (0..3).map(|_| rng.uniform(-1.0, 1.0)).collect();
            net.advance(&x);
            assert_eq!(net.window_len(), (t + 1).min(5));
        }
    }

    #[test]
    fn ring_order_is_newest_first() {
        let mut net = TbpttNet::new(1, 1, 3, 0);
        for t in 0..7 {
            net.advance(&[t as f32]);
            let xs: Vec<f32> = net.window_rev().map(|r| r.x[0]).collect();
            let want: Vec<f32> = (0..=t)
                .rev()
                .take(3)
                .map(|v| v as f32)
                .collect();
            assert_eq!(xs, want, "at t={t}");
        }
    }

    #[test]
    fn grad_changes_with_truncation_window() {
        let mk = |k: usize| {
            let mut net = TbpttNet::new(2, 3, k, 9);
            let mut rng = Xoshiro256::seed_from_u64(2);
            for _ in 0..30 {
                let x: Vec<f32> = (0..2).map(|_| rng.uniform(-1.0, 1.0)).collect();
                net.advance(&x);
            }
            let mut grad = vec![0.0; net.n_learnable_params()];
            net.grad_y(&[0.5, -0.3, 0.9], &mut grad);
            grad
        };
        let g2 = mk(2);
        let g20 = mk(20);
        let diff: f32 = g2.iter().zip(&g20).map(|(a, b)| (a - b).abs()).sum();
        assert!(diff > 1e-4, "longer window must see more credit");
    }

    #[test]
    fn json_roundtrip_continues_bit_exactly() {
        // the restored net must produce the *same gradients* as the
        // original, which exercises the ring cursor/filled bookkeeping.
        let mut net = TbpttNet::new(3, 2, 5, 11);
        let mut rng = Xoshiro256::seed_from_u64(4);
        for _ in 0..17 {
            // 17 % 5 != 0: cursor lands mid-ring
            let x: Vec<f32> = (0..3).map(|_| rng.uniform(-1.0, 1.0)).collect();
            net.advance(&x);
        }
        let text = net.to_json().dump();
        let mut back =
            TbpttNet::from_json(&crate::util::json::Json::parse(&text).unwrap())
                .expect("tbptt roundtrip");
        assert_eq!(back.features(), net.features());
        let w_out = vec![0.3, -0.7];
        let mut ga = vec![0.0; net.n_learnable_params()];
        let mut gb = vec![0.0; back.n_learnable_params()];
        net.grad_y(&w_out, &mut ga);
        back.grad_y(&w_out, &mut gb);
        assert_eq!(ga, gb, "restored BPTT window must match");
        for _ in 0..20 {
            let x: Vec<f32> = (0..3).map(|_| rng.uniform(-1.0, 1.0)).collect();
            net.advance(&x);
            back.advance(&x);
            assert_eq!(net.features(), back.features());
        }
        net.grad_y(&w_out, &mut ga);
        back.grad_y(&w_out, &mut gb);
        assert_eq!(ga, gb);
    }

    #[test]
    fn from_json_rejects_corrupted_ring() {
        let net = TbpttNet::new(2, 2, 3, 0);
        let j = net.to_json();
        // cursor out of range
        if let crate::util::json::Json::Obj(mut o) = j.clone() {
            o.insert("cursor".into(), crate::util::json::Json::Num(3.0));
            assert!(TbpttNet::from_json(&crate::util::json::Json::Obj(o)).is_err());
        }
        // ring length != k
        if let crate::util::json::Json::Obj(mut o) = j {
            o.insert("k".into(), crate::util::json::Json::Num(4.0));
            assert!(TbpttNet::from_json(&crate::util::json::Json::Obj(o)).is_err());
        }
    }

    #[test]
    fn flops_match_appendix() {
        let net = TbpttNet::new(7, 2, 30, 0);
        assert_eq!(net.flops_per_step(), compute::tbptt_ops(2, 7, 30));
    }

    #[test]
    fn features_are_hidden_state() {
        let mut net = TbpttNet::new(2, 4, 3, 5);
        let mut rng = Xoshiro256::seed_from_u64(3);
        for _ in 0..10 {
            let x: Vec<f32> = (0..2).map(|_| rng.uniform(-1.0, 1.0)).collect();
            net.advance(&x);
        }
        assert_eq!(net.features(), net.lstm.h.as_slice());
        assert!(net.features().iter().all(|v| v.abs() <= 1.0));
    }
}
