//! T-BPTT comparator (Williams & Peng 1990; the paper's main baseline).
//!
//! A fully connected LSTM whose prediction gradient dy_t/dtheta is
//! computed every step by backpropagating through the last `k` recorded
//! steps. Gradients are *biased*: dependencies longer than k are
//! invisible (Figures 5, 6 and 11 quantify the cost of that bias). The
//! per-step compute is (k+1) forward-equivalents (Appendix A).

use super::lstm_full::{LstmFull, StepRecord};
use super::PredictionNet;
use crate::compute;
use crate::util::prng::Xoshiro256;

pub struct TbpttNet {
    lstm: LstmFull,
    /// preallocated ring of the last k step records (no per-step allocs):
    /// `ring[(cursor - 1 - i).rem_euclid(k)]` is the i-th newest record.
    ring: Vec<StepRecord>,
    cursor: usize,
    filled: usize,
    k: usize,
    feats: Vec<f32>,
}

impl TbpttNet {
    pub fn new(n_inputs: usize, d: usize, k: usize, seed: u64) -> Self {
        assert!(k >= 1);
        let mut rng = Xoshiro256::seed_from_u64(seed ^ 0x7470_7474); // "tptt"
        Self {
            lstm: LstmFull::new(n_inputs, d, &mut rng, 1.0),
            ring: (0..k).map(|_| StepRecord::zeroed(n_inputs, d)).collect(),
            cursor: 0,
            filled: 0,
            k,
            feats: vec![0.0; d],
        }
    }

    pub fn truncation(&self) -> usize {
        self.k
    }

    /// Records newest-first (the order the backward pass consumes).
    fn window_rev(&self) -> impl Iterator<Item = &StepRecord> {
        let (head, tail) = self.ring.split_at(self.cursor);
        head.iter()
            .rev()
            .chain(tail.iter().rev())
            .take(self.filled)
    }

    #[cfg(test)]
    fn window_len(&self) -> usize {
        self.filled
    }
}

impl PredictionNet for TbpttNet {
    fn n_features(&self) -> usize {
        self.lstm.d
    }

    fn advance(&mut self, x: &[f32]) {
        // write into the ring slot in place — zero allocation per step
        let slot = self.cursor;
        // split borrow: lstm and ring are disjoint fields
        let Self { lstm, ring, .. } = self;
        lstm.step_into_record(x, &mut ring[slot]);
        self.cursor = (self.cursor + 1) % self.k;
        self.filled = (self.filled + 1).min(self.k);
        self.feats.copy_from_slice(&self.lstm.h);
    }

    fn features(&self) -> &[f32] {
        &self.feats
    }

    fn n_learnable_params(&self) -> usize {
        LstmFull::n_params(self.lstm.n, self.lstm.d)
    }

    fn grad_y(&self, w_out: &[f32], grad: &mut [f32]) {
        // newest-first walk over the ring buffer; no window clone
        self.lstm.bptt_grad_rev(self.window_rev(), w_out, grad);
    }

    fn apply_update(&mut self, delta: &[f32]) {
        self.lstm.apply_update(delta);
    }

    fn flops_per_step(&self) -> u64 {
        compute::tbptt_ops(self.lstm.d as u64, self.lstm.n as u64, self.k as u64)
    }

    fn name(&self) -> &'static str {
        "tbptt"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn window_is_bounded_by_k() {
        let mut net = TbpttNet::new(3, 2, 5, 0);
        let mut rng = Xoshiro256::seed_from_u64(1);
        for t in 0..20 {
            let x: Vec<f32> = (0..3).map(|_| rng.uniform(-1.0, 1.0)).collect();
            net.advance(&x);
            assert_eq!(net.window_len(), (t + 1).min(5));
        }
    }

    #[test]
    fn ring_order_is_newest_first() {
        let mut net = TbpttNet::new(1, 1, 3, 0);
        for t in 0..7 {
            net.advance(&[t as f32]);
            let xs: Vec<f32> = net.window_rev().map(|r| r.x[0]).collect();
            let want: Vec<f32> = (0..=t)
                .rev()
                .take(3)
                .map(|v| v as f32)
                .collect();
            assert_eq!(xs, want, "at t={t}");
        }
    }

    #[test]
    fn grad_changes_with_truncation_window() {
        let mk = |k: usize| {
            let mut net = TbpttNet::new(2, 3, k, 9);
            let mut rng = Xoshiro256::seed_from_u64(2);
            for _ in 0..30 {
                let x: Vec<f32> = (0..2).map(|_| rng.uniform(-1.0, 1.0)).collect();
                net.advance(&x);
            }
            let mut grad = vec![0.0; net.n_learnable_params()];
            net.grad_y(&[0.5, -0.3, 0.9], &mut grad);
            grad
        };
        let g2 = mk(2);
        let g20 = mk(20);
        let diff: f32 = g2.iter().zip(&g20).map(|(a, b)| (a - b).abs()).sum();
        assert!(diff > 1e-4, "longer window must see more credit");
    }

    #[test]
    fn flops_match_appendix() {
        let net = TbpttNet::new(7, 2, 30, 0);
        assert_eq!(net.flops_per_step(), compute::tbptt_ops(2, 7, 30));
    }

    #[test]
    fn features_are_hidden_state() {
        let mut net = TbpttNet::new(2, 4, 3, 5);
        let mut rng = Xoshiro256::seed_from_u64(3);
        for _ in 0..10 {
            let x: Vec<f32> = (0..2).map(|_| rng.uniform(-1.0, 1.0)).collect();
            net.advance(&x);
        }
        assert_eq!(net.features(), net.lstm.h.as_slice());
        assert!(net.features().iter().all(|v| v.abs() <= 1.0));
    }
}
