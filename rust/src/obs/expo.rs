//! Prometheus text exposition: the whole registry as scrapeable
//! plaintext, behind a zero-dependency HTTP/1.1 `GET /metrics`
//! responder (`--metrics-listen`).
//!
//! Two pieces:
//!
//! - [`render_prometheus`]: encode one [`RegistrySnapshot`] in the
//!   Prometheus text format (version 0.0.4). Histograms become
//!   *cumulative* `_bucket{le="..."}` series (upper bounds from
//!   [`bucket_bounds`], a terminal `+Inf` bucket, `_sum`/`_count`),
//!   counters become `_total` series, windowed counters become gauges
//!   labelled by window. Names are sanitized (`op.step` →
//!   `ccn_op_step_ns`) and values are nanoseconds where the registry's
//!   are.
//! - [`MetricsServer`]: a minimal HTTP responder over the serve
//!   transport's [`Listener`] (TCP or unix socket, no external crates).
//!   Each scrape takes a fresh snapshot, so the endpoint is
//!   measurement-only by construction — it shares nothing with the
//!   serving path but the registry's atomics.

use std::io::{Read, Write};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use crate::serve::transport::{Listener, SocketLock, Stream, POLL_INTERVAL};
use crate::serve::ListenAddr;

use super::{bucket_bounds, HistogramSnapshot, Registry, RegistrySnapshot, N_BUCKETS};

/// Every exported series name starts with this.
const NAMESPACE: &str = "ccn";
/// A scraper that takes longer than this to send its request line (or
/// drain the response) is cut off — the endpoint must never wedge.
const SCRAPE_IO_TIMEOUT: Duration = Duration::from_secs(2);
/// Longest request head we will buffer before answering.
const MAX_REQUEST_HEAD: usize = 8 * 1024;

/// `metric.name` → `metric_name`: Prometheus names are
/// `[a-zA-Z_:][a-zA-Z0-9_:]*`; everything else becomes `_`.
fn sanitize(name: &str) -> String {
    name.chars()
        .map(|c| if c.is_ascii_alphanumeric() { c } else { '_' })
        .collect()
}

fn render_histogram(out: &mut String, name: &str, h: &HistogramSnapshot) {
    let base = format!("{NAMESPACE}_{}_ns", sanitize(name));
    out.push_str(&format!("# TYPE {base} histogram\n"));
    let mut cum = 0u64;
    for i in 0..N_BUCKETS {
        let n = h.bucket_count(i);
        if n == 0 {
            continue;
        }
        cum += n;
        let (_, hi) = bucket_bounds(i);
        out.push_str(&format!("{base}_bucket{{le=\"{hi}\"}} {cum}\n"));
    }
    out.push_str(&format!("{base}_bucket{{le=\"+Inf\"}} {cum}\n"));
    out.push_str(&format!("{base}_sum {}\n", h.sum()));
    out.push_str(&format!("{base}_count {cum}\n"));
}

/// Encode one registry snapshot as Prometheus text exposition (0.0.4).
pub fn render_prometheus(snap: &RegistrySnapshot) -> String {
    let mut out = String::new();
    for (name, h) in &snap.hists {
        render_histogram(&mut out, name, h);
    }
    for (name, &v) in &snap.counters {
        let base = format!("{NAMESPACE}_{}_total", sanitize(name));
        out.push_str(&format!("# TYPE {base} counter\n{base} {v}\n"));
    }
    for (name, w) in &snap.windows {
        let base = format!("{NAMESPACE}_window_{}", sanitize(name));
        out.push_str(&format!("# TYPE {base} gauge\n"));
        for (label, n) in
            [("1s", w.last_1s), ("10s", w.last_10s), ("60s", w.last_60s)]
        {
            out.push_str(&format!("{base}{{window=\"{label}\"}} {n}\n"));
        }
    }
    out
}

fn http_response(status: &str, content_type: &str, body: &str) -> Vec<u8> {
    format!(
        "HTTP/1.1 {status}\r\nContent-Type: {content_type}\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    )
    .into_bytes()
}

/// Read the request head (through the blank line, bounded) and answer
/// one scrape. Any I/O failure just drops the connection — a scraper is
/// never worth an error path that could wedge the accept loop.
fn answer_scrape(mut stream: Stream, registry: &Registry) {
    let _ = stream.set_read_timeout(Some(SCRAPE_IO_TIMEOUT));
    let _ = stream.set_write_timeout(Some(SCRAPE_IO_TIMEOUT));
    let mut head: Vec<u8> = Vec::new();
    let mut chunk = [0u8; 1024];
    loop {
        if head.windows(4).any(|w| w == b"\r\n\r\n")
            || head.windows(2).any(|w| w == b"\n\n")
            || head.len() > MAX_REQUEST_HEAD
        {
            break;
        }
        match stream.read(&mut chunk) {
            Ok(0) => break,
            Ok(n) => head.extend_from_slice(&chunk[..n]),
            Err(_) => break, // timeout/reset: answer what we have
        }
    }
    let first_line = match std::str::from_utf8(&head) {
        Ok(text) => text.lines().next().unwrap_or("").to_string(),
        Err(_) => String::new(),
    };
    let mut parts = first_line.split_whitespace();
    let (method, path) =
        (parts.next().unwrap_or(""), parts.next().unwrap_or(""));
    let reply = if method != "GET" {
        http_response("405 Method Not Allowed", "text/plain", "GET only\n")
    } else if path == "/metrics" || path.starts_with("/metrics?") {
        let body = render_prometheus(&registry.snapshot());
        http_response(
            "200 OK",
            "text/plain; version=0.0.4; charset=utf-8",
            &body,
        )
    } else {
        http_response(
            "404 Not Found",
            "text/plain",
            "try /metrics\n",
        )
    };
    let _ = stream.write_all(&reply).and_then(|()| stream.flush());
    stream.shutdown();
}

/// The `--metrics-listen` endpoint: a background accept loop answering
/// `GET /metrics` scrapes against a shared [`Registry`]. Scrapes are
/// handled serially (they are rare, read-only and bounded by
/// [`SCRAPE_IO_TIMEOUT`]); serving traffic never routes through here.
pub struct MetricsServer {
    stop: Arc<AtomicBool>,
    join: Option<JoinHandle<()>>,
    local: String,
    unix_path: Option<PathBuf>,
    sock_lock: Option<SocketLock>,
}

impl MetricsServer {
    pub fn bind(
        addr: &ListenAddr,
        registry: Arc<Registry>,
    ) -> Result<MetricsServer, String> {
        let (listener, local, sock_lock) = Listener::bind(addr)?;
        listener
            .set_nonblocking(true)
            .map_err(|e| format!("metrics-listen: set nonblocking: {e}"))?;
        let stop = Arc::new(AtomicBool::new(false));
        let join = {
            let stop = Arc::clone(&stop);
            std::thread::spawn(move || {
                while !stop.load(Ordering::Relaxed) {
                    match listener.accept() {
                        Ok(stream) => {
                            let _ = stream.set_nonblocking(false);
                            answer_scrape(stream, &registry);
                        }
                        Err(e)
                            if matches!(
                                e.kind(),
                                std::io::ErrorKind::WouldBlock
                                    | std::io::ErrorKind::TimedOut
                            ) =>
                        {
                            std::thread::sleep(POLL_INTERVAL);
                        }
                        Err(e)
                            if e.kind()
                                == std::io::ErrorKind::Interrupted => {}
                        Err(_) => std::thread::sleep(POLL_INTERVAL),
                    }
                }
            })
        };
        Ok(MetricsServer {
            stop,
            join: Some(join),
            local,
            unix_path: match addr {
                ListenAddr::Unix(p) => Some(p.clone()),
                ListenAddr::Tcp(_) => None,
            },
            sock_lock,
        })
    }

    /// The bound endpoint (real port when 0 was requested).
    pub fn local_addr(&self) -> &str {
        &self.local
    }

    /// Stop accepting and join the loop; removes a unix socket + lock.
    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(join) = self.join.take() {
            let _ = join.join();
        }
        if let Some(path) = &self.unix_path {
            let _ = std::fs::remove_file(path);
        }
        drop(self.sock_lock.take());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn series_value(text: &str, line_start: &str) -> Option<f64> {
        text.lines()
            .find(|l| l.starts_with(line_start))
            .and_then(|l| l.rsplit(' ').next())
            .and_then(|v| v.parse().ok())
    }

    #[test]
    fn histogram_series_are_cumulative_and_count_matches_inf() {
        let reg = Registry::new();
        let h = reg.histogram("op.step");
        for v in [1u64, 1, 5, 900, 900, 900] {
            h.record(v);
        }
        let text = render_prometheus(&reg.snapshot());
        assert!(text.contains("# TYPE ccn_op_step_ns histogram"), "{text}");
        // buckets: 1 → le=1 (2 events), 5 → le=7, 900 → le=1023
        assert_eq!(series_value(&text, "ccn_op_step_ns_bucket{le=\"1\"}"), Some(2.0));
        assert_eq!(series_value(&text, "ccn_op_step_ns_bucket{le=\"7\"}"), Some(3.0));
        assert_eq!(
            series_value(&text, "ccn_op_step_ns_bucket{le=\"1023\"}"),
            Some(6.0)
        );
        assert_eq!(
            series_value(&text, "ccn_op_step_ns_bucket{le=\"+Inf\"}"),
            Some(6.0)
        );
        assert_eq!(series_value(&text, "ccn_op_step_ns_count"), Some(6.0));
        assert_eq!(
            series_value(&text, "ccn_op_step_ns_sum"),
            Some((1 + 1 + 5 + 900 * 3) as f64)
        );
        // cumulative counts never decrease as le grows
        let mut prev = -1.0;
        for line in text.lines().filter(|l| l.contains("_bucket{")) {
            let v: f64 = line.rsplit(' ').next().unwrap().parse().unwrap();
            assert!(v >= prev, "non-monotone bucket line: {line}");
            prev = v;
        }
    }

    #[test]
    fn counters_and_windows_export_with_sanitized_names() {
        let reg = Registry::new();
        reg.counter("transport.err_decode")
            .fetch_add(3, Ordering::Relaxed);
        reg.window("ops").add(12);
        let text = render_prometheus(&reg.snapshot());
        assert!(
            text.contains("# TYPE ccn_transport_err_decode_total counter"),
            "{text}"
        );
        assert_eq!(
            series_value(&text, "ccn_transport_err_decode_total"),
            Some(3.0)
        );
        assert!(text.contains("# TYPE ccn_window_ops gauge"), "{text}");
        assert_eq!(
            series_value(&text, "ccn_window_ops{window=\"10s\"}"),
            Some(12.0)
        );
    }

    #[test]
    fn empty_histograms_still_emit_a_complete_series() {
        let reg = Registry::new();
        reg.histogram("stage.queue_wait");
        let text = render_prometheus(&reg.snapshot());
        assert_eq!(
            series_value(&text, "ccn_stage_queue_wait_ns_bucket{le=\"+Inf\"}"),
            Some(0.0)
        );
        assert_eq!(series_value(&text, "ccn_stage_queue_wait_ns_count"), Some(0.0));
        assert_eq!(series_value(&text, "ccn_stage_queue_wait_ns_sum"), Some(0.0));
    }

    #[test]
    fn http_endpoint_answers_scrapes_and_404s_elsewhere() {
        let reg = Arc::new(Registry::standard());
        reg.histogram("op.step").record(1000);
        let srv = MetricsServer::bind(
            &ListenAddr::parse("tcp://127.0.0.1:0").unwrap(),
            Arc::clone(&reg),
        )
        .unwrap();
        let hostport = srv.local_addr().strip_prefix("tcp://").unwrap();
        let scrape = |path: &str| -> String {
            let mut s = std::net::TcpStream::connect(hostport).unwrap();
            write!(s, "GET {path} HTTP/1.1\r\nHost: x\r\n\r\n").unwrap();
            let mut body = String::new();
            s.read_to_string(&mut body).unwrap();
            body
        };
        let ok = scrape("/metrics");
        assert!(ok.starts_with("HTTP/1.1 200 OK\r\n"), "{ok}");
        assert!(ok.contains("ccn_op_step_ns_count 1"), "{ok}");
        // every pre-registered op series is present even at count 0
        for op in super::super::names::OPS {
            assert!(
                ok.contains(&format!("ccn_op_{}_ns_count", sanitize(op))),
                "missing op series {op}"
            );
        }
        let missing = scrape("/other");
        assert!(missing.starts_with("HTTP/1.1 404"), "{missing}");
        srv.shutdown();
    }
}
