//! Fixed-size log2-bucketed latency histogram over atomic counters.
//!
//! `record` is four relaxed atomic ops (bucket, sum, min, max) — cheap
//! enough to sit on every wire op and internal stage without perturbing
//! the thing being measured. Values are nanoseconds by convention
//! ([`Histogram::record_duration`]), but the type is unit-agnostic.
//!
//! Buckets: index 0 holds exactly the value `0`; bucket `i` in `1..=64`
//! holds `[2^(i-1), 2^i - 1]` (bucket 64's upper bound saturates at
//! `u64::MAX`). 65 buckets cover the whole `u64` range, so every value
//! lands in a bucket whose bounds contain it — there is no overflow
//! bucket to lose tail latencies in.
//!
//! Percentiles use the same nearest-rank convention as
//! [`crate::metrics::percentile`] (rank = `round((count-1) * q)`), applied
//! to the cumulative bucket counts; the reported value is the bucket's
//! upper bound clamped into the observed `[min, max]`, which keeps
//! `min <= p50 <= p90 <= p99 <= p999 <= max` and makes percentiles
//! monotone in `q`. When all samples share one bucket the clamp makes the
//! nearest-rank answer exact.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

use crate::util::json::Json;

/// Bucket 0 is `{0}`; buckets `1..=64` are the log2 ranges. See module docs.
pub const N_BUCKETS: usize = 65;

/// Index of the bucket whose bounds contain `v`.
#[inline]
pub fn bucket_index(v: u64) -> usize {
    if v == 0 {
        0
    } else {
        (64 - v.leading_zeros()) as usize
    }
}

/// Inclusive `(lo, hi)` bounds of bucket `i`. Panics if `i >= N_BUCKETS`.
pub fn bucket_bounds(i: usize) -> (u64, u64) {
    assert!(i < N_BUCKETS, "bucket index {i} out of range");
    if i == 0 {
        (0, 0)
    } else {
        let lo = 1u64 << (i - 1);
        let hi = if i == 64 { u64::MAX } else { (1u64 << i) - 1 };
        (lo, hi)
    }
}

/// Lock-free mergeable histogram. Shared via `Arc`; all methods take
/// `&self`. The total count is *derived* from the buckets at snapshot
/// time, so `count == Σ bucket counts` holds by construction even while
/// writers race the snapshot.
pub struct Histogram {
    buckets: [AtomicU64; N_BUCKETS],
    sum: AtomicU64,
    /// `u64::MAX` until the first record (sentinel, resolved in accessors).
    min: AtomicU64,
    max: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Histogram {
        Histogram::new()
    }
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            sum: AtomicU64::new(0),
            min: AtomicU64::new(u64::MAX),
            max: AtomicU64::new(0),
        }
    }

    /// Record one value. Four relaxed atomic ops; never blocks.
    #[inline]
    pub fn record(&self, v: u64) {
        self.buckets[bucket_index(v)].fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
        self.min.fetch_min(v, Ordering::Relaxed);
        self.max.fetch_max(v, Ordering::Relaxed);
    }

    /// Record a wall-clock duration in nanoseconds (saturating).
    #[inline]
    pub fn record_duration(&self, d: Duration) {
        self.record(d.as_nanos().min(u64::MAX as u128) as u64);
    }

    /// One pass over the atomics; the result is a plain value type safe
    /// to merge, serialize, and query without further synchronization.
    pub fn snapshot(&self) -> HistogramSnapshot {
        HistogramSnapshot {
            buckets: std::array::from_fn(|i| self.buckets[i].load(Ordering::Relaxed)),
            sum: self.sum.load(Ordering::Relaxed),
            min: self.min.load(Ordering::Relaxed),
            max: self.max.load(Ordering::Relaxed),
        }
    }
}

/// Point-in-time copy of a [`Histogram`]. `min` keeps the raw
/// `u64::MAX` empty sentinel internally so merge stays a plain
/// min-of-mins; the [`HistogramSnapshot::min`] accessor resolves it.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct HistogramSnapshot {
    buckets: [u64; N_BUCKETS],
    sum: u64,
    min: u64,
    max: u64,
}

impl Default for HistogramSnapshot {
    fn default() -> HistogramSnapshot {
        HistogramSnapshot::empty()
    }
}

impl HistogramSnapshot {
    pub fn empty() -> HistogramSnapshot {
        HistogramSnapshot {
            buckets: [0; N_BUCKETS],
            sum: 0,
            min: u64::MAX,
            max: 0,
        }
    }

    pub fn count(&self) -> u64 {
        self.buckets.iter().sum()
    }

    pub fn sum(&self) -> u64 {
        self.sum
    }

    pub fn min(&self) -> u64 {
        if self.min == u64::MAX {
            0
        } else {
            self.min
        }
    }

    pub fn max(&self) -> u64 {
        self.max
    }

    pub fn bucket_count(&self, i: usize) -> u64 {
        self.buckets[i]
    }

    /// Bucketwise sum plus min-of-mins / max-of-maxes: associative,
    /// commutative, and count-preserving (merged count is the sum of the
    /// operands' counts).
    pub fn merge(&self, other: &HistogramSnapshot) -> HistogramSnapshot {
        HistogramSnapshot {
            buckets: std::array::from_fn(|i| self.buckets[i] + other.buckets[i]),
            sum: self.sum + other.sum,
            min: self.min.min(other.min),
            max: self.max.max(other.max),
        }
    }

    /// Nearest-rank percentile over the bucket counts; `p` is clamped to
    /// `[0, 1]`. Returns 0 for an empty histogram. See module docs for
    /// the rank and clamping conventions.
    pub fn percentile(&self, p: f64) -> u64 {
        let count = self.count();
        if count == 0 {
            return 0;
        }
        let rank = ((count - 1) as f64 * p.clamp(0.0, 1.0)).round() as u64;
        let lo_obs = self.min();
        // defensive vs. in-flight snapshot skew: never report below min
        // or above max even if the racing bucket/extrema reads disagree
        let hi_obs = self.max.max(lo_obs);
        let mut seen = 0u64;
        for (i, &n) in self.buckets.iter().enumerate() {
            seen += n;
            if seen > rank {
                let (_, hi) = bucket_bounds(i);
                return hi.min(hi_obs).max(lo_obs);
            }
        }
        hi_obs
    }

    /// Inverse of [`HistogramSnapshot::to_json`]: rebuild a snapshot
    /// from the wire shape, so a fleet roll-up can re-merge per-backend
    /// `metrics` replies with the in-process [`HistogramSnapshot::merge`].
    /// The derived fields (`count`, `p*_ns`) are recomputed from the
    /// buckets, never trusted from the wire; `min_ns == 0` with a zero
    /// count restores the empty sentinel so merge identity still holds.
    pub fn from_json(v: &Json) -> Result<HistogramSnapshot, String> {
        let field = |key: &str| -> Result<u64, String> {
            v.get(key)
                .and_then(|n| n.as_f64())
                .filter(|&n| n >= 0.0 && n.fract() == 0.0)
                .map(|n| n as u64)
                .ok_or_else(|| format!("histogram: missing or invalid '{key}'"))
        };
        let mut buckets = [0u64; N_BUCKETS];
        let pairs = v
            .get("buckets")
            .and_then(|b| b.as_arr())
            .ok_or_else(|| "histogram: missing 'buckets' array".to_string())?;
        for pair in pairs {
            let pair = pair
                .as_arr()
                .filter(|p| p.len() == 2)
                .ok_or_else(|| "histogram: bucket is not a [lo, count] pair".to_string())?;
            let lo = pair[0]
                .as_f64()
                .filter(|&n| n >= 0.0 && n.fract() == 0.0)
                .ok_or_else(|| "histogram: bucket lo is not an integer".to_string())?
                as u64;
            let n = pair[1]
                .as_f64()
                .filter(|&n| n >= 0.0 && n.fract() == 0.0)
                .ok_or_else(|| "histogram: bucket count is not an integer".to_string())?
                as u64;
            let i = bucket_index(lo);
            if bucket_bounds(i).0 != lo {
                return Err(format!("histogram: {lo} is not a bucket lower bound"));
            }
            buckets[i] += n;
        }
        let count: u64 = buckets.iter().sum();
        let min = field("min_ns")?;
        Ok(HistogramSnapshot {
            buckets,
            sum: field("sum_ns")?,
            min: if count == 0 { u64::MAX } else { min },
            max: field("max_ns")?,
        })
    }

    /// The one histogram JSON shape used everywhere: the `metrics` wire
    /// op, the `stats` latency block sources, and every `BENCH_*.json`.
    /// `buckets` is sparse — ascending `[lo_ns, count]` pairs for the
    /// nonzero buckets only.
    pub fn to_json(&self) -> Json {
        let buckets: Vec<Json> = self
            .buckets
            .iter()
            .enumerate()
            .filter(|(_, &n)| n > 0)
            .map(|(i, &n)| {
                Json::Arr(vec![
                    Json::Num(bucket_bounds(i).0 as f64),
                    Json::Num(n as f64),
                ])
            })
            .collect();
        Json::obj(vec![
            ("count", Json::Num(self.count() as f64)),
            ("sum_ns", Json::Num(self.sum as f64)),
            ("min_ns", Json::Num(self.min() as f64)),
            ("max_ns", Json::Num(self.max() as f64)),
            ("p50_ns", Json::Num(self.percentile(0.50) as f64)),
            ("p90_ns", Json::Num(self.percentile(0.90) as f64)),
            ("p99_ns", Json::Num(self.percentile(0.99) as f64)),
            ("p999_ns", Json::Num(self.percentile(0.999) as f64)),
            ("buckets", Json::Arr(buckets)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Xoshiro256;

    #[test]
    fn bucket_bounds_contain_every_value() {
        let probes = [
            0u64,
            1,
            2,
            3,
            4,
            7,
            8,
            15,
            16,
            17,
            1000,
            1023,
            1024,
            u64::MAX / 2,
            u64::MAX - 1,
            u64::MAX,
        ];
        for &v in &probes {
            let i = bucket_index(v);
            let (lo, hi) = bucket_bounds(i);
            assert!(lo <= v && v <= hi, "value {v} outside bucket {i} [{lo},{hi}]");
        }
        // powers of two start a fresh bucket; their predecessors end one
        for k in 0..63u32 {
            let p = 1u64 << k;
            assert_eq!(bucket_bounds(bucket_index(p)).0, p);
            if p > 1 {
                assert_eq!(bucket_bounds(bucket_index(p - 1)).1, p - 1);
            }
        }
    }

    #[test]
    fn bucket_bounds_tile_u64_without_gaps() {
        let mut next = 0u64;
        for i in 0..N_BUCKETS {
            let (lo, hi) = bucket_bounds(i);
            assert_eq!(lo, next, "bucket {i} does not start where {} ended", i.max(1) - 1);
            assert!(hi >= lo);
            if i < N_BUCKETS - 1 {
                next = hi + 1;
            } else {
                assert_eq!(hi, u64::MAX);
            }
        }
    }

    #[test]
    fn random_records_land_in_containing_buckets() {
        let mut rng = Xoshiro256::seed_from_u64(0xB0C4);
        let h = Histogram::new();
        let mut values = Vec::new();
        for _ in 0..4000 {
            // bias toward small values but cover the full width
            let shift = (rng.next_u64() % 64) as u32;
            let v = rng.next_u64() >> shift;
            h.record(v);
            values.push(v);
        }
        let snap = h.snapshot();
        assert_eq!(snap.count() as usize, values.len());
        assert_eq!(snap.sum(), values.iter().copied().fold(0u64, u64::wrapping_add));
        assert_eq!(snap.min(), *values.iter().min().unwrap());
        assert_eq!(snap.max(), *values.iter().max().unwrap());
        // per-bucket recount from raw values must match exactly
        let mut expect = [0u64; N_BUCKETS];
        for &v in &values {
            expect[bucket_index(v)] += 1;
        }
        for (i, &want) in expect.iter().enumerate() {
            assert_eq!(snap.bucket_count(i), want, "bucket {i}");
        }
    }

    fn random_snapshot(rng: &mut Xoshiro256, n: usize) -> HistogramSnapshot {
        let h = Histogram::new();
        for _ in 0..n {
            let shift = (rng.next_u64() % 64) as u32;
            h.record(rng.next_u64() >> shift);
        }
        h.snapshot()
    }

    #[test]
    fn merge_is_associative_and_count_preserving() {
        let mut rng = Xoshiro256::seed_from_u64(0x51AB);
        let (a, b, c) = (
            random_snapshot(&mut rng, 100),
            random_snapshot(&mut rng, 57),
            random_snapshot(&mut rng, 213),
        );
        let left = a.merge(&b).merge(&c);
        let right = a.merge(&b.merge(&c));
        assert_eq!(left, right);
        assert_eq!(left.count(), a.count() + b.count() + c.count());
        assert_eq!(left.sum(), a.sum() + b.sum() + c.sum());
        assert_eq!(left.min(), a.min().min(b.min()).min(c.min()));
        assert_eq!(left.max(), a.max().max(b.max()).max(c.max()));
        // merging with empty is the identity
        assert_eq!(a.merge(&HistogramSnapshot::empty()), a);
        assert_eq!(HistogramSnapshot::empty().merge(&a), a);
    }

    #[test]
    fn percentiles_monotone_in_q_and_bounded_by_extrema() {
        let mut rng = Xoshiro256::seed_from_u64(0x9E37);
        for trial in 0..8 {
            let snap = random_snapshot(&mut rng, 50 + trial * 97);
            let qs = [0.0, 0.1, 0.25, 0.5, 0.75, 0.9, 0.99, 0.999, 1.0];
            let vals: Vec<u64> = qs.iter().map(|&q| snap.percentile(q)).collect();
            for w in vals.windows(2) {
                assert!(w[0] <= w[1], "percentiles not monotone: {vals:?}");
            }
            assert!(vals[0] >= snap.min());
            assert!(*vals.last().unwrap() <= snap.max());
        }
    }

    #[test]
    fn percentiles_match_metrics_convention_on_bucket_bounds() {
        // values sitting exactly on bucket upper bounds make the bucket
        // walk exact, so the histogram must agree with
        // metrics::percentile on the raw samples, rank for rank
        let values: Vec<u64> = vec![1, 3, 7, 15];
        let h = Histogram::new();
        for &v in &values {
            h.record(v);
        }
        let snap = h.snapshot();
        for q in [0.0, 0.25, 0.5, 0.75, 0.9, 0.99, 1.0] {
            let mut raw: Vec<f64> = values.iter().map(|&v| v as f64).collect();
            let want = crate::metrics::percentile(&mut raw, q).unwrap();
            assert_eq!(
                snap.percentile(q) as f64,
                want,
                "q={q}: histogram disagrees with metrics::percentile"
            );
        }
    }

    #[test]
    fn single_value_histogram_is_exact_everywhere() {
        let h = Histogram::new();
        for _ in 0..10 {
            h.record(42);
        }
        let snap = h.snapshot();
        for q in [0.0, 0.5, 0.99, 1.0] {
            assert_eq!(snap.percentile(q), 42);
        }
        assert_eq!(snap.min(), 42);
        assert_eq!(snap.max(), 42);
        assert_eq!(snap.sum(), 420);
    }

    #[test]
    fn empty_histogram_serializes_to_zeros() {
        let snap = Histogram::new().snapshot();
        assert_eq!(snap.count(), 0);
        let j = snap.to_json();
        for key in ["count", "sum_ns", "min_ns", "max_ns", "p50_ns", "p999_ns"] {
            assert_eq!(j.get(key).and_then(|v| v.as_f64()), Some(0.0), "{key}");
        }
        assert!(j.get("buckets").and_then(|v| v.as_arr()).unwrap().is_empty());
    }

    #[test]
    fn json_buckets_are_sparse_ascending_and_sum_to_count() {
        let h = Histogram::new();
        for v in [0u64, 1, 1, 5, 5, 5, 900, u64::MAX] {
            h.record(v);
        }
        let j = h.snapshot().to_json();
        let buckets = j.get("buckets").and_then(|v| v.as_arr()).unwrap();
        let mut prev_lo = -1.0f64;
        let mut total = 0.0f64;
        for b in buckets {
            let pair = b.as_arr().unwrap();
            let lo = pair[0].as_f64().unwrap();
            let n = pair[1].as_f64().unwrap();
            assert!(lo > prev_lo, "bucket bounds must ascend");
            assert!(n > 0.0, "sparse form must omit empty buckets");
            prev_lo = lo;
            total += n;
        }
        assert_eq!(total, j.get("count").and_then(|v| v.as_f64()).unwrap());
    }

    #[test]
    fn json_round_trip_reconstructs_the_snapshot_exactly() {
        let mut rng = Xoshiro256::seed_from_u64(0xF1EE);
        for n in [0usize, 1, 57, 400] {
            let snap = random_snapshot(&mut rng, n);
            let back = HistogramSnapshot::from_json(&snap.to_json()).unwrap();
            assert_eq!(back, snap, "round trip at n={n}");
        }
        // empty round trip restores the min sentinel, so merge identity
        // survives the wire
        let empty = HistogramSnapshot::from_json(&HistogramSnapshot::empty().to_json()).unwrap();
        let a = random_snapshot(&mut rng, 33);
        assert_eq!(a.merge(&empty), a);
    }

    #[test]
    fn parsed_snapshots_merge_count_preserving() {
        let mut rng = Xoshiro256::seed_from_u64(0xDEC0);
        let (a, b) = (random_snapshot(&mut rng, 120), random_snapshot(&mut rng, 81));
        let wire_merge = HistogramSnapshot::from_json(&a.to_json())
            .unwrap()
            .merge(&HistogramSnapshot::from_json(&b.to_json()).unwrap());
        assert_eq!(wire_merge, a.merge(&b));
        assert_eq!(wire_merge.count(), a.count() + b.count());
    }

    #[test]
    fn from_json_rejects_malformed_shapes() {
        for bad in [
            r#"{"sum_ns":0,"min_ns":0,"max_ns":0}"#,
            r#"{"sum_ns":0,"min_ns":0,"max_ns":0,"buckets":[[3,1]]}"#,
            r#"{"sum_ns":0,"min_ns":0,"max_ns":0,"buckets":[[1]]}"#,
            r#"{"sum_ns":0,"min_ns":0,"max_ns":0,"buckets":[[1,-2]]}"#,
            r#"{"min_ns":0,"max_ns":0,"buckets":[]}"#,
        ] {
            let v = Json::parse(bad).unwrap();
            assert!(HistogramSnapshot::from_json(&v).is_err(), "{bad}");
        }
    }

    #[test]
    fn concurrent_records_are_all_counted() {
        use std::sync::Arc;
        let h = Arc::new(Histogram::new());
        let threads: Vec<_> = (0..4)
            .map(|t| {
                let h = Arc::clone(&h);
                std::thread::spawn(move || {
                    for i in 0..1000u64 {
                        h.record(t * 1000 + i);
                    }
                })
            })
            .collect();
        for th in threads {
            th.join().unwrap();
        }
        let snap = h.snapshot();
        assert_eq!(snap.count(), 4000);
        assert_eq!(snap.min(), 0);
        assert_eq!(snap.max(), 3999);
    }
}
