//! `obs` — zero-dependency telemetry: latency histograms, named
//! counters, and a structured trace log for the serving stack.
//!
//! Three pieces:
//!
//! - [`Histogram`]: log2-bucketed latency histogram over atomics.
//!   Recording is four relaxed atomic ops; snapshots are mergeable and
//!   serialize to the one histogram JSON shape shared by the `metrics`
//!   wire op and every `BENCH_*.json`.
//! - [`Registry`]: named histograms and counters handed out as `Arc`s.
//!   Callers resolve their handles once (at shard/connection setup), so
//!   the hot path never touches the registry lock.
//! - [`trace::TraceHandle`]: optional JSONL trace log behind a bounded
//!   channel and a dedicated writer thread (`ccn serve --trace-file`).
//!
//! # Naming convention
//!
//! - `op.<name>` — wall time of one wire op, dispatch to reply
//!   ([`names::OPS`]).
//! - `stage.<name>` — one internal stage of an op ([`names::STAGES`]):
//!   shard queue wait, scalar vs. batched step kernel, store
//!   append/load/compaction, transport read/decode/write.
//! - plain names — counters ([`names::COUNTERS`], plus dynamic
//!   `steps.<kind>` per-learner-kind step counts).
//!
//! # Consistency model
//!
//! [`Registry::snapshot`] reads every histogram and counter in one pass
//! while holding the registry lock. The lock excludes *registration*,
//! not recording — writers keep appending while the snapshot runs — so
//! a snapshot is not a global instant. What it does guarantee:
//!
//! - each histogram is read exactly once, in one pass over its atomics,
//!   so every derived statistic (count, percentiles, buckets) in a reply
//!   comes from the same per-histogram observation — a `p50` and `p99`
//!   in one reply can never straddle an update of the same histogram;
//! - `count == Σ bucket counts` holds by construction (the count is
//!   derived from the buckets, never stored separately);
//! - cross-histogram skew is bounded by the ops in flight during the
//!   single pass.
//!
//! Telemetry is measurement-only: nothing here feeds back into
//! predictions, shard routing, or persisted state, and recording never
//! blocks (the trace queue drops on overflow rather than backpressure).

pub mod histogram;
pub mod trace;

pub use histogram::{bucket_bounds, bucket_index, Histogram, HistogramSnapshot, N_BUCKETS};
pub use trace::{TraceConfig, TraceHandle};

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::util::json::Json;

/// Canonical metric names. Pre-registered by [`Registry::standard`] so
/// the `metrics` reply schema is stable from the first request — an op
/// or stage that has never fired still appears with `count = 0`.
pub mod names {
    /// Every wire op, index-aligned with `serve`'s op timer table.
    pub const OPS: [&str; 12] = [
        "open",
        "step",
        "step_batch",
        "predict",
        "snapshot",
        "restore",
        "park",
        "warm",
        "close",
        "stats",
        "metrics",
        "ping",
    ];

    /// Internal stages a wire op decomposes into.
    pub const STAGES: [&str; 9] = [
        "queue_wait",
        "step_scalar",
        "step_batched",
        "store_append",
        "store_load",
        "store_compact",
        "transport_read",
        "transport_decode",
        "transport_write",
    ];

    /// Fixed counters (dynamic `steps.<kind>` counters register lazily).
    pub const COUNTERS: [&str; 5] = [
        "transport.err_decode",
        "transport.err_oversize",
        "transport.err_ghost_id",
        "transport.err_io",
        "trace.dropped",
    ];
}

/// Named histograms + counters, shared via `Arc` across shards, the
/// store, and transport threads. Get-or-create handles once at setup;
/// record through the returned `Arc`s thereafter.
#[derive(Default)]
pub struct Registry {
    hists: Mutex<BTreeMap<String, Arc<Histogram>>>,
    counters: Mutex<BTreeMap<String, Arc<AtomicU64>>>,
}

/// A poisoned telemetry lock must not take the serving path down with
/// it — the maps hold only `Arc`s, which cannot be left half-written.
fn relock<T>(lock: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    lock.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

impl Registry {
    pub fn new() -> Registry {
        Registry::default()
    }

    /// A registry with every canonical op/stage histogram and counter
    /// pre-registered (see [`names`]), so reply schemas don't depend on
    /// which code paths have fired yet.
    pub fn standard() -> Registry {
        let reg = Registry::new();
        for op in names::OPS {
            reg.histogram(&format!("op.{op}"));
        }
        for stage in names::STAGES {
            reg.histogram(&format!("stage.{stage}"));
        }
        for counter in names::COUNTERS {
            reg.counter(counter);
        }
        reg
    }

    /// Get-or-create the named histogram.
    pub fn histogram(&self, name: &str) -> Arc<Histogram> {
        let mut hists = relock(&self.hists);
        match hists.get(name) {
            Some(h) => Arc::clone(h),
            None => {
                let h = Arc::new(Histogram::new());
                hists.insert(name.to_string(), Arc::clone(&h));
                h
            }
        }
    }

    /// Get-or-create the named counter.
    pub fn counter(&self, name: &str) -> Arc<AtomicU64> {
        let mut counters = relock(&self.counters);
        match counters.get(name) {
            Some(c) => Arc::clone(c),
            None => {
                let c = Arc::new(AtomicU64::new(0));
                counters.insert(name.to_string(), Arc::clone(&c));
                c
            }
        }
    }

    /// One consistent read of the whole registry (see module docs for
    /// exactly what "consistent" means here).
    pub fn snapshot(&self) -> RegistrySnapshot {
        let hists = relock(&self.hists)
            .iter()
            .map(|(name, h)| (name.clone(), h.snapshot()))
            .collect();
        let counters = relock(&self.counters)
            .iter()
            .map(|(name, c)| (name.clone(), c.load(Ordering::Relaxed)))
            .collect();
        RegistrySnapshot { hists, counters }
    }
}

/// Point-in-time copy of a [`Registry`]. Plain data; query and
/// serialize freely.
pub struct RegistrySnapshot {
    pub hists: BTreeMap<String, HistogramSnapshot>,
    pub counters: BTreeMap<String, u64>,
}

impl RegistrySnapshot {
    /// Group by naming convention: `op.*` under `"ops"` and `stage.*`
    /// under `"stages"` (prefixes stripped), any other histograms under
    /// `"histograms"`, counters flat under `"counters"`.
    pub fn to_json(&self) -> Json {
        let mut ops = BTreeMap::new();
        let mut stages = BTreeMap::new();
        let mut other = BTreeMap::new();
        for (name, snap) in &self.hists {
            if let Some(op) = name.strip_prefix("op.") {
                ops.insert(op.to_string(), snap.to_json());
            } else if let Some(stage) = name.strip_prefix("stage.") {
                stages.insert(stage.to_string(), snap.to_json());
            } else {
                other.insert(name.clone(), snap.to_json());
            }
        }
        let counters: BTreeMap<String, Json> = self
            .counters
            .iter()
            .map(|(name, &v)| (name.clone(), Json::Num(v as f64)))
            .collect();
        let mut fields = vec![
            ("ops", Json::Obj(ops)),
            ("stages", Json::Obj(stages)),
            ("counters", Json::Obj(counters)),
        ];
        if !other.is_empty() {
            fields.push(("histograms", Json::Obj(other)));
        }
        Json::obj(fields)
    }
}

/// Per-request stage breakdown for a *sampled* traced op. The shard
/// worker fills the cell; the dispatch thread reads it after the reply
/// arrives (the reply channel orders the two). `shard` doubles as the
/// filled-marker: `u64::MAX` until a worker writes it.
pub struct StageCell {
    pub queue_ns: AtomicU64,
    pub exec_ns: AtomicU64,
    pub store_ns: AtomicU64,
    pub kernel_ns: AtomicU64,
    pub shard: AtomicU64,
}

impl Default for StageCell {
    fn default() -> StageCell {
        StageCell {
            queue_ns: AtomicU64::new(0),
            exec_ns: AtomicU64::new(0),
            store_ns: AtomicU64::new(0),
            kernel_ns: AtomicU64::new(0),
            shard: AtomicU64::new(u64::MAX),
        }
    }
}

impl StageCell {
    /// True once a shard worker has written the breakdown.
    pub fn filled(&self) -> bool {
        self.shard.load(Ordering::Relaxed) != u64::MAX
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_handles_are_shared() {
        let reg = Registry::new();
        let a = reg.histogram("stage.queue_wait");
        let b = reg.histogram("stage.queue_wait");
        a.record(7);
        b.record(9);
        assert_eq!(reg.snapshot().hists["stage.queue_wait"].count(), 2);
    }

    #[test]
    fn counter_handles_are_shared() {
        let reg = Registry::new();
        let a = reg.counter("steps.columnar");
        let b = reg.counter("steps.columnar");
        a.fetch_add(3, Ordering::Relaxed);
        b.fetch_add(4, Ordering::Relaxed);
        assert_eq!(reg.snapshot().counters["steps.columnar"], 7);
    }

    #[test]
    fn standard_registry_pre_registers_the_full_schema() {
        let snap = Registry::standard().snapshot();
        for op in names::OPS {
            assert!(snap.hists.contains_key(&format!("op.{op}")), "op.{op}");
        }
        for stage in names::STAGES {
            assert!(
                snap.hists.contains_key(&format!("stage.{stage}")),
                "stage.{stage}"
            );
        }
        for counter in names::COUNTERS {
            assert!(snap.counters.contains_key(counter), "{counter}");
        }
        // and the grouped JSON carries them even at count 0
        let j = snap.to_json();
        let ops = j.get("ops").and_then(|v| v.as_obj()).unwrap();
        assert_eq!(ops.len(), names::OPS.len());
        let stages = j.get("stages").and_then(|v| v.as_obj()).unwrap();
        assert_eq!(stages.len(), names::STAGES.len());
    }

    #[test]
    fn snapshot_json_groups_by_prefix() {
        let reg = Registry::new();
        reg.histogram("op.step").record(1000);
        reg.histogram("stage.queue_wait").record(50);
        reg.histogram("bench.probe").record(9);
        reg.counter("steps.ccn").fetch_add(12, Ordering::Relaxed);
        let j = reg.snapshot().to_json();
        assert!(j.get("ops").unwrap().get("step").is_some());
        assert!(j.get("stages").unwrap().get("queue_wait").is_some());
        assert!(j.get("histograms").unwrap().get("bench.probe").is_some());
        assert_eq!(
            j.get("counters").unwrap().get("steps.ccn").and_then(|v| v.as_f64()),
            Some(12.0)
        );
    }

    #[test]
    fn stage_cell_marks_filled_via_shard_sentinel() {
        let cell = StageCell::default();
        assert!(!cell.filled());
        cell.shard.store(0, Ordering::Relaxed);
        assert!(cell.filled());
    }
}
