//! `obs` — zero-dependency telemetry: latency histograms, named
//! counters, and a structured trace log for the serving stack.
//!
//! The pieces:
//!
//! - [`Histogram`]: log2-bucketed latency histogram over atomics.
//!   Recording is four relaxed atomic ops; snapshots are mergeable and
//!   serialize to the one histogram JSON shape shared by the `metrics`
//!   wire op and every `BENCH_*.json`.
//! - [`WindowedCounter`]: lock-free ring of one-second buckets, so
//!   `stats`/`metrics` report recent *rates* (1s/10s/60s) next to the
//!   lifetime totals.
//! - [`Registry`]: named histograms, counters and windows handed out as
//!   `Arc`s. Callers resolve their handles once (at shard/connection
//!   setup), so the hot path never touches the registry lock.
//!   [`RegistrySnapshot`] round-trips through the `metrics` reply shape
//!   and merges across processes — the router's fleet-scope roll-up is
//!   `fold(merge)` over parsed backend replies.
//! - [`trace::TraceHandle`]: optional JSONL trace log behind a bounded
//!   channel and a dedicated writer thread (`ccn serve --trace-file`,
//!   `ccn route --trace-file`), with [`span`] correlation ids stitching
//!   router and backend events into one end-to-end trace.
//! - [`expo::MetricsServer`]: zero-dep Prometheus text endpoint
//!   (`--metrics-listen`).
//!
//! # Naming convention
//!
//! - `op.<name>` — wall time of one wire op, dispatch to reply
//!   ([`names::OPS`]).
//! - `stage.<name>` — one internal stage of an op ([`names::STAGES`]):
//!   shard queue wait, scalar vs. batched step kernel, store
//!   append/load/compaction, transport read/decode/write.
//! - plain names — counters ([`names::COUNTERS`], plus dynamic
//!   `steps.<kind>` per-learner-kind step counts).
//!
//! # Consistency model
//!
//! [`Registry::snapshot`] reads every histogram and counter in one pass
//! while holding the registry lock. The lock excludes *registration*,
//! not recording — writers keep appending while the snapshot runs — so
//! a snapshot is not a global instant. What it does guarantee:
//!
//! - each histogram is read exactly once, in one pass over its atomics,
//!   so every derived statistic (count, percentiles, buckets) in a reply
//!   comes from the same per-histogram observation — a `p50` and `p99`
//!   in one reply can never straddle an update of the same histogram;
//! - `count == Σ bucket counts` holds by construction (the count is
//!   derived from the buckets, never stored separately);
//! - cross-histogram skew is bounded by the ops in flight during the
//!   single pass.
//!
//! Telemetry is measurement-only: nothing here feeds back into
//! predictions, shard routing, or persisted state, and recording never
//! blocks (the trace queue drops on overflow rather than backpressure).

pub mod expo;
pub mod histogram;
pub mod span;
pub mod trace;
pub mod window;

pub use expo::{render_prometheus, MetricsServer};
pub use histogram::{bucket_bounds, bucket_index, Histogram, HistogramSnapshot, N_BUCKETS};
pub use span::{mint_id, SpanIds};
pub use trace::{TraceConfig, TraceHandle};
pub use window::{WindowCounts, WindowedCounter};

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::util::json::Json;

/// Canonical metric names. Pre-registered by [`Registry::standard`] so
/// the `metrics` reply schema is stable from the first request — an op
/// or stage that has never fired still appears with `count = 0`.
pub mod names {
    /// Every wire op, index-aligned with `serve`'s op timer table.
    pub const OPS: [&str; 13] = [
        "open",
        "step",
        "step_batch",
        "predict",
        "snapshot",
        "restore",
        "park",
        "warm",
        "close",
        "stats",
        "metrics",
        "ping",
        "replicate",
    ];

    /// Internal stages a wire op decomposes into.
    pub const STAGES: [&str; 9] = [
        "queue_wait",
        "step_scalar",
        "step_batched",
        "store_append",
        "store_load",
        "store_compact",
        "transport_read",
        "transport_decode",
        "transport_write",
    ];

    /// Fixed counters (dynamic `steps.<kind>` counters register lazily).
    pub const COUNTERS: [&str; 5] = [
        "transport.err_decode",
        "transport.err_oversize",
        "transport.err_ghost_id",
        "transport.err_io",
        "trace.dropped",
    ];

    /// Windowed rate counters ([`super::Registry::window`]): recent
    /// throughput next to the lifetime totals.
    pub const WINDOWS: [&str; 5] =
        ["ops", "steps", "parks", "warms", "trace.dropped"];
}

/// Named histograms + counters, shared via `Arc` across shards, the
/// store, and transport threads. Get-or-create handles once at setup;
/// record through the returned `Arc`s thereafter.
#[derive(Default)]
pub struct Registry {
    hists: Mutex<BTreeMap<String, Arc<Histogram>>>,
    counters: Mutex<BTreeMap<String, Arc<AtomicU64>>>,
    windows: Mutex<BTreeMap<String, Arc<WindowedCounter>>>,
}

/// A poisoned telemetry lock must not take the serving path down with
/// it — the maps hold only `Arc`s, which cannot be left half-written.
fn relock<T>(lock: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    lock.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

impl Registry {
    pub fn new() -> Registry {
        Registry::default()
    }

    /// A registry with every canonical op/stage histogram and counter
    /// pre-registered (see [`names`]), so reply schemas don't depend on
    /// which code paths have fired yet.
    pub fn standard() -> Registry {
        let reg = Registry::new();
        for op in names::OPS {
            reg.histogram(&format!("op.{op}"));
        }
        for stage in names::STAGES {
            reg.histogram(&format!("stage.{stage}"));
        }
        for counter in names::COUNTERS {
            reg.counter(counter);
        }
        for win in names::WINDOWS {
            reg.window(win);
        }
        reg
    }

    /// Get-or-create the named histogram.
    pub fn histogram(&self, name: &str) -> Arc<Histogram> {
        let mut hists = relock(&self.hists);
        match hists.get(name) {
            Some(h) => Arc::clone(h),
            None => {
                let h = Arc::new(Histogram::new());
                hists.insert(name.to_string(), Arc::clone(&h));
                h
            }
        }
    }

    /// Get-or-create the named counter.
    pub fn counter(&self, name: &str) -> Arc<AtomicU64> {
        let mut counters = relock(&self.counters);
        match counters.get(name) {
            Some(c) => Arc::clone(c),
            None => {
                let c = Arc::new(AtomicU64::new(0));
                counters.insert(name.to_string(), Arc::clone(&c));
                c
            }
        }
    }

    /// Get-or-create the named windowed rate counter.
    pub fn window(&self, name: &str) -> Arc<WindowedCounter> {
        let mut windows = relock(&self.windows);
        match windows.get(name) {
            Some(w) => Arc::clone(w),
            None => {
                let w = Arc::new(WindowedCounter::new());
                windows.insert(name.to_string(), Arc::clone(&w));
                w
            }
        }
    }

    /// One consistent read of the whole registry (see module docs for
    /// exactly what "consistent" means here).
    pub fn snapshot(&self) -> RegistrySnapshot {
        let hists = relock(&self.hists)
            .iter()
            .map(|(name, h)| (name.clone(), h.snapshot()))
            .collect();
        let counters = relock(&self.counters)
            .iter()
            .map(|(name, c)| (name.clone(), c.load(Ordering::Relaxed)))
            .collect();
        let windows = relock(&self.windows)
            .iter()
            .map(|(name, w)| (name.clone(), w.counts()))
            .collect();
        RegistrySnapshot { hists, counters, windows }
    }
}

/// Point-in-time copy of a [`Registry`]. Plain data; query and
/// serialize freely. Snapshots are *mergeable* across processes
/// ([`RegistrySnapshot::merge`]) and round-trip through the `metrics`
/// reply shape ([`RegistrySnapshot::from_metrics_json`]) — that pair is
/// what the router's fleet-scope roll-up is built from.
#[derive(Default)]
pub struct RegistrySnapshot {
    pub hists: BTreeMap<String, HistogramSnapshot>,
    pub counters: BTreeMap<String, u64>,
    pub windows: BTreeMap<String, WindowCounts>,
}

impl RegistrySnapshot {
    /// Group by naming convention: `op.*` under `"ops"` and `stage.*`
    /// under `"stages"` (prefixes stripped), any other histograms under
    /// `"histograms"`, counters flat under `"counters"`, windowed rates
    /// under `"windows"` (the latter two groups only when non-empty, so
    /// pre-window consumers see an unchanged shape).
    pub fn to_json(&self) -> Json {
        let mut ops = BTreeMap::new();
        let mut stages = BTreeMap::new();
        let mut other = BTreeMap::new();
        for (name, snap) in &self.hists {
            if let Some(op) = name.strip_prefix("op.") {
                ops.insert(op.to_string(), snap.to_json());
            } else if let Some(stage) = name.strip_prefix("stage.") {
                stages.insert(stage.to_string(), snap.to_json());
            } else {
                other.insert(name.clone(), snap.to_json());
            }
        }
        let counters: BTreeMap<String, Json> = self
            .counters
            .iter()
            .map(|(name, &v)| (name.clone(), Json::Num(v as f64)))
            .collect();
        let mut fields = vec![
            ("ops", Json::Obj(ops)),
            ("stages", Json::Obj(stages)),
            ("counters", Json::Obj(counters)),
        ];
        if !other.is_empty() {
            fields.push(("histograms", Json::Obj(other)));
        }
        if !self.windows.is_empty() {
            let windows: BTreeMap<String, Json> = self
                .windows
                .iter()
                .map(|(name, w)| (name.clone(), w.to_json()))
                .collect();
            fields.push(("windows", Json::Obj(windows)));
        }
        Json::obj(fields)
    }

    /// Inverse of [`RegistrySnapshot::to_json`]: rebuild a snapshot from
    /// a `metrics` reply, re-applying the `op.`/`stage.` prefixes the
    /// grouping stripped. Every group is optional (a pre-window backend
    /// simply contributes no windows), but a present group must parse.
    pub fn from_metrics_json(v: &Json) -> Result<RegistrySnapshot, String> {
        let mut snap = RegistrySnapshot::default();
        for (group, prefix) in [("ops", "op."), ("stages", "stage."), ("histograms", "")] {
            let Some(block) = v.get(group) else { continue };
            let block = block
                .as_obj()
                .ok_or_else(|| format!("metrics: '{group}' is not an object"))?;
            for (name, hist) in block {
                let parsed = HistogramSnapshot::from_json(hist)
                    .map_err(|e| format!("metrics: {group}.{name}: {e}"))?;
                snap.hists.insert(format!("{prefix}{name}"), parsed);
            }
        }
        if let Some(block) = v.get("counters") {
            let block = block
                .as_obj()
                .ok_or_else(|| "metrics: 'counters' is not an object".to_string())?;
            for (name, val) in block {
                let n = val
                    .as_f64()
                    .filter(|&n| n >= 0.0 && n.fract() == 0.0)
                    .ok_or_else(|| format!("metrics: counter '{name}' is not an integer"))?;
                snap.counters.insert(name.clone(), n as u64);
            }
        }
        if let Some(block) = v.get("windows") {
            let block = block
                .as_obj()
                .ok_or_else(|| "metrics: 'windows' is not an object".to_string())?;
            for (name, win) in block {
                let parsed = WindowCounts::from_json(win)
                    .map_err(|e| format!("metrics: windows.{name}: {e}"))?;
                snap.windows.insert(name.clone(), parsed);
            }
        }
        Ok(snap)
    }

    /// Union-keyed merge: histograms merge bucketwise
    /// ([`HistogramSnapshot::merge`]), counters and window totals add. A
    /// name present on one side only passes through unchanged, so the
    /// empty snapshot is the identity and the fold over any backend
    /// order gives the same fleet totals.
    pub fn merge(&self, other: &RegistrySnapshot) -> RegistrySnapshot {
        let mut merged = RegistrySnapshot {
            hists: self.hists.clone(),
            counters: self.counters.clone(),
            windows: self.windows.clone(),
        };
        for (name, h) in &other.hists {
            let slot = merged.hists.entry(name.clone()).or_default();
            *slot = slot.merge(h);
        }
        for (name, &v) in &other.counters {
            *merged.counters.entry(name.clone()).or_insert(0) += v;
        }
        for (name, w) in &other.windows {
            let slot = merged.windows.entry(name.clone()).or_default();
            *slot = slot.merge(w);
        }
        merged
    }
}

/// Per-request stage breakdown for a *sampled* traced op. The shard
/// worker fills the cell; the dispatch thread reads it after the reply
/// arrives (the reply channel orders the two). `shard` doubles as the
/// filled-marker: `u64::MAX` until a worker writes it.
pub struct StageCell {
    pub queue_ns: AtomicU64,
    pub exec_ns: AtomicU64,
    pub store_ns: AtomicU64,
    pub kernel_ns: AtomicU64,
    pub shard: AtomicU64,
}

impl Default for StageCell {
    fn default() -> StageCell {
        StageCell {
            queue_ns: AtomicU64::new(0),
            exec_ns: AtomicU64::new(0),
            store_ns: AtomicU64::new(0),
            kernel_ns: AtomicU64::new(0),
            shard: AtomicU64::new(u64::MAX),
        }
    }
}

impl StageCell {
    /// True once a shard worker has written the breakdown.
    pub fn filled(&self) -> bool {
        self.shard.load(Ordering::Relaxed) != u64::MAX
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_handles_are_shared() {
        let reg = Registry::new();
        let a = reg.histogram("stage.queue_wait");
        let b = reg.histogram("stage.queue_wait");
        a.record(7);
        b.record(9);
        assert_eq!(reg.snapshot().hists["stage.queue_wait"].count(), 2);
    }

    #[test]
    fn counter_handles_are_shared() {
        let reg = Registry::new();
        let a = reg.counter("steps.columnar");
        let b = reg.counter("steps.columnar");
        a.fetch_add(3, Ordering::Relaxed);
        b.fetch_add(4, Ordering::Relaxed);
        assert_eq!(reg.snapshot().counters["steps.columnar"], 7);
    }

    #[test]
    fn standard_registry_pre_registers_the_full_schema() {
        let snap = Registry::standard().snapshot();
        for op in names::OPS {
            assert!(snap.hists.contains_key(&format!("op.{op}")), "op.{op}");
        }
        for stage in names::STAGES {
            assert!(
                snap.hists.contains_key(&format!("stage.{stage}")),
                "stage.{stage}"
            );
        }
        for counter in names::COUNTERS {
            assert!(snap.counters.contains_key(counter), "{counter}");
        }
        for win in names::WINDOWS {
            assert!(snap.windows.contains_key(win), "window {win}");
        }
        // and the grouped JSON carries them even at count 0
        let j = snap.to_json();
        let ops = j.get("ops").and_then(|v| v.as_obj()).unwrap();
        assert_eq!(ops.len(), names::OPS.len());
        let stages = j.get("stages").and_then(|v| v.as_obj()).unwrap();
        assert_eq!(stages.len(), names::STAGES.len());
        let windows = j.get("windows").and_then(|v| v.as_obj()).unwrap();
        assert_eq!(windows.len(), names::WINDOWS.len());
    }

    #[test]
    fn metrics_json_round_trips_and_merges_like_the_in_process_snapshots() {
        let mk = |seed: u64| {
            let reg = Registry::standard();
            reg.histogram("op.step").record(1000 + seed);
            reg.histogram("op.open").record(seed);
            reg.histogram("stage.queue_wait").record(10 * seed + 1);
            reg.counter("trace.dropped").fetch_add(seed, Ordering::Relaxed);
            reg.counter(&format!("steps.kind{}", seed % 2))
                .fetch_add(3, Ordering::Relaxed);
            reg.window("ops").add(seed + 1);
            reg.snapshot()
        };
        let (a, b) = (mk(3), mk(8));
        // wire round trip is lossless for every group
        let back = RegistrySnapshot::from_metrics_json(&a.to_json()).unwrap();
        assert_eq!(back.to_json().dump(), a.to_json().dump());
        // merging parsed replies == merging the in-process snapshots,
        // including union-only keys (steps.kind0 vs steps.kind1)
        let wire = RegistrySnapshot::from_metrics_json(&a.to_json())
            .unwrap()
            .merge(&RegistrySnapshot::from_metrics_json(&b.to_json()).unwrap());
        let direct = a.merge(&b);
        assert_eq!(wire.to_json().dump(), direct.to_json().dump());
        assert_eq!(
            direct.hists["op.step"].count(),
            a.hists["op.step"].count() + b.hists["op.step"].count()
        );
        assert_eq!(direct.counters["trace.dropped"], 11);
        assert_eq!(direct.counters["steps.kind0"], 3);
        assert_eq!(direct.windows["ops"].last_60s, 4 + 9);
        // merge identity
        let empty = RegistrySnapshot::default();
        assert_eq!(a.merge(&empty).to_json().dump(), a.to_json().dump());
    }

    #[test]
    fn snapshot_json_groups_by_prefix() {
        let reg = Registry::new();
        reg.histogram("op.step").record(1000);
        reg.histogram("stage.queue_wait").record(50);
        reg.histogram("bench.probe").record(9);
        reg.counter("steps.ccn").fetch_add(12, Ordering::Relaxed);
        let j = reg.snapshot().to_json();
        assert!(j.get("ops").unwrap().get("step").is_some());
        assert!(j.get("stages").unwrap().get("queue_wait").is_some());
        assert!(j.get("histograms").unwrap().get("bench.probe").is_some());
        assert_eq!(
            j.get("counters").unwrap().get("steps.ccn").and_then(|v| v.as_f64()),
            Some(12.0)
        );
    }

    #[test]
    fn stage_cell_marks_filled_via_shard_sentinel() {
        let cell = StageCell::default();
        assert!(!cell.filled());
        cell.shard.store(0, Ordering::Relaxed);
        assert!(cell.filled());
    }
}
