//! Cross-process span context: the correlation ids that stitch one
//! request's trace events together across the router/backend hop.
//!
//! The router mints a `trace_id` for each request it forwards (reusing a
//! client-supplied one, so an upstream tracer keeps working) plus a
//! `span_id` for its own hop, and splices both into the forwarded JSONL
//! op as ordinary optional fields — the backend's strict op parser reads
//! only the keys it knows, so correlated and uncorrelated requests are
//! the same op. A tracing backend echoes the pair into its own event
//! (`trace_id` + `parent_span_id`) and mints a fresh `span_id` for its
//! side, which is exactly the join key `scripts/check_trace.py` uses to
//! assemble the end-to-end span tree.
//!
//! Ids are 16 lowercase hex chars (a `u64`): unique across processes by
//! mixing the wall clock, the pid and a process-local sequence through
//! SplitMix64 (a bijection — two mints in the same nanosecond still
//! differ because the sequence does).

use std::sync::atomic::{AtomicU64, Ordering};

use crate::util::json::Json;

/// Longest correlation id accepted from the wire — ids are copied into
/// trace events, so an abusive client must not get megabytes echoed
/// into the trace file.
const MAX_WIRE_ID_LEN: usize = 64;

static MINT_SEQ: AtomicU64 = AtomicU64::new(0);

#[inline]
fn splitmix64(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9E3779B97F4A7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// Mint one correlation id: 16 lowercase hex chars, unique across
/// concurrent mints and across processes.
pub fn mint_id() -> String {
    let seq = MINT_SEQ.fetch_add(1, Ordering::Relaxed);
    let t = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_nanos() as u64)
        .unwrap_or(0);
    let pid = std::process::id() as u64;
    let raw = splitmix64(t ^ (pid << 32).wrapping_add(pid))
        ^ splitmix64(seq.wrapping_mul(0xA24BAED4963EE407));
    format!("{raw:016x}")
}

/// The correlation pair carried on a forwarded op. `span_id` is the
/// *sender's* hop span — the receiver treats it as its parent.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SpanIds {
    pub trace_id: String,
    pub span_id: Option<String>,
}

/// Is `s` a plausible wire correlation id? Bounded and printable-plain
/// (hex plus `-`, covering W3C-style ids) — anything else is ignored
/// rather than copied around.
fn valid_wire_id(s: &str) -> bool {
    !s.is_empty()
        && s.len() <= MAX_WIRE_ID_LEN
        && s.bytes()
            .all(|b| b.is_ascii_alphanumeric() || b == b'-')
}

/// Extract the correlation fields from a parsed request object, if the
/// sender attached any. Invalid or oversized values are treated as
/// absent (correlation is diagnostic, never load-bearing).
pub fn from_wire(v: &Json) -> Option<SpanIds> {
    let trace_id = v
        .get("trace_id")
        .and_then(|t| t.as_str())
        .filter(|t| valid_wire_id(t))?
        .to_string();
    let span_id = v
        .get("span_id")
        .and_then(|s| s.as_str())
        .filter(|s| valid_wire_id(s))
        .map(|s| s.to_string());
    Some(SpanIds { trace_id, span_id })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn minted_ids_are_hex_and_distinct() {
        let mut seen = std::collections::HashSet::new();
        for _ in 0..10_000 {
            let id = mint_id();
            assert_eq!(id.len(), 16);
            assert!(id.bytes().all(|b| b.is_ascii_hexdigit()));
            assert!(seen.insert(id), "duplicate trace id minted");
        }
    }

    #[test]
    fn wire_extraction_validates_and_bounds() {
        let ok = Json::parse(
            r#"{"op":"step","trace_id":"a1b2c3","span_id":"deadbeef"}"#,
        )
        .unwrap();
        assert_eq!(
            from_wire(&ok),
            Some(SpanIds {
                trace_id: "a1b2c3".to_string(),
                span_id: Some("deadbeef".to_string()),
            })
        );
        // span without trace: no context
        let no_trace = Json::parse(r#"{"op":"step","span_id":"x1"}"#).unwrap();
        assert_eq!(from_wire(&no_trace), None);
        // trace alone is enough
        let bare = Json::parse(r#"{"op":"step","trace_id":"t-1"}"#).unwrap();
        assert_eq!(
            from_wire(&bare).unwrap(),
            SpanIds { trace_id: "t-1".to_string(), span_id: None }
        );
        // junk is dropped, not echoed
        let oversize = format!(
            r#"{{"op":"step","trace_id":"{}"}}"#,
            "a".repeat(MAX_WIRE_ID_LEN + 1)
        );
        assert_eq!(from_wire(&Json::parse(&oversize).unwrap()), None);
        let bad_chars =
            Json::parse(r#"{"op":"step","trace_id":"no spaces"}"#).unwrap();
        assert_eq!(from_wire(&bad_chars), None);
        let non_string =
            Json::parse(r#"{"op":"step","trace_id":42}"#).unwrap();
        assert_eq!(from_wire(&non_string), None);
    }
}
