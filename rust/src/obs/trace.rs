//! Structured trace log: one JSONL event per sampled wire op.
//!
//! The serving hot path must never block on disk, so events go through a
//! bounded channel to a dedicated writer thread. When the channel is
//! full the event is *dropped* (and counted in the `trace.dropped`
//! registry counter) rather than applying backpressure — the trace is a
//! diagnostic, not a ledger. [`TraceHandle::finish`] closes the channel
//! and joins the writer, so every event accepted before shutdown is on
//! disk when `finish` returns.

use std::io::Write;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{self, SyncSender, TrySendError};
use std::sync::Arc;

use crate::util::json::Json;

/// How many events may queue between the serving threads and the writer
/// before new events are dropped.
const TRACE_QUEUE_CAP: usize = 1024;

/// Where the trace goes and how often: `sample = N` emits every Nth op
/// (N = 1 traces everything). `sample = 0` is rejected at open.
#[derive(Debug, Clone)]
pub struct TraceConfig {
    pub path: PathBuf,
    pub sample: u64,
}

/// Live trace log. Owned by the `Service` (or the cluster `Router`);
/// cloned handles are not needed because sampling and emission happen at
/// the single dispatch point. Dropping the handle flushes and joins the
/// writer, so a handle buried in an `Arc`-shared owner still closes its
/// file deterministically when the last owner goes away.
pub struct TraceHandle {
    /// `Some` until the handle shuts down; `Option` so `Drop` can close
    /// the channel *before* joining the writer (joining with a live
    /// sender would deadlock on the blocked `recv`).
    tx: Option<SyncSender<String>>,
    /// global op sequence number — drives deterministic 1-in-N sampling
    seq: AtomicU64,
    sample: u64,
    dropped: Arc<AtomicU64>,
    /// optional windowed twin of `dropped`, so drop *rates* show up in
    /// the `windows` block next to the lifetime total
    dropped_win: Option<Arc<crate::obs::WindowedCounter>>,
    join: Option<std::thread::JoinHandle<()>>,
}

impl TraceHandle {
    /// Create (truncate) the trace file and start the writer thread.
    /// `dropped` is the registry counter bumped on queue overflow.
    pub fn open(cfg: &TraceConfig, dropped: Arc<AtomicU64>) -> Result<TraceHandle, String> {
        if cfg.sample == 0 {
            return Err("trace sample must be >= 1 (1 = trace every op)".to_string());
        }
        let file = std::fs::File::create(&cfg.path)
            .map_err(|e| format!("trace file {}: {e}", cfg.path.display()))?;
        let (tx, rx) = mpsc::sync_channel::<String>(TRACE_QUEUE_CAP);
        let join = std::thread::spawn(move || {
            let mut out = std::io::BufWriter::new(file);
            for line in rx {
                // flush per event: a crashed or killed server still
                // leaves a readable trace up to the last accepted event
                if writeln!(out, "{line}").and_then(|()| out.flush()).is_err() {
                    break;
                }
            }
            let _ = out.flush();
        });
        Ok(TraceHandle {
            tx: Some(tx),
            seq: AtomicU64::new(0),
            sample: cfg.sample,
            dropped,
            dropped_win: None,
            join: Some(join),
        })
    }

    /// Attach a windowed counter bumped alongside the lifetime
    /// `trace.dropped` counter on every overflow.
    pub fn set_drop_window(&mut self, win: Arc<crate::obs::WindowedCounter>) {
        self.dropped_win = Some(win);
    }

    /// Advance the op sequence; true when this op should emit an event.
    pub fn should_sample(&self) -> bool {
        self.seq.fetch_add(1, Ordering::Relaxed) % self.sample == 0
    }

    /// Queue one event line. Never blocks: a full queue (or a dead
    /// writer) drops the event and bumps the `trace.dropped` counter.
    pub fn emit(&self, event: &Json) {
        let sent = match &self.tx {
            Some(tx) => !matches!(
                tx.try_send(event.dump()),
                Err(TrySendError::Full(_)) | Err(TrySendError::Disconnected(_))
            ),
            None => false,
        };
        if !sent {
            self.dropped.fetch_add(1, Ordering::Relaxed);
            if let Some(win) = &self.dropped_win {
                win.add(1);
            }
        }
    }

    /// Close the channel and join the writer; all accepted events are on
    /// disk when this returns. (Equivalent to dropping the handle — kept
    /// as an explicit name for shutdown paths.)
    pub fn finish(self) {}
}

impl Drop for TraceHandle {
    fn drop(&mut self) {
        // close the channel first, then join: the writer exits its recv
        // loop only once every sender is gone
        drop(self.tx.take());
        if let Some(join) = self.join.take() {
            let _ = join.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_path(tag: &str) -> PathBuf {
        let nanos = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .unwrap()
            .as_nanos();
        std::env::temp_dir().join(format!("ccn_trace_{tag}_{}_{nanos}.jsonl", std::process::id()))
    }

    #[test]
    fn zero_sample_rate_is_rejected() {
        let cfg = TraceConfig {
            path: tmp_path("zero"),
            sample: 0,
        };
        assert!(TraceHandle::open(&cfg, Arc::new(AtomicU64::new(0))).is_err());
    }

    #[test]
    fn sampling_takes_every_nth_op() {
        let cfg = TraceConfig {
            path: tmp_path("nth"),
            sample: 3,
        };
        let t = TraceHandle::open(&cfg, Arc::new(AtomicU64::new(0))).unwrap();
        let hits: Vec<bool> = (0..9).map(|_| t.should_sample()).collect();
        assert_eq!(
            hits,
            [true, false, false, true, false, false, true, false, false]
        );
        t.finish();
        let _ = std::fs::remove_file(&cfg.path);
    }

    #[test]
    fn finish_flushes_every_accepted_event() {
        let cfg = TraceConfig {
            path: tmp_path("flush"),
            sample: 1,
        };
        let dropped = Arc::new(AtomicU64::new(0));
        let t = TraceHandle::open(&cfg, Arc::clone(&dropped)).unwrap();
        for i in 0..100 {
            t.emit(&Json::obj(vec![("i", Json::Num(i as f64))]));
        }
        t.finish();
        let body = std::fs::read_to_string(&cfg.path).unwrap();
        let lines: Vec<&str> = body.lines().collect();
        assert_eq!(lines.len() as u64 + dropped.load(Ordering::Relaxed), 100);
        for line in lines {
            let ev = Json::parse(line).expect("every trace line is standalone JSON");
            assert!(ev.get("i").is_some());
        }
    }
}
