//! Ring-buffered windowed counters: recent *rates* next to the
//! registry's lifetime totals.
//!
//! A [`WindowedCounter`] is a ring of [`WINDOW_SLOTS`] one-second
//! buckets. Each slot packs `(second stamp, count)` into one `AtomicU64`
//! (stamp in the high 32 bits, count in the low 32), so both the lazy
//! reset of a recycled slot and the increment are a single CAS — no
//! lock, no lost updates, and a reader can always tell a fresh bucket
//! from a stale one left over from the ring's previous lap. Per-second
//! counts saturate at `u32::MAX` (4.2 billion events in one second is
//! beyond anything this process can generate).
//!
//! Readers take a [`WindowCounts`] — the totals over the trailing 1s,
//! 10s and 60s (including the current partial second) — which is a plain
//! value: mergeable across processes (the cluster roll-up sums them) and
//! serializable into the `windows` block of `stats`/`metrics` replies.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

use crate::util::json::Json;

/// Ring capacity. Must exceed the widest reported window (60s) so a
/// stamp inside the window can never be a collision from a previous lap.
pub const WINDOW_SLOTS: usize = 64;

#[inline]
fn pack(sec: u32, n: u32) -> u64 {
    ((sec as u64) << 32) | n as u64
}

#[inline]
fn unpack(v: u64) -> (u32, u32) {
    ((v >> 32) as u32, v as u32)
}

/// Lock-free ring of one-second event buckets. Shared via `Arc` from
/// [`super::Registry::window`]; `add` on the hot path is one load + one
/// CAS in the common case.
pub struct WindowedCounter {
    epoch: Instant,
    slots: [AtomicU64; WINDOW_SLOTS],
}

impl Default for WindowedCounter {
    fn default() -> WindowedCounter {
        WindowedCounter::new()
    }
}

impl WindowedCounter {
    pub fn new() -> WindowedCounter {
        WindowedCounter {
            epoch: Instant::now(),
            // slot 0 starts stamped for second 0, count 0 — correct
            slots: std::array::from_fn(|_| AtomicU64::new(0)),
        }
    }

    #[inline]
    fn now_sec(&self) -> u64 {
        self.epoch.elapsed().as_secs()
    }

    /// Count `n` events in the current second.
    #[inline]
    pub fn add(&self, n: u64) {
        self.add_at(self.now_sec(), n);
    }

    /// Totals over the trailing windows, ending at the current second.
    pub fn counts(&self) -> WindowCounts {
        self.counts_at(self.now_sec())
    }

    /// Clock-explicit `add` (the testable core; `sec` is seconds since
    /// the counter's epoch, which only ever moves forward).
    pub fn add_at(&self, sec: u64, n: u64) {
        let stamp = sec as u32;
        let slot = &self.slots[(sec as usize) % WINDOW_SLOTS];
        let mut cur = slot.load(Ordering::Relaxed);
        loop {
            let (s, c) = unpack(cur);
            let next = if s == stamp {
                // same second: bump in place (saturating)
                pack(stamp, c.saturating_add(n.min(u32::MAX as u64) as u32))
            } else {
                // recycled slot from an earlier lap: restamp and reset
                pack(stamp, n.min(u32::MAX as u64) as u32)
            };
            match slot.compare_exchange_weak(
                cur,
                next,
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => return,
                Err(now) => cur = now,
            }
        }
    }

    /// Clock-explicit window read: totals over the 1/10/60 seconds
    /// ending at `now_sec` inclusive. Slots whose stamp falls outside a
    /// window (stale laps, future-free by construction) contribute 0.
    pub fn counts_at(&self, now_sec: u64) -> WindowCounts {
        let snap: [u64; WINDOW_SLOTS] =
            std::array::from_fn(|i| self.slots[i].load(Ordering::Relaxed));
        let total_over = |w: u64| -> u64 {
            let lo = now_sec.saturating_sub(w - 1);
            (lo..=now_sec)
                .map(|sec| {
                    let (s, c) = unpack(snap[(sec as usize) % WINDOW_SLOTS]);
                    if s == sec as u32 {
                        c as u64
                    } else {
                        0
                    }
                })
                .sum()
        };
        WindowCounts {
            last_1s: total_over(1),
            last_10s: total_over(10),
            last_60s: total_over(60),
        }
    }
}

/// Point-in-time read of a [`WindowedCounter`]: event totals over the
/// trailing 1s/10s/60s. Plain data — mergeable (bucket totals add, same
/// contract as [`super::HistogramSnapshot::merge`]) and serializable.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct WindowCounts {
    pub last_1s: u64,
    pub last_10s: u64,
    pub last_60s: u64,
}

impl WindowCounts {
    /// Windowwise sum — the cluster roll-up's per-window totals.
    pub fn merge(&self, other: &WindowCounts) -> WindowCounts {
        WindowCounts {
            last_1s: self.last_1s + other.last_1s,
            last_10s: self.last_10s + other.last_10s,
            last_60s: self.last_60s + other.last_60s,
        }
    }

    /// The `windows` block value: raw totals plus derived per-second
    /// rates (`per_s_10s = last_10s / 10`, etc.).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("last_1s", Json::Num(self.last_1s as f64)),
            ("last_10s", Json::Num(self.last_10s as f64)),
            ("last_60s", Json::Num(self.last_60s as f64)),
            ("per_s_1s", Json::Num(self.last_1s as f64)),
            ("per_s_10s", Json::Num(self.last_10s as f64 / 10.0)),
            ("per_s_60s", Json::Num(self.last_60s as f64 / 60.0)),
        ])
    }

    /// Inverse of [`WindowCounts::to_json`] (the derived `per_s_*`
    /// fields are recomputed, not read back).
    pub fn from_json(v: &Json) -> Result<WindowCounts, String> {
        let field = |key: &str| -> Result<u64, String> {
            v.get(key)
                .and_then(|n| n.as_f64())
                .filter(|&n| n >= 0.0 && n.fract() == 0.0)
                .map(|n| n as u64)
                .ok_or_else(|| format!("window: missing or invalid '{key}'"))
        };
        Ok(WindowCounts {
            last_1s: field("last_1s")?,
            last_10s: field("last_10s")?,
            last_60s: field("last_60s")?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counts_cover_exactly_the_trailing_windows() {
        let w = WindowedCounter::new();
        // one event per second for 100 virtual seconds
        for sec in 0..100u64 {
            w.add_at(sec, 1);
        }
        let c = w.counts_at(99);
        assert_eq!(c.last_1s, 1);
        assert_eq!(c.last_10s, 10);
        assert_eq!(c.last_60s, 60);
    }

    #[test]
    fn stale_laps_do_not_leak_into_a_window() {
        let w = WindowedCounter::new();
        w.add_at(5, 1000); // will be lapped by sec 5 + 64
        w.add_at(5 + WINDOW_SLOTS as u64, 7);
        let c = w.counts_at(5 + WINDOW_SLOTS as u64);
        assert_eq!(c.last_1s, 7);
        assert_eq!(c.last_60s, 7, "the lapped bucket must have been reset");
        // and a slot that was never revisited reads as stale, not as a
        // phantom contribution to a much later window
        let later = w.counts_at(5 + 3 * WINDOW_SLOTS as u64);
        assert_eq!(later.last_60s, 0);
    }

    #[test]
    fn same_second_adds_accumulate() {
        let w = WindowedCounter::new();
        for _ in 0..50 {
            w.add_at(3, 2);
        }
        assert_eq!(w.counts_at(3).last_1s, 100);
        assert_eq!(w.counts_at(4).last_1s, 0, "next second starts empty");
        assert_eq!(w.counts_at(4).last_10s, 100);
    }

    #[test]
    fn concurrent_adds_are_all_counted() {
        use std::sync::Arc;
        let w = Arc::new(WindowedCounter::new());
        let threads: Vec<_> = (0..4)
            .map(|_| {
                let w = Arc::clone(&w);
                std::thread::spawn(move || {
                    for _ in 0..10_000 {
                        w.add_at(7, 1);
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        assert_eq!(w.counts_at(7).last_1s, 40_000);
    }

    #[test]
    fn json_round_trips_and_merge_sums() {
        let a = WindowCounts { last_1s: 3, last_10s: 25, last_60s: 120 };
        let b = WindowCounts { last_1s: 1, last_10s: 5, last_60s: 40 };
        let back = WindowCounts::from_json(&a.to_json()).unwrap();
        assert_eq!(back, a);
        let m = a.merge(&b);
        assert_eq!(m, WindowCounts { last_1s: 4, last_10s: 30, last_60s: 160 });
        // derived rates are recomputed from the merged totals
        assert_eq!(
            m.to_json().get("per_s_10s").and_then(|v| v.as_f64()),
            Some(3.0)
        );
        assert!(WindowCounts::from_json(&Json::obj(vec![(
            "last_1s",
            Json::Num(1.0)
        )]))
        .is_err());
    }

    #[test]
    fn wall_clock_entry_points_count_in_the_current_second() {
        let w = WindowedCounter::new();
        w.add(5);
        w.add(2);
        let c = w.counts();
        // the test may straddle a second boundary; the 10s window cannot
        assert_eq!(c.last_10s, 7);
        assert!(c.last_1s <= 7);
    }
}
