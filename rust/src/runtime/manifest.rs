//! Parsing of the AOT outputs' metadata: `manifest.json` (what was
//! lowered, at which shapes, with which io orders) and `golden.json`
//! (the cross-language numeric fixture).

use std::path::Path;

use anyhow::{anyhow, Context, Result};

use crate::util::json::Json;

#[derive(Clone, Debug)]
pub struct ArtifactInfo {
    pub file: String,
    /// "step" (learning stage) or "fwd" (frozen stage)
    pub kind: String,
    pub n_cols: usize,
    pub m: usize,
    pub inputs: Vec<String>,
    pub outputs: Vec<String>,
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub eps: f32,
    pub gate_order: String,
    pub artifacts: Vec<ArtifactInfo>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("read {path:?} — run `make artifacts` first"))?;
        let v = Json::parse(&text).map_err(|e| anyhow!("{path:?}: {e}"))?;
        let arts = v
            .get("artifacts")
            .and_then(|a| a.as_arr())
            .context("manifest: artifacts[]")?;
        let mut artifacts = Vec::with_capacity(arts.len());
        for a in arts {
            artifacts.push(ArtifactInfo {
                file: a.get("file").and_then(|x| x.as_str()).context("file")?.into(),
                kind: a.get("kind").and_then(|x| x.as_str()).context("kind")?.into(),
                n_cols: a.get("n_cols").and_then(|x| x.as_usize()).context("n_cols")?,
                m: a.get("m").and_then(|x| x.as_usize()).context("m")?,
                inputs: a
                    .get("inputs")
                    .and_then(|x| x.as_arr())
                    .context("inputs")?
                    .iter()
                    .filter_map(|s| s.as_str().map(String::from))
                    .collect(),
                outputs: a
                    .get("outputs")
                    .and_then(|x| x.as_arr())
                    .context("outputs")?
                    .iter()
                    .filter_map(|s| s.as_str().map(String::from))
                    .collect(),
            });
        }
        Ok(Self {
            eps: v.get("eps").and_then(|x| x.as_f64()).context("eps")? as f32,
            gate_order: v
                .get("gate_order")
                .and_then(|x| x.as_str())
                .context("gate_order")?
                .into(),
            artifacts,
        })
    }
}

/// One tensor of the golden fixture.
#[derive(Clone, Debug)]
pub struct GoldenTensor {
    pub shape: Vec<i64>,
    pub data: Vec<f32>,
}

#[derive(Clone, Debug)]
pub struct GoldenCase {
    pub inputs: Vec<GoldenTensor>,
    pub outputs: Vec<GoldenTensor>,
}

#[derive(Clone, Debug)]
pub struct Golden {
    pub n_cols: usize,
    pub m: usize,
    pub eps: f32,
    pub step: GoldenCase,
    pub fwd: GoldenCase,
}

fn parse_tensors(v: &Json) -> Result<Vec<GoldenTensor>> {
    v.as_arr()
        .context("tensor list")?
        .iter()
        .map(|t| {
            Ok(GoldenTensor {
                shape: t
                    .get("shape")
                    .and_then(|s| s.as_arr())
                    .context("shape")?
                    .iter()
                    .filter_map(|x| x.as_f64().map(|f| f as i64))
                    .collect(),
                data: t
                    .get("data")
                    .and_then(|d| d.to_f32_vec())
                    .context("data")?,
            })
        })
        .collect()
}

fn parse_case(v: &Json) -> Result<GoldenCase> {
    Ok(GoldenCase {
        inputs: parse_tensors(v.get("inputs").context("inputs")?)?,
        outputs: parse_tensors(v.get("outputs").context("outputs")?)?,
    })
}

impl Golden {
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("golden.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("read {path:?} — run `make artifacts` first"))?;
        let v = Json::parse(&text).map_err(|e| anyhow!("{path:?}: {e}"))?;
        Ok(Self {
            n_cols: v.get("n_cols").and_then(|x| x.as_usize()).context("n_cols")?,
            m: v.get("m").and_then(|x| x.as_usize()).context("m")?,
            eps: v.get("eps").and_then(|x| x.as_f64()).context("eps")? as f32,
            step: parse_case(v.get("step").context("step")?)?,
            fwd: parse_case(v.get("fwd").context("fwd")?)?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> Option<std::path::PathBuf> {
        let dir = std::path::PathBuf::from("artifacts");
        if dir.join("manifest.json").exists() {
            Some(dir)
        } else {
            None
        }
    }

    #[test]
    fn manifest_parses_when_present() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: artifacts/ not built");
            return;
        };
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.gate_order, "ifog");
        assert!(m.artifacts.len() >= 10);
        // every referenced file exists
        for a in &m.artifacts {
            assert!(dir.join(&a.file).exists(), "{} missing", a.file);
            assert!(a.kind == "step" || a.kind == "fwd");
            assert!(a.n_cols > 0 && a.m > 0);
        }
        // the paper's configurations are covered
        assert!(m.artifacts.iter().any(|a| a.n_cols == 5 && a.m == 7));
        assert!(m.artifacts.iter().any(|a| a.n_cols == 7 && a.m == 277));
    }

    #[test]
    fn golden_parses_when_present() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: artifacts/ not built");
            return;
        };
        let g = Golden::load(&dir).unwrap();
        assert_eq!(g.n_cols, 3);
        assert_eq!(g.m, 4);
        assert_eq!(g.step.inputs.len(), 14);
        assert_eq!(g.step.outputs.len(), 12);
        assert_eq!(g.fwd.inputs.len(), 8);
        assert_eq!(g.fwd.outputs.len(), 6);
        // shapes coherent: w is [3, 4, 4]
        assert_eq!(g.step.inputs[1].shape, vec![3, 4, 4]);
        assert_eq!(g.step.inputs[1].data.len(), 48);
    }
}
