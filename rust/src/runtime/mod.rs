//! PJRT runtime: load and execute the JAX/Pallas AOT artifacts from Rust.
//!
//! `make artifacts` lowers the Layer-2 model (which calls the Layer-1
//! Pallas kernel) to **HLO text** files plus a `manifest.json`; this
//! module compiles them on the PJRT CPU client (`xla` crate) and exposes
//! [`PjrtColumnarStage`] — a stage of LSTM columns whose forward + RTRL
//! trace update runs inside XLA rather than in native Rust. Python never
//! runs at this point; the Rust binary is self-contained.
//!
//! The interchange is HLO *text*, not serialized protos: jax >= 0.5 emits
//! 64-bit instruction ids that xla_extension 0.5.1 rejects, while the
//! text parser reassigns ids (see DESIGN.md and /opt/xla-example).
//!
//! Numerical parity with the native path ([`crate::nets::lstm_column`])
//! is enforced two ways: the `golden.json` cross-language fixture written
//! by `aot.py`, and step-by-step native-vs-PJRT comparisons in
//! `rust/tests/pjrt_parity.rs`.

pub mod manifest;
pub mod stage;

pub use manifest::{ArtifactInfo, Golden, Manifest};
pub use stage::PjrtColumnarStage;

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use anyhow::{anyhow, Context, Result};

/// Compiled-executable cache over the artifact directory.
pub struct PjrtRuntime {
    client: xla::PjRtClient,
    dir: PathBuf,
    pub manifest: Manifest,
    cache: Mutex<HashMap<String, xla::PjRtLoadedExecutable>>,
}

impl PjrtRuntime {
    /// Load the manifest and connect the PJRT CPU client.
    pub fn load(dir: &Path) -> Result<Self> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow!("pjrt cpu client: {e:?}"))?;
        Ok(Self {
            client,
            dir: dir.to_path_buf(),
            manifest,
            cache: Mutex::new(HashMap::new()),
        })
    }

    /// Default artifact directory (env override: CCN_ARTIFACTS).
    pub fn default_dir() -> PathBuf {
        std::env::var("CCN_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Find the artifact for (kind, n_cols, m) if it was lowered.
    pub fn find(&self, kind: &str, n_cols: usize, m: usize) -> Option<ArtifactInfo> {
        self.manifest
            .artifacts
            .iter()
            .find(|a| a.kind == kind && a.n_cols == n_cols && a.m == m)
            .cloned()
    }

    /// Execute an artifact with f32 inputs of the given shapes; returns the
    /// flattened f32 outputs (the lowered functions return one tuple).
    pub fn execute(
        &self,
        file: &str,
        inputs: &[(&[f32], &[i64])],
    ) -> Result<Vec<Vec<f32>>> {
        // compile (or fetch) under the lock, then clone the handle out —
        // PjRtLoadedExecutable is a shared handle into the client.
        {
            let mut cache = self.cache.lock().unwrap();
            if !cache.contains_key(file) {
                let path = self.dir.join(file);
                let proto = xla::HloModuleProto::from_text_file(
                    path.to_str().context("artifact path utf8")?,
                )
                .map_err(|e| anyhow!("parse {file}: {e:?}"))?;
                let comp = xla::XlaComputation::from_proto(&proto);
                let exe = self
                    .client
                    .compile(&comp)
                    .map_err(|e| anyhow!("compile {file}: {e:?}"))?;
                cache.insert(file.to_string(), exe);
            }
        }
        let cache = self.cache.lock().unwrap();
        let exe = cache.get(file).unwrap();

        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|(data, shape)| {
                let lit = xla::Literal::vec1(data);
                if shape.len() == 1 && shape[0] as usize == data.len() {
                    Ok(lit)
                } else {
                    lit.reshape(shape)
                        .map_err(|e| anyhow!("reshape {shape:?}: {e:?}"))
                }
            })
            .collect::<Result<_>>()?;

        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("execute {file}: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch result: {e:?}"))?;
        let parts = result
            .to_tuple()
            .map_err(|e| anyhow!("untuple: {e:?}"))?;
        parts
            .into_iter()
            .map(|lit| lit.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e:?}")))
            .collect()
    }

    /// Run the cross-language golden check written by `aot.py`: execute the
    /// c3/m4 step and fwd artifacts on the recorded inputs and compare all
    /// outputs against what JAX computed at build time.
    pub fn verify_golden(&self) -> Result<()> {
        let golden = Golden::load(&self.dir)?;
        for (kind, case) in [("step", &golden.step), ("fwd", &golden.fwd)] {
            let art = self
                .find(kind, golden.n_cols, golden.m)
                .with_context(|| format!("no {kind} artifact for golden shape"))?;
            let inputs: Vec<(&[f32], &[i64])> = case
                .inputs
                .iter()
                .map(|t| (t.data.as_slice(), t.shape.as_slice()))
                .collect();
            let outputs = self.execute(&art.file, &inputs)?;
            if outputs.len() != case.outputs.len() {
                return Err(anyhow!(
                    "{kind}: {} outputs, expected {}",
                    outputs.len(),
                    case.outputs.len()
                ));
            }
            for (idx, (got, want)) in outputs.iter().zip(&case.outputs).enumerate() {
                if got.len() != want.data.len() {
                    return Err(anyhow!("{kind} output {idx}: length mismatch"));
                }
                for (a, b) in got.iter().zip(&want.data) {
                    if (a - b).abs() > 2e-5 * (1.0 + b.abs()) {
                        return Err(anyhow!(
                            "{kind} output {idx}: {a} != {b} (golden)"
                        ));
                    }
                }
            }
        }
        Ok(())
    }
}
