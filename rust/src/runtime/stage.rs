//! A stage of LSTM columns whose compute runs through PJRT (the
//! XLA-compiled JAX/Pallas artifact) instead of native Rust.
//!
//! Holds parameters, state, RTRL traces and normalizer statistics as flat
//! host vectors; every `step`/`step_frozen` round-trips them through the
//! compiled executable. This is deliberately the *same* state layout as
//! the Python model, so the golden fixture and the native Rust columns
//! can both be compared element-for-element.

use anyhow::{anyhow, Result};

use super::PjrtRuntime;
use crate::nets::lstm_column::LstmColumn;
use crate::util::prng::Xoshiro256;

pub struct PjrtColumnarStage<'rt> {
    rt: &'rt PjrtRuntime,
    step_file: String,
    fwd_file: String,
    pub n_cols: usize,
    pub m: usize,
    // parameters
    pub w: Vec<f32>,   // [C*4*m]
    pub u: Vec<f32>,   // [C*4]
    pub b: Vec<f32>,   // [C*4]
    // state
    pub h: Vec<f32>,   // [C]
    pub c: Vec<f32>,   // [C]
    pub thw: Vec<f32>, // [C*4*m]
    pub tcw: Vec<f32>,
    pub thu: Vec<f32>, // [C*4]
    pub tcu: Vec<f32>,
    pub thb: Vec<f32>,
    pub tcb: Vec<f32>,
    pub mu: Vec<f32>,  // [C]
    pub var: Vec<f32>, // [C]
    // latest normalized output
    pub h_norm: Vec<f32>,
    pub denom: Vec<f32>,
}

impl<'rt> PjrtColumnarStage<'rt> {
    /// Create a stage over an (n_cols, m) artifact pair from the manifest.
    pub fn new(rt: &'rt PjrtRuntime, n_cols: usize, m: usize, seed: u64) -> Result<Self> {
        let step = rt
            .find("step", n_cols, m)
            .ok_or_else(|| anyhow!("no step artifact for c{n_cols} m{m}"))?;
        let fwd = rt
            .find("fwd", n_cols, m)
            .ok_or_else(|| anyhow!("no fwd artifact for c{n_cols} m{m}"))?;
        let mut rng = Xoshiro256::seed_from_u64(seed ^ 0x706a_7274); // "pjrt"
        let w = (0..n_cols * 4 * m).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let u = (0..n_cols * 4).map(|_| rng.uniform(-1.0, 1.0)).collect();
        Ok(Self {
            rt,
            step_file: step.file,
            fwd_file: fwd.file,
            n_cols,
            m,
            w,
            u,
            b: vec![0.0; n_cols * 4],
            h: vec![0.0; n_cols],
            c: vec![0.0; n_cols],
            thw: vec![0.0; n_cols * 4 * m],
            tcw: vec![0.0; n_cols * 4 * m],
            thu: vec![0.0; n_cols * 4],
            tcu: vec![0.0; n_cols * 4],
            thb: vec![0.0; n_cols * 4],
            tcb: vec![0.0; n_cols * 4],
            mu: vec![0.0; n_cols],
            var: vec![1.0; n_cols],
            h_norm: vec![0.0; n_cols],
            denom: vec![1.0; n_cols],
        })
    }

    /// Copy parameters from native columns (parity tests).
    pub fn set_params_from_columns(&mut self, cols: &[LstmColumn]) {
        assert_eq!(cols.len(), self.n_cols);
        for (k, col) in cols.iter().enumerate() {
            assert_eq!(col.m, self.m);
            self.w[k * 4 * self.m..(k + 1) * 4 * self.m].copy_from_slice(&col.w);
            for a in 0..4 {
                self.u[k * 4 + a] = col.u[a];
                self.b[k * 4 + a] = col.b[a];
            }
        }
    }

    fn shapes(&self) -> ([i64; 1], [i64; 3], [i64; 2], [i64; 1]) {
        (
            [self.m as i64],
            [self.n_cols as i64, 4, self.m as i64],
            [self.n_cols as i64, 4],
            [self.n_cols as i64],
        )
    }

    /// Learning step: forward + RTRL traces + normalizer, all in XLA.
    pub fn step(&mut self, x: &[f32]) -> Result<()> {
        assert_eq!(x.len(), self.m);
        let (sx, s3, s2, s1) = self.shapes();
        let outputs = self.rt.execute(
            &self.step_file,
            &[
                (x, &sx),
                (&self.w, &s3),
                (&self.u, &s2),
                (&self.b, &s2),
                (&self.h, &s1),
                (&self.c, &s1),
                (&self.thw, &s3),
                (&self.tcw, &s3),
                (&self.thu, &s2),
                (&self.tcu, &s2),
                (&self.thb, &s2),
                (&self.tcb, &s2),
                (&self.mu, &s1),
                (&self.var, &s1),
            ],
        )?;
        // outputs: h2 c2 thw2 tcw2 thu2 tcu2 thb2 tcb2 mu2 var2 h_norm denom
        let [h2, c2, thw2, tcw2, thu2, tcu2, thb2, tcb2, mu2, var2, h_norm, denom]: [Vec<f32>; 12] =
            outputs
                .try_into()
                .map_err(|_| anyhow!("step artifact returned wrong arity"))?;
        self.h = h2;
        self.c = c2;
        self.thw = thw2;
        self.tcw = tcw2;
        self.thu = thu2;
        self.tcu = tcu2;
        self.thb = thb2;
        self.tcb = tcb2;
        self.mu = mu2;
        self.var = var2;
        self.h_norm = h_norm;
        self.denom = denom;
        Ok(())
    }

    /// Frozen step: forward + normalizer only.
    pub fn step_frozen(&mut self, x: &[f32]) -> Result<()> {
        assert_eq!(x.len(), self.m);
        let (sx, s3, s2, s1) = self.shapes();
        let outputs = self.rt.execute(
            &self.fwd_file,
            &[
                (x, &sx),
                (&self.w, &s3),
                (&self.u, &s2),
                (&self.b, &s2),
                (&self.h, &s1),
                (&self.c, &s1),
                (&self.mu, &s1),
                (&self.var, &s1),
            ],
        )?;
        let [h2, c2, mu2, var2, h_norm, denom]: [Vec<f32>; 6] = outputs
            .try_into()
            .map_err(|_| anyhow!("fwd artifact returned wrong arity"))?;
        self.h = h2;
        self.c = c2;
        self.mu = mu2;
        self.var = var2;
        self.h_norm = h_norm;
        self.denom = denom;
        Ok(())
    }

    /// dy/dtheta for column k with readout weight w_k (same contract as
    /// the native path): scale = w_k / denom_k, layout [W | u | b].
    pub fn write_grad(&self, k: usize, w_k: f32, out: &mut [f32]) {
        let per = 4 * self.m + 8;
        assert_eq!(out.len(), per);
        let scale = w_k / self.denom[k];
        let base = k * 4 * self.m;
        for j in 0..4 * self.m {
            out[j] = scale * self.thw[base + j];
        }
        for a in 0..4 {
            out[4 * self.m + a] = scale * self.thu[k * 4 + a];
            out[4 * self.m + 4 + a] = scale * self.thb[k * 4 + a];
        }
    }
}
