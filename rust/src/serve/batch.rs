//! Batched structure-of-arrays columnar stepping — the serving hot path.
//!
//! The paper's structural trick (columns are independent modules, so RTRL
//! factorizes per column) is also a *batching* opportunity: B independent
//! columns with the same input width can be advanced in one pass over
//! lane-interleaved arrays, turning the per-column scalar recurrences into
//! vectorizable inner loops across lanes.
//!
//! Two layers live here:
//!
//! - [`BatchedColumnStepper`]: B·d independent LSTM columns in SoA form,
//!   advanced with full RTRL traces in one cache-friendly pass.
//!   Numerically **identical** to [`LstmColumn::step_with_traces`] lane
//!   by lane — every per-lane floating-point expression is evaluated in
//!   the same order as the scalar code, so parity is exact, not
//!   approximate.
//! - [`ColumnarSessionBatch`]: B complete TD(lambda) *sessions* (columnar
//!   net + online normalizer + readout + both eligibility traces) over a
//!   shared spec, stepped together. Sessions enter and leave a batch as
//!   [`ColumnarLane`] bundles (used by the shard layer and by snapshots).
//! - [`StagedSessionBatch`]: the same for constructive/CCN sessions
//!   mid-growth — one stepper per materialized stage (frozen stages
//!   forward-only, the learning stage with RTRL traces), grouped into
//!   **stage-keyed cohorts**: every session in a batch is at the same
//!   learning stage, and a session whose stage clock crosses
//!   `steps_per_stage` is reported pending so the shard layer can hop it
//!   to the next stage's cohort via the same O(lane) membership ops.
//!   Interchange format: [`StagedLane`].
//!
//! # Capacity-padded lane strides
//!
//! All lane-innermost arrays are allocated at a fixed session
//! **capacity**, not at the current session count: a per-column row is a
//! `cap`-entry chunk of which only the first `active` entries are live
//! (layout `[gate][j][column][cap]`, lane `l = k * cap + b` for column
//! `k` of session slot `b`). Because the stride is the capacity, it is
//! **invariant across membership changes**, which makes both membership
//! ops O(one lane's state) instead of O(the whole batch):
//!
//! - [`ColumnarSessionBatch::push_lane`] writes one session's columns
//!   into slot `active` in place and bumps the count — no other lane
//!   moves. When the batch is full, capacity doubles first: a re-stride
//!   that relocates every live lane bit-for-bit, paid amortized O(1)
//!   per insertion;
//! - [`ColumnarSessionBatch::swap_remove_lane`] copies exactly the last
//!   session's lanes over the removed slot and decrements the count.
//!
//! Invariants:
//!
//! - **Dense prefix**: live sessions always occupy slots `0..active` of
//!   every chunk — swap-remove compaction keeps the prefix dense, so the
//!   occupancy mask is implicit (`slot < active` ⇔ live) and the hot
//!   loops simply iterate `0..active` within each `cap`-strided chunk.
//! - **Padding is dead**: slots `active..cap` hold stale bytes that are
//!   never read; every write path ([`BatchedColumnStepper::load_lane`] +
//!   the lane write in `push_lane`) rewrites a slot completely before it
//!   becomes live again.
//! - **Bit-exact moves**: grow, shrink ([`ColumnarSessionBatch::compact`])
//!   and swap-remove copy f32 values verbatim — a session's trajectory
//!   is unaffected by where (or at what stride) its lanes live. The
//!   membership-churn property test pins this down against scalar
//!   agents and against a `from_lanes`-rebuilt twin.
//!
//! `compact()` re-strides the arrays down to twice the live count (so
//! the next insertion still lands in padding instead of forcing an
//! immediate regrow). It is the one deliberately O(batch state)
//! operation and runs only on cold paths: the shard layer invokes it
//! after a removal leaves a batch at ≤ 1/4 occupancy, so a drained
//! population does not pin its high-water-mark allocation, while
//! steady-state churn never re-strides at all.
//!
//! Observations enter the stepper in the same padded layout (`[m][cap]`,
//! live prefix `active`), so the innermost loops run over equal-length
//! contiguous slices and stay vectorizable exactly as before.

use crate::learn::{TdConfig, TdState};
use crate::nets::lstm_column::LstmColumn;
use crate::util::{dot, sigmoid};

/// Smallest non-zero capacity `push_lane` grows to: batches churn from
/// empty constantly (the shard layer creates them on first placement),
/// so skip the 1→2→4 doubling steps.
const MIN_CAPACITY: usize = 4;

/// Re-stride `v` — a sequence of `chunks` equal chunks of `old_cap`
/// entries — to `new_cap`-entry chunks, preserving each chunk's first
/// `live` entries bit-for-bit and zero-filling the rest. Works in both
/// directions (grow and compact).
fn restride(v: &mut Vec<f32>, chunks: usize, old_cap: usize, new_cap: usize, live: usize) {
    debug_assert_eq!(v.len(), chunks * old_cap);
    debug_assert!(live <= old_cap && live <= new_cap);
    let mut next = vec![0.0f32; chunks * new_cap];
    for ch in 0..chunks {
        let (s, d) = (ch * old_cap, ch * new_cap);
        next[d..d + live].copy_from_slice(&v[s..s + live]);
    }
    *v = next;
}

/// B·d independent LSTM columns in structure-of-arrays form.
///
/// `batch` live sessions × `groups` columns each, padded to a `cap`
/// session capacity; all columns share input width `m`. Lane
/// `l = k * cap + b` is column `k` of session slot `b` (`b < batch`),
/// and a step consumes one observation per *session* (shape `[m][cap]`,
/// slot-innermost with live prefix `batch`), broadcast across that
/// session's column group. `groups == 1` gives B fully independent
/// columns, each with its own input — the configuration the parity
/// property tests exercise.
pub struct BatchedColumnStepper {
    m: usize,
    /// live sessions (dense prefix of every chunk)
    batch: usize,
    /// session capacity — the stride unit; invariant across membership
    cap: usize,
    groups: usize,
    /// input weights `[4][m][groups][cap]`, lane-innermost
    pub(super) w: Vec<f32>,
    /// recurrent weights `[4][groups][cap]`
    pub(super) u: Vec<f32>,
    /// biases `[4][groups][cap]`
    pub(super) b: Vec<f32>,
    /// hidden / cell state `[groups][cap]`
    pub(super) h: Vec<f32>,
    pub(super) c: Vec<f32>,
    /// RTRL traces, same layouts as the parameters
    pub(super) thw: Vec<f32>,
    pub(super) tcw: Vec<f32>,
    pub(super) thu: Vec<f32>,
    pub(super) tcu: Vec<f32>,
    pub(super) thb: Vec<f32>,
    pub(super) tcb: Vec<f32>,
    // per-lane scratch, reused every step
    z: Vec<f32>, // [4][groups][cap]
    f_gate: Vec<f32>,
    a_coef: Vec<f32>,
    b_coef: Vec<f32>,
    e_coef: Vec<f32>,
    qi: Vec<f32>,
    qf: Vec<f32>,
    qg: Vec<f32>,
    ro: Vec<f32>,
    h_prev: Vec<f32>,
    zero: Vec<f32>,
}

impl BatchedColumnStepper {
    /// A stepper whose capacity equals its live count (no padding slack).
    pub fn new(m: usize, batch: usize, groups: usize) -> Self {
        Self::with_capacity(m, batch, groups, batch)
    }

    /// A stepper padded to `cap` session slots, `batch` of them live.
    pub fn with_capacity(m: usize, batch: usize, groups: usize, cap: usize) -> Self {
        assert!(batch <= cap, "live count {batch} exceeds capacity {cap}");
        let l = cap * groups;
        Self {
            m,
            batch,
            cap,
            groups,
            w: vec![0.0; 4 * m * l],
            u: vec![0.0; 4 * l],
            b: vec![0.0; 4 * l],
            h: vec![0.0; l],
            c: vec![0.0; l],
            thw: vec![0.0; 4 * m * l],
            tcw: vec![0.0; 4 * m * l],
            thu: vec![0.0; 4 * l],
            tcu: vec![0.0; 4 * l],
            thb: vec![0.0; 4 * l],
            tcb: vec![0.0; 4 * l],
            z: vec![0.0; 4 * l],
            f_gate: vec![0.0; l],
            a_coef: vec![0.0; l],
            b_coef: vec![0.0; l],
            e_coef: vec![0.0; l],
            qi: vec![0.0; l],
            qf: vec![0.0; l],
            qg: vec![0.0; l],
            ro: vec![0.0; l],
            h_prev: vec![0.0; l],
            zero: vec![0.0; l],
        }
    }

    pub fn m(&self) -> usize {
        self.m
    }

    pub fn batch(&self) -> usize {
        self.batch
    }

    pub fn groups(&self) -> usize {
        self.groups
    }

    /// Session capacity (the stride unit of every chunk).
    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// Live lanes (`batch * groups`); padding lanes are not counted.
    pub fn lanes(&self) -> usize {
        self.batch * self.groups
    }

    /// Allocated lanes including padding — the stride of a gate row.
    #[inline]
    fn lcap(&self) -> usize {
        self.cap * self.groups
    }

    /// Set the live-session count (callers have already written — or
    /// logically removed — the affected slots).
    pub(super) fn set_batch(&mut self, n: usize) {
        assert!(n <= self.cap, "live count {n} exceeds capacity {}", self.cap);
        self.batch = n;
    }

    /// Copy every per-lane value (parameters, traces, state) from lane
    /// `src` to lane `dst` — the O(lane) primitive behind swap-remove.
    /// Scratch is not copied; it is recomputed inside every step.
    pub(super) fn copy_lane(&mut self, src: usize, dst: usize) {
        let (m, lcap) = (self.m, self.lcap());
        debug_assert!(src < lcap && dst < lcap);
        if src == dst {
            return;
        }
        for p in 0..4 * m {
            let row = p * lcap;
            self.w[row + dst] = self.w[row + src];
            self.thw[row + dst] = self.thw[row + src];
            self.tcw[row + dst] = self.tcw[row + src];
        }
        for a in 0..4 {
            let row = a * lcap;
            self.u[row + dst] = self.u[row + src];
            self.b[row + dst] = self.b[row + src];
            self.thu[row + dst] = self.thu[row + src];
            self.tcu[row + dst] = self.tcu[row + src];
            self.thb[row + dst] = self.thb[row + src];
            self.tcb[row + dst] = self.tcb[row + src];
        }
        self.h[dst] = self.h[src];
        self.c[dst] = self.c[src];
    }

    /// Re-stride every array to a new session capacity (grow or shrink),
    /// preserving the live prefix of each chunk bit-for-bit.
    pub(super) fn set_capacity(&mut self, new_cap: usize) {
        debug_assert!(self.batch <= new_cap);
        let (old, live) = (self.cap, self.batch);
        if new_cap == old {
            return;
        }
        let (m, groups) = (self.m, self.groups);
        restride(&mut self.w, 4 * m * groups, old, new_cap, live);
        restride(&mut self.thw, 4 * m * groups, old, new_cap, live);
        restride(&mut self.tcw, 4 * m * groups, old, new_cap, live);
        restride(&mut self.u, 4 * groups, old, new_cap, live);
        restride(&mut self.b, 4 * groups, old, new_cap, live);
        restride(&mut self.thu, 4 * groups, old, new_cap, live);
        restride(&mut self.tcu, 4 * groups, old, new_cap, live);
        restride(&mut self.thb, 4 * groups, old, new_cap, live);
        restride(&mut self.tcb, 4 * groups, old, new_cap, live);
        restride(&mut self.h, groups, old, new_cap, live);
        restride(&mut self.c, groups, old, new_cap, live);
        // scratch is recomputed every step: reallocate at the new stride
        let l = new_cap * groups;
        self.z = vec![0.0; 4 * l];
        for v in [
            &mut self.f_gate,
            &mut self.a_coef,
            &mut self.b_coef,
            &mut self.e_coef,
            &mut self.qi,
            &mut self.qf,
            &mut self.qg,
            &mut self.ro,
            &mut self.h_prev,
            &mut self.zero,
        ] {
            *v = vec![0.0; l];
        }
        self.cap = new_cap;
    }

    pub fn h(&self, lane: usize) -> f32 {
        self.h[lane]
    }

    pub fn c(&self, lane: usize) -> f32 {
        self.c[lane]
    }

    /// Pack a scalar column (params, state, traces) into lane `lane`
    /// (padded coordinates: `lane = k * capacity + slot`). Writes every
    /// per-lane value, so a stale padding slot becomes fully defined.
    pub fn load_lane(&mut self, lane: usize, col: &LstmColumn) {
        assert_eq!(col.m, self.m, "column width mismatch");
        let (m, l) = (self.m, self.lcap());
        assert!(lane < l);
        for a in 0..4 {
            for j in 0..m {
                let p = a * m + j;
                self.w[p * l + lane] = col.w[p];
                self.thw[p * l + lane] = col.thw[p];
                self.tcw[p * l + lane] = col.tcw[p];
            }
            self.u[a * l + lane] = col.u[a];
            self.b[a * l + lane] = col.b[a];
            self.thu[a * l + lane] = col.thu[a];
            self.tcu[a * l + lane] = col.tcu[a];
            self.thb[a * l + lane] = col.thb[a];
            self.tcb[a * l + lane] = col.tcb[a];
        }
        self.h[lane] = col.h;
        self.c[lane] = col.c;
    }

    /// Unpack lane `lane` back into a scalar column. Unlike
    /// [`Self::load_lane`] (which may target padding about to become
    /// live), reading is only meaningful for live lanes — dead padding
    /// is a bookkeeping bug, caught here instead of returning garbage.
    pub fn extract_lane(&self, lane: usize) -> LstmColumn {
        let (m, l) = (self.m, self.lcap());
        assert!(lane < l);
        assert!(lane % self.cap < self.batch, "lane {lane} is not live");
        let mut col = LstmColumn::zeroed(m);
        for a in 0..4 {
            for j in 0..m {
                let p = a * m + j;
                col.w[p] = self.w[p * l + lane];
                col.thw[p] = self.thw[p * l + lane];
                col.tcw[p] = self.tcw[p * l + lane];
            }
            col.u[a] = self.u[a * l + lane];
            col.b[a] = self.b[a * l + lane];
            col.thu[a] = self.thu[a * l + lane];
            col.tcu[a] = self.tcu[a * l + lane];
            col.thb[a] = self.thb[a * l + lane];
            col.tcb[a] = self.tcb[a * l + lane];
        }
        col.h = self.h[lane];
        col.c = self.c[lane];
        col
    }

    /// Gate pre-activations: `z[a][l] = sum_j w[a][j][l] * x[j][slot]`.
    /// One pass over the weights; the inner loop runs over the live
    /// prefix of each `cap`-strided chunk, contiguous in both `w` and
    /// `x`, so it autovectorizes across the batch exactly as the tight
    /// layout did — padding is skipped, never computed.
    #[inline]
    fn accumulate_gate_preacts(&mut self, x: &[f32]) {
        let (m, bsz, cap, groups) = (self.m, self.batch, self.cap, self.groups);
        let l = cap * groups;
        debug_assert_eq!(x.len(), m * cap);
        // zero only the live windows — padding z slots are never read
        for a in 0..4 {
            let zrow = &mut self.z[a * l..a * l + l];
            for k in 0..groups {
                zrow[k * cap..k * cap + bsz].iter_mut().for_each(|v| *v = 0.0);
            }
        }
        for a in 0..4 {
            for j in 0..m {
                let row = (a * m + j) * l;
                let wrow = &self.w[row..row + l];
                let xrow = &x[j * cap..j * cap + bsz];
                let zrow = &mut self.z[a * l..a * l + l];
                for k in 0..groups {
                    let zs = &mut zrow[k * cap..k * cap + bsz];
                    let ws = &wrow[k * cap..k * cap + bsz];
                    for ((zv, &wv), &xv) in zs.iter_mut().zip(ws).zip(xrow) {
                        *zv += wv * xv;
                    }
                }
            }
        }
    }

    /// Gate activations and the fused trace-recursion coefficients; also
    /// advances `h`/`c`. Mirrors the scalar column expression-for-
    /// expression so lane results are bit-identical. The per-gate rows of
    /// `z`/`u`/`b` are split into slices up front and each group's live
    /// window is resliced once — the lane loop then runs over
    /// equal-length slices with no residual bounds checks and four
    /// independent gate chains per iteration for the scheduler to
    /// overlap.
    #[inline]
    fn activate(&mut self, fill_scratch: bool) {
        let (bsz, cap, groups) = (self.batch, self.cap, self.groups);
        let l = cap * groups;
        let Self {
            z,
            u,
            b,
            h,
            c,
            f_gate,
            a_coef,
            b_coef,
            e_coef,
            qi,
            qf,
            qg,
            ro,
            h_prev: h_prev_buf,
            ..
        } = self;
        let (zi, zrest) = z.split_at(l);
        let (zf, zrest) = zrest.split_at(l);
        let (zo, zg) = zrest.split_at(l);
        let (ui, urest) = u.split_at(l);
        let (uf, urest) = urest.split_at(l);
        let (uo, ug) = urest.split_at(l);
        let (bi, brest) = b.split_at(l);
        let (bf, brest) = brest.split_at(l);
        let (bo, bg) = brest.split_at(l);
        for k in 0..groups {
            let s = k * cap;
            let e = s + bsz;
            let zi = &zi[s..e];
            let zf = &zf[s..e];
            let zo = &zo[s..e];
            let zg = &zg[s..e];
            let ui = &ui[s..e];
            let uf = &uf[s..e];
            let uo = &uo[s..e];
            let ug = &ug[s..e];
            let bi = &bi[s..e];
            let bf = &bf[s..e];
            let bo = &bo[s..e];
            let bg = &bg[s..e];
            let h = &mut h[s..e];
            let c = &mut c[s..e];
            let f_gate = &mut f_gate[s..e];
            let a_coef = &mut a_coef[s..e];
            let b_coef = &mut b_coef[s..e];
            let e_coef = &mut e_coef[s..e];
            let qi = &mut qi[s..e];
            let qf = &mut qf[s..e];
            let qg = &mut qg[s..e];
            let ro = &mut ro[s..e];
            let h_prev_buf = &mut h_prev_buf[s..e];
            for lane in 0..bsz {
                let h_prev = h[lane];
                let c_prev = c[lane];
                let i = sigmoid(zi[lane] + ui[lane] * h_prev + bi[lane]);
                let f = sigmoid(zf[lane] + uf[lane] * h_prev + bf[lane]);
                let o = sigmoid(zo[lane] + uo[lane] * h_prev + bo[lane]);
                let g = (zg[lane] + ug[lane] * h_prev + bg[lane]).tanh();
                let c2 = f * c_prev + i * g;
                let tanh_c2 = c2.tanh();
                let h2 = o * tanh_c2;
                if fill_scratch {
                    let di = i * (1.0 - i);
                    let df = f * (1.0 - f);
                    let do_ = o * (1.0 - o);
                    let dg = 1.0 - g * g;
                    a_coef[lane] = c_prev * df * uf[lane]
                        + i * dg * ug[lane]
                        + g * di * ui[lane];
                    b_coef[lane] = tanh_c2 * do_ * uo[lane];
                    e_coef[lane] = o * (1.0 - tanh_c2 * tanh_c2);
                    qi[lane] = g * di;
                    qf[lane] = c_prev * df;
                    qg[lane] = i * dg;
                    ro[lane] = tanh_c2 * do_;
                    f_gate[lane] = f;
                    h_prev_buf[lane] = h_prev;
                }
                h[lane] = h2;
                c[lane] = c2;
            }
        }
    }

    /// Forward + RTRL trace update for every live lane: the batched twin
    /// of [`LstmColumn::step_with_traces`]. `x` has shape `[m][cap]`
    /// (slot-innermost, live prefix `batch`); session `b`'s observation
    /// feeds all its lanes.
    ///
    /// Per-lane arithmetic is expression-for-expression the scalar
    /// column's, in the same order — the padded stride changes only
    /// *where* a lane's values live, never what each lane computes, and
    /// the lane-exact parity property tests pin that down.
    #[inline]
    pub fn step_traces(&mut self, x: &[f32]) {
        if self.lanes() == 0 {
            return;
        }
        self.accumulate_gate_preacts(x);
        self.activate(true);
        let Self {
            m,
            batch,
            cap,
            groups,
            thw,
            tcw,
            thu,
            tcu,
            thb,
            tcb,
            f_gate,
            a_coef,
            b_coef,
            e_coef,
            qi,
            qf,
            qg,
            ro,
            h_prev,
            zero,
            ..
        } = self;
        let (m, bsz, cap, groups) = (*m, *batch, *cap, *groups);
        let l = cap * groups;
        for a in 0..4 {
            // per-gate direct coefficients into c' (q) and h' (r); only
            // the output gate has an r term, only the others have q.
            let (q, r): (&[f32], &[f32]) = match a {
                0 => (&qi[..], &zero[..]),
                1 => (&qf[..], &zero[..]),
                2 => (&zero[..], &ro[..]),
                _ => (&qg[..], &zero[..]),
            };
            // W traces: direct term x_j. Each (row, group) live window is
            // resliced once so the slot-innermost loop runs over
            // equal-length slices — bounds checks hoist out and the
            // three-term recurrences across lanes are independent, which
            // is what lets the backend vectorize/overlap them.
            for j in 0..m {
                let row = (a * m + j) * l;
                let xrow = &x[j * cap..j * cap + bsz];
                for k in 0..groups {
                    let off = row + k * cap;
                    let lane0 = k * cap;
                    let th_row = &mut thw[off..off + bsz];
                    let tc_row = &mut tcw[off..off + bsz];
                    let fg = &f_gate[lane0..lane0 + bsz];
                    let ac = &a_coef[lane0..lane0 + bsz];
                    let ec = &e_coef[lane0..lane0 + bsz];
                    let bc = &b_coef[lane0..lane0 + bsz];
                    let qs = &q[lane0..lane0 + bsz];
                    let rs = &r[lane0..lane0 + bsz];
                    for bb in 0..bsz {
                        let xj = xrow[bb];
                        let th_prev = th_row[bb];
                        let tc =
                            fg[bb] * tc_row[bb] + ac[bb] * th_prev + qs[bb] * xj;
                        th_row[bb] = ec[bb] * tc + bc[bb] * th_prev + rs[bb] * xj;
                        tc_row[bb] = tc;
                    }
                }
            }
            // u traces (direct term h(t-1)) and b traces (direct term 1),
            // same reslicing: one gate row's live window per group.
            let row = a * l;
            for k in 0..groups {
                let s = k * cap;
                let thu_row = &mut thu[row + s..row + s + bsz];
                let tcu_row = &mut tcu[row + s..row + s + bsz];
                let thb_row = &mut thb[row + s..row + s + bsz];
                let tcb_row = &mut tcb[row + s..row + s + bsz];
                let fg = &f_gate[s..s + bsz];
                let ac = &a_coef[s..s + bsz];
                let ec = &e_coef[s..s + bsz];
                let bc = &b_coef[s..s + bsz];
                let hp_s = &h_prev[s..s + bsz];
                let qs = &q[s..s + bsz];
                let rs = &r[s..s + bsz];
                for lane in 0..bsz {
                    let hp = hp_s[lane];
                    let th_prev = thu_row[lane];
                    let tc = fg[lane] * tcu_row[lane]
                        + ac[lane] * th_prev
                        + qs[lane] * hp;
                    thu_row[lane] =
                        ec[lane] * tc + bc[lane] * th_prev + rs[lane] * hp;
                    tcu_row[lane] = tc;
                    let thb_prev = thb_row[lane];
                    let tcb_new =
                        fg[lane] * tcb_row[lane] + ac[lane] * thb_prev + qs[lane];
                    thb_row[lane] =
                        ec[lane] * tcb_new + bc[lane] * thb_prev + rs[lane];
                    tcb_row[lane] = tcb_new;
                }
            }
        }
    }

    /// Forward only, no trace bookkeeping (frozen columns).
    pub fn step_forward(&mut self, x: &[f32]) {
        if self.lanes() == 0 {
            return;
        }
        self.accumulate_gate_preacts(x);
        self.activate(false);
    }

    /// Advance a *single* lane with traces: the strided scalar path used
    /// for per-session protocol steps against a batched store. Identical
    /// arithmetic to [`Self::step_traces`], visiting only one lane
    /// (padded coordinates).
    pub fn step_lane_traces(&mut self, lane: usize, x: &[f32]) {
        let (m, l) = (self.m, self.lcap());
        assert!(lane < l);
        assert!(lane % self.cap < self.batch, "lane {lane} is not live");
        debug_assert_eq!(x.len(), m);
        let mut z = [0.0f32; 4];
        for (a, zv) in z.iter_mut().enumerate() {
            let mut acc = 0.0f32;
            for (j, &xj) in x.iter().enumerate() {
                acc += self.w[(a * m + j) * l + lane] * xj;
            }
            *zv = acc;
        }
        let h_prev = self.h[lane];
        let c_prev = self.c[lane];
        let i = sigmoid(z[0] + self.u[lane] * h_prev + self.b[lane]);
        let f = sigmoid(z[1] + self.u[l + lane] * h_prev + self.b[l + lane]);
        let o = sigmoid(z[2] + self.u[2 * l + lane] * h_prev + self.b[2 * l + lane]);
        let g = (z[3] + self.u[3 * l + lane] * h_prev + self.b[3 * l + lane]).tanh();
        let c2 = f * c_prev + i * g;
        let tanh_c2 = c2.tanh();
        let h2 = o * tanh_c2;
        let di = i * (1.0 - i);
        let df = f * (1.0 - f);
        let do_ = o * (1.0 - o);
        let dg = 1.0 - g * g;
        let a_coef = c_prev * df * self.u[l + lane]
            + i * dg * self.u[3 * l + lane]
            + g * di * self.u[lane];
        let b_coef = tanh_c2 * do_ * self.u[2 * l + lane];
        let e_coef = o * (1.0 - tanh_c2 * tanh_c2);
        let q = [g * di, c_prev * df, 0.0, i * dg];
        let r = [0.0, 0.0, tanh_c2 * do_, 0.0];
        for a in 0..4 {
            let (qa, ra) = (q[a], r[a]);
            for (j, &xj) in x.iter().enumerate() {
                let idx = (a * m + j) * l + lane;
                let th_prev = self.thw[idx];
                let tc = f * self.tcw[idx] + a_coef * th_prev + qa * xj;
                self.thw[idx] = e_coef * tc + b_coef * th_prev + ra * xj;
                self.tcw[idx] = tc;
            }
            let idx = a * l + lane;
            let tcu = f * self.tcu[idx] + a_coef * self.thu[idx] + qa * h_prev;
            self.thu[idx] = e_coef * tcu + b_coef * self.thu[idx] + ra * h_prev;
            self.tcu[idx] = tcu;
            let tcb = f * self.tcb[idx] + a_coef * self.thb[idx] + qa;
            self.thb[idx] = e_coef * tcb + b_coef * self.thb[idx] + ra;
            self.tcb[idx] = tcb;
        }
        self.h[lane] = h2;
        self.c[lane] = c2;
    }

    /// Advance a *single* lane forward-only: the strided twin of
    /// [`LstmColumn::step_forward_only`], used for per-session protocol
    /// steps against a frozen stage of a staged cohort. Traces are left
    /// untouched (frozen columns keep their stale trace bytes, exactly
    /// like the scalar path).
    pub fn step_lane_forward(&mut self, lane: usize, x: &[f32]) {
        let (m, l) = (self.m, self.lcap());
        assert!(lane < l);
        assert!(lane % self.cap < self.batch, "lane {lane} is not live");
        debug_assert_eq!(x.len(), m);
        let mut z = [0.0f32; 4];
        for (a, zv) in z.iter_mut().enumerate() {
            let mut acc = 0.0f32;
            for (j, &xj) in x.iter().enumerate() {
                acc += self.w[(a * m + j) * l + lane] * xj;
            }
            *zv = acc;
        }
        let h_prev = self.h[lane];
        let i = sigmoid(z[0] + self.u[lane] * h_prev + self.b[lane]);
        let f = sigmoid(z[1] + self.u[l + lane] * h_prev + self.b[l + lane]);
        let o = sigmoid(z[2] + self.u[2 * l + lane] * h_prev + self.b[2 * l + lane]);
        let g = (z[3] + self.u[3 * l + lane] * h_prev + self.b[3 * l + lane]).tanh();
        let c2 = f * self.c[lane] + i * g;
        self.c[lane] = c2;
        self.h[lane] = o * c2.tanh();
    }
}

/// The shared shape of every session in a [`ColumnarSessionBatch`].
#[derive(Clone, Debug)]
pub struct ColumnarBatchSpec {
    pub n_inputs: usize,
    /// columns (= features) per session
    pub d: usize,
    pub td: TdConfig,
    /// normalizer epsilon
    pub eps: f32,
    /// normalizer beta
    pub beta: f32,
}

/// One session's complete state, extracted from (or insertable into) a
/// batch: the d columns with their traces, the normalizer statistics and
/// the TD(lambda) learning state. This is the interchange format between
/// the batched store, the scalar [`super::session::Session`] path and
/// snapshots — it is stride-independent, so it survives any batch
/// re-layout unchanged.
#[derive(Clone, Debug)]
pub struct ColumnarLane {
    pub columns: Vec<LstmColumn>,
    pub norm_mu: Vec<f32>,
    pub norm_var: Vec<f32>,
    pub norm_denom: Vec<f32>,
    pub td: TdState,
}

/// B columnar TD(lambda) sessions stepped as one SoA batch.
///
/// Per step and per session this performs exactly the scalar pipeline —
/// advance columns with RTRL traces, update/apply the online normalizer,
/// predict through the linear readout, TD-update readout and column
/// parameters, decay both eligibility traces — with every per-session
/// floating-point expression evaluated in the scalar order, so a batched
/// session's trajectory is identical to the same session stepped alone.
///
/// Sessions occupy slots `0..len()` of capacity-padded arrays (see the
/// module docs): [`Self::push_lane`] and [`Self::swap_remove_lane`] are
/// O(one session's state), so membership churn against a large resident
/// batch costs the same as against a small one.
pub struct ColumnarSessionBatch {
    spec: ColumnarBatchSpec,
    stepper: BatchedColumnStepper,
    /// live sessions — slots `0..active` of every padded chunk
    active: usize,
    // normalizer SoA, [d][cap]
    mu: Vec<f32>,
    var: Vec<f32>,
    denom: Vec<f32>,
    feats: Vec<f32>,
    // readout + eligibilities, [d][cap]
    w_out: Vec<f32>,
    e_w: Vec<f32>,
    // theta eligibilities, parallel to the stepper's parameter layout
    ew_w: Vec<f32>, // [4][m][d][cap]
    ew_u: Vec<f32>, // [4][d][cap]
    ew_b: Vec<f32>, // [4][d][cap]
    // per-session TD bookkeeping, [cap]
    y_prev: Vec<f32>,
    have_prev: Vec<bool>,
    steps: Vec<u64>,
    // scratch
    xt: Vec<f32>,      // [n][cap] observation transpose
    ys: Vec<f32>,      // [cap]
    a_delta: Vec<f32>, // [cap]
    scale: Vec<f32>,   // [d][cap]
    wbuf: Vec<f32>,    // [d]
    fbuf: Vec<f32>,    // [d]
}

impl ColumnarSessionBatch {
    /// Expected flat e_theta length for one session under `spec`.
    fn e_theta_len(spec: &ColumnarBatchSpec) -> usize {
        spec.d * LstmColumn::n_params(spec.n_inputs)
    }

    /// An empty batch padded to `cap` session slots.
    pub fn with_capacity(spec: ColumnarBatchSpec, cap: usize) -> Self {
        let (n, d) = (spec.n_inputs, spec.d);
        let l = d * cap;
        Self {
            stepper: BatchedColumnStepper::with_capacity(n, 0, d, cap),
            active: 0,
            mu: vec![0.0; l],
            var: vec![0.0; l],
            denom: vec![0.0; l],
            feats: vec![0.0; l],
            w_out: vec![0.0; l],
            e_w: vec![0.0; l],
            ew_w: vec![0.0; 4 * n * l],
            ew_u: vec![0.0; 4 * l],
            ew_b: vec![0.0; 4 * l],
            y_prev: vec![0.0; cap],
            have_prev: vec![false; cap],
            steps: vec![0; cap],
            xt: vec![0.0; n * cap],
            ys: vec![0.0; cap],
            a_delta: vec![0.0; cap],
            scale: vec![0.0; l],
            wbuf: vec![0.0; d],
            fbuf: vec![0.0; d],
            spec,
        }
    }

    /// Build a batch holding `lanes` sessions (possibly zero), with
    /// capacity exactly `lanes.len()`.
    pub fn from_lanes(
        spec: ColumnarBatchSpec,
        lanes: &[ColumnarLane],
    ) -> Result<Self, String> {
        let mut batch = Self::with_capacity(spec, lanes.len());
        for lane in lanes {
            batch.push_ref(lane)?;
        }
        Ok(batch)
    }

    /// Number of sessions currently in the batch.
    pub fn len(&self) -> usize {
        self.active
    }

    pub fn is_empty(&self) -> bool {
        self.active == 0
    }

    /// Allocated session slots; `capacity() - len()` is padding slack.
    pub fn capacity(&self) -> usize {
        self.stepper.capacity()
    }

    pub fn spec(&self) -> &ColumnarBatchSpec {
        &self.spec
    }

    pub fn session_steps(&self, b: usize) -> u64 {
        debug_assert!(b < self.active);
        self.steps[b]
    }

    /// Check a lane bundle's shape against the batch spec without
    /// touching any state.
    fn validate_lane(&self, lane: &ColumnarLane) -> Result<(), String> {
        let (n, d) = (self.spec.n_inputs, self.spec.d);
        if lane.columns.len() != d {
            return Err(format!("lane has {} columns, want {d}", lane.columns.len()));
        }
        if lane.columns.iter().any(|c| c.m != n) {
            return Err(format!("lane column width != {n}"));
        }
        if lane.norm_mu.len() != d
            || lane.norm_var.len() != d
            || lane.norm_denom.len() != d
        {
            return Err("lane normalizer width mismatch".into());
        }
        if lane.td.w.len() != d || lane.td.e_w.len() != d {
            return Err("lane readout width mismatch".into());
        }
        if lane.td.e_theta.len() != Self::e_theta_len(&self.spec) {
            return Err(format!(
                "lane e_theta length {} != {}",
                lane.td.e_theta.len(),
                Self::e_theta_len(&self.spec)
            ));
        }
        Ok(())
    }

    /// Write one session's complete state into slot `b_` (which may be a
    /// dead padding slot — every field is overwritten). The caller must
    /// have run [`Self::validate_lane`] first (and, in `push_ref`,
    /// before growing — so a rejected lane leaves the batch untouched).
    fn write_lane(&mut self, b_: usize, lane: &ColumnarLane) {
        let (n, d) = (self.spec.n_inputs, self.spec.d);
        let cap = self.capacity();
        let l = d * cap;
        let np = LstmColumn::n_params(n);
        for k in 0..d {
            let ln = k * cap + b_;
            self.stepper.load_lane(ln, &lane.columns[k]);
            self.mu[ln] = lane.norm_mu[k];
            self.var[ln] = lane.norm_var[k];
            self.denom[ln] = lane.norm_denom[k];
            self.w_out[ln] = lane.td.w[k];
            self.e_w[ln] = lane.td.e_w[k];
            // scalar e_theta layout per column: [4n W | 4 u | 4 b]
            let base = k * np;
            for a in 0..4 {
                for j in 0..n {
                    self.ew_w[(a * n + j) * l + ln] =
                        lane.td.e_theta[base + a * n + j];
                }
                self.ew_u[a * l + ln] = lane.td.e_theta[base + 4 * n + a];
                self.ew_b[a * l + ln] = lane.td.e_theta[base + 4 * n + 4 + a];
            }
        }
        self.y_prev[b_] = lane.td.y_prev;
        self.have_prev[b_] = lane.td.have_prev;
        self.steps[b_] = lane.td.steps;
    }

    /// Extract session `b_` as a standalone [`ColumnarLane`] (the batch
    /// is unchanged). O(one session's state) — reads straight out of the
    /// padded arrays; the snapshot/park path never materializes any
    /// other lane.
    pub fn extract_lane(&self, b_: usize) -> ColumnarLane {
        assert!(b_ < self.active, "lane {b_} out of range");
        let (n, d) = (self.spec.n_inputs, self.spec.d);
        let cap = self.capacity();
        let l = d * cap;
        let np = LstmColumn::n_params(n);
        let mut columns = Vec::with_capacity(d);
        let mut norm_mu = Vec::with_capacity(d);
        let mut norm_var = Vec::with_capacity(d);
        let mut norm_denom = Vec::with_capacity(d);
        let mut w = Vec::with_capacity(d);
        let mut e_w = Vec::with_capacity(d);
        let mut e_theta = vec![0.0; d * np];
        for k in 0..d {
            let ln = k * cap + b_;
            columns.push(self.stepper.extract_lane(ln));
            norm_mu.push(self.mu[ln]);
            norm_var.push(self.var[ln]);
            norm_denom.push(self.denom[ln]);
            w.push(self.w_out[ln]);
            e_w.push(self.e_w[ln]);
            let base = k * np;
            for a in 0..4 {
                for j in 0..n {
                    e_theta[base + a * n + j] = self.ew_w[(a * n + j) * l + ln];
                }
                e_theta[base + 4 * n + a] = self.ew_u[a * l + ln];
                e_theta[base + 4 * n + 4 + a] = self.ew_b[a * l + ln];
            }
        }
        ColumnarLane {
            columns,
            norm_mu,
            norm_var,
            norm_denom,
            td: TdState {
                w,
                e_w,
                e_theta,
                y_prev: self.y_prev[b_],
                have_prev: self.have_prev[b_],
                epoch_seen: 1, // columnar nets never change epoch after init
                steps: self.steps[b_],
            },
        }
    }

    pub fn extract_all(&self) -> Vec<ColumnarLane> {
        (0..self.len()).map(|b_| self.extract_lane(b_)).collect()
    }

    /// Add a session in place; returns its slot index. O(one session's
    /// state): the new lane is written into the first padding slot, no
    /// existing lane moves and the stride does not change. When the
    /// batch is full, capacity doubles first (amortized O(1) re-layouts
    /// per insertion).
    pub fn push_lane(&mut self, lane: ColumnarLane) -> Result<usize, String> {
        self.push_ref(&lane)
    }

    fn push_ref(&mut self, lane: &ColumnarLane) -> Result<usize, String> {
        // validate before growing: a rejected lane must not leave a
        // permanently re-strided (and twice as large) batch behind
        self.validate_lane(lane)?;
        if self.active == self.capacity() {
            self.set_capacity((self.capacity() * 2).max(MIN_CAPACITY));
        }
        let b_ = self.active;
        self.write_lane(b_, lane);
        self.active += 1;
        self.stepper.set_batch(self.active);
        Ok(b_)
    }

    /// Remove session `idx` in place, returning it. The **last** session
    /// is copied into slot `idx` (swap-remove) — callers owning an
    /// id→lane map must re-key that moved session. O(one session's
    /// state): exactly one lane is extracted and at most one copied; no
    /// re-layout, no allocation beyond the returned bundle.
    pub fn swap_remove_lane(&mut self, idx: usize) -> Result<ColumnarLane, String> {
        if idx >= self.active {
            return Err(format!("lane {idx} out of range"));
        }
        let removed = self.extract_lane(idx);
        self.discard_lane(idx)?;
        Ok(removed)
    }

    /// Remove session `idx` in place **without** materializing it: the
    /// evict path, where the state was already snapshotted straight from
    /// the live arrays — same swap-remove mechanics as
    /// [`Self::swap_remove_lane`], zero extraction or allocation.
    pub fn discard_lane(&mut self, idx: usize) -> Result<(), String> {
        if idx >= self.active {
            return Err(format!("lane {idx} out of range"));
        }
        let last = self.active - 1;
        if idx != last {
            self.copy_session(last, idx);
        }
        self.active = last;
        self.stepper.set_batch(last);
        Ok(())
    }

    /// Shrink a sparse batch's padded arrays (slot order preserved,
    /// values copied bit-for-bit, id→lane maps stay valid). Capacity
    /// drops to **twice** the live count (min `MIN_CAPACITY`), not an
    /// exact fit — an exact fit would guarantee the very next
    /// `push_lane` pays an immediate O(batch) re-stride. Deliberately
    /// O(batch state) — run it on cold paths (the shard layer calls it
    /// when a batch drops to ≤ 1/4 occupancy), never per membership op.
    pub fn compact(&mut self) {
        let target = (self.active * 2).max(MIN_CAPACITY);
        if target < self.capacity() {
            self.set_capacity(target);
        }
    }

    /// Re-stride every array to a new session capacity, preserving live
    /// state bit-for-bit and reallocating scratch.
    fn set_capacity(&mut self, new_cap: usize) {
        debug_assert!(new_cap >= self.active);
        let old = self.capacity();
        if new_cap == old {
            return;
        }
        let (n, d) = (self.spec.n_inputs, self.spec.d);
        let live = self.active;
        self.stepper.set_capacity(new_cap);
        restride(&mut self.mu, d, old, new_cap, live);
        restride(&mut self.var, d, old, new_cap, live);
        restride(&mut self.denom, d, old, new_cap, live);
        restride(&mut self.w_out, d, old, new_cap, live);
        restride(&mut self.e_w, d, old, new_cap, live);
        restride(&mut self.ew_w, 4 * n * d, old, new_cap, live);
        restride(&mut self.ew_u, 4 * d, old, new_cap, live);
        restride(&mut self.ew_b, 4 * d, old, new_cap, live);
        restride(&mut self.y_prev, 1, old, new_cap, live);
        self.have_prev.resize(new_cap, false);
        self.steps.resize(new_cap, 0);
        // scratch is fully rewritten inside every step before it is read
        let l = d * new_cap;
        self.feats = vec![0.0; l];
        self.scale = vec![0.0; l];
        self.xt = vec![0.0; n * new_cap];
        self.ys = vec![0.0; new_cap];
        self.a_delta = vec![0.0; new_cap];
    }

    /// Copy every piece of session state (stepper lanes, normalizer,
    /// readout, eligibilities, TD bookkeeping) from slot `src` to slot
    /// `dst` — the O(lane) primitive behind swap-remove.
    fn copy_session(&mut self, src: usize, dst: usize) {
        let (n, d) = (self.spec.n_inputs, self.spec.d);
        let cap = self.capacity();
        let l = d * cap;
        for k in 0..d {
            let (s, t) = (k * cap + src, k * cap + dst);
            self.stepper.copy_lane(s, t);
            self.mu[t] = self.mu[s];
            self.var[t] = self.var[s];
            self.denom[t] = self.denom[s];
            self.w_out[t] = self.w_out[s];
            self.e_w[t] = self.e_w[s];
            for a in 0..4 {
                for j in 0..n {
                    let row = (a * n + j) * l;
                    self.ew_w[row + t] = self.ew_w[row + s];
                }
                let row = a * l;
                self.ew_u[row + t] = self.ew_u[row + s];
                self.ew_b[row + t] = self.ew_b[row + s];
            }
        }
        self.y_prev[dst] = self.y_prev[src];
        self.have_prev[dst] = self.have_prev[src];
        self.steps[dst] = self.steps[src];
    }

    /// Shared normalizer recursion (identical to
    /// [`crate::nets::normalizer::OnlineNormalizer::update_and_normalize`]).
    #[inline]
    fn normalize_lane(&mut self, lane: usize) {
        let beta = self.spec.beta;
        let fv = self.stepper.h[lane];
        let prev_mu = self.mu[lane];
        let mu = beta * prev_mu + (1.0 - beta) * fv;
        let var = beta * self.var[lane] + (1.0 - beta) * (mu - fv) * (prev_mu - fv);
        self.mu[lane] = mu;
        self.var[lane] = var;
        let dn = self.spec.eps.max(var.max(0.0).sqrt());
        self.denom[lane] = dn;
        self.feats[lane] = (fv - mu) / dn;
    }

    /// Readout prediction for session `b_`, gathered into contiguous
    /// buffers so the dot product uses the exact summation order of the
    /// scalar agent's `util::dot`.
    #[inline]
    fn predict_session(&mut self, b_: usize) -> f32 {
        let (d, cap) = (self.spec.d, self.capacity());
        for k in 0..d {
            self.wbuf[k] = self.w_out[k * cap + b_];
            self.fbuf[k] = self.feats[k * cap + b_];
        }
        dot(&self.wbuf, &self.fbuf)
    }

    /// One TD(lambda) step for **all** sessions: `obs` is `[B][n]`
    /// session-major, `cumulants` is `[B]` (`B = len()`, tight — the
    /// padding is internal). Returns the predictions made this step.
    /// This is the serving hot path.
    pub fn step_all(&mut self, obs: &[f32], cumulants: &[f32]) -> &[f32] {
        let (n, d) = (self.spec.n_inputs, self.spec.d);
        let bsz = self.active;
        assert_eq!(obs.len(), n * bsz, "obs shape");
        assert_eq!(cumulants.len(), bsz, "cumulant shape");
        if bsz == 0 {
            return &self.ys[..0];
        }
        let cap = self.capacity();
        let l = d * cap;
        // transpose observations to padded [n][cap] for the SoA kernel
        for j in 0..n {
            for b_ in 0..bsz {
                self.xt[j * cap + b_] = obs[b_ * n + j];
            }
        }
        self.stepper.step_traces(&self.xt);
        for k in 0..d {
            for b_ in 0..bsz {
                self.normalize_lane(k * cap + b_);
            }
        }
        for b_ in 0..bsz {
            self.ys[b_] = self.predict_session(b_);
        }
        let TdConfig {
            alpha,
            gamma,
            lambda,
        } = self.spec.td;
        for b_ in 0..bsz {
            self.a_delta[b_] = if self.have_prev[b_] {
                alpha * (cumulants[b_] + gamma * self.ys[b_] - self.y_prev[b_])
            } else {
                0.0
            };
        }
        // TD update of readout and column parameters (using the
        // eligibilities accumulated through t-1), then trace decay with
        // this step's gradients — the scalar agent's order. Every loop
        // walks the live prefix of each cap-strided chunk.
        for k in 0..d {
            let s = k * cap;
            for b_ in 0..bsz {
                self.w_out[s + b_] += self.a_delta[b_] * self.e_w[s + b_];
            }
        }
        for a in 0..4 {
            for j in 0..n {
                let row = (a * n + j) * l;
                for k in 0..d {
                    let off = row + k * cap;
                    for b_ in 0..bsz {
                        self.stepper.w[off + b_] +=
                            self.a_delta[b_] * self.ew_w[off + b_];
                    }
                }
            }
            let row = a * l;
            for k in 0..d {
                let off = row + k * cap;
                for b_ in 0..bsz {
                    let ad = self.a_delta[b_];
                    self.stepper.u[off + b_] += ad * self.ew_u[off + b_];
                    self.stepper.b[off + b_] += ad * self.ew_b[off + b_];
                }
            }
        }
        let gl = gamma * lambda;
        for k in 0..d {
            let s = k * cap;
            for b_ in 0..bsz {
                self.e_w[s + b_] = gl * self.e_w[s + b_] + self.feats[s + b_];
            }
        }
        // dy/dtheta = (w_k / denom_k) * TH — with the *updated* readout,
        // as in the scalar agent.
        for k in 0..d {
            let s = k * cap;
            for b_ in 0..bsz {
                self.scale[s + b_] = self.w_out[s + b_] / self.denom[s + b_];
            }
        }
        for a in 0..4 {
            for j in 0..n {
                let row = (a * n + j) * l;
                for k in 0..d {
                    let off = row + k * cap;
                    let s = k * cap;
                    for b_ in 0..bsz {
                        self.ew_w[off + b_] = gl * self.ew_w[off + b_]
                            + self.scale[s + b_] * self.stepper.thw[off + b_];
                    }
                }
            }
            let row = a * l;
            for k in 0..d {
                let off = row + k * cap;
                let s = k * cap;
                for b_ in 0..bsz {
                    self.ew_u[off + b_] = gl * self.ew_u[off + b_]
                        + self.scale[s + b_] * self.stepper.thu[off + b_];
                    self.ew_b[off + b_] = gl * self.ew_b[off + b_]
                        + self.scale[s + b_] * self.stepper.thb[off + b_];
                }
            }
        }
        for b_ in 0..bsz {
            self.y_prev[b_] = self.ys[b_];
            self.have_prev[b_] = true;
            self.steps[b_] += 1;
        }
        &self.ys[..bsz]
    }

    /// One TD(lambda) step for a single session (strided path for
    /// per-session protocol requests). Identical arithmetic to
    /// [`Self::step_all`] restricted to session `b_`.
    pub fn step_one(&mut self, b_: usize, x: &[f32], cumulant: f32) -> f32 {
        let (n, d) = (self.spec.n_inputs, self.spec.d);
        assert!(b_ < self.active);
        assert_eq!(x.len(), n, "obs width");
        let cap = self.capacity();
        let l = d * cap;
        for k in 0..d {
            self.stepper.step_lane_traces(k * cap + b_, x);
        }
        for k in 0..d {
            self.normalize_lane(k * cap + b_);
        }
        let y = self.predict_session(b_);
        let TdConfig {
            alpha,
            gamma,
            lambda,
        } = self.spec.td;
        if self.have_prev[b_] {
            let ad = alpha * (cumulant + gamma * y - self.y_prev[b_]);
            for k in 0..d {
                let lane = k * cap + b_;
                self.w_out[lane] += ad * self.e_w[lane];
            }
            for a in 0..4 {
                for j in 0..n {
                    for k in 0..d {
                        let idx = (a * n + j) * l + k * cap + b_;
                        self.stepper.w[idx] += ad * self.ew_w[idx];
                    }
                }
                for k in 0..d {
                    let idx = a * l + k * cap + b_;
                    self.stepper.u[idx] += ad * self.ew_u[idx];
                    self.stepper.b[idx] += ad * self.ew_b[idx];
                }
            }
        }
        let gl = gamma * lambda;
        for k in 0..d {
            let lane = k * cap + b_;
            self.e_w[lane] = gl * self.e_w[lane] + self.feats[lane];
            let scale = self.w_out[lane] / self.denom[lane];
            for a in 0..4 {
                for j in 0..n {
                    let idx = (a * n + j) * l + lane;
                    self.ew_w[idx] =
                        gl * self.ew_w[idx] + scale * self.stepper.thw[idx];
                }
                let idx = a * l + lane;
                self.ew_u[idx] = gl * self.ew_u[idx] + scale * self.stepper.thu[idx];
                self.ew_b[idx] = gl * self.ew_b[idx] + scale * self.stepper.thb[idx];
            }
        }
        self.y_prev[b_] = y;
        self.have_prev[b_] = true;
        self.steps[b_] += 1;
        y
    }

    /// Prediction without learning for one session. The recurrent state,
    /// traces and normalizer advance (exactly like the scalar agent's
    /// `predict_only`), but no TD update happens and the bootstrap
    /// bookkeeping is untouched.
    pub fn predict_one(&mut self, b_: usize, x: &[f32]) -> f32 {
        let (n, d) = (self.spec.n_inputs, self.spec.d);
        assert!(b_ < self.active);
        assert_eq!(x.len(), n, "obs width");
        let cap = self.capacity();
        for k in 0..d {
            self.stepper.step_lane_traces(k * cap + b_, x);
        }
        for k in 0..d {
            self.normalize_lane(k * cap + b_);
        }
        self.predict_session(b_)
    }
}

/// The shared structural shape of every session in a
/// [`StagedSessionBatch`]: a constructive/CCN net mid-growth. All
/// sessions in one cohort are at the **same learning stage** over the
/// same config, so their frozen prefixes have identical layout (widths
/// and input fan-in per stage) and their learning stages are columnar
/// twins — per-lane *values* (parameters, traces, normalizer stats,
/// stage clocks) differ freely.
#[derive(Clone, Debug)]
pub struct StagedBatchSpec {
    pub n_inputs: usize,
    pub features_per_stage: usize,
    pub total_features: usize,
    pub steps_per_stage: u64,
    /// learning-stage index; `stage + 1` stages are materialized
    pub stage: usize,
    /// all features materialized and frozen: no learnable parameters,
    /// no stage clock boundary will ever fire
    pub frozen_forever: bool,
    /// column init scale (the cohort hop constructs next-stage columns)
    pub init_scale: f32,
    pub td: TdConfig,
    /// normalizer epsilon
    pub eps: f32,
    /// normalizer beta
    pub beta: f32,
}

impl StagedBatchSpec {
    pub fn n_stages(&self) -> usize {
        self.stage + 1
    }

    /// Column count of stage `s` (every frozen stage is full width; only
    /// the last stage can be a remainder).
    pub fn stage_width(&self, s: usize) -> usize {
        self.features_per_stage
            .min(self.total_features - self.features_per_stage * s)
    }

    /// Input fan-in of stage `s`: raw inputs + all earlier stages' feats.
    pub fn stage_m(&self, s: usize) -> usize {
        self.n_inputs + self.features_per_stage * s
    }

    /// Materialized feature count (readout width).
    pub fn d(&self) -> usize {
        self.features_per_stage * self.stage + self.stage_width(self.stage)
    }
}

/// One materialized stage of a [`StagedLane`]: its columns (with traces —
/// frozen stages keep their stale trace bytes so snapshots round-trip
/// bit-for-bit) and its online-normalizer statistics.
#[derive(Clone, Debug)]
pub struct StagedLaneStage {
    pub columns: Vec<LstmColumn>,
    pub norm_mu: Vec<f32>,
    pub norm_var: Vec<f32>,
    pub norm_denom: Vec<f32>,
}

/// One staged session's complete state: every materialized stage, the
/// stage clock, the rng that will mint the *next* stage's columns, and
/// the TD(lambda) learning state. Stride-independent interchange format
/// between staged cohorts, the scalar session path and snapshots —
/// exactly like [`ColumnarLane`] for the columnar fast path.
#[derive(Clone, Debug)]
pub struct StagedLane {
    pub stages: Vec<StagedLaneStage>,
    pub steps_in_stage: u64,
    /// captured Xoshiro256 state; consumed only by a cohort hop
    pub rng: [u64; 4],
    pub td: TdState,
}

/// B constructive/CCN TD(lambda) sessions **at the same learning stage**
/// stepped as one SoA batch: one [`BatchedColumnStepper`] per
/// materialized stage (shared session capacity), frozen stages advanced
/// forward-only in a batched pass, the learning stage with full RTRL
/// traces, plus the shared normalizer/readout/eligibility arrays.
///
/// Per step and per session this performs exactly the scalar pipeline —
/// stages advanced in order, each consuming the current-step normalized
/// outputs of the stages before it, then predict/TD-update/trace-decay —
/// with every per-session floating-point expression evaluated in the
/// scalar order, so a batched session's trajectory is bit-identical to
/// the same session stepped alone (the same bar the columnar batch
/// meets).
///
/// What a cohort does **not** do is cross a stage boundary: when a
/// lane's `steps_in_stage` reaches `steps_per_stage` during a step, the
/// lane is reported *pending* ([`Self::pending_lanes`] /
/// [`Self::lane_pending`]) and the owner must immediately hop it —
/// extract, settle the boundary (which consumes the lane's rng exactly
/// like the scalar net would), and push it into the next stage's cohort.
/// Membership uses the same O(lane) capacity-padded mechanics as
/// [`ColumnarSessionBatch`] (see the module docs), which is what makes
/// the hop cheap.
pub struct StagedSessionBatch {
    spec: StagedBatchSpec,
    /// one stepper per materialized stage, all at the same capacity
    steppers: Vec<BatchedColumnStepper>,
    /// live sessions — slots `0..active` of every padded chunk
    active: usize,
    // normalizer SoA over all materialized features, [d][cap]
    mu: Vec<f32>,
    var: Vec<f32>,
    denom: Vec<f32>,
    feats: Vec<f32>,
    // readout + eligibilities over all features, [d][cap]
    w_out: Vec<f32>,
    e_w: Vec<f32>,
    // learning-stage theta eligibilities (empty when frozen_forever),
    // parallel to the learning stepper's parameter layout
    ew_w: Vec<f32>, // [4][m_learn][u_learn][cap]
    ew_u: Vec<f32>, // [4][u_learn][cap]
    ew_b: Vec<f32>, // [4][u_learn][cap]
    // per-session TD + stage bookkeeping, [cap]
    y_prev: Vec<f32>,
    have_prev: Vec<bool>,
    steps: Vec<u64>,
    steps_in_stage: Vec<u64>,
    epoch: Vec<u64>,
    rng: Vec<[u64; 4]>,
    /// slots whose stage clock crossed the boundary in the last step
    pending: Vec<usize>,
    // scratch
    xbuf: Vec<f32>,    // [n + fps*stage][cap] — raw obs + frozen feats
    xone: Vec<f32>,    // [m_learn] single-lane input
    ys: Vec<f32>,      // [cap]
    a_delta: Vec<f32>, // [cap]
    scale: Vec<f32>,   // [u_learn][cap]
    wbuf: Vec<f32>,    // [d]
    fbuf: Vec<f32>,    // [d]
}

impl StagedSessionBatch {
    /// Expected flat e_theta length for one session under `spec`.
    fn e_theta_len(spec: &StagedBatchSpec) -> usize {
        if spec.frozen_forever {
            0
        } else {
            spec.stage_width(spec.stage)
                * LstmColumn::n_params(spec.stage_m(spec.stage))
        }
    }

    /// An empty cohort padded to `cap` session slots.
    pub fn with_capacity(spec: StagedBatchSpec, cap: usize) -> Self {
        let d = spec.d();
        let l = d * cap;
        let (m_l, u_l) = (spec.stage_m(spec.stage), spec.stage_width(spec.stage));
        let ll = u_l * cap;
        let theta = !spec.frozen_forever;
        let steppers = (0..spec.n_stages())
            .map(|s| {
                BatchedColumnStepper::with_capacity(
                    spec.stage_m(s),
                    0,
                    spec.stage_width(s),
                    cap,
                )
            })
            .collect();
        Self {
            steppers,
            active: 0,
            mu: vec![0.0; l],
            var: vec![0.0; l],
            denom: vec![0.0; l],
            feats: vec![0.0; l],
            w_out: vec![0.0; l],
            e_w: vec![0.0; l],
            ew_w: vec![0.0; if theta { 4 * m_l * ll } else { 0 }],
            ew_u: vec![0.0; if theta { 4 * ll } else { 0 }],
            ew_b: vec![0.0; if theta { 4 * ll } else { 0 }],
            y_prev: vec![0.0; cap],
            have_prev: vec![false; cap],
            steps: vec![0; cap],
            steps_in_stage: vec![0; cap],
            epoch: vec![0; cap],
            rng: vec![[0; 4]; cap],
            pending: Vec::new(),
            xbuf: vec![0.0; m_l * cap],
            xone: vec![0.0; m_l],
            ys: vec![0.0; cap],
            a_delta: vec![0.0; cap],
            scale: vec![0.0; ll],
            wbuf: vec![0.0; d],
            fbuf: vec![0.0; d],
            spec,
        }
    }

    /// Build a cohort holding `lanes` sessions (possibly zero), with
    /// capacity exactly `lanes.len()`.
    pub fn from_lanes(
        spec: StagedBatchSpec,
        lanes: &[StagedLane],
    ) -> Result<Self, String> {
        let mut batch = Self::with_capacity(spec, lanes.len());
        for lane in lanes {
            batch.push_ref(lane)?;
        }
        Ok(batch)
    }

    pub fn len(&self) -> usize {
        self.active
    }

    pub fn is_empty(&self) -> bool {
        self.active == 0
    }

    pub fn capacity(&self) -> usize {
        self.steppers[0].capacity()
    }

    pub fn spec(&self) -> &StagedBatchSpec {
        &self.spec
    }

    pub fn session_steps(&self, b_: usize) -> u64 {
        debug_assert!(b_ < self.active);
        self.steps[b_]
    }

    /// Slot `b_`'s stage clock.
    pub fn session_steps_in_stage(&self, b_: usize) -> u64 {
        debug_assert!(b_ < self.active);
        self.steps_in_stage[b_]
    }

    /// Did slot `b_`'s stage clock cross the boundary? A pending lane
    /// must be hopped to the next cohort before its next step.
    pub fn lane_pending(&self, b_: usize) -> bool {
        debug_assert!(b_ < self.active);
        !self.spec.frozen_forever
            && self.steps_in_stage[b_] >= self.spec.steps_per_stage
    }

    /// Slots that crossed the stage boundary during the last
    /// [`Self::step_all`], ascending. Resolve these to session ids
    /// **before** removing any lane — swap-remove renumbers slots.
    pub fn pending_lanes(&self) -> &[usize] {
        &self.pending
    }

    /// Check a lane bundle's shape against the cohort spec without
    /// touching any state.
    fn validate_lane(&self, lane: &StagedLane) -> Result<(), String> {
        let spec = &self.spec;
        if lane.stages.len() != spec.n_stages() {
            return Err(format!(
                "staged lane has {} stages, want {}",
                lane.stages.len(),
                spec.n_stages()
            ));
        }
        for (s, st) in lane.stages.iter().enumerate() {
            let (want_u, want_m) = (spec.stage_width(s), spec.stage_m(s));
            if st.columns.len() != want_u {
                return Err(format!(
                    "staged lane stage {s}: {} columns, want {want_u}",
                    st.columns.len()
                ));
            }
            if st.columns.iter().any(|c| c.m != want_m) {
                return Err(format!("staged lane stage {s}: column width != {want_m}"));
            }
            if st.norm_mu.len() != want_u
                || st.norm_var.len() != want_u
                || st.norm_denom.len() != want_u
            {
                return Err(format!("staged lane stage {s}: normalizer width mismatch"));
            }
        }
        let d = spec.d();
        if lane.td.w.len() != d || lane.td.e_w.len() != d {
            return Err("staged lane readout width mismatch".into());
        }
        if lane.td.e_theta.len() != Self::e_theta_len(spec) {
            return Err(format!(
                "staged lane e_theta length {} != {}",
                lane.td.e_theta.len(),
                Self::e_theta_len(spec)
            ));
        }
        Ok(())
    }

    /// Write one session's complete state into slot `b_` (which may be a
    /// dead padding slot — every field is overwritten). Caller has
    /// validated.
    fn write_lane(&mut self, b_: usize, lane: &StagedLane) {
        let cap = self.capacity();
        let fps = self.spec.features_per_stage;
        let stage = self.spec.stage;
        for (s, st) in lane.stages.iter().enumerate() {
            let width = self.spec.stage_width(s);
            let base = fps * s;
            for k in 0..width {
                let ln = k * cap + b_;
                self.steppers[s].load_lane(ln, &st.columns[k]);
                let fl = (base + k) * cap + b_;
                self.mu[fl] = st.norm_mu[k];
                self.var[fl] = st.norm_var[k];
                self.denom[fl] = st.norm_denom[k];
            }
        }
        let d = self.spec.d();
        for k in 0..d {
            let fl = k * cap + b_;
            self.w_out[fl] = lane.td.w[k];
            self.e_w[fl] = lane.td.e_w[k];
        }
        if !self.spec.frozen_forever {
            let (m_l, u_l) =
                (self.spec.stage_m(stage), self.spec.stage_width(stage));
            let ll = u_l * cap;
            let np = LstmColumn::n_params(m_l);
            for k in 0..u_l {
                let ln = k * cap + b_;
                // scalar e_theta layout per column: [4m W | 4 u | 4 b]
                let bbase = k * np;
                for a in 0..4 {
                    for j in 0..m_l {
                        self.ew_w[(a * m_l + j) * ll + ln] =
                            lane.td.e_theta[bbase + a * m_l + j];
                    }
                    self.ew_u[a * ll + ln] = lane.td.e_theta[bbase + 4 * m_l + a];
                    self.ew_b[a * ll + ln] =
                        lane.td.e_theta[bbase + 4 * m_l + 4 + a];
                }
            }
        }
        self.y_prev[b_] = lane.td.y_prev;
        self.have_prev[b_] = lane.td.have_prev;
        self.steps[b_] = lane.td.steps;
        self.steps_in_stage[b_] = lane.steps_in_stage;
        self.epoch[b_] = lane.td.epoch_seen;
        self.rng[b_] = lane.rng;
    }

    /// Extract session `b_` as a standalone [`StagedLane`] (the cohort is
    /// unchanged). O(one session's state).
    pub fn extract_lane(&self, b_: usize) -> StagedLane {
        assert!(b_ < self.active, "lane {b_} out of range");
        let cap = self.capacity();
        let fps = self.spec.features_per_stage;
        let stage = self.spec.stage;
        let d = self.spec.d();
        let mut stages = Vec::with_capacity(self.spec.n_stages());
        for s in 0..self.spec.n_stages() {
            let width = self.spec.stage_width(s);
            let base = fps * s;
            let mut st = StagedLaneStage {
                columns: Vec::with_capacity(width),
                norm_mu: Vec::with_capacity(width),
                norm_var: Vec::with_capacity(width),
                norm_denom: Vec::with_capacity(width),
            };
            for k in 0..width {
                st.columns.push(self.steppers[s].extract_lane(k * cap + b_));
                let fl = (base + k) * cap + b_;
                st.norm_mu.push(self.mu[fl]);
                st.norm_var.push(self.var[fl]);
                st.norm_denom.push(self.denom[fl]);
            }
            stages.push(st);
        }
        let mut w = Vec::with_capacity(d);
        let mut e_w = Vec::with_capacity(d);
        for k in 0..d {
            let fl = k * cap + b_;
            w.push(self.w_out[fl]);
            e_w.push(self.e_w[fl]);
        }
        let mut e_theta = vec![0.0; Self::e_theta_len(&self.spec)];
        if !self.spec.frozen_forever {
            let (m_l, u_l) =
                (self.spec.stage_m(stage), self.spec.stage_width(stage));
            let ll = u_l * cap;
            let np = LstmColumn::n_params(m_l);
            for k in 0..u_l {
                let ln = k * cap + b_;
                let bbase = k * np;
                for a in 0..4 {
                    for j in 0..m_l {
                        e_theta[bbase + a * m_l + j] =
                            self.ew_w[(a * m_l + j) * ll + ln];
                    }
                    e_theta[bbase + 4 * m_l + a] = self.ew_u[a * ll + ln];
                    e_theta[bbase + 4 * m_l + 4 + a] = self.ew_b[a * ll + ln];
                }
            }
        }
        StagedLane {
            stages,
            steps_in_stage: self.steps_in_stage[b_],
            rng: self.rng[b_],
            td: TdState {
                w,
                e_w,
                e_theta,
                y_prev: self.y_prev[b_],
                have_prev: self.have_prev[b_],
                epoch_seen: self.epoch[b_],
                steps: self.steps[b_],
            },
        }
    }

    pub fn extract_all(&self) -> Vec<StagedLane> {
        (0..self.len()).map(|b_| self.extract_lane(b_)).collect()
    }

    /// Add a session in place; returns its slot index. O(one session's
    /// state) with amortized-O(1) capacity doubling, exactly like
    /// [`ColumnarSessionBatch::push_lane`].
    pub fn push_lane(&mut self, lane: StagedLane) -> Result<usize, String> {
        self.push_ref(&lane)
    }

    fn push_ref(&mut self, lane: &StagedLane) -> Result<usize, String> {
        // validate before growing: a rejected lane must not leave a
        // permanently re-strided batch behind
        self.validate_lane(lane)?;
        if self.active == self.capacity() {
            self.set_capacity((self.capacity() * 2).max(MIN_CAPACITY));
        }
        let b_ = self.active;
        self.write_lane(b_, lane);
        self.active += 1;
        for st in self.steppers.iter_mut() {
            st.set_batch(self.active);
        }
        Ok(b_)
    }

    /// Remove session `idx` in place, returning it (swap-remove: the last
    /// session moves into slot `idx`; callers owning an id→lane map must
    /// re-key the moved session). O(one session's state).
    pub fn swap_remove_lane(&mut self, idx: usize) -> Result<StagedLane, String> {
        if idx >= self.active {
            return Err(format!("lane {idx} out of range"));
        }
        let removed = self.extract_lane(idx);
        self.discard_lane(idx)?;
        Ok(removed)
    }

    /// Remove session `idx` without materializing it (evict path).
    pub fn discard_lane(&mut self, idx: usize) -> Result<(), String> {
        if idx >= self.active {
            return Err(format!("lane {idx} out of range"));
        }
        let last = self.active - 1;
        if idx != last {
            self.copy_session(last, idx);
        }
        self.active = last;
        for st in self.steppers.iter_mut() {
            st.set_batch(last);
        }
        Ok(())
    }

    /// Shrink a sparse cohort to twice its live count (cold path only —
    /// same policy as [`ColumnarSessionBatch::compact`]).
    pub fn compact(&mut self) {
        let target = (self.active * 2).max(MIN_CAPACITY);
        if target < self.capacity() {
            self.set_capacity(target);
        }
    }

    /// Re-stride every array to a new session capacity, preserving live
    /// state bit-for-bit and reallocating scratch.
    fn set_capacity(&mut self, new_cap: usize) {
        debug_assert!(new_cap >= self.active);
        let old = self.capacity();
        if new_cap == old {
            return;
        }
        let live = self.active;
        let d = self.spec.d();
        for st in self.steppers.iter_mut() {
            st.set_capacity(new_cap);
        }
        restride(&mut self.mu, d, old, new_cap, live);
        restride(&mut self.var, d, old, new_cap, live);
        restride(&mut self.denom, d, old, new_cap, live);
        restride(&mut self.w_out, d, old, new_cap, live);
        restride(&mut self.e_w, d, old, new_cap, live);
        if !self.spec.frozen_forever {
            let (m_l, u_l) = (
                self.spec.stage_m(self.spec.stage),
                self.spec.stage_width(self.spec.stage),
            );
            restride(&mut self.ew_w, 4 * m_l * u_l, old, new_cap, live);
            restride(&mut self.ew_u, 4 * u_l, old, new_cap, live);
            restride(&mut self.ew_b, 4 * u_l, old, new_cap, live);
        }
        restride(&mut self.y_prev, 1, old, new_cap, live);
        self.have_prev.resize(new_cap, false);
        self.steps.resize(new_cap, 0);
        self.steps_in_stage.resize(new_cap, 0);
        self.epoch.resize(new_cap, 0);
        self.rng.resize(new_cap, [0; 4]);
        // scratch is fully rewritten inside every step before it is read
        let m_l = self.spec.stage_m(self.spec.stage);
        let u_l = self.spec.stage_width(self.spec.stage);
        self.feats = vec![0.0; d * new_cap];
        self.scale = vec![0.0; u_l * new_cap];
        self.xbuf = vec![0.0; m_l * new_cap];
        self.ys = vec![0.0; new_cap];
        self.a_delta = vec![0.0; new_cap];
    }

    /// Copy every piece of session state from slot `src` to slot `dst` —
    /// the O(lane) primitive behind swap-remove.
    fn copy_session(&mut self, src: usize, dst: usize) {
        let cap = self.capacity();
        let d = self.spec.d();
        for s in 0..self.spec.n_stages() {
            let width = self.spec.stage_width(s);
            for k in 0..width {
                self.steppers[s].copy_lane(k * cap + src, k * cap + dst);
            }
        }
        for k in 0..d {
            let (sl, tl) = (k * cap + src, k * cap + dst);
            self.mu[tl] = self.mu[sl];
            self.var[tl] = self.var[sl];
            self.denom[tl] = self.denom[sl];
            self.w_out[tl] = self.w_out[sl];
            self.e_w[tl] = self.e_w[sl];
        }
        if !self.spec.frozen_forever {
            let (m_l, u_l) = (
                self.spec.stage_m(self.spec.stage),
                self.spec.stage_width(self.spec.stage),
            );
            let ll = u_l * cap;
            for k in 0..u_l {
                let (sl, tl) = (k * cap + src, k * cap + dst);
                for a in 0..4 {
                    for j in 0..m_l {
                        let row = (a * m_l + j) * ll;
                        self.ew_w[row + tl] = self.ew_w[row + sl];
                    }
                    let row = a * ll;
                    self.ew_u[row + tl] = self.ew_u[row + sl];
                    self.ew_b[row + tl] = self.ew_b[row + sl];
                }
            }
        }
        self.y_prev[dst] = self.y_prev[src];
        self.have_prev[dst] = self.have_prev[src];
        self.steps[dst] = self.steps[src];
        self.steps_in_stage[dst] = self.steps_in_stage[src];
        self.epoch[dst] = self.epoch[src];
        self.rng[dst] = self.rng[src];
    }

    /// Readout prediction for session `b_`, gathered into contiguous
    /// buffers so the dot product uses the exact summation order of the
    /// scalar agent's `util::dot`.
    #[inline]
    fn predict_session(&mut self, b_: usize) -> f32 {
        let (d, cap) = (self.spec.d(), self.capacity());
        for k in 0..d {
            self.wbuf[k] = self.w_out[k * cap + b_];
            self.fbuf[k] = self.feats[k * cap + b_];
        }
        dot(&self.wbuf[..d], &self.fbuf[..d])
    }

    /// Advance every live session's net: stages in order, each consuming
    /// the current-step normalized outputs of the stages before it
    /// (paper Figure 2), frozen stages forward-only, the learning stage
    /// with RTRL traces. Observations arrive transposed in `xbuf` rows
    /// `0..n`; this fills `feats` (and the frozen-feat rows of `xbuf`).
    fn advance_all(&mut self, bsz: usize) {
        let cap = self.capacity();
        let Self {
            spec,
            steppers,
            mu,
            var,
            denom,
            feats,
            xbuf,
            ..
        } = self;
        let n = spec.n_inputs;
        let fps = spec.features_per_stage;
        let stage = spec.stage;
        let beta = spec.beta;
        for s in 0..=stage {
            let width = spec.stage_width(s);
            let m_s = spec.stage_m(s);
            let st = &mut steppers[s];
            if s == stage && !spec.frozen_forever {
                st.step_traces(&xbuf[..m_s * cap]);
            } else {
                st.step_forward(&xbuf[..m_s * cap]);
            }
            // normalize this stage's fresh features — the scalar
            // OnlineNormalizer recursion per (feature, session)
            let base = fps * s;
            for k in 0..width {
                let hrow = k * cap;
                let frow = (base + k) * cap;
                for b_ in 0..bsz {
                    let fv = st.h[hrow + b_];
                    let prev_mu = mu[frow + b_];
                    let mu_new = beta * prev_mu + (1.0 - beta) * fv;
                    let var_new = beta * var[frow + b_]
                        + (1.0 - beta) * (mu_new - fv) * (prev_mu - fv);
                    mu[frow + b_] = mu_new;
                    var[frow + b_] = var_new;
                    let dn = spec.eps.max(var_new.max(0.0).sqrt());
                    denom[frow + b_] = dn;
                    feats[frow + b_] = (fv - mu_new) / dn;
                }
            }
            // expose them to the stages after this one
            if s < stage {
                for k in 0..width {
                    let frow = (base + k) * cap;
                    let xrow = (n + base + k) * cap;
                    for b_ in 0..bsz {
                        xbuf[xrow + b_] = feats[frow + b_];
                    }
                }
            }
        }
    }

    /// One TD(lambda) step for **all** sessions: `obs` is `[B][n]`
    /// session-major, `cumulants` is `[B]` (`B = len()`). Returns the
    /// predictions made this step and records which lanes crossed their
    /// stage boundary ([`Self::pending_lanes`]).
    pub fn step_all(&mut self, obs: &[f32], cumulants: &[f32]) -> &[f32] {
        let n = self.spec.n_inputs;
        let bsz = self.active;
        assert_eq!(obs.len(), n * bsz, "obs shape");
        assert_eq!(cumulants.len(), bsz, "cumulant shape");
        self.pending.clear();
        if bsz == 0 {
            return &self.ys[..0];
        }
        let cap = self.capacity();
        let d = self.spec.d();
        let fps = self.spec.features_per_stage;
        let stage = self.spec.stage;
        let theta = !self.spec.frozen_forever;
        // transpose observations to padded [n][cap] for the SoA kernels
        for j in 0..n {
            for b_ in 0..bsz {
                self.xbuf[j * cap + b_] = obs[b_ * n + j];
            }
        }
        self.advance_all(bsz);
        for b_ in 0..bsz {
            self.ys[b_] = self.predict_session(b_);
        }
        let TdConfig {
            alpha,
            gamma,
            lambda,
        } = self.spec.td;
        for b_ in 0..bsz {
            self.a_delta[b_] = if self.have_prev[b_] {
                alpha * (cumulants[b_] + gamma * self.ys[b_] - self.y_prev[b_])
            } else {
                0.0
            };
        }
        // TD update of readout (all features) and of the learning stage's
        // parameters (eligibilities accumulated through t-1), then trace
        // decay with this step's gradients — the scalar agent's order.
        for k in 0..d {
            let s = k * cap;
            for b_ in 0..bsz {
                self.w_out[s + b_] += self.a_delta[b_] * self.e_w[s + b_];
            }
        }
        let (m_l, u_l) = (
            self.spec.stage_m(stage),
            self.spec.stage_width(stage),
        );
        let ll = u_l * cap;
        if theta {
            let Self {
                steppers,
                ew_w,
                ew_u,
                ew_b,
                a_delta,
                ..
            } = self;
            let lst = &mut steppers[stage];
            for a in 0..4 {
                for j in 0..m_l {
                    let row = (a * m_l + j) * ll;
                    for k in 0..u_l {
                        let off = row + k * cap;
                        for b_ in 0..bsz {
                            lst.w[off + b_] += a_delta[b_] * ew_w[off + b_];
                        }
                    }
                }
                let row = a * ll;
                for k in 0..u_l {
                    let off = row + k * cap;
                    for b_ in 0..bsz {
                        let ad = a_delta[b_];
                        lst.u[off + b_] += ad * ew_u[off + b_];
                        lst.b[off + b_] += ad * ew_b[off + b_];
                    }
                }
            }
        }
        let gl = gamma * lambda;
        for k in 0..d {
            let s = k * cap;
            for b_ in 0..bsz {
                self.e_w[s + b_] = gl * self.e_w[s + b_] + self.feats[s + b_];
            }
        }
        if theta {
            // dy/dtheta = (w_k / denom_k) * TH over the learning stage,
            // with the *updated* readout — as in the scalar agent.
            for k in 0..u_l {
                let s = k * cap;
                let fl = (fps * stage + k) * cap;
                for b_ in 0..bsz {
                    self.scale[s + b_] =
                        self.w_out[fl + b_] / self.denom[fl + b_];
                }
            }
            let Self {
                steppers,
                ew_w,
                ew_u,
                ew_b,
                scale,
                ..
            } = self;
            let lst = &steppers[stage];
            for a in 0..4 {
                for j in 0..m_l {
                    let row = (a * m_l + j) * ll;
                    for k in 0..u_l {
                        let off = row + k * cap;
                        let s = k * cap;
                        for b_ in 0..bsz {
                            ew_w[off + b_] = gl * ew_w[off + b_]
                                + scale[s + b_] * lst.thw[off + b_];
                        }
                    }
                }
                let row = a * ll;
                for k in 0..u_l {
                    let off = row + k * cap;
                    let s = k * cap;
                    for b_ in 0..bsz {
                        ew_u[off + b_] = gl * ew_u[off + b_]
                            + scale[s + b_] * lst.thu[off + b_];
                        ew_b[off + b_] = gl * ew_b[off + b_]
                            + scale[s + b_] * lst.thb[off + b_];
                    }
                }
            }
        }
        for b_ in 0..bsz {
            self.y_prev[b_] = self.ys[b_];
            self.have_prev[b_] = true;
            self.steps[b_] += 1;
            self.steps_in_stage[b_] += 1;
            if theta && self.steps_in_stage[b_] >= self.spec.steps_per_stage {
                self.pending.push(b_);
            }
        }
        &self.ys[..bsz]
    }

    /// Advance one session's net through every stage (strided single-lane
    /// path). Mirrors [`Self::advance_all`] for a single slot.
    fn advance_one(&mut self, b_: usize, x: &[f32]) {
        let mut xone = std::mem::take(&mut self.xone);
        let cap = self.capacity();
        let n = self.spec.n_inputs;
        xone[..n].copy_from_slice(x);
        {
            let Self {
                spec,
                steppers,
                mu,
                var,
                denom,
                feats,
                ..
            } = self;
            let fps = spec.features_per_stage;
            let stage = spec.stage;
            let beta = spec.beta;
            for s in 0..=stage {
                let width = spec.stage_width(s);
                let m_s = spec.stage_m(s);
                let st = &mut steppers[s];
                for k in 0..width {
                    let lane = k * cap + b_;
                    if s == stage && !spec.frozen_forever {
                        st.step_lane_traces(lane, &xone[..m_s]);
                    } else {
                        st.step_lane_forward(lane, &xone[..m_s]);
                    }
                }
                let base = fps * s;
                for k in 0..width {
                    let fv = st.h[k * cap + b_];
                    let fl = (base + k) * cap + b_;
                    let prev_mu = mu[fl];
                    let mu_new = beta * prev_mu + (1.0 - beta) * fv;
                    let var_new = beta * var[fl]
                        + (1.0 - beta) * (mu_new - fv) * (prev_mu - fv);
                    mu[fl] = mu_new;
                    var[fl] = var_new;
                    let dn = spec.eps.max(var_new.max(0.0).sqrt());
                    denom[fl] = dn;
                    let f_hat = (fv - mu_new) / dn;
                    feats[fl] = f_hat;
                    if s < stage {
                        xone[n + base + k] = f_hat;
                    }
                }
            }
        }
        self.xone = xone;
    }

    /// One TD(lambda) step for a single session (per-session protocol
    /// requests). Identical arithmetic to [`Self::step_all`] restricted
    /// to slot `b_`. Check [`Self::lane_pending`] afterwards — the lane
    /// must hop before its next step if its stage clock crossed.
    pub fn step_one(&mut self, b_: usize, x: &[f32], cumulant: f32) -> f32 {
        let n = self.spec.n_inputs;
        assert!(b_ < self.active);
        assert_eq!(x.len(), n, "obs width");
        let cap = self.capacity();
        let d = self.spec.d();
        let fps = self.spec.features_per_stage;
        let stage = self.spec.stage;
        let theta = !self.spec.frozen_forever;
        self.advance_one(b_, x);
        let y = self.predict_session(b_);
        let TdConfig {
            alpha,
            gamma,
            lambda,
        } = self.spec.td;
        let (m_l, u_l) = (
            self.spec.stage_m(stage),
            self.spec.stage_width(stage),
        );
        let ll = u_l * cap;
        if self.have_prev[b_] {
            let ad = alpha * (cumulant + gamma * y - self.y_prev[b_]);
            for k in 0..d {
                let lane = k * cap + b_;
                self.w_out[lane] += ad * self.e_w[lane];
            }
            if theta {
                let Self {
                    steppers,
                    ew_w,
                    ew_u,
                    ew_b,
                    ..
                } = self;
                let lst = &mut steppers[stage];
                for a in 0..4 {
                    for j in 0..m_l {
                        for k in 0..u_l {
                            let idx = (a * m_l + j) * ll + k * cap + b_;
                            lst.w[idx] += ad * ew_w[idx];
                        }
                    }
                    for k in 0..u_l {
                        let idx = a * ll + k * cap + b_;
                        lst.u[idx] += ad * ew_u[idx];
                        lst.b[idx] += ad * ew_b[idx];
                    }
                }
            }
        }
        let gl = gamma * lambda;
        for k in 0..d {
            let lane = k * cap + b_;
            self.e_w[lane] = gl * self.e_w[lane] + self.feats[lane];
        }
        if theta {
            let Self {
                steppers,
                ew_w,
                ew_u,
                ew_b,
                w_out,
                denom,
                ..
            } = self;
            let lst = &steppers[stage];
            for k in 0..u_l {
                let fl = (fps * stage + k) * cap + b_;
                let scale = w_out[fl] / denom[fl];
                for a in 0..4 {
                    for j in 0..m_l {
                        let idx = (a * m_l + j) * ll + k * cap + b_;
                        ew_w[idx] = gl * ew_w[idx] + scale * lst.thw[idx];
                    }
                    let idx = a * ll + k * cap + b_;
                    ew_u[idx] = gl * ew_u[idx] + scale * lst.thu[idx];
                    ew_b[idx] = gl * ew_b[idx] + scale * lst.thb[idx];
                }
            }
        }
        self.y_prev[b_] = y;
        self.have_prev[b_] = true;
        self.steps[b_] += 1;
        self.steps_in_stage[b_] += 1;
        y
    }

    /// Prediction without learning for one session: recurrent state,
    /// traces and normalizers advance (exactly like the scalar agent's
    /// `predict_only`), no TD update, bootstrap and stage clocks
    /// untouched.
    pub fn predict_one(&mut self, b_: usize, x: &[f32]) -> f32 {
        let n = self.spec.n_inputs;
        assert!(b_ < self.active);
        assert_eq!(x.len(), n, "obs width");
        self.advance_one(b_, x);
        self.predict_session(b_)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::check;
    use crate::util::prng::Xoshiro256;

    fn random_column(m: usize, rng: &mut Xoshiro256) -> LstmColumn {
        let mut col = LstmColumn::new(m, rng, 0.8);
        // randomize state and traces too, so parity covers warm columns
        col.h = rng.uniform(-0.5, 0.5);
        col.c = rng.uniform(-0.5, 0.5);
        for v in col.thw.iter_mut().chain(col.tcw.iter_mut()) {
            *v = rng.uniform(-0.1, 0.1);
        }
        col
    }

    fn assert_lane_close(cols: &[LstmColumn], stepper: &BatchedColumnStepper, tol: f32) {
        for (lane, col) in cols.iter().enumerate() {
            let got = stepper.extract_lane(lane);
            assert!((got.h - col.h).abs() <= tol, "h: {} vs {}", got.h, col.h);
            assert!((got.c - col.c).abs() <= tol, "c: {} vs {}", got.c, col.c);
            for p in 0..4 * col.m {
                assert!(
                    (got.thw[p] - col.thw[p]).abs() <= tol,
                    "TH[{p}]: {} vs {}",
                    got.thw[p],
                    col.thw[p]
                );
                assert!(
                    (got.tcw[p] - col.tcw[p]).abs() <= tol,
                    "TC[{p}]: {} vs {}",
                    got.tcw[p],
                    col.tcw[p]
                );
            }
            for a in 0..4 {
                assert!((got.thu[a] - col.thu[a]).abs() <= tol);
                assert!((got.tcu[a] - col.tcu[a]).abs() <= tol);
                assert!((got.thb[a] - col.thb[a]).abs() <= tol);
                assert!((got.tcb[a] - col.tcb[a]).abs() <= tol);
            }
        }
    }

    #[test]
    fn load_extract_roundtrip_is_exact() {
        let mut rng = Xoshiro256::seed_from_u64(3);
        let m = 5;
        let cols: Vec<LstmColumn> = (0..6).map(|_| random_column(m, &mut rng)).collect();
        let mut st = BatchedColumnStepper::new(m, 6, 1);
        for (i, c) in cols.iter().enumerate() {
            st.load_lane(i, c);
        }
        for (i, c) in cols.iter().enumerate() {
            let got = st.extract_lane(i);
            assert_eq!(got.w, c.w);
            assert_eq!(got.u, c.u);
            assert_eq!(got.h, c.h);
            assert_eq!(got.thw, c.thw);
            assert_eq!(got.tcb, c.tcb);
        }
    }

    /// The ISSUE's acceptance property: batched == scalar to <= 1e-6 on
    /// h, c, TH, TC over random widths, batch sizes and 100-step
    /// rollouts. (The implementation is expression-for-expression
    /// identical, so this holds exactly; the tolerance is the contract.)
    #[test]
    fn prop_batched_stepper_matches_scalar_columns() {
        check("batched == scalar column stepping", 15, |g| {
            let m = g.sized_usize(1, 9);
            let bsz = g.sized_usize(1, 7);
            let mut rng = Xoshiro256::seed_from_u64(g.rng.next_u64());
            let mut cols: Vec<LstmColumn> =
                (0..bsz).map(|_| random_column(m, &mut rng)).collect();
            let mut st = BatchedColumnStepper::new(m, bsz, 1);
            for (i, c) in cols.iter().enumerate() {
                st.load_lane(i, c);
            }
            for _ in 0..100 {
                // one observation per lane (groups == 1): shape [m][B]
                let xs: Vec<Vec<f32>> = (0..bsz)
                    .map(|_| (0..m).map(|_| rng.uniform(-1.0, 1.0)).collect())
                    .collect();
                let mut xt = vec![0.0f32; m * bsz];
                for (b_, x) in xs.iter().enumerate() {
                    for j in 0..m {
                        xt[j * bsz + b_] = x[j];
                    }
                }
                st.step_traces(&xt);
                for (col, x) in cols.iter_mut().zip(&xs) {
                    col.step_with_traces(x);
                }
            }
            for (lane, col) in cols.iter().enumerate() {
                let got = st.extract_lane(lane);
                let tol = 1e-6f32;
                if (got.h - col.h).abs() > tol || (got.c - col.c).abs() > tol {
                    return Err(format!("state diverged: h {} vs {}", got.h, col.h));
                }
                for p in 0..4 * m {
                    if (got.thw[p] - col.thw[p]).abs() > tol
                        || (got.tcw[p] - col.tcw[p]).abs() > tol
                    {
                        return Err(format!("trace {p} diverged"));
                    }
                }
                for a in 0..4 {
                    if (got.thu[a] - col.thu[a]).abs() > tol
                        || (got.tcu[a] - col.tcu[a]).abs() > tol
                        || (got.thb[a] - col.thb[a]).abs() > tol
                        || (got.tcb[a] - col.tcb[a]).abs() > tol
                    {
                        return Err(format!("u/b trace {a} diverged"));
                    }
                }
            }
            Ok(())
        });
    }

    /// Padding slack must be invisible: a stepper with capacity 8 but
    /// only 3 live lanes steps those lanes bit-identically to the
    /// scalar columns (the padded tail is never computed or read).
    #[test]
    fn padded_slack_keeps_scalar_parity() {
        let (m, live, cap) = (4usize, 3usize, 8usize);
        let mut rng = Xoshiro256::seed_from_u64(21);
        let mut cols: Vec<LstmColumn> =
            (0..live).map(|_| random_column(m, &mut rng)).collect();
        let mut st = BatchedColumnStepper::with_capacity(m, 0, 1, cap);
        assert_eq!(st.capacity(), cap);
        for (i, c) in cols.iter().enumerate() {
            st.load_lane(i, c);
            st.set_batch(i + 1);
        }
        assert_eq!(st.batch(), live);
        for _ in 0..60 {
            let xs: Vec<Vec<f32>> = (0..live)
                .map(|_| (0..m).map(|_| rng.uniform(-1.0, 1.0)).collect())
                .collect();
            // padded observation layout: [m][cap], live prefix filled
            let mut xt = vec![0.0f32; m * cap];
            for (b_, x) in xs.iter().enumerate() {
                for j in 0..m {
                    xt[j * cap + b_] = x[j];
                }
            }
            st.step_traces(&xt);
            for (col, x) in cols.iter_mut().zip(&xs) {
                col.step_with_traces(x);
            }
        }
        assert_lane_close(&cols, &st, 0.0);
    }

    #[test]
    fn grouped_lanes_share_observations() {
        // groups = d > 1: all of a session's columns see the same x.
        let (m, bsz, d) = (4, 3, 2);
        let mut rng = Xoshiro256::seed_from_u64(9);
        let cols: Vec<Vec<LstmColumn>> = (0..bsz)
            .map(|_| (0..d).map(|_| random_column(m, &mut rng)).collect())
            .collect();
        let mut st = BatchedColumnStepper::new(m, bsz, d);
        for (b_, session) in cols.iter().enumerate() {
            for (k, c) in session.iter().enumerate() {
                st.load_lane(k * bsz + b_, c);
            }
        }
        let mut scalar = cols.clone();
        for _ in 0..60 {
            let xs: Vec<Vec<f32>> = (0..bsz)
                .map(|_| (0..m).map(|_| rng.uniform(-1.0, 1.0)).collect())
                .collect();
            let mut xt = vec![0.0f32; m * bsz];
            for (b_, x) in xs.iter().enumerate() {
                for j in 0..m {
                    xt[j * bsz + b_] = x[j];
                }
            }
            st.step_traces(&xt);
            for (b_, session) in scalar.iter_mut().enumerate() {
                for col in session.iter_mut() {
                    col.step_with_traces(&xs[b_]);
                }
            }
        }
        let flat: Vec<LstmColumn> = (0..d)
            .flat_map(|k| (0..bsz).map(move |b_| (k, b_)))
            .map(|(k, b_)| scalar[b_][k].clone())
            .collect();
        assert_lane_close(&flat, &st, 1e-6);
    }

    #[test]
    fn step_lane_matches_full_step() {
        let (m, bsz) = (5, 4);
        let mut rng = Xoshiro256::seed_from_u64(17);
        let cols: Vec<LstmColumn> =
            (0..bsz).map(|_| random_column(m, &mut rng)).collect();
        let mut full = BatchedColumnStepper::new(m, bsz, 1);
        let mut lane_wise = BatchedColumnStepper::new(m, bsz, 1);
        for (i, c) in cols.iter().enumerate() {
            full.load_lane(i, c);
            lane_wise.load_lane(i, c);
        }
        for _ in 0..40 {
            let xs: Vec<Vec<f32>> = (0..bsz)
                .map(|_| (0..m).map(|_| rng.uniform(-1.0, 1.0)).collect())
                .collect();
            let mut xt = vec![0.0f32; m * bsz];
            for (b_, x) in xs.iter().enumerate() {
                for j in 0..m {
                    xt[j * bsz + b_] = x[j];
                }
            }
            full.step_traces(&xt);
            for (b_, x) in xs.iter().enumerate() {
                lane_wise.step_lane_traces(b_, x);
            }
        }
        for lane in 0..bsz {
            let a = full.extract_lane(lane);
            let b = lane_wise.extract_lane(lane);
            assert_eq!(a.h, b.h, "strided single-lane path must match batch");
            assert_eq!(a.thw, b.thw);
            assert_eq!(a.tcu, b.tcu);
        }
    }

    fn fresh_lane(spec: &ColumnarBatchSpec, seed: u64) -> ColumnarLane {
        // a freshly opened session: random columns, unit normalizer
        // stats, zero learning state — exactly what a scalar columnar
        // CcnNet + TdLambdaAgent start from.
        let net = crate::config::build_ccn(
            &crate::config::LearnerKind::Columnar { d: spec.d },
            spec.n_inputs,
            spec.eps,
            seed,
        )
        .unwrap();
        let columns = (0..spec.d).map(|k| net.column(0, k).clone()).collect();
        let (mu, var, denom) = net.stage_norm(0).state();
        ColumnarLane {
            columns,
            norm_mu: mu.to_vec(),
            norm_var: var.to_vec(),
            norm_denom: denom.to_vec(),
            td: TdState {
                w: vec![0.0; spec.d],
                e_w: vec![0.0; spec.d],
                e_theta: vec![0.0; spec.d * LstmColumn::n_params(spec.n_inputs)],
                y_prev: 0.0,
                have_prev: false,
                epoch_seen: 1,
                steps: 0,
            },
        }
    }

    #[test]
    fn batched_sessions_match_scalar_agents_exactly() {
        use crate::config::{build_ccn, LearnerKind};
        use crate::learn::TdLambdaAgent;

        // beta must be NORM_BETA so the scalar twins (built via
        // build_ccn, which hardwires the paper's beta) match the batch.
        let spec = ColumnarBatchSpec {
            n_inputs: 3,
            d: 4,
            td: TdConfig {
                alpha: 0.01,
                gamma: 0.9,
                lambda: 0.9,
            },
            eps: 0.01,
            beta: crate::nets::normalizer::NORM_BETA,
        };
        let bsz = 3;
        let lanes: Vec<ColumnarLane> =
            (0..bsz as u64).map(|s| fresh_lane(&spec, s)).collect();
        let mut batch = ColumnarSessionBatch::from_lanes(spec.clone(), &lanes).unwrap();
        let mut scalars: Vec<TdLambdaAgent<crate::nets::ccn::CcnNet>> = (0..bsz
            as u64)
            .map(|s| {
                let net = build_ccn(
                    &LearnerKind::Columnar { d: spec.d },
                    spec.n_inputs,
                    spec.eps,
                    s,
                )
                .unwrap();
                TdLambdaAgent::new(net, spec.td)
            })
            .collect();
        let mut rng = Xoshiro256::seed_from_u64(5);
        for t in 0..300 {
            let obs: Vec<f32> = (0..bsz * spec.n_inputs)
                .map(|_| rng.uniform(-1.0, 1.0))
                .collect();
            let cs: Vec<f32> = (0..bsz).map(|_| rng.uniform(-0.5, 0.5)).collect();
            let ys = batch.step_all(&obs, &cs).to_vec();
            for (b_, agent) in scalars.iter_mut().enumerate() {
                let x = &obs[b_ * spec.n_inputs..(b_ + 1) * spec.n_inputs];
                let y = agent.step(x, cs[b_]);
                assert!(
                    (ys[b_] - y).abs() <= 1e-6,
                    "t={t} b={b_}: batched {} vs scalar {y}",
                    ys[b_]
                );
            }
        }
    }

    #[test]
    fn step_one_matches_step_all() {
        let spec = ColumnarBatchSpec {
            n_inputs: 4,
            d: 3,
            td: TdConfig {
                alpha: 0.01,
                gamma: 0.9,
                lambda: 0.95,
            },
            eps: 0.01,
            beta: 0.999,
        };
        let bsz = 4usize;
        let lanes: Vec<ColumnarLane> =
            (0..bsz as u64).map(|s| fresh_lane(&spec, s)).collect();
        let mut a = ColumnarSessionBatch::from_lanes(spec.clone(), &lanes).unwrap();
        let mut b = ColumnarSessionBatch::from_lanes(spec.clone(), &lanes).unwrap();
        let mut rng = Xoshiro256::seed_from_u64(6);
        for _ in 0..100 {
            let obs: Vec<f32> = (0..bsz * spec.n_inputs)
                .map(|_| rng.uniform(-1.0, 1.0))
                .collect();
            let cs: Vec<f32> = (0..bsz).map(|_| rng.uniform(-0.5, 0.5)).collect();
            let ys = a.step_all(&obs, &cs).to_vec();
            for b_ in 0..bsz {
                let y = b.step_one(
                    b_,
                    &obs[b_ * spec.n_inputs..(b_ + 1) * spec.n_inputs],
                    cs[b_],
                );
                assert_eq!(ys[b_], y, "session {b_}");
            }
        }
    }

    #[test]
    fn membership_changes_leave_survivors_untouched() {
        let spec = ColumnarBatchSpec {
            n_inputs: 3,
            d: 2,
            td: TdConfig::default(),
            eps: 0.01,
            beta: 0.999,
        };
        let lanes: Vec<ColumnarLane> =
            (0..3u64).map(|s| fresh_lane(&spec, s)).collect();
        let mut batch = ColumnarSessionBatch::from_lanes(spec.clone(), &lanes).unwrap();
        let mut solo = ColumnarSessionBatch::from_lanes(
            spec.clone(),
            &[lanes[1].clone()],
        )
        .unwrap();
        let mut rng = Xoshiro256::seed_from_u64(2);
        // step everyone a while
        for _ in 0..50 {
            let obs: Vec<f32> = (0..3 * spec.n_inputs)
                .map(|_| rng.uniform(-1.0, 1.0))
                .collect();
            let cs = [0.1f32, -0.2, 0.3];
            batch.step_all(&obs, &cs);
            solo.step_one(
                0,
                &obs[spec.n_inputs..2 * spec.n_inputs],
                cs[1],
            );
        }
        // remove session 0; session 2 swaps into slot 0, session 1 stays
        batch.swap_remove_lane(0).unwrap();
        assert_eq!(batch.len(), 2);
        // grow again
        batch.push_lane(fresh_lane(&spec, 99)).unwrap();
        assert_eq!(batch.len(), 3);
        // session 1 (still at index 1) must have been unaffected
        for _ in 0..20 {
            let x: Vec<f32> = (0..spec.n_inputs).map(|_| rng.uniform(-1.0, 1.0)).collect();
            let y_batch = batch.step_one(1, &x, 0.05);
            let y_solo = solo.step_one(0, &x, 0.05);
            assert_eq!(y_batch, y_solo, "membership churn corrupted a survivor");
        }
    }

    /// Capacity mechanics: push doubles amortized (no per-push
    /// re-layout), compact shrinks to fit, and neither perturbs a single
    /// bit of live state.
    #[test]
    fn grow_and_compact_preserve_state_bit_exact() {
        let spec = ColumnarBatchSpec {
            n_inputs: 3,
            d: 2,
            td: TdConfig {
                alpha: 0.01,
                gamma: 0.9,
                lambda: 0.9,
            },
            eps: 0.01,
            beta: 0.999,
        };
        let mut batch = ColumnarSessionBatch::from_lanes(spec.clone(), &[]).unwrap();
        assert_eq!(batch.capacity(), 0);
        let mut caps = Vec::new();
        for s in 0..6u64 {
            batch.push_lane(fresh_lane(&spec, s)).unwrap();
            caps.push(batch.capacity());
        }
        assert_eq!(caps, vec![4, 4, 4, 4, 8, 8], "amortized doubling");
        // warm everyone up
        let mut rng = Xoshiro256::seed_from_u64(11);
        for _ in 0..30 {
            let obs: Vec<f32> = (0..6 * spec.n_inputs)
                .map(|_| rng.uniform(-1.0, 1.0))
                .collect();
            let cs: Vec<f32> = (0..6).map(|_| rng.uniform(-0.5, 0.5)).collect();
            batch.step_all(&obs, &cs);
        }
        // a near-full batch never shrinks (6 live in cap 8 keeps its
        // headroom)...
        batch.compact();
        assert_eq!(batch.capacity(), 8, "compact must not strip headroom");
        // ...a sparse one shrinks to twice its live count, so the next
        // push still lands in padding instead of forcing a regrow
        for _ in 0..4 {
            batch.swap_remove_lane(batch.len() - 1).unwrap();
        }
        let mut twin =
            ColumnarSessionBatch::from_lanes(spec.clone(), &batch.extract_all())
                .unwrap();
        batch.compact();
        assert_eq!(batch.capacity(), 4);
        assert_eq!(batch.len(), 2);
        batch.push_lane(fresh_lane(&spec, 50)).unwrap();
        twin.push_lane(fresh_lane(&spec, 50)).unwrap();
        assert_eq!(batch.capacity(), 4, "post-compact push must not regrow");
        for _ in 0..20 {
            let obs: Vec<f32> = (0..3 * spec.n_inputs)
                .map(|_| rng.uniform(-1.0, 1.0))
                .collect();
            let cs: Vec<f32> = (0..3).map(|_| rng.uniform(-0.5, 0.5)).collect();
            let a = batch.step_all(&obs, &cs).to_vec();
            let b = twin.step_all(&obs, &cs).to_vec();
            assert_eq!(a, b, "compact must preserve state bit-for-bit");
        }
    }

    /// The padded-layout acceptance property: an arbitrary interleaving
    /// of step_all / push_lane / swap_remove_lane / compact (grow rides
    /// on push) stays **bit-exact** against (a) never-batched scalar
    /// agents stepped in lockstep and (b) a from_lanes-rebuilt twin at
    /// the end.
    #[test]
    fn prop_membership_churn_is_bit_exact() {
        use crate::config::{build_ccn, LearnerKind};
        use crate::learn::TdLambdaAgent;

        check("padded membership churn == scalar agents", 8, |g| {
            let spec = ColumnarBatchSpec {
                n_inputs: g.sized_usize(1, 4),
                d: g.sized_usize(1, 3),
                td: TdConfig {
                    alpha: 0.01,
                    gamma: 0.9,
                    lambda: 0.9,
                },
                eps: 0.01,
                beta: crate::nets::normalizer::NORM_BETA,
            };
            let mut rng = Xoshiro256::seed_from_u64(g.rng.next_u64());
            let mut batch = ColumnarSessionBatch::from_lanes(spec.clone(), &[])?;
            let mut twins: Vec<TdLambdaAgent<crate::nets::ccn::CcnNet>> = Vec::new();
            let mut next_seed = 0u64;
            for _ in 0..40 {
                match rng.int_in(0, 9) {
                    // push (drives the 0→4→8 capacity doublings)
                    0 | 1 if batch.len() < 6 => {
                        let seed = next_seed;
                        next_seed += 1;
                        batch.push_lane(fresh_lane(&spec, seed))?;
                        let net = build_ccn(
                            &LearnerKind::Columnar { d: spec.d },
                            spec.n_inputs,
                            spec.eps,
                            seed,
                        )
                        .map_err(|e| e.to_string())?;
                        twins.push(TdLambdaAgent::new(net, spec.td));
                    }
                    // swap-remove a random session; twins mirror the swap
                    2 if !batch.is_empty() => {
                        let idx =
                            rng.int_in(0, batch.len() as u64 - 1) as usize;
                        batch.swap_remove_lane(idx)?;
                        twins.swap_remove(idx);
                    }
                    // shrink-to-fit mid-stream
                    3 => batch.compact(),
                    // one synchronized step of everyone
                    _ => {
                        let bsz = batch.len();
                        if bsz == 0 {
                            continue;
                        }
                        let obs: Vec<f32> = (0..bsz * spec.n_inputs)
                            .map(|_| rng.uniform(-1.0, 1.0))
                            .collect();
                        let cs: Vec<f32> =
                            (0..bsz).map(|_| rng.uniform(-0.5, 0.5)).collect();
                        let ys = batch.step_all(&obs, &cs).to_vec();
                        for (b_, twin) in twins.iter_mut().enumerate() {
                            let x = &obs
                                [b_ * spec.n_inputs..(b_ + 1) * spec.n_inputs];
                            let y = twin.step(x, cs[b_]);
                            if ys[b_] != y {
                                return Err(format!(
                                    "slot {b_} diverged after churn: {} vs {y}",
                                    ys[b_]
                                ));
                            }
                        }
                    }
                }
            }
            // a twin rebuilt through the interchange format must continue
            // bit-identically to the churned original
            let mut rebuilt =
                ColumnarSessionBatch::from_lanes(spec.clone(), &batch.extract_all())?;
            for _ in 0..5 {
                let bsz = batch.len();
                if bsz == 0 {
                    break;
                }
                let obs: Vec<f32> = (0..bsz * spec.n_inputs)
                    .map(|_| rng.uniform(-1.0, 1.0))
                    .collect();
                let cs: Vec<f32> =
                    (0..bsz).map(|_| rng.uniform(-0.5, 0.5)).collect();
                let a = batch.step_all(&obs, &cs).to_vec();
                let b = rebuilt.step_all(&obs, &cs).to_vec();
                if a != b {
                    return Err("from_lanes-rebuilt twin diverged".into());
                }
            }
            Ok(())
        });
    }

    // ---- staged cohorts ----

    use crate::config::{build_ccn, LearnerKind};
    use crate::learn::TdLambdaAgent;
    use crate::nets::ccn::{CcnConfig, CcnNet};
    use crate::nets::normalizer::OnlineNormalizer;
    use crate::nets::{PersistableNet, PredictionNet};

    const STAGED_TD: TdConfig = TdConfig {
        alpha: 0.01,
        gamma: 0.9,
        lambda: 0.9,
    };

    fn staged_spec_of(net: &CcnNet, td: TdConfig) -> StagedBatchSpec {
        let cfg = net.config();
        StagedBatchSpec {
            n_inputs: cfg.n_inputs,
            features_per_stage: cfg.features_per_stage,
            total_features: cfg.total_features,
            steps_per_stage: cfg.steps_per_stage,
            stage: net.n_stages() - 1,
            frozen_forever: net.frozen_forever(),
            init_scale: cfg.init_scale,
            td,
            eps: cfg.norm_eps,
            beta: cfg.norm_beta,
        }
    }

    fn staged_lane_of(agent: &TdLambdaAgent<CcnNet>) -> StagedLane {
        let net = &agent.net;
        let stages = (0..net.n_stages())
            .map(|s| {
                let (mu, var, denom) = net.stage_norm(s).state();
                StagedLaneStage {
                    columns: (0..mu.len()).map(|k| net.column(s, k).clone()).collect(),
                    norm_mu: mu.to_vec(),
                    norm_var: var.to_vec(),
                    norm_denom: denom.to_vec(),
                }
            })
            .collect();
        StagedLane {
            stages,
            steps_in_stage: net.steps_in_stage(),
            rng: net.rng_state(),
            td: agent.td_state(),
        }
    }

    fn staged_agent(
        seed: u64,
        total: usize,
        per_stage: usize,
        steps_per_stage: u64,
    ) -> TdLambdaAgent<CcnNet> {
        let net = build_ccn(
            &LearnerKind::Ccn {
                total,
                per_stage,
                steps_per_stage,
            },
            3,
            0.01,
            seed,
        )
        .unwrap();
        TdLambdaAgent::new(net, STAGED_TD)
    }

    /// The scalar side of a cohort hop: rebuild the net from a pending
    /// lane, settle the stage boundary (consuming the lane's rng exactly
    /// like the scalar net would have), and zero-extend the TD state —
    /// the recipe the serve layer uses between cohorts.
    fn hop_to_agent(spec: &StagedBatchSpec, lane: &StagedLane) -> TdLambdaAgent<CcnNet> {
        let cfg = CcnConfig {
            n_inputs: spec.n_inputs,
            total_features: spec.total_features,
            features_per_stage: spec.features_per_stage,
            steps_per_stage: spec.steps_per_stage,
            init_scale: spec.init_scale,
            norm_eps: spec.eps,
            norm_beta: spec.beta,
        };
        let parts = lane
            .stages
            .iter()
            .map(|st| {
                (
                    st.columns.clone(),
                    OnlineNormalizer::from_state(
                        spec.beta,
                        spec.eps,
                        st.norm_mu.clone(),
                        st.norm_var.clone(),
                        st.norm_denom.clone(),
                    )
                    .unwrap(),
                )
            })
            .collect();
        let mut net = CcnNet::from_parts(
            cfg,
            parts,
            lane.steps_in_stage,
            lane.td.epoch_seen,
            spec.frozen_forever,
            Xoshiro256::from_state(lane.rng),
        )
        .unwrap();
        let mut td = lane.td.clone();
        if !spec.frozen_forever && lane.steps_in_stage >= spec.steps_per_stage {
            net.settle_stage_boundary();
            let d = net.n_features();
            td.w.resize(d, 0.0);
            td.e_w.resize(d, 0.0);
            td.e_theta = vec![0.0; net.n_learnable_params()];
            td.epoch_seen = net.param_epoch();
        }
        let mut agent = TdLambdaAgent::new(net, spec.td);
        agent.set_td_state(td).unwrap();
        agent
    }

    /// Mid-growth parity: sessions with a two-stage frozen prefix and a
    /// learning third stage step bit-identically to never-batched scalar
    /// agents, and the extracted lanes round-trip the full TD state.
    #[test]
    fn staged_batch_matches_scalar_agents_mid_growth() {
        let (n, bsz) = (3usize, 3usize);
        let mut rng = Xoshiro256::seed_from_u64(31);
        // two boundaries crossed during warmup: stage 2 learning, 20/40
        let mut scalars: Vec<TdLambdaAgent<CcnNet>> =
            (0..bsz as u64).map(|s| staged_agent(s, 6, 2, 40)).collect();
        for _ in 0..100 {
            for agent in scalars.iter_mut() {
                let x: Vec<f32> = (0..n).map(|_| rng.uniform(-1.0, 1.0)).collect();
                agent.step(&x, rng.uniform(-0.5, 0.5));
            }
        }
        let spec = staged_spec_of(&scalars[0].net, STAGED_TD);
        assert_eq!(spec.stage, 2);
        assert_eq!(spec.d(), 6);
        assert!(!spec.frozen_forever);
        let lanes: Vec<StagedLane> = scalars.iter().map(staged_lane_of).collect();
        let mut batch = StagedSessionBatch::from_lanes(spec.clone(), &lanes).unwrap();
        for t in 0..15 {
            let obs: Vec<f32> =
                (0..bsz * n).map(|_| rng.uniform(-1.0, 1.0)).collect();
            let cs: Vec<f32> = (0..bsz).map(|_| rng.uniform(-0.5, 0.5)).collect();
            let ys = batch.step_all(&obs, &cs).to_vec();
            assert!(batch.pending_lanes().is_empty(), "t={t}: early boundary");
            for (b_, agent) in scalars.iter_mut().enumerate() {
                let y = agent.step(&obs[b_ * n..(b_ + 1) * n], cs[b_]);
                assert_eq!(ys[b_], y, "t={t} b={b_}");
            }
        }
        for (b_, agent) in scalars.iter().enumerate() {
            assert_eq!(
                batch.extract_lane(b_).td,
                agent.td_state(),
                "lane {b_} round-trip"
            );
        }
    }

    /// Fully materialized nets (`frozen_forever`) form a cohort with no
    /// theta eligibilities: forward-only column passes plus readout TD,
    /// still bit-exact against scalar agents, never pending.
    #[test]
    fn staged_frozen_forever_cohort_matches_scalar() {
        let (n, bsz) = (3usize, 2usize);
        let mut rng = Xoshiro256::seed_from_u64(32);
        let mut scalars: Vec<TdLambdaAgent<CcnNet>> =
            (0..bsz as u64).map(|s| staged_agent(s, 4, 2, 25)).collect();
        for _ in 0..60 {
            for agent in scalars.iter_mut() {
                let x: Vec<f32> = (0..n).map(|_| rng.uniform(-1.0, 1.0)).collect();
                agent.step(&x, rng.uniform(-0.5, 0.5));
            }
        }
        let spec = staged_spec_of(&scalars[0].net, STAGED_TD);
        assert!(spec.frozen_forever);
        assert_eq!(StagedSessionBatch::e_theta_len(&spec), 0);
        let lanes: Vec<StagedLane> = scalars.iter().map(staged_lane_of).collect();
        assert!(lanes.iter().all(|l| l.td.e_theta.is_empty()));
        let mut batch = StagedSessionBatch::from_lanes(spec.clone(), &lanes).unwrap();
        for t in 0..20 {
            let obs: Vec<f32> =
                (0..bsz * n).map(|_| rng.uniform(-1.0, 1.0)).collect();
            let cs: Vec<f32> = (0..bsz).map(|_| rng.uniform(-0.5, 0.5)).collect();
            let ys = batch.step_all(&obs, &cs).to_vec();
            assert!(batch.pending_lanes().is_empty(), "frozen cohorts never hop");
            for (b_, agent) in scalars.iter_mut().enumerate() {
                let y = agent.step(&obs[b_ * n..(b_ + 1) * n], cs[b_]);
                assert_eq!(ys[b_], y, "t={t} b={b_}");
            }
        }
    }

    #[test]
    fn staged_step_one_matches_step_all() {
        let (n, bsz) = (3usize, 4usize);
        let mut rng = Xoshiro256::seed_from_u64(33);
        let mut scalars: Vec<TdLambdaAgent<CcnNet>> =
            (0..bsz as u64).map(|s| staged_agent(s, 6, 2, 50)).collect();
        for _ in 0..60 {
            for agent in scalars.iter_mut() {
                let x: Vec<f32> = (0..n).map(|_| rng.uniform(-1.0, 1.0)).collect();
                agent.step(&x, rng.uniform(-0.5, 0.5));
            }
        }
        let spec = staged_spec_of(&scalars[0].net, STAGED_TD);
        let lanes: Vec<StagedLane> = scalars.iter().map(staged_lane_of).collect();
        let mut a = StagedSessionBatch::from_lanes(spec.clone(), &lanes).unwrap();
        let mut b = StagedSessionBatch::from_lanes(spec.clone(), &lanes).unwrap();
        for _ in 0..30 {
            let obs: Vec<f32> =
                (0..bsz * n).map(|_| rng.uniform(-1.0, 1.0)).collect();
            let cs: Vec<f32> = (0..bsz).map(|_| rng.uniform(-0.5, 0.5)).collect();
            let ys = a.step_all(&obs, &cs).to_vec();
            for b_ in 0..bsz {
                let y = b.step_one(b_, &obs[b_ * n..(b_ + 1) * n], cs[b_]);
                assert_eq!(ys[b_], y, "session {b_}");
                assert_eq!(a.lane_pending(b_), b.lane_pending(b_));
            }
        }
    }

    /// The cohort-hop contract end to end at the batch level: lanes enter
    /// a cohort at different stage clocks, the boundary fires per lane
    /// (the crossing step's prediction still matches scalar — the scalar
    /// net settles *after* its TD update), pending lanes hop through the
    /// interchange format and continue bit-identically to scalar twins
    /// that crossed naturally, and the survivors ride out the churn
    /// (swap-remove + compact + push) untouched.
    #[test]
    fn staged_cohort_hop_and_churn_are_bit_exact() {
        let n = 3usize;
        let mut rng = Xoshiro256::seed_from_u64(34);
        // staggered entry: twin 0 is 5 steps younger in the stage
        let mut twins: Vec<TdLambdaAgent<CcnNet>> =
            (0..3u64).map(|s| staged_agent(s, 4, 2, 30)).collect();
        for (i, agent) in twins.iter_mut().enumerate() {
            let warm = if i == 0 { 20 } else { 25 };
            for _ in 0..warm {
                let x: Vec<f32> = (0..n).map(|_| rng.uniform(-1.0, 1.0)).collect();
                agent.step(&x, rng.uniform(-0.5, 0.5));
            }
        }
        let spec = staged_spec_of(&twins[0].net, STAGED_TD);
        assert_eq!(spec.stage, 0);
        let lanes: Vec<StagedLane> = twins.iter().map(staged_lane_of).collect();
        assert_eq!(lanes[0].steps_in_stage, 20);
        assert_eq!(lanes[1].steps_in_stage, 25);
        let mut batch = StagedSessionBatch::from_lanes(spec.clone(), &lanes).unwrap();
        // 5 joint steps: lanes 1 and 2 cross on the 5th, and even that
        // step's predictions match the scalar twins bit-for-bit
        for t in 0..5 {
            let obs: Vec<f32> = (0..3 * n).map(|_| rng.uniform(-1.0, 1.0)).collect();
            let cs: Vec<f32> = (0..3).map(|_| rng.uniform(-0.5, 0.5)).collect();
            let ys = batch.step_all(&obs, &cs).to_vec();
            for (b_, twin) in twins.iter_mut().enumerate() {
                let y = twin.step(&obs[b_ * n..(b_ + 1) * n], cs[b_]);
                assert_eq!(ys[b_], y, "t={t} b={b_}");
            }
        }
        assert_eq!(batch.pending_lanes(), &[1, 2]);
        assert!(!batch.lane_pending(0));
        // hop lane 1 through the interchange format; its rebuilt agent
        // must match twin 1 (which settled the same boundary in-net)
        // down to the serialized bytes, rng state included
        let hopped_lane = batch.swap_remove_lane(1).unwrap();
        assert_eq!(hopped_lane.steps_in_stage, 30);
        let mut hopped = hop_to_agent(&spec, &hopped_lane);
        assert_eq!(hopped.net.n_stages(), 2);
        assert_eq!(
            hopped.net.save().dump(),
            twins[1].net.save().dump(),
            "hop must replicate the scalar stage transition exactly"
        );
        for _ in 0..10 {
            let x: Vec<f32> = (0..n).map(|_| rng.uniform(-1.0, 1.0)).collect();
            let c = rng.uniform(-0.5, 0.5);
            assert_eq!(hopped.step(&x, c), twins[1].step(&x, c));
        }
        // lane 2 swapped into slot 1 by the removal; hop it out too, then
        // churn the cohort around the survivor
        assert!(batch.lane_pending(1));
        batch.swap_remove_lane(1).unwrap();
        assert_eq!(batch.len(), 1);
        batch.compact();
        batch
            .push_lane(staged_lane_of(&staged_agent(9, 4, 2, 30)))
            .unwrap();
        let mut fresh_twin = staged_agent(9, 4, 2, 30);
        for _ in 0..5 {
            let obs: Vec<f32> = (0..2 * n).map(|_| rng.uniform(-1.0, 1.0)).collect();
            let cs: Vec<f32> = (0..2).map(|_| rng.uniform(-0.5, 0.5)).collect();
            let ys = batch.step_all(&obs, &cs).to_vec();
            assert_eq!(ys[0], twins[0].step(&obs[..n], cs[0]), "survivor");
            assert_eq!(ys[1], fresh_twin.step(&obs[n..2 * n], cs[1]), "pushed");
        }
        // the survivor (entered 5 steps late) crosses on its own clock
        assert!(!batch.lane_pending(0));
        for _ in 0..5 {
            let obs: Vec<f32> = (0..2 * n).map(|_| rng.uniform(-1.0, 1.0)).collect();
            let cs: Vec<f32> = (0..2).map(|_| rng.uniform(-0.5, 0.5)).collect();
            let ys = batch.step_all(&obs, &cs).to_vec();
            assert_eq!(ys[0], twins[0].step(&obs[..n], cs[0]));
            assert_eq!(ys[1], fresh_twin.step(&obs[n..2 * n], cs[1]));
        }
        assert_eq!(batch.pending_lanes(), &[0], "per-lane stage clock");
    }

    /// A staged lane that does not fit the cohort spec is rejected
    /// without disturbing the batch.
    #[test]
    fn staged_lane_validation_rejects_mismatched_shapes() {
        let agent = staged_agent(1, 6, 2, 40);
        let spec = staged_spec_of(&agent.net, STAGED_TD);
        let good = staged_lane_of(&agent);
        let mut batch = StagedSessionBatch::from_lanes(spec.clone(), &[]).unwrap();

        let mut missing_stage = good.clone();
        missing_stage.stages.pop();
        assert!(batch.push_lane(missing_stage).is_err());

        let mut bad_readout = good.clone();
        bad_readout.td.w.push(0.0);
        assert!(batch.push_lane(bad_readout).is_err());

        let mut bad_theta = good.clone();
        bad_theta.td.e_theta.truncate(3);
        assert!(batch.push_lane(bad_theta).is_err());

        assert_eq!(batch.len(), 0);
        batch.push_lane(good).unwrap();
        assert_eq!(batch.len(), 1);
    }
}
