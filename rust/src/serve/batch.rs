//! Batched structure-of-arrays columnar stepping — the serving hot path.
//!
//! The paper's structural trick (columns are independent modules, so RTRL
//! factorizes per column) is also a *batching* opportunity: B independent
//! columns with the same input width can be advanced in one pass over
//! lane-interleaved arrays, turning the per-column scalar recurrences into
//! vectorizable inner loops across lanes.
//!
//! Two layers live here:
//!
//! - [`BatchedColumnStepper`]: B·d independent LSTM columns in SoA form
//!   (lane-innermost layout `[gate][j][lane]`), advanced with full RTRL
//!   traces in one cache-friendly pass. Numerically **identical** to
//!   [`LstmColumn::step_with_traces`] lane by lane — every per-lane
//!   floating-point expression is evaluated in the same order as the
//!   scalar code, so parity is exact, not approximate.
//! - [`ColumnarSessionBatch`]: B complete TD(lambda) *sessions* (columnar
//!   net + online normalizer + readout + both eligibility traces) over a
//!   shared spec, stepped together. Lane `l = k * B + b` holds column `k`
//!   of session `b`. Sessions enter and leave a batch as
//!   [`ColumnarLane`] bundles (used by the shard layer and by snapshots).

use crate::learn::{TdConfig, TdState};
use crate::nets::lstm_column::LstmColumn;
use crate::util::{dot, sigmoid};

/// B·d independent LSTM columns in structure-of-arrays form.
///
/// `batch` sessions × `groups` columns each; all columns share input
/// width `m`. Lane `l = k * batch + b` is column `k` of session `b`, and
/// a step consumes one observation per *session* (shape `[m][batch]`,
/// batch-innermost), broadcast across that session's column group.
/// `groups == 1` gives B fully independent columns, each with its own
/// input — the configuration the parity property tests exercise.
pub struct BatchedColumnStepper {
    m: usize,
    batch: usize,
    groups: usize,
    /// input weights `[4][m][L]`, lane-innermost
    pub(super) w: Vec<f32>,
    /// recurrent weights `[4][L]`
    pub(super) u: Vec<f32>,
    /// biases `[4][L]`
    pub(super) b: Vec<f32>,
    /// hidden / cell state `[L]`
    pub(super) h: Vec<f32>,
    pub(super) c: Vec<f32>,
    /// RTRL traces, same layouts as the parameters
    pub(super) thw: Vec<f32>,
    pub(super) tcw: Vec<f32>,
    pub(super) thu: Vec<f32>,
    pub(super) tcu: Vec<f32>,
    pub(super) thb: Vec<f32>,
    pub(super) tcb: Vec<f32>,
    // per-lane scratch, reused every step
    z: Vec<f32>, // [4][L]
    f_gate: Vec<f32>,
    a_coef: Vec<f32>,
    b_coef: Vec<f32>,
    e_coef: Vec<f32>,
    qi: Vec<f32>,
    qf: Vec<f32>,
    qg: Vec<f32>,
    ro: Vec<f32>,
    h_prev: Vec<f32>,
    zero: Vec<f32>,
}

impl BatchedColumnStepper {
    pub fn new(m: usize, batch: usize, groups: usize) -> Self {
        let l = batch * groups;
        Self {
            m,
            batch,
            groups,
            w: vec![0.0; 4 * m * l],
            u: vec![0.0; 4 * l],
            b: vec![0.0; 4 * l],
            h: vec![0.0; l],
            c: vec![0.0; l],
            thw: vec![0.0; 4 * m * l],
            tcw: vec![0.0; 4 * m * l],
            thu: vec![0.0; 4 * l],
            tcu: vec![0.0; 4 * l],
            thb: vec![0.0; 4 * l],
            tcb: vec![0.0; 4 * l],
            z: vec![0.0; 4 * l],
            f_gate: vec![0.0; l],
            a_coef: vec![0.0; l],
            b_coef: vec![0.0; l],
            e_coef: vec![0.0; l],
            qi: vec![0.0; l],
            qf: vec![0.0; l],
            qg: vec![0.0; l],
            ro: vec![0.0; l],
            h_prev: vec![0.0; l],
            zero: vec![0.0; l],
        }
    }

    pub fn m(&self) -> usize {
        self.m
    }

    pub fn batch(&self) -> usize {
        self.batch
    }

    pub fn groups(&self) -> usize {
        self.groups
    }

    pub fn lanes(&self) -> usize {
        self.batch * self.groups
    }

    pub fn h(&self, lane: usize) -> f32 {
        self.h[lane]
    }

    pub fn c(&self, lane: usize) -> f32 {
        self.c[lane]
    }

    /// Pack a scalar column (params, state, traces) into lane `lane`.
    pub fn load_lane(&mut self, lane: usize, col: &LstmColumn) {
        assert_eq!(col.m, self.m, "column width mismatch");
        assert!(lane < self.lanes());
        let (m, l) = (self.m, self.lanes());
        for a in 0..4 {
            for j in 0..m {
                let p = a * m + j;
                self.w[p * l + lane] = col.w[p];
                self.thw[p * l + lane] = col.thw[p];
                self.tcw[p * l + lane] = col.tcw[p];
            }
            self.u[a * l + lane] = col.u[a];
            self.b[a * l + lane] = col.b[a];
            self.thu[a * l + lane] = col.thu[a];
            self.tcu[a * l + lane] = col.tcu[a];
            self.thb[a * l + lane] = col.thb[a];
            self.tcb[a * l + lane] = col.tcb[a];
        }
        self.h[lane] = col.h;
        self.c[lane] = col.c;
    }

    /// Unpack lane `lane` back into a scalar column.
    pub fn extract_lane(&self, lane: usize) -> LstmColumn {
        assert!(lane < self.lanes());
        let (m, l) = (self.m, self.lanes());
        let mut col = LstmColumn::zeroed(m);
        for a in 0..4 {
            for j in 0..m {
                let p = a * m + j;
                col.w[p] = self.w[p * l + lane];
                col.thw[p] = self.thw[p * l + lane];
                col.tcw[p] = self.tcw[p * l + lane];
            }
            col.u[a] = self.u[a * l + lane];
            col.b[a] = self.b[a * l + lane];
            col.thu[a] = self.thu[a * l + lane];
            col.tcu[a] = self.tcu[a * l + lane];
            col.thb[a] = self.thb[a * l + lane];
            col.tcb[a] = self.tcb[a * l + lane];
        }
        col.h = self.h[lane];
        col.c = self.c[lane];
        col
    }

    /// Gate pre-activations: `z[a][l] = sum_j w[a][j][l] * x[j][l % B]`.
    /// One pass over the weights; the inner loop is contiguous in both
    /// `w` and `x` so it autovectorizes across the batch.
    #[inline]
    fn accumulate_gate_preacts(&mut self, x: &[f32]) {
        let (m, bsz, groups) = (self.m, self.batch, self.groups);
        let l = bsz * groups;
        debug_assert_eq!(x.len(), m * bsz);
        self.z.iter_mut().for_each(|v| *v = 0.0);
        for a in 0..4 {
            for j in 0..m {
                let row = (a * m + j) * l;
                let wrow = &self.w[row..row + l];
                let xrow = &x[j * bsz..j * bsz + bsz];
                let zrow = &mut self.z[a * l..a * l + l];
                for k in 0..groups {
                    let zs = &mut zrow[k * bsz..k * bsz + bsz];
                    let ws = &wrow[k * bsz..k * bsz + bsz];
                    for ((zv, &wv), &xv) in zs.iter_mut().zip(ws).zip(xrow) {
                        *zv += wv * xv;
                    }
                }
            }
        }
    }

    /// Gate activations and the fused trace-recursion coefficients; also
    /// advances `h`/`c`. Mirrors the scalar column expression-for-
    /// expression so lane results are bit-identical. The per-gate rows of
    /// `z`/`u`/`b` are split into slices up front — the lane loop then
    /// runs over equal-length slices with no residual bounds checks and
    /// four independent gate chains per iteration for the scheduler to
    /// overlap.
    #[inline]
    fn activate(&mut self, fill_scratch: bool) {
        let l = self.lanes();
        let Self {
            z,
            u,
            b,
            h,
            c,
            f_gate,
            a_coef,
            b_coef,
            e_coef,
            qi,
            qf,
            qg,
            ro,
            h_prev: h_prev_buf,
            ..
        } = self;
        let (zi, zrest) = z.split_at(l);
        let (zf, zrest) = zrest.split_at(l);
        let (zo, zg) = zrest.split_at(l);
        let (ui, urest) = u.split_at(l);
        let (uf, urest) = urest.split_at(l);
        let (uo, ug) = urest.split_at(l);
        let (bi, brest) = b.split_at(l);
        let (bf, brest) = brest.split_at(l);
        let (bo, bg) = brest.split_at(l);
        let h = &mut h[..l];
        let c = &mut c[..l];
        let f_gate = &mut f_gate[..l];
        let a_coef = &mut a_coef[..l];
        let b_coef = &mut b_coef[..l];
        let e_coef = &mut e_coef[..l];
        let qi = &mut qi[..l];
        let qf = &mut qf[..l];
        let qg = &mut qg[..l];
        let ro = &mut ro[..l];
        let h_prev_buf = &mut h_prev_buf[..l];
        for lane in 0..l {
            let h_prev = h[lane];
            let c_prev = c[lane];
            let i = sigmoid(zi[lane] + ui[lane] * h_prev + bi[lane]);
            let f = sigmoid(zf[lane] + uf[lane] * h_prev + bf[lane]);
            let o = sigmoid(zo[lane] + uo[lane] * h_prev + bo[lane]);
            let g = (zg[lane] + ug[lane] * h_prev + bg[lane]).tanh();
            let c2 = f * c_prev + i * g;
            let tanh_c2 = c2.tanh();
            let h2 = o * tanh_c2;
            if fill_scratch {
                let di = i * (1.0 - i);
                let df = f * (1.0 - f);
                let do_ = o * (1.0 - o);
                let dg = 1.0 - g * g;
                a_coef[lane] = c_prev * df * uf[lane]
                    + i * dg * ug[lane]
                    + g * di * ui[lane];
                b_coef[lane] = tanh_c2 * do_ * uo[lane];
                e_coef[lane] = o * (1.0 - tanh_c2 * tanh_c2);
                qi[lane] = g * di;
                qf[lane] = c_prev * df;
                qg[lane] = i * dg;
                ro[lane] = tanh_c2 * do_;
                f_gate[lane] = f;
                h_prev_buf[lane] = h_prev;
            }
            h[lane] = h2;
            c[lane] = c2;
        }
    }

    /// Forward + RTRL trace update for every lane: the batched twin of
    /// [`LstmColumn::step_with_traces`]. `x` has shape `[m][batch]`
    /// (batch-innermost); session `b`'s observation feeds all its lanes.
    ///
    /// Per-lane arithmetic is expression-for-expression the scalar
    /// column's, in the same order — the ILP work here (row reslicing,
    /// hoisted bounds checks, `#[inline]` stages) changes only how the
    /// lanes are walked, never what each lane computes, and the
    /// lane-exact parity property test pins that down.
    #[inline]
    pub fn step_traces(&mut self, x: &[f32]) {
        if self.lanes() == 0 {
            return;
        }
        self.accumulate_gate_preacts(x);
        self.activate(true);
        let Self {
            m,
            batch,
            groups,
            thw,
            tcw,
            thu,
            tcu,
            thb,
            tcb,
            f_gate,
            a_coef,
            b_coef,
            e_coef,
            qi,
            qf,
            qg,
            ro,
            h_prev,
            zero,
            ..
        } = self;
        let (m, bsz, groups) = (*m, *batch, *groups);
        let l = bsz * groups;
        for a in 0..4 {
            // per-gate direct coefficients into c' (q) and h' (r); only
            // the output gate has an r term, only the others have q.
            let (q, r): (&[f32], &[f32]) = match a {
                0 => (&qi[..], &zero[..]),
                1 => (&qf[..], &zero[..]),
                2 => (&zero[..], &ro[..]),
                _ => (&qg[..], &zero[..]),
            };
            // W traces: direct term x_j. Each (row, group) chunk is
            // resliced once so the batch-innermost loop runs over
            // equal-length slices — bounds checks hoist out and the
            // three-term recurrences across lanes are independent, which
            // is what lets the backend vectorize/overlap them.
            for j in 0..m {
                let row = (a * m + j) * l;
                let xrow = &x[j * bsz..j * bsz + bsz];
                for k in 0..groups {
                    let off = row + k * bsz;
                    let lane0 = k * bsz;
                    let th_row = &mut thw[off..off + bsz];
                    let tc_row = &mut tcw[off..off + bsz];
                    let fg = &f_gate[lane0..lane0 + bsz];
                    let ac = &a_coef[lane0..lane0 + bsz];
                    let ec = &e_coef[lane0..lane0 + bsz];
                    let bc = &b_coef[lane0..lane0 + bsz];
                    let qs = &q[lane0..lane0 + bsz];
                    let rs = &r[lane0..lane0 + bsz];
                    for bb in 0..bsz {
                        let xj = xrow[bb];
                        let th_prev = th_row[bb];
                        let tc =
                            fg[bb] * tc_row[bb] + ac[bb] * th_prev + qs[bb] * xj;
                        th_row[bb] = ec[bb] * tc + bc[bb] * th_prev + rs[bb] * xj;
                        tc_row[bb] = tc;
                    }
                }
            }
            // u traces (direct term h(t-1)) and b traces (direct term 1),
            // same reslicing: one gate row of each trace array at a time.
            let row = a * l;
            let thu_row = &mut thu[row..row + l];
            let tcu_row = &mut tcu[row..row + l];
            let thb_row = &mut thb[row..row + l];
            let tcb_row = &mut tcb[row..row + l];
            let fg = &f_gate[..l];
            let ac = &a_coef[..l];
            let ec = &e_coef[..l];
            let bc = &b_coef[..l];
            let hp_s = &h_prev[..l];
            let qs = &q[..l];
            let rs = &r[..l];
            for lane in 0..l {
                let hp = hp_s[lane];
                let th_prev = thu_row[lane];
                let tc =
                    fg[lane] * tcu_row[lane] + ac[lane] * th_prev + qs[lane] * hp;
                thu_row[lane] = ec[lane] * tc + bc[lane] * th_prev + rs[lane] * hp;
                tcu_row[lane] = tc;
                let thb_prev = thb_row[lane];
                let tcb_new =
                    fg[lane] * tcb_row[lane] + ac[lane] * thb_prev + qs[lane];
                thb_row[lane] = ec[lane] * tcb_new + bc[lane] * thb_prev + rs[lane];
                tcb_row[lane] = tcb_new;
            }
        }
    }

    /// Forward only, no trace bookkeeping (frozen columns).
    pub fn step_forward(&mut self, x: &[f32]) {
        if self.lanes() == 0 {
            return;
        }
        self.accumulate_gate_preacts(x);
        self.activate(false);
    }

    /// Advance a *single* lane with traces: the strided scalar path used
    /// for per-session protocol steps against a batched store. Identical
    /// arithmetic to [`Self::step_traces`], visiting only one lane.
    pub fn step_lane_traces(&mut self, lane: usize, x: &[f32]) {
        let (m, l) = (self.m, self.lanes());
        assert!(lane < l);
        debug_assert_eq!(x.len(), m);
        let mut z = [0.0f32; 4];
        for (a, zv) in z.iter_mut().enumerate() {
            let mut acc = 0.0f32;
            for (j, &xj) in x.iter().enumerate() {
                acc += self.w[(a * m + j) * l + lane] * xj;
            }
            *zv = acc;
        }
        let h_prev = self.h[lane];
        let c_prev = self.c[lane];
        let i = sigmoid(z[0] + self.u[lane] * h_prev + self.b[lane]);
        let f = sigmoid(z[1] + self.u[l + lane] * h_prev + self.b[l + lane]);
        let o = sigmoid(z[2] + self.u[2 * l + lane] * h_prev + self.b[2 * l + lane]);
        let g = (z[3] + self.u[3 * l + lane] * h_prev + self.b[3 * l + lane]).tanh();
        let c2 = f * c_prev + i * g;
        let tanh_c2 = c2.tanh();
        let h2 = o * tanh_c2;
        let di = i * (1.0 - i);
        let df = f * (1.0 - f);
        let do_ = o * (1.0 - o);
        let dg = 1.0 - g * g;
        let a_coef = c_prev * df * self.u[l + lane]
            + i * dg * self.u[3 * l + lane]
            + g * di * self.u[lane];
        let b_coef = tanh_c2 * do_ * self.u[2 * l + lane];
        let e_coef = o * (1.0 - tanh_c2 * tanh_c2);
        let q = [g * di, c_prev * df, 0.0, i * dg];
        let r = [0.0, 0.0, tanh_c2 * do_, 0.0];
        for a in 0..4 {
            let (qa, ra) = (q[a], r[a]);
            for (j, &xj) in x.iter().enumerate() {
                let idx = (a * m + j) * l + lane;
                let th_prev = self.thw[idx];
                let tc = f * self.tcw[idx] + a_coef * th_prev + qa * xj;
                self.thw[idx] = e_coef * tc + b_coef * th_prev + ra * xj;
                self.tcw[idx] = tc;
            }
            let idx = a * l + lane;
            let tcu = f * self.tcu[idx] + a_coef * self.thu[idx] + qa * h_prev;
            self.thu[idx] = e_coef * tcu + b_coef * self.thu[idx] + ra * h_prev;
            self.tcu[idx] = tcu;
            let tcb = f * self.tcb[idx] + a_coef * self.thb[idx] + qa;
            self.thb[idx] = e_coef * tcb + b_coef * self.thb[idx] + ra;
            self.tcb[idx] = tcb;
        }
        self.h[lane] = h2;
        self.c[lane] = c2;
    }
}

/// The shared shape of every session in a [`ColumnarSessionBatch`].
#[derive(Clone, Debug)]
pub struct ColumnarBatchSpec {
    pub n_inputs: usize,
    /// columns (= features) per session
    pub d: usize,
    pub td: TdConfig,
    /// normalizer epsilon
    pub eps: f32,
    /// normalizer beta
    pub beta: f32,
}

/// One session's complete state, extracted from (or insertable into) a
/// batch: the d columns with their traces, the normalizer statistics and
/// the TD(lambda) learning state. This is the interchange format between
/// the batched store, the scalar [`super::session::Session`] path and
/// snapshots.
#[derive(Clone, Debug)]
pub struct ColumnarLane {
    pub columns: Vec<LstmColumn>,
    pub norm_mu: Vec<f32>,
    pub norm_var: Vec<f32>,
    pub norm_denom: Vec<f32>,
    pub td: TdState,
}

/// B columnar TD(lambda) sessions stepped as one SoA batch.
///
/// Per step and per session this performs exactly the scalar pipeline —
/// advance columns with RTRL traces, update/apply the online normalizer,
/// predict through the linear readout, TD-update readout and column
/// parameters, decay both eligibility traces — with every per-session
/// floating-point expression evaluated in the scalar order, so a batched
/// session's trajectory is identical to the same session stepped alone.
pub struct ColumnarSessionBatch {
    spec: ColumnarBatchSpec,
    stepper: BatchedColumnStepper,
    // normalizer SoA, [L]
    mu: Vec<f32>,
    var: Vec<f32>,
    denom: Vec<f32>,
    feats: Vec<f32>,
    // readout + eligibilities, [L]
    w_out: Vec<f32>,
    e_w: Vec<f32>,
    // theta eligibilities, parallel to the stepper's parameter layout
    ew_w: Vec<f32>, // [4][m][L]
    ew_u: Vec<f32>, // [4][L]
    ew_b: Vec<f32>, // [4][L]
    // per-session TD bookkeeping, [B]
    y_prev: Vec<f32>,
    have_prev: Vec<bool>,
    steps: Vec<u64>,
    // scratch
    xt: Vec<f32>,      // [m][B] observation transpose
    ys: Vec<f32>,      // [B]
    a_delta: Vec<f32>, // [B]
    scale: Vec<f32>,   // [L]
    wbuf: Vec<f32>,    // [d]
    fbuf: Vec<f32>,    // [d]
}

impl ColumnarSessionBatch {
    /// Expected flat e_theta length for one session under `spec`.
    fn e_theta_len(spec: &ColumnarBatchSpec) -> usize {
        spec.d * LstmColumn::n_params(spec.n_inputs)
    }

    /// Build a batch holding `lanes` sessions (possibly zero).
    pub fn from_lanes(
        spec: ColumnarBatchSpec,
        lanes: &[ColumnarLane],
    ) -> Result<Self, String> {
        let (n, d) = (spec.n_inputs, spec.d);
        let bsz = lanes.len();
        let l = d * bsz;
        let mut batch = Self {
            stepper: BatchedColumnStepper::new(n, bsz, d),
            mu: vec![0.0; l],
            var: vec![0.0; l],
            denom: vec![0.0; l],
            feats: vec![0.0; l],
            w_out: vec![0.0; l],
            e_w: vec![0.0; l],
            ew_w: vec![0.0; 4 * n * l],
            ew_u: vec![0.0; 4 * l],
            ew_b: vec![0.0; 4 * l],
            y_prev: vec![0.0; bsz],
            have_prev: vec![false; bsz],
            steps: vec![0; bsz],
            xt: vec![0.0; n * bsz],
            ys: vec![0.0; bsz],
            a_delta: vec![0.0; bsz],
            scale: vec![0.0; l],
            wbuf: vec![0.0; d],
            fbuf: vec![0.0; d],
            spec,
        };
        for (b_, lane) in lanes.iter().enumerate() {
            batch.write_lane(b_, lane)?;
        }
        Ok(batch)
    }

    /// Number of sessions currently in the batch.
    pub fn len(&self) -> usize {
        self.y_prev.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn spec(&self) -> &ColumnarBatchSpec {
        &self.spec
    }

    pub fn session_steps(&self, b: usize) -> u64 {
        self.steps[b]
    }

    fn write_lane(&mut self, b_: usize, lane: &ColumnarLane) -> Result<(), String> {
        let (n, d) = (self.spec.n_inputs, self.spec.d);
        let bsz = self.len();
        let l = d * bsz;
        let np = LstmColumn::n_params(n);
        if lane.columns.len() != d {
            return Err(format!("lane has {} columns, want {d}", lane.columns.len()));
        }
        if lane.columns.iter().any(|c| c.m != n) {
            return Err(format!("lane column width != {n}"));
        }
        if lane.norm_mu.len() != d
            || lane.norm_var.len() != d
            || lane.norm_denom.len() != d
        {
            return Err("lane normalizer width mismatch".into());
        }
        if lane.td.w.len() != d || lane.td.e_w.len() != d {
            return Err("lane readout width mismatch".into());
        }
        if lane.td.e_theta.len() != d * np {
            return Err(format!(
                "lane e_theta length {} != {}",
                lane.td.e_theta.len(),
                d * np
            ));
        }
        for k in 0..d {
            let ln = k * bsz + b_;
            self.stepper.load_lane(ln, &lane.columns[k]);
            self.mu[ln] = lane.norm_mu[k];
            self.var[ln] = lane.norm_var[k];
            self.denom[ln] = lane.norm_denom[k];
            self.w_out[ln] = lane.td.w[k];
            self.e_w[ln] = lane.td.e_w[k];
            // scalar e_theta layout per column: [4n W | 4 u | 4 b]
            let base = k * np;
            for a in 0..4 {
                for j in 0..n {
                    self.ew_w[(a * n + j) * l + ln] = lane.td.e_theta[base + a * n + j];
                }
                self.ew_u[a * l + ln] = lane.td.e_theta[base + 4 * n + a];
                self.ew_b[a * l + ln] = lane.td.e_theta[base + 4 * n + 4 + a];
            }
        }
        self.y_prev[b_] = lane.td.y_prev;
        self.have_prev[b_] = lane.td.have_prev;
        self.steps[b_] = lane.td.steps;
        Ok(())
    }

    /// Extract session `b_` as a standalone [`ColumnarLane`] (the batch
    /// is unchanged).
    pub fn extract_lane(&self, b_: usize) -> ColumnarLane {
        let (n, d) = (self.spec.n_inputs, self.spec.d);
        let bsz = self.len();
        let l = d * bsz;
        let np = LstmColumn::n_params(n);
        let mut columns = Vec::with_capacity(d);
        let mut norm_mu = Vec::with_capacity(d);
        let mut norm_var = Vec::with_capacity(d);
        let mut norm_denom = Vec::with_capacity(d);
        let mut w = Vec::with_capacity(d);
        let mut e_w = Vec::with_capacity(d);
        let mut e_theta = vec![0.0; d * np];
        for k in 0..d {
            let ln = k * bsz + b_;
            columns.push(self.stepper.extract_lane(ln));
            norm_mu.push(self.mu[ln]);
            norm_var.push(self.var[ln]);
            norm_denom.push(self.denom[ln]);
            w.push(self.w_out[ln]);
            e_w.push(self.e_w[ln]);
            let base = k * np;
            for a in 0..4 {
                for j in 0..n {
                    e_theta[base + a * n + j] = self.ew_w[(a * n + j) * l + ln];
                }
                e_theta[base + 4 * n + a] = self.ew_u[a * l + ln];
                e_theta[base + 4 * n + 4 + a] = self.ew_b[a * l + ln];
            }
        }
        ColumnarLane {
            columns,
            norm_mu,
            norm_var,
            norm_denom,
            td: TdState {
                w,
                e_w,
                e_theta,
                y_prev: self.y_prev[b_],
                have_prev: self.have_prev[b_],
                epoch_seen: 1, // columnar nets never change epoch after init
                steps: self.steps[b_],
            },
        }
    }

    pub fn extract_all(&self) -> Vec<ColumnarLane> {
        (0..self.len()).map(|b_| self.extract_lane(b_)).collect()
    }

    /// Add a session; returns its lane index. O(total batch state) — the
    /// SoA arrays are re-laid-out — which is fine for open/restore but
    /// not for per-step paths.
    pub fn push_lane(&mut self, lane: ColumnarLane) -> Result<usize, String> {
        let mut lanes = self.extract_all();
        lanes.push(lane);
        *self = Self::from_lanes(self.spec.clone(), &lanes)?;
        Ok(self.len() - 1)
    }

    /// Remove session `idx`, returning it. The **last** session moves
    /// into slot `idx` (swap-remove) — callers owning an id→lane map
    /// must re-key that moved session.
    pub fn swap_remove_lane(&mut self, idx: usize) -> Result<ColumnarLane, String> {
        let mut lanes = self.extract_all();
        if idx >= lanes.len() {
            return Err(format!("lane {idx} out of range"));
        }
        let removed = lanes.swap_remove(idx);
        *self = Self::from_lanes(self.spec.clone(), &lanes)?;
        Ok(removed)
    }

    /// Shared normalizer recursion (identical to
    /// [`crate::nets::normalizer::OnlineNormalizer::update_and_normalize`]).
    #[inline]
    fn normalize_lane(&mut self, lane: usize) {
        let beta = self.spec.beta;
        let fv = self.stepper.h[lane];
        let prev_mu = self.mu[lane];
        let mu = beta * prev_mu + (1.0 - beta) * fv;
        let var = beta * self.var[lane] + (1.0 - beta) * (mu - fv) * (prev_mu - fv);
        self.mu[lane] = mu;
        self.var[lane] = var;
        let dn = self.spec.eps.max(var.max(0.0).sqrt());
        self.denom[lane] = dn;
        self.feats[lane] = (fv - mu) / dn;
    }

    /// Readout prediction for session `b_`, gathered into contiguous
    /// buffers so the dot product uses the exact summation order of the
    /// scalar agent's `util::dot`.
    #[inline]
    fn predict_session(&mut self, b_: usize) -> f32 {
        let (d, bsz) = (self.spec.d, self.len());
        for k in 0..d {
            self.wbuf[k] = self.w_out[k * bsz + b_];
            self.fbuf[k] = self.feats[k * bsz + b_];
        }
        dot(&self.wbuf, &self.fbuf)
    }

    /// One TD(lambda) step for **all** sessions: `obs` is `[B][n]`
    /// session-major, `cumulants` is `[B]`. Returns the predictions made
    /// this step. This is the serving hot path.
    pub fn step_all(&mut self, obs: &[f32], cumulants: &[f32]) -> &[f32] {
        let (n, d) = (self.spec.n_inputs, self.spec.d);
        let bsz = self.len();
        assert_eq!(obs.len(), n * bsz, "obs shape");
        assert_eq!(cumulants.len(), bsz, "cumulant shape");
        if bsz == 0 {
            return &self.ys;
        }
        let l = d * bsz;
        // transpose observations to [n][B] for the SoA kernel
        for j in 0..n {
            for b_ in 0..bsz {
                self.xt[j * bsz + b_] = obs[b_ * n + j];
            }
        }
        self.stepper.step_traces(&self.xt);
        for lane in 0..l {
            self.normalize_lane(lane);
        }
        for b_ in 0..bsz {
            self.ys[b_] = self.predict_session(b_);
        }
        let TdConfig {
            alpha,
            gamma,
            lambda,
        } = self.spec.td;
        for b_ in 0..bsz {
            self.a_delta[b_] = if self.have_prev[b_] {
                alpha * (cumulants[b_] + gamma * self.ys[b_] - self.y_prev[b_])
            } else {
                0.0
            };
        }
        // TD update of readout and column parameters (using the
        // eligibilities accumulated through t-1), then trace decay with
        // this step's gradients — the scalar agent's order.
        for lane in 0..l {
            self.w_out[lane] += self.a_delta[lane % bsz] * self.e_w[lane];
        }
        for a in 0..4 {
            for j in 0..n {
                let row = (a * n + j) * l;
                for lane in 0..l {
                    self.stepper.w[row + lane] +=
                        self.a_delta[lane % bsz] * self.ew_w[row + lane];
                }
            }
            let row = a * l;
            for lane in 0..l {
                let ad = self.a_delta[lane % bsz];
                self.stepper.u[row + lane] += ad * self.ew_u[row + lane];
                self.stepper.b[row + lane] += ad * self.ew_b[row + lane];
            }
        }
        let gl = gamma * lambda;
        for lane in 0..l {
            self.e_w[lane] = gl * self.e_w[lane] + self.feats[lane];
        }
        // dy/dtheta = (w_k / denom_k) * TH — with the *updated* readout,
        // as in the scalar agent.
        for lane in 0..l {
            self.scale[lane] = self.w_out[lane] / self.denom[lane];
        }
        for a in 0..4 {
            for j in 0..n {
                let row = (a * n + j) * l;
                for lane in 0..l {
                    self.ew_w[row + lane] = gl * self.ew_w[row + lane]
                        + self.scale[lane] * self.stepper.thw[row + lane];
                }
            }
            let row = a * l;
            for lane in 0..l {
                self.ew_u[row + lane] = gl * self.ew_u[row + lane]
                    + self.scale[lane] * self.stepper.thu[row + lane];
                self.ew_b[row + lane] = gl * self.ew_b[row + lane]
                    + self.scale[lane] * self.stepper.thb[row + lane];
            }
        }
        for b_ in 0..bsz {
            self.y_prev[b_] = self.ys[b_];
            self.have_prev[b_] = true;
            self.steps[b_] += 1;
        }
        &self.ys
    }

    /// One TD(lambda) step for a single session (strided path for
    /// per-session protocol requests). Identical arithmetic to
    /// [`Self::step_all`] restricted to session `b_`.
    pub fn step_one(&mut self, b_: usize, x: &[f32], cumulant: f32) -> f32 {
        let (n, d) = (self.spec.n_inputs, self.spec.d);
        let bsz = self.len();
        assert!(b_ < bsz);
        assert_eq!(x.len(), n, "obs width");
        let l = d * bsz;
        for k in 0..d {
            self.stepper.step_lane_traces(k * bsz + b_, x);
        }
        for k in 0..d {
            self.normalize_lane(k * bsz + b_);
        }
        let y = self.predict_session(b_);
        let TdConfig {
            alpha,
            gamma,
            lambda,
        } = self.spec.td;
        if self.have_prev[b_] {
            let ad = alpha * (cumulant + gamma * y - self.y_prev[b_]);
            for k in 0..d {
                let lane = k * bsz + b_;
                self.w_out[lane] += ad * self.e_w[lane];
            }
            for a in 0..4 {
                for j in 0..n {
                    for k in 0..d {
                        let idx = (a * n + j) * l + k * bsz + b_;
                        self.stepper.w[idx] += ad * self.ew_w[idx];
                    }
                }
                for k in 0..d {
                    let idx = a * l + k * bsz + b_;
                    self.stepper.u[idx] += ad * self.ew_u[idx];
                    self.stepper.b[idx] += ad * self.ew_b[idx];
                }
            }
        }
        let gl = gamma * lambda;
        for k in 0..d {
            let lane = k * bsz + b_;
            self.e_w[lane] = gl * self.e_w[lane] + self.feats[lane];
            let scale = self.w_out[lane] / self.denom[lane];
            for a in 0..4 {
                for j in 0..n {
                    let idx = (a * n + j) * l + lane;
                    self.ew_w[idx] =
                        gl * self.ew_w[idx] + scale * self.stepper.thw[idx];
                }
                let idx = a * l + lane;
                self.ew_u[idx] = gl * self.ew_u[idx] + scale * self.stepper.thu[idx];
                self.ew_b[idx] = gl * self.ew_b[idx] + scale * self.stepper.thb[idx];
            }
        }
        self.y_prev[b_] = y;
        self.have_prev[b_] = true;
        self.steps[b_] += 1;
        y
    }

    /// Prediction without learning for one session. The recurrent state,
    /// traces and normalizer advance (exactly like the scalar agent's
    /// `predict_only`), but no TD update happens and the bootstrap
    /// bookkeeping is untouched.
    pub fn predict_one(&mut self, b_: usize, x: &[f32]) -> f32 {
        let (n, d) = (self.spec.n_inputs, self.spec.d);
        let bsz = self.len();
        assert!(b_ < bsz);
        assert_eq!(x.len(), n, "obs width");
        for k in 0..d {
            self.stepper.step_lane_traces(k * bsz + b_, x);
        }
        for k in 0..d {
            self.normalize_lane(k * bsz + b_);
        }
        self.predict_session(b_)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::check;
    use crate::util::prng::Xoshiro256;

    fn random_column(m: usize, rng: &mut Xoshiro256) -> LstmColumn {
        let mut col = LstmColumn::new(m, rng, 0.8);
        // randomize state and traces too, so parity covers warm columns
        col.h = rng.uniform(-0.5, 0.5);
        col.c = rng.uniform(-0.5, 0.5);
        for v in col.thw.iter_mut().chain(col.tcw.iter_mut()) {
            *v = rng.uniform(-0.1, 0.1);
        }
        col
    }

    fn assert_lane_close(cols: &[LstmColumn], stepper: &BatchedColumnStepper, tol: f32) {
        for (lane, col) in cols.iter().enumerate() {
            let got = stepper.extract_lane(lane);
            assert!((got.h - col.h).abs() <= tol, "h: {} vs {}", got.h, col.h);
            assert!((got.c - col.c).abs() <= tol, "c: {} vs {}", got.c, col.c);
            for p in 0..4 * col.m {
                assert!(
                    (got.thw[p] - col.thw[p]).abs() <= tol,
                    "TH[{p}]: {} vs {}",
                    got.thw[p],
                    col.thw[p]
                );
                assert!(
                    (got.tcw[p] - col.tcw[p]).abs() <= tol,
                    "TC[{p}]: {} vs {}",
                    got.tcw[p],
                    col.tcw[p]
                );
            }
            for a in 0..4 {
                assert!((got.thu[a] - col.thu[a]).abs() <= tol);
                assert!((got.tcu[a] - col.tcu[a]).abs() <= tol);
                assert!((got.thb[a] - col.thb[a]).abs() <= tol);
                assert!((got.tcb[a] - col.tcb[a]).abs() <= tol);
            }
        }
    }

    #[test]
    fn load_extract_roundtrip_is_exact() {
        let mut rng = Xoshiro256::seed_from_u64(3);
        let m = 5;
        let cols: Vec<LstmColumn> = (0..6).map(|_| random_column(m, &mut rng)).collect();
        let mut st = BatchedColumnStepper::new(m, 6, 1);
        for (i, c) in cols.iter().enumerate() {
            st.load_lane(i, c);
        }
        for (i, c) in cols.iter().enumerate() {
            let got = st.extract_lane(i);
            assert_eq!(got.w, c.w);
            assert_eq!(got.u, c.u);
            assert_eq!(got.h, c.h);
            assert_eq!(got.thw, c.thw);
            assert_eq!(got.tcb, c.tcb);
        }
    }

    /// The ISSUE's acceptance property: batched == scalar to <= 1e-6 on
    /// h, c, TH, TC over random widths, batch sizes and 100-step
    /// rollouts. (The implementation is expression-for-expression
    /// identical, so this holds exactly; the tolerance is the contract.)
    #[test]
    fn prop_batched_stepper_matches_scalar_columns() {
        check("batched == scalar column stepping", 15, |g| {
            let m = g.sized_usize(1, 9);
            let bsz = g.sized_usize(1, 7);
            let mut rng = Xoshiro256::seed_from_u64(g.rng.next_u64());
            let mut cols: Vec<LstmColumn> =
                (0..bsz).map(|_| random_column(m, &mut rng)).collect();
            let mut st = BatchedColumnStepper::new(m, bsz, 1);
            for (i, c) in cols.iter().enumerate() {
                st.load_lane(i, c);
            }
            for _ in 0..100 {
                // one observation per lane (groups == 1): shape [m][B]
                let xs: Vec<Vec<f32>> = (0..bsz)
                    .map(|_| (0..m).map(|_| rng.uniform(-1.0, 1.0)).collect())
                    .collect();
                let mut xt = vec![0.0f32; m * bsz];
                for (b_, x) in xs.iter().enumerate() {
                    for j in 0..m {
                        xt[j * bsz + b_] = x[j];
                    }
                }
                st.step_traces(&xt);
                for (col, x) in cols.iter_mut().zip(&xs) {
                    col.step_with_traces(x);
                }
            }
            for (lane, col) in cols.iter().enumerate() {
                let got = st.extract_lane(lane);
                let tol = 1e-6f32;
                if (got.h - col.h).abs() > tol || (got.c - col.c).abs() > tol {
                    return Err(format!("state diverged: h {} vs {}", got.h, col.h));
                }
                for p in 0..4 * m {
                    if (got.thw[p] - col.thw[p]).abs() > tol
                        || (got.tcw[p] - col.tcw[p]).abs() > tol
                    {
                        return Err(format!("trace {p} diverged"));
                    }
                }
                for a in 0..4 {
                    if (got.thu[a] - col.thu[a]).abs() > tol
                        || (got.tcu[a] - col.tcu[a]).abs() > tol
                        || (got.thb[a] - col.thb[a]).abs() > tol
                        || (got.tcb[a] - col.tcb[a]).abs() > tol
                    {
                        return Err(format!("u/b trace {a} diverged"));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn grouped_lanes_share_observations() {
        // groups = d > 1: all of a session's columns see the same x.
        let (m, bsz, d) = (4, 3, 2);
        let mut rng = Xoshiro256::seed_from_u64(9);
        let cols: Vec<Vec<LstmColumn>> = (0..bsz)
            .map(|_| (0..d).map(|_| random_column(m, &mut rng)).collect())
            .collect();
        let mut st = BatchedColumnStepper::new(m, bsz, d);
        for (b_, session) in cols.iter().enumerate() {
            for (k, c) in session.iter().enumerate() {
                st.load_lane(k * bsz + b_, c);
            }
        }
        let mut scalar = cols.clone();
        for _ in 0..60 {
            let xs: Vec<Vec<f32>> = (0..bsz)
                .map(|_| (0..m).map(|_| rng.uniform(-1.0, 1.0)).collect())
                .collect();
            let mut xt = vec![0.0f32; m * bsz];
            for (b_, x) in xs.iter().enumerate() {
                for j in 0..m {
                    xt[j * bsz + b_] = x[j];
                }
            }
            st.step_traces(&xt);
            for (b_, session) in scalar.iter_mut().enumerate() {
                for col in session.iter_mut() {
                    col.step_with_traces(&xs[b_]);
                }
            }
        }
        let flat: Vec<LstmColumn> = (0..d)
            .flat_map(|k| (0..bsz).map(move |b_| (k, b_)))
            .map(|(k, b_)| scalar[b_][k].clone())
            .collect();
        assert_lane_close(&flat, &st, 1e-6);
    }

    #[test]
    fn step_lane_matches_full_step() {
        let (m, bsz) = (5, 4);
        let mut rng = Xoshiro256::seed_from_u64(17);
        let cols: Vec<LstmColumn> =
            (0..bsz).map(|_| random_column(m, &mut rng)).collect();
        let mut full = BatchedColumnStepper::new(m, bsz, 1);
        let mut lane_wise = BatchedColumnStepper::new(m, bsz, 1);
        for (i, c) in cols.iter().enumerate() {
            full.load_lane(i, c);
            lane_wise.load_lane(i, c);
        }
        for _ in 0..40 {
            let xs: Vec<Vec<f32>> = (0..bsz)
                .map(|_| (0..m).map(|_| rng.uniform(-1.0, 1.0)).collect())
                .collect();
            let mut xt = vec![0.0f32; m * bsz];
            for (b_, x) in xs.iter().enumerate() {
                for j in 0..m {
                    xt[j * bsz + b_] = x[j];
                }
            }
            full.step_traces(&xt);
            for (b_, x) in xs.iter().enumerate() {
                lane_wise.step_lane_traces(b_, x);
            }
        }
        for lane in 0..bsz {
            let a = full.extract_lane(lane);
            let b = lane_wise.extract_lane(lane);
            assert_eq!(a.h, b.h, "strided single-lane path must match batch");
            assert_eq!(a.thw, b.thw);
            assert_eq!(a.tcu, b.tcu);
        }
    }

    fn fresh_lane(spec: &ColumnarBatchSpec, seed: u64) -> ColumnarLane {
        // a freshly opened session: random columns, unit normalizer
        // stats, zero learning state — exactly what a scalar columnar
        // CcnNet + TdLambdaAgent start from.
        let net = crate::config::build_ccn(
            &crate::config::LearnerKind::Columnar { d: spec.d },
            spec.n_inputs,
            spec.eps,
            seed,
        )
        .unwrap();
        let columns = (0..spec.d).map(|k| net.column(0, k).clone()).collect();
        let (mu, var, denom) = net.stage_norm(0).state();
        ColumnarLane {
            columns,
            norm_mu: mu.to_vec(),
            norm_var: var.to_vec(),
            norm_denom: denom.to_vec(),
            td: TdState {
                w: vec![0.0; spec.d],
                e_w: vec![0.0; spec.d],
                e_theta: vec![0.0; spec.d * LstmColumn::n_params(spec.n_inputs)],
                y_prev: 0.0,
                have_prev: false,
                epoch_seen: 1,
                steps: 0,
            },
        }
    }

    #[test]
    fn batched_sessions_match_scalar_agents_exactly() {
        use crate::config::{build_ccn, LearnerKind};
        use crate::learn::TdLambdaAgent;

        // beta must be NORM_BETA so the scalar twins (built via
        // build_ccn, which hardwires the paper's beta) match the batch.
        let spec = ColumnarBatchSpec {
            n_inputs: 3,
            d: 4,
            td: TdConfig {
                alpha: 0.01,
                gamma: 0.9,
                lambda: 0.9,
            },
            eps: 0.01,
            beta: crate::nets::normalizer::NORM_BETA,
        };
        let bsz = 3;
        let lanes: Vec<ColumnarLane> =
            (0..bsz as u64).map(|s| fresh_lane(&spec, s)).collect();
        let mut batch = ColumnarSessionBatch::from_lanes(spec.clone(), &lanes).unwrap();
        let mut scalars: Vec<TdLambdaAgent<crate::nets::ccn::CcnNet>> = (0..bsz
            as u64)
            .map(|s| {
                let net = build_ccn(
                    &LearnerKind::Columnar { d: spec.d },
                    spec.n_inputs,
                    spec.eps,
                    s,
                )
                .unwrap();
                TdLambdaAgent::new(net, spec.td)
            })
            .collect();
        let mut rng = Xoshiro256::seed_from_u64(5);
        for t in 0..300 {
            let obs: Vec<f32> = (0..bsz * spec.n_inputs)
                .map(|_| rng.uniform(-1.0, 1.0))
                .collect();
            let cs: Vec<f32> = (0..bsz).map(|_| rng.uniform(-0.5, 0.5)).collect();
            let ys = batch.step_all(&obs, &cs).to_vec();
            for (b_, agent) in scalars.iter_mut().enumerate() {
                let x = &obs[b_ * spec.n_inputs..(b_ + 1) * spec.n_inputs];
                let y = agent.step(x, cs[b_]);
                assert!(
                    (ys[b_] - y).abs() <= 1e-6,
                    "t={t} b={b_}: batched {} vs scalar {y}",
                    ys[b_]
                );
            }
        }
    }

    #[test]
    fn step_one_matches_step_all() {
        let spec = ColumnarBatchSpec {
            n_inputs: 4,
            d: 3,
            td: TdConfig {
                alpha: 0.01,
                gamma: 0.9,
                lambda: 0.95,
            },
            eps: 0.01,
            beta: 0.999,
        };
        let bsz = 4usize;
        let lanes: Vec<ColumnarLane> =
            (0..bsz as u64).map(|s| fresh_lane(&spec, s)).collect();
        let mut a = ColumnarSessionBatch::from_lanes(spec.clone(), &lanes).unwrap();
        let mut b = ColumnarSessionBatch::from_lanes(spec.clone(), &lanes).unwrap();
        let mut rng = Xoshiro256::seed_from_u64(6);
        for _ in 0..100 {
            let obs: Vec<f32> = (0..bsz * spec.n_inputs)
                .map(|_| rng.uniform(-1.0, 1.0))
                .collect();
            let cs: Vec<f32> = (0..bsz).map(|_| rng.uniform(-0.5, 0.5)).collect();
            let ys = a.step_all(&obs, &cs).to_vec();
            for b_ in 0..bsz {
                let y = b.step_one(
                    b_,
                    &obs[b_ * spec.n_inputs..(b_ + 1) * spec.n_inputs],
                    cs[b_],
                );
                assert_eq!(ys[b_], y, "session {b_}");
            }
        }
    }

    #[test]
    fn membership_changes_leave_survivors_untouched() {
        let spec = ColumnarBatchSpec {
            n_inputs: 3,
            d: 2,
            td: TdConfig::default(),
            eps: 0.01,
            beta: 0.999,
        };
        let lanes: Vec<ColumnarLane> =
            (0..3u64).map(|s| fresh_lane(&spec, s)).collect();
        let mut batch = ColumnarSessionBatch::from_lanes(spec.clone(), &lanes).unwrap();
        let mut solo = ColumnarSessionBatch::from_lanes(
            spec.clone(),
            &[lanes[1].clone()],
        )
        .unwrap();
        let mut rng = Xoshiro256::seed_from_u64(2);
        // step everyone a while
        for _ in 0..50 {
            let obs: Vec<f32> = (0..3 * spec.n_inputs)
                .map(|_| rng.uniform(-1.0, 1.0))
                .collect();
            let cs = [0.1f32, -0.2, 0.3];
            batch.step_all(&obs, &cs);
            solo.step_one(
                0,
                &obs[spec.n_inputs..2 * spec.n_inputs],
                cs[1],
            );
        }
        // remove session 0; session 2 swaps into slot 0, session 1 stays
        batch.swap_remove_lane(0).unwrap();
        assert_eq!(batch.len(), 2);
        // grow again
        batch.push_lane(fresh_lane(&spec, 99)).unwrap();
        assert_eq!(batch.len(), 3);
        // session 1 (still at index 1) must have been unaffected
        for _ in 0..20 {
            let x: Vec<f32> = (0..spec.n_inputs).map(|_| rng.uniform(-1.0, 1.0)).collect();
            let y_batch = batch.step_one(1, &x, 0.05);
            let y_solo = solo.step_one(0, &x, 0.05);
            assert_eq!(y_batch, y_solo, "membership churn corrupted a survivor");
        }
    }
}
