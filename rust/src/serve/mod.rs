//! `serve` — the multi-session online prediction service.
//!
//! The paper's learners run one stream at a time
//! ([`crate::coordinator::runner`]). Production traffic is thousands of
//! concurrent streams, each an independent online TD(lambda) session.
//! This subsystem turns the reproduction into that service:
//!
//! - [`session`]: session lifecycle — open from a [`crate::config::LearnerKind`]
//!   spec, step, predict, snapshot to JSON, restore, close. Sessions wrap
//!   the existing [`crate::learn::TdLambdaAgent`] over a boxed
//!   [`crate::nets::ServableNet`], so every net family the crate
//!   implements is serveable through one surface.
//! - [`batch`]: the hot path — B independent columns (and full columnar
//!   sessions) laid out in structure-of-arrays form and advanced in one
//!   fused, vectorizable pass, parity-checked against the scalar
//!   [`crate::nets::lstm_column::LstmColumn`]. Lanes are
//!   **capacity-padded** (stride = capacity, not population), so a
//!   session entering or leaving a batch — every LRU evict/rehydrate
//!   under `--resident-cap` — is O(that session's state), not a
//!   re-layout of the whole batch.
//! - [`shard`]: N worker threads each owning a disjoint id-routed set of
//!   sessions behind an mpsc queue; aggregate throughput scales with
//!   cores and the hot path takes no locks.
//! - [`protocol`]: the JSONL wire format.
//! - [`transport`]: the network front end — a concurrent TCP/UDS
//!   listener (`--listen`) running one reader/writer thread pair per
//!   client over the same protocol and service.
//! - [`crate::store`] (mounted via `--store-dir`): the durable session
//!   tier — cold sessions park on disk, hot ones stay resident.
//!
//! # The registry/trait surface
//!
//! Serving is architecture-agnostic through three traits
//! ([`crate::nets`]):
//!
//! - [`crate::nets::PredictionNet`] — stepping and gradient estimates
//!   (pre-existing; the TD(lambda) agent's interface).
//! - [`crate::nets::PersistableNet`] — `kind()` (a stable snapshot tag),
//!   `save()` (complete JSON state capture), `n_inputs()` and
//!   `batch_capability()` (SoA fast-path discovery).
//! - [`crate::nets::ServableNet`] — the sum of the two plus runtime
//!   downcasting; sessions hold `Box<dyn ServableNet>`.
//!
//! [`crate::nets::NetRegistry`] maps every registered kind —
//! `columnar`, `constructive`, `ccn`, `tbptt`, `snap1` — to its
//! constructor-from-json. Adding an architecture to the service is one
//! registry entry plus the two trait impls; no session, shard or
//! protocol changes.
//!
//! # Snapshot envelope (v2)
//!
//! ```json
//! {"v":2, "kind":"tbptt", "spec":{...}, "net":{...}, "td":{...}}
//! ```
//!
//! `kind` routes `net` through the registry on restore; `spec` is the
//! opening [`SessionSpec`]; `td` is the TD(lambda) learning state.
//! Version-1 envelopes (PR 1; CCN family only, no `kind`) restore
//! through a migration shim. Restores are validated: unknown kinds,
//! kind/spec family mismatches, input-width mismatches and TD-shape
//! mismatches are all rejected with a useful error.
//!
//! # Protocol
//!
//! `ccn serve --shards N` speaks JSON-Lines over stdin/stdout: one
//! request object per input line produces exactly one response object on
//! stdout, in order. Every response has `"ok": true` or
//! `"ok": false, "error": "..."`.
//!
//! | op | request | response |
//! |----|---------|----------|
//! | `open` | `{"op":"open","learner":"columnar:8","n_inputs":8,"alpha":0.001,"gamma":0.9,"lambda":0.99,"eps":0.01,"seed":0}` | `{"ok":true,"id":1}` |
//! | `step` | `{"op":"step","id":1,"x":[...],"c":0.25}` | `{"ok":true,"y":0.41}` |
//! | `step_batch` | `{"op":"step_batch","ids":[1,2],"xs":[[...],[...]],"cs":[0,1]}` | `{"ok":true,"ys":[0.4,0.2]}` (failed items are `null`, detailed under `"errors"`) |
//! | `predict` | `{"op":"predict","id":1,"x":[...]}` | `{"ok":true,"y":0.41}` (advances state, no learning) |
//! | `snapshot` | `{"op":"snapshot","id":1}` | `{"ok":true,"state":{...}}` |
//! | `restore` | `{"op":"restore","state":{...}}` | `{"ok":true,"id":2}` (a fresh id; the restored session continues bit-identically). An explicit `"id":N` restores *as* that id — the cluster handoff hook ([`crate::cluster`]) |
//! | `park` | `{"op":"park","id":1}` | `{"ok":true,"id":1,"parked":true}` (session moves to the store; needs `--store-dir`) |
//! | `warm` | `{"op":"warm","id":1}` | `{"ok":true,"id":1,"resident":true,"rehydrated":true}` |
//! | `replicate` | `{"op":"replicate","id":1,"state":{...}}` | `{"ok":true,"id":1,"replica":true}` (park a warm-standby copy of a session homed *elsewhere*; refused when the id is resident here; needs `--store-dir`) |
//! | `close` | `{"op":"close","id":1}` | `{"ok":true,"id":1,"steps":1234}` |
//! | `stats` | `{"op":"stats"}` | `{"ok":true,"sessions":3,"resident":2,"parked":1,"steps":5000,"store_bytes":8192,"evictions":9,"rehydrations":7,"kinds":{"columnar":2,"tbptt":1},"cohorts":{"stage0:d2":1},"shards":[...],"latency":{"step":{"count":5000,"p50_us":1.2,"p90_us":3.1,"p99_us":8.0},...,"trace_dropped":0},"windows":{"ops":{"last_1s":...,"per_s_10s":...},...}}` |
//! | `metrics` | `{"op":"metrics"}` | `{"ok":true,"ops":{"step":{histogram},...},"stages":{"queue_wait":{histogram},...},"counters":{"steps.columnar":5000,...},"windows":{...}}`. On the router tier, `{"op":"metrics","scope":"fleet"}` fans out to every live backend and returns the merged fleet snapshot ([`crate::cluster`]) |
//! | `ping` | `{"op":"ping"}` | `{"ok":true,"pong":true}` (liveness probe, answered inline — no shard round-trip) |
//! | `health` | `{"op":"health"}` | router-tier only ([`crate::cluster`]): per-backend liveness + stats roll-up |
//! | `handoff` | `{"op":"handoff","id":1,"to":"tcp://..."}` | router-tier only: live-migrate session 1 to another backend |
//! | `drain` | `{"op":"drain","backend":"tcp://..."}` | router-tier only: migrate every routed session off a backend |
//! | `rebalance` | `{"op":"rebalance"}` | router-tier only: re-point sessions to their consistent-hash homes |
//! | `promote` | `{"op":"promote","id":1}` | router-tier only: fail session 1 over to its warm standby (`warm` the replica there, re-pin the placement table) — the manual form of the failover the router performs automatically when a pinned backend dies |
//!
//! Errors carry `"ok":false,"error":"..."` and, when the failure is
//! safe to retry elsewhere (a store-tier fault on one backend, an op
//! that provably never reached its shard), `"retriable":true` — the
//! retry taxonomy the router's failover path keys on.
//!
//! Every request may additionally carry optional `trace_id` (and
//! `span_id`) correlation fields — bounded plain strings, ignored by the
//! op parser and absent from the reply. A tracing server echoes them
//! into its sampled trace events (with the sender's `span_id` as
//! `parent_span_id`), which is how a `ccn route` front end stitches its
//! trace file and a backend's into one end-to-end span tree.
//!
//! `open` accepts any registered kind: `columnar:D`,
//! `constructive:TOTAL:STEPS_PER_STAGE`,
//! `ccn:TOTAL:PER_STAGE:STEPS_PER_STAGE`, `tbptt:D:K`, `snap1:D`.
//! Opening and driving a T-BPTT comparator session, for example:
//!
//! ```json
//! {"op":"open","learner":"tbptt:16:10","n_inputs":8,"alpha":0.001,"gamma":0.9,"lambda":0.99,"seed":7}
//! {"ok":true,"id":4}
//! {"op":"step","id":4,"x":[0.1,0,0,0.3,0,0,0,0.9],"c":0.25}
//! {"ok":true,"y":0.0312}
//! {"op":"snapshot","id":4}
//! {"ok":true,"state":{"v":2,"kind":"tbptt","spec":{...},"net":{...},"td":{...}}}
//! ```
//!
//! Sessions whose net reports a columnar [`crate::nets::BatchCapability`]
//! and share a shape are transparently stored in SoA batches per shard,
//! and growing ccn/constructive sessions
//! ([`crate::nets::BatchCapability::Staged`]) in stage-keyed *cohorts*:
//! the batch key is (spec shape, learning-stage index), so every cohort
//! member shares one SoA learning stage plus batched forward passes over
//! its frozen prefix. A session whose stage clock crosses
//! `steps_per_stage` hops to the next stage's cohort in O(its own lane)
//! — swap-remove, settle the boundary, re-place — and ends in the
//! frozen-forever cohort once every feature is materialized. A
//! `step_batch` covering a whole batch advances it in one fused pass.
//! Batched, staged and scalar paths produce identical numbers —
//! placement is purely a throughput decision. `stats` reports per-kind
//! session counts plus per-cohort counts (`"cohorts":
//! {"stage1:d4":128, "frozen:d8":16, ...}`) so mixed deployments can
//! watch their populations migrate toward the frozen cohort.
//!
//! # The durable session tier
//!
//! `ccn serve --store-dir DIR --resident-cap K` mounts [`crate::store`]:
//! each shard keeps at most K sessions resident, evicting its coldest
//! (snapshot -> park -> drop, SoA lane included) and transparently
//! rehydrating parked sessions on their next op. Because eviction rides
//! the same envelope as `snapshot`/`restore`, a session that bounced
//! through disk continues **bit-identically** — and because every `park`
//! is synced before it is acknowledged, a killed server restarts with
//! every parked session intact (`stats` shows them under `"parked"`).
//! Explicitly parking a cold session and warming it later:
//!
//! ```json
//! {"op":"open","learner":"ccn:8:2:50000","n_inputs":4,"seed":3}
//! {"ok":true,"id":9}
//! {"op":"step","id":9,"x":[0.1,0,0,0.7],"c":0.5}
//! {"ok":true,"y":0.0188}
//! {"op":"park","id":9}
//! {"ok":true,"id":9,"parked":true}
//! {"op":"stats"}
//! {"ok":true,"sessions":1,"resident":0,"parked":1,...}
//! {"op":"warm","id":9}
//! {"ok":true,"id":9,"resident":true,"rehydrated":true}
//! {"op":"step","id":9,"x":[0,0.2,0,0.7],"c":0.5}
//! {"ok":true,"y":0.0191}
//! ```
//!
//! (`warm` is optional — a bare `step` to a parked id rehydrates too;
//! warming ahead of expected traffic just moves the load off the
//! latency path.) A graceful shutdown ([`Service::close`]) flushes every
//! resident session, so nothing is lost across planned restarts either.
//!
//! # The network transport
//!
//! Stdio serves exactly one client. `ccn serve --listen tcp://HOST:PORT`
//! (or `unix://PATH`) puts a concurrent listener ([`transport::Server`])
//! in front of the same service: each accepted connection gets a
//! reader/writer thread pair, replies come back strictly in per-client
//! request order, and every op for a session id serializes through its
//! owning shard no matter which client sent it — so per-session
//! histories stay exactly replayable while different sessions from
//! different clients interleave freely. `--max-conns N` caps concurrent
//! clients (excess connections get one error line and are closed), and
//! `stats` over the transport reports a `"transport"` block tagging the
//! asking connection and listing every live one.
//!
//! Quickstart from a shell (any JSONL-speaking client works — here `nc`;
//! `< /dev/null &` daemonizes: with stdin closed at startup the server
//! runs until killed instead of watching for EOF):
//!
//! ```text
//! $ ccn serve --shards 4 --listen tcp://127.0.0.1:7777 < /dev/null &
//! $ nc 127.0.0.1 7777
//! {"op":"open","learner":"columnar:8","n_inputs":4,"seed":1}
//! {"ok":true,"id":1}
//! {"op":"step","id":1,"x":[0.1,0,0,0.4],"c":0.5}
//! {"ok":true,"y":0.0132}
//! {"op":"snapshot","id":1}
//! {"ok":true,"state":{"v":2,"kind":"columnar",...}}
//! {"op":"stats"}
//! {"ok":true,...,"transport":{"conn":1,"active_conns":1,...}}
//! ```
//!
//! A listening server with a live stdin runs until stdin closes, then
//! drains every connection and flushes the store; started with stdin
//! already closed (daemonized) it serves until killed. Killing is the
//! crash path — acknowledged `park`s survive, everything else is lost,
//! and the next boot resumes the parked sessions.
//!
//! # Observability
//!
//! Every wire op and every internal stage records into a shared
//! [`crate::obs::Registry`] of log2-bucketed latency histograms
//! ([`crate::obs::Histogram`]) and counters. The `metrics` op dumps the
//! whole registry; each histogram value reports
//! `count/sum_ns/min_ns/max_ns`, nearest-rank `p50/p90/p99/p999_ns`, and
//! its sparse nonzero `[lo_ns, count]` buckets:
//!
//! ```json
//! {"op":"metrics"}
//! {"ok":true,
//!  "ops":{"open":{...},"step":{"count":5000,"sum_ns":6200000,
//!         "min_ns":800,"max_ns":41000,"p50_ns":1100,"p90_ns":2300,
//!         "p99_ns":8100,"p999_ns":32000,
//!         "buckets":[[512,120],[1024,4000],[2048,700],...]}, ...},
//!  "stages":{"queue_wait":{...},"step_scalar":{...},
//!            "step_batched":{...},"store_append":{...},
//!            "store_load":{...},"store_compact":{...},
//!            "transport_read":{...},"transport_decode":{...},
//!            "transport_write":{...}},
//!  "counters":{"steps.columnar":4200,"steps.tbptt":800,
//!              "transport.err_decode":0,"trace.dropped":0}}
//! ```
//!
//! A slow `step` decomposes: `op.step` minus `queue_wait` (time in the
//! shard's mpsc queue) minus `store_load`/`store_append` (rehydration /
//! eviction I/O, only under `--resident-cap` churn) minus
//! `step_scalar`/`step_batched` (the learner kernel itself) leaves
//! routing overhead. All summaries in one reply derive from a single
//! registry snapshot (see [`crate::obs`] for the consistency model), and
//! `stats` carries a compact per-op `latency` block
//! (`count/p50/p90/p99_us` plus the `trace_dropped` total) for
//! dashboards that don't want full buckets. Both replies also carry a
//! `windows` block — ring-buffered 1s/10s/60s totals and derived per-s
//! rates for ops, steps, parks, warms and trace drops
//! ([`crate::obs::WindowedCounter`]) — so throughput is readable as a
//! *rate*, not just a lifetime count. With `ccn serve --trace-file PATH
//! [--trace-sample N]` every Nth op additionally appends one JSONL event
//! — `{"ts_ns":…,"op":"step","id":7,"shard":1,"dur_ns":…,"queue_ns":…,
//! "exec_ns":…,"store_ns":…,"kernel_ns":…,"ok":true}` — written by a
//! dedicated thread behind a bounded queue, so tracing never blocks the
//! serving path; a request carrying `trace_id`/`span_id` gets those (and
//! a freshly minted hop `span_id`) echoed into its event. `ccn serve
//! --metrics-listen tcp://H:P` additionally exposes the registry as
//! Prometheus text at `GET /metrics` ([`crate::obs::MetricsServer`]).
//! Telemetry is measurement-only: predictions and persisted state are
//! bit-exact with all of it on, off, or sampled.

pub mod batch;
pub mod protocol;
pub mod session;
pub mod shard;
pub mod transport;

pub use batch::{
    BatchedColumnStepper, ColumnarBatchSpec, ColumnarLane, ColumnarSessionBatch,
    StagedBatchSpec, StagedLane, StagedLaneStage, StagedSessionBatch,
};
pub use session::{Session, SessionSpec};
pub use shard::{ShardPool, ShardState};
pub use transport::{ListenAddr, Server};

use std::io::{BufRead, Write};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::obs::{
    self, Histogram, Registry, RegistrySnapshot, SpanIds, StageCell, TraceConfig, TraceHandle,
    WindowedCounter,
};
use crate::store::StoreConfig;
use crate::util::json::Json;
use protocol::{parse_wire_op, Request, Response, WireOp};

/// The protocol front end: parses request lines, routes them through a
/// [`ShardPool`], encodes responses. Every op records its wall time into
/// the shared telemetry registry; an optional trace log samples ops into
/// JSONL events with a per-stage breakdown.
pub struct Service {
    pool: ShardPool,
    obs: Arc<Registry>,
    /// per-op wall-time histograms, index-aligned with [`obs::names::OPS`]
    op_timers: Vec<Arc<Histogram>>,
    /// windowed rate counters (see [`obs::names::WINDOWS`]), resolved
    /// once so the per-op bump never touches the registry lock
    win_ops: Arc<WindowedCounter>,
    win_steps: Arc<WindowedCounter>,
    win_parks: Arc<WindowedCounter>,
    win_warms: Arc<WindowedCounter>,
    trace: Option<TraceHandle>,
    /// origin for trace timestamps (monotonic, ns since service boot)
    epoch: Instant,
}

/// `(name, OPS index, session id)` of a wire op, before dispatch
/// consumes it. The index MUST match [`obs::names::OPS`] — pinned by a
/// unit test below.
fn op_meta(op: &WireOp) -> (&'static str, usize, Option<u64>) {
    match op {
        WireOp::Open(_) => ("open", 0, None),
        WireOp::Step { id, .. } => ("step", 1, Some(*id)),
        WireOp::StepBatch(_) => ("step_batch", 2, None),
        WireOp::Predict { id, .. } => ("predict", 3, Some(*id)),
        WireOp::Snapshot { id } => ("snapshot", 4, Some(*id)),
        WireOp::Restore { id, .. } => ("restore", 5, *id),
        WireOp::Park { id } => ("park", 6, Some(*id)),
        WireOp::Warm { id } => ("warm", 7, Some(*id)),
        WireOp::Close { id } => ("close", 8, Some(*id)),
        WireOp::Stats => ("stats", 9, None),
        WireOp::Metrics => ("metrics", 10, None),
        WireOp::Ping => ("ping", 11, None),
        WireOp::Replicate { id, .. } => ("replicate", 12, Some(*id)),
    }
}

impl Service {
    pub fn new(n_shards: usize) -> Self {
        Self::with_store(n_shards, None)
            .expect("a storeless service cannot fail to boot")
    }

    /// A service with the durable session tier mounted (see
    /// [`crate::store`]): boot recovers every parked session from the
    /// store directory before the first request is served.
    pub fn with_store(
        n_shards: usize,
        cfg: Option<StoreConfig>,
    ) -> Result<Self, String> {
        // pre-registered registry: the metrics reply schema is complete
        // from the first request, not only after every op has fired
        let obs = Arc::new(Registry::standard());
        let pool = ShardPool::with_store_and_obs(n_shards, cfg, Arc::clone(&obs))?;
        let op_timers = obs::names::OPS
            .iter()
            .map(|name| obs.histogram(&format!("op.{name}")))
            .collect();
        let win_ops = obs.window("ops");
        let win_steps = obs.window("steps");
        let win_parks = obs.window("parks");
        let win_warms = obs.window("warms");
        Ok(Self {
            pool,
            obs,
            op_timers,
            win_ops,
            win_steps,
            win_parks,
            win_warms,
            trace: None,
            epoch: Instant::now(),
        })
    }

    pub fn pool(&self) -> &ShardPool {
        &self.pool
    }

    /// The telemetry registry (shared with the pool's shard workers and
    /// the transport layer).
    pub fn registry(&self) -> &Arc<Registry> {
        &self.obs
    }

    /// Partition the id space for multi-backend deployments (`ccn serve
    /// --id-offset K --id-stride N`): this service mints ids `offset,
    /// offset+stride, offset+2*stride, ...`, so N backends behind a
    /// `ccn route` front end never collide on a session id. Call before
    /// serving traffic; the defaults (0, 1) are the single-process
    /// behavior, bit-identical to before.
    pub fn set_id_scheme(&mut self, offset: u64, stride: u64) -> Result<(), String> {
        self.pool.set_id_scheme(offset, stride)
    }

    /// Mount the structured trace log (`--trace-file`): every
    /// `cfg.sample`-th op emits one JSONL event. Replaces any previous
    /// trace; call before serving traffic.
    pub fn set_trace(&mut self, cfg: &TraceConfig) -> Result<(), String> {
        let dropped = self.obs.counter("trace.dropped");
        let mut trace = TraceHandle::open(cfg, dropped)?;
        trace.set_drop_window(self.obs.window("trace.dropped"));
        self.trace = Some(trace);
        Ok(())
    }

    /// Graceful shutdown: flush every resident session to the store,
    /// join the shard workers, and finish the trace log (every accepted
    /// event is on disk when this returns). Returns the number of
    /// sessions flushed, or an error naming the sessions that could not
    /// be flushed.
    pub fn close(&mut self) -> Result<usize, String> {
        if let Some(trace) = self.trace.take() {
            trace.finish();
        }
        self.pool.close()
    }

    /// Execute one already-parsed wire operation, timing it (and, when
    /// the trace log samples it, emitting one event with the shard
    /// worker's stage breakdown).
    pub fn handle_op(&self, op: WireOp) -> Json {
        self.handle_op_spanned(op, None)
    }

    /// [`Service::handle_op`] with the sender's correlation context: a
    /// sampled trace event echoes `span.trace_id`, records the sender's
    /// hop as `parent_span_id`, and mints its own `span_id` — the join
    /// keys that stitch a router-side and a backend-side trace file into
    /// one end-to-end span tree. Correlation never touches the reply.
    pub fn handle_op_spanned(&self, op: WireOp, span: Option<&SpanIds>) -> Json {
        let (name, op_idx, id) = op_meta(&op);
        self.win_ops.add(1);
        match &op {
            WireOp::Step { .. } => self.win_steps.add(1),
            WireOp::StepBatch(items) => self.win_steps.add(items.len() as u64),
            WireOp::Park { .. } => self.win_parks.add(1),
            WireOp::Warm { .. } => self.win_warms.add(1),
            _ => {}
        }
        let sampled = self.trace.as_ref().filter(|t| t.should_sample());
        let stages = sampled.map(|_| Arc::new(StageCell::default()));
        let t0 = Instant::now();
        let reply = self.dispatch(op, stages.clone());
        let dur = t0.elapsed();
        self.op_timers[op_idx].record_duration(dur);
        if let Some(trace) = sampled {
            trace.emit(&trace_event(
                self.epoch,
                name,
                id,
                dur,
                stages.as_deref(),
                span,
                &reply,
            ));
        }
        reply
    }

    fn dispatch(&self, op: WireOp, stages: Option<Arc<StageCell>>) -> Json {
        let resp = match op {
            WireOp::Open(spec) => self.pool.open_traced(spec, stages),
            WireOp::Step { id, x, c } => {
                self.pool.call_traced(Request::Step { id, x, c }, stages)
            }
            WireOp::StepBatch(items) => Response::SteppedMany {
                ys: self.pool.step_batch(items),
            },
            WireOp::Predict { id, x } => {
                self.pool.call_traced(Request::Predict { id, x }, stages)
            }
            WireOp::Snapshot { id } => {
                self.pool.call_traced(Request::Snapshot { id }, stages)
            }
            WireOp::Restore { state, id: None } => {
                self.pool.restore_traced(state, stages)
            }
            WireOp::Restore { state, id: Some(id) } => {
                self.pool.restore_at_traced(id, state, stages)
            }
            WireOp::Park { id } => self.pool.call_traced(Request::Park { id }, stages),
            WireOp::Warm { id } => self.pool.call_traced(Request::Warm { id }, stages),
            WireOp::Replicate { id, state } => {
                self.pool.replicate_at_traced(id, state, stages)
            }
            WireOp::Close { id } => {
                self.pool.call_traced(Request::Close { id }, stages)
            }
            WireOp::Stats => return self.stats_reply(),
            WireOp::Metrics => return self.metrics_reply(),
            // liveness probe: answered inline, no shard round-trip — a
            // wedged shard must not make the server look dead to the
            // router, and a healthy one must not pay a queue hop per ping
            WireOp::Ping => {
                return Json::obj(vec![
                    ("ok", Json::Bool(true)),
                    ("pong", Json::Bool(true)),
                ])
            }
        };
        resp.to_json()
    }

    fn stats_reply(&self) -> Json {
        let per_shard = self.pool.stats();
        let sessions: usize = per_shard.iter().map(|s| s.sessions).sum();
        let resident: usize = per_shard.iter().map(|s| s.resident).sum();
        let parked: usize = per_shard.iter().map(|s| s.parked).sum();
        let steps: u64 = per_shard.iter().map(|s| s.steps).sum();
        let store_bytes: u64 = per_shard.iter().map(|s| s.store_bytes).sum();
        let evictions: u64 = per_shard.iter().map(|s| s.evictions).sum();
        let rehydrations: u64 = per_shard.iter().map(|s| s.rehydrations).sum();
        let kinds: std::collections::BTreeMap<String, Json> =
            protocol::ShardStats::merge_kinds(&per_shard)
                .into_iter()
                .map(|(k, n)| (k, Json::Num(n as f64)))
                .collect();
        let cohorts: std::collections::BTreeMap<String, Json> =
            protocol::ShardStats::merge_cohorts(&per_shard)
                .into_iter()
                .map(|(k, n)| (k, Json::Num(n as f64)))
                .collect();
        let shards: Vec<Json> = per_shard
            .iter()
            .map(|st| {
                Json::obj(vec![
                    ("sessions", Json::Num(st.sessions as f64)),
                    ("resident", Json::Num(st.resident as f64)),
                    ("parked", Json::Num(st.parked as f64)),
                    ("steps", Json::Num(st.steps as f64)),
                ])
            })
            .collect();
        // one registry snapshot for the whole latency + windows block:
        // no p50 in this reply can straddle an update of its p99's
        // histogram, and rates come from the same instant as the totals
        let snap = self.obs.snapshot();
        let latency = latency_summary(&snap);
        let windows: std::collections::BTreeMap<String, Json> = snap
            .windows
            .iter()
            .map(|(name, w)| (name.clone(), w.to_json()))
            .collect();
        Json::obj(vec![
            ("ok", Json::Bool(true)),
            ("sessions", Json::Num(sessions as f64)),
            ("resident", Json::Num(resident as f64)),
            ("parked", Json::Num(parked as f64)),
            ("steps", Json::Num(steps as f64)),
            ("store_bytes", Json::Num(store_bytes as f64)),
            ("evictions", Json::Num(evictions as f64)),
            ("rehydrations", Json::Num(rehydrations as f64)),
            ("kinds", Json::Obj(kinds)),
            ("cohorts", Json::Obj(cohorts)),
            ("shards", Json::Arr(shards)),
            ("latency", latency),
            ("windows", Json::Obj(windows)),
        ])
    }

    fn metrics_reply(&self) -> Json {
        // one consistent snapshot (see crate::obs): ops, stages, and
        // counters in this reply come from a single registry pass
        match self.obs.snapshot().to_json() {
            Json::Obj(mut fields) => {
                fields.insert("ok".to_string(), Json::Bool(true));
                Json::Obj(fields)
            }
            other => other,
        }
    }

    /// Handle one raw request line (the unit the JSONL loop and the
    /// end-to-end tests drive). Always returns a single-line response.
    pub fn handle_line(&self, line: &str) -> String {
        let reply = match Json::parse(line) {
            Err(e) => Response::error(format!("bad json: {e}")).to_json(),
            Ok(v) => match parse_wire_op(&v) {
                Err(e) => Response::error(e).to_json(),
                Ok(op) => {
                    // the op parser reads only the keys it knows, so the
                    // correlation fields ride any request without
                    // changing its meaning (or its reply)
                    let span = obs::span::from_wire(&v);
                    self.handle_op_spanned(op, span.as_ref())
                }
            },
        };
        reply.dump()
    }

    /// Serve JSONL over stdin/stdout until EOF. Blank lines are ignored.
    pub fn run_stdio(&self) -> Result<(), String> {
        let stdin = std::io::stdin();
        let stdout = std::io::stdout();
        let mut out = stdout.lock();
        for line in stdin.lock().lines() {
            let line = line.map_err(|e| e.to_string())?;
            if line.trim().is_empty() {
                continue;
            }
            let reply = self.handle_line(&line);
            writeln!(out, "{reply}").map_err(|e| e.to_string())?;
            out.flush().map_err(|e| e.to_string())?;
        }
        Ok(())
    }
}

/// Compact per-op `{count, p50_us, p90_us, p99_us}` block for the
/// `stats` reply, derived from one registry snapshot, plus a flat
/// `trace_dropped` count — a saturated trace queue must be visible
/// without asking for the full registry.
fn latency_summary(snap: &RegistrySnapshot) -> Json {
    let mut ops = std::collections::BTreeMap::new();
    for name in obs::names::OPS {
        if let Some(h) = snap.hists.get(&format!("op.{name}")) {
            ops.insert(
                name.to_string(),
                Json::obj(vec![
                    ("count", Json::Num(h.count() as f64)),
                    ("p50_us", Json::Num(h.percentile(0.50) as f64 / 1000.0)),
                    ("p90_us", Json::Num(h.percentile(0.90) as f64 / 1000.0)),
                    ("p99_us", Json::Num(h.percentile(0.99) as f64 / 1000.0)),
                ]),
            );
        }
    }
    if let Some(&dropped) = snap.counters.get("trace.dropped") {
        ops.insert("trace_dropped".to_string(), Json::Num(dropped as f64));
    }
    Json::Obj(ops)
}

/// One JSONL trace event. Stage fields appear only when a shard worker
/// filled the breakdown cell (single-session routed ops); fan-out and
/// introspection ops carry the op-level duration alone. When the request
/// carried correlation context, the event echoes its `trace_id`, records
/// the sender's hop as `parent_span_id`, and mints a fresh `span_id` for
/// this hop.
fn trace_event(
    epoch: Instant,
    op: &str,
    id: Option<u64>,
    dur: Duration,
    stages: Option<&StageCell>,
    span: Option<&SpanIds>,
    reply: &Json,
) -> Json {
    use std::sync::atomic::Ordering;
    let mut fields: Vec<(&str, Json)> = vec![
        ("ts_ns", Json::Num(epoch.elapsed().as_nanos() as f64)),
        ("op", Json::Str(op.to_string())),
    ];
    if let Some(span) = span {
        fields.push(("trace_id", Json::Str(span.trace_id.clone())));
        fields.push(("span_id", Json::Str(obs::mint_id())));
        if let Some(parent) = &span.span_id {
            fields.push(("parent_span_id", Json::Str(parent.clone())));
        }
    }
    // ops that mint their id (open/restore) tag the event from the reply
    let id = id.or_else(|| {
        reply
            .get("id")
            .and_then(|v| v.as_f64())
            .map(|v| v as u64)
    });
    if let Some(id) = id {
        fields.push(("id", Json::Num(id as f64)));
    }
    fields.push(("dur_ns", Json::Num(dur.as_nanos() as f64)));
    if let Some(cell) = stages.filter(|c| c.filled()) {
        fields.push(("shard", Json::Num(cell.shard.load(Ordering::Relaxed) as f64)));
        fields.push((
            "queue_ns",
            Json::Num(cell.queue_ns.load(Ordering::Relaxed) as f64),
        ));
        fields.push((
            "exec_ns",
            Json::Num(cell.exec_ns.load(Ordering::Relaxed) as f64),
        ));
        fields.push((
            "store_ns",
            Json::Num(cell.store_ns.load(Ordering::Relaxed) as f64),
        ));
        fields.push((
            "kernel_ns",
            Json::Num(cell.kernel_ns.load(Ordering::Relaxed) as f64),
        ));
    }
    fields.push(("ok", Json::Bool(reply.get("ok") == Some(&Json::Bool(true)))));
    Json::obj(fields)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// `op_meta`'s indices address `Service::op_timers`, which is built
    /// in `obs::names::OPS` order — drift would account ops against the
    /// wrong histogram.
    #[test]
    fn op_meta_indices_align_with_registry_names() {
        let probes: Vec<WireOp> = vec![
            WireOp::Step { id: 1, x: vec![], c: 0.0 },
            WireOp::StepBatch(vec![]),
            WireOp::Predict { id: 1, x: vec![] },
            WireOp::Snapshot { id: 1 },
            WireOp::Restore { state: Json::Null, id: None },
            WireOp::Park { id: 1 },
            WireOp::Warm { id: 1 },
            WireOp::Close { id: 1 },
            WireOp::Stats,
            WireOp::Metrics,
            WireOp::Ping,
            WireOp::Replicate { id: 1, state: Json::Null },
        ];
        for op in &probes {
            let (name, idx, _) = op_meta(op);
            assert_eq!(obs::names::OPS[idx], name, "{name} misindexed");
        }
        // `open` needs a spec; check the name table directly
        assert_eq!(obs::names::OPS[0], "open");
        assert_eq!(probes.len() + 1, obs::names::OPS.len());
    }

    #[test]
    fn trace_event_includes_stage_breakdown_only_when_filled() {
        use std::sync::atomic::Ordering;
        let epoch = Instant::now();
        let reply = Json::obj(vec![("ok", Json::Bool(true))]);
        let cell = StageCell::default();
        let ev = trace_event(epoch, "step", Some(3), Duration::from_micros(5), Some(&cell), None, &reply);
        assert!(ev.get("shard").is_none(), "unfilled cell must not emit stages");
        assert_eq!(ev.get("op").and_then(|v| v.as_str()), Some("step"));
        assert_eq!(ev.get("ok"), Some(&Json::Bool(true)));
        cell.shard.store(2, Ordering::Relaxed);
        cell.kernel_ns.store(1234, Ordering::Relaxed);
        let ev = trace_event(epoch, "step", Some(3), Duration::from_micros(5), Some(&cell), None, &reply);
        assert_eq!(ev.get("shard").and_then(|v| v.as_f64()), Some(2.0));
        assert_eq!(ev.get("kernel_ns").and_then(|v| v.as_f64()), Some(1234.0));
    }

    #[test]
    fn trace_event_takes_minted_id_from_reply() {
        let reply = Json::obj(vec![("ok", Json::Bool(true)), ("id", Json::Num(7.0))]);
        let ev = trace_event(Instant::now(), "open", None, Duration::ZERO, None, None, &reply);
        assert_eq!(ev.get("id").and_then(|v| v.as_f64()), Some(7.0));
    }

    #[test]
    fn trace_event_echoes_correlation_and_mints_its_own_span() {
        let reply = Json::obj(vec![("ok", Json::Bool(true))]);
        let span = SpanIds {
            trace_id: "cafe01".to_string(),
            span_id: Some("beef02".to_string()),
        };
        let ev = trace_event(
            Instant::now(),
            "step",
            Some(1),
            Duration::ZERO,
            None,
            Some(&span),
            &reply,
        );
        assert_eq!(ev.get("trace_id").and_then(|v| v.as_str()), Some("cafe01"));
        assert_eq!(
            ev.get("parent_span_id").and_then(|v| v.as_str()),
            Some("beef02")
        );
        let own = ev.get("span_id").and_then(|v| v.as_str()).unwrap();
        assert_eq!(own.len(), 16, "minted hop span");
        assert_ne!(own, "beef02");
        // no context, no correlation fields
        let bare = trace_event(
            Instant::now(),
            "step",
            Some(1),
            Duration::ZERO,
            None,
            None,
            &reply,
        );
        assert!(bare.get("trace_id").is_none());
        assert!(bare.get("span_id").is_none());
    }
}
