//! `serve` — the multi-session online prediction service.
//!
//! The paper's learners run one stream at a time
//! ([`crate::coordinator::runner`]). Production traffic is thousands of
//! concurrent streams, each an independent online TD(lambda) session.
//! This subsystem turns the reproduction into that service:
//!
//! - [`session`]: session lifecycle — open from a [`crate::config::LearnerKind`]
//!   spec, step, predict, snapshot to JSON, restore, close. Sessions wrap
//!   the existing [`crate::learn::TdLambdaAgent`] over a boxed
//!   [`crate::nets::ServableNet`], so every net family the crate
//!   implements is serveable through one surface.
//! - [`batch`]: the hot path — B independent columns (and full columnar
//!   sessions) laid out in structure-of-arrays form and advanced in one
//!   fused, vectorizable pass, parity-checked against the scalar
//!   [`crate::nets::lstm_column::LstmColumn`]. Lanes are
//!   **capacity-padded** (stride = capacity, not population), so a
//!   session entering or leaving a batch — every LRU evict/rehydrate
//!   under `--resident-cap` — is O(that session's state), not a
//!   re-layout of the whole batch.
//! - [`shard`]: N worker threads each owning a disjoint id-routed set of
//!   sessions behind an mpsc queue; aggregate throughput scales with
//!   cores and the hot path takes no locks.
//! - [`protocol`]: the JSONL wire format.
//! - [`transport`]: the network front end — a concurrent TCP/UDS
//!   listener (`--listen`) running one reader/writer thread pair per
//!   client over the same protocol and service.
//! - [`crate::store`] (mounted via `--store-dir`): the durable session
//!   tier — cold sessions park on disk, hot ones stay resident.
//!
//! # The registry/trait surface
//!
//! Serving is architecture-agnostic through three traits
//! ([`crate::nets`]):
//!
//! - [`crate::nets::PredictionNet`] — stepping and gradient estimates
//!   (pre-existing; the TD(lambda) agent's interface).
//! - [`crate::nets::PersistableNet`] — `kind()` (a stable snapshot tag),
//!   `save()` (complete JSON state capture), `n_inputs()` and
//!   `batch_capability()` (SoA fast-path discovery).
//! - [`crate::nets::ServableNet`] — the sum of the two plus runtime
//!   downcasting; sessions hold `Box<dyn ServableNet>`.
//!
//! [`crate::nets::NetRegistry`] maps every registered kind —
//! `columnar`, `constructive`, `ccn`, `tbptt`, `snap1` — to its
//! constructor-from-json. Adding an architecture to the service is one
//! registry entry plus the two trait impls; no session, shard or
//! protocol changes.
//!
//! # Snapshot envelope (v2)
//!
//! ```json
//! {"v":2, "kind":"tbptt", "spec":{...}, "net":{...}, "td":{...}}
//! ```
//!
//! `kind` routes `net` through the registry on restore; `spec` is the
//! opening [`SessionSpec`]; `td` is the TD(lambda) learning state.
//! Version-1 envelopes (PR 1; CCN family only, no `kind`) restore
//! through a migration shim. Restores are validated: unknown kinds,
//! kind/spec family mismatches, input-width mismatches and TD-shape
//! mismatches are all rejected with a useful error.
//!
//! # Protocol
//!
//! `ccn serve --shards N` speaks JSON-Lines over stdin/stdout: one
//! request object per input line produces exactly one response object on
//! stdout, in order. Every response has `"ok": true` or
//! `"ok": false, "error": "..."`.
//!
//! | op | request | response |
//! |----|---------|----------|
//! | `open` | `{"op":"open","learner":"columnar:8","n_inputs":8,"alpha":0.001,"gamma":0.9,"lambda":0.99,"eps":0.01,"seed":0}` | `{"ok":true,"id":1}` |
//! | `step` | `{"op":"step","id":1,"x":[...],"c":0.25}` | `{"ok":true,"y":0.41}` |
//! | `step_batch` | `{"op":"step_batch","ids":[1,2],"xs":[[...],[...]],"cs":[0,1]}` | `{"ok":true,"ys":[0.4,0.2]}` (failed items are `null`, detailed under `"errors"`) |
//! | `predict` | `{"op":"predict","id":1,"x":[...]}` | `{"ok":true,"y":0.41}` (advances state, no learning) |
//! | `snapshot` | `{"op":"snapshot","id":1}` | `{"ok":true,"state":{...}}` |
//! | `restore` | `{"op":"restore","state":{...}}` | `{"ok":true,"id":2}` (a fresh id; the restored session continues bit-identically) |
//! | `park` | `{"op":"park","id":1}` | `{"ok":true,"id":1,"parked":true}` (session moves to the store; needs `--store-dir`) |
//! | `warm` | `{"op":"warm","id":1}` | `{"ok":true,"id":1,"resident":true,"rehydrated":true}` |
//! | `close` | `{"op":"close","id":1}` | `{"ok":true,"id":1,"steps":1234}` |
//! | `stats` | `{"op":"stats"}` | `{"ok":true,"sessions":3,"resident":2,"parked":1,"steps":5000,"store_bytes":8192,"evictions":9,"rehydrations":7,"kinds":{"columnar":2,"tbptt":1},"shards":[...]}` |
//!
//! `open` accepts any registered kind: `columnar:D`,
//! `constructive:TOTAL:STEPS_PER_STAGE`,
//! `ccn:TOTAL:PER_STAGE:STEPS_PER_STAGE`, `tbptt:D:K`, `snap1:D`.
//! Opening and driving a T-BPTT comparator session, for example:
//!
//! ```json
//! {"op":"open","learner":"tbptt:16:10","n_inputs":8,"alpha":0.001,"gamma":0.9,"lambda":0.99,"seed":7}
//! {"ok":true,"id":4}
//! {"op":"step","id":4,"x":[0.1,0,0,0.3,0,0,0,0.9],"c":0.25}
//! {"ok":true,"y":0.0312}
//! {"op":"snapshot","id":4}
//! {"ok":true,"state":{"v":2,"kind":"tbptt","spec":{...},"net":{...},"td":{...}}}
//! ```
//!
//! Sessions whose net reports a columnar [`crate::nets::BatchCapability`]
//! and share a shape are transparently stored in SoA batches per shard;
//! a `step_batch` covering all of them advances each shard's batch in
//! one fused pass. Batched and scalar paths produce identical numbers —
//! placement is purely a throughput decision. `stats` reports per-kind
//! session counts so mixed-kind deployments can see what they host.
//!
//! # The durable session tier
//!
//! `ccn serve --store-dir DIR --resident-cap K` mounts [`crate::store`]:
//! each shard keeps at most K sessions resident, evicting its coldest
//! (snapshot -> park -> drop, SoA lane included) and transparently
//! rehydrating parked sessions on their next op. Because eviction rides
//! the same envelope as `snapshot`/`restore`, a session that bounced
//! through disk continues **bit-identically** — and because every `park`
//! is synced before it is acknowledged, a killed server restarts with
//! every parked session intact (`stats` shows them under `"parked"`).
//! Explicitly parking a cold session and warming it later:
//!
//! ```json
//! {"op":"open","learner":"ccn:8:2:50000","n_inputs":4,"seed":3}
//! {"ok":true,"id":9}
//! {"op":"step","id":9,"x":[0.1,0,0,0.7],"c":0.5}
//! {"ok":true,"y":0.0188}
//! {"op":"park","id":9}
//! {"ok":true,"id":9,"parked":true}
//! {"op":"stats"}
//! {"ok":true,"sessions":1,"resident":0,"parked":1,...}
//! {"op":"warm","id":9}
//! {"ok":true,"id":9,"resident":true,"rehydrated":true}
//! {"op":"step","id":9,"x":[0,0.2,0,0.7],"c":0.5}
//! {"ok":true,"y":0.0191}
//! ```
//!
//! (`warm` is optional — a bare `step` to a parked id rehydrates too;
//! warming ahead of expected traffic just moves the load off the
//! latency path.) A graceful shutdown ([`Service::close`]) flushes every
//! resident session, so nothing is lost across planned restarts either.
//!
//! # The network transport
//!
//! Stdio serves exactly one client. `ccn serve --listen tcp://HOST:PORT`
//! (or `unix://PATH`) puts a concurrent listener ([`transport::Server`])
//! in front of the same service: each accepted connection gets a
//! reader/writer thread pair, replies come back strictly in per-client
//! request order, and every op for a session id serializes through its
//! owning shard no matter which client sent it — so per-session
//! histories stay exactly replayable while different sessions from
//! different clients interleave freely. `--max-conns N` caps concurrent
//! clients (excess connections get one error line and are closed), and
//! `stats` over the transport reports a `"transport"` block tagging the
//! asking connection and listing every live one.
//!
//! Quickstart from a shell (any JSONL-speaking client works — here `nc`;
//! `< /dev/null &` daemonizes: with stdin closed at startup the server
//! runs until killed instead of watching for EOF):
//!
//! ```text
//! $ ccn serve --shards 4 --listen tcp://127.0.0.1:7777 < /dev/null &
//! $ nc 127.0.0.1 7777
//! {"op":"open","learner":"columnar:8","n_inputs":4,"seed":1}
//! {"ok":true,"id":1}
//! {"op":"step","id":1,"x":[0.1,0,0,0.4],"c":0.5}
//! {"ok":true,"y":0.0132}
//! {"op":"snapshot","id":1}
//! {"ok":true,"state":{"v":2,"kind":"columnar",...}}
//! {"op":"stats"}
//! {"ok":true,...,"transport":{"conn":1,"active_conns":1,...}}
//! ```
//!
//! A listening server with a live stdin runs until stdin closes, then
//! drains every connection and flushes the store; started with stdin
//! already closed (daemonized) it serves until killed. Killing is the
//! crash path — acknowledged `park`s survive, everything else is lost,
//! and the next boot resumes the parked sessions.

pub mod batch;
pub mod protocol;
pub mod session;
pub mod shard;
pub mod transport;

pub use batch::{BatchedColumnStepper, ColumnarBatchSpec, ColumnarLane, ColumnarSessionBatch};
pub use session::{Session, SessionSpec};
pub use shard::{ShardPool, ShardState};
pub use transport::{ListenAddr, Server};

use std::io::{BufRead, Write};

use crate::store::StoreConfig;
use crate::util::json::Json;
use protocol::{parse_wire_op, Request, Response, WireOp};

/// The protocol front end: parses request lines, routes them through a
/// [`ShardPool`], encodes responses.
pub struct Service {
    pool: ShardPool,
}

impl Service {
    pub fn new(n_shards: usize) -> Self {
        Self {
            pool: ShardPool::new(n_shards),
        }
    }

    /// A service with the durable session tier mounted (see
    /// [`crate::store`]): boot recovers every parked session from the
    /// store directory before the first request is served.
    pub fn with_store(
        n_shards: usize,
        cfg: Option<StoreConfig>,
    ) -> Result<Self, String> {
        Ok(Self {
            pool: ShardPool::with_store(n_shards, cfg)?,
        })
    }

    pub fn pool(&self) -> &ShardPool {
        &self.pool
    }

    /// Graceful shutdown: flush every resident session to the store and
    /// join the shard workers. Returns the number of sessions flushed,
    /// or an error naming the sessions that could not be flushed.
    pub fn close(&mut self) -> Result<usize, String> {
        self.pool.close()
    }

    /// Execute one already-parsed wire operation.
    pub fn handle_op(&self, op: WireOp) -> Json {
        let resp = match op {
            WireOp::Open(spec) => self.pool.open(spec),
            WireOp::Step { id, x, c } => self.pool.call(Request::Step { id, x, c }),
            WireOp::StepBatch(items) => Response::SteppedMany {
                ys: self.pool.step_batch(items),
            },
            WireOp::Predict { id, x } => self.pool.call(Request::Predict { id, x }),
            WireOp::Snapshot { id } => self.pool.call(Request::Snapshot { id }),
            WireOp::Restore(state) => self.pool.restore(state),
            WireOp::Park { id } => self.pool.call(Request::Park { id }),
            WireOp::Warm { id } => self.pool.call(Request::Warm { id }),
            WireOp::Close { id } => self.pool.call(Request::Close { id }),
            WireOp::Stats => {
                let per_shard = self.pool.stats();
                let sessions: usize = per_shard.iter().map(|s| s.sessions).sum();
                let resident: usize = per_shard.iter().map(|s| s.resident).sum();
                let parked: usize = per_shard.iter().map(|s| s.parked).sum();
                let steps: u64 = per_shard.iter().map(|s| s.steps).sum();
                let store_bytes: u64 =
                    per_shard.iter().map(|s| s.store_bytes).sum();
                let evictions: u64 = per_shard.iter().map(|s| s.evictions).sum();
                let rehydrations: u64 =
                    per_shard.iter().map(|s| s.rehydrations).sum();
                let kinds: std::collections::BTreeMap<String, Json> =
                    protocol::ShardStats::merge_kinds(&per_shard)
                        .into_iter()
                        .map(|(k, n)| (k, Json::Num(n as f64)))
                        .collect();
                let shards: Vec<Json> = per_shard
                    .iter()
                    .map(|st| {
                        Json::obj(vec![
                            ("sessions", Json::Num(st.sessions as f64)),
                            ("resident", Json::Num(st.resident as f64)),
                            ("parked", Json::Num(st.parked as f64)),
                            ("steps", Json::Num(st.steps as f64)),
                        ])
                    })
                    .collect();
                return Json::obj(vec![
                    ("ok", Json::Bool(true)),
                    ("sessions", Json::Num(sessions as f64)),
                    ("resident", Json::Num(resident as f64)),
                    ("parked", Json::Num(parked as f64)),
                    ("steps", Json::Num(steps as f64)),
                    ("store_bytes", Json::Num(store_bytes as f64)),
                    ("evictions", Json::Num(evictions as f64)),
                    ("rehydrations", Json::Num(rehydrations as f64)),
                    ("kinds", Json::Obj(kinds)),
                    ("shards", Json::Arr(shards)),
                ]);
            }
        };
        resp.to_json()
    }

    /// Handle one raw request line (the unit the JSONL loop and the
    /// end-to-end tests drive). Always returns a single-line response.
    pub fn handle_line(&self, line: &str) -> String {
        let reply = match Json::parse(line) {
            Err(e) => Response::error(format!("bad json: {e}")).to_json(),
            Ok(v) => match parse_wire_op(&v) {
                Err(e) => Response::error(e).to_json(),
                Ok(op) => self.handle_op(op),
            },
        };
        reply.dump()
    }

    /// Serve JSONL over stdin/stdout until EOF. Blank lines are ignored.
    pub fn run_stdio(&self) -> Result<(), String> {
        let stdin = std::io::stdin();
        let stdout = std::io::stdout();
        let mut out = stdout.lock();
        for line in stdin.lock().lines() {
            let line = line.map_err(|e| e.to_string())?;
            if line.trim().is_empty() {
                continue;
            }
            let reply = self.handle_line(&line);
            writeln!(out, "{reply}").map_err(|e| e.to_string())?;
            out.flush().map_err(|e| e.to_string())?;
        }
        Ok(())
    }
}
