//! The serve wire protocol: JSONL requests/responses over stdin/stdout.
//!
//! One JSON object per line in, one JSON object per line out, strictly in
//! request order. Every response carries `"ok": true|false`; failures
//! carry `"error"`. See [`crate::serve`] module docs for the full
//! operation reference with examples.

use crate::util::json::Json;

use super::session::SessionSpec;

/// A single step item: session id, observation, cumulant.
#[derive(Clone, Debug)]
pub struct StepItem {
    pub id: u64,
    pub x: Vec<f32>,
    pub c: f32,
}

/// One shard's stats snapshot: known sessions (resident + parked), steps
/// served, session counts per learner kind (sorted by kind tag), and the
/// durable-tier counters (zero when no store is mounted).
#[derive(Clone, Debug, Default)]
pub struct ShardStats {
    /// resident + parked
    pub sessions: usize,
    pub steps: u64,
    pub kinds: Vec<(String, usize)>,
    /// session counts per staged cohort, labeled
    /// `stage<k>:d<width>` / `frozen:d<width>` (sorted by label; empty
    /// when no ccn/constructive sessions are resident)
    pub cohorts: Vec<(String, usize)>,
    /// sessions live in shard memory
    pub resident: usize,
    /// sessions parked on disk only
    pub parked: usize,
    /// on-disk record volume of this shard's store
    pub store_bytes: u64,
    /// LRU evictions (snapshot -> park -> drop) since boot
    pub evictions: u64,
    /// lazy rehydrations (load -> restore) since boot
    pub rehydrations: u64,
}

impl ShardStats {
    /// Merge the per-kind session counts of many shards into one total,
    /// keyed and sorted by kind tag (the service's `stats` reply and the
    /// serve bench both report this).
    pub fn merge_kinds(stats: &[ShardStats]) -> std::collections::BTreeMap<String, usize> {
        let mut totals = std::collections::BTreeMap::new();
        for st in stats {
            for (kind, n) in &st.kinds {
                *totals.entry(kind.clone()).or_insert(0) += n;
            }
        }
        totals
    }

    /// Merge the per-cohort session counts of many shards into one
    /// total, keyed and sorted by cohort label.
    pub fn merge_cohorts(
        stats: &[ShardStats],
    ) -> std::collections::BTreeMap<String, usize> {
        let mut totals = std::collections::BTreeMap::new();
        for st in stats {
            for (label, n) in &st.cohorts {
                *totals.entry(label.clone()).or_insert(0) += n;
            }
        }
        totals
    }
}

/// Requests a shard can execute. `Open`/`Restore` carry the id the
/// service pre-assigned (ids are allocated centrally, routed by
/// `id % n_shards`).
#[derive(Clone, Debug)]
pub enum Request {
    Open { id: u64, spec: SessionSpec },
    Step { id: u64, x: Vec<f32>, c: f32 },
    /// Step many sessions of this shard in one call (the batched path).
    StepMany { items: Vec<StepItem> },
    Predict { id: u64, x: Vec<f32> },
    Snapshot { id: u64 },
    Restore { id: u64, state: Json },
    /// Evict a session to the durable store now (explicit `park` op).
    Park { id: u64 },
    /// Rehydrate a parked session into shard memory (explicit `warm`).
    Warm { id: u64 },
    /// Hold a snapshot envelope parked-as-replica for a session that
    /// lives on *another* backend (the warm-standby hook): validated,
    /// written to the store, never made resident. A later `warm`/`step`
    /// to the id — after the router promotes this backend — rehydrates
    /// it through the normal parked path.
    Replicate { id: u64, state: Json },
    Close { id: u64 },
    Stats,
    /// Flush every resident session to the store (graceful shutdown).
    Drain,
}

impl Request {
    /// The session id this request routes on (`None` for shard-local
    /// aggregates like `Stats`/`Drain` and pre-partitioned `StepMany`).
    pub fn route_id(&self) -> Option<u64> {
        match self {
            Request::Open { id, .. }
            | Request::Step { id, .. }
            | Request::Predict { id, .. }
            | Request::Snapshot { id }
            | Request::Restore { id, .. }
            | Request::Park { id }
            | Request::Warm { id }
            | Request::Replicate { id, .. }
            | Request::Close { id } => Some(*id),
            Request::StepMany { .. } | Request::Stats | Request::Drain => None,
        }
    }
}

/// Shard replies, mirrored 1:1 from requests.
#[derive(Clone, Debug)]
pub enum Response {
    Opened { id: u64 },
    Stepped { y: f32 },
    SteppedMany { ys: Vec<Result<f32, String>> },
    Predicted { y: f32 },
    Snapshotted { state: Json },
    /// The session is now on disk (idempotent for already-parked ids).
    Parked { id: u64 },
    /// The session is resident; `rehydrated` is false when it already was.
    Warmed { id: u64, rehydrated: bool },
    /// The replica envelope is parked on this backend's store.
    Replicated { id: u64 },
    Closed { id: u64, steps: u64 },
    Stats(ShardStats),
    /// Shutdown flush: how many resident sessions were written out, and
    /// per-session failures (the drain keeps going past them).
    Drained { flushed: usize, errors: Vec<String> },
    /// `retriable` marks failures where the session itself is intact and
    /// the same op may simply be sent again later (a store-tier error
    /// under graceful degradation); it encodes as `"retriable":true` and
    /// is omitted from the wire otherwise, so the error shape is
    /// unchanged for every pre-existing failure.
    Error { message: String, retriable: bool },
}

impl Response {
    pub fn error(message: impl Into<String>) -> Response {
        Response::Error {
            message: message.into(),
            retriable: false,
        }
    }

    /// An error the client may safely retry later: the target session is
    /// intact, only this attempt failed (store-tier degradation).
    pub fn error_retriable(message: impl Into<String>) -> Response {
        Response::Error {
            message: message.into(),
            retriable: true,
        }
    }

    /// Encode as one wire object.
    pub fn to_json(&self) -> Json {
        let ok = |mut fields: Vec<(&str, Json)>| {
            let mut all = vec![("ok", Json::Bool(true))];
            all.append(&mut fields);
            Json::obj(all)
        };
        match self {
            Response::Opened { id } => ok(vec![("id", Json::Num(*id as f64))]),
            Response::Stepped { y } => ok(vec![("y", Json::Num(*y as f64))]),
            Response::SteppedMany { ys } => {
                let arr: Vec<Json> = ys
                    .iter()
                    .map(|r| match r {
                        Ok(y) => Json::Num(*y as f64),
                        Err(_) => Json::Null,
                    })
                    .collect();
                let errors: Vec<Json> = ys
                    .iter()
                    .enumerate()
                    .filter_map(|(i, r)| match r {
                        Ok(_) => None,
                        Err(e) => Some(Json::obj(vec![
                            ("index", Json::Num(i as f64)),
                            ("error", Json::Str(e.clone())),
                        ])),
                    })
                    .collect();
                let mut fields = vec![("ys", Json::Arr(arr))];
                if !errors.is_empty() {
                    fields.push(("errors", Json::Arr(errors)));
                }
                ok(fields)
            }
            Response::Predicted { y } => ok(vec![("y", Json::Num(*y as f64))]),
            Response::Snapshotted { state } => {
                ok(vec![("state", state.clone())])
            }
            Response::Parked { id } => ok(vec![
                ("id", Json::Num(*id as f64)),
                ("parked", Json::Bool(true)),
            ]),
            Response::Warmed { id, rehydrated } => ok(vec![
                ("id", Json::Num(*id as f64)),
                ("resident", Json::Bool(true)),
                ("rehydrated", Json::Bool(*rehydrated)),
            ]),
            Response::Replicated { id } => ok(vec![
                ("id", Json::Num(*id as f64)),
                ("replica", Json::Bool(true)),
            ]),
            Response::Closed { id, steps } => ok(vec![
                ("id", Json::Num(*id as f64)),
                ("steps", Json::Num(*steps as f64)),
            ]),
            Response::Stats(st) => {
                let kinds: std::collections::BTreeMap<String, Json> = st
                    .kinds
                    .iter()
                    .map(|(k, n)| (k.clone(), Json::Num(*n as f64)))
                    .collect();
                let cohorts: std::collections::BTreeMap<String, Json> = st
                    .cohorts
                    .iter()
                    .map(|(k, n)| (k.clone(), Json::Num(*n as f64)))
                    .collect();
                ok(vec![
                    ("sessions", Json::Num(st.sessions as f64)),
                    ("resident", Json::Num(st.resident as f64)),
                    ("parked", Json::Num(st.parked as f64)),
                    ("steps", Json::Num(st.steps as f64)),
                    ("store_bytes", Json::Num(st.store_bytes as f64)),
                    ("evictions", Json::Num(st.evictions as f64)),
                    ("rehydrations", Json::Num(st.rehydrations as f64)),
                    ("kinds", Json::Obj(kinds)),
                    ("cohorts", Json::Obj(cohorts)),
                ])
            }
            Response::Drained { flushed, errors } => {
                let mut fields = vec![("flushed", Json::Num(*flushed as f64))];
                if !errors.is_empty() {
                    fields.push((
                        "errors",
                        Json::Arr(
                            errors.iter().map(|e| Json::Str(e.clone())).collect(),
                        ),
                    ));
                }
                ok(fields)
            }
            Response::Error { message, retriable } => {
                let mut fields = vec![
                    ("ok", Json::Bool(false)),
                    ("error", Json::Str(message.clone())),
                ];
                if *retriable {
                    fields.push(("retriable", Json::Bool(true)));
                }
                Json::obj(fields)
            }
        }
    }
}

/// A parsed wire operation, before the service assigns ids / routes.
#[derive(Clone, Debug)]
pub enum WireOp {
    Open(SessionSpec),
    Step { id: u64, x: Vec<f32>, c: f32 },
    StepBatch(Vec<StepItem>),
    Predict { id: u64, x: Vec<f32> },
    Snapshot { id: u64 },
    /// `id: None` mints a fresh id; `Some(id)` restores *as* that id —
    /// the cluster handoff hook, so a session keeps its public id when
    /// it moves between backends.
    Restore { state: Json, id: Option<u64> },
    Park { id: u64 },
    Warm { id: u64 },
    /// Park `state` as a warm-standby replica of session `id` (which
    /// lives on another backend); refused if the id is resident here.
    Replicate { id: u64, state: Json },
    Close { id: u64 },
    Stats,
    Metrics,
    /// Liveness probe: answered inline by the service, no shard
    /// round-trip (the cluster router health-checks with it).
    Ping,
}

/// A session id must be a non-negative integer; anything else (strings,
/// negatives, fractions) is a malformed request, not "session 0".
fn id_value(n: &Json) -> Result<u64, String> {
    match n.as_f64() {
        // strictly below 2^64: `u64::MAX as f64` rounds UP to 2^64, so
        // an inclusive bound would silently saturate the out-of-range
        // id 2^64 onto u64::MAX instead of rejecting it
        Some(f) if f >= 0.0 && f.fract() == 0.0 && f < u64::MAX as f64 => {
            Ok(f as u64)
        }
        Some(_) => Err("'id' must be a non-negative integer".into()),
        None => Err("missing or non-numeric 'id'".into()),
    }
}

fn get_id(v: &Json) -> Result<u64, String> {
    id_value(v.get("id").unwrap_or(&Json::Null))
}

/// Strict numeric-array decode. [`Json::to_f32_vec`] silently *drops*
/// non-numeric entries (fine for trusted files, lethal for a wire
/// protocol: `[1,"a",2]` would step a 2-input session with the wrong
/// observation instead of erroring).
fn f32s(x: &Json, what: &str) -> Result<Vec<f32>, String> {
    let arr = x
        .as_arr()
        .ok_or_else(|| format!("'{what}' must be an array of numbers"))?;
    arr.iter()
        .map(|e| {
            e.as_f64()
                .map(|f| f as f32)
                .ok_or_else(|| format!("'{what}' must be an array of numbers"))
        })
        .collect()
}

fn get_obs(v: &Json, key: &str) -> Result<Vec<f32>, String> {
    match v.get(key) {
        None => Err(format!("missing or non-array '{key}'")),
        Some(x) => f32s(x, key),
    }
}

/// Parse one request line. The `open` op accepts the spec fields inline:
///
/// ```json
/// {"op":"open","learner":"columnar:8","n_inputs":8,"alpha":0.001,
///  "gamma":0.9,"lambda":0.99,"eps":0.01,"seed":0}
/// ```
pub fn parse_wire_op(v: &Json) -> Result<WireOp, String> {
    if v.as_obj().is_none() {
        return Err("request must be a json object".into());
    }
    let op = v
        .get("op")
        .and_then(|o| o.as_str())
        .ok_or("missing 'op' field")?;
    match op {
        "open" => {
            let learner_spec = v
                .get("learner")
                .and_then(|l| l.as_str())
                .ok_or("open: missing 'learner' spec string")?;
            let learner = crate::config::LearnerKind::parse(learner_spec)
                .map_err(|e| e.to_string())?;
            // absent fields take defaults; *present but non-numeric*
            // fields are an error — silently defaulting a typo would
            // train with the wrong hyperparameters undetected.
            let num = |key: &str, default: f64| -> Result<f64, String> {
                match v.get(key) {
                    None => Ok(default),
                    Some(j) => j
                        .as_f64()
                        .ok_or_else(|| format!("open: '{key}' must be a number")),
                }
            };
            let n_inputs = v
                .get("n_inputs")
                .and_then(|n| n.as_usize())
                .ok_or("open: missing 'n_inputs'")?;
            Ok(WireOp::Open(SessionSpec {
                learner,
                n_inputs,
                td: crate::learn::TdConfig {
                    alpha: num("alpha", 0.001)? as f32,
                    gamma: num("gamma", 0.9)? as f32,
                    lambda: num("lambda", 0.99)? as f32,
                },
                eps: num("eps", 0.01)? as f32,
                seed: num("seed", 0.0)? as u64,
            }))
        }
        "step" => Ok(WireOp::Step {
            id: get_id(v)?,
            x: get_obs(v, "x")?,
            c: match v.get("c") {
                None => 0.0,
                Some(j) => {
                    j.as_f64().ok_or("step: 'c' must be a number")? as f32
                }
            },
        }),
        "step_batch" => {
            let ids = v
                .get("ids")
                .and_then(|a| a.as_arr())
                .ok_or("step_batch: missing 'ids'")?;
            let xs = v
                .get("xs")
                .and_then(|a| a.as_arr())
                .ok_or("step_batch: missing 'xs'")?;
            let cs = match v.get("cs") {
                None => return Err("step_batch: missing 'cs'".into()),
                Some(a) => f32s(a, "cs").map_err(|e| format!("step_batch: {e}"))?,
            };
            if ids.len() != xs.len() || ids.len() != cs.len() {
                return Err(format!(
                    "step_batch: ids/xs/cs lengths differ ({}/{}/{})",
                    ids.len(),
                    xs.len(),
                    cs.len()
                ));
            }
            let mut items = Vec::with_capacity(ids.len());
            for ((idj, xj), &c) in ids.iter().zip(xs).zip(&cs) {
                let id =
                    id_value(idj).map_err(|e| format!("step_batch: {e}"))?;
                let x = f32s(xj, "xs").map_err(|e| format!("step_batch: {e}"))?;
                items.push(StepItem { id, x, c });
            }
            Ok(WireOp::StepBatch(items))
        }
        "predict" => Ok(WireOp::Predict {
            id: get_id(v)?,
            x: get_obs(v, "x")?,
        }),
        "snapshot" => Ok(WireOp::Snapshot { id: get_id(v)? }),
        "restore" => Ok(WireOp::Restore {
            state: v.get("state").cloned().ok_or("restore: missing 'state'")?,
            // optional explicit id (cluster handoff): present-but-bad
            // ids are an error, never a silent fall-back to minting
            id: match v.get("id") {
                None => None,
                Some(j) => {
                    Some(id_value(j).map_err(|e| format!("restore: {e}"))?)
                }
            },
        }),
        "park" => Ok(WireOp::Park { id: get_id(v)? }),
        "warm" => Ok(WireOp::Warm { id: get_id(v)? }),
        "replicate" => Ok(WireOp::Replicate {
            id: get_id(v).map_err(|e| format!("replicate: {e}"))?,
            state: v
                .get("state")
                .cloned()
                .ok_or("replicate: missing 'state'")?,
        }),
        "close" => Ok(WireOp::Close { id: get_id(v)? }),
        "stats" => Ok(WireOp::Stats),
        "metrics" => Ok(WireOp::Metrics),
        "ping" => Ok(WireOp::Ping),
        other => Err(format!(
            "unknown op '{other}' \
             (open|step|step_batch|predict|snapshot|restore|park|warm|replicate|close|stats|metrics|ping)"
        )),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(text: &str) -> Result<WireOp, String> {
        parse_wire_op(&Json::parse(text).map_err(|e| e.to_string())?)
    }

    #[test]
    fn open_parses_with_defaults() {
        let op = parse(r#"{"op":"open","learner":"columnar:4","n_inputs":3}"#)
            .unwrap();
        match op {
            WireOp::Open(spec) => {
                assert_eq!(spec.n_inputs, 3);
                assert_eq!(spec.td.gamma, 0.9);
                assert_eq!(spec.td.lambda, 0.99);
                assert_eq!(spec.seed, 0);
            }
            other => panic!("wrong op {other:?}"),
        }
    }

    #[test]
    fn step_and_batch_parse() {
        let op = parse(r#"{"op":"step","id":4,"x":[1,2,3],"c":0.5}"#).unwrap();
        match op {
            WireOp::Step { id, x, c } => {
                assert_eq!(id, 4);
                assert_eq!(x, vec![1.0, 2.0, 3.0]);
                assert_eq!(c, 0.5);
            }
            other => panic!("wrong op {other:?}"),
        }
        let op = parse(
            r#"{"op":"step_batch","ids":[1,2],"xs":[[0.1],[0.2]],"cs":[0,1]}"#,
        )
        .unwrap();
        match op {
            WireOp::StepBatch(items) => {
                assert_eq!(items.len(), 2);
                assert_eq!(items[1].id, 2);
                assert_eq!(items[1].c, 1.0);
            }
            other => panic!("wrong op {other:?}"),
        }
    }

    #[test]
    fn malformed_requests_error_cleanly() {
        assert!(parse(r#"{"op":"warp"}"#).is_err());
        assert!(parse(r#"{"learner":"columnar:4"}"#).is_err());
        assert!(parse(r#"{"op":"step","id":1}"#).is_err());
        // present-but-malformed numeric fields must error, not default
        assert!(parse(
            r#"{"op":"open","learner":"columnar:4","n_inputs":3,"gamma":"0.99"}"#
        )
        .is_err());
        assert!(parse(r#"{"op":"step","id":1,"x":[1],"c":"big"}"#).is_err());
        assert!(parse(
            r#"{"op":"step_batch","ids":[1],"xs":[[1],[2]],"cs":[0]}"#
        )
        .is_err());
        assert!(parse(r#"{"op":"open","learner":"tbptt","n_inputs":2}"#).is_err());
    }

    #[test]
    fn wrong_typed_fields_are_rejected_not_coerced() {
        // a request must be an object at all
        assert!(parse(r#"[1,2,3]"#).is_err());
        assert!(parse(r#""step""#).is_err());
        // ids: negatives, fractions and strings are malformed, never
        // silently cast to some other session's id
        assert!(parse(r#"{"op":"step","id":-1,"x":[1],"c":0}"#).is_err());
        assert!(parse(r#"{"op":"step","id":1.5,"x":[1],"c":0}"#).is_err());
        assert!(parse(r#"{"op":"snapshot","id":"7"}"#).is_err());
        // 2^64 would saturate to u64::MAX under an `as` cast; reject it
        assert!(parse(r#"{"op":"snapshot","id":18446744073709551616}"#).is_err());
        // observations with non-numeric entries must error loudly —
        // to_f32_vec-style dropping would step with a wrong-width x
        assert!(parse(r#"{"op":"step","id":1,"x":[1,"a",2],"c":0}"#).is_err());
        assert!(parse(r#"{"op":"predict","id":1,"x":[null]}"#).is_err());
        assert!(parse(
            r#"{"op":"step_batch","ids":[1,2],"xs":[[1],["b"]],"cs":[0,0]}"#
        )
        .is_err());
        assert!(parse(
            r#"{"op":"step_batch","ids":[1,2],"xs":[[1],[2]],"cs":[0,true]}"#
        )
        .is_err());
        assert!(parse(
            r#"{"op":"step_batch","ids":[1,-2],"xs":[[1],[2]],"cs":[0,0]}"#
        )
        .is_err());
        // well-typed requests still parse after all that strictness
        assert!(parse(r#"{"op":"step","id":1,"x":[1,2],"c":0.5}"#).is_ok());
    }

    #[test]
    fn stats_and_metrics_parse() {
        assert!(matches!(parse(r#"{"op":"stats"}"#), Ok(WireOp::Stats)));
        assert!(matches!(parse(r#"{"op":"metrics"}"#), Ok(WireOp::Metrics)));
        // the unknown-op hint advertises the full op list
        let err = parse(r#"{"op":"metricz"}"#).unwrap_err();
        assert!(err.contains("unknown op"));
        assert!(err.contains("metrics"));
        assert!(err.contains("ping"));
    }

    #[test]
    fn ping_parses() {
        assert!(matches!(parse(r#"{"op":"ping"}"#), Ok(WireOp::Ping)));
    }

    #[test]
    fn replicate_parses_and_encodes() {
        match parse(r#"{"op":"replicate","id":7,"state":{"v":2}}"#).unwrap() {
            WireOp::Replicate { id, state } => {
                assert_eq!(id, 7);
                assert!(state.get("v").is_some());
            }
            other => panic!("wrong op {other:?}"),
        }
        // both fields are mandatory — a replica without a target id (or
        // without a payload) is meaningless
        assert!(parse(r#"{"op":"replicate","state":{"v":2}}"#).is_err());
        assert!(parse(r#"{"op":"replicate","id":7}"#).is_err());
        assert!(parse(r#"{"op":"replicate","id":-1,"state":{}}"#).is_err());
        let r = Response::Replicated { id: 7 }.to_json();
        assert_eq!(r.get("ok"), Some(&Json::Bool(true)));
        assert_eq!(r.get("id"), Some(&Json::Num(7.0)));
        assert_eq!(r.get("replica"), Some(&Json::Bool(true)));
        // the unknown-op hint advertises it
        let err = parse(r#"{"op":"replicat"}"#).unwrap_err();
        assert!(err.contains("replicate"), "{err}");
    }

    #[test]
    fn retriable_errors_carry_the_flag_plain_errors_do_not() {
        let plain = Response::error("nope").to_json();
        assert_eq!(plain.get("retriable"), None, "wire shape must not change");
        let retri = Response::error_retriable("store is sad").to_json();
        assert_eq!(retri.get("ok"), Some(&Json::Bool(false)));
        assert_eq!(retri.get("retriable"), Some(&Json::Bool(true)));
        assert_eq!(
            retri.get("error"),
            Some(&Json::Str("store is sad".into()))
        );
    }

    #[test]
    fn restore_parses_with_and_without_explicit_id() {
        match parse(r#"{"op":"restore","state":{"v":2}}"#).unwrap() {
            WireOp::Restore { id, .. } => assert_eq!(id, None),
            other => panic!("wrong op {other:?}"),
        }
        match parse(r#"{"op":"restore","state":{"v":2},"id":9}"#).unwrap() {
            WireOp::Restore { id, .. } => assert_eq!(id, Some(9)),
            other => panic!("wrong op {other:?}"),
        }
        // a present-but-malformed id must error, not silently mint
        assert!(parse(r#"{"op":"restore","state":{},"id":-3}"#).is_err());
        assert!(parse(r#"{"op":"restore","state":{},"id":1.5}"#).is_err());
        assert!(parse(r#"{"op":"restore","state":{},"id":"7"}"#).is_err());
    }

    #[test]
    fn park_and_warm_parse_and_encode() {
        match parse(r#"{"op":"park","id":3}"#).unwrap() {
            WireOp::Park { id } => assert_eq!(id, 3),
            other => panic!("wrong op {other:?}"),
        }
        match parse(r#"{"op":"warm","id":4}"#).unwrap() {
            WireOp::Warm { id } => assert_eq!(id, 4),
            other => panic!("wrong op {other:?}"),
        }
        assert!(parse(r#"{"op":"park"}"#).is_err());
        assert!(parse(r#"{"op":"warm","id":"x"}"#).is_err());
        let p = Response::Parked { id: 3 }.to_json();
        assert_eq!(p.get("ok"), Some(&Json::Bool(true)));
        assert_eq!(p.get("parked"), Some(&Json::Bool(true)));
        let w = Response::Warmed {
            id: 4,
            rehydrated: true,
        }
        .to_json();
        assert_eq!(w.get("resident"), Some(&Json::Bool(true)));
        assert_eq!(w.get("rehydrated"), Some(&Json::Bool(true)));
        // stats carries the durable-tier counters
        let st = Response::Stats(ShardStats {
            sessions: 3,
            resident: 1,
            parked: 2,
            store_bytes: 640,
            evictions: 5,
            rehydrations: 4,
            cohorts: vec![("stage1:d4".to_string(), 2)],
            ..ShardStats::default()
        })
        .to_json();
        assert_eq!(st.get("resident"), Some(&Json::Num(1.0)));
        assert_eq!(st.get("parked"), Some(&Json::Num(2.0)));
        assert_eq!(st.get("store_bytes"), Some(&Json::Num(640.0)));
        assert_eq!(st.get("evictions"), Some(&Json::Num(5.0)));
        assert_eq!(st.get("rehydrations"), Some(&Json::Num(4.0)));
        let cohorts = st.get("cohorts").and_then(|c| c.get("stage1:d4"));
        assert_eq!(cohorts, Some(&Json::Num(2.0)));
    }

    #[test]
    fn responses_encode_ok_and_error() {
        let r = Response::Stepped { y: 0.25 }.to_json();
        assert_eq!(r.get("ok"), Some(&Json::Bool(true)));
        assert_eq!(r.get("y"), Some(&Json::Num(0.25)));
        let e = Response::error("nope").to_json();
        assert_eq!(e.get("ok"), Some(&Json::Bool(false)));
        assert_eq!(e.get("error"), Some(&Json::Str("nope".into())));
        let m = Response::SteppedMany {
            ys: vec![Ok(1.0), Err("gone".into())],
        }
        .to_json();
        let ys = m.get("ys").unwrap().as_arr().unwrap();
        assert_eq!(ys[0], Json::Num(1.0));
        assert_eq!(ys[1], Json::Null);
        assert!(m.get("errors").is_some());
    }
}
